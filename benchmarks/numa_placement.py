"""NUMA lane placement: near- vs far-socket PMem, and what the placer recovers.

The paper's bandwidth figures are per-socket; Izraelevitz et al.
(arXiv:1903.05714) measure far-socket PMem access at roughly 2-3x the
near-socket cost. This sweep runs the Fig. 2 workload (concurrent
lane-striped group-commit logging) on a modeled 2-socket pool under
three placements:

  * ``near``  — every lane's CPU socket == its region's home socket;
  * ``far``   — every lane runs on the *other* socket (worst case);
  * ``placer``— :class:`repro.io.LanePlacer` decides (spread regions,
    near-first CPU assignment, adaptive group commit).

Checks: far-only placement costs >= 2x near (the Izraelevitz gap on the
modeled engine); the placer lands within 20% of near; with more lanes
than near-socket CPU capacity it degrades gracefully between near and
far; and dynamic group-commit sizing recovers most of a remote lane's
barrier overhead. A page-flush epoch (Fig. 5 side) is swept near-vs-far
too.
"""

from __future__ import annotations

import numpy as np

from repro.core import COST_MODEL
from repro.io.multilog import MultiLog
from repro.io.placer import LanePlacer
from repro.pool import Pool

from benchmarks.common import check, emit

LANES = 4
APPENDS = 512
PAYLOAD = b"x" * 48
GROUP_COMMIT = 8


class _PinnedPlacer:
    """Degenerate placer that pins every flush lane to one CPU socket
    (the benchmark's far-socket-only page-flush configuration)."""

    def __init__(self, socket: int) -> None:
        self.socket = socket

    def place(self, region_sockets):
        return [self.socket] * len(region_sockets)


def _wal_time(lane_sockets, lane_cpu, *, placer=False, lanes=LANES,
              group_commit=GROUP_COMMIT, appends=APPENDS) -> float:
    """Modeled engine time of the Fig. 2 log workload under a placement."""
    pool = Pool.create(None, 1 << 22, sockets=2)
    ml = MultiLog(pool, "wal", lanes=lanes, capacity=lanes << 18,
                  technique="zero", group_commit=group_commit,
                  lane_sockets=lane_sockets, lane_cpu_sockets=lane_cpu,
                  placer=placer)
    before = pool.stats.snapshot()
    for _ in range(appends):
        ml.append(PAYLOAD)
    ml.commit()
    return COST_MODEL.engine_time_ns(pool.stats.delta(before),
                                     active_lanes=lanes)


def _flush_time(cpu_socket) -> float:
    """Modeled engine time of one page-flush epoch (Fig. 5 side) with the
    page region homed on socket 1 and the flush lanes pinned to
    ``cpu_socket`` (None = near)."""
    pool = Pool.create(None, 1 << 23, sockets=2)
    pages = pool.pages("heap", npages=16, page_size=4096, socket=1)
    placer = None if cpu_socket is None else _PinnedPlacer(cpu_socket)
    fq = pages.flush_queue(lanes=4, placer=placer)
    for pid in range(16):
        fq.enqueue(pid, np.full(4096, pid + 1, dtype=np.uint8))
    rep = fq.flush_epoch()
    return rep.modeled_ns


def run() -> bool:
    ok = True

    # --- log side: the Fig. 2 workload under three placements ------------
    spread = [i % 2 for i in range(LANES)]
    far_cpu = [1 - s for s in spread]
    t_near = _wal_time(spread, spread)
    t_far = _wal_time(spread, far_cpu)
    t_placer = _wal_time(None, None, placer=None)   # pool placer (adaptive)
    emit("numa.wal.near", t_near / 1e3 / APPENDS, "all lanes near-socket")
    emit("numa.wal.far", t_far / 1e3 / APPENDS, "all lanes far-socket")
    emit("numa.wal.placer", t_placer / 1e3 / APPENDS, "LanePlacer placement")
    ok &= check("numa: far-socket-only costs >= 2x near (Izraelevitz gap)",
                t_far >= 2.0 * t_near, f"ratio {t_far / t_near:.2f}")
    ok &= check("numa: placer lands within 20% of near-socket-only",
                t_placer <= 1.2 * t_near,
                f"ratio {t_placer / t_near:.2f}")

    # --- under load: more lanes homed on a socket than its CPUs ----------
    # (an existing pool whose six lane regions all live on socket 0: the
    # placer keeps four near and overflows two to socket-1 CPUs, remote)
    pool = Pool.create(None, 1 << 23, sockets=2)
    tight = LanePlacer(pool.pmem, cpu_lanes_per_socket=4)
    n = 6
    ml = MultiLog(pool, "wal", lanes=n, capacity=n << 18, technique="zero",
                  group_commit=GROUP_COMMIT, lane_sockets=[0] * n,
                  placer=tight)
    remote_lanes = sum(1 for c, h in zip(ml.lane_cpu, ml.lane_sockets)
                       if c != h)
    before = pool.stats.snapshot()
    for _ in range(APPENDS):
        ml.append(PAYLOAD)
    ml.commit()
    t_loaded = COST_MODEL.engine_time_ns(pool.stats.delta(before),
                                         active_lanes=n)
    emit("numa.wal.overloaded", t_loaded / 1e3 / APPENDS,
         f"{n} lanes, {remote_lanes} remote")
    ok &= check("numa: placer spills to remote lanes only under load",
                0 < remote_lanes < n, f"{remote_lanes}/{n} remote")

    # --- dynamic group commit on a remote lane ---------------------------
    # (base k=2: a caller already batching; base=1 is a durability
    # contract the placer never overrides)
    t_static = _wal_time(spread, far_cpu, group_commit=2)
    pool = Pool.create(None, 1 << 22, sockets=2)
    ml = MultiLog(pool, "wal", lanes=LANES, capacity=LANES << 18,
                  technique="zero", group_commit=2, lane_sockets=spread,
                  lane_cpu_sockets=far_cpu, placer=LanePlacer(pool.pmem))
    before = pool.stats.snapshot()
    for _ in range(APPENDS):
        ml.append(PAYLOAD)
    ml.commit()
    t_adaptive = COST_MODEL.engine_time_ns(pool.stats.delta(before),
                                           active_lanes=LANES)
    emit("numa.wal.remote.static_k2", t_static / 1e3 / APPENDS,
         "far lanes, group_commit=2")
    emit("numa.wal.remote.adaptive_k", t_adaptive / 1e3 / APPENDS,
         f"far lanes, adaptive k -> {ml.lane_group_commit}")
    ok &= check("numa: dynamic group-commit amortizes remote barriers",
                t_adaptive < 0.7 * t_static,
                f"adaptive/static {t_adaptive / t_static:.2f}")

    # --- page-flush side (Fig. 5 epoch) ----------------------------------
    f_near = _flush_time(None)
    f_far = _flush_time(0)      # region homed on socket 1, lanes pinned to 0
    emit("numa.flush.near", f_near / 1e3, "epoch near-socket")
    emit("numa.flush.far", f_far / 1e3, "epoch far-socket")
    ok &= check("numa: far-socket page-flush epoch costs >= 1.8x near",
                f_far >= 1.8 * f_near, f"ratio {f_far / f_near:.2f}")
    return ok


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="same sweep (it is all modeled and fast); kept "
                         "for CI symmetry with benchmarks/run.py --smoke")
    ap.parse_args()
    raise SystemExit(0 if run() else 1)
