"""Cross-PR bench comparison: diff two ``BENCH_results.json`` files.

Usage::

    python benchmarks/compare.py PREV.json CURR.json [--threshold 0.10]

Rows are matched by ``(suite, name)`` on their ``us_per_call`` values
(the modeled-time column every suite emits). A row whose modeled time
grew by more than the threshold is a **regression**; the exit code is
non-zero if any exist, which is how CI gates a PR against the previous
run's uploaded artifact. Rows present on only one side (new or retired
benchmarks) are reported but never fail the gate — growing the suite
must not be penalized. Rows at (near-)zero time on either side are
skipped: they are labels, not measurements.

This same mechanism doubles as the serving **SLO gate**: the
``serve_load`` suite emits ``serve.p99.ref_admission_on`` — the
admission-controlled p99 (in us) at the reference offered load — as an
ordinary row, so a PR that regresses tail latency at the reference
load by more than the threshold (default 10%) fails CI here, with no
special-casing.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Tuple

#: below this many microseconds a row is a label, not a measurement
EPS_US = 1e-3


def load_rows(path: str) -> Dict[Tuple[str, str], float]:
    """``{(suite, row name): us_per_call}`` from one results file."""
    with open(path) as f:
        doc = json.load(f)
    rows: Dict[Tuple[str, str], float] = {}
    for suite, rec in doc.get("suites", {}).items():
        for row in rec.get("rows", []):
            rows[(suite, row["name"])] = float(row["us_per_call"])
    return rows


def compare(prev: Dict[Tuple[str, str], float],
            curr: Dict[Tuple[str, str], float],
            threshold: float) -> Tuple[list, list, list]:
    """Returns (regressions, improvements, only_one_side); each
    regression/improvement is (suite, name, prev_us, curr_us, ratio)."""
    regressions, improvements, lopsided = [], [], []
    for key in sorted(set(prev) | set(curr)):
        p, c = prev.get(key), curr.get(key)
        if p is None or c is None:
            lopsided.append((key, "new" if p is None else "removed"))
            continue
        if p < EPS_US or c < EPS_US:
            continue
        ratio = c / p
        if ratio > 1.0 + threshold:
            regressions.append((*key, p, c, ratio))
        elif ratio < 1.0 - threshold:
            improvements.append((*key, p, c, ratio))
    return regressions, improvements, lopsided


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("prev", help="previous BENCH_results.json")
    ap.add_argument("curr", help="current BENCH_results.json")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="relative modeled-time growth that counts as a "
                         "regression (default 0.10 = 10%%)")
    args = ap.parse_args()

    prev, curr = load_rows(args.prev), load_rows(args.curr)
    regressions, improvements, lopsided = compare(prev, curr, args.threshold)

    for suite, name, p, c, r in improvements:
        print(f"IMPROVED   {suite}/{name}: {p:.3f} -> {c:.3f} us "
              f"({(1 - r) * 100:.0f}% faster)")
    for key, status in lopsided:
        print(f"{status.upper():10s} {key[0]}/{key[1]}")
    for suite, name, p, c, r in regressions:
        print(f"REGRESSED  {suite}/{name}: {p:.3f} -> {c:.3f} us "
              f"(+{(r - 1) * 100:.0f}%)")

    matched = len(set(prev) & set(curr))
    print(f"# compared {matched} rows: {len(regressions)} regressed, "
          f"{len(improvements)} improved, {len(lopsided)} one-sided "
          f"(threshold {args.threshold * 100:.0f}%)")
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
