"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh) cell, three terms in seconds per step:

  compute    = HLO_FLOPs / peak_FLOP/s            (197 TFLOP/s bf16 / chip)
  memory     = HLO_bytes / HBM_bw                 (819 GB/s / chip)
  collective = collective_bytes / link_bw         (~50 GB/s/link ICI)

All three inputs are *per-device* quantities extracted from the compiled
partitioned HLO by launch/hlo_analysis.py (scan bodies × trip counts).
MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) for train; 2·N(_active)
per token for decode — the ratio MODEL/HLO exposes remat & padding waste.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional

from repro.configs import ALIASES, get_config
from repro.launch.specs import SHAPES

PEAK_FLOPS = 197e12     # bf16 / chip (v5e)
HBM_BW = 819e9          # B/s / chip (819 GB/s — the same constant as
                        # PMemCostModel.hbm_read_bw_gbps)
LINK_BW = 50e9          # B/s / ICI link


def flush_pipeline(sizes=(4 * 2**20, 256 * 2**20, 4 * 2**30),
                   dirty_frac: float = 0.01) -> List[Dict[str, Any]]:
    """Modeled HBM traffic of a delta-checkpoint scan: staged vs fused.

    Pure bandwidth math (no dry-run artifacts needed): per live buffer of
    ``nbytes``, the staged chain reads the bytes for dirty_diff, again
    for popcnt_checksum, and re-reads each dirty block for the
    delta_pack gather — ``2·nbytes + dirty·nbytes`` total; the fused
    flush_pack kernel reads them once. At v5e HBM bandwidth the ratio is
    the wall-clock headroom the fusion buys on the device side of a save
    (Wu arXiv:2005.07658: redundant flush passes dominate PMem cost;
    Izraelevitz arXiv:1903.05714: read bandwidth is the scarce axis).
    """
    rows = []
    print("buffer_MiB,dirty_frac,staged_bytes,fused_bytes,ratio,"
          "staged_ms,fused_ms")
    for nbytes in sizes:
        staged = int(2 * nbytes + dirty_frac * nbytes)
        fused = int(nbytes)
        r = {
            "buffer_bytes": nbytes, "dirty_frac": dirty_frac,
            "staged_bytes": staged, "fused_bytes": fused,
            "ratio": staged / fused,
            "staged_ms": staged / HBM_BW * 1e3,
            "fused_ms": fused / HBM_BW * 1e3,
        }
        rows.append(r)
        print(f"{nbytes / 2**20:.0f},{dirty_frac:.2f},{staged},{fused},"
              f"{r['ratio']:.2f}x,{r['staged_ms']:.3f},{r['fused_ms']:.3f}")
    print(f"# fused flush pipeline: {rows[0]['ratio']:.2f}x fewer device "
          f"bytes per delta checkpoint at any buffer size")
    return rows


def restore_pipeline(sizes=(4 * 2**20, 256 * 2**20, 4 * 2**30)
                     ) -> List[Dict[str, Any]]:
    """Modeled HBM traffic of a checkpoint restore: staged vs fused.

    The mirror of :func:`flush_pipeline` for the read-back direction.
    Per restored buffer of ``nbytes``, the staged restore reads every
    page once to popcount-verify it and again to copy it into the
    assembled image — ``2·nbytes`` total; the fused ``apply_unpack``
    kernel verifies and scatters in ONE pass — ``nbytes``. At v5e HBM
    bandwidth the 2x ratio is the device-side headroom that makes a
    restart cost what a save costs (Wu arXiv:2005.07658: restart time
    is dominated by the read-side scan; Izraelevitz arXiv:1903.05714:
    PMem read bandwidth is the axis that scales).
    """
    rows = []
    print("buffer_MiB,staged_bytes,fused_bytes,ratio,staged_ms,fused_ms")
    for nbytes in sizes:
        staged = int(2 * nbytes)
        fused = int(nbytes)
        r = {
            "buffer_bytes": nbytes,
            "staged_bytes": staged, "fused_bytes": fused,
            "ratio": staged / fused,
            "staged_ms": staged / HBM_BW * 1e3,
            "fused_ms": fused / HBM_BW * 1e3,
        }
        rows.append(r)
        print(f"{nbytes / 2**20:.0f},{staged},{fused},"
              f"{r['ratio']:.2f}x,{r['staged_ms']:.3f},{r['fused_ms']:.3f}")
    print(f"# fused restore pipeline: {rows[0]['ratio']:.2f}x fewer device "
          f"bytes per restore at any buffer size")
    return rows


def model_flops_per_device(arch: str, shape: str, ndev: int, kind: str) -> float:
    cfg = get_config(arch)
    n_active = cfg.active_param_count()
    info = SHAPES[shape]
    if kind == "train":
        tokens = info["seq"] * info["batch"]
        return 6.0 * n_active * tokens / ndev
    if kind == "prefill":
        tokens = info["seq"] * info["batch"]
        return 2.0 * n_active * tokens / ndev
    # decode: one token per sequence
    return 2.0 * n_active * info["batch"] / ndev


def load_cells(art_dir: str) -> List[Dict[str, Any]]:
    cells = []
    for fn in sorted(os.listdir(art_dir)):
        if fn.endswith(".json"):
            with open(os.path.join(art_dir, fn)) as f:
                cells.append(json.load(f))
    return cells


def analyze_cell(cell: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if cell["status"] != "ok":
        return None
    h = cell["hlo_analysis"]
    ndev = cell["ndev"]
    t_comp = h["flops"] / PEAK_FLOPS
    t_mem = h["traffic_bytes"] / HBM_BW
    t_coll = h["collective_bytes"] / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(
        ALIASES.get(cell["arch"], cell["arch"]), cell["shape"], ndev,
        cell.get("kind", "train"))
    bound = max(terms.values())
    # roofline fraction: useful model flops at peak vs the modeled step time
    frac = (mf / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "kind": cell.get("kind"),
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops": h["flops"],
        "useful_ratio": mf / h["flops"] if h["flops"] else 0.0,
        "roofline_fraction": frac,
        "temp_bytes": cell["memory"]["temp_bytes"],
    }


def _fmt(x: float) -> str:
    return f"{x:.3e}"


def run(art_dir: str = "artifacts/dryrun") -> List[Dict[str, Any]]:
    cells = load_cells(art_dir)
    rows = []
    print("arch,shape,mesh,kind,t_compute_s,t_memory_s,t_collective_s,"
          "dominant,useful_ratio,roofline_fraction,temp_GiB")
    skipped, errors = 0, 0
    for cell in cells:
        if cell["status"] == "skipped":
            skipped += 1
            print(f"{cell['arch']},{cell['shape']},{cell['mesh']},skipped,,,,,,,")
            continue
        if cell["status"] == "error":
            errors += 1
            print(f"{cell['arch']},{cell['shape']},{cell['mesh']},ERROR,,,,,,,")
            continue
        r = analyze_cell(cell)
        rows.append(r)
        print(
            f"{r['arch']},{r['shape']},{r['mesh']},{r['kind']},"
            f"{_fmt(r['t_compute_s'])},{_fmt(r['t_memory_s'])},"
            f"{_fmt(r['t_collective_s'])},{r['dominant']},"
            f"{r['useful_ratio']:.3f},{r['roofline_fraction']:.3f},"
            f"{(r['temp_bytes'] or 0) / 2**30:.1f}")
    print(f"# cells: {len(rows)} ok, {skipped} skipped, {errors} errors")
    if rows:
        worst = min(rows, key=lambda r: r["roofline_fraction"])
        coll = max(rows, key=lambda r: r["t_collective_s"] /
                   max(r["t_compute_s"] + r["t_memory_s"], 1e-12))
        print(f"# worst roofline fraction: {worst['arch']}|{worst['shape']}|"
              f"{worst['mesh']} ({worst['roofline_fraction']:.3f})")
        print(f"# most collective-bound: {coll['arch']}|{coll['shape']}|"
              f"{coll['mesh']}")
    return rows


if __name__ == "__main__":
    import sys
    flush_pipeline()
    restore_pipeline()
    art = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"
    if os.path.isdir(art):
        run(art)
