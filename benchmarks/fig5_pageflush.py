"""Paper Fig. 5: failure-atomic page flush — 16 KB pages, CoW (all lines /
dirty lines ☆) vs µLog vs Hybrid, across dirty-line counts and threads —
plus the repro.io engine's batched epoch flush swept over active lanes.

Counts come from the functional PageStore sim (exact barriers / device
blocks); time from the calibrated model incl. the multi-thread
write-combining collapse that moves the µLog crossover from ≈119 dirty
lines (1 thread) to ≈31 (7 threads). Also reproduces §3.2.1's ≈10 % win
of pvn-CoW over invalidate-CoW, and Fig. 5(b)'s throughput peak at 7-11
writer threads — both closed-form and end-to-end through the engine's
lane-partitioned flush queue.
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    COST_MODEL,
    AccessPattern,
    FlushKind,
    HybridPolicy,
    PageStoreLayout,
)
from repro.pool import Pool

from benchmarks.common import check, emit

PAGE = 16384  # 256 cache lines, as in the paper


def fresh_store(npages=2, nslots=4):
    pool = Pool.create(None, Pool.overhead_bytes()
                       + nslots * (PAGE + 4096) + 64 * 4096)
    pages = pool.pages("fig5", npages=npages, page_size=PAGE, nslots=nslots)
    return pool.pmem, pages


def measured_cost_ns(technique: str, dirty: int, threads: int) -> float:
    """Run the real protocol in the sim; convert its op counts to time."""
    pm, store = fresh_store()
    page = np.arange(PAGE, dtype=np.uint8)
    store.flush_cow(0, page)
    store.flush_cow(0, page)  # establish current + shadow
    page2 = page.copy()
    lines = list(range(dirty))  # sequential dirty run (buffer-manager-like)
    for li in lines:
        page2[li * 64 : (li + 1) * 64] ^= 0xFF
    before = pm.stats.snapshot()
    if technique == "cow":
        store.flush_cow(0, page2)
    elif technique == "cow_dirty":
        store.flush_cow(0, page2, dirty_lines=lines)
    elif technique == "cow_invalidate":
        store.flush_cow(0, page2, invalidate_first=True)
    elif technique == "mulog":
        store.flush_mulog(0, page2, lines)
    delta = pm.stats.delta(before)
    return COST_MODEL.time_ns(delta, kind=FlushKind.NT,
                              pattern=AccessPattern.SEQUENTIAL, threads=threads)


def run() -> bool:
    # closed-form policy costs need only the layout shape, no pool
    pol = HybridPolicy(PageStoreLayout(base=0, page_size=PAGE, npages=2,
                                       nslots=4))
    ok = True

    # --- (a)/(c): pages/s vs dirty lines at 1 and 7 threads -------------
    for threads in (1, 7):
        for dirty in (1, 8, 32, 64, 112, 128, 192, 256):
            cow = pol.cow_cost_ns(threads)
            mu = pol.mulog_cost_ns(dirty, threads)
            hyb = min(cow, mu)
            n_thr = threads
            for name, ns in (("cow", cow), ("mulog", mu), ("hybrid", hyb)):
                emit(f"fig5.t{threads}.d{dirty}.{name}", ns / 1000,
                     f"{n_thr / ns * 1e9:.0f}pages/s/threadgroup")

    x1, x7 = pol.crossover(1), pol.crossover(7)
    emit("fig5.crossover.t1", 0, f"{x1}dirty_lines")
    emit("fig5.crossover.t7", 0, f"{x7}dirty_lines")
    ok &= check("fig5: 1-thread crossover ≈112 (96..136)", 96 <= x1 <= 136, str(x1))
    ok &= check("fig5: 7-thread crossover ≈32 (24..40)", 24 <= x7 <= 40, str(x7))

    # --- sim-backed spot checks (barriers & device bytes are exact) ------
    # pvn-vs-invalidate: the exact claim is 3 barriers → 2 (§3.2.1); the
    # throughput delta depends on how "hot" the old slot header still is in
    # the WC buffer: flushing the same page back-to-back re-persists a hot
    # line (paper's ≈10 % sits between our cold ≈4 % and hot ≈20 % bounds).
    pm, store = fresh_store()
    page = np.arange(PAGE, dtype=np.uint8)
    store.flush_cow(0, page)
    b0 = pm.stats.barriers
    store.flush_cow(0, page)
    pvn_barriers = pm.stats.barriers - b0
    store.flush_cow(0, page, invalidate_first=True)
    inv_barriers = pm.stats.barriers - b0 - pvn_barriers
    ok &= check("fig5: pvn removes the 3rd barrier (exact count)",
                pvn_barriers == 2 and inv_barriers == 3,
                f"{inv_barriers}→{pvn_barriers}")
    cow_ns = measured_cost_ns("cow", 256, 1)
    inv_ns = measured_cost_ns("cow_invalidate", 256, 1)
    hot_gain = (1 / cow_ns) / (1 / inv_ns) - 1

    def cold_cost(invalidate: bool) -> float:
        # round-robin over many pages: old headers are cold, as in the
        # paper's background-flusher setting
        pm, store = fresh_store(npages=8, nslots=16)
        page = np.arange(PAGE, dtype=np.uint8)
        for pid in range(8):
            store.flush_cow(pid, page)
        before = pm.stats.snapshot()
        for pid in range(8):
            store.flush_cow(pid, page, invalidate_first=invalidate)
        delta = pm.stats.delta(before)
        return COST_MODEL.time_ns(delta, kind=FlushKind.NT,
                                  pattern=AccessPattern.SEQUENTIAL, threads=1) / 8

    cold_gain = cold_cost(True) / cold_cost(False) - 1
    emit("fig5.cow_pvn.hot", cow_ns / 1000, f"+{hot_gain * 100:.1f}%_vs_invalidate")
    emit("fig5.cow_pvn.cold", cold_cost(False) / 1000,
         f"+{cold_gain * 100:.1f}%_vs_invalidate")
    ok &= check("fig5: pvn gain brackets the paper's ≈10% (cold..hot)",
                0.005 < cold_gain < 0.12 and 0.08 < hot_gain < 0.40,
                f"cold={cold_gain * 100:.1f}% hot={hot_gain * 100:.1f}%")

    mu8 = measured_cost_ns("mulog", 8, 1)
    ok &= check("fig5: µLog beats CoW for few dirty lines (sim-backed)",
                mu8 < cow_ns, f"{mu8:.0f} < {cow_ns:.0f}")
    mu256 = measured_cost_ns("mulog", 256, 1)
    ok &= check("fig5: CoW beats µLog for fully-dirty pages (sim-backed)",
                cow_ns < mu256, f"{cow_ns:.0f} < {mu256:.0f}")

    # --- (b): thread scaling, full-page CoW ------------------------------
    best_t, best_rate = 0, 0.0
    for t in (1, 2, 4, 7, 9, 11, 16, 24):
        ns = pol.cow_cost_ns(t)
        rate = t / ns * 1e9
        emit(f"fig5.scaling.t{t}", ns / 1000, f"{rate:.0f}pages/s")
        if rate > best_rate:
            best_t, best_rate = t, rate
    ok &= check("fig5: aggregate throughput peaks at 7-11 threads",
                7 <= best_t <= 11, f"peak at {best_t}")

    # --- repro.io engine: batched epoch flush, lane sweep ----------------
    # The flush queue drains one epoch of dirty pages lane-partitioned;
    # modeled time is max-over-lanes on the burst curve — same shape as
    # (b), but now measured end-to-end on the REAL protocol (sim counts).
    # Aggregate throughput: constant pages PER LANE (4), so the sweep
    # measures the concurrency curve, not fixed-batch tail effects.
    def lane_rate(lanes: int) -> float:
        npages = 4 * lanes
        pool = Pool.create(None, Pool.overhead_bytes()
                           + (2 * npages + 4) * (PAGE + 4096) + 64 * 4096)
        pages = pool.pages("fig5q", npages=npages, page_size=PAGE,
                           nslots=2 * npages + 4)
        page = np.arange(PAGE, dtype=np.uint8)
        for pid in range(npages):
            pages.flush_cow(pid, page)
        fq = pages.flush_queue(lanes=lanes)
        for pid in range(npages):
            fq.enqueue(pid, page[::-1].copy())
        rep = fq.flush_epoch()
        return rep.pages / (rep.modeled_ns * 1e-9)

    rates = {}
    for lanes in (1, 2, 4, 7, 9, 12, 16):
        rates[lanes] = lane_rate(lanes)
        emit(f"fig5.engine.l{lanes}", 1e6 / rates[lanes],
             f"{rates[lanes]:.0f}pages/s")
    peak = max(rates, key=rates.get)
    ok &= check("fig5: engine epoch throughput peaks at 7-11 active lanes",
                7 <= peak <= 11, f"peak at {peak}")
    ok &= check("fig5: engine oversaturation degrades past the peak (G4)",
                rates[16] < rates[peak],
                f"{rates[16]:.0f} < {rates[peak]:.0f}pages/s")
    ok &= check("fig5: engine lanes scale below the peak (4 lanes > 2.5x 1)",
                rates[4] > 2.5 * rates[1], f"{rates[4] / rates[1]:.2f}x")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
