"""Kernel sanity benchmark: the persistence kernels against their oracles,
plus the delta-checkpoint byte savings they enable (the paper's µLog story
at checkpoint scale)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dirty_blocks, pack_delta, popcount_checksum

from benchmarks.common import check, emit


def run() -> bool:
    ok = True
    rng = np.random.default_rng(0)
    n = 1 << 20  # 4 MiB of f32 "parameters"
    snap = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    cur = np.asarray(snap).copy()
    dirty_positions = rng.choice(n, size=64, replace=False)
    cur[dirty_positions] += 1.0
    cur = jnp.asarray(cur)

    t0 = time.perf_counter()
    flags = np.asarray(dirty_blocks(cur, snap, impl="ref"))
    t1 = time.perf_counter()
    emit("kernels.dirty_diff.4MiB", (t1 - t0) * 1e6, f"{int(flags.sum())}dirty")

    idx = jnp.asarray(np.flatnonzero(flags).astype(np.int32))
    delta = pack_delta(cur, idx, impl="ref")
    full_bytes = n * 4
    delta_bytes = int(np.asarray(delta).nbytes)
    emit("kernels.delta_pack.4MiB", 0.0,
         f"{delta_bytes}B_vs_{full_bytes}B_full")
    ok &= check("kernels: sparse delta ≪ full snapshot",
                delta_bytes < 0.1 * full_bytes,
                f"{delta_bytes / full_bytes * 100:.1f}%")

    c = int(popcount_checksum(cur, impl="ref"))
    ok &= check("kernels: checksum nonzero (Zero-log cnt≠0 convention)", c != 0)

    # interpret-mode pallas vs oracle on a small slice (full sweep in tests)
    small_cur, small_snap = cur[: 1 << 16], snap[: 1 << 16]
    same = np.array_equal(
        np.asarray(dirty_blocks(small_cur, small_snap, impl="pallas")),
        np.asarray(dirty_blocks(small_cur, small_snap, impl="ref")))
    ok &= check("kernels: pallas(interpret) == oracle", same)
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
