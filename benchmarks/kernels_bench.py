"""Kernel benchmark: the fused flush + restore pipelines vs the staged
chains.

Times the persistence kernels at the full 4 MiB benchmark shape — the
staged dirty_diff → popcnt → delta_pack chain (three dispatches plus a
host round-trip, the save path before fusion) against the one-pass
``flush_pack`` kernel, and the staged popcnt-verify → scatter-apply
restore chain against the one-pass ``apply_unpack`` kernel — and
parity-checks the Pallas kernels against the oracles at the same full
shape (not a small slice).

Timed rows are this container's wall-clock (best-of-N, no TPU: Pallas
runs in interpret mode, ``auto`` dispatches the jitted oracle). The
``kernels.*.modeled_read`` rows are deterministic: modeled device bytes
read per delta checkpoint at the v5e HBM read bandwidth
(``PMemCostModel.hbm_read_bw_gbps``) — those are the stable
``compare.py`` gate targets for kernel regressions.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocks import TPU_TILE
from repro.core.costmodel import COST_MODEL
from repro.kernels import (
    apply_delta,
    apply_unpack,
    dirty_blocks,
    flush_pack,
    pack_dirty,
    popcount_blocks,
    popcount_checksum,
)

from benchmarks.common import check, emit

N = 1 << 20          # 4 MiB of f32 "parameters" — the benchmark shape
DIRTY = 64           # touched elements → up to 64 dirty 4 KiB blocks
REPS = 7


def _best_of(fn, reps: int = REPS) -> float:
    """Best-of-``reps`` wall-clock of ``fn`` in microseconds."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run() -> bool:
    ok = True
    rng = np.random.default_rng(0)
    snap = jnp.asarray(rng.standard_normal(N).astype(np.float32))
    cur = np.asarray(snap).copy()
    cur[rng.choice(N, size=DIRTY, replace=False)] += 1.0
    cur = jnp.asarray(cur)
    full_bytes = N * 4

    # --- staged chain: the save path before fusion ---------------------
    def staged():
        flags = dirty_blocks(cur, snap)
        counts = popcount_blocks(cur)
        delta, idx, k = pack_dirty(cur, flags)
        jax.block_until_ready((counts, delta))
        return flags, counts, delta, idx, k

    flags_s, counts_s, delta_s, idx_s, k = staged()     # warm + oracles
    t_dirty = _best_of(lambda: jax.block_until_ready(dirty_blocks(cur, snap)))
    emit("kernels.dirty_diff.4MiB", t_dirty, f"{k}dirty")

    delta_bytes = int(np.asarray(delta_s).nbytes)
    t_pack = _best_of(
        lambda: jax.block_until_ready(pack_dirty(cur, flags_s)[0]))
    emit("kernels.delta_pack.4MiB", t_pack,
         f"{delta_bytes}B_vs_{full_bytes}B_full")
    ok &= check("kernels: sparse delta ≪ full snapshot",
                delta_bytes < 0.1 * full_bytes,
                f"{delta_bytes / full_bytes * 100:.1f}%")

    c = int(popcount_checksum(cur, impl="ref"))
    ok &= check("kernels: checksum nonzero (Zero-log cnt≠0 convention)",
                c != 0)

    # --- fused pass, timed at the full benchmark shape ----------------
    def fused(impl: str = "auto"):
        fp = flush_pack(cur, snap, impl=impl)
        jax.block_until_ready(fp.packed)
        return fp

    fp = fused()                                        # warm
    t_staged = _best_of(lambda: staged())
    t_fused = _best_of(lambda: fused())
    emit("kernels.staged.4MiB", t_staged, "3_dispatches+host_sync")
    emit("kernels.fused.4MiB", t_fused, "1_dispatch")
    ok &= check("kernels: fused wall-clock beats staged chain at 4 MiB",
                t_fused < t_staged,
                f"{t_fused:.0f}us_vs_{t_staged:.0f}us")

    fp_pal = fused("pallas")                            # interpret off-TPU
    t_pallas = _best_of(lambda: fused("pallas"), reps=3)
    emit("kernels.pallas.4MiB", t_pallas, "interpret_mode_off_tpu")

    # --- parity at the FULL benchmark shape ----------------------------
    same = fp_pal.total == fp.total and all(
        np.array_equal(np.asarray(getattr(fp_pal, f)),
                       np.asarray(getattr(fp, f)))
        for f in ("flags", "counts", "offsets", "packed", "index"))
    ok &= check("kernels: fused pallas == oracle at 4 MiB", same)
    same_staged = (
        np.array_equal(np.asarray(fp.flags), np.asarray(flags_s))
        and np.array_equal(np.asarray(fp.counts), np.asarray(counts_s))
        and fp.total == k
        and np.array_equal(np.asarray(fp.index[:k]), np.asarray(idx_s))
        and np.array_equal(np.asarray(fp.packed[:k]), np.asarray(delta_s)))
    ok &= check("kernels: fused == staged oracles (flags/counts/packed)",
                same_staged)

    # --- modeled device reads per delta checkpoint (stable gate rows) --
    fused_bytes = full_bytes                       # one pass over the live bytes
    staged_bytes = 2 * full_bytes + k * TPU_TILE   # diff + popcnt + gather
    emit("kernels.fused.modeled_read.4MiB",
         COST_MODEL.scan_read_ns(fused_bytes) / 1e3, f"{fused_bytes}B")
    emit("kernels.staged.modeled_read.4MiB",
         COST_MODEL.scan_read_ns(staged_bytes) / 1e3, f"{staged_bytes}B")
    ratio = staged_bytes / fused_bytes
    ok &= check("kernels: fused ≥2x fewer device bytes per delta ckpt",
                ratio >= 2.0, f"{ratio:.2f}x")

    # --- restore direction: staged verify-then-apply vs fused ---------
    from repro.kernels.common import as_blocks
    blocked_all, _ = as_blocks(cur, TPU_TILE)      # restore every block
    k_all = blocked_all.shape[0]
    base = jnp.zeros_like(cur)
    idx_all = jnp.arange(k_all, dtype=jnp.int32)
    exp_all = popcount_blocks(cur, impl="ref")

    def staged_apply():
        counts = popcount_blocks(cur)              # read 1: verify
        out = apply_delta(base, blocked_all, idx_all)   # read 2: copy
        jax.block_until_ready((counts, out))
        return counts, out

    def fused_apply(impl: str = "auto"):
        res = apply_unpack(base, blocked_all, idx_all, exp_all, impl=impl)
        jax.block_until_ready(res.out)
        return res

    counts_a, out_a = staged_apply()               # warm + oracles
    res = fused_apply()                            # warm
    t_astaged = _best_of(staged_apply)
    t_afused = _best_of(lambda: fused_apply())
    emit("kernels.apply.staged.4MiB", t_astaged, "2_dispatches")
    emit("kernels.apply.fused.4MiB", t_afused, "1_dispatch")

    res_pal = fused_apply("pallas")                # interpret off-TPU
    ok &= check("kernels: apply_unpack == staged chain at 4 MiB",
                res.nbad == 0
                and np.array_equal(np.asarray(res.out), np.asarray(out_a))
                and np.array_equal(np.asarray(res.out), np.asarray(cur))
                and np.array_equal(np.asarray(res.counts),
                                   np.asarray(counts_a)))
    ok &= check("kernels: apply_unpack pallas == oracle at 4 MiB",
                res_pal.nbad == 0
                and np.array_equal(np.asarray(res_pal.out),
                                   np.asarray(res.out))
                and np.array_equal(np.asarray(res_pal.counts),
                                   np.asarray(res.counts)))
    bad_exp = jnp.asarray(exp_all).at[0].add(1)
    ok &= check("kernels: apply_unpack flags a corrupted block",
                apply_unpack(base, blocked_all, idx_all, bad_exp).nbad == 1)

    # modeled restore reads: staged = verify pass + copy pass; fused = 1
    afused_bytes = full_bytes
    astaged_bytes = 2 * full_bytes
    emit("kernels.apply.fused.modeled_read.4MiB",
         COST_MODEL.scan_read_ns(afused_bytes) / 1e3, f"{afused_bytes}B")
    emit("kernels.apply.staged.modeled_read.4MiB",
         COST_MODEL.scan_read_ns(astaged_bytes) / 1e3, f"{astaged_bytes}B")
    aratio = astaged_bytes / afused_bytes
    ok &= check("kernels: fused apply ≥2x fewer device bytes per restore",
                aratio >= 2.0, f"{aratio:.2f}x")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
