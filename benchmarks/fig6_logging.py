"""Paper Fig. 6: transaction-log throughput vs entry size —
Classic / Header(naive & 64 dancing fields) / Zero × unpadded / padded —
plus the repro.io engine's lane sweep: a lane-striped group-commit
MultiLog vs independent single-lane logs, across 1..16 lanes.

Every data point runs the REAL log writer on the functional sim (exact
barrier / block / same-line counts) and converts counts → time with the
calibrated model. Reproduces: padding ≈8×; Zero ≈2× Classic; naive Header
worst (same-line size-field rewrites); dancing restores Header to Classic;
and the Fig. 2 concurrency shape for the lane sweep (throughput rises
near-linearly below the write-combining lane limit, then flattens).
"""

from __future__ import annotations

from repro.core import COST_MODEL, AccessPattern, FlushKind, LogConfig
from repro.pool import Pool

from benchmarks.common import check, emit

N_ENTRIES = 400
CAP = 1 << 22
LANE_SWEEP = (1, 2, 3, 4, 6, 8, 12, 16)


def throughput(technique: str, entry_size: int, *, padded: bool,
               dancing: int = 1) -> float:
    """Modeled appends/second for one configuration."""
    pool = Pool.create(None, CAP + Pool.overhead_bytes())
    log = pool.log("fig6", capacity=CAP, technique=technique,
                   cfg=LogConfig(pad_to_line=padded, dancing=dancing))
    payload = bytes(entry_size)
    log.reset_stats()          # measure appends only, not pool setup
    for _ in range(N_ENTRIES):
        log.append(payload)
    ns = COST_MODEL.time_ns(log.stats(), kind=FlushKind.NT,
                            pattern=AccessPattern.SEQUENTIAL, threads=1)
    return N_ENTRIES / (ns * 1e-9)


def lane_throughput(lanes: int, *, group_commit: int = 8,
                    entry_size: int = 48) -> float:
    """Modeled appends/second of a lane-striped group-commit MultiLog —
    the engine's wall clock is the max over concurrently-active lanes."""
    pool = Pool.create(None, CAP + Pool.overhead_bytes())
    ml = pool.multilog("fig6", capacity=CAP // 2, lanes=lanes,
                       technique="zero", group_commit=group_commit)
    payload = bytes(entry_size)
    before = pool.stats.snapshot()
    for _ in range(N_ENTRIES):
        ml.append(payload)
    ml.commit()
    ns = COST_MODEL.engine_time_ns(pool.stats.delta(before),
                                   active_lanes=lanes)
    return N_ENTRIES / (ns * 1e-9)


def run() -> bool:
    ok = True
    tput = {}
    for padded in (False, True):
        for technique in ("classic", "header", "zero"):
            for size in (64, 128, 256, 512, 1024):
                tp = throughput(technique, size, padded=padded)
                tput[(technique, size, padded)] = tp
                tag = "padded" if padded else "naive"
                emit(f"fig6.{tag}.{technique}.{size}B", 1e6 / tp,
                     f"{tp / 1e6:.2f}M/s")
    for size in (64, 256):
        tp = throughput("header", size, padded=True, dancing=64)
        tput[("header64", size, True)] = tp
        emit(f"fig6.padded.header_dancing64.{size}B", 1e6 / tp, f"{tp / 1e6:.2f}M/s")

    pad_gain = tput[("zero", 64, True)] / tput[("zero", 64, False)]
    ok &= check("fig6: padding ≈8x (6..10x) for small entries",
                6.0 < pad_gain < 10.0, f"{pad_gain:.1f}x")
    z_over_c = tput[("zero", 64, True)] / tput[("classic", 64, True)]
    ok &= check("fig6: Zero ≈2x Classic (1.6..2.4x)",
                1.6 < z_over_c < 2.4, f"{z_over_c:.2f}x")
    ok &= check("fig6: naive Header slowest padded technique (size field)",
                tput[("header", 64, True)] < tput[("classic", 64, True)],
                f"{tput[('header', 64, True)]/1e6:.2f} < "
                f"{tput[('classic', 64, True)]/1e6:.2f}M/s")
    danced = tput[("header64", 64, True)] / tput[("classic", 64, True)]
    ok &= check("fig6: 64 dancing fields restore Header to Classic (±15%)",
                0.85 < danced < 1.25, f"{danced:.2f}")
    ok &= check("fig6: Zero fastest everywhere",
                all(tput[("zero", s, p)] >= max(tput[("classic", s, p)],
                                                tput[("header", s, p)])
                    for s in (64, 128, 256, 512, 1024) for p in (True, False)))

    # --- repro.io engine: group-commit lane sweep (Fig. 2 shape) ---------
    lt = {}
    for lanes in LANE_SWEEP:
        lt[lanes] = lane_throughput(lanes)
        emit(f"fig6.lanes.zero.gc8.l{lanes}", 1e6 / lt[lanes],
             f"{lt[lanes] / 1e6:.1f}M/s")
    single = tput[("zero", 64, True)]
    ok &= check("fig6: group commit (k=8) beats per-append barriers >2x",
                lt[1] > 2.0 * single,
                f"{lt[1] / 1e6:.1f} vs {single / 1e6:.1f}M/s")
    ok &= check("fig6: lanes scale below the WC limit (2 lanes > 1.5x)",
                lt[2] > 1.5 * lt[1], f"{lt[2] / lt[1]:.2f}x")
    ok &= check("fig6: throughput flattens past the WC lane limit "
                "(8 lanes < 1.25x 4 lanes, Fig. 2 shape)",
                lt[8] < 1.25 * lt[4] and lt[8] > 0.75 * lt[4],
                f"{lt[8] / lt[4]:.2f}x")
    ok &= check("fig6: oversaturation does not help (16 lanes <= peak)",
                lt[16] <= max(lt.values()),
                f"{lt[16] / 1e6:.1f} <= {max(lt.values()) / 1e6:.1f}M/s")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
