"""Cluster reshard under load: p99 during migration vs quiesced, and
bytes moved == the migrating ranges only.

The view-change protocol claims two things worth numbers:

1. **Migration traffic is bounded by the moving ranges.** A reshard
   after a checkpoint moves exactly ``len(moving_ranges) x
   pages_per_range x page_size`` page bytes and zero WAL bytes — the
   non-moving ranges contribute nothing. The check computes the
   prediction from the shard map alone and compares it to the measured
   ``ReshardReport``.
2. **Foreground p99 degrades boundedly while migrating.** The same
   deterministic op stream is priced twice on per-shard engine-time
   horizons (arrival vs completion on the ``engine_time_ns`` clock):
   once quiesced, once with one migration step interleaved every
   ``STEP_EVERY`` ops, each step's modeled cost (engine deltas + the
   ``cluster_transfer_ns`` interconnect term) charged to the source and
   target shards' horizons. Ops behind a migration step queue, but only
   behind ONE step: steps are spaced widely enough that backlogs drain,
   so p99 may exceed quiesced by at most one step's cost (and the max
   by ``P99_BOUND`` steps).

All numbers are modeled (exact sim op counts x calibrated constants);
both runs are bit-deterministic from the literal seed, which the last
check asserts by running the migrating sweep twice.

The ``cluster.p99.reshard`` row is the regression gate:
``benchmarks/compare.py`` fails CI if a PR regresses the
p99-under-migration by more than the threshold (default 10%).
"""

from __future__ import annotations

from repro.cluster import ClusterConfig, ClusterKV
from repro.core import KVConfig
from repro.core.costmodel import COST_MODEL
from repro.pool import Pool

from benchmarks.common import check, emit

N_OPS = 1200
STEP_EVERY = 150          # one migration step every this many foreground ops
INTERARRIVAL_NS = 1200.0  # open-loop arrival spacing (global stream)
P99_BOUND = 3.0           # max latency bound, in units of one step's cost
SEED = 12345


def _build():
    cfg = ClusterConfig(kv=KVConfig(npages=32, page_size=1024, value_size=64,
                                    log_capacity=1 << 17),
                        n_ranges=32)
    meta = Pool.create(None, ClusterKV.meta_pool_bytes(cfg))
    pools = {sid: Pool.create(None, ClusterKV.shard_pool_bytes(cfg))
             for sid in range(4)}
    c = ClusterKV(meta, pools, cfg, shards=range(3))
    for k in range(cfg.nkeys):
        c.put(k, bytes([k % 256]) * cfg.kv.value_size)
    c.commit()
    c.checkpoint()          # migration source = page images, WAL empty
    return cfg, c


def _op_stream(cfg, n):
    """Deterministic LCG mix: 70% get / 30% put over the key space."""
    x, ops = SEED, []
    for i in range(n):
        x = (1103515245 * x + 12345) & 0x7FFFFFFF
        key = x % cfg.nkeys
        ops.append(("put" if x % 10 < 3 else "get", key,
                    bytes(((x >> 7) + j) % 256 for j in range(64))))
    return ops


def _service_ns(c, sid, op, key, value):
    """Run one op on its owner engine and price the deltas it caused."""
    eng = c.engine(sid)
    p0 = c.pool(sid).stats.snapshot()
    c0 = eng.cache.stats.snapshot()
    if op == "put":
        c.put(key, value)
    else:
        c.get(key)
    return COST_MODEL.engine_time_ns(c.pool(sid).stats.delta(p0),
                                     cache=eng.cache.stats.delta(c0))


def _migration_step(c, vc, free, now_ns, expect):
    """One vc.step(); charge its modeled cost to the source and target
    shards' horizons and fold the flipped ranges' expected traffic
    (durable pages + WAL records committed before the flip) into
    ``expect``."""
    owners0 = dict(c.map.owners())
    cost0 = vc.engine_ns + vc.transfer_ns
    more = vc.step()
    step_ns = (vc.engine_ns + vc.transfer_ns) - cost0
    moved = [r for r, s in c.map.owners().items() if owners0[r] != s]
    expect["max_step_ns"] = max(expect["max_step_ns"], step_ns)
    for r in moved:
        expect["pages"] += c.cfg.pages_per_range
        expect["wal_records"] += expect["puts"].get(r, 0)
        for s in (owners0[r], c.map.owners()[r]):
            free[s] = max(free[s], now_ns) + step_ns / len(moved)
    return more


def _sweep(migrate: bool):
    """Price the op stream on per-shard horizons; optionally interleave
    one migration step (cluster 3 shards -> 4) every STEP_EVERY ops.
    Returns (sorted latencies us, ReshardReport or None, cluster,
    expected-traffic dict)."""
    cfg, c = _build()
    ops = _op_stream(cfg, N_OPS)
    vc = c.begin_reshard([0, 1, 2, 3]) if migrate else None
    free = {sid: 0.0 for sid in range(4)}
    # per-range count of WAL records committed and not yet migrated:
    # the exact traffic a flip of that range must move on top of pages
    expect = {"pages": 0, "wal_records": 0, "puts": {}, "max_step_ns": 0.0}
    lats, more = [], True
    for i, (op, key, value) in enumerate(ops):
        if vc is not None and more and i and i % STEP_EVERY == 0:
            more = _migration_step(c, vc, free, i * INTERARRIVAL_NS, expect)
        arrival = i * INTERARRIVAL_NS
        sid = c.owner_of(key)
        ns = _service_ns(c, sid, op, key, value)
        if op == "put":
            r = c.range_of(key)
            expect["puts"][r] = expect["puts"].get(r, 0) + 1
        start = max(arrival, free[sid])
        free[sid] = start + ns
        lats.append((free[sid] - arrival) / 1000.0)
    while vc is not None and more:    # drain remaining migration steps
        more = _migration_step(c, vc, free, N_OPS * INTERARRIVAL_NS, expect)
    return sorted(lats), (vc.report() if vc else None), c, expect


def _p(lats, q):
    return lats[min(len(lats) - 1, int(q * (len(lats) - 1)))]


def run() -> bool:
    ok = True

    quiesced, _, _, _ = _sweep(migrate=False)
    migrating, rep, c, expect = _sweep(migrate=True)
    p99_q, p99_m = _p(quiesced, 0.99), _p(migrating, 0.99)

    emit("cluster.reshard.p99_quiesced", p99_q,
         f"p50={_p(quiesced, 0.5):.3f}us max={quiesced[-1]:.3f}us n={N_OPS}")
    emit("cluster.p99.reshard", p99_m,
         f"p50={_p(migrating, 0.5):.3f}us max={migrating[-1]:.3f}us "
         f"step_every={STEP_EVERY}")
    emit("cluster.reshard.transfer", rep.transfer_ns / 1000.0,
         f"bytes={rep.bytes_moved} ranges={len(rep.ranges_moved)} "
         f"view={rep.view}")

    # -------- bytes moved == the migrating ranges, exactly --------------
    cfg = c.cfg
    pred_pages = expect["pages"] * cfg.kv.page_size
    ok &= check("cluster: reshard moved only the migrating ranges' bytes",
                rep.pages_moved == expect["pages"]
                and rep.page_bytes == pred_pages
                and rep.wal_records_moved == expect["wal_records"],
                f"pages {rep.pages_moved} == {expect['pages']}, wal "
                f"records {rep.wal_records_moved} == "
                f"{expect['wal_records']} (committed pre-flip puts)")
    ok &= check("cluster: the new shard won ranges (view advanced)",
                len(rep.ranges_moved) >= 1 and c.shards == (0, 1, 2, 3),
                f"moved {sorted(rep.ranges_moved)}")

    # -------- tail under migration: visible but bounded ------------------
    step_us = expect["max_step_ns"] / 1000.0
    ok &= check("cluster: migration is visible in the max latency",
                migrating[-1] > quiesced[-1],
                f"{migrating[-1]:.2f}us vs {quiesced[-1]:.2f}us quiesced")
    # any op waits at most ~one migration step: steps are spaced widely
    # enough (STEP_EVERY x interarrival >> step cost) that backlogs drain
    ok &= check("cluster: p99 interference bounded by one migration step",
                p99_m <= p99_q + step_us,
                f"p99 {p99_m:.2f}us <= {p99_q:.2f}us + step {step_us:.2f}us")
    ok &= check("cluster: max interference bounded by "
                f"{P99_BOUND:.0f}x one migration step",
                migrating[-1] <= quiesced[-1] + P99_BOUND * step_us,
                f"max {migrating[-1]:.2f}us <= {quiesced[-1]:.2f}us + "
                f"{P99_BOUND:.0f} x {step_us:.2f}us")

    # -------- determinism ------------------------------------------------
    migrating2, rep2, c2, _ = _sweep(migrate=True)
    ok &= check("cluster: sweep bit-stable across identical runs",
                migrating2 == migrating and rep2 == rep
                and c2.digest() == c.digest(),
                f"digest {c.digest()[:16]} both runs")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
