"""Paper Fig. 3: random-access read latency.

DRAM vs PMem (app direct) vs memory-mode with small (8 GB, DRAM-cached)
and large (360 GB, cache-thrashing) working sets. PMem = 3.2× DRAM.
"""

from __future__ import annotations

from repro.core import COST_MODEL

from benchmarks.common import check, emit


def run() -> bool:
    cm = COST_MODEL
    dram = cm.dram.load_latency_ns
    pmem = cm.load_latency_ns
    mm_small = dram * (1 + cm.memory_mode_hit_overhead)
    # 360GB working set vs ~200GB DRAM cache: miss rate ~(360-200)/360
    miss = (360 - 200) / 360
    mm_large = (1 - miss) * mm_small + miss * pmem

    emit("fig3.read_latency.dram", dram / 1000, f"{dram:.0f}ns")
    emit("fig3.read_latency.pmem", pmem / 1000, f"{pmem:.0f}ns")
    emit("fig3.read_latency.memmode_8gb", mm_small / 1000, f"{mm_small:.0f}ns")
    emit("fig3.read_latency.memmode_360gb", mm_large / 1000, f"{mm_large:.0f}ns")

    ok = True
    ok &= check("fig3: PMem read latency 3.2x DRAM",
                3.0 < pmem / dram < 3.4, f"{pmem / dram:.2f}")
    ok &= check("fig3: memory mode ~10% overhead when cached",
                1.05 < mm_small / dram < 1.15, f"{mm_small / dram:.2f}")
    ok &= check("fig3: memory mode degrades when working set >> DRAM",
                mm_large > 1.5 * dram and mm_large < pmem,
                f"{mm_large:.0f}ns")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
