"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per benchmark plus
``# CHECK PASS/FAIL`` lines for every claim validated against the paper.
Exit code is non-zero if any check fails. ``--json OUT`` additionally
writes every row and check as machine-readable JSON (per-figure modeled
times + stats), so the perf trajectory is trackable across PRs — CI
uploads it as the ``BENCH_results.json`` artifact.

Roofline/dry-run results (benchmarks/roofline.py) are included when
artifacts/dryrun/*.json exist (produced by ``python -m repro.launch.dryrun
--all --mesh both --out artifacts/dryrun``).
"""

from __future__ import annotations

import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast sanity subset (the pool-backed sim benches: "
                         "Fig.5/Fig.6/YCSB) — used by CI")
    ap.add_argument("--json", metavar="OUT", default=None,
                    help="write all rows + checks to this JSON file "
                         "(e.g. BENCH_results.json)")
    args = ap.parse_args()

    from benchmarks import (
        cluster_reshard,
        fig1_bandwidth,
        fig2_threads,
        fig3_read_latency,
        fig4_persist_latency,
        fig5_pageflush,
        fig6_logging,
        kernels_bench,
        numa_placement,
        readpath,
        restore_path,
        serve_load,
        tab_ycsb,
        tier_capacity,
    )

    suites = [
        (fig1_bandwidth, "Fig.1 bandwidth vs access granularity", False),
        (fig2_threads, "Fig.2 bandwidth vs thread count", False),
        (fig3_read_latency, "Fig.3 read latency", False),
        (fig4_persist_latency, "Fig.4 persistent-write latency", False),
        (fig5_pageflush, "Fig.5 failure-atomic page flush", True),
        (fig6_logging, "Fig.6 transaction log throughput", True),
        (tab_ycsb, "§3.3.2 YCSB validation", True),
        (tier_capacity, "Tiered storage: capacity-pressure sweep", True),
        (numa_placement, "NUMA lane placement: near vs far socket", True),
        (readpath, "Read path: DRAM cache hit-ratio x admission-k", True),
        (serve_load, "Serving: throughput vs p99, admission + isolation",
         True),
        (cluster_reshard, "Cluster: reshard under load, bytes moved + p99",
         True),
        # in smoke so CI's BENCH_results.json carries the kernels.fused.*
        # rows for compare.py's cross-PR regression gate
        (kernels_bench, "Kernels: fused flush pipeline vs staged chain",
         True),
        # in smoke: restore.fused.modeled_read.* and restore.reshard.wall.*
        # are compare.py gate rows too
        (restore_path, "Restore path: fused apply + parallel recovery",
         True),
    ]
    from benchmarks import common

    ok = True
    for mod, title, in_smoke in suites:
        if args.smoke and not in_smoke:
            continue
        print(f"\n### {title}")
        common.set_suite(mod.__name__.rsplit(".", 1)[-1])
        ok &= mod.run()

    if not args.smoke:
        from benchmarks import roofline
        print("\n### Roofline: fused flush pipeline (modeled HBM traffic)")
        common.set_suite("roofline")
        roofline.flush_pipeline()
        print("\n### Roofline: fused restore pipeline (modeled HBM traffic)")
        roofline.restore_pipeline()
        art = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
        if os.path.isdir(art) and any(f.endswith(".json") for f in os.listdir(art)):
            print("\n### Roofline (from dry-run artifacts)")
            roofline.run(art)

    if args.json:
        common.write_json(args.json)

    print(f"\n=== {'ALL CHECKS PASS' if ok else 'SOME CHECKS FAILED'} ===")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
