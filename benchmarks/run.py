"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows per benchmark plus
``# CHECK PASS/FAIL`` lines for every claim validated against the paper.
Exit code is non-zero if any check fails.

Roofline/dry-run results (benchmarks/roofline.py) are included when
artifacts/dryrun/*.json exist (produced by ``python -m repro.launch.dryrun
--all --mesh both --out artifacts/dryrun``).
"""

from __future__ import annotations

import os
import sys


def main() -> None:
    from benchmarks import (
        fig1_bandwidth,
        fig2_threads,
        fig3_read_latency,
        fig4_persist_latency,
        fig5_pageflush,
        fig6_logging,
        tab_ycsb,
    )

    ok = True
    for mod, title in (
        (fig1_bandwidth, "Fig.1 bandwidth vs access granularity"),
        (fig2_threads, "Fig.2 bandwidth vs thread count"),
        (fig3_read_latency, "Fig.3 read latency"),
        (fig4_persist_latency, "Fig.4 persistent-write latency"),
        (fig5_pageflush, "Fig.5 failure-atomic page flush"),
        (fig6_logging, "Fig.6 transaction log throughput"),
        (tab_ycsb, "§3.3.2 YCSB validation"),
    ):
        print(f"\n### {title}")
        ok &= mod.run()

    art = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
    if os.path.isdir(art) and any(f.endswith(".json") for f in os.listdir(art)):
        print("\n### Roofline (from dry-run artifacts)")
        from benchmarks import roofline
        roofline.run(art)

    print("\n### kernel sanity (interpret mode vs oracle)")
    from benchmarks import kernels_bench
    ok &= kernels_bench.run()

    print(f"\n=== {'ALL CHECKS PASS' if ok else 'SOME CHECKS FAILED'} ===")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
