"""Paper Fig. 1: PMem/DRAM bandwidth vs adjacently-accessed cache lines.

Reproduces: (a) PMem stores peak only at multiples of the 256 B block
(4 cache lines); regular stores need clwb to reach streaming performance;
(c) PMem loads show the same block granularity plus the prefetcher penalty
at ≥10 adjacent lines; (b)/(d) DRAM is flat in comparison; and the summary
ratios — write BW 7.5× and read BW 2.6× below DRAM.
"""

from __future__ import annotations

from repro.core import COST_MODEL, FlushKind

from benchmarks.common import check, emit


def run() -> bool:
    cm = COST_MODEL
    ok = True
    peaks = {}
    for kind, label in ((FlushKind.NT, "nt"), (FlushKind.CLWB, "store+clwb"),
                        (FlushKind.FLUSH, "store")):
        best = 0.0
        for lines in range(1, 17):
            bw = cm.store_bandwidth_gbps(lines, threads=24, kind=kind)
            gb_per_call = lines * 64 / 1e9
            emit(f"fig1.store.pmem.{label}.lines{lines}",
                 gb_per_call / bw * 1e6, f"{bw:.2f}GB/s")
            best = max(best, bw)
        peaks[label] = best
    for lines in range(1, 17):
        bw = cm.load_bandwidth_gbps(lines, threads=24)
        emit(f"fig1.load.pmem.lines{lines}", lines * 64 / 1e9 / bw * 1e6,
             f"{bw:.2f}GB/s")
    dram_store = cm.dram.store_bw_nt_gbps
    dram_load = cm.dram.load_bw_gbps
    emit("fig1.store.dram.nt", 64 / 1e9 / dram_store * 1e6, f"{dram_store:.2f}GB/s")
    emit("fig1.load.dram", 64 / 1e9 / dram_load * 1e6, f"{dram_load:.2f}GB/s")

    # block granularity: 4 lines strictly better than 3 or 5 per-line
    bw3 = cm.store_bandwidth_gbps(3, 24, FlushKind.NT)
    bw4 = cm.store_bandwidth_gbps(4, 24, FlushKind.NT)
    bw5 = cm.store_bandwidth_gbps(5, 24, FlushKind.NT)
    ok &= check("fig1: peak store BW at 256B multiples",
                bw4 > bw3 and bw4 > bw5, f"{bw3:.1f} < {bw4:.1f} > {bw5:.1f}")
    # clwb == streaming for stores (peak-to-peak: each kind at its best
    # thread count — nt peaks at 3 threads, clwb at 12, Fig. 2)
    peak_nt = max(cm.store_bandwidth_gbps(4, t, FlushKind.NT) for t in range(1, 49))
    peak_clwb = max(cm.store_bandwidth_gbps(4, t, FlushKind.CLWB) for t in range(1, 49))
    bw_bare = cm.store_bandwidth_gbps(4, 24, FlushKind.FLUSH)
    ok &= check("fig1: store+clwb reaches streaming BW (peak)",
                abs(peak_clwb - peak_nt) / peak_nt < 0.05,
                f"{peak_clwb:.1f}≈{peak_nt:.1f}")
    ok &= check("fig1: bare stores lose write combining",
                bw_bare < 0.55 * peak_nt, f"{bw_bare:.1f} << {peak_nt:.1f}")
    # prefetcher penalty at >=10 lines (per-line efficiency drops)
    eff9 = cm.load_bandwidth_gbps(12, 24) / cm.load_bandwidth_gbps(8, 24)
    ok &= check("fig1: prefetcher hurts loads at >=10 lines", eff9 < 1.0,
                f"ratio {eff9:.2f}")
    # summary ratios (peak vs peak, as in the paper's §2.2 summary)
    r_w = dram_store / peak_nt
    r_r = dram_load / cm.load_bandwidth_gbps(4, 24)
    ok &= check("fig1: write BW 7.5x below DRAM", 7.0 < r_w < 8.0, f"{r_w:.2f}")
    ok &= check("fig1: read BW 2.6x below DRAM", 2.3 < r_r < 2.9, f"{r_r:.2f}")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
