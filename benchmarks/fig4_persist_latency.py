"""Paper Fig. 4: persistent-write latency — same / sequential / random
cache line × flush / flushopt / clwb / streaming.

Reproduces: same-line persists are the pathology (streaming strongly
preferred there); clwb == flushopt because Cascade Lake implements clwb as
flushopt; among non-streaming variants there is "no significant
difference" within a pattern group.
"""

from __future__ import annotations

from repro.core import COST_MODEL, AccessPattern, FlushKind

from benchmarks.common import check, emit


def run() -> bool:
    cm = COST_MODEL
    table = {}
    for pat in AccessPattern:
        for kind in FlushKind:
            ns = cm.persist_latency_ns(kind, pat)
            table[(pat, kind)] = ns
            emit(f"fig4.persist.{pat.value}.{kind.value}", ns / 1000, f"{ns:.0f}ns")

    ok = True
    same, seq = AccessPattern.SAME_LINE, AccessPattern.SEQUENTIAL
    ok &= check("fig4: streaming wins on same-line writes",
                table[(same, FlushKind.NT)] < 0.4 * table[(same, FlushKind.CLWB)],
                f"{table[(same, FlushKind.NT)]:.0f} vs {table[(same, FlushKind.CLWB)]:.0f}")
    ok &= check("fig4: clwb == flushopt (Cascade Lake)",
                all(abs(table[(p, FlushKind.CLWB)] - table[(p, FlushKind.FLUSHOPT)])
                    / table[(p, FlushKind.FLUSHOPT)] < 0.05 for p in AccessPattern))
    ok &= check("fig4: same-line >> sequential for cached flushes",
                table[(same, FlushKind.CLWB)] > 3 * table[(seq, FlushKind.CLWB)],
                f"{table[(same, FlushKind.CLWB)]:.0f} vs {table[(seq, FlushKind.CLWB)]:.0f}")
    ok &= check("fig4: clflush never beats clwb/flushopt",
                all(table[(p, FlushKind.FLUSH)] >= table[(p, FlushKind.CLWB)]
                    for p in AccessPattern))
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
