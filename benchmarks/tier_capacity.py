"""Capacity-pressure sweep: working set vs a fixed PMem budget.

The paper positions PMem between DRAM and flash; this bench shows what
the tier below buys. A :class:`PersistentKV` runs the same write+
checkpoint workload at growing working-set sizes against ONE fixed PMem
pool:

* **seed engine** (no tier): classic sizing — every page needs a PMem
  slot, so once the working set outgrows the pool the engine cannot even
  be built (allocation fails).
* **tiered engine**: a fixed ``slot_budget`` of PMem slots plus the SSD
  spill tier — cold slots overflow at checkpoint epochs, the redo log
  runs lane-striped over a generation ring that checkpoints roll and the
  scheduler retires to SSD. Every point completes; modeled time degrades
  *gracefully* (the SSD's Fig. 1 latency/bandwidth gap shows up as a
  growing but bounded per-put cost, not an OOM).

Also asserted here: the lane-striped WAL runs through >= 3
checkpoint/truncate cycles with a bounded PMem log footprint (the
generation ring never grows; the retired watermark advances).
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    COST_MODEL,
    AccessPattern,
    FlushKind,
    KVConfig,
    PersistentKV,
    SSD,
)
from repro.pool import Pool

from benchmarks.common import check, emit

PAGE = 1024
VALUE = 64
BUDGET = 8            # PMem page slots available to the tiered engine
WAL_LANES = 4
LOG_CAP = 1 << 13
ROUNDS = 3            # write rounds, one checkpoint each → 3 WAL rolls
SWEEP = (4, 8, 16, 32, 64)


def _tiered_cfg(npages: int) -> KVConfig:
    return KVConfig(npages=npages, page_size=PAGE, value_size=VALUE,
                    log_capacity=LOG_CAP, slot_budget=BUDGET,
                    wal_lanes=WAL_LANES, wal_gen_sets=2, flush_lanes=4)


def _seed_cfg(npages: int) -> KVConfig:
    return KVConfig(npages=npages, page_size=PAGE, value_size=VALUE,
                    log_capacity=LOG_CAP)


def pmem_budget_bytes() -> int:
    """The fixed pool size: what the tiered engine needs at its slot
    budget (independent of the working set — that is the point)."""
    return PersistentKV.region_bytes(_tiered_cfg(max(SWEEP)))


def run_seed(npages: int, pmem_bytes: int):
    """Seed engine against the fixed budget. Returns modeled ns/put, or
    None if the pool cannot hold the working set (allocation failure)."""
    cfg = _seed_cfg(npages)
    pool = Pool.create(None, pmem_bytes)
    try:
        kv = pool.kv("kv", cfg)
    except (RuntimeError, ValueError):
        return None   # pool full: the seed engine OOMs at this size
    n = _workload(kv, cfg)
    delta = pool.stats.delta(pool.stats.__class__())  # totals since create
    ns = COST_MODEL.time_ns(delta, kind=FlushKind.NT,
                            pattern=AccessPattern.SEQUENTIAL)
    return ns / n


def run_tiered(npages: int, pmem_bytes: int):
    """Tiered engine against the same fixed budget. Returns
    (modeled ns/put incl. SSD, pages spilled, WAL generation)."""
    cfg = _tiered_cfg(npages)
    pool = Pool.create(None, pmem_bytes)
    ssd = pool.attach_ssd(SSD(1 << 26))
    kv = pool.kv("kv", cfg)
    n = _workload(kv, cfg)
    pm_delta = pool.stats.delta(pool.stats.__class__())
    pm_ns = COST_MODEL.engine_time_ns(pm_delta, kind=FlushKind.NT,
                                      pattern=AccessPattern.SEQUENTIAL,
                                      burst=True)
    from repro.core import SSD_COST_MODEL
    ssd_ns = SSD_COST_MODEL.time_ns(ssd.stats)
    spilled = kv._spill.stats.pages_spilled if kv._spill is not None else 0
    return (pm_ns + ssd_ns) / n, spilled, \
        kv.wal.generation, kv.wal.retired_upto


def _workload(kv: PersistentKV, cfg: KVConfig) -> int:
    """ROUNDS passes touching every page once, checkpoint per pass.
    Returns the number of puts."""
    rng = np.random.default_rng(0)
    n = 0
    for r in range(ROUNDS):
        for pid in range(cfg.npages):
            key = pid * cfg.recs_per_page
            kv.put(key, bytes(rng.integers(0, 256, VALUE, dtype=np.uint8)))
            n += 1
        kv.checkpoint()
    return n


def run() -> bool:
    budget = pmem_budget_bytes()
    emit("tier.pmem_budget_bytes", 0.0, f"{budget}B_{BUDGET}slots")
    ok = True
    seed_ns, tier_ns, n_spilled = {}, {}, {}
    for npages in SWEEP:
        s = run_seed(npages, budget)
        t, spilled, gen, retired = run_tiered(npages, budget)
        seed_ns[npages], tier_ns[npages] = s, t
        n_spilled[npages] = spilled
        emit(f"tier.seed.w{npages}", (s or 0.0) / 1e3,
             "alloc_fail" if s is None else f"{s:.0f}ns/put")
        emit(f"tier.spill.w{npages}", t / 1e3,
             f"{t:.0f}ns/put_spilled{spilled}_gen{gen}")

    over = [w for w in SWEEP if seed_ns[w] is None]
    under = [w for w in SWEEP if seed_ns[w] is not None]
    ok &= check("tier: seed engine fails allocation once the working set "
                "outgrows the PMem budget",
                bool(over) and max(SWEEP) in over,
                f"fails at {over}")
    ok &= check("tier: seed engine still works inside the budget",
                bool(under) and min(SWEEP) in under,
                f"completes at {under}")
    ok &= check("tier: spill engine completes EVERY point on the same "
                "budget",
                all(tier_ns[w] is not None and np.isfinite(tier_ns[w])
                    for w in SWEEP))
    # graceful degradation: once the working set is WELL past the budget
    # (>= 2x — fully in the spill regime, not the crossing ramp), each
    # further doubling costs a bounded factor — flash bandwidth, not an
    # OOM. (Crossing INTO the tier pays the Fig. 1 PMem-vs-flash gap by
    # design; the ramp between "barely spilling" and "fully spilling" is
    # part of that crossing.)
    pressure = [w for w in SWEEP if n_spilled[w] > 0 and w >= 2 * BUDGET]
    inside = [w for w in SWEEP if n_spilled[w] == 0]
    steps = [tier_ns[b] / tier_ns[a] for a, b in zip(pressure, pressure[1:])]
    ok &= check("tier: degradation under pressure is gradual "
                "(each doubling < 2x)",
                all(st < 2.0 for st in steps),
                "x".join(f"{st:.2f}" for st in steps))
    ok &= check("tier: cost grows monotonically under pressure (±5%)",
                all(st > 0.95 for st in steps))
    gap = tier_ns[pressure[0]] / tier_ns[inside[-1]]
    ok &= check("tier: crossing the budget pays the PMem-vs-flash gap "
                "(>5x, <500x)", 5.0 < gap < 500.0, f"{gap:.0f}x")

    # WAL generation roll: >= 3 checkpoint cycles, bounded PMem footprint
    _, _, gen, retired = run_tiered(max(SWEEP), budget)
    ok &= check("tier: lane-striped WAL rolled >= 3 generations "
                "(one per checkpoint)",
                gen >= ROUNDS + 1, f"gen={gen}")
    ok &= check("tier: WAL PMem footprint bounded (ring of 2 generation "
                "sets; retired watermark advances)",
                retired >= gen - 2, f"retired={retired} gen={gen}")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
