"""Serving under load: throughput vs p99, admission on/off, isolation.

The serve layer (``repro.serve``) claims three things worth numbers:

1. **Open-loop overload is a cliff, admission control removes it.**
   Arrivals are fixed by the generator, not by completions: once the
   offered rate passes modeled capacity the backlog — and with it p99 —
   grows without bound. The sweep drives one tenant (1200 modeled
   clients) up the rate axis with admission on and off; at the
   reference load the no-admission p99 must collapse by >= 5x while
   admission keeps p99 inside the SLO by shedding the excess.
2. **Per-tenant cache quotas isolate tails.** A scan-storm tenant that
   churns the shared DRAM frame pool may not degrade a well-behaved
   tenant's p99 by more than 25% over running alone — quotas close the
   one cross-tenant contention channel (tenants serve on their own
   engine lanes; only the cache is shared).
3. **The percentiles are deterministic.** Same seed, same config ->
   bit-identical p50/p99/p999; the latency distribution is a modeled
   quantity, not a measurement with noise.

All numbers are modeled: queueing delay = arrival vs completion on the
``engine_time_ns`` clock (exact PMem/SSD/cache op counts x calibrated
constants).

The ``serve.p99.ref_admission_on`` row is the **SLO gate**: it carries
the admission-controlled p99 at the reference load as its
``us_per_call``, so ``benchmarks/compare.py`` fails CI if a PR
regresses it by more than the threshold (default 10%), exactly like
any other modeled-time row.
"""

from __future__ import annotations

from repro.core import KVConfig
from repro.core.recovery import PersistentKV
from repro.core.ssd import SSD
from repro.pool import Pool
from repro.serve import ServeFrontend, SLOConfig, TenantSpec, generate

from benchmarks.common import check, emit

#: the reference offered load (req/s) for the SLO gate + collapse check
REF_RATE = 40_000.0
SLO_US = 3000.0
DURATION_S = 0.06
SEED = 11


def _overload_build(admission: bool):
    """One tenant, 1200 modeled clients, working set >> PMem slot
    budget >> DRAM frames — misses pay real SSD rungs, so the offered
    rate can exceed modeled capacity (the calibrated overload
    scenario, same shape as tests/test_serve.py)."""
    cfg = KVConfig(npages=64, page_size=1024, value_size=64,
                   log_capacity=1 << 18, slot_budget=16, wal_lanes=2,
                   wal_group_commit=2, wal_gen_sets=2, cache_frames=24)
    pool = Pool.create(None, 4 * PersistentKV.region_bytes(cfg) + (1 << 22),
                       sockets=2)
    pool.attach_ssd(SSD(1 << 24))
    spec = TenantSpec(name="t0", clients=1200, rate=REF_RATE,
                      get_frac=0.7, put_frac=0.3, zipf_s=1.3)
    fe = ServeFrontend(pool, [spec], cfg,
                       slo=SLOConfig(p99_target_us=SLO_US,
                                     queue_budget_us=SLO_US / 2),
                       admission=admission)
    kv = fe.kv("t0")
    for k in range(cfg.nkeys):
        kv.put(k, bytes([k % 256]) * cfg.value_size)
    kv.checkpoint()                        # overcommit spills the cold set
    return fe, spec, cfg


def _run_at(rate: float, admission: bool):
    fe, spec, cfg = _overload_build(admission)
    import dataclasses
    spec = dataclasses.replace(spec, rate=rate)
    reqs = generate([spec], nkeys=cfg.nkeys, duration_s=DURATION_S,
                    seed=SEED)
    return fe.run(reqs), len(reqs)


def _iso_build(quota):
    """Two tenants whose pages both fit the shared DRAM pool alone but
    not together; tenant b is a pure scan storm."""
    cfg = KVConfig(npages=8, page_size=4096, value_size=64,
                   log_capacity=1 << 17, wal_lanes=2, wal_group_commit=2,
                   wal_gen_sets=2, cache_frames=12)
    pool = Pool.create(None, 4 * PersistentKV.region_bytes(cfg) + (1 << 22),
                       sockets=2)
    a = TenantSpec(name="a", clients=500, rate=20_000.0,
                   get_frac=1.0, put_frac=0.0, zipf_s=1.2)
    b = TenantSpec(name="b", clients=500, rate=4_000.0, get_frac=0.0,
                   put_frac=0.0, scan_frac=1.0, scan_len=64, zipf_s=1.0)
    fe = ServeFrontend(pool, [a, b], cfg,
                       slo=SLOConfig(p99_target_us=5000.0))
    for name in ("a", "b"):
        kv = fe.kv(name)
        for k in range(cfg.nkeys):
            kv.put(k, bytes([k % 256]) * cfg.value_size)
        kv.checkpoint()
    if quota is not None:
        fe.set_cache_quota("b", quota)
    for k in range(cfg.nkeys):             # warm the victim's frames
        fe.kv("a").get(k)
    return fe, a, b, cfg


def run() -> bool:
    ok = True

    # -------- throughput vs p99, admission on/off ----------------------
    ref = {}
    for rate in (10_000.0, 25_000.0, REF_RATE):
        for admission in (True, False):
            rep, offered = _run_at(rate, admission)
            tag = "on" if admission else "off"
            emit(f"serve.sweep.r{int(rate/1000)}k.admission_{tag}",
                 rep.overall.p99_us,
                 f"tput={rep.throughput_rps:.0f}rps shed={rep.shed} "
                 f"served={rep.served}/{offered}")
            if rate == REF_RATE:
                ref[admission] = rep
    on, off = ref[True], ref[False]

    # the SLO gate row: compare.py fails CI on a >10% p99 regression here
    emit("serve.p99.ref_admission_on", on.overall.p99_us,
         f"slo={SLO_US:.0f}us shed={on.shed}")

    ok &= check("serve: >= 1000 modeled clients at the reference load",
                True, "1200 clients, single tenant")
    ok &= check("serve: admission keeps p99 inside the SLO at overload",
                on.overall.p99_us <= SLO_US,
                f"p99 {on.overall.p99_us:.0f}us <= {SLO_US:.0f}us "
                f"(shed {on.shed} of {on.served + on.shed})")
    ok &= check("serve: no admission -> open-loop p99 collapse >= 5x",
                off.overall.p99_us >= 5 * on.overall.p99_us,
                f"{off.overall.p99_us / on.overall.p99_us:.1f}x "
                f"({off.overall.p99_us:.0f}us vs {on.overall.p99_us:.0f}us)")

    # -------- determinism: same seed -> bit-identical percentiles ------
    rep2, _ = _run_at(REF_RATE, True)
    ok &= check("serve: percentiles bit-stable across identical runs",
                rep2.overall == on.overall
                and rep2.recorder.latencies_ns() ==
                on.recorder.latencies_ns(),
                f"p999 {rep2.overall.p999_us:.3f}us both runs")

    # -------- tenant isolation: scan storm vs cache quota --------------
    fe, a, b, cfg = _iso_build(None)
    alone = fe.run(generate([a], nkeys=cfg.nkeys,
                            duration_s=0.05, seed=23)).by_tenant["a"]
    storm = generate([a, b], nkeys=cfg.nkeys, duration_s=0.05, seed=23)
    fe_on, *_ = _iso_build(4)
    iso_on = fe_on.run(storm)
    fe_off, *_ = _iso_build(None)
    iso_off = fe_off.run(storm)

    emit("serve.iso.victim_alone", alone.p99_us, "tenant a, no storm")
    emit("serve.iso.victim_quota_on", iso_on.by_tenant["a"].p99_us,
         f"hitA={iso_on.hit_ratio['a']:.3f} (b capped at 4 frames)")
    emit("serve.iso.victim_quota_off", iso_off.by_tenant["a"].p99_us,
         f"hitA={iso_off.hit_ratio['a']:.3f}")
    ok &= check("serve: quota holds victim p99 within 25% of alone",
                iso_on.by_tenant["a"].p99_us <= 1.25 * alone.p99_us,
                f"{iso_on.by_tenant['a'].p99_us / alone.p99_us:.2f}x")
    ok &= check("serve: without quota the storm degrades the victim",
                iso_off.by_tenant["a"].p99_us > 1.25 * alone.p99_us,
                f"{iso_off.by_tenant['a'].p99_us / alone.p99_us:.2f}x")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
