"""Restore path: fused apply_unpack traffic + parallel recovery wall-clock.

The save path got its numbers (flush_pack: one HBM pass per save); this
suite gives the restart direction the same treatment — the PR's claim is
"restarts as fast as saves", and Wu (arXiv:2005.07658) measures restart
time as dominated by the read-side scan:

1. **Fused restore traffic.** Restoring a 4 MiB checkpoint through the
   staged chain reads every page twice (popcount-verify, then copy into
   the assembled image); the fused ``apply_unpack`` kernel verifies and
   scatters in ONE device pass. ``CheckpointManager.restore`` accounts
   its own read traffic (``RestoreReport.restore_read_bytes``), so the
   ≥2x claim is checked on the manager's real restore, not on an
   abstract model — and both paths must recover bit-identical state
   (fused is checked against the staged chain AND the jnp oracle).

2. **Concurrent reshard wall-clock.** A ``width=4`` view change flights
   four ranges through the copy→flush→own→invalidate protocol
   stage-interleaved; distinct src/dst engine pairs overlap on the
   modeled clock (``ReshardReport.wall_ns``), so migrating everything
   off four shards onto four fresh ones takes ≤0.6x the serial wall
   time — while the migrated bytes and the cluster digest stay
   byte-identical to the ``width=1`` run.

3. **Lane-parallel WAL replay.** The same committed writes replay on
   reopen through a 4-lane WAL at max-over-lanes cost vs a single-lane
   WAL's serial cost (``PersistentKV.last_recovery``) — Izraelevitz
   (arXiv:1903.05714): PMem read bandwidth scales with threads.

All rows are modeled (deterministic from literal seeds), so
``restore.fused.modeled_read.4MiB`` and ``restore.reshard.wall.width4``
are stable ``benchmarks/compare.py`` gate targets for the >10%
regression threshold.
"""

from __future__ import annotations

import warnings

import numpy as np

from repro.cluster import ClusterConfig, ClusterKV
from repro.core import KVConfig, PMem, PersistentKV
from repro.persistence import CheckpointConfig, CheckpointManager
from repro.pool import Pool

from benchmarks.common import check, emit

STATE_BYTES = 4 << 20          # the 4 MiB benchmark shape
PAGE_SIZE = 256 * 1024         # 16 pages per restore
SEED = 20260808


def _state():
    rng = np.random.default_rng(SEED)
    n = STATE_BYTES // 4
    return {"params": rng.standard_normal(n).astype(np.float32)}


def _restore_once(kernel_impl: str):
    """Save the 4 MiB state and restore it through one kernel dispatch;
    returns (restored state, RestoreReport)."""
    cfg = CheckpointConfig(page_size=PAGE_SIZE, manifest_capacity=1 << 16,
                           kernel_impl=kernel_impl)
    m = CheckpointManager(None, cfg)
    m.save(7, _state())
    step, got = m.restore()
    assert step == 7
    return got, m.last_restore


def _bench_restore() -> bool:
    ok = True
    want = _state()["params"]
    got_staged, rep_staged = _restore_once("staged")
    got_oracle, rep_oracle = _restore_once("auto")     # jnp oracle off-TPU
    got_pallas, rep_pallas = _restore_once("fused")    # interpret off-TPU

    emit("restore.staged.modeled_read.4MiB", rep_staged.scan_ns / 1e3,
         f"{rep_staged.restore_read_bytes}B_{rep_staged.pages_total}pages")
    emit("restore.fused.modeled_read.4MiB", rep_oracle.scan_ns / 1e3,
         f"{rep_oracle.restore_read_bytes}B_{rep_oracle.pages_total}pages")

    ratio = rep_staged.restore_read_bytes / rep_oracle.restore_read_bytes
    ok &= check("restore: fused ≥2x less read traffic than staged at 4 MiB",
                ratio >= 2.0,
                f"{rep_staged.restore_read_bytes}B vs "
                f"{rep_oracle.restore_read_bytes}B = {ratio:.2f}x")
    ok &= check("restore: fused == staged chain (bit-identical recovery)",
                np.array_equal(got_oracle["params"], got_staged["params"])
                and np.array_equal(got_staged["params"], want))
    ok &= check("restore: fused pallas == jnp oracle (bit-identical)",
                np.array_equal(got_pallas["params"], got_oracle["params"])
                and rep_pallas.restore_read_bytes
                == rep_oracle.restore_read_bytes)
    return ok


def _reshard_once(width: int):
    """Drain four shards onto four fresh ones: every range moves, and
    the src/dst engine pairs are disjoint — the width>1 overlap case."""
    cfg = ClusterConfig(kv=KVConfig(npages=64, page_size=2048, value_size=64,
                                    log_capacity=1 << 18),
                        n_ranges=16)
    meta = Pool.create(None, ClusterKV.meta_pool_bytes(cfg))
    pools = {sid: Pool.create(None, ClusterKV.shard_pool_bytes(cfg))
             for sid in range(8)}
    c = ClusterKV(meta, pools, cfg, shards=range(4))
    for k in range(cfg.nkeys):
        c.put(k, bytes([(k * 31) % 256]) * cfg.kv.value_size)
    c.commit()
    c.checkpoint()
    for k in range(0, cfg.nkeys, 5):     # post-checkpoint WAL traffic too
        c.put(k, bytes([(k * 77) % 256]) * cfg.kv.value_size)
    c.commit()
    rep = c.reshard([4, 5, 6, 7], width=width)
    return c.digest(), rep


def _bench_reshard() -> bool:
    ok = True
    d1, rep1 = _reshard_once(1)
    d4, rep4 = _reshard_once(4)

    emit("restore.reshard.wall.serial", rep1.wall_ns / 1e3,
         f"{len(rep1.ranges_moved)}ranges_{rep1.bytes_moved}B")
    emit("restore.reshard.wall.width4", rep4.wall_ns / 1e3,
         f"speedup={rep1.wall_ns / rep4.wall_ns:.2f}x")

    ok &= check("reshard: width=4 wall ≤0.6x serial modeled wall-clock",
                rep4.wall_ns <= 0.6 * rep1.wall_ns,
                f"{rep4.wall_ns / 1e3:.1f}us vs {rep1.wall_ns / 1e3:.1f}us "
                f"serial ({rep1.wall_ns / rep4.wall_ns:.2f}x)")
    ok &= check("reshard: width=4 migrated bytes byte-identical to serial",
                d4 == d1 and rep4.bytes_moved == rep1.bytes_moved
                and rep4.pages_moved == rep1.pages_moved
                and rep4.wal_records_moved == rep1.wal_records_moved
                and sorted(rep4.ranges_moved) == sorted(rep1.ranges_moved),
                f"digest {d1[:16]} both, {rep1.bytes_moved}B both")
    ok &= check("reshard: serial engine work identical at both widths",
                abs(rep4.engine_ns - rep1.engine_ns) < 1e-6 * rep1.engine_ns,
                f"{rep1.engine_ns:.0f}ns vs {rep4.engine_ns:.0f}ns")
    return ok


def _replay_once(wal_lanes: int):
    kw = dict(npages=8, page_size=1024, value_size=64,
              technique="zero", log_capacity=1 << 17)
    if wal_lanes > 1:
        kw["wal_lanes"] = wal_lanes
    cfg = KVConfig(**kw)
    pm = PMem(PersistentKV.region_bytes(cfg))
    pm.memset_zero()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        kv = PersistentKV(pm, cfg)
    for k in range(cfg.nkeys):
        kv.put(k, bytes([(k * 13) % 256]) * cfg.value_size)
    pm.crash(evict=lambda li: False)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        kv2 = PersistentKV.open(pm, cfg)
    state = [kv2.get(k) for k in range(cfg.nkeys)]
    return state, kv2.last_recovery


def _bench_replay() -> bool:
    ok = True
    s1, r1 = _replay_once(1)
    s4, r4 = _replay_once(4)

    emit("restore.replay.wall.1lane", r1.modeled_ns / 1e3,
         f"{r1.wal_entries}entries_{r1.wal_bytes}B")
    emit("restore.replay.wall.4lane", r4.modeled_ns / 1e3,
         f"active_lanes={r4.active_lanes} "
         f"speedup={r1.modeled_ns / r4.modeled_ns:.2f}x")

    ok &= check("replay: 4-lane WAL replays faster than single-lane",
                r4.active_lanes == 4 and r4.modeled_ns < r1.modeled_ns,
                f"{r4.modeled_ns:.0f}ns vs {r1.modeled_ns:.0f}ns")
    ok &= check("replay: lane-parallel replay recovers identical state",
                s4 == s1 and r4.wal_entries == r1.wal_entries)
    return ok


def run() -> bool:
    ok = _bench_restore()
    ok &= _bench_reshard()
    ok &= _bench_replay()
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
