"""Paper Fig. 2: PMem bandwidth vs thread count (4 adjacent lines).

Reproduces: streaming stores peak at ≈3 threads; store+clwb scales to
≈12; bare stores stop write-combining beyond ≈4 threads; over-saturation
degrades throughput past the peak (guideline G4).
"""

from __future__ import annotations

from repro.core import COST_MODEL, FlushKind

from benchmarks.common import check, emit


def run() -> bool:
    cm = COST_MODEL
    curves = {}
    for kind, label in ((FlushKind.NT, "nt"), (FlushKind.CLWB, "store+clwb"),
                        (FlushKind.FLUSH, "store")):
        curve = []
        for t in (1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48):
            bw = cm.store_bandwidth_gbps(4, t, kind)
            curve.append((t, bw))
            emit(f"fig2.store.pmem.{label}.t{t}", 256 / 1e9 / bw * 1e6,
                 f"{bw:.2f}GB/s")
        curves[label] = dict(curve)
    for t in (1, 4, 12, 24, 48):
        bw = cm.load_bandwidth_gbps(4, t)
        emit(f"fig2.load.pmem.t{t}", 256 / 1e9 / bw * 1e6, f"{bw:.2f}GB/s")

    ok = True
    nt = curves["nt"]
    clwb = curves["store+clwb"]
    bare = curves["store"]
    nt_peak = max(nt, key=nt.get)
    clwb_peak = max(clwb, key=clwb.get)
    ok &= check("fig2: nt stores peak at ~3 threads", 2 <= nt_peak <= 4,
                f"peak at {nt_peak}")
    ok &= check("fig2: clwb stores peak at ~12 threads", 8 <= clwb_peak <= 16,
                f"peak at {clwb_peak}")
    ok &= check("fig2: oversaturation degrades (G4)",
                nt[48] < nt[nt_peak] and clwb[48] < clwb[clwb_peak],
                f"nt {nt[48]:.1f}<{nt[nt_peak]:.1f}")
    ok &= check("fig2: bare stores collapse past 4 threads",
                bare[8] < 0.55 * clwb[8] and abs(bare[2] - clwb[2]) / clwb[2] < 0.2,
                f"t8 {bare[8]:.1f} vs {clwb[8]:.1f}")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
