"""Paper §3.3.2: write-only YCSB validation on the PersistentKV engine,
plus a multi-client sweep through the repro.io group-commit engine.

The paper integrates the three logging techniques into HyMem and reports
2.0 / 1.7 / 1.5 M txn/s (Zero / Header / Classic) on 100 %-write YCSB.
We run the same-shape experiment on our minimal engine: every txn is a
durable put through the WAL; non-logging work (hashing, record copy,
buffer-pool bookkeeping) is a fixed calibrated cost. Reported checks are
the *ordering* and the Zero-vs-Classic ratio band; the exact Header
position depends on engine details the paper does not specify (their
integrated Header variant lands between — ours uses 64 dancing fields,
which our Fig-6 microbench shows is Classic-equivalent; deviation noted).

The multi-client sweep models N YCSB clients committing through one
lane-striped MultiLog (one lane per client, k-txn group commit): txn work
runs client-parallel, logging wall-clock is the engine's max-over-lanes —
aggregate throughput rises with clients and flattens past the
write-combining lane limit (Fig. 2 shape).
"""

from __future__ import annotations

import struct

import numpy as np

from repro.core import (
    COST_MODEL,
    AccessPattern,
    FlushKind,
    KVConfig,
    LogConfig,
    PersistentKV,
)
from repro.pool import Pool

from benchmarks.common import check, emit

N_TXN = 2000
#: fixed non-logging work per YCSB txn (hash, record copy, index) —
#: calibrated so Zero lands at the paper's ≈2M txn/s absolute figure.
TXN_WORK_NS = 140.0


def run_one(technique: str) -> float:
    cfg = KVConfig(npages=16, page_size=4096, value_size=64,
                   log_capacity=1 << 21, technique=technique,
                   log=LogConfig(pad_to_line=True,
                                 dancing=64 if technique == "header" else 1))
    pool = Pool.create(None, PersistentKV.region_bytes(cfg))
    kv = pool.kv("ycsb", cfg)
    rng = np.random.default_rng(0)
    keys = rng.integers(0, cfg.nkeys, N_TXN)
    before = pool.stats.snapshot()
    for i in range(N_TXN):
        kv.put(int(keys[i]), bytes([i % 256]) * 64)
    delta = pool.stats.delta(before)
    log_ns = COST_MODEL.time_ns(delta, kind=FlushKind.NT,
                                pattern=AccessPattern.SEQUENTIAL, threads=1)
    total_ns = log_ns + N_TXN * TXN_WORK_NS
    return N_TXN / (total_ns * 1e-9)


def run_multiclient(clients: int, *, group_commit: int = 4):
    """N clients commit redo records through one group-commit MultiLog
    (one zero-log lane per client); txn work runs client-parallel.
    Returns (total txn/s, logging-only txn/s)."""
    pool = Pool.create(None, 1 << 22)
    ml = pool.multilog("ycsb", capacity=1 << 21, lanes=clients,
                       technique="zero", group_commit=group_commit)
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 1024, N_TXN)
    before = pool.stats.snapshot()
    for i in range(N_TXN):
        ml.append(struct.pack("<II", int(keys[i]), 64)
                  + bytes([i % 256]) * 64)
    ml.commit()
    log_ns = COST_MODEL.engine_time_ns(pool.stats.delta(before),
                                       active_lanes=clients)
    total_ns = log_ns + N_TXN * TXN_WORK_NS / clients
    return N_TXN / (total_ns * 1e-9), N_TXN / (log_ns * 1e-9)


def run() -> bool:
    tps = {}
    for technique in ("zero", "header", "classic"):
        tps[technique] = run_one(technique)
        emit(f"ycsb.write100.{technique}", 1e6 / tps[technique],
             f"{tps[technique] / 1e6:.2f}Mtxn/s")
    ok = True
    ok &= check("ycsb: Zero fastest (paper: 2.0 vs 1.7 vs 1.5 M)",
                tps["zero"] > tps["header"] and tps["zero"] > tps["classic"])
    ratio = tps["zero"] / tps["classic"]
    ok &= check("ycsb: Zero/Classic ratio in band (paper 1.33; sim 1.2..1.8)",
                1.2 < ratio < 1.8, f"{ratio:.2f}")
    zero_abs = tps["zero"] / 1e6
    ok &= check("ycsb: Zero absolute ≈2M txn/s (1.5..2.5)",
                1.5 < zero_abs < 2.5, f"{zero_abs:.2f}M")

    # --- multi-client sweep through the repro.io engine ------------------
    mc, mlog = {}, {}
    for clients in (1, 2, 3, 4, 6, 8, 12):
        mc[clients], mlog[clients] = run_multiclient(clients)
        emit(f"ycsb.write100.zero.gc4.c{clients}", 1e6 / mc[clients],
             f"{mc[clients] / 1e6:.2f}Mtxn/s_log{mlog[clients] / 1e6:.1f}M")
    ok &= check("ycsb: group commit lifts single-client throughput",
                mc[1] > tps["zero"],
                f"{mc[1] / 1e6:.2f} > {tps['zero'] / 1e6:.2f}M")
    ok &= check("ycsb: clients scale below the WC limit (2 > 1.5x 1)",
                mc[2] > 1.5 * mc[1], f"{mc[2] / mc[1]:.2f}x")
    # CPU-side txn work keeps scaling with client cores; the WC-defeat
    # flattening is a property of the LOGGING wall clock (Fig. 2 shape)
    ok &= check("ycsb: logging throughput flattens past the WC lane limit "
                "(Fig. 2)",
                mlog[8] < 1.25 * mlog[4] and mlog[12] <= max(mlog.values()),
                f"log 8c/4c={mlog[8] / mlog[4]:.2f}x")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
