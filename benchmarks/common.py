"""Shared benchmark helpers: CSV emission + calibrated model access +
machine-readable result collection.

Numbers come from two sources, always labeled:
  - ``counts``  — exact operation counts from the functional PMem sim
    (barriers, device blocks, same-line rewrites). Ground truth.
  - ``modeled`` — nanoseconds via the cost model calibrated to the paper's
    measured ratios (core/costmodel.py docstring lists every target).
This container has no Optane hardware; wall-clock would measure the Python
interpreter, not the algorithms.

Every ``emit``/``check`` is also recorded under the current suite (set by
``set_suite``) so ``benchmarks/run.py --json OUT`` can write a
``BENCH_results.json`` and the perf trajectory is trackable across PRs.
"""

from __future__ import annotations

import json
import sys
from typing import Any, Dict, Iterable

ROWS: list = []

#: machine-readable mirror of everything printed, grouped per suite
RESULTS: Dict[str, Any] = {"suites": {}, "ok": True}
_suite = "default"


def set_suite(name: str) -> None:
    """Group subsequent emit()/check() calls under this suite name."""
    global _suite
    _suite = name
    RESULTS["suites"].setdefault(name, {"rows": [], "checks": []})


def _suite_rec() -> Dict[str, list]:
    return RESULTS["suites"].setdefault(_suite, {"rows": [], "checks": []})


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """Print one CSV row: name,us_per_call,derived."""
    row = f"{name},{us_per_call:.4f},{derived}"
    ROWS.append(row)
    _suite_rec()["rows"].append(
        {"name": name, "us_per_call": round(us_per_call, 4), "derived": derived})
    print(row)
    sys.stdout.flush()


def check(name: str, ok: bool, detail: str = "") -> bool:
    status = "PASS" if ok else "FAIL"
    _suite_rec()["checks"].append(
        {"name": name, "ok": bool(ok), "detail": detail})
    RESULTS["ok"] = RESULTS["ok"] and bool(ok)
    print(f"# CHECK {status}: {name}  {detail}")
    return ok


def write_json(path: str) -> None:
    """Write the collected per-suite rows + checks as one JSON document."""
    doc = dict(RESULTS)
    doc["n_rows"] = sum(len(s["rows"]) for s in doc["suites"].values())
    doc["n_checks"] = sum(len(s["checks"]) for s in doc["suites"].values())
    doc["n_failed"] = sum(
        1 for s in doc["suites"].values() for c in s["checks"] if not c["ok"])
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    print(f"# wrote {doc['n_rows']} rows / {doc['n_checks']} checks -> {path}")
