"""Shared benchmark helpers: CSV emission + calibrated model access.

Numbers come from two sources, always labeled:
  - ``counts``  — exact operation counts from the functional PMem sim
    (barriers, device blocks, same-line rewrites). Ground truth.
  - ``modeled`` — nanoseconds via the cost model calibrated to the paper's
    measured ratios (core/costmodel.py docstring lists every target).
This container has no Optane hardware; wall-clock would measure the Python
interpreter, not the algorithms.
"""

from __future__ import annotations

import sys
from typing import Any, Iterable

ROWS: list = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """Print one CSV row: name,us_per_call,derived."""
    row = f"{name},{us_per_call:.4f},{derived}"
    ROWS.append(row)
    print(row)
    sys.stdout.flush()


def check(name: str, ok: bool, detail: str = "") -> bool:
    status = "PASS" if ok else "FAIL"
    print(f"# CHECK {status}: {name}  {detail}")
    return ok
