"""Read path: DRAM cache hit ratio × admission-k, on the Fig. 3 ladder.

The buffer manager (``repro.cache``) claims two things worth numbers:

1. **The ladder is real end-to-end.** A read served from a DRAM frame
   costs orders of magnitude less modeled time than re-promoting the
   page from the SSD spill tier (Fig. 3: DRAM ≪ PMem ≪ flash — the
   promotion additionally pays PMem CoW write traffic).
2. **k-touch admission dominates promote-on-first-access.** On a
   scan-dominated workload (every page touched once per pass, working
   set ≫ PMem slot budget), promote-always turns every read into an
   SSD read + PMem CoW + eviction write-back of whatever it displaced;
   ``admit_k > 1`` serves the scan out of SSD reads alone. On a skewed
   (Zipf) workload the hot set earns promotion after k touches and the
   two policies converge — k-touch must stay within 10 % of
   promote-always there (the deferred touches are a bounded one-time
   cost).

All numbers are modeled: exact op counts (PMem lanes, SSD commands,
per-tier cache hits) × the calibrated constants. Total read-path time =
``engine_time_ns`` (PMem, with DRAM hits folded in via ``cache=``) +
``SSDCostModel.time_ns`` (flash commands).
"""

from __future__ import annotations

import numpy as np

from repro.cache import BufferManager
from repro.core import COST_MODEL
from repro.core.costmodel import SSD_COST_MODEL
from repro.core.ssd import SSD
from repro.io.flushq import FlushQueue
from repro.pool import Pool
from repro.tier import SpillScheduler

from benchmarks.common import check, emit

PAGE = 4096
NPAGES = 64
NSLOTS = 8
FRAMES = 16


def build(admit_k: int):
    """A tiered page region with a bounded cache, pre-populated so most
    pages are SSD-resident (working set ≫ slot budget)."""
    pool = Pool.create(None, 1 << 24)
    ssd = SSD(1 << 24)
    pool.attach_ssd(ssd)
    sp = SpillScheduler(pool, name="sp", map_capacity=1 << 16)
    pages = pool.pages("heap", npages=NPAGES, page_size=PAGE, nslots=NSLOTS)
    sp.attach_pages(pages)
    fq = FlushQueue(pages, lanes=4, spill=sp)
    cache = pool.cache(frames=FRAMES, admit_k=admit_k)
    cache.attach_pages(pages, flushq=fq, spill=sp)
    rng = np.random.default_rng(0)
    for pid in range(NPAGES):
        cache.put(pid, rng.integers(0, 256, PAGE, dtype=np.uint8))
        if pid % NSLOTS == NSLOTS - 1:
            cache.writeback()
    cache.writeback()
    sp.ensure_slots(pages.store, need=NSLOTS)   # cold-start: all on SSD
    cache.invalidate()
    return pool, ssd, sp, pages, cache


def run_workload(accesses, admit_k: int):
    """Replay a pid access stream through the cache; returns the modeled
    total ns (PMem engine + SSD device + DRAM hits) and the stat deltas."""
    pool, ssd, sp, pages, cache = build(admit_k)
    pm0 = pool.stats.snapshot()
    ssd0 = ssd.stats.snapshot()
    c0 = cache.stats.snapshot()
    for pid in accesses:
        cache.get(int(pid))
    pm = pool.stats.delta(pm0)
    ssd_d = ssd.stats.delta(ssd0)
    c = cache.stats.delta(c0)
    total = (COST_MODEL.engine_time_ns(pm, active_lanes=1, cache=c)
             + SSD_COST_MODEL.time_ns(ssd_d))
    return total, c, sp


def scan_stream(passes: int):
    """Sequential passes over the whole page set (touch count per page =
    number of passes)."""
    return np.tile(np.arange(NPAGES), passes)


def zipf_stream(n: int):
    """Zipf(1.4)-ranked accesses: a hot head touched constantly, a cold
    tail touched rarely."""
    rng = np.random.default_rng(7)
    ranks = np.minimum(rng.zipf(1.4, n) - 1, NPAGES - 1)
    perm = rng.permutation(NPAGES)
    return perm[ranks]


def run() -> bool:
    ok = True

    # -------- rung costs: one DRAM hit vs one SSD promotion ------------
    dram_hit_ns = COST_MODEL.dram.read_ns(PAGE)
    # measure a real promotion: single hot page, admit on first touch
    pool, ssd, sp, pages, cache = build(admit_k=1)
    pm0, ssd0 = pool.stats.snapshot(), ssd.stats.snapshot()
    cache.get(0)                                   # SSD read + CoW promote
    promo_ns = (COST_MODEL.engine_time_ns(pool.stats.delta(pm0),
                                          active_lanes=1)
                + SSD_COST_MODEL.time_ns(ssd.stats.delta(ssd0)))
    emit("readpath.dram_hit", dram_hit_ns / 1000, f"{dram_hit_ns:.0f}ns")
    emit("readpath.ssd_promotion", promo_ns / 1000, f"{promo_ns:.0f}ns")
    ok &= check("readpath: DRAM hit >= 10x cheaper than SSD promotion",
                promo_ns > 10 * dram_hit_ns,
                f"{promo_ns / dram_hit_ns:.0f}x")

    # -------- scan-dominated: admission should refuse the churn --------
    scan = scan_stream(passes=2)
    t_always, c_always, _ = run_workload(scan, admit_k=1)
    t_ktouch, c_ktouch, _ = run_workload(scan, admit_k=3)
    emit("readpath.scan.promote_always", t_always / 1000,
         f"promos={c_always.promotions}")
    emit("readpath.scan.ktouch_k3", t_ktouch / 1000,
         f"promos={c_ktouch.promotions} hit={c_ktouch.hit_ratio:.2f}")
    ok &= check("readpath: k-touch beats promote-always on a scan",
                t_ktouch < t_always,
                f"{t_always / t_ktouch:.2f}x faster")
    ok &= check("readpath: scan under k-touch promotes ~nothing",
                c_ktouch.promotions <= NPAGES // 8,
                f"{c_ktouch.promotions} promotions")

    # -------- skewed (Zipf): the policies must converge ----------------
    zipf = zipf_stream(1500)
    z_always, cz_always, _ = run_workload(zipf, admit_k=1)
    z_ktouch, cz_ktouch, _ = run_workload(zipf, admit_k=3)
    emit("readpath.zipf.promote_always", z_always / 1000,
         f"promos={cz_always.promotions} hit={cz_always.hit_ratio:.2f}")
    emit("readpath.zipf.ktouch_k3", z_ktouch / 1000,
         f"promos={cz_ktouch.promotions} hit={cz_ktouch.hit_ratio:.2f}")
    ok &= check("readpath: k-touch within 10% of promote-always on Zipf",
                z_ktouch <= 1.10 * z_always,
                f"{z_ktouch / z_always:.3f}x")
    ok &= check("readpath: Zipf hot set served from DRAM",
                cz_ktouch.hit_ratio > 0.5,
                f"hit ratio {cz_ktouch.hit_ratio:.2f}")

    # -------- hit-ratio × admission-k sweep ----------------------------
    for k in (1, 2, 4):
        t, c, _ = run_workload(zipf, admit_k=k)
        emit(f"readpath.sweep.zipf_k{k}", t / 1000,
             f"hit={c.hit_ratio:.2f} promos={c.promotions} "
             f"deferred={c.admissions_deferred}")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
