"""Read path: DRAM cache hit ratio × admission-k, on the Fig. 3 ladder.

The buffer manager (``repro.cache``) claims two things worth numbers:

1. **The ladder is real end-to-end.** A read served from a DRAM frame
   costs orders of magnitude less modeled time than re-promoting the
   page from the SSD spill tier (Fig. 3: DRAM ≪ PMem ≪ flash — the
   promotion additionally pays PMem CoW write traffic).
2. **k-touch admission dominates promote-on-first-access.** On a
   scan-dominated workload (every page touched once per pass, working
   set ≫ PMem slot budget), promote-always turns every read into an
   SSD read + PMem CoW + eviction write-back of whatever it displaced;
   ``admit_k > 1`` serves the scan out of SSD reads alone. On a skewed
   (Zipf) workload the hot set earns promotion after k touches and the
   two policies converge — k-touch must stay within 10 % of
   promote-always there (the deferred touches are a bounded one-time
   cost).

All numbers are modeled: exact op counts (PMem lanes, SSD commands,
per-tier cache hits) × the calibrated constants. Total read-path time =
``engine_time_ns`` (PMem, with DRAM hits folded in via ``cache=``) +
``SSDCostModel.time_ns`` (flash commands).
"""

from __future__ import annotations

import numpy as np

from repro.cache import BufferManager
from repro.core import COST_MODEL
from repro.core.costmodel import SSD_COST_MODEL
from repro.core.ssd import SSD
from repro.io.flushq import FlushQueue
from repro.pool import Pool
from repro.tier import SpillScheduler

from benchmarks.common import check, emit

PAGE = 4096
NPAGES = 64
NSLOTS = 8
FRAMES = 16


def build(admit_k: int):
    """A tiered page region with a bounded cache, pre-populated so most
    pages are SSD-resident (working set ≫ slot budget)."""
    pool = Pool.create(None, 1 << 24)
    ssd = SSD(1 << 24)
    pool.attach_ssd(ssd)
    sp = SpillScheduler(pool, name="sp", map_capacity=1 << 16)
    pages = pool.pages("heap", npages=NPAGES, page_size=PAGE, nslots=NSLOTS)
    sp.attach_pages(pages)
    fq = FlushQueue(pages, lanes=4, spill=sp)
    cache = pool.cache(frames=FRAMES, admit_k=admit_k)
    cache.attach_pages(pages, flushq=fq, spill=sp)
    rng = np.random.default_rng(0)
    for pid in range(NPAGES):
        cache.put(pid, rng.integers(0, 256, PAGE, dtype=np.uint8))
        if pid % NSLOTS == NSLOTS - 1:
            cache.writeback()
    cache.writeback()
    sp.ensure_slots(pages.store, need=NSLOTS)   # cold-start: all on SSD
    cache.invalidate()
    return pool, ssd, sp, pages, cache


def run_workload(accesses, admit_k: int):
    """Replay a pid access stream through the cache; returns the modeled
    total ns (PMem engine + SSD device + DRAM hits) and the stat deltas."""
    pool, ssd, sp, pages, cache = build(admit_k)
    pm0 = pool.stats.snapshot()
    ssd0 = ssd.stats.snapshot()
    c0 = cache.stats.snapshot()
    for pid in accesses:
        cache.get(int(pid))
    pm = pool.stats.delta(pm0)
    ssd_d = ssd.stats.delta(ssd0)
    c = cache.stats.delta(c0)
    total = (COST_MODEL.engine_time_ns(pm, active_lanes=1, cache=c)
             + SSD_COST_MODEL.time_ns(ssd_d))
    return total, c, sp


def scan_stream(passes: int):
    """Sequential passes over the whole page set (touch count per page =
    number of passes)."""
    return np.tile(np.arange(NPAGES), passes)


def zipf_stream(n: int):
    """Zipf(1.4)-ranked accesses: a hot head touched constantly, a cold
    tail touched rarely."""
    rng = np.random.default_rng(7)
    ranks = np.minimum(rng.zipf(1.4, n) - 1, NPAGES - 1)
    perm = rng.permutation(NPAGES)
    return perm[ranks]


# All-near Zipf k=3 total from the pre-NUMA cost model: with zero remote
# fills the surcharge term is exactly 0.0, so the unified model must
# reproduce this value BIT-identically (not approximately).
ALL_NEAR_GOLDEN_NS = 50701019.22264716


def fill_heavy(socket: int):
    """A fill-dominated read stream against a region homed on ``socket``
    of a two-socket pool (every get misses: 48 pages, 8 frames, no
    re-touch), priced by the unified model. The only difference between
    socket 0 and socket 1 is where the fills come from."""
    pool = Pool.create(None, 1 << 25, sockets=2)
    pages = pool.pages("r", npages=48, page_size=PAGE, socket=socket)
    fq = FlushQueue(pages, lanes=2)
    cache = BufferManager(pool, frames=8, local_socket=0)
    cache.attach_pages(pages, flushq=fq)
    for pid in range(48):
        cache.put(pid, np.full(PAGE, 3, dtype=np.uint8))
        if pid % 8 == 7:
            cache.writeback()
    cache.writeback()
    cache.invalidate()
    pm0, c0 = pool.stats.snapshot(), cache.stats.snapshot()
    for pid in range(48):
        cache.get(pid)
    pm, c = pool.stats.delta(pm0), cache.stats.delta(c0)
    return COST_MODEL.engine_time_ns(pm, active_lanes=1, cache=c), c


def numa_sweep(numa_evict: bool):
    """Mixed-socket sweep: a Zipf-style hot head on the near socket is
    read under a cold far-socket ingest sweep (RMW writes fault far
    pages in, periodic epoch drains keep them clean and evictable). The
    socket-blind clock churns the near hot set; far-first eviction
    recycles the far-filled frames instead. Returns the modeled engine
    total, the remote penalty actually charged, the stat delta and the
    hot-set hit ratio."""
    hot_n, frames, passes, epoch_every = 16, 24, 2, 8
    pool = Pool.create(None, 1 << 25, sockets=2)
    near = pool.pages("near", npages=32, page_size=PAGE, socket=0)
    far = pool.pages("far", npages=128, page_size=PAGE, socket=1)
    fq_n = FlushQueue(near, lanes=2)
    fq_f = FlushQueue(far, lanes=2)
    cache = BufferManager(pool, frames=frames, local_socket=0)
    cache.numa_evict = numa_evict
    cache.attach_pages(near, flushq=fq_n)
    cache.attach_pages(far, flushq=fq_f)
    for pid in range(32):
        cache.put(pid, np.full(PAGE, 1, dtype=np.uint8), store=near)
        if pid % 8 == 7:
            cache.writeback(store=near)
    for pid in range(128):
        cache.put(pid, np.full(PAGE, 2, dtype=np.uint8), store=far)
        if pid % 8 == 7:
            cache.writeback(store=far)
    cache.writeback(store=near)
    cache.writeback(store=far)
    cache.invalidate(store=near)
    cache.invalidate(store=far)
    for pid in range(hot_n):                  # warm + graduate the hot set
        cache.get(pid, store=near)
        cache.get(pid, store=near)
    pm0, c0 = pool.stats.snapshot(), cache.stats.snapshot()
    hot_hits = hot_tot = hi = dirt = 0
    for _ in range(passes):
        for spid in range(128):
            pid = hi % hot_n
            hi += 1
            before = cache.stats.dram_hits
            cache.get(pid, store=near)
            hot_tot += 1
            hot_hits += cache.stats.dram_hits - before
            cache.write(spid, 64, b"\xbb" * 64, store=far)
            dirt += 1
            if dirt % epoch_every == 0:
                cache.writeback(store=far)
    cache.writeback(store=far)
    cache.writeback(store=near)
    pm, c = pool.stats.delta(pm0), cache.stats.delta(c0)
    eng = COST_MODEL.engine_time_ns(pm, active_lanes=1, cache=c)
    penalty = COST_MODEL.remote_fill_ns(c.remote_fills, c.remote_fill_bytes)
    return eng, penalty, c, hot_hits / hot_tot


def scan_resist(scan_frac, with_scan: bool = True):
    """Hot-set hit ratio of a quota'd owner under a 2-pass ingest scan
    (sequential puts — the access shape that laps the clock). Returns
    (hot hit ratio, modeled read-path ns)."""
    quota, hot_n, scan_hi, passes, epoch_every = 16, 8, 64, 2, 24
    pool = Pool.create(None, 1 << 22)
    pages = pool.pages("heap", npages=128, page_size=PAGE)
    fq = FlushQueue(pages, lanes=2)
    cache = BufferManager(pool, frames=quota, scan_frac=scan_frac)
    cache.attach_pages(pages, flushq=fq)
    cache.set_quota("heap", quota)
    for pid in range(hot_n):                  # warm + graduate the hot set
        cache.get(pid)
        cache.get(pid)
    c0 = cache.stats.snapshot()
    scan_pids = list(range(hot_n, scan_hi)) * passes if with_scan else []
    hot_hits = hot_tot = 0
    dirt = 0
    for i in range(max(len(scan_pids), (scan_hi - hot_n) * passes)):
        before = cache.stats.dram_hits
        cache.get(i % hot_n)
        hot_tot += 1
        hot_hits += cache.stats.dram_hits - before
        if i < len(scan_pids):
            cache.put(scan_pids[i],
                      np.full(PAGE, scan_pids[i] % 251, dtype=np.uint8))
            dirt += 1
            if dirt % epoch_every == 0:
                cache.writeback()
    cache.writeback()
    c = cache.stats.delta(c0)
    return hot_hits / hot_tot, COST_MODEL.readpath_time_ns(c)


def run() -> bool:
    ok = True

    # -------- rung costs: one DRAM hit vs one SSD promotion ------------
    dram_hit_ns = COST_MODEL.dram.read_ns(PAGE)
    # measure a real promotion: single hot page, admit on first touch
    pool, ssd, sp, pages, cache = build(admit_k=1)
    pm0, ssd0 = pool.stats.snapshot(), ssd.stats.snapshot()
    cache.get(0)                                   # SSD read + CoW promote
    promo_ns = (COST_MODEL.engine_time_ns(pool.stats.delta(pm0),
                                          active_lanes=1)
                + SSD_COST_MODEL.time_ns(ssd.stats.delta(ssd0)))
    emit("readpath.dram_hit", dram_hit_ns / 1000, f"{dram_hit_ns:.0f}ns")
    emit("readpath.ssd_promotion", promo_ns / 1000, f"{promo_ns:.0f}ns")
    ok &= check("readpath: DRAM hit >= 10x cheaper than SSD promotion",
                promo_ns > 10 * dram_hit_ns,
                f"{promo_ns / dram_hit_ns:.0f}x")

    # -------- scan-dominated: admission should refuse the churn --------
    scan = scan_stream(passes=2)
    t_always, c_always, _ = run_workload(scan, admit_k=1)
    t_ktouch, c_ktouch, _ = run_workload(scan, admit_k=3)
    emit("readpath.scan.promote_always", t_always / 1000,
         f"promos={c_always.promotions}")
    emit("readpath.scan.ktouch_k3", t_ktouch / 1000,
         f"promos={c_ktouch.promotions} hit={c_ktouch.hit_ratio:.2f}")
    ok &= check("readpath: k-touch beats promote-always on a scan",
                t_ktouch < t_always,
                f"{t_always / t_ktouch:.2f}x faster")
    ok &= check("readpath: scan under k-touch promotes ~nothing",
                c_ktouch.promotions <= NPAGES // 8,
                f"{c_ktouch.promotions} promotions")

    # -------- skewed (Zipf): the policies must converge ----------------
    zipf = zipf_stream(1500)
    z_always, cz_always, _ = run_workload(zipf, admit_k=1)
    z_ktouch, cz_ktouch, _ = run_workload(zipf, admit_k=3)
    emit("readpath.zipf.promote_always", z_always / 1000,
         f"promos={cz_always.promotions} hit={cz_always.hit_ratio:.2f}")
    emit("readpath.zipf.ktouch_k3", z_ktouch / 1000,
         f"promos={cz_ktouch.promotions} hit={cz_ktouch.hit_ratio:.2f}")
    ok &= check("readpath: k-touch within 10% of promote-always on Zipf",
                z_ktouch <= 1.10 * z_always,
                f"{z_ktouch / z_always:.3f}x")
    ok &= check("readpath: Zipf hot set served from DRAM",
                cz_ktouch.hit_ratio > 0.5,
                f"hit ratio {cz_ktouch.hit_ratio:.2f}")

    # -------- hit-ratio × admission-k sweep ----------------------------
    for k in (1, 2, 4):
        t, c, _ = run_workload(zipf, admit_k=k)
        emit(f"readpath.sweep.zipf_k{k}", t / 1000,
             f"hit={c.hit_ratio:.2f} promos={c.promotions} "
             f"deferred={c.admissions_deferred}")

    # -------- NUMA: remote fills on the Izraelevitz read rung ----------
    # All-near runs must price BIT-identically to the pre-NUMA model:
    # the surcharge is (mult-1)*pmem_read_ns(fills, bytes), exactly 0.0
    # at zero remote fills. The Zipf k=3 run above is single-socket.
    ok &= check("readpath: all-near fills bit-identical to pre-NUMA model",
                cz_ktouch.remote_fills == 0
                and z_ktouch == ALL_NEAR_GOLDEN_NS,
                f"{z_ktouch!r} vs golden {ALL_NEAR_GOLDEN_NS!r}, "
                f"remote_fills={cz_ktouch.remote_fills}")
    t_nearf, c_nearf = fill_heavy(0)
    t_farf, c_farf = fill_heavy(1)
    emit("readpath.numa.remote_fill.near", t_nearf / 1000,
         f"fills={c_nearf.pmem_fills} remote={c_nearf.remote_fills}")
    emit("readpath.numa.remote_fill.far", t_farf / 1000,
         f"fills={c_farf.pmem_fills} remote={c_farf.remote_fills}")
    ok &= check("readpath: far-fill-heavy charged >= 2x the near run",
                t_farf >= 2.0 * t_nearf, f"{t_farf / t_nearf:.2f}x")

    # -------- NUMA: far-first eviction on a mixed-socket sweep ---------
    e_blind, pen_blind, c_blind, hit_blind = numa_sweep(numa_evict=False)
    e_far, pen_far, c_far, hit_far = numa_sweep(numa_evict=True)
    emit("readpath.numa.sweep.socket_blind", e_blind / 1000,
         f"hot_hit={hit_blind:.2f} remote={c_blind.remote_fills}")
    emit("readpath.numa.sweep.far_first", e_far / 1000,
         f"hot_hit={hit_far:.2f} remote={c_far.remote_fills}")
    recovered = (e_blind - e_far) / pen_blind
    ok &= check("readpath: far-first recovers >= 25% of the remote penalty",
                recovered >= 0.25, f"{recovered:.0%} of "
                f"{pen_blind / 1000:.1f}us penalty")

    # -------- scan resistance: probationary segment vs the churn -------
    hit_free, t_free = scan_resist(0.25, with_scan=False)
    hit_split, t_split = scan_resist(0.25)
    hit_churn, t_churn = scan_resist(1.0)
    emit("readpath.scan_resist.scan_free", t_free / 1000,
         f"hot_hit={hit_free:.2f}")
    emit("readpath.scan_resist.frac25", t_split / 1000,
         f"hot_hit={hit_split:.2f}")
    emit("readpath.scan_resist.frac100", t_churn / 1000,
         f"hot_hit={hit_churn:.2f}")
    ok &= check("readpath: scan_frac keeps hot-set hits within 5% of "
                "scan-free", hit_split >= hit_free - 0.05,
                f"{hit_split:.2f} vs scan-free {hit_free:.2f}")
    ok &= check("readpath: the full-quota clock does churn under the scan",
                hit_churn <= hit_free - 0.25,
                f"{hit_churn:.2f} vs scan-free {hit_free:.2f}")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
