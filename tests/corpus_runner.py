"""Shared crash-scenario runners — one body per crash property.

Each function here is the full body of one hypothesis crash property,
parameterized by the generated values: the ``tests/*_props.py`` suites
wrap them in ``@given`` (randomized search, needs the ``test`` extra),
and ``tests/test_crash_corpus.py`` replays a checked-in seed corpus
through the *same* bodies deterministically — so the crash properties
run (not skip) in tier-1 even where hypothesis is not installed.

Keeping a single body per property means a seed that once found a bug
stays a regression test forever, and the two suites can never assert
different things.
"""

import os

import numpy as np

from repro.cache import BufferManager
from repro.core import KVConfig, PMem, PersistentKV
from repro.core.ssd import SSD
from repro.io.flushq import FlushQueue
from repro.io.multilog import MultiLog
from repro.pool import Pool
from repro.tier import SpillScheduler

__all__ = [
    "SimCrash",
    "CrashAt",
    "run_kv_crash",
    "run_multilog_crash",
    "run_pool_alloc_crash",
    "run_generation_spill_crash",
    "run_page_spill_crash",
    "run_cache_crash",
    "run_cache_restore_crash",
    "run_ckpt_fused_crash",
    "run_restore_fused_crash",
    "run_serve_crash",
    "run_cluster_crash",
]


class SimCrash(BaseException):
    """Raised by the failpoint to cut a spill protocol mid-flight.
    Derived from BaseException so no protocol-level handler can eat it."""


class CrashAt:
    """Failpoint callable: crash at the Nth protocol point reached."""

    def __init__(self, n: int) -> None:
        self.n = n
        self.seen = 0

    def __call__(self, point: str) -> None:
        self.seen += 1
        if self.seen == self.n:
            raise SimCrash(point)


# ========================================================== KV crash (core)

def make_kv(technique="zero", **kw):
    kw.setdefault("log_capacity", 1 << 15)
    cfg = KVConfig(npages=4, page_size=1024, value_size=64,
                   technique=technique, **kw)
    pm = PMem(PersistentKV.region_bytes(cfg))
    pm.memset_zero()
    return pm, PersistentKV(pm, cfg), cfg


def run_kv_crash(technique, ops, ckpt_every, seed, prob):
    """Every committed put survives an arbitrary crash; recovered values
    are exactly the last committed value per key."""
    pm, kv, cfg = make_kv(technique)
    expected = {}
    for i, (k, v) in enumerate(ops):
        value = bytes([(v + j) % 256 for j in range(64)])
        kv.put(k, value)
        expected[k] = value
        if ckpt_every and (i + 1) % ckpt_every == 0:
            kv.checkpoint()
    pm.crash(rng=np.random.default_rng(seed), evict_prob=prob)
    kv2 = PersistentKV.open(pm, cfg)
    for k, value in expected.items():
        assert kv2.get(k) == value


# ================================================= cross-lane log recovery

def run_multilog_crash(technique, lanes, group_commit, n_entries,
                       commit_after, seed, prob, lane_sockets=None,
                       lane_cpu_sockets=None, sockets=1):
    """Cross-lane crash property: whatever durable-line subset a crash
    leaves behind, a MultiLog recovers entries forming EXACTLY the global
    LSNs 1..m, with correct payloads, covering at least every entry
    appended before the last full commit(); and the repaired log accepts
    new appends that extend the prefix with no duplicate LSNs.

    ``lane_sockets``/``lane_cpu_sockets``/``sockets`` exercise the same
    property under NUMA placements — placement is a performance hint and
    must never change what recovers.
    """
    pool = Pool.create(None, 1 << 21, sockets=sockets)
    ml = MultiLog(pool, "ml", lanes=lanes, capacity=1 << 19,
                  technique=technique, group_commit=group_commit,
                  lane_sockets=lane_sockets,
                  lane_cpu_sockets=lane_cpu_sockets)
    payloads = {}
    committed_through = 0
    for i in range(n_entries):
        glsn = ml.append(b"payload-%04d-%d" % (i, seed % 97))
        payloads[glsn] = b"payload-%04d-%d" % (i, seed % 97)
        if i in commit_after:
            ml.commit()
            committed_through = glsn
    pool.pmem.crash(rng=np.random.default_rng(seed), evict_prob=prob)

    pool2 = Pool.open(pmem=pool.pmem)
    ml2 = MultiLog(pool2, "ml")
    rec = ml2.recovered
    m = len(rec.glsns)
    assert rec.glsns == list(range(1, m + 1))          # contiguous prefix
    assert m >= committed_through                       # commits survive
    for glsn, payload in zip(rec.glsns, rec.entries):
        assert payload == payloads[glsn]
    # appending continues cleanly after the truncation repair
    new_glsn = ml2.append(b"post-crash", sync=True)
    assert new_glsn == m + 1
    rec2 = ml2.recover()
    assert rec2.glsns == list(range(1, m + 2))
    assert rec2.entries[-1] == b"post-crash"
    return rec


# ======================================================== pool allocation

def run_pool_alloc_crash(n_entries, payload, crash_stage, seed, prob):
    """A crash at ANY point of a region allocation, with ANY eviction
    subset, never corrupts previously committed regions — the directory
    recovers every committed record and its contents bit-exact."""
    import repro.core.directory as directory_mod
    from repro.core.directory import KIND_LOG

    pool = Pool.create(None, 1 << 19)
    log = pool.log("committed", capacity=1 << 14, technique="zero")
    appended = []
    for i in range(n_entries):
        log.append(payload + bytes([i]))
        appended.append(payload + bytes([i]))
    rec_a = pool.regions()["committed"]
    img_a = pool.pmem.durable_view()[rec_a.base : rec_a.base + rec_a.length].copy()

    # drive the allocation protocol up to the chosen crash point
    d = pool.directory
    rec, slot = d._place("newborn", KIND_LOG, 1 << 14, (2, 1, 1, 0))
    if crash_stage in ("initialized", "entry_stored"):
        d._initialize(rec)
    if crash_stage == "entry_stored":
        entry = directory_mod._ENTRY.pack(
            b"newborn", rec.kind, rec.generation, rec.base, rec.length,
            *rec.meta)
        pool.pmem.store(d._entry_off(slot), entry, streaming=True)
        # no fence: durability of the entry is up to spontaneous eviction
    pool.pmem.crash(rng=np.random.default_rng(seed), evict_prob=prob)

    pool2 = Pool.open(pmem=pool.pmem)
    got_a = pool2.regions()["committed"]
    assert (got_a.base, got_a.length, got_a.meta) == \
        (rec_a.base, rec_a.length, rec_a.meta)
    img2 = pool.pmem.durable_view()[rec_a.base : rec_a.base + rec_a.length]
    assert np.array_equal(img2, img_a), "committed region not bit-exact"
    assert pool2.log("committed").recovered.entries == appended

    if "newborn" in pool2.regions():
        # only possible in the entry_stored stage, and only as a valid
        # empty region over durably zeroed space
        assert crash_stage == "entry_stored"
        assert pool2.log("newborn").recovered.entries == []


# ================================================== crash-during-spill

def run_generation_spill_crash(lanes, gen_sets, group_commit, per_gen,
                               crash_step, seed, pmem_prob, ssd_keep):
    """Roll several WAL generations, crash at an arbitrary point inside
    the spill drain (plus arbitrary device-level durability subsets), and
    assert every generation recovers complete from exactly the tier the
    durable watermark names."""
    pool = Pool.create(None, 1 << 21)
    ssd = SSD(1 << 22)
    pool.attach_ssd(ssd)
    sp = SpillScheduler(pool, name="sp", map_capacity=1 << 13)
    ml = MultiLog(pool, "wal", lanes=lanes, capacity=1 << 13,
                  gen_sets=gen_sets, group_commit=group_commit)
    ml.attach_spill(sp)

    contents = {}          # gen -> full payload list
    gen = 1
    committed_live = 0
    crashed = False
    sp.failpoints = CrashAt(crash_step)
    try:
        for count in per_gen:
            contents[gen] = [b"g%d-e%d" % (gen, i) for i in range(count)]
            for p in contents[gen]:
                ml.append(p)
            ml.roll()           # seals gen; may force a drain (failpoints!)
            gen += 1
        contents[gen] = [b"g%d-live" % gen]
        ml.append(contents[gen][0])
        ml.commit()
        committed_live = 1
        sp.drain()              # retire whatever is still queued
    except SimCrash:
        crashed = True

    # power failure: arbitrary surviving subsets on both devices
    rng = np.random.default_rng(seed)
    pool.pmem.crash(rng=rng, evict_prob=pmem_prob)
    ssd.crash(rng=rng, keep_prob=ssd_keep)

    pool2 = Pool.open(pmem=pool.pmem)
    pool2.attach_ssd(ssd)
    sp2 = SpillScheduler(pool2, name="sp")
    ml2 = MultiLog(pool2, "wal")
    ml2.attach_spill(sp2)

    assert ml2.retired_upto < ml2.current_gen
    resident_window = range(ml2.retired_upto + 1, ml2.current_gen + 1)
    for g in range(1, ml2.current_gen + 1):
        if g <= ml2.retired_upto:
            # the watermark says SSD: the copy there must be COMPLETE —
            # the watermark only advances after the device flush and the
            # checksummed map record
            src, entries = ml2.read_generation(g)
            assert src == "ssd"
            assert [bytes(e) for e in entries] == contents[g], g
        elif g < ml2.current_gen:
            # sealed but unretired: wholly from PMem, bit-exact (the SSD
            # may hold a torn partial copy — it must never be consulted)
            assert g in resident_window
            src, entries = ml2.read_generation(g)
            assert src == "pmem"
            assert [bytes(e) for e in entries] == contents[g], g
        else:
            # the live generation: a durable prefix covering every commit
            src, entries = ml2.read_generation(g)
            assert src == "pmem"
            got = [bytes(e) for e in entries]
            assert got == contents.get(g, [])[: len(got)]
            if not crashed:
                assert len(got) >= committed_live

    # …and CONTINUE: roll through the whole ring after recovery. No
    # generation sealed before the crash may be lost to ring reuse (the
    # orphaned-generation regression: sealed-but-unretired generations
    # must be re-enqueued on attach_spill, not silently discarded).
    resume = ml2.current_gen
    for _ in range(ml2.gen_sets):
        ml2.append(b"post")
        ml2.roll()
    sp2.drain()
    for g in range(1, resume):
        src, entries = ml2.read_generation(g)
        assert [bytes(e) for e in entries] == contents[g], (g, src)


def run_cache_crash(frames, admit_k, ops, epoch_every, crash_step, seed,
                    pmem_prob, ssd_keep):
    """The DRAM buffer manager is volatile by construction: the SAME op
    stream, run once with a warm ``frames``-frame cache and once with
    ``frames=0`` (no cache at all), crashed at the SAME spill-protocol
    point with the SAME device rngs, must recover IDENTICAL state — and
    that state must be correct (each flushed page recovers its last
    drained epoch's image or the in-flight epoch's, from exactly one
    tier).

    The stream mixes writes (dirty frames pending write-back at crash
    time), reads of spilled pages (k-touch admission: the crash can land
    mid-promotion), and reads of fresh pages. Parity holds because dirty
    data only ever reaches PMem through the shared flush queue and
    promotions fire on the k-th touch in both runs; the scenario keeps
    each epoch's dirty set within the frame budget (a clock-evicted
    dirty frame parks in the queue — still DRAM — but shifts the
    drain order a frameless run never sees)."""
    npages, page_size, nslots = 16, 512, 4

    def one_run(nframes):
        pool = Pool.create(None, 1 << 21)
        ssd = SSD(1 << 22)
        pool.attach_ssd(ssd)
        sp = SpillScheduler(pool, name="sp", map_capacity=1 << 13)
        pages = pool.pages("heap", npages=npages, page_size=page_size,
                           nslots=nslots)
        sp.attach_pages(pages)
        fq = FlushQueue(pages, lanes=2, spill=sp)
        cache = BufferManager(pool, frames=nframes, admit_k=admit_k)
        cache.attach_pages(pages, flushq=fq, spill=sp)

        flushed = {}    # pid -> content of the last DRAINED epoch
        pending = {}    # pid -> content dirty in DRAM (frame or queue)
        sp.failpoints = CrashAt(crash_step)
        try:
            for i, (op, pid, fill) in enumerate(ops):
                if op == "w":
                    img = np.full(page_size, fill, dtype=np.uint8)
                    cache.put(pid, img)
                    pending[pid] = img
                else:
                    got = cache.get(pid)
                    want = pending.get(pid, flushed.get(pid))
                    if want is not None:
                        assert bytes(got) == bytes(want), (i, pid)
                if (i + 1) % epoch_every == 0:
                    cache.writeback()
                    flushed.update(pending)
                    pending.clear()
            cache.writeback()
            flushed.update(pending)
            pending.clear()
        except SimCrash:
            pass

        rng = np.random.default_rng(seed)
        pool.pmem.crash(rng=rng, evict_prob=pmem_prob)
        ssd.crash(rng=rng, keep_prob=ssd_keep)

        pool2 = Pool.open(pmem=pool.pmem)
        pool2.attach_ssd(ssd)
        sp2 = SpillScheduler(pool2, name="sp")
        pages2 = pool2.pages("heap")
        sp2.attach_pages(pages2)
        recovered = {}
        for pid in range(npages):
            try:
                recovered[pid] = bytes(
                    sp2.read_page(pages2.store, pid, promote=False))
            except KeyError:
                pass    # page in neither tier
        # correctness: every drained page recovers one of its two
        # legitimate images, never a torn mix, never anything older
        for pid, img in flushed.items():
            acceptable = {bytes(img)}
            if pid in pending:
                acceptable.add(bytes(pending[pid]))
            assert recovered.get(pid) in acceptable, pid
        return recovered

    warm = one_run(frames)
    cold = one_run(0)
    assert warm == cold, \
        "recovered state diverged between a warm cache and frames=0"


def run_cache_restore_crash(frames, admit_k, epoch_every, n_evict_writes,
                            crash_step, seed, pmem_prob, ssd_keep):
    """Restore after dirty eviction: a write burst past the frame budget
    clock-evicts dirty frames, PARKING their images in the flush queue
    (still DRAM); then a snapshot restore invalidates the cache and
    rewrites part of the page table. ``invalidate()`` must pop those
    parked images along with the frames — a survivor would ride the next
    epoch drain and flush pre-restore bytes over the restored (or the
    untouched durable) pages. The crux is the pids the restore does NOT
    rewrite: ``put()``/``install()`` supersede a parked image for the
    pids they touch, so only the invalidate-time purge protects the
    rest.

    A crash failpoint is armed across the whole run (baseline drain,
    restore drain, post-restore drain), and as in ``run_cache_crash``
    the SAME scenario runs warm and with ``frames=0`` and must recover
    IDENTICAL state — and no pid may EVER recover phase-B bytes, since
    those images never legitimately left DRAM."""
    npages, page_size, nslots = 16, 512, 4

    def one_run(nframes):
        pool = Pool.create(None, 1 << 21)
        ssd = SSD(1 << 22)
        pool.attach_ssd(ssd)
        sp = SpillScheduler(pool, name="sp", map_capacity=1 << 13)
        pages = pool.pages("heap", npages=npages, page_size=page_size,
                           nslots=nslots)
        sp.attach_pages(pages)
        fq = FlushQueue(pages, lanes=2, spill=sp)
        cache = BufferManager(pool, frames=nframes, admit_k=admit_k)
        cache.attach_pages(pages, flushq=fq, spill=sp)

        flushed = {}    # pid -> content of the last DRAINED epoch
        pending = {}    # pid -> content dirty in DRAM (frame or queue)
        stale = {}      # pid -> phase-B content discarded at restore
        sp.failpoints = CrashAt(crash_step)
        try:
            # Phase A — durable baseline over every pid.
            for pid in range(npages):
                img = np.full(page_size, 1 + pid, dtype=np.uint8)
                cache.put(pid, img)
                pending[pid] = img
                if (pid + 1) % epoch_every == 0:
                    cache.writeback()
                    flushed.update(pending)
                    pending.clear()
            cache.writeback()
            flushed.update(pending)
            pending.clear()

            # Phase B — dirty burst past the frame budget. Clock-evicted
            # dirty frames park in the flush queue; nothing drains.
            for i in range(n_evict_writes):
                pid = i % npages
                img = np.full(page_size, 100 + pid, dtype=np.uint8)
                cache.put(pid, img)
                pending[pid] = img

            # Restore — drop ALL DRAM state (frames AND parked images),
            # rewrite the lower half of the page table from a snapshot,
            # warm two upper pids with their still-durable baseline, and
            # leave the REST of the upper half untouched: for those pids
            # no put/install supersedes the parked image, so only the
            # invalidate-time purge stands between their phase-B bytes
            # and the restore drain.
            stale.update(pending)
            pending.clear()
            cache.invalidate()
            for pid in range(npages // 2):
                img = np.full(page_size, 200 + pid, dtype=np.uint8)
                cache.put(pid, img)
                pending[pid] = img
            for pid in range(npages // 2, npages // 2 + 2):
                cache.install(pid, np.full(page_size, 1 + pid,
                                           dtype=np.uint8))
            cache.writeback()
            flushed.update(pending)
            pending.clear()

            # Phase C — post-restore writes, spanning both halves.
            for pid in (1, 3, npages - 2):
                img = np.full(page_size, 60 + pid, dtype=np.uint8)
                cache.put(pid, img)
                pending[pid] = img
            cache.writeback()
            flushed.update(pending)
            pending.clear()
        except SimCrash:
            pass

        rng = np.random.default_rng(seed)
        pool.pmem.crash(rng=rng, evict_prob=pmem_prob)
        ssd.crash(rng=rng, keep_prob=ssd_keep)

        pool2 = Pool.open(pmem=pool.pmem)
        pool2.attach_ssd(ssd)
        sp2 = SpillScheduler(pool2, name="sp")
        pages2 = pool2.pages("heap")
        sp2.attach_pages(pages2)
        recovered = {}
        for pid in range(npages):
            try:
                recovered[pid] = bytes(
                    sp2.read_page(pages2.store, pid, promote=False))
            except KeyError:
                pass
        # Never-resurrect: phase-B bytes only ever existed in DRAM and
        # were discarded by invalidate() — no crash point may expose
        # them. (Fill ranges are disjoint: A=1.., B=100.., R=200..,
        # C=60.., so a match can only be a genuine resurrection.)
        for pid, img in stale.items():
            assert recovered.get(pid) != bytes(img), \
                f"pre-restore bytes resurrected on pid {pid}"
        # Correctness: every drained page recovers its last drained
        # epoch's image or the in-flight one, never anything else.
        for pid, img in flushed.items():
            acceptable = {bytes(img)}
            if pid in pending:
                acceptable.add(bytes(pending[pid]))
            assert recovered.get(pid) in acceptable, pid
        return recovered

    warm = one_run(frames)
    cold = one_run(0)
    assert warm == cold, \
        "recovered state diverged between a warm cache and frames=0"


def run_page_spill_crash(nslots, writes, crash_step, seed, pmem_prob,
                         ssd_keep):
    """Flush epochs over an overcommitted store with a crash at an
    arbitrary point inside the eviction protocol: every flushed page
    recovers, from exactly one tier, either its last completed epoch's
    image or the in-flight epoch's (a page flush is failure-atomic) —
    never a torn mix, never anything older."""
    pool = Pool.create(None, 1 << 21)
    ssd = SSD(1 << 22)
    pool.attach_ssd(ssd)
    sp = SpillScheduler(pool, name="sp", map_capacity=1 << 13)
    pages = pool.pages("heap", npages=16, page_size=512, nslots=nslots)
    sp.attach_pages(pages)
    fq = FlushQueue(pages, lanes=2, spill=sp)

    flushed = {}        # pid -> content of the last DRAINED epoch
    pending = {}        # pid -> content enqueued for the in-flight epoch
    sp.failpoints = CrashAt(crash_step)
    try:
        for i, (pid, fill) in enumerate(writes):
            img = np.full(512, fill, dtype=np.uint8)
            fq.enqueue(pid, img)
            pending[pid] = img
            if (i + 1) % 8 == 0:
                fq.flush_epoch()
                flushed.update(pending)
                pending.clear()
        fq.flush_epoch()
        flushed.update(pending)
        pending.clear()
    except SimCrash:
        pass

    rng = np.random.default_rng(seed)
    pool.pmem.crash(rng=rng, evict_prob=pmem_prob)
    ssd.crash(rng=rng, keep_prob=ssd_keep)

    pool2 = Pool.open(pmem=pool.pmem)
    pool2.attach_ssd(ssd)
    sp2 = SpillScheduler(pool2, name="sp")
    pages2 = pool2.pages("heap")
    sp2.attach_pages(pages2)
    for pid, img in flushed.items():
        got = bytes(sp2.read_page(pages2.store, pid, promote=False))
        acceptable = {bytes(img)}
        if pid in pending:   # the crashed epoch may have flushed it already
            acceptable.add(bytes(pending[pid]))
        assert got in acceptable, pid


# ============================================= crash-mid-fused-flush (ckpt)

def run_ckpt_fused_crash(tmpdir, sparse_positions, crash_step, seed, prob):
    """Kill a checkpoint save's epoch drain after ``crash_step - 1`` page
    flushes, then crash the device with an arbitrary eviction subset —
    once with the fused ``flush_pack`` scan (``kernel_impl="fused"``) and
    once with the staged dirty_diff → popcnt → compaction chain
    (``"staged"``). Both runs must recover the SAME committed step with
    byte-identical state: the fused kernel changes how dirtiness is
    computed, never what the shadow-slot protocol makes durable.

    The save sequence is full → full rewrite → sparse → sparse, so the
    armed save (the second sparse one) takes the µLog shadow-slot path
    and the crash lands mid-delta, not just mid-CoW."""
    from repro.persistence import CheckpointConfig, CheckpointManager

    def one_run(impl):
        path = os.path.join(tmpdir, "ckpt-%s.pmem" % impl)
        # 128 KiB pages (32 dirty lines each): the geometry where the
        # hybrid policy actually has a µLog region below the crossover
        cfg = CheckpointConfig(page_size=128 * 1024,
                               manifest_capacity=1 << 16, kernel_impl=impl)
        m = CheckpointManager(path, cfg)
        base = np.random.default_rng(7).standard_normal(131072)
        s = {"w": base.astype(np.float32)}           # 512 KiB → 4 pages
        m.save(0, s)
        s = {"w": s["w"] + 1.0}                      # full rewrite
        m.save(1, s)
        s = {"w": s["w"].copy()}                     # sparse #1 (CoW: the
        for p in sparse_positions:                   # delta unions with the
            s["w"][p] += 1.0                         # full-rewrite dirt)
        m.save(2, s)
        committed = {k: v.copy() for k, v in s.items()}
        s = {"w": s["w"].copy()}                     # sparse #2 → µLog
        for p in sparse_positions:
            s["w"][p] += 1.0
        fp = CrashAt(crash_step)
        orig = m._flushq._flush_fn
        def failing(pid, page, dirty, active):
            fp("ckpt_page_flush")
            return orig(pid, page, dirty, active)
        m._flushq._flush_fn = failing
        crashed = False
        try:
            rep = m.save(3, s)
        except SimCrash:
            crashed = True
        m.pmem.crash(rng=np.random.default_rng(seed), evict_prob=prob)

        m2 = CheckpointManager(path, cfg)
        step, got = m2.restore()
        if crashed:
            # uncommitted save: exactly the last committed cut comes back
            assert step == 2
            want = committed
        else:
            assert step == 3 and rep.pages_mulog >= 1
            want = s
        for k in want:
            assert np.array_equal(got[k], want[k]), (impl, step, k)
        return crashed, step, {k: got[k].tobytes() for k in sorted(got)}

    fused = one_run("fused")
    staged = one_run("staged")
    assert fused == staged, \
        "recovery diverged between the fused and staged scan pipelines"


# ============================================ crash-mid-fused-restore

def run_restore_fused_crash(tmpdir, sparse_positions, crash_step, seed,
                            prob):
    """Kill a restore mid-apply — after ``crash_step - 1`` leaf
    assemblies (the per-leaf ``apply_unpack`` dispatch, or the staged
    verify-then-copy chain) — after the device already crashed with an
    arbitrary eviction subset. Restore is read-only: the interrupted
    attempt must leave the durable cut untouched, so a fresh manager
    recovers the full committed step byte-identically. Run once under
    ``kernel_impl="fused"`` and once under ``"staged"``; both must
    produce the SAME (crashed, step, bytes) tuple — the fused kernel
    changes how verification and assembly are scheduled, never what the
    manifest protocol can recover.

    Three leaves of different sizes/dtypes give three apply points per
    manifest entry, so crash steps 1–3 land mid-entry."""
    from repro.persistence import CheckpointConfig, CheckpointManager

    def one_run(impl):
        path = os.path.join(tmpdir, "restore-%s.pmem" % impl)
        cfg = CheckpointConfig(page_size=128 * 1024,
                               manifest_capacity=1 << 16, kernel_impl=impl)
        m = CheckpointManager(path, cfg)
        base = np.random.default_rng(11).standard_normal(131072)
        s = {"w": base.astype(np.float32),                # 512 KiB
             "b": np.arange(8192, dtype=np.float32),     # 32 KiB
             "step_mask": np.arange(4096, dtype=np.uint32)}
        m.save(0, s)
        s = {k: v.copy() for k, v in s.items()}
        for p in sparse_positions:
            s["w"][p] += 1.0
        m.save(1, s)
        committed = {k: v.copy() for k, v in s.items()}
        m.pmem.crash(rng=np.random.default_rng(seed), evict_prob=prob)

        m2 = CheckpointManager(path, cfg)
        fp = CrashAt(crash_step)
        # one failpoint per leaf assembly, whichever chain runs it
        for name in ("_fused_assemble", "_staged_assemble"):
            orig = getattr(m2, name)
            def failing(pages, csums, verify, _orig=orig):
                fp("restore_apply")
                return _orig(pages, csums, verify)
            setattr(m2, name, failing)
        crashed = False
        try:
            step, got = m2.restore()
        except SimCrash:
            crashed = True
        if not crashed:
            assert step == 1
            for k in committed:
                assert np.array_equal(got[k], committed[k]), (impl, k)

        # the aborted restore mutated nothing durable: a fresh manager
        # recovers the same committed cut, bit for bit
        m3 = CheckpointManager(path, cfg)
        step3, got3 = m3.restore()
        assert step3 == 1
        for k in committed:
            assert np.array_equal(got3[k], committed[k]), (impl, k)
        return crashed, step3, {k: got3[k].tobytes() for k in sorted(got3)}

    fused = one_run("fused")
    staged = one_run("staged")
    assert fused == staged, \
        "restore recovery diverged between fused and staged apply"


# ================================================ crash-mid-request-batch

def run_serve_crash(n_requests, wl_seed, crash_step, seed, prob, *,
                    admission=True, slo_us=500.0):
    """Crash the serving frontend at an arbitrary protocol point inside
    a request batch (``req_applied`` / ``batch_commit``), then crash the
    device with an arbitrary eviction subset. Per tenant, the recovered
    KV must hold exactly the replay of its recovered WAL prefix — a
    contiguous prefix of the puts the frontend *applied*, in admit
    order, covering at least every put whose batch finished committing.
    Admitted-but-uncommitted requests recover as if they had been shed:
    their (request-unique) values are absent, and a key they alone
    touched reads as never written."""
    from repro.serve import ServeFrontend, SLOConfig, TenantSpec, generate

    cfg = KVConfig(npages=8, page_size=1024, value_size=64,
                   log_capacity=1 << 17, wal_lanes=2, wal_group_commit=2,
                   wal_gen_sets=2, auto_checkpoint=False)
    pool = Pool.create(None,
                       2 * PersistentKV.region_bytes(cfg) + (1 << 21),
                       sockets=2)
    tenants = [
        TenantSpec(name="t0", clients=40, rate=40_000.0,
                   get_frac=0.4, put_frac=0.6),
        TenantSpec(name="t1", clients=40, rate=40_000.0,
                   get_frac=0.3, put_frac=0.5, scan_frac=0.2, scan_len=4),
    ]
    reqs = generate(tenants, nkeys=cfg.nkeys, duration_s=0.2,
                    seed=wl_seed, limit=n_requests)
    assert len(reqs) == n_requests
    fe = ServeFrontend(pool, tenants, cfg,
                       slo=SLOConfig(p99_target_us=slo_us),
                       admission=admission,
                       failpoints=CrashAt(crash_step),
                       record_applied=True)
    crashed = False
    try:
        fe.run(reqs)
    except SimCrash:
        crashed = True
    if crash_step > 2 * n_requests:
        assert not crashed          # sized to land beyond the run

    pool.pmem.crash(rng=np.random.default_rng(seed), evict_prob=prob)
    pool2 = Pool.open(pmem=pool.pmem)
    for tname in ("t0", "t1"):
        kv2 = pool2.kv(tname, cfg)
        applied = [(k, v) for (t, k, v) in fe.applied_puts if t == tname]
        floor = fe.committed_puts(tname)
        m = len(kv2.wal.recovered.entries)
        # the WAL recovers a contiguous prefix of this tenant's applied
        # puts, at least through the last completed batch commit
        assert floor <= m <= len(applied), (tname, floor, m, len(applied))
        expected = {}
        for k, v in applied[:m]:
            expected[k] = v
        zero = bytes(cfg.value_size)
        for k in range(cfg.nkeys):
            got = kv2.get(k)
            if k in expected:
                assert got == expected[k], (tname, k)
            else:
                # uncommitted (or shed) puts recover as never-written —
                # values are request-unique, so any leak would show here
                assert got == zero, (tname, k)
    return crashed


# ================================================== crash-mid-reshard

def run_cluster_crash(nshards, new_nshards, n_ops, ckpt, crash_step, seed,
                      prob, *, tiered=False, ssd_keep=1.0,
                      resume_interleave=False, width=1):
    """Crash a live view change at an arbitrary protocol point (the
    router's failpoints: view:started, then per moving range copy:page*,
    copy:wal*, flush:done, own:committed, invalidate:done, finally
    view:committed), then crash every device with arbitrary eviction
    subsets. The recovered cluster must answer every committed put with
    its last committed value, from a map in which every range is owned
    by EXACTLY its pre-reshard owner or exactly its rendezvous target —
    never both tiers of the handoff, never neither. Resuming the
    interrupted reshard must converge to the target view, re-migrating
    only the ranges whose ownership record had not flipped, and must
    leave the sources durably scrubbed.

    ``resume_interleave`` arms the stale-WAL-residue scenario: between
    the reopen and ``resume()``, every key of every still-moving range
    is overwritten through its recovered owner (covering exactly the
    keys a crash-interrupted copy may already have replayed into the
    migration target's WAL), and those *source* engines checkpoint —
    the new values move into page images and the sources' WALs empty,
    so the re-run copy ships images only, while the targets' WALs are
    deliberately left alone. After convergence, every device crashes
    AGAIN and the cluster reopens: any record the interrupted copy left
    in a target's WAL would now replay over the newer images and revert
    a committed write — the reopen scrub must have fenced it away.

    ``width`` flights that many ranges through the concurrent migration
    driver per batch (stage-interleaved), so a single crash step lands
    with 2+ ranges at MIXED protocol stages — e.g. one range's pages
    already written back while its batch-mate is still mid-copy. The
    exactly-old-XOR-exactly-new invariant and every other assertion
    here must hold unchanged, because batching never reorders one
    range's own copy → flush → own → invalidate sequence."""
    from repro.cluster import ClusterConfig, ClusterKV

    kv_kw = dict(npages=8, page_size=512, value_size=32,
                 log_capacity=1 << 15)
    if tiered:
        kv_kw["slot_budget"] = 4
    cfg = ClusterConfig(kv=KVConfig(**kv_kw), n_ranges=8)
    all_sids = range(max(nshards, new_nshards))
    meta = Pool.create(None, ClusterKV.meta_pool_bytes(cfg))
    pools, ssds = {}, {}
    for sid in all_sids:
        pools[sid] = Pool.create(None, ClusterKV.shard_pool_bytes(cfg)
                                 + (1 << 18 if tiered else 0))
        if tiered:
            ssds[sid] = SSD(1 << 23)
            pools[sid].attach_ssd(ssds[sid])
    c = ClusterKV(meta, pools, cfg, shards=range(nshards))

    # committed workload, deterministic from the seed (LCG, no numpy rng
    # in value generation — the corpus rows must replay bit-exact)
    expected = {}
    x = (seed & 0x7FFFFFFF) or 1
    for i in range(n_ops):
        x = (1103515245 * x + 12345) & 0x7FFFFFFF
        k = x % cfg.nkeys
        value = bytes(((x >> 7) + i + j) % 256 for j in range(32))
        c.put(k, value)
        expected[k] = value
        if ckpt and (i + 1) % ckpt == 0:
            c.checkpoint()
    c.commit()

    target = sorted(range(new_nshards))
    pre_owner = dict(c.map.owners())
    goal = c.map.assignment(target)
    c.failpoints = CrashAt(crash_step)
    crashed = False
    try:
        c.reshard(target, width=width)
    except SimCrash:
        crashed = True
    c.failpoints = None

    rng = np.random.default_rng(seed)
    meta.pmem.crash(rng=rng, evict_prob=prob)
    for sid in sorted(pools):
        pools[sid].pmem.crash(rng=rng, evict_prob=prob)
        if tiered:
            ssds[sid].crash(rng=rng, keep_prob=ssd_keep)

    meta2 = Pool.open(pmem=meta.pmem)
    pools2 = {}
    for sid, p in pools.items():
        pools2[sid] = Pool.open(pmem=p.pmem)
        if tiered:
            pools2[sid].attach_ssd(ssds[sid])
    c2 = ClusterKV.open(meta2, pools2, cfg)

    # --- exactly-old-owner or exactly-new-owner, per range
    owners_after_crash = dict(c2.map.owners())
    for r in range(cfg.n_ranges):
        assert owners_after_crash[r] in (pre_owner[r], goal[r]), \
            (r, owners_after_crash[r], pre_owner[r], goal[r])
    if not crashed:
        assert owners_after_crash == goal
        assert c2.map.pending is None

    # --- every committed put answers with its last committed value,
    # from the single owner the map names; no key leaks foreign bytes
    zero = bytes(cfg.kv.value_size)
    for k in range(cfg.nkeys):
        if k in expected:
            assert c2.get(k) == expected[k], k
        else:
            try:
                got = c2.get(k)
            except KeyError:
                continue        # tiered: never-written page in no tier
            assert got == zero, k

    # --- stale-WAL arm: overwrite every key of every still-moving range
    # and checkpoint their current owners before resuming, so the re-run
    # copy ships the new values as page images with no WAL records (see
    # the docstring; same LCG stream, continued)
    if resume_interleave:
        still_moving = [r for r in range(cfg.n_ranges)
                        if owners_after_crash[r] != goal[r]]
        keys_per_range = cfg.pages_per_range * cfg.kv.recs_per_page
        for r in still_moving:
            for k in range(r * keys_per_range, (r + 1) * keys_per_range):
                x = (1103515245 * x + 12345) & 0x7FFFFFFF
                value = bytes(((x >> 9) + k + j) % 256 for j in range(32))
                c2.put(k, value)
                expected[k] = value
        c2.commit()
        for sid in sorted({owners_after_crash[r] for r in still_moving}):
            c2.engine(sid).checkpoint()

    # --- resume: converge to the target view, re-moving only the
    # not-yet-flipped ranges (same concurrency as the interrupted run)
    rep = c2.resume(width=width)
    if rep is not None:
        already_flipped = {r for r in range(cfg.n_ranges)
                           if owners_after_crash[r] == goal[r]
                           and pre_owner[r] != goal[r]}
        assert set(rep.ranges_moved).isdisjoint(already_flipped)
    assert c2.map.pending is None
    assert dict(c2.map.owners()) == goal
    assert tuple(c2.map.shards) == tuple(target)
    for k, value in expected.items():
        assert c2.get(k) == value, k

    # --- sources durably scrubbed: a moved range's old owner holds no
    # copy in either tier
    ppr = cfg.pages_per_range
    for r in range(cfg.n_ranges):
        if goal[r] == pre_owner[r]:
            continue
        eng = c2.engine(pre_owner[r])
        for pid in range(r * ppr, (r + 1) * ppr):
            assert eng.durable_page_image(pid) is None, (r, pid)

    # --- second crash + reopen: nothing a crash-interrupted copy left
    # in any WAL may replay over the resumed migration's newer images
    if resume_interleave:
        rng2 = np.random.default_rng(seed + 1)
        meta.pmem.crash(rng=rng2, evict_prob=prob)
        for sid in sorted(pools):
            pools[sid].pmem.crash(rng=rng2, evict_prob=prob)
            if tiered:
                ssds[sid].crash(rng=rng2, keep_prob=ssd_keep)
        meta3 = Pool.open(pmem=meta.pmem)
        pools3 = {}
        for sid, p in pools.items():
            pools3[sid] = Pool.open(pmem=p.pmem)
            if tiered:
                pools3[sid].attach_ssd(ssds[sid])
        c3 = ClusterKV.open(meta3, pools3, cfg)
        assert c3.map.pending is None
        assert dict(c3.map.owners()) == goal
        for k, value in expected.items():
            assert c3.get(k) == value, ("post-resume restart", k)
    return crashed
