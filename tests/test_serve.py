"""repro.serve: open-loop workload, admission control, tail latency.

Covers the serve-layer acceptance claims end to end on the modeled
clock: deterministic (bit-stable, seed-keyed) percentiles from
``engine_time_ns`` with >= 1000 modeled clients; admission ON holding
p99 inside the SLO at an offered load where admission OFF collapses by
>= 5x; and per-tenant cache quotas keeping one tenant's scan storm
from degrading another tenant's p99 by more than 25%. Plus the
per-owner CacheStats attribution the frontend consumes, the public
``MultiLog.lane_k`` surface, and the model-state paging scenario.
"""

import dataclasses

import numpy as np
import pytest

from repro.cache import BufferManager
from repro.core import KVConfig
from repro.core.recovery import PersistentKV
from repro.core.ssd import SSD
from repro.io.flushq import FlushQueue
from repro.pool import Pool
from repro.serve import (
    LatencyRecorder,
    ModelStateStore,
    ServeFrontend,
    SLOConfig,
    TenantSpec,
    generate,
    percentile_ns,
)


# ============================================================== workload

T0 = TenantSpec(name="t0", clients=600, rate=30_000.0,
                get_frac=0.7, put_frac=0.3, zipf_s=1.3)
T1 = TenantSpec(name="t1", clients=600, rate=20_000.0, get_frac=0.5,
                put_frac=0.3, scan_frac=0.2, scan_len=4, zipf_s=1.2)


def test_workload_deterministic():
    a = generate([T0, T1], nkeys=256, duration_s=0.02, seed=9)
    b = generate([T0, T1], nkeys=256, duration_s=0.02, seed=9)
    assert a == b                      # bit-stable, not just statistically
    assert len(a) > 100


def test_workload_seed_keyed():
    a = generate([T0], nkeys=256, duration_s=0.02, seed=1)
    b = generate([T0], nkeys=256, duration_s=0.02, seed=2)
    assert a != b


def test_workload_arrival_order_and_rids():
    reqs = generate([T0, T1], nkeys=256, duration_s=0.02, seed=3)
    assert [r.rid for r in reqs] == list(range(len(reqs)))
    arrivals = [r.arrival_ns for r in reqs]
    assert arrivals == sorted(arrivals)
    assert {r.tenant for r in reqs} == {"t0", "t1"}


def test_workload_poisson_rate():
    reqs = generate([T0], nkeys=256, duration_s=0.1, seed=4)
    expect = T0.rate * 0.1
    assert 0.85 * expect < len(reqs) < 1.15 * expect


def test_workload_zipf_skew():
    skewed = TenantSpec(name="z", rate=50_000.0, get_frac=1.0,
                        put_frac=0.0, zipf_s=1.5)
    flat = dataclasses.replace(skewed, zipf_s=1.0)
    ns, nf = 4096, 4096
    s = generate([skewed], nkeys=ns, duration_s=0.1, seed=5)
    f = generate([flat], nkeys=nf, duration_s=0.1, seed=5)
    def top_frac(reqs):
        counts = {}
        for r in reqs:
            counts[r.key] = counts.get(r.key, 0) + 1
        return max(counts.values()) / len(reqs)
    # zipf(1.5): the hottest key draws a double-digit share; uniform
    # over 4096 keys leaves every key well under 1 %
    assert top_frac(s) > 0.10
    assert top_frac(f) < 0.01


def test_workload_burst_phases():
    burst = TenantSpec(name="b", rate=10_000.0, get_frac=1.0, put_frac=0.0,
                       burst_every_s=0.02, burst_len_s=0.005, burst_x=8.0)
    reqs = generate([burst], nkeys=64, duration_s=0.1, seed=6)
    in_burst = sum(1 for r in reqs
                   if (r.arrival_ns / 1e9) % 0.02 < 0.005)
    # burst windows are 25 % of the time but >> 25 % of the arrivals
    assert in_burst / len(reqs) > 0.5


def test_workload_mix_and_scan_len():
    reqs = generate([T1], nkeys=256, duration_s=0.1, seed=7)
    frac = {op: sum(1 for r in reqs if r.op == op) / len(reqs)
            for op in ("get", "put", "scan")}
    assert abs(frac["get"] - 0.5) < 0.06
    assert abs(frac["put"] - 0.3) < 0.06
    assert abs(frac["scan"] - 0.2) < 0.06
    for r in reqs:
        assert r.scan_len == (4 if r.op == "scan" else 1)
        assert 0 <= r.key < 256
        assert 0 <= r.client < T1.clients


def test_workload_validation():
    with pytest.raises(ValueError, match="fractions"):
        TenantSpec(name="x", get_frac=0.9, put_frac=0.3, scan_frac=0.0)
    with pytest.raises(ValueError, match="duplicate"):
        generate([T0, T0], nkeys=16, duration_s=0.001, seed=0)


# =============================================================== latency

def test_percentile_nearest_rank():
    vals = list(range(1, 101))           # 1..100
    assert percentile_ns(vals, 0.50) == 50
    assert percentile_ns(vals, 0.99) == 99
    assert percentile_ns(vals, 1.0) == 100
    assert percentile_ns([7], 0.999) == 7
    assert percentile_ns([], 0.5) == 0.0
    with pytest.raises(ValueError):
        percentile_ns(vals, 0.0)


def test_recorder_summary_exact():
    rec = LatencyRecorder()
    for i in range(1, 1001):             # 1..1000 ns
        rec.record("a", 0, i)
    s = rec.summary("a")
    assert (s.count, s.p50_us, s.p99_us, s.p999_us, s.max_us) == \
        (1000, 0.5, 0.99, 0.999, 1.0)


def test_recorder_shed_separate():
    rec = LatencyRecorder()
    rec.record("a", 10, 20)
    rec.shed("a")
    rec.shed("b")
    assert rec.summary("a").count == 1
    assert rec.summary("a").shed == 1
    assert rec.shed_count() == 2
    assert rec.summary("a").served_frac == 0.5
    with pytest.raises(ValueError):
        rec.record("a", 20, 10)          # completion precedes arrival


def test_recorder_histogram():
    rec = LatencyRecorder()
    for lat in (500, 1500, 3000, 250_000):
        rec.record("a", 0, lat)
    hist = rec.histogram(base_us=1.0)
    assert sum(c for _, c in hist) == 4
    assert hist[0] == (1.0, 1)           # the 0.5 µs sample


# ============================================================== frontend

def _tiered_build(admission, *, slo_us=3000.0, rate=40_000.0, seed=11):
    """Single tenant, working set >> PMem slot budget >> DRAM frames:
    misses pay real SSD rungs, so offered load can exceed capacity."""
    cfg = KVConfig(npages=64, page_size=1024, value_size=64,
                   log_capacity=1 << 18, slot_budget=16, wal_lanes=2,
                   wal_group_commit=2, wal_gen_sets=2, cache_frames=24)
    pool = Pool.create(None, 4 * PersistentKV.region_bytes(cfg) + (1 << 22),
                       sockets=2)
    pool.attach_ssd(SSD(1 << 24))
    spec = TenantSpec(name="t0", clients=1200, rate=rate,
                      get_frac=0.7, put_frac=0.3, zipf_s=1.3)
    fe = ServeFrontend(pool, [spec], cfg,
                       slo=SLOConfig(p99_target_us=slo_us,
                                     queue_budget_us=slo_us / 2),
                       admission=admission)
    kv = fe.kv("t0")
    for k in range(cfg.nkeys):
        kv.put(k, bytes([k % 256]) * cfg.value_size)
    kv.checkpoint()                      # overcommit spills the cold set
    reqs = generate([spec], nkeys=cfg.nkeys, duration_s=0.06, seed=seed)
    return fe, reqs


@pytest.fixture(scope="module")
def overload():
    """The acceptance scenario, computed once: >= 1000 modeled clients
    at an offered load beyond modeled capacity, admission on vs off."""
    fe_on, reqs = _tiered_build(True)
    rep_on = fe_on.run(reqs)
    fe_off, reqs2 = _tiered_build(False)
    rep_off = fe_off.run(reqs2)
    assert reqs == reqs2
    return reqs, rep_on, rep_off


def test_overload_has_1000_clients_and_requests(overload):
    reqs, _, _ = overload
    assert max(r.client for r in reqs) + 1 > 1000 or \
        len({r.client for r in reqs}) > 1000 * 0.6
    assert len(reqs) > 1000


def test_admission_sheds_and_meets_slo(overload):
    _, rep_on, _ = overload
    assert rep_on.shed > 0
    assert rep_on.overall.p99_us <= 3000.0          # the configured SLO


def test_no_admission_p99_collapse_5x(overload):
    _, rep_on, rep_off = overload
    assert rep_off.shed == 0
    assert rep_off.overall.p99_us >= 5 * rep_on.overall.p99_us


def test_open_loop_backlog_grows_without_admission(overload):
    _, rep_on, rep_off = overload
    # admission off serves everything, but long after it arrived:
    # makespan stretches past the offered 60 ms window
    assert rep_off.served == rep_off.overall.count
    assert rep_off.makespan_ns > 1.5 * rep_on.makespan_ns


def test_serve_deterministic_bit_stable(overload):
    reqs, rep_on, _ = overload
    fe2, reqs2 = _tiered_build(True)
    rep2 = fe2.run(reqs2)
    assert reqs == reqs2
    assert rep_on.overall == rep2.overall            # exact, not approx
    assert rep_on.by_tenant == rep2.by_tenant
    assert rep_on.hit_ratio == rep2.hit_ratio
    assert rep_on.recorder.latencies_ns() == rep2.recorder.latencies_ns()


def test_percentiles_are_seed_keyed(overload):
    reqs, rep_on, _ = overload
    fe2, _ = _tiered_build(True, seed=12)
    reqs2 = generate([TenantSpec(name="t0", clients=1200, rate=40_000.0,
                                 get_frac=0.7, put_frac=0.3, zipf_s=1.3)],
                     nkeys=fe2.kv_cfg.nkeys, duration_s=0.06, seed=12)
    rep2 = fe2.run(reqs2)
    assert rep2.overall != rep_on.overall


def test_serve_state_matches_replay():
    """After a run with no shedding, every tenant's KV holds exactly the
    last applied put per key (dict replay of the admit order)."""
    cfg = KVConfig(npages=8, page_size=1024, value_size=64,
                   log_capacity=1 << 17, wal_lanes=2, wal_group_commit=2,
                   wal_gen_sets=2)
    pool = Pool.create(None, 4 * PersistentKV.region_bytes(cfg) + (1 << 21),
                       sockets=2)
    fe = ServeFrontend(pool, [T0, T1], cfg, admission=False,
                       record_applied=True)
    reqs = generate([T0, T1], nkeys=cfg.nkeys, duration_s=0.01, seed=13)
    rep = fe.run(reqs)
    assert rep.shed == 0 and rep.served == len(reqs)
    expected = {"t0": {}, "t1": {}}
    for tenant, key, value in fe.applied_puts:
        expected[tenant][key] = value
    zero = bytes(cfg.value_size)
    for tenant in ("t0", "t1"):
        for k in range(cfg.nkeys):
            assert fe.kv(tenant).get(k) == expected[tenant].get(k, zero)


def test_shed_requests_never_touch_the_engine():
    fe, reqs = _tiered_build(True)
    fe.record_applied = True
    rep = fe.run(reqs)
    assert rep.shed > 0
    n_put_applied = len(fe.applied_puts)
    n_put_offered = sum(1 for r in reqs if r.op == "put")
    assert n_put_applied < n_put_offered     # some puts were shed
    # every applied value decodes back to an offered put request
    offered = {(r.tenant, r.key, r.vseed) for r in reqs if r.op == "put"}
    for tenant, key, value in fe.applied_puts:
        vseed = int(value.split(b":")[2])
        assert (tenant, key, vseed) in offered


def test_batches_sized_by_lane_k(overload):
    _, rep_on, _ = overload
    fe, reqs = _tiered_build(True)
    base_budget = fe.lane_k_budget("t0")
    assert base_budget == max(fe.min_batch, sum(fe.kv("t0").wal.lane_k()))
    fe.run(reqs)
    # sustained overload grows the adaptive k; the budget follows it
    assert fe.lane_k_budget("t0") == \
        max(fe.min_batch, sum(fe.kv("t0").wal.lane_k()))
    assert rep_on.batches < rep_on.served            # real batching happened


# ===================================================== tenant isolation

_ISO_A = TenantSpec(name="a", clients=500, rate=20_000.0,
                    get_frac=1.0, put_frac=0.0, zipf_s=1.2)
_ISO_B = TenantSpec(name="b", clients=500, rate=4_000.0, get_frac=0.0,
                    put_frac=0.0, scan_frac=1.0, scan_len=64, zipf_s=1.0)


def _iso_build(quota):
    """Two tenants whose pages both fit DRAM alone but not together
    (12 shared frames vs 8+8 pages): tenant b's scan storm can only
    hurt tenant a through the cache — the channel quotas close."""
    cfg = KVConfig(npages=8, page_size=4096, value_size=64,
                   log_capacity=1 << 17, wal_lanes=2, wal_group_commit=2,
                   wal_gen_sets=2, cache_frames=12)
    pool = Pool.create(None, 4 * PersistentKV.region_bytes(cfg) + (1 << 22),
                       sockets=2)
    fe = ServeFrontend(pool, [_ISO_A, _ISO_B], cfg,
                       slo=SLOConfig(p99_target_us=5000.0))
    for name in ("a", "b"):
        kv = fe.kv(name)
        for k in range(cfg.nkeys):
            kv.put(k, bytes([k % 256]) * cfg.value_size)
        kv.checkpoint()
    if quota is not None:
        fe.set_cache_quota("b", quota)
    for k in range(cfg.nkeys):           # warm tenant a's frames
        fe.kv("a").get(k)
    return fe, cfg


@pytest.fixture(scope="module")
def isolation():
    fe, cfg = _iso_build(None)
    alone = fe.run(generate([_ISO_A], nkeys=cfg.nkeys,
                            duration_s=0.05, seed=23))
    storm = generate([_ISO_A, _ISO_B], nkeys=cfg.nkeys,
                     duration_s=0.05, seed=23)
    fe_on, _ = _iso_build(4)
    rep_on = fe_on.run(storm)
    fe_off, _ = _iso_build(None)
    rep_off = fe_off.run(storm)
    return alone.by_tenant["a"], rep_on, rep_off


def test_quota_keeps_victim_p99_within_25pct(isolation):
    alone, rep_on, _ = isolation
    assert rep_on.by_tenant["a"].p99_us <= 1.25 * alone.p99_us


def test_no_quota_storm_degrades_victim(isolation):
    alone, _, rep_off = isolation
    assert rep_off.by_tenant["a"].p99_us > 1.25 * alone.p99_us
    # and the damage channel is the cache, visibly
    assert rep_off.hit_ratio["a"] < 0.95


def test_quota_preserves_victim_hit_ratio(isolation):
    _, rep_on, rep_off = isolation
    assert rep_on.hit_ratio["a"] > rep_off.hit_ratio["a"]
    assert rep_on.hit_ratio["a"] > 0.99


def test_storm_tenant_still_served_under_quota(isolation):
    _, rep_on, _ = isolation
    assert rep_on.by_tenant["b"].count > 0
    assert rep_on.hit_ratio["b"] > 0.5   # scans hit within their pages


# ==================================================== per-owner stats

def _two_owner_cache(frames=8, admit_k=1):
    pool = Pool.create(None, 1 << 22)
    cache = pool.cache(frames=frames, admit_k=admit_k)
    handles = {}
    for name in ("o1", "o2"):
        pages = pool.pages(name, npages=8, page_size=512)
        fq = FlushQueue(pages.store)
        cache.attach_pages(pages, flushq=fq)
        handles[name] = pages.store
    return pool, cache, handles


def test_owner_stats_sum_to_global():
    _, cache, st = _two_owner_cache()
    rng = np.random.default_rng(0)
    for i in range(40):
        owner = "o1" if i % 3 else "o2"
        if i % 5 == 0:
            cache.put(i % 8, rng.integers(0, 256, 512, dtype=np.uint8),
                      store=st[owner])
        else:
            cache.get(i % 8, store=st[owner])
    import dataclasses as dc
    for f in dc.fields(type(cache.stats)):
        total = sum(getattr(s, f.name) for s in cache.stats_by_owner.values())
        assert total == getattr(cache.stats, f.name), f.name


def test_owner_hit_attribution():
    _, cache, st = _two_owner_cache()
    cache.put(0, np.zeros(512, dtype=np.uint8), store=st["o1"])
    cache.get(0, store=st["o1"])
    cache.get(0, store=st["o1"])
    assert cache.owner_stats("o1").dram_hits == 2
    assert cache.owner_stats("o2").dram_hits == 0


def test_eviction_attributed_to_victim_owner():
    _, cache, st = _two_owner_cache(frames=4)
    for pid in range(4):                  # o1 fills the pool (clean reads)
        cache.get(pid, store=st["o1"])
    for pid in range(2):                  # o2 must evict o1's frames
        cache.get(pid, store=st["o2"])
    assert cache.owner_stats("o1").evictions_clean == 2
    assert cache.owner_stats("o2").evictions_clean == 0


def test_owner_quota_enforced():
    _, cache, st = _two_owner_cache(frames=8)
    cache.set_quota("o2", 2)
    assert cache.quota("o2") == 2 and cache.quota("o1") is None
    for pid in range(6):
        cache.get(pid, store=st["o1"])
    for pid in range(6):
        cache.get(pid, store=st["o2"])
    assert cache.frames_of("o2") <= 2
    assert cache.frames_of("o1") == 6     # the neighbor kept its frames
    cache.set_quota("o2", None)           # lifting the cap
    assert cache.quota("o2") is None
    with pytest.raises(ValueError):
        cache.set_quota("o1", -1)


def test_owner_quota_best_effort_when_pinned():
    _, cache, st = _two_owner_cache(frames=8)
    cache.set_quota("o2", 1)
    cache.get(0, store=st["o2"], pin=True)
    cache.get(1, store=st["o2"])          # quota full of pinned frames:
    assert cache.frames_of("o2") == 2     # overflow rather than fail
    cache.unpin(0, store=st["o2"])


def test_cache_stats_by_owner_in_kv_engine():
    cfg = KVConfig(npages=4, page_size=1024, value_size=64,
                   log_capacity=1 << 15)
    pool = Pool.create(None, 2 * PersistentKV.region_bytes(cfg) + (1 << 20))
    kv = pool.kv("k1", cfg)
    for k in range(8):
        kv.put(k, bytes(64))
    for k in range(8):
        kv.get(k)
    assert pool.cache().owner_stats("k1.pages").dram_hits > 0


# ============================================================ lane_k API

def test_lane_k_public_surface():
    pool = Pool.create(None, 1 << 21, sockets=2)
    from repro.io.multilog import MultiLog
    ml = MultiLog(pool, "ml", lanes=2, capacity=1 << 19, group_commit=2)
    ks = ml.lane_k()
    assert ks == [2, 2] and ml.lane_k(0) == 2
    ks[0] = 999                            # a copy, not the live array
    assert ml.lane_k() == [2, 2]
    assert ml.lane_group_commit == ml.lane_k()


# =========================================================== model state

@pytest.fixture(scope="module")
def modelstate():
    pool = Pool.create(None, 1 << 23)
    pool.attach_ssd(SSD(1 << 24))
    ms = ModelStateStore(pool, "tinyllama-1.1b", name="ms",
                         page_size=4096, slot_frac=0.25, seed=3)
    return pool, ms


def test_modelstate_layout(modelstate):
    _, ms = modelstate
    assert ms.num_shards == ms.config.num_layers + 1
    covered = []
    for s in range(ms.num_shards):
        covered.extend(ms.shard_pages(s))
    assert covered == list(range(ms.npages))         # contiguous, disjoint
    embed_bytes = ms.config.vocab_size * ms.config.d_model * 2
    first, npages = ms.shards[0]
    assert first == 0 and npages == -(-embed_bytes // ms.page_size)
    assert ms.tiered and ms.nslots < ms.npages


def test_modelstate_roundtrip_through_tiers(modelstate):
    _, ms = modelstate
    tiers = {ms.residency(pid) for pid in range(ms.npages)}
    assert "ssd" in tiers                 # the cold set really spilled
    for s in range(ms.num_shards):
        assert ms.verify_shard(s)


def test_modelstate_hot_shard_earns_dram(modelstate):
    pool, ms = modelstate
    cache = pool.cache()
    hot = 0    # the embedding shard (32 pages) fits the 64-frame pool
    for _ in range(3):
        ms.read_shard(hot)
    o = cache.owner_stats("ms.pages")
    before = o.snapshot()
    ms.read_shard(hot)
    d = o.delta(before)
    assert d.hit_ratio == 1.0             # fully DRAM-resident by now
