"""Deterministic crash-fuzz regression corpus — tier-1, no hypothesis.

PR 2 and PR 3 shipped their strongest correctness evidence as hypothesis
crash properties, which skip wherever the ``test`` extra is not
installed (this container included) — so the crash arguments were only
ever exercised locally. This corpus fixes that: a checked-in seed list,
distilled once from the hypothesis suites' strategy spaces (every
technique, lane count x group commit, crash stage, failpoint protocol
point, and eviction/keep probability including the 0.0/1.0 extremes),
replayed through the *same* property bodies (``tests/corpus_runner.py``)
that ``@given`` randomizes. No imports beyond numpy/pytest — these run
(not skip) in a bare environment, and a seed that ever finds a bug
should be appended here as a permanent regression.
"""

import pytest

from corpus_runner import (
    run_cache_crash,
    run_cache_restore_crash,
    run_ckpt_fused_crash,
    run_cluster_crash,
    run_restore_fused_crash,
    run_generation_spill_crash,
    run_kv_crash,
    run_multilog_crash,
    run_page_spill_crash,
    run_pool_alloc_crash,
    run_serve_crash,
)


def _ops(seed: int, n: int, nkeys: int = 64):
    """Deterministic (key, value-seed) op list: a tiny LCG expansion of
    one corpus seed (no RNG imports, bit-exact everywhere)."""
    x, out = seed & 0x7FFFFFFF, []
    for _ in range(n):
        x = (1103515245 * x + 12345) & 0x7FFFFFFF
        out.append((x % nkeys, (x >> 7) % (10 ** 6)))
    return out


# ============================================================== KV engine
# (technique, ops-seed, n_ops, ckpt_every, crash-seed, evict_prob)

KV_CORPUS = [
    ("classic", 1, 24, 0, 101, 0.0),
    ("classic", 2, 40, 7, 202, 0.4),
    ("classic", 3, 17, 13, 303, 1.0),
    ("header", 4, 24, 0, 404, 1.0),
    ("header", 5, 40, 7, 505, 0.0),
    ("header", 6, 33, 13, 606, 0.4),
    ("zero", 7, 24, 0, 707, 0.4),
    ("zero", 8, 40, 13, 808, 1.0),
    ("zero", 9, 1, 0, 909, 0.0),          # single put, nothing durable yet
    ("zero", 10, 39, 7, 1010, 0.4),       # crash right before a checkpoint
]


@pytest.mark.parametrize("technique,ops_seed,n,ckpt,seed,prob", KV_CORPUS)
def test_kv_crash_corpus(technique, ops_seed, n, ckpt, seed, prob):
    run_kv_crash(technique, _ops(ops_seed, n), ckpt, seed, prob)


# ============================================================== MultiLog
# (technique, lanes, group_commit, n_entries, commit_after, seed, prob)

MULTILOG_CORPUS = [
    ("zero", 1, 1, 12, {3, 7}, 11, 0.3),
    ("zero", 2, 8, 40, {19}, 22, 0.7),
    ("zero", 3, 4, 25, set(), 33, 0.5),
    ("zero", 5, 9, 40, {0, 39}, 44, 1.0),
    ("zero", 4, 2, 31, {5, 17, 29}, 55, 0.0),
    ("classic", 2, 3, 20, {9}, 66, 0.7),
    ("classic", 4, 8, 40, set(), 77, 0.3),
    ("classic", 3, 1, 7, {2}, 88, 1.0),
    ("header", 2, 5, 26, {13}, 99, 0.3),
    ("header", 5, 7, 40, {11, 31}, 111, 0.5),
    ("header", 4, 4, 0, set(), 122, 0.7),   # empty log recovers empty
]


@pytest.mark.parametrize(
    "technique,lanes,gc,n,commits,seed,prob", MULTILOG_CORPUS)
def test_multilog_crash_corpus(technique, lanes, gc, n, commits, seed, prob):
    run_multilog_crash(technique, lanes, gc, n, commits, seed, prob)


# ====================================================== pool allocation
# (n_entries, payload, crash_stage, seed, prob)

POOL_CORPUS = [
    (0, b"a", "placed", 7, 0.5),
    (3, b"pool-payload", "placed", 14, 1.0),
    (6, b"x" * 120, "initialized", 21, 0.0),
    (2, b"\x00\xff" * 30, "initialized", 28, 0.75),
    (4, b"entry", "entry_stored", 35, 0.0),     # entry line dropped
    (4, b"entry", "entry_stored", 42, 1.0),     # entry line survives
    (1, b"q" * 64, "entry_stored", 49, 0.5),
    (5, b"\xaa" * 33, "entry_stored", 56, 0.25),
]


@pytest.mark.parametrize("n,payload,stage,seed,prob", POOL_CORPUS)
def test_pool_alloc_crash_corpus(n, payload, stage, seed, prob):
    run_pool_alloc_crash(n, payload, stage, seed, prob)


# ============================================== crash-during-spill (WAL)
# (lanes, gen_sets, group_commit, per_gen, crash_step, seed,
#  pmem_prob, ssd_keep) — crash steps 1..4 land on each failpoint of the
# first generation drain (ssd_written / ssd_flushed / mapped / retired);
# larger steps land in later drains or never fire.

GEN_SPILL_CORPUS = [
    (1, 2, 1, [3], 1, 1001, 0.5, 0.5),
    (2, 2, 2, [4, 6], 2, 1002, 1.0, 0.0),
    (3, 3, 1, [2, 5, 9], 3, 1003, 0.0, 1.0),
    (4, 2, 5, [12, 1], 4, 1004, 0.5, 1.0),
    (2, 3, 3, [7, 7, 7], 6, 1005, 1.0, 0.5),
    (1, 3, 1, [1, 1, 1, 1, 1], 9, 1006, 0.5, 0.0),
    (3, 2, 4, [10, 3, 8], 11, 1007, 0.0, 0.0),
    (2, 2, 1, [5], 40, 1008, 1.0, 1.0),     # no crash: full drain path
]


@pytest.mark.parametrize(
    "lanes,gen_sets,gc,per_gen,step,seed,pprob,skeep", GEN_SPILL_CORPUS)
def test_generation_spill_crash_corpus(lanes, gen_sets, gc, per_gen, step,
                                       seed, pprob, skeep):
    run_generation_spill_crash(lanes, gen_sets, gc, per_gen, step, seed,
                               pprob, skeep)


# ============================================= crash-during-spill (pages)
# (nslots, writes-seed, n_writes, crash_step, seed, pmem_prob, ssd_keep)

PAGE_SPILL_CORPUS = [
    (3, 11, 40, 1, 2001, 0.5, 0.5),
    (3, 12, 24, 2, 2002, 1.0, 0.0),
    (4, 13, 40, 3, 2003, 0.0, 1.0),
    (4, 14, 33, 5, 2004, 0.5, 1.0),
    (5, 15, 40, 8, 2005, 1.0, 0.5),
    (6, 16, 16, 13, 2006, 0.0, 0.0),
    (3, 17, 40, 21, 2007, 0.5, 0.0),
    (5, 18, 9, 60, 2008, 1.0, 1.0),         # no crash: clean epochs
]


@pytest.mark.parametrize(
    "nslots,wseed,n,step,seed,pprob,skeep", PAGE_SPILL_CORPUS)
def test_page_spill_crash_corpus(nslots, wseed, n, step, seed, pprob, skeep):
    writes = [(k % 16, v % 256) for k, v in _ops(wseed, n, nkeys=16)]
    run_page_spill_crash(nslots, writes, step, seed, pprob, skeep)


# ============================================ DRAM cache (buffer manager)
# (frames, admit_k, ops-seed, n_ops, epoch_every, crash_step, seed,
#  pmem_prob, ssd_keep) — the op stream mixes ~1/3 writes over pids 0-7
# with reads over pids 0-15 (see _cache_ops), so dirty frames sit pending
# write-back and k-touch promotions are in flight when the failpoint
# fires; crash steps land on eviction points (ssd_written / ssd_flushed /
# mapped) and on mid-promotion (promoted), plus no-crash full runs. Each
# case runs TWICE — warm cache and frames=0 — and asserts identical
# recovered state (see corpus_runner.run_cache_crash).

def _cache_ops(seed: int, n: int):
    """Deterministic read/write stream (same LCG discipline as _ops):
    writes confined to 8 pids so an epoch's dirty set stays within the
    frame budget; reads range over all 16 pids."""
    x, out = seed & 0x7FFFFFFF, []
    for _ in range(n):
        x = (1103515245 * x + 12345) & 0x7FFFFFFF
        if (x >> 3) % 3 == 0:
            out.append(("w", (x >> 5) % 8, x % 256))
        else:
            out.append(("r", (x >> 5) % 16, 0))
    return out


CACHE_CORPUS = [
    (8, 2, 21, 48, 6, 1, 3001, 0.5, 0.5),
    (8, 2, 22, 48, 6, 2, 3002, 1.0, 0.0),
    (6, 1, 23, 40, 5, 3, 3003, 0.0, 1.0),     # promote-on-first-access
    (8, 3, 24, 60, 6, 4, 3004, 0.5, 1.0),     # crash lands mid-promotion
    (6, 2, 25, 48, 8, 7, 3005, 1.0, 0.5),
    (8, 4, 26, 64, 6, 11, 3006, 0.0, 0.0),    # high admission threshold
    (8, 2, 27, 36, 6, 60, 3007, 0.5, 0.5),    # no crash: full clean run
    (16, 1, 28, 48, 4, 5, 3008, 1.0, 1.0),    # every page fits a frame
]


@pytest.mark.parametrize(
    "frames,admit_k,oseed,n,epoch,step,seed,pprob,skeep", CACHE_CORPUS)
def test_cache_crash_corpus(frames, admit_k, oseed, n, epoch, step, seed,
                            pprob, skeep):
    run_cache_crash(frames, admit_k, _cache_ops(oseed, n), epoch, step,
                    seed, pprob, skeep)


# ================================== restore after dirty eviction (cache)
# (frames, admit_k, epoch_every, n_evict_writes, crash_step, seed,
#  pmem_prob, ssd_keep) — a write burst past the frame budget parks
# clock-evicted dirty images in the flush queue, then a snapshot restore
# invalidates the cache and rewrites only PART of the page table: the
# untouched pids are protected against stale-image resurrection solely
# by invalidate()'s parked-image purge. Crash steps land in the baseline
# drain, the restore drain, and the post-restore drain, plus no-crash
# full runs; each case runs warm and frames=0 and must recover identical
# state with phase-B bytes never resurfacing (see
# corpus_runner.run_cache_restore_crash).

CACHE_RESTORE_CORPUS = [
    (8, 2, 6, 24, 99, 4099, 0.5, 0.5),     # no crash: full restore cycle
    (8, 2, 6, 24, 3, 4003, 0.5, 0.5),      # crash in the baseline drain
    (8, 2, 6, 24, 12, 4012, 1.0, 0.0),     # crash in the restore drain
    (8, 2, 6, 24, 20, 4020, 0.0, 1.0),     # crash post-restore drain
    (6, 1, 4, 32, 9, 4109, 0.5, 1.0),      # promote-on-first-access
    (6, 3, 8, 24, 16, 4216, 1.0, 0.5),     # high admission threshold
    (16, 2, 6, 24, 99, 4399, 0.0, 0.0),    # every page fits a frame
]


@pytest.mark.parametrize(
    "frames,admit_k,epoch,nwrites,step,seed,pprob,skeep",
    CACHE_RESTORE_CORPUS)
def test_cache_restore_crash_corpus(frames, admit_k, epoch, nwrites, step,
                                    seed, pprob, skeep):
    run_cache_restore_crash(frames, admit_k, epoch, nwrites, step, seed,
                            pprob, skeep)


# ============================================ crash-mid-fused-flush (ckpt)
# (sparse-positions, crash_step, crash-seed, evict_prob) — arms a
# failpoint on the checkpoint flush queue so the µLog save's epoch drain
# dies after crash_step-1 page flushes, then runs the SAME scenario under
# kernel_impl="fused" and "staged" and asserts byte-identical recovery
# (see corpus_runner.run_ckpt_fused_crash). Positions index a 512 KiB
# float32 leaf split into 128 KiB pages (32768 elements each); the huge
# step is the no-crash control.

CKPT_FUSED_CORPUS = [
    ((0, 40000), 1, 5001, 0.5),      # die on the first page flush
    ((0, 40000), 2, 5002, 1.0),      # second flush, every line evicted
    ((13000,), 1, 5003, 0.0),        # single dirty page, nothing evicted
    ((5, 70000, 131071), 2, 5004, 0.4),   # three pages dirty
    ((0, 40000), 60, 5005, 0.5),     # no crash: clean fused µLog save
]


@pytest.mark.parametrize("positions,step,seed,prob", CKPT_FUSED_CORPUS)
def test_ckpt_fused_crash_corpus(tmp_path, positions, step, seed, prob):
    run_ckpt_fused_crash(str(tmp_path), positions, step, seed, prob)


# ============================================ crash-mid-fused-restore
# (sparse-positions, crash_step, crash-seed, evict_prob) — the restore
# direction of the fused-kernel corpus above: the device crashes with an
# arbitrary eviction subset, then the restore itself dies after
# crash_step-1 per-leaf apply dispatches (apply_unpack under
# kernel_impl="fused", the verify-then-copy chain under "staged").
# Restore is read-only, so the aborted attempt must leave the durable
# cut untouched and a fresh manager recovers the committed step
# byte-identically under BOTH impls (see
# corpus_runner.run_restore_fused_crash). The state has three leaves,
# so steps 1-3 land mid-manifest-entry; the huge step is the no-crash
# control.

RESTORE_FUSED_CORPUS = [
    ((0, 40000), 1, 6001, 0.5),          # die on the first leaf apply
    ((13000,), 2, 6002, 1.0),            # mid-entry, every line evicted
    ((5, 70000, 131071), 3, 6003, 0.0),  # last leaf of the entry
    ((0, 40000), 60, 6004, 0.4),         # no crash: clean restore control
]


@pytest.mark.parametrize("positions,step,seed,prob", RESTORE_FUSED_CORPUS)
def test_restore_fused_crash_corpus(tmp_path, positions, step, seed, prob):
    run_restore_fused_crash(str(tmp_path), positions, step, seed, prob)


# ============================================ crash-mid-request-batch
# (n_requests, workload-seed, crash_step, crash-seed, evict_prob,
#  admission, slo_us) — crash steps land on ``req_applied`` /
# ``batch_commit`` failpoints of the serving frontend (two tenants,
# two-lane group-commit WALs each); admitted-but-uncommitted requests
# must recover as if shed (see corpus_runner.run_serve_crash). The
# tight-SLO case serves with real shedding in flight; the huge-step
# case is the no-crash control.

SERVE_CORPUS = [
    (40, 1, 3, 4101, 0.5, True, 500.0),     # crash in the first batch
    (40, 2, 33, 4102, 1.0, True, 500.0),    # mid-run, nothing evicted
    (40, 3, 57, 4103, 0.0, True, 500.0),    # late, everything evicted
    (40, 4, 21, 4104, 0.4, True, 0.05),     # shedding active at crash
    (32, 5, 26, 4105, 0.7, False, 500.0),   # admission off: pure queueing
    (24, 6, 999, 4106, 0.5, True, 500.0),   # no crash: full clean run
]


@pytest.mark.parametrize(
    "n,wseed,step,seed,prob,admission,slo", SERVE_CORPUS)
def test_serve_crash_corpus(n, wseed, step, seed, prob, admission, slo):
    run_serve_crash(n, wseed, step, seed, prob,
                    admission=admission, slo_us=slo)


# ================================================== crash-mid-reshard
# (nshards, new_nshards, n_ops, ckpt_every, crash_step, crash-seed,
#  evict_prob, tiered, ssd_keep) — crash steps land on the router's
# view-change failpoints. The step numbers below were chosen against
# the deterministic failpoint traces of each scenario (seed 12345 LCG
# workload): the checkpointed 2→3 grow migrates one range as
#   1 view:started · 2 copy:page · 3 flush:done · 4 own:committed ·
#   5 invalidate:done · 6 view:committed,
# the 4→2 shrink moves two ranges (steps 2-6 first range incl. a
# copy:wal, 7-10 second), and the never-checkpointed 2→4 grow ships
# WAL records only (steps 2-13 copy:wal). Each case asserts
# exactly-old-owner or exactly-new-owner recovery per range (never
# both/neither), last-committed-value reads, convergence on resume
# with only unflipped ranges re-moved, and durably scrubbed sources
# (see corpus_runner.run_cluster_crash).

CLUSTER_CORPUS = [
    (2, 3, 40, 10, 2, 7101, 0.5, False, 1.0),   # mid-copy: page image shipped
    (2, 3, 40, 10, 3, 7102, 1.0, False, 1.0),   # after target flush, pre-own
    (2, 3, 40, 10, 4, 7103, 0.0, False, 1.0),   # at the ownership flip
    (2, 3, 40, 10, 5, 7104, 0.5, False, 1.0),   # after source invalidation
    (4, 2, 48, 10, 6, 7105, 0.5, False, 1.0),   # range 1 flipped, range 2 not
    (4, 2, 48, 10, 9, 7106, 1.0, False, 1.0),   # mid-second-range ownership
    (2, 4, 48, 0, 7, 7107, 0.5, False, 1.0),    # mid-WAL-only copy stream
    (2, 4, 48, 0, 15, 7108, 0.0, False, 1.0),   # second range's flush step
    (3, 4, 48, 8, 4, 7109, 0.5, True, 0.5),     # tiered source, own flip
    (3, 4, 48, 8, 5, 7110, 0.5, True, 0.0),     # tiered, SSD loses all
    (2, 3, 40, 10, 99, 7111, 0.5, False, 1.0),  # no crash: clean control
]


@pytest.mark.parametrize(
    "nsh,new,n,ckpt,step,seed,prob,tiered,skeep", CLUSTER_CORPUS)
def test_cluster_crash_corpus(nsh, new, n, ckpt, step, seed, prob,
                              tiered, skeep):
    run_cluster_crash(nsh, new, n, ckpt, step, seed, prob,
                      tiered=tiered, ssd_keep=skeep)


# Concurrent driver: the same view-change protocol, but width ranges
# flighted per stage-interleaved batch — so one crash step lands with
# 2+ ranges at MIXED protocol stages (one range's ownership already
# flipped while its batch-mate is still pre-own, both mid-copy, etc.).
# Steps below index the deterministic width>1 failpoint traces: the
# 4→2 shrink batches both moving ranges (2-3 copy:page, 4-5 copy:wal,
# 6-7 flush:done, 8-9 own:committed, 10-11 invalidate:done); the 4→1
# drain moves four ranges as a batch of three (2-15) then one (16-21);
# the never-checkpointed 2→4 grow ships a batched WAL-only stream
# (2-11 copy:wal, then 12-17 flush/own/invalidate pairs). Same
# invariants as the serial corpus — exactly-old-XOR-exactly-new per
# range, committed reads, resume convergence at the same width,
# scrubbed sources — because batching never reorders one range's own
# copy → flush → own → invalidate sequence.

CLUSTER_WIDTH_CORPUS = [
    (4, 2, 48, 10, 3, 7301, 0.5, 2),   # batch of 2, both mid-page-copy
    (4, 2, 48, 10, 9, 7302, 1.0, 2),   # range A flipped, batch-mate not
    (4, 2, 48, 10, 11, 7303, 0.0, 2),  # both owned, one not invalidated
    (2, 4, 48, 0, 7, 7304, 0.5, 2),    # mid batched WAL-only stream
    (4, 1, 48, 10, 5, 7305, 0.5, 3),   # batch of 3 at three copy stages
    (4, 1, 48, 10, 11, 7306, 0.5, 3),  # 2 of 3 flipped inside one batch
    (4, 1, 48, 10, 17, 7307, 1.0, 3),  # second batch mid-copy
    (4, 1, 48, 10, 99, 7308, 0.5, 3),  # no crash: clean width=3 control
]


@pytest.mark.parametrize(
    "nsh,new,n,ckpt,step,seed,prob,width", CLUSTER_WIDTH_CORPUS)
def test_cluster_width_crash_corpus(nsh, new, n, ckpt, step, seed, prob,
                                    width):
    run_cluster_crash(nsh, new, n, ckpt, step, seed, prob, width=width)


# Stale-WAL fence: crash mid-copy AFTER copy:wal replayed committed
# source records into the migration target's WAL, reopen (the scrub
# must checkpoint the target, truncating that residue), then overwrite
# the still-moving ranges' keys and checkpoint their owners — source
# WALs empty, the new values live only in page images — resume, and
# crash + reopen once more. Without the fence the target's leftover
# records replay over the newer images on that second restart and
# revert committed writes (run_cluster_crash resume_interleave arm).
# The never-checkpointed rows ship WAL records only, so any mid-copy
# step lands inside the copy:wal stream; the ckpt=10 rows mix page
# images and WAL records.
CLUSTER_STALE_WAL_CORPUS = [
    (2, 4, 48, 0, 3, 7201, 0.5, False, 1.0),    # early in the WAL stream
    (2, 4, 48, 0, 9, 7202, 0.0, False, 1.0),    # deep in the WAL stream
    (2, 4, 48, 0, 15, 7203, 0.5, False, 1.0),   # past one range's flip
    (2, 3, 40, 10, 3, 7204, 0.5, False, 1.0),   # images + WAL tail mixed
    (4, 2, 48, 10, 5, 7205, 0.5, False, 1.0),   # shrink, first range mid-copy
    (3, 4, 48, 8, 3, 7206, 0.5, True, 1.0),     # tiered source mid-copy
]


@pytest.mark.parametrize(
    "nsh,new,n,ckpt,step,seed,prob,tiered,skeep", CLUSTER_STALE_WAL_CORPUS)
def test_cluster_stale_wal_corpus(nsh, new, n, ckpt, step, seed, prob,
                                  tiered, skeep):
    run_cluster_crash(nsh, new, n, ckpt, step, seed, prob,
                      tiered=tiered, ssd_keep=skeep, resume_interleave=True)


def test_cluster_stale_wal_concurrent_driver():
    # the stale-WAL-residue scenario under the width=2 driver: the
    # crash-interrupted batched copy leaves records in TWO targets' WALs
    # at once, and the reopen scrub must fence both before the
    # interleaved overwrites + width=2 resume + second restart
    run_cluster_crash(2, 4, 48, 0, 7, 7309, 0.5,
                      width=2, resume_interleave=True)
