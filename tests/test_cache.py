"""repro.cache.BufferManager — the DRAM rung of the three-tier read path.

Covers: hit/miss accounting per tier, clock (second-chance) eviction with
clean-first preference and dirty-frame parking, pin/unpin (clock immunity
+ the spill scheduler's mid-flush guard), k-touch admission replacing
promote-on-first-access, write-faults-never-promote, frames=0
pass-through, the Fig. 3 read-path cost model, pool.cache() lifecycle,
and the refactored consumers (PersistentKV buffer pool, CheckpointManager
snapshot frames, Trainer-style generational WAL roll cadence).
"""

import numpy as np
import pytest

from repro.cache import BufferManager, CacheStats
from repro.core import COST_MODEL, KVConfig, PersistentKV
from repro.core.costmodel import SSD_COST_MODEL
from repro.core.pmem import PMemStats
from repro.core.ssd import SSD
from repro.io.flushq import FlushQueue
from repro.pool import Pool
from repro.tier import SpillScheduler


def page(fill, size=512):
    return np.full(size, fill, dtype=np.uint8)


def tiered_rig(*, frames=8, admit_k=2, npages=16, nslots=4, page_size=512):
    pool = Pool.create(None, 1 << 21)
    ssd = SSD(1 << 22)
    pool.attach_ssd(ssd)
    sp = SpillScheduler(pool, name="sp", map_capacity=1 << 13)
    pages = pool.pages("heap", npages=npages, page_size=page_size,
                       nslots=nslots)
    sp.attach_pages(pages)
    fq = FlushQueue(pages, lanes=2, spill=sp)
    cache = BufferManager(pool, frames=frames, admit_k=admit_k)
    cache.attach_pages(pages, flushq=fq, spill=sp)
    return pool, ssd, sp, pages, fq, cache


def plain_rig(*, frames=4, npages=8, page_size=512):
    pool = Pool.create(None, 1 << 20)
    pages = pool.pages("heap", npages=npages, page_size=page_size)
    fq = FlushQueue(pages, lanes=2)
    cache = BufferManager(pool, frames=frames)
    cache.attach_pages(pages, flushq=fq)
    return pool, pages, fq, cache


# ===================================================== basic frame traffic

def test_fresh_page_reads_zero_and_counts():
    _, _, _, cache = plain_rig()
    got = cache.get(3)
    assert not got.any()
    assert cache.stats.fresh_pages == 1
    # second read: a DRAM frame hit
    cache.get(3)
    assert cache.stats.dram_hits == 1
    assert cache.stats.hit_ratio == 0.5


def test_put_get_writeback_durable():
    pool, pages, _, cache = plain_rig()
    cache.put(1, page(7))
    assert bytes(cache.get(1)) == bytes(page(7))
    assert cache.dirty_pages() == [1]
    rep = cache.writeback()
    assert rep.pages == 1
    assert cache.dirty_pages() == []
    assert bytes(pages.store.durable_page(1)) == bytes(page(7))
    # frame survived write-back, now clean: read is still a DRAM hit
    before = cache.stats.snapshot()
    cache.get(1)
    assert cache.stats.delta(before).dram_hits == 1


def test_get_out_of_range_raises():
    _, _, _, cache = plain_rig(npages=8)
    with pytest.raises(KeyError):
        cache.get(8)


def test_pmem_fill_is_uncached_device_read():
    pool, pages, _, cache = plain_rig()
    cache.put(0, page(9))
    cache.writeback()
    cache.invalidate()
    before = pool.stats.snapshot()
    cache.get(0)
    delta = pool.stats.delta(before)
    assert delta.device_read_bytes >= 512          # the whole page
    assert cache.stats.pmem_fills == 1


def test_write_is_read_modify_write():
    pool, pages, _, cache = plain_rig()
    cache.put(2, page(5))
    cache.writeback()
    cache.invalidate()
    cache.write(2, 64, b"\xaa" * 64)
    got = cache.get(2)
    assert bytes(got[64:128]) == b"\xaa" * 64
    assert bytes(got[:64]) == bytes(page(5)[:64])  # faulted from PMem
    cache.writeback()
    want = page(5)
    want[64:128] = 0xAA
    assert bytes(pages.store.durable_page(2)) == bytes(want)


# ================================================== clock eviction + pins

def test_clock_prefers_clean_victims():
    _, pages, fq, cache = plain_rig(frames=2)
    cache.put(0, page(1))              # dirty
    cache.get(5)                       # clean (fresh zeros)
    cache.get(6)                       # needs a frame -> evicts the CLEAN 5
    assert cache.stats.evictions_clean == 1
    assert cache.stats.evictions_dirty == 0
    assert cache.peek(0) is not None   # dirty frame untouched


def test_dirty_eviction_parks_in_flush_queue():
    _, pages, fq, cache = plain_rig(frames=2)
    cache.put(0, page(1))
    cache.put(1, page(2))
    cache.get(5)                       # all frames dirty -> one parks
    assert cache.stats.evictions_dirty == 1
    parked = [p for p in (0, 1) if fq.pending_image(p) is not None]
    assert len(parked) == 1
    # the parked image is still the page's newest content, served as DRAM
    assert bytes(cache.get(parked[0])) == bytes(page(parked[0] + 1))
    # and the next epoch flushes BOTH pages (frame + parked)
    cache.writeback()
    for pid in (0, 1):
        assert bytes(pages.store.durable_page(pid)) == bytes(page(pid + 1))


def test_parked_image_readopted_on_write():
    _, pages, fq, cache = plain_rig(frames=2)
    cache.put(0, page(1))
    cache.put(1, page(2))
    cache.get(5)                       # parks one dirty frame
    parked = next(p for p in (0, 1) if fq.pending_image(p) is not None)
    cache.write(parked, 0, b"\x77" * 64)
    assert fq.pending_image(parked) is None   # popped back into a frame
    cache.writeback()
    want = page(parked + 1)
    want[:64] = 0x77
    assert bytes(pages.store.durable_page(parked)) == bytes(want)


def test_pin_blocks_clock_eviction():
    _, _, _, cache = plain_rig(frames=2)
    cache.get(0, pin=True)
    cache.get(1, pin=True)
    with pytest.raises(RuntimeError, match="pinned"):
        cache.get(2)
    cache.unpin(1)
    cache.get(2)                       # now evictable
    assert cache.peek(0) is not None   # the pinned frame survived
    with pytest.raises(ValueError):
        cache.unpin(2 if cache.peek(2) is None else 9)


def test_pin_readopts_parked_image():
    # pinning a page whose dirty image parked in the flush queue must
    # re-frame it (dirty set intact) so the pin contract actually holds
    _, pages, fq, cache = plain_rig(frames=2)
    cache.put(0, page(1))
    cache.put(1, page(2))
    cache.get(5)                       # parks one dirty frame
    parked = next(p for p in (0, 1) if fq.pending_image(p) is not None)
    cache.pin(parked)
    assert fq.pending_image(parked) is None      # back in a frame
    assert cache._is_pinned("heap", parked)
    assert bytes(cache.peek(parked)) == bytes(page(parked + 1))
    cache.unpin(parked)                          # pairs cleanly
    cache.writeback()
    assert bytes(pages.store.durable_page(parked)) == bytes(page(parked + 1))


def test_pin_guard_protects_pmem_slot_from_spill():
    pool, ssd, sp, pages, fq, cache = tiered_rig(frames=8, nslots=4)
    for pid in range(3):
        cache.put(pid, page(pid + 1))
    cache.writeback()
    assert set(pages.store.table) == {0, 1, 2}
    cache.pin(0)
    # evicting down to the floor must pick the unpinned pages first
    sp.ensure_slots(pages.store, need=4)
    assert 0 in pages.store.table, "pinned page's slot was spilled"
    cache.unpin(0)


# =============================================== k-touch admission policy

def spill_all(cache, sp, pages, pids):
    """Flush pids then force their slots out to SSD."""
    for pid in pids:
        cache.put(pid, page(pid + 1))
    cache.writeback()
    sp.ensure_slots(pages.store, need=pages.store.layout.nslots)
    for pid in pids:
        assert sp.residency(pages.store, pid) == "ssd"


def test_ktouch_admission_defers_then_promotes():
    pool, ssd, sp, pages, fq, cache = tiered_rig(frames=8, admit_k=3)
    spill_all(cache, sp, pages, [0])
    cache.invalidate()                 # force tier reads
    assert bytes(cache.get(0)) == bytes(page(1))   # touch 1: SSD, no promote
    assert sp.residency(pages.store, 0) == "ssd"
    assert cache.stats.admissions_deferred == 1
    cache.invalidate()
    cache.get(0)                                   # touch 2: still SSD
    assert sp.residency(pages.store, 0) == "ssd"
    cache.invalidate()
    cache.get(0)                                   # touch 3: promotes
    assert sp.residency(pages.store, 0) == "pmem"
    assert cache.stats.promotions == 1
    assert sp.stats.pages_promoted == 1


def test_dram_hit_still_promotes_at_threshold():
    # admission is a property of the access stream, not frame residency
    pool, ssd, sp, pages, fq, cache = tiered_rig(frames=8, admit_k=2)
    spill_all(cache, sp, pages, [0])
    cache.invalidate()
    cache.get(0)                       # touch 1: framed, still SSD
    assert sp.residency(pages.store, 0) == "ssd"
    cache.get(0)                       # touch 2: DRAM hit AND promotion
    assert sp.residency(pages.store, 0) == "pmem"
    assert cache.stats.promotions == 1


def test_admit_k1_is_promote_on_first_access():
    pool, ssd, sp, pages, fq, cache = tiered_rig(frames=8, admit_k=1)
    spill_all(cache, sp, pages, [0])
    cache.invalidate()
    cache.get(0)
    assert sp.residency(pages.store, 0) == "pmem"


def test_direct_spill_read_inherits_admission():
    # the scheduler's own read_page(promote=True) consults the policy
    pool, ssd, sp, pages, fq, cache = tiered_rig(frames=8, admit_k=3)
    spill_all(cache, sp, pages, [0])
    sp.read_page(pages.store, 0, promote=True)
    assert sp.residency(pages.store, 0) == "ssd"   # below threshold


def test_write_fault_never_promotes():
    pool, ssd, sp, pages, fq, cache = tiered_rig(frames=8, admit_k=1)
    spill_all(cache, sp, pages, [0])
    cache.invalidate()
    cache.write(0, 0, b"\x55" * 64)    # faults from SSD, must not promote
    assert sp.residency(pages.store, 0) == "ssd"
    assert cache.stats.promotions == 0
    cache.writeback()                  # ...the flush itself re-homes it
    assert sp.residency(pages.store, 0) == "pmem"
    want = page(1)
    want[:64] = 0x55
    assert bytes(cache.get(0)) == bytes(want)


def test_spill_eviction_resets_touch_count():
    pool, ssd, sp, pages, fq, cache = tiered_rig(frames=8, admit_k=2,
                                                 nslots=4)
    spill_all(cache, sp, pages, [0])
    cache.invalidate()
    cache.get(0)
    cache.get(0)                       # promoted (2 touches)
    assert sp.residency(pages.store, 0) == "pmem"
    assert cache.touches(0) >= 2
    sp.ensure_slots(pages.store, need=4)   # spills it again
    assert cache.touches(0) == 0, "re-promotion must be re-earned"


# ======================================================= frames=0 bypass

def test_frames_zero_is_pass_through():
    pool, ssd, sp, pages, fq, cache = tiered_rig(frames=0, admit_k=2)
    cache.put(0, page(3))
    assert cache.frames_in_use == 0
    assert fq.pending_image(0) is not None
    assert bytes(cache.get(0)) == bytes(page(3))   # served from the queue
    cache.write(0, 0, b"\x11" * 64)
    cache.writeback()
    want = page(3)
    want[:64] = 0x11
    assert bytes(pages.store.durable_page(0)) == bytes(want)
    # reads now always hit the resident tier
    before = cache.stats.snapshot()
    cache.get(0)
    cache.get(0)
    d = cache.stats.delta(before)
    assert d.pmem_fills == 2 and d.dram_hits == 0
    cache.pin(0)                       # no-op, must not raise
    cache.unpin(0)


# ==================================================== modeled read costs

def test_fig3_ladder_ordering():
    cm = COST_MODEL
    dram = cm.dram.read_ns(4096)
    pmem = cm.pmem_read_ns(4096)
    ssd = SSD_COST_MODEL.read_ns(4096)
    assert dram < pmem < ssd
    assert 3.0 < cm.load_latency_ns / cm.dram.load_latency_ns < 3.4
    assert ssd / dram > 100


def test_readpath_time_accounts_each_tier():
    cm = COST_MODEL
    c = CacheStats(dram_hits=10, dram_hit_bytes=10 * 4096,
                   pmem_fills=2, pmem_fill_bytes=2 * 4096,
                   ssd_fills=1, ssd_fill_bytes=4096)
    t = cm.readpath_time_ns(c)
    want = (10 * cm.dram.load_latency_ns
            + 10 * 4096 / (cm.dram.load_bw_gbps * (1 << 30)) * 1e9
            + 2 * cm.load_latency_ns
            + 2 * 4096 / (cm.load_bw_gbps * (1 << 30)) * 1e9
            + SSD_COST_MODEL.read_latency_ns
            + 4096 / (SSD_COST_MODEL.read_bw_gbps * (1 << 30)) * 1e9)
    assert abs(t - want) < 1e-6 * want


def test_engine_time_folds_dram_hits():
    cm = COST_MODEL
    stats = PMemStats()
    c = CacheStats(dram_hits=5, dram_hit_bytes=5 * 4096)
    base = cm.engine_time_ns(stats, active_lanes=2)
    with_cache = cm.engine_time_ns(stats, active_lanes=2, cache=c)
    assert with_cache - base == pytest.approx(
        5 * cm.dram.load_latency_ns
        + 5 * 4096 / (cm.dram.load_bw_gbps * (1 << 30)) * 1e9)


def test_modeled_read_ns_window():
    _, _, _, cache = plain_rig()
    cache.put(0, page(1))
    cache.writeback()
    cache.invalidate()
    before = cache.stats.snapshot()
    cache.get(0)                       # one PMem fill
    cache.get(0)                       # one DRAM hit
    d = cache.stats.delta(before)
    ns = cache.modeled_read_ns(d)
    assert ns == pytest.approx(COST_MODEL.pmem_read_ns(512)
                               + COST_MODEL.dram.read_ns(512))


# ======================================================= pool.cache() API

def test_pool_cache_is_cached_and_conflict_checked():
    pool = Pool.create(None, 1 << 20)
    c1 = pool.cache(frames=8, admit_k=3)
    assert pool.cache() is c1
    assert pool.cache(frames=8, admit_k=3) is c1
    with pytest.raises(ValueError, match="frame"):
        pool.cache(frames=16)
    with pytest.raises(ValueError, match="admission|admits"):
        pool.cache(admit_k=1)


def test_multi_store_needs_explicit_store():
    pool = Pool.create(None, 1 << 21)
    a = pool.pages("a", npages=4, page_size=512)
    b = pool.pages("b", npages=4, page_size=512)
    cache = pool.cache(frames=8)
    cache.attach_pages(a, flushq=FlushQueue(a))
    cache.attach_pages(b, flushq=FlushQueue(b))
    with pytest.raises(ValueError, match="store="):
        cache.get(0)
    cache.put(0, page(1), store=a)
    cache.put(0, page(2), store=b)
    cache.writeback(a)
    cache.writeback(b)
    assert bytes(a.store.durable_page(0)) == bytes(page(1))
    assert bytes(b.store.durable_page(0)) == bytes(page(2))


def test_unregistered_store_rejected():
    pool, pages, fq, cache = plain_rig()
    other = Pool.create(None, 1 << 20).pages("x", npages=2, page_size=512)
    with pytest.raises(ValueError, match="not registered"):
        cache.get(0, store=other)


# ====================================== consumers: KV on a bounded cache

def val(seed, size=64):
    return bytes([(seed + j) % 256 for j in range(size)])


def test_kv_bounded_cache_roundtrip_and_recovery():
    cfg = KVConfig(npages=8, page_size=512, value_size=64,
                   log_capacity=1 << 15, cache_frames=3)
    pool = Pool.create(None, PersistentKV.region_bytes(cfg))
    kv = pool.kv("kv", cfg)
    assert kv.cache.capacity == 3
    expected = {}
    for i in range(40):
        k = (i * 7) % cfg.nkeys
        kv.put(k, val(i))
        expected[k] = val(i)
    for k, v in expected.items():
        assert kv.get(k) == v, k
    kv.checkpoint()
    kv.put(0, val(99))
    expected[0] = val(99)
    pool.pmem.crash(rng=np.random.default_rng(5), evict_prob=0.6)
    kv2 = PersistentKV.open(Pool.open(pmem=pool.pmem), cfg, name="kv")
    for k, v in expected.items():
        assert kv2.get(k) == v, k


def test_kv_tiered_bounded_cache():
    cfg = KVConfig(npages=16, page_size=512, value_size=64,
                   log_capacity=1 << 15, slot_budget=4, wal_lanes=2,
                   wal_gen_sets=2, flush_lanes=2, cache_frames=5)
    pool = Pool.create(None, PersistentKV.region_bytes(cfg))
    pool.attach_ssd(SSD(1 << 22))
    kv = pool.kv("kv", cfg)
    expected = {}
    for i in range(120):
        k = (i * 11) % cfg.nkeys
        kv.put(k, val(i))
        expected[k] = val(i)
        if i % 30 == 29:
            kv.checkpoint()
    for k, v in expected.items():
        assert kv.get(k) == v, k
    assert kv.cache.frames_in_use <= 5


def test_kv_default_cache_is_full_buffer_pool():
    cfg = KVConfig(npages=4, page_size=512, value_size=64,
                   log_capacity=1 << 14)
    pool = Pool.create(None, PersistentKV.region_bytes(cfg))
    kv = pool.kv("kv", cfg)
    assert kv.cache.capacity == cfg.npages


def test_kv_admit_k_conflict_with_existing_pool_cache_raises():
    # a non-default cache_admit_k must be verified against a pre-existing
    # pool cache, not silently dropped
    cfg = KVConfig(npages=4, page_size=512, value_size=64,
                   log_capacity=1 << 14, cache_admit_k=5)
    pool = Pool.create(None, PersistentKV.region_bytes(cfg))
    pool.cache(frames=8, admit_k=2)
    with pytest.raises(ValueError, match="admits"):
        pool.kv("kv", cfg)
    # ...while the default admit_k reuses the existing cache quietly
    pool2 = Pool.create(None, PersistentKV.region_bytes(cfg))
    shared = pool2.cache(frames=8, admit_k=2)
    kv = pool2.kv("kv", KVConfig(npages=4, page_size=512, value_size=64,
                                 log_capacity=1 << 14))
    assert kv.cache is shared


# =========================== consumers: checkpoint snapshots live in frames

def test_checkpoint_snapshots_from_cache():
    from repro.persistence import CheckpointConfig, CheckpointManager
    cfg = CheckpointConfig(page_size=8192, threads=2)
    mgr = CheckpointManager(None, cfg)
    state = {"w": np.arange(6000, dtype=np.uint8)}
    r1 = mgr.save(1, state)
    assert r1.pages_cow == r1.pages_total            # first save: full
    r2 = mgr.save(2, state)
    assert r2.pages_clean == r2.pages_total          # unchanged: all clean
    state["w"] = state["w"].copy()
    state["w"][0] = 255
    r3 = mgr.save(3, state)
    assert r3.pages_clean == r3.pages_total - 1      # one dirty page
    assert mgr._cache.frames_in_use >= 1
    step, restored = mgr.restore()
    assert step == 3
    np.testing.assert_array_equal(restored["w"], state["w"])
    # restore seeded the snapshot frames: an unchanged re-save is clean
    r4 = mgr.save(4, state)
    assert r4.pages_clean == r4.pages_total


def test_checkpoint_bounded_frames_degrade_to_full_rewrite():
    from repro.persistence import CheckpointConfig, CheckpointManager
    cfg = CheckpointConfig(page_size=8192, cache_frames=1)
    mgr = CheckpointManager(None, cfg)
    state = {"a": np.arange(20000, dtype=np.uint8)}    # 3 pages > 1 frame
    mgr.save(1, state)
    r2 = mgr.save(2, state)                            # snapshots evicted
    assert r2.pages_cow == r2.pages_total              # conservative: full
    step, restored = mgr.restore()
    np.testing.assert_array_equal(restored["a"], state["a"])


# ============================= Trainer cadence: generational WAL + spill

def test_wal_roll_cadence_retires_generations():
    """The Trainer's checkpoint-cadence WAL discipline (pool.wal with
    gen_sets, roll per checkpoint, spill drain retiring the sealed
    generation) keeps the ring bounded with every generation readable
    from exactly one tier — the loop body Trainer.run now executes."""
    from repro.persistence import StepRecord
    pool = Pool.create(None, 1 << 21)
    pool.attach_ssd(SSD(1 << 22))
    sp = SpillScheduler(pool, name="twsp", map_capacity=1 << 13)
    wal = pool.wal("train_wal", capacity_steps=64, lanes=2, gen_sets=2)
    wal.log.attach_spill(sp)
    ckpt_every = 5
    for step in range(20):
        wal.commit_step(StepRecord(step + 1, step + 1, (0, 0), 0.5, 1.0,
                                   1.0))
        if (step + 1) % ckpt_every == 0:
            wal.roll()
            sp.drain()
    assert wal.log.current_gen == 5
    assert wal.log.retired_upto == 4                 # all sealed gens on SSD
    for gen in range(1, 5):
        src, entries = wal.log.read_generation(gen)
        assert src == "ssd"
        steps = [StepRecord.unpack(e).step for e in entries]
        assert steps == list(range((gen - 1) * ckpt_every + 1,
                                   gen * ckpt_every + 1))


def test_trainer_config_threads_gen_sets():
    """TrainerConfig grew the knob and Trainer wires the retirement path
    (spot-check the wiring without spinning up a jax model)."""
    import inspect
    from repro.launch.train import Trainer, TrainerConfig
    assert TrainerConfig(wal_gen_sets=3).wal_gen_sets == 3
    src = inspect.getsource(Trainer)
    assert "attach_spill" in src and ".roll()" in src


# ========================================= SSD arena reclamation on reopen

def test_reopen_rebuilds_free_extents_from_map():
    pool, ssd, sp, pages, fq, cache = tiered_rig(frames=0, admit_k=1,
                                                 npages=8, nslots=3)
    for pid in range(6):
        cache.put(pid, page(pid + 1))
        cache.writeback()
    sp.ensure_slots(pages.store, need=3)     # everything cold goes to SSD
    spilled = set(sp.spilled_pages(pages.store))
    assert len(spilled) >= 4
    # promote two pages back (admit_k=1: first read admits): their
    # tombstoned extents become holes
    for pid in sorted(spilled)[:2]:
        cache.get(pid)
        assert sp.residency(pages.store, pid) == "pmem"
    bump_before = sp._bump

    pool2 = Pool.open(pmem=pool.pmem)
    pool2.attach_ssd(ssd)
    sp2 = SpillScheduler(pool2, name="sp")
    pages2 = pool2.pages("heap")
    sp2.attach_pages(pages2)
    holes = sum(ln for _, ln in sp2._free_extents)
    assert holes >= 2 * 512, "promoted pages' extents not reclaimed"
    # new spills must reuse the holes instead of growing the arenas
    sp2.ensure_slots(pages2.store, need=3)
    assert sp2._bump == bump_before, "reopen spill grew past the old bump"
    # and everything still reads back correctly
    for pid in range(6):
        assert bytes(sp2.read_page(pages2.store, pid, promote=False)) \
            == bytes(page(pid + 1))


def test_free_extents_exclude_live_records():
    pool, ssd, sp, pages, fq, cache = tiered_rig(frames=0, admit_k=1,
                                                 npages=8, nslots=3)
    for pid in range(6):
        cache.put(pid, page(pid + 16))
        cache.writeback()
    sp.ensure_slots(pages.store, need=3)
    pool2 = Pool.open(pmem=pool.pmem)
    pool2.attach_ssd(ssd)
    sp2 = SpillScheduler(pool2, name="sp")
    live = sorted((off, off + ln) for off, ln, _, _
                  in sp2._page_map.values())
    for foff, fln in sp2._free_extents:
        for loff, lend in live:
            assert foff + fln <= loff or foff >= lend, \
                "free extent overlaps a live record"


# ==================================== DRAM-state invalidation bug sweep

def test_invalidate_pops_parked_pending_images():
    """A restore that rewrites the page table must not leave pre-restore
    bytes parked in the flush queue: the next epoch drain would flush
    them over the restored pages."""
    pool, pages, fq, cache = plain_rig(frames=2)
    cache.put(0, page(1))
    cache.writeback()                      # durable baseline: page 0 = 1
    cache.put(0, page(7))                  # pre-restore dirty content
    cache.put(1, page(8))
    cache.get(5)                           # both frames dirty -> one parks
    assert fq.pending_pids(), "scenario needs a parked image"
    cache.invalidate()
    assert fq.pending_pids() == []
    assert cache.frames_in_use == 0
    # "restore": reseed the durable content, then drain an epoch — the
    # parked pre-restore image must not resurrect
    cache.install(0, page(1))
    fq.flush_epoch()
    assert bytes(pages.store.durable_page(0)) == bytes(page(1))


def test_install_supersedes_parked_image():
    """install() must pop a parked pending image the way put() does —
    a restore's content wins over a pre-restore parked copy."""
    pool, pages, fq, cache = plain_rig(frames=2)
    cache.put(0, page(3))
    cache.writeback()                      # durable baseline: page 0 = 3
    cache.put(0, page(5))                  # dirty again (pre-restore)
    cache.put(1, page(6))
    cache.get(5)                           # parks page 0's dirty image
    assert 0 in fq.pending_pids()
    cache.install(0, page(3))              # restore reseeds page 0
    assert 0 not in fq.pending_pids()
    fq.flush_epoch()
    assert bytes(pages.store.durable_page(0)) == bytes(page(3))


def test_install_supersedes_parked_image_frames0():
    pool, pages, fq, cache = plain_rig(frames=0)
    cache.put(0, page(3))
    fq.flush_epoch()                       # durable baseline: page 0 = 3
    cache.put(0, page(5))                  # parks straight into pending
    assert 0 in fq.pending_pids()
    cache.install(0, page(3))              # restore supersedes the park
    assert 0 not in fq.pending_pids()
    fq.flush_epoch()
    assert bytes(pages.store.durable_page(0)) == bytes(page(3))


def test_invalidate_refuses_pinned_frames():
    """Discarding a pinned frame would break the pin contract mid-epoch
    (spill.pin_guard stops guarding the page, a later unpin raises):
    invalidate must refuse, like drop()."""
    pool, pages, fq, cache = plain_rig()
    cache.put(0, page(1))
    cache.put(1, page(2))
    cache.pin(0)
    with pytest.raises(ValueError, match="pinned"):
        cache.invalidate()
    # nothing was dropped by the refused call
    assert cache.peek(0) is not None
    assert cache.peek(1) is not None
    cache.unpin(0)                         # the pin contract still holds
    cache.invalidate()
    assert cache.frames_in_use == 0


def test_quota_overflow_counter_when_all_pinned():
    """An owner-restricted sweep that fails because every frame of the
    owner is pinned overflows the cap best-effort — but audibly, via
    CacheStats.quota_overflows (globally and under the owner)."""
    pool, pages, fq, cache = plain_rig(frames=4)
    cache.set_quota("heap", 1)
    cache.put(0, page(1))
    cache.pin(0)
    cache.put(1, page(2))                  # at quota, only frame pinned
    assert cache.stats.quota_overflows == 1
    assert cache.owner_stats("heap").quota_overflows == 1
    assert cache.frames_of("heap") == 2    # the overshoot really happened
    cache.unpin(0)
    cache.put(2, page(3))                  # now evictable: no new overflow
    assert cache.stats.quota_overflows == 1
    assert cache.frames_of("heap") <= 2


# ================================ NUMA-aware fills + far-first eviction

def numa_rig(*, frames=4, npages=8, page_size=512):
    """A 2-socket pool with one near-homed and one far-homed page
    region sharing a cache whose consumers fault from socket 0."""
    pool = Pool.create(None, 1 << 21, sockets=2)
    near = pool.pages("near", npages=npages, page_size=page_size, socket=0)
    far = pool.pages("far", npages=npages, page_size=page_size, socket=1)
    fq_n = FlushQueue(near, lanes=2)
    fq_f = FlushQueue(far, lanes=2)
    cache = BufferManager(pool, frames=frames, local_socket=0)
    cache.attach_pages(near, flushq=fq_n)
    cache.attach_pages(far, flushq=fq_f)
    return pool, near, far, cache


def test_remote_fill_accounting():
    pool, near, far, cache = numa_rig()
    for h, n in ((near, 0), (far, 0)):
        cache.put(0, page(9), store=h)
        cache.writeback(store=h)
        cache.invalidate(store=h)
    c0 = cache.stats.snapshot()
    cache.get(0, store=near)               # near-homed slot: local fill
    assert cache.stats.delta(c0).remote_fills == 0
    c1 = cache.stats.snapshot()
    cache.get(0, store=far)                # far-homed slot: remote fill
    d = cache.stats.delta(c1)
    assert d.remote_fills == 1 and d.remote_fill_bytes == 512
    assert d.pmem_fills == 1               # remote is a subset, not extra
    # per-owner attribution follows the accessed region
    assert cache.owner_stats("far").remote_fills == 1
    assert cache.owner_stats("near").remote_fills == 0


def test_remote_fill_charged_izraelevitz_rung():
    """readpath_time_ns and engine_time_ns(cache=) both add the
    (numa_remote_block_mult - 1) excess for exactly the remote fills;
    zero remote counts add exactly 0.0 (all-near bit-parity)."""
    near = CacheStats(pmem_fills=1, pmem_fill_bytes=512)
    remote = CacheStats(pmem_fills=1, pmem_fill_bytes=512,
                        remote_fills=1, remote_fill_bytes=512)
    surcharge = ((COST_MODEL.numa_remote_block_mult - 1.0)
                 * COST_MODEL.pmem_read_time_ns(1, 512))
    assert COST_MODEL.remote_fill_ns(0, 0) == 0.0
    assert COST_MODEL.remote_fill_ns(1, 512) == surcharge
    assert (COST_MODEL.readpath_time_ns(remote)
            == COST_MODEL.readpath_time_ns(near) + surcharge)
    pm = PMemStats()
    assert (COST_MODEL.engine_time_ns(pm, active_lanes=1, cache=remote)
            == COST_MODEL.engine_time_ns(pm, active_lanes=1, cache=near)
            + surcharge)


def test_far_first_eviction_prefers_far_clean():
    pool, near, far, cache = numa_rig(frames=2)
    for h in (near, far):
        cache.put(0, page(4), store=h)
        cache.writeback(store=h)
        cache.invalidate(store=h)
    cache.get(0, store=near)               # near frame (ring slot 0)
    cache.get(0, store=far)                # far frame  (ring slot 1)
    cache.get(1, store=near)               # pressure: one must go
    assert cache.peek(0, store=near) is not None, \
        "far-first eviction must spare the near frame"
    assert cache.peek(0, store=far) is None
    assert cache.stats.evictions_clean == 1


def test_numa_evict_off_restores_socket_blind_clock():
    pool, near, far, cache = numa_rig(frames=2)
    cache.numa_evict = False
    for h in (near, far):
        cache.put(0, page(4), store=h)
        cache.writeback(store=h)
        cache.invalidate(store=h)
    cache.get(0, store=near)
    cache.get(0, store=far)
    cache.get(1, store=near)               # clock order: near frame first
    assert cache.peek(0, store=near) is None
    assert cache.peek(0, store=far) is not None


# ======================================== 2Q scan resistance (scan_frac)

def test_scan_cycles_probationary_fraction_only():
    """With a quota and scan_frac < 1, an ingest scan (sequential puts —
    the access shape that actually churns the clock, since put installs
    carry a ref bit and force the hand to lap) recycles only the
    probationary fraction of the owner's budget: the re-referenced
    (protected) hot set stays resident."""
    pool, pages, fq, cache = plain_rig(frames=8, npages=16)
    cache.set_quota("heap", 4)
    cache.set_scan_frac("heap", 0.5)       # probationary segment: 2
    for pid in (0, 1):                     # hot set: install + graduate
        cache.get(pid)
        cache.get(pid)
    for pid in range(2, 16):               # one sequential ingest pass
        cache.put(pid, page(pid))
        if pid % 4 == 1:
            cache.writeback()              # keep the parked set bounded
    assert cache.peek(0) is not None, "scan churned the protected hot set"
    assert cache.peek(1) is not None
    assert cache.frames_of("heap") <= 5    # quota + at most the overshoot


def test_scan_frac_one_disables_the_split():
    """Same ingest scan, split off (scan_frac=1.0): the clock cycles the
    whole quota and the hot set churns — the fairness gap scan_frac
    exists to close."""
    pool, pages, fq, cache = plain_rig(frames=8, npages=16)
    cache.set_quota("heap", 4)             # scan_frac defaults to 1.0
    for pid in (0, 1):
        cache.get(pid)
        cache.get(pid)
    for pid in range(2, 16):
        cache.put(pid, page(pid))
        if pid % 4 == 1:
            cache.writeback()
    assert cache.peek(0) is None and cache.peek(1) is None


def test_scan_frac_validation_and_overrides():
    pool, pages, fq, cache = plain_rig()
    with pytest.raises(ValueError):
        cache.set_scan_frac("heap", 0.0)
    with pytest.raises(ValueError):
        cache.set_scan_frac("heap", 1.5)
    cache.set_scan_frac("heap", 0.25)
    assert cache.scan_frac_of("heap") == 0.25
    assert cache.scan_frac_of("other") == 1.0
    cache.set_scan_frac("heap", None)      # revert to the cache-wide value
    assert cache.scan_frac_of("heap") == 1.0
    with pytest.raises(ValueError):
        BufferManager(None, frames=4, scan_frac=0.0)


def test_pool_cache_scan_frac_fixed_at_first_construction():
    pool = Pool.create(None, 1 << 20)
    pool.cache(frames=4, scan_frac=0.5)
    assert pool.cache(scan_frac=0.5) is pool.cache()   # same value: fine
    with pytest.raises(ValueError, match="scan_frac"):
        pool.cache(scan_frac=0.25)


def test_kv_config_threads_scan_frac():
    cfg = KVConfig(npages=8, page_size=512, value_size=64,
                   log_capacity=1 << 15, cache_frames=4,
                   cache_scan_frac=0.5)
    pool = Pool.create(None, PersistentKV.region_bytes(cfg))
    kv = pool.kv("kv", cfg)
    assert kv.cache.scan_frac == 0.5
