"""Hypothesis property: a crash at ANY point of a region allocation, with
ANY eviction subset, never corrupts previously committed regions — the
directory recovers every committed record and its contents bit-exact.

Requires the ``test`` extra; deterministic pool tests live in
``test_pool.py``.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

import repro.core.directory as directory_mod
from repro.core.directory import KIND_LOG
from repro.pool import Pool

SIZE = 1 << 19


@settings(max_examples=80, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    n_entries=st.integers(0, 6),
    payload=st.binary(min_size=1, max_size=120),
    crash_stage=st.sampled_from(["placed", "initialized", "entry_stored"]),
    seed=st.integers(0, 2**31 - 1),
    prob=st.sampled_from([0.0, 0.25, 0.5, 0.75, 1.0]),
)
def test_crash_mid_allocation_never_corrupts_committed(
        n_entries, payload, crash_stage, seed, prob):
    pool = Pool.create(None, SIZE)
    log = pool.log("committed", capacity=1 << 14, technique="zero")
    appended = []
    for i in range(n_entries):
        log.append(payload + bytes([i]))
        appended.append(payload + bytes([i]))
    rec_a = pool.regions()["committed"]
    img_a = pool.pmem.durable_view()[rec_a.base : rec_a.base + rec_a.length].copy()

    # drive the allocation protocol up to the chosen crash point
    d = pool.directory
    rec, slot = d._place("newborn", KIND_LOG, 1 << 14, (2, 1, 1, 0))
    if crash_stage in ("initialized", "entry_stored"):
        d._initialize(rec)
    if crash_stage == "entry_stored":
        entry = directory_mod._ENTRY.pack(
            b"newborn", rec.kind, rec.generation, rec.base, rec.length,
            *rec.meta)
        pool.pmem.store(d._entry_off(slot), entry, streaming=True)
        # no fence: durability of the entry is up to spontaneous eviction
    pool.pmem.crash(rng=np.random.default_rng(seed), evict_prob=prob)

    pool2 = Pool.open(pmem=pool.pmem)
    got_a = pool2.regions()["committed"]
    assert (got_a.base, got_a.length, got_a.meta) == \
        (rec_a.base, rec_a.length, rec_a.meta)
    img2 = pool.pmem.durable_view()[rec_a.base : rec_a.base + rec_a.length]
    assert np.array_equal(img2, img_a), "committed region not bit-exact"
    assert pool2.log("committed").recovered.entries == appended

    if "newborn" in pool2.regions():
        # only possible in the entry_stored stage, and only as a valid
        # empty region over durably zeroed space
        assert crash_stage == "entry_stored"
        assert pool2.log("newborn").recovered.entries == []
