"""Hypothesis property: a crash at ANY point of a region allocation, with
ANY eviction subset, never corrupts previously committed regions — the
directory recovers every committed record and its contents bit-exact.

The property body lives in ``tests/corpus_runner.py`` (shared with the
deterministic regression corpus in ``test_crash_corpus.py``). Requires
the ``test`` extra; deterministic pool tests live in ``test_pool.py``.
"""

import pytest

pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from corpus_runner import run_pool_alloc_crash


@settings(max_examples=80, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    n_entries=st.integers(0, 6),
    payload=st.binary(min_size=1, max_size=120),
    crash_stage=st.sampled_from(["placed", "initialized", "entry_stored"]),
    seed=st.integers(0, 2**31 - 1),
    prob=st.sampled_from([0.0, 0.25, 0.5, 0.75, 1.0]),
)
def test_crash_mid_allocation_never_corrupts_committed(
        n_entries, payload, crash_stage, seed, prob):
    run_pool_alloc_crash(n_entries, payload, crash_stage, seed, prob)
