"""CSE138-style acceptance scenarios for ``repro.cluster``, ported onto
the modeled engines — deterministic, seed-driven, tier-1 (no extras).

Three scenario families, mirroring the classic distributed-KV
assignment-test design:

* **Key-assignment consistency** — every key is answered by exactly one
  owner per view; assignment is a pure function of ids (bit-identical
  across processes), balanced, durable across reopen, and moves
  minimally when the shard set changes.
* **Resharding** — after a view change all data is reachable at the new
  owners, ONLY the migrating ranges' bytes moved (page images +
  committed WAL records, predicted exactly), no-op reshards move
  nothing, round trips restore the original assignment, and interrupted
  migrations resume to convergence.
* **Causal chains** — a read observing a write implies all its causal
  predecessors are observable, across shards and across crashes: a
  session's cross-shard dependency commits make each shard's recovered
  WAL prefix cover every predecessor of any surviving write.

Everything is deterministic from literal seeds: identical runs produce
bit-identical ``ClusterKV.digest()`` values, which the determinism
tests assert outright. Membership policies (heartbeat failure
detection, EWMA straggler cordoning) are exercised where they feed
view planning; the crash-mid-reshard protocol points live in
``test_crash_corpus.py``.
"""

import numpy as np
import pytest

from repro.cluster import (BackupStepPolicy, ClusterConfig, ClusterKV,
                           HeartbeatRegistry, ShardMap, plan_view,
                           rendezvous_owner)
from repro.core import KVConfig
from repro.core.costmodel import COST_MODEL
from repro.core.ssd import SSD
from repro.pool import Pool

from corpus_runner import CrashAt, SimCrash


def small_cfg(**kv_kw) -> ClusterConfig:
    kw = dict(npages=8, page_size=512, value_size=64, log_capacity=1 << 15)
    kw.update(kv_kw)
    return ClusterConfig(kv=KVConfig(**kw), n_ranges=8)


def make_cluster(nshards=3, *, cfg=None, initial=None, tiered=False,
                 npools=None):
    """A cluster on fresh in-memory pools; returns (cfg, meta, pools,
    ssds, cluster). ``initial`` restricts the first view to a subset of
    the ``npools`` (default ``nshards``) pools built."""
    cfg = cfg or small_cfg(**({"slot_budget": 4} if tiered else {}))
    meta = Pool.create(None, ClusterKV.meta_pool_bytes(cfg))
    pools, ssds = {}, {}
    for sid in range(npools if npools is not None else nshards):
        pools[sid] = Pool.create(None, ClusterKV.shard_pool_bytes(cfg)
                                 + (1 << 18 if tiered else 0))
        if tiered:
            ssds[sid] = SSD(1 << 23)
            pools[sid].attach_ssd(ssds[sid])
    c = ClusterKV(meta, pools, cfg,
                  shards=initial if initial is not None else range(nshards))
    return cfg, meta, pools, ssds, c


def val(key: int, tag: str, size: int = 64) -> bytes:
    s = f"{tag}:{key}:".encode()
    return (s * (size // len(s) + 1))[:size]


def fill(c, cfg, tag="a"):
    for k in range(cfg.nkeys):
        c.put(k, val(k, tag, cfg.kv.value_size))
    c.commit()


def reopen(meta, pools, ssds, cfg):
    meta2 = Pool.open(pmem=meta.pmem)
    pools2 = {}
    for sid, p in pools.items():
        pools2[sid] = Pool.open(pmem=p.pmem)
        if sid in ssds:
            pools2[sid].attach_ssd(ssds[sid])
    return ClusterKV.open(meta2, pools2, cfg)


# ================================================= assignment consistency

def test_every_key_exactly_one_owner():
    cfg, _, _, _, c = make_cluster(3)
    by_owner = {}
    for k in range(cfg.nkeys):
        sid = c.owner_of(k)
        assert sid in c.shards
        by_owner.setdefault(sid, []).append(k)
    # one owner per key by construction; the partition must cover the
    # whole key space and match the per-range ownership records
    assert sum(len(v) for v in by_owner.values()) == cfg.nkeys
    owners = c.map.owners()
    for k in range(cfg.nkeys):
        assert c.owner_of(k) == owners[c.range_of(k)]


def test_ranges_are_page_aligned():
    cfg, _, _, _, c = make_cluster(2)
    for k in range(cfg.nkeys):
        pid = k // cfg.kv.recs_per_page
        assert c.range_of(k) == pid // cfg.pages_per_range
    # all keys of one page share a range, hence an owner
    for pid in range(cfg.kv.npages):
        keys = range(pid * cfg.kv.recs_per_page,
                     (pid + 1) * cfg.kv.recs_per_page)
        assert len({c.owner_of(k) for k in keys}) == 1


def test_assignment_pure_function_of_ids():
    a = {r: rendezvous_owner(r, [0, 1, 2]) for r in range(64)}
    b = {r: rendezvous_owner(r, [2, 0, 1]) for r in range(64)}
    assert a == b                       # order-independent
    _, _, _, _, c1 = make_cluster(3)
    _, _, _, _, c2 = make_cluster(3)
    assert c1.map.owners() == c2.map.owners()


def test_assignment_balanced():
    counts = {0: 0, 1: 0, 2: 0}
    for r in range(96):
        counts[rendezvous_owner(r, [0, 1, 2])] += 1
    # 96 ranges over 3 shards: each should land near 32; rendezvous over
    # a full-avalanche mix must not starve or swamp anyone
    for sid, n in counts.items():
        assert 16 <= n <= 48, (sid, counts)


def test_minimal_movement_on_add():
    before = {r: rendezvous_owner(r, [0, 1, 2]) for r in range(96)}
    after = {r: rendezvous_owner(r, [0, 1, 2, 3]) for r in range(96)}
    moved = {r for r in before if before[r] != after[r]}
    assert moved                         # the new shard does win ranges
    for r in moved:
        assert after[r] == 3             # ...and ONLY the new shard


def test_minimal_movement_on_remove():
    before = {r: rendezvous_owner(r, [0, 1, 2, 3]) for r in range(96)}
    after = {r: rendezvous_owner(r, [0, 1, 2]) for r in range(96)}
    for r in range(96):
        if before[r] != 3:               # survivors keep everything
            assert after[r] == before[r]


def test_shard_map_durable_across_reopen():
    pool = Pool.create(None, 1 << 18)
    sm = ShardMap(pool, n_ranges=16, nkeys=128, shards=[0, 1, 2])
    view = sm.begin_view([0, 1, 2, 3])
    for r in sm.moving_ranges([0, 1, 2, 3]):
        sm.record_owner(r, view, 3)
    sm.commit_view()
    want = (sm.view, sm.shards, sm.owners())
    pool.pmem.crash(rng=np.random.default_rng(3), evict_prob=0.5)
    sm2 = ShardMap(Pool.open(pmem=pool.pmem))
    assert (sm2.view, sm2.shards, sm2.owners()) == want
    assert sm2.pending is None


def test_shard_map_pending_view_survives_reopen():
    pool = Pool.create(None, 1 << 18)
    sm = ShardMap(pool, n_ranges=8, nkeys=64, shards=[0, 1])
    sm.begin_view([0, 1, 2])
    pool.pmem.crash(rng=np.random.default_rng(4), evict_prob=1.0)
    sm2 = ShardMap(Pool.open(pmem=pool.pmem))
    assert sm2.pending == (2, (0, 1, 2))
    assert sm2.view == 1                 # still routing on the old view


def test_shard_map_creation_crash_before_genesis():
    """A crash between the map's region allocation and its first record
    leaves the head region present but the logs empty — reopening with
    the create arguments must re-run creation, not misread the pool as
    a corrupt existing map."""
    pool = Pool.create(None, 1 << 18)
    pool.raw("sm.hd", nbytes=2 * pool.geometry.cache_line)
    sm = ShardMap(pool, n_ranges=8, nkeys=64, shards=[0, 1, 2])
    assert sm.view == 1 and sm.pending is None
    assert sm.owners() == sm.assignment([0, 1, 2])
    # ...and the same partial state under ClusterKV (which keys its
    # reopen scrub off the hard-coded "sm.hd" directory entry)
    cfg = small_cfg()
    meta = Pool.create(None, ClusterKV.meta_pool_bytes(cfg))
    meta.raw("sm.hd", nbytes=2 * meta.geometry.cache_line)
    pools = {sid: Pool.create(None, ClusterKV.shard_pool_bytes(cfg))
             for sid in range(2)}
    c = ClusterKV(meta, pools, cfg)
    assert c.view == 1
    c.put(0, val(0, "a"))
    assert c.get(0) == val(0, "a")


@pytest.mark.parametrize("crash_after", [0, 1, 5])
def test_shard_map_creation_crash_mid_owners(crash_after):
    """Cut creation after ``crash_after`` ownership records (plus an
    arbitrary eviction subset): reopening with the create arguments
    finishes the initial view idempotently — every range owned by its
    rendezvous shard, view 1 committed, nothing pending."""

    class CrashingCreate(ShardMap):
        def record_owner(self, r, view, sid):
            if len(self._owner) >= crash_after:
                raise SimCrash("create")
            super().record_owner(r, view, sid)

    pool = Pool.create(None, 1 << 18)
    with pytest.raises(SimCrash):
        CrashingCreate(pool, n_ranges=8, nkeys=64, shards=[0, 1, 2])
    pool.pmem.crash(rng=np.random.default_rng(crash_after), evict_prob=0.5)
    sm = ShardMap(Pool.open(pmem=pool.pmem),
                  n_ranges=8, nkeys=64, shards=[0, 1, 2])
    assert sm.view == 1 and sm.pending is None
    assert (sm.n_ranges, sm.nkeys) == (8, 64)
    assert sm.owners() == sm.assignment([0, 1, 2])
    # the completed creation is durable: a plain reopen recovers it
    sm2 = ShardMap(Pool.open(pmem=pool.pmem))
    assert (sm2.view, sm2.owners()) == (1, sm.owners())


def test_shard_map_capacity_overflow_diagnostic():
    """A live record set that cannot fit a map buffer even after
    compaction surfaces the map_capacity diagnostic, not the log's
    generic error — including when the overflow happens *inside* the
    compaction rewrite."""
    pool = Pool.create(None, 1 << 20)
    with pytest.raises(RuntimeError, match="map_capacity"):
        ShardMap(pool, n_ranges=256, nkeys=2048, shards=[0, 1, 2],
                 map_capacity=1 << 10)


def test_ownership_map_compaction_ping_pong():
    cfg = ClusterConfig(kv=KVConfig(npages=8, page_size=512, value_size=64,
                                    log_capacity=1 << 15),
                        n_ranges=8, map_capacity=1 << 10)
    cfg2, meta, pools, ssds, c = make_cluster(3, cfg=cfg, npools=3,
                                              initial=[0, 1])
    fill(c, cfg)
    for target in ([0, 1, 2], [0, 1], [0, 1, 2], [0, 1], [0, 1, 2]):
        c.reshard(target)
    assert c.map._hd_counter >= 1, "compaction never flipped the head"
    assert c.map.owners() == c.map.assignment([0, 1, 2])
    c2 = reopen(meta, pools, ssds, cfg)
    assert c2.map.owners() == c.map.owners()
    for k in range(cfg.nkeys):
        assert c2.get(k) == val(k, "a")


def test_bad_shard_sets_rejected():
    cfg, meta, pools, _, c = make_cluster(2)
    with pytest.raises(ValueError):
        c.reshard([0, 1, 7])             # no pool behind shard 7
    with pytest.raises(KeyError):
        c.put(cfg.nkeys, b"x" * 64)      # key outside the space
    with pytest.raises(ValueError):
        ClusterKV(meta, pools, cfg, shards=[0, 9])


# ============================================================= resharding

def test_reshard_add_shard_all_reachable():
    cfg, _, _, _, c = make_cluster(3, npools=3, initial=[0, 1])
    fill(c, cfg)
    c.checkpoint()
    rep = c.reshard([0, 1, 2])
    assert rep.view == 2 and rep.shards == (0, 1, 2)
    assert rep.ranges_moved            # shard 2 won something
    for k in range(cfg.nkeys):
        assert c.get(k) == val(k, "a"), k


def test_reshard_remove_shard_all_reachable():
    cfg, _, _, _, c = make_cluster(3)
    fill(c, cfg)
    c.checkpoint()
    gone = [r for r, sid in c.map.owners().items() if sid == 2]
    rep = c.reshard([0, 1])
    assert set(rep.ranges_moved) == set(gone)
    for k in range(cfg.nkeys):
        assert c.get(k) == val(k, "a"), k
    # the removed shard is durably empty
    for pid in range(cfg.kv.npages):
        assert c.engine(2).durable_page_image(pid) is None


def test_reshard_bytes_exactly_migrating_pages():
    cfg, _, _, _, c = make_cluster(3, npools=3, initial=[0, 1])
    fill(c, cfg)
    c.checkpoint()                       # all data durable, WAL empty
    moving = c.map.moving_ranges([0, 1, 2])
    predicted = len(moving) * cfg.pages_per_range * cfg.kv.page_size
    rep = c.reshard([0, 1, 2])
    assert set(rep.ranges_moved) == set(moving)
    assert rep.page_bytes == predicted
    assert rep.wal_bytes == 0 and rep.wal_records_moved == 0
    assert rep.bytes_moved == predicted


def test_reshard_wal_only_when_never_checkpointed():
    cfg, _, _, _, c = make_cluster(3, npools=3, initial=[0, 1])
    fill(c, cfg)                         # no checkpoint: nothing flushed
    moving = set(c.map.moving_ranges([0, 1, 2]))
    n_moving_puts = sum(1 for k in range(cfg.nkeys)
                        if c.range_of(k) in moving)
    rep = c.reshard([0, 1, 2])
    assert rep.pages_moved == 0 and rep.page_bytes == 0
    assert rep.wal_records_moved == n_moving_puts
    for k in range(cfg.nkeys):
        assert c.get(k) == val(k, "a"), k


def test_noop_reshard_moves_nothing():
    cfg, _, _, _, c = make_cluster(3)
    fill(c, cfg)
    c.checkpoint()
    rep = c.reshard([0, 1, 2])           # same shard set
    assert rep.ranges_moved == () and rep.bytes_moved == 0
    assert c.view == 2                   # the view still advanced


def test_round_trip_reshard_restores_assignment():
    cfg, _, _, _, c = make_cluster(3, npools=3, initial=[0, 1])
    fill(c, cfg)
    c.checkpoint()
    before = c.map.owners()
    c.reshard([0, 1, 2])
    c.reshard([0, 1])
    assert c.map.owners() == before
    for k in range(cfg.nkeys):
        assert c.get(k) == val(k, "a"), k


def test_puts_route_to_new_owner_after_reshard():
    cfg, _, _, _, c = make_cluster(3, npools=3, initial=[0, 1])
    fill(c, cfg)
    c.checkpoint()
    rep = c.reshard([0, 1, 2])
    r = rep.ranges_moved[0]
    key = r * cfg.pages_per_range * cfg.kv.recs_per_page
    old, new = None, c.map.owners()[r]
    assert new == 2
    c.put(key, val(key, "z"))
    c.commit()
    # the put landed on the new owner's engine, not the old one's
    assert c.engine(new).get(key) == val(key, "z")
    assert c.get(key) == val(key, "z")


def test_reshard_tiered_source():
    cfg, _, _, _, c = make_cluster(3, tiered=True, npools=3, initial=[0, 1])
    fill(c, cfg)
    c.checkpoint()                       # slot_budget=4 < npages: spills
    assert any(c.engine(s)._spill.stats.pages_spilled
               for s in (0, 1)), "scenario must actually spill"
    rep = c.reshard([0, 1, 2])
    assert rep.ranges_moved
    for k in range(cfg.nkeys):
        assert c.get(k) == val(k, "a"), k


def test_transfer_term_monotonic_in_bytes():
    assert COST_MODEL.cluster_transfer_ns(0) == 0.0
    a = COST_MODEL.cluster_transfer_ns(4096)
    b = COST_MODEL.cluster_transfer_ns(8192)
    assert 0 < a < b
    # derated below the local NT-store rate: remote bytes are never free
    assert b - a >= 4096 / COST_MODEL.store_bw_nt_gbps


def test_reshard_charges_modeled_time():
    cfg, _, _, _, c = make_cluster(3, npools=3, initial=[0, 1])
    fill(c, cfg)
    c.checkpoint()
    rep = c.reshard([0, 1, 2])
    assert rep.transfer_ns == COST_MODEL.cluster_transfer_ns(rep.bytes_moved)
    assert rep.engine_ns > rep.transfer_ns > 0.0


def test_interrupted_reshard_resumes_to_convergence():
    cfg, _, _, _, c = make_cluster(4, npools=4, initial=[0, 1, 2, 3])
    fill(c, cfg)
    c.checkpoint()
    goal = c.map.assignment([0, 1])
    c.failpoints = CrashAt(6)            # lands mid-protocol, range 1+
    with pytest.raises(SimCrash):
        c.reshard([0, 1])
    c.failpoints = None
    assert c.map.pending == (2, (0, 1))
    mixed = c.map.owners()
    assert any(mixed[r] == goal[r] for r in mixed if goal[r] != 3) or True
    rep = c.resume()
    assert rep is not None and c.map.pending is None
    assert c.map.owners() == goal
    for k in range(cfg.nkeys):
        assert c.get(k) == val(k, "a"), k
    assert c.resume() is None            # nothing left to resume


def test_step_at_a_time_view_change():
    cfg, _, _, _, c = make_cluster(4, npools=4, initial=[0, 1, 2, 3])
    fill(c, cfg)
    c.checkpoint()
    vc = c.begin_reshard([0, 1])
    steps = 0
    while vc.step():
        steps += 1
        # foreground traffic interleaves between migration steps
        c.put(0, val(0, f"s{steps}"))
    assert steps == len(vc.moved) - 1
    assert c.map.pending is None
    assert c.get(0) == val(0, f"s{steps}")
    rep = vc.report()
    assert tuple(sorted(rep.ranges_moved)) == tuple(sorted(vc.moved))


# ========================================================== causal chains

def _keys_on_distinct_shards(c, cfg, n=3):
    """One key from a range of each of n distinct owners."""
    seen, keys = {}, []
    for r, sid in c.map.owners().items():
        if sid not in seen:
            seen[sid] = r
            keys.append(r * cfg.pages_per_range * cfg.kv.recs_per_page)
        if len(keys) == n:
            return keys
    raise AssertionError(f"need {n} distinct owners, got {len(keys)}")


def test_session_read_your_writes():
    cfg, _, _, _, c = make_cluster(3)
    s = c.session()
    ka, kb, kc = _keys_on_distinct_shards(c, cfg)
    for k in (ka, kb, kc):
        s.put(k, val(k, "w"))
        assert s.get(k) == val(k, "w")


def test_causal_chain_prefix_survives_crash():
    # group-commit WALs: appends are durable only at commit; the session
    # commits each dependency shard before writing the next link
    cfg = small_cfg(wal_lanes=2, wal_group_commit=4, wal_gen_sets=2,
                    auto_checkpoint=False)
    _, meta, pools, ssds, c = make_cluster(3, cfg=cfg)
    ka, kb, kc = _keys_on_distinct_shards(c, cfg)
    s = c.session()
    s.put(ka, val(ka, "w1"))
    s.put(kb, val(kb, "w2"))             # commits ka's shard first
    s.put(kc, val(kc, "w3"))             # commits kb's shard first
    # w3 is uncommitted; everything it causally follows is durable
    rng = np.random.default_rng(21)
    meta.pmem.crash(rng=rng, evict_prob=1.0)
    for p in pools.values():
        p.pmem.crash(rng=rng, evict_prob=1.0)
    c2 = reopen(meta, pools, ssds, cfg)
    assert c2.get(ka) == val(ka, "w1")
    assert c2.get(kb) == val(kb, "w2")
    got = c2.get(kc)
    assert got in (val(kc, "w3"), bytes(cfg.kv.value_size))
    # the invariant proper: a read observing a write implies all its
    # causal predecessors are observable — held above for every link


def test_causal_chain_across_view_change():
    cfg = small_cfg(wal_lanes=2, wal_group_commit=4, wal_gen_sets=2,
                    auto_checkpoint=False)
    _, meta, pools, ssds, c = make_cluster(3, cfg=cfg, npools=3,
                                           initial=[0, 1])
    ka, kb = _keys_on_distinct_shards(c, cfg, n=2)
    s = c.session()
    s.put(ka, val(ka, "w1"))
    s.put(kb, val(kb, "w2"))
    c.reshard([0, 1, 2])                 # may migrate either key's range
    assert c.get(ka) == val(ka, "w1")    # migration preserved the chain
    assert c.get(kb) == val(kb, "w2")
    s2 = c.session()
    s2.put(ka, val(ka, "w3"))            # chain continues on the new view
    s2.put(kb, val(kb, "w4"))
    rng = np.random.default_rng(22)
    meta.pmem.crash(rng=rng, evict_prob=1.0)
    for p in pools.values():
        p.pmem.crash(rng=rng, evict_prob=1.0)
    c2 = reopen(meta, pools, ssds, cfg)
    got_a, got_b = c2.get(ka), c2.get(kb)
    # w1 was committed by the dependency protocol (and survives
    # migration if its range moved); w3 was committed when s2 wrote w4
    assert got_a in (val(ka, "w1"), val(ka, "w3"))
    # w4 observable ⇒ its causal predecessor w3 observable
    if got_b == val(kb, "w4"):
        assert got_a == val(ka, "w3")
    else:
        # w2 may be lost (its shard's batch never committed) — allowed
        # precisely because nothing observable depended on it
        assert got_b in (val(kb, "w2"), bytes(cfg.kv.value_size))


def test_monotonic_reads_across_view_change():
    cfg, _, _, _, c = make_cluster(3, npools=3, initial=[0, 1])
    fill(c, cfg, tag="old")
    c.checkpoint()
    for k in range(0, cfg.nkeys, 5):
        c.put(k, val(k, "new"))
    c.commit()
    before = {k: c.get(k) for k in range(cfg.nkeys)}
    c.reshard([0, 1, 2])
    for k in range(cfg.nkeys):
        assert c.get(k) == before[k], k  # never an older value after


def test_interleaved_sessions_deterministic():
    def run():
        cfg, _, _, _, c = make_cluster(3)
        s1, s2 = c.session(), c.session()
        ks = _keys_on_distinct_shards(c, cfg)
        for i in range(12):
            s = s1 if i % 2 == 0 else s2
            k = ks[i % 3]
            s.put(k, val(k, f"i{i}"))
        s1.flush()
        s2.flush()
        return c.digest()

    assert run() == run()


# ============================================================ determinism

def test_full_scenario_digest_bit_identical():
    def run():
        cfg, _, _, _, c = make_cluster(3, npools=3, initial=[0, 1])
        fill(c, cfg)
        c.checkpoint()
        rep1 = c.reshard([0, 1, 2])
        for k in range(0, cfg.nkeys, 3):
            c.put(k, val(k, "b"))
        c.commit()
        rep2 = c.reshard([0, 1])
        return c.digest(), rep1, rep2

    d1, a1, b1 = run()
    d2, a2, b2 = run()
    assert d1 == d2
    assert a1 == a2 and b1 == b2         # byte counts AND modeled ns


def test_crash_recovery_deterministic():
    def run():
        cfg, meta, pools, ssds, c = make_cluster(3, npools=3, initial=[0, 1])
        fill(c, cfg)
        c.checkpoint()
        c.failpoints = CrashAt(4)
        try:
            c.reshard([0, 1, 2])
        except SimCrash:
            pass
        rng = np.random.default_rng(77)
        meta.pmem.crash(rng=rng, evict_prob=0.5)
        for p in pools.values():
            p.pmem.crash(rng=rng, evict_prob=0.5)
        c2 = reopen(meta, pools, ssds, cfg)
        c2.resume()
        return c2.digest()

    assert run() == run()


# ============================================================= membership

def test_heartbeat_detection_feeds_view_planning():
    reg = HeartbeatRegistry(deadline_s=5.0)
    for h in (0, 1, 2):
        reg.beat(h, now=0.0)
    reg.beat(0, now=4.0)
    reg.beat(1, now=4.0)
    assert reg.sweep(now=6.0) == [2]
    assert reg.alive == [0, 1]
    reg.beat(2, now=6.5)                 # dead is sticky
    assert reg.dead == {2}
    assert plan_view([0, 1, 2], registry=reg) == [0, 1]


def test_straggler_cordon_feeds_view_planning():
    pol = BackupStepPolicy(threshold=1.5, patience=2)
    for _ in range(6):
        for h in (0, 1, 2):
            pol.observe(h, 1.0 if h != 2 else 10.0)
        pol.evaluate()
    assert pol.cordoned == {2}
    assert plan_view([0, 1, 2], policy=pol) == [0, 1]
    with pytest.raises(ValueError):
        plan_view([2], policy=pol)       # nobody left


def test_decommission_via_planned_view():
    cfg, _, _, _, c = make_cluster(3)
    fill(c, cfg)
    c.checkpoint()
    reg = HeartbeatRegistry(deadline_s=1.0)
    for h in c.shards:
        reg.beat(h, now=0.0)
    reg.beat(0, now=2.0)
    reg.beat(1, now=2.0)
    reg.sweep(now=3.0)                   # shard 2 went silent
    rep = c.reshard(plan_view(c.shards, registry=reg))
    assert c.shards == (0, 1)
    for k in range(cfg.nkeys):
        assert c.get(k) == val(k, "a"), k
