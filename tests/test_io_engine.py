"""repro.io engine tests: group-commit barrier amortization (the PR's
acceptance criterion), batch-append parity, merged multi-lane recovery
with truncation repair, batched lane-partitioned page flushing, lane
accounting, and the legacy-shim deprecation warnings."""

import numpy as np
import pytest

from repro.core import COST_MODEL, KVConfig, LOG_TECHNIQUES, PMem, PersistentKV
from repro.io import FlushQueue, IOEngine, MultiLog
from repro.persistence import StepRecord, TrainWAL
from repro.pool import Pool


def fresh_pool(size=1 << 22):
    return Pool.create(None, size)


# ===================================================================== batch

@pytest.mark.parametrize("technique,expected", [("classic", 2), ("header", 2),
                                                ("zero", 1)])
def test_append_batch_barriers(technique, expected):
    """A whole batch costs what ONE append costs in barriers."""
    pool = fresh_pool()
    log = pool.log("l", capacity=1 << 20, technique=technique)
    log.append(b"warmup")
    before = pool.stats.barriers
    log.append_batch([bytes([i]) * 40 for i in range(16)])
    assert pool.stats.barriers - before == expected


@pytest.mark.parametrize("technique", ["classic", "header", "zero"])
def test_append_batch_recovery_parity(technique):
    """Batched appends recover identically to sequential appends."""
    payloads = [bytes([i]) * (5 + 7 * i) for i in range(12)]
    pool = fresh_pool()
    log = pool.log("l", capacity=1 << 20, technique=technique)
    log.append_batch(payloads[:5])
    log.append(payloads[5])
    log.append_batch(payloads[6:])
    rec = log.recover()
    assert rec.entries == payloads
    assert rec.lsns == list(range(1, 13))
    assert rec.offsets == sorted(rec.offsets)


def test_append_batch_full_is_all_or_nothing():
    pool = fresh_pool()
    log = pool.log("l", capacity=1 << 10, technique="zero")
    with pytest.raises(RuntimeError):
        log.append_batch([bytes(64)] * 64)
    assert log.recover().entries == []   # nothing was written


# ================================================================== multilog

def test_multilog_fewer_barriers_than_independent_lanes():
    """ACCEPTANCE: MultiLog with group commit issues strictly fewer
    barriers per appended entry than N independent single-lane logs."""
    n_entries, lanes = 64, 4
    pool = fresh_pool()
    ml = pool.multilog("ml", capacity=1 << 20, lanes=lanes,
                       technique="zero", group_commit=8)
    before = pool.stats.snapshot()
    for i in range(n_entries):
        ml.append(bytes([i % 256]) * 48)
    ml.commit()
    grouped = pool.stats.delta(before).barriers

    pool2 = fresh_pool()
    logs = [pool2.log(f"l{i}", capacity=1 << 18, technique="zero")
            for i in range(lanes)]
    before2 = pool2.stats.snapshot()
    for i in range(n_entries):
        logs[i % lanes].append(bytes([i % 256]) * 48)
    independent = pool2.stats.delta(before2).barriers

    assert grouped / n_entries < independent / n_entries
    assert independent == n_entries          # zero: 1 barrier per append
    assert grouped == lanes * (n_entries // lanes // 8)


def test_multilog_global_lsn_merge_recovery():
    pool = fresh_pool()
    ml = pool.multilog("ml", capacity=1 << 20, lanes=3, group_commit=4)
    payloads = [b"entry-%03d" % i for i in range(25)]
    for p in payloads:
        ml.append(p)
    ml.commit()
    rec = ml.recover()
    assert rec.entries == payloads           # glsn order, across lanes
    assert rec.glsns == list(range(1, 26))

    # reopen-by-name discovers lanes and merges
    ml2 = pool.multilog("ml")
    assert ml2.lanes == 3
    assert ml2.recovered.entries == payloads
    assert ml2.next_glsn == 26


def test_multilog_crash_recovers_consistent_prefix_and_repairs():
    """A lost batch in one lane cuts the global prefix; durable entries
    beyond the gap are discarded and their lanes truncated, so appending
    continues with no duplicate global LSNs."""
    pool = fresh_pool()
    ml = pool.multilog("ml", capacity=1 << 20, lanes=3, group_commit=2)
    for i in range(6):            # glsns 1..6, all lanes auto-commit
        ml.append(b"a%d" % i)
    ml.commit()
    ml.append(b"a6")              # glsn 7 -> lane 0, pending
    ml.append(b"a7")              # glsn 8 -> lane 1, pending
    ml._commit_lane(1)            # lane 1 commits glsn 8; glsn 7 is lost
    pool.pmem.crash(evict=lambda li: True)   # everything in flight survives

    pool2 = Pool.open(pmem=pool.pmem)
    ml2 = pool2.multilog("ml")
    assert ml2.recovered.glsns == [1, 2, 3, 4, 5, 6]
    assert ml2.recovered.discarded == 1      # durable glsn 8, beyond the gap
    assert ml2.next_glsn == 7
    ml2.append(b"b0", sync=True)             # re-issues glsn 7
    rec = ml2.recover()
    assert rec.glsns == [1, 2, 3, 4, 5, 6, 7]
    assert rec.entries[-1] == b"b0"          # not the discarded a7


def test_multilog_lane_accounting_and_engine_time():
    pool = fresh_pool()
    eng = IOEngine(pool, lanes=4, group_commit=8)
    ml = eng.multilog("ml", capacity=1 << 20)
    before = pool.stats.snapshot()
    for i in range(32):
        ml.append(bytes(48))
    ml.commit()
    d = pool.stats.delta(before)
    assert d.active_lanes() == 4
    assert sum(d.lane_barriers.values()) == d.barriers
    assert sum(d.lane_blocks_written.values()) == d.blocks_written
    # overlapping lanes: engine wall-clock < serialized wall-clock
    assert (COST_MODEL.engine_time_ns(d, active_lanes=4)
            < COST_MODEL.time_ns(d, threads=1))


def test_multilog_lane_sweep_fig2_shape():
    """Modeled throughput rises with lanes, then flattens past the
    write-combining lane limit (Fig. 2 shape)."""
    tput = {}
    for lanes in (1, 2, 4, 8):
        pool = fresh_pool(1 << 23)
        ml = pool.multilog("s", capacity=1 << 21, lanes=lanes, group_commit=8)
        before = pool.stats.snapshot()
        for _ in range(256):
            ml.append(bytes(48))
        ml.commit()
        ns = COST_MODEL.engine_time_ns(pool.stats.delta(before),
                                       active_lanes=lanes)
        tput[lanes] = 256 / ns
    assert tput[2] > 1.5 * tput[1]           # scales below the limit
    assert tput[4] > tput[2]
    assert tput[8] < 1.25 * tput[4]          # flattens past the limit


def test_multilog_create_fails_before_leaking_lane_regions():
    """Creation validates the worst lane name and the pool space BEFORE
    allocating lane 0 — a mid-loop failure would leak durable regions."""
    pool = fresh_pool()
    with pytest.raises(ValueError, match="region-name cap"):
        pool.multilog("abcdefghijklmn", capacity=1 << 16, lanes=12)
    with pytest.raises(ValueError, match="free bytes"):
        pool.multilog("big", capacity=1 << 30, lanes=4)
    assert all(not n.startswith(("abcdefghijklmn", "big"))
               for n in pool.regions())


def test_trainwal_lane_config_conflicts_raise():
    pool = fresh_pool()
    pool.wal("w", capacity_steps=50)            # single-lane
    with pytest.raises(ValueError, match="single-lane"):
        pool.wal("w", lanes=4)
    pool.wal("m", capacity_steps=50, lanes=2)   # multi-lane
    with pytest.raises(ValueError, match="cannot grow"):
        pool.wal("m", capacity_steps=10 ** 6)
    assert pool.wal("m", capacity_steps=50)._multilog


# =================================================================== flushq

def page_bytes(seed, size=16384):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 256, size, dtype=np.uint8)


def make_pages(npages=8, nslots=18, page=16384):
    pool = Pool.create(None, Pool.overhead_bytes() + nslots * (page + 4096)
                       + 64 * 4096)
    return pool, pool.pages("p", npages=npages, page_size=page, nslots=nslots)


def test_flush_queue_coalesces_same_page():
    pool, pages = make_pages()
    fq = pages.flush_queue(lanes=2)
    base = page_bytes(0)
    pages.flush_cow(0, base)
    p1 = base.copy()
    p1[1 * 64 : 3 * 64] ^= 0xFF              # lines 1, 2
    fq.enqueue(0, p1, [1, 2])
    p2 = p1.copy()
    p2[7 * 64 : 8 * 64] ^= 0xFF              # line 7
    fq.enqueue(0, p2, [7])
    assert len(fq) == 1
    rep = fq.flush_epoch()                   # one flush, dirty = {1, 2, 7}
    assert rep.pages == 1
    assert rep.cow + rep.mulog == 1
    np.testing.assert_array_equal(pages.read_page(0), p2)


def test_flush_queue_epoch_lane_partitioned():
    pool, pages = make_pages()
    for pid in range(8):
        pages.flush_cow(pid, page_bytes(pid))
    fq = pages.flush_queue(lanes=4)
    before = pool.stats.snapshot()
    for pid in range(8):
        fq.enqueue(pid, page_bytes(100 + pid))
    rep = fq.flush_epoch()
    assert rep.pages == 8 and rep.active_lanes == 4
    d = pool.stats.delta(before)
    assert d.active_lanes() == 4
    assert rep.modeled_ns == pytest.approx(
        COST_MODEL.engine_time_ns(d, active_lanes=4, burst=True))
    assert len(fq) == 0


def test_flush_queue_threads_move_hybrid_crossover():
    """The epoch's actual lane count drives the µLog-vs-CoW decision: a
    dirty count between the 7-lane and 1-lane crossovers flushes µLog in
    a 1-page epoch but CoW in a 7-lane epoch."""
    pool, pages = make_pages(npages=8, nslots=18)
    policy = pages.policy
    dirty = (policy.crossover(7) + policy.crossover(1)) // 2
    for pid in range(8):
        pages.flush_cow(pid, page_bytes(pid))
        pages.flush_cow(pid, page_bytes(pid))   # current + shadow pvn
    assert policy.prefer_mulog(dirty, 1)
    assert not policy.prefer_mulog(dirty, 7)

    fq1 = pages.flush_queue(lanes=7)
    fq1.enqueue(0, page_bytes(50), list(range(dirty)))
    rep1 = fq1.flush_epoch()                  # 1 page -> 1 active lane
    assert rep1.active_lanes == 1 and rep1.mulog == 1

    fq7 = pages.flush_queue(lanes=7)
    for pid in range(1, 8):
        fq7.enqueue(pid, page_bytes(60 + pid), list(range(dirty)))
    rep7 = fq7.flush_epoch()                  # 7 pages -> 7 active lanes
    assert rep7.active_lanes == 7
    assert rep7.cow == 7 and rep7.mulog == 0


# ============================================================ trainwal lanes

def test_trainwal_multilane_group_commit_and_recovery():
    pool = fresh_pool()
    wal = pool.wal("wal", capacity_steps=1000, lanes=4, group_commit=8)
    before = pool.stats.snapshot()
    for s in range(32):
        wal.commit_step(StepRecord(s, s * 16, (s, s + 1), float(s), 0.1, 1.0),
                        sync=False)
    wal.flush()
    barriers = pool.stats.delta(before).barriers
    assert barriers < 32                      # amortized vs 1/step single-lane
    assert wal.barriers_per_step() < 1

    pool.pmem.crash(evict=lambda li: True)
    pool2 = Pool.open(pmem=pool.pmem)
    wal2 = pool2.wal("wal")                   # lanes discovered on reopen
    assert [r.step for r in wal2.records] == list(range(32))
    assert wal2.last.data_cursor == 31 * 16


def test_trainwal_unsynced_tail_lost_on_crash_is_a_prefix():
    pool = fresh_pool()
    wal = pool.wal("wal", capacity_steps=1000, lanes=2, group_commit=16)
    for s in range(5):
        wal.commit_step(StepRecord(s, s, (0, 0), 0.0, 0.0, 1.0), sync=False)
    wal.flush()
    for s in range(5, 9):                     # buffered, never committed
        wal.commit_step(StepRecord(s, s, (0, 0), 0.0, 0.0, 1.0), sync=False)
    pool.pmem.crash(evict=lambda li: False)
    pool2 = Pool.open(pmem=pool.pmem)
    wal2 = pool2.wal("wal")
    assert [r.step for r in wal2.records] == list(range(5))


# =============================================================== kv lanes

def test_kv_checkpoint_with_flush_lanes():
    cfg = KVConfig(npages=8, page_size=1024, value_size=64,
                   log_capacity=1 << 15, flush_lanes=4)
    pool = Pool.create(None, PersistentKV.region_bytes(cfg))
    kv = pool.kv("kv", cfg)
    for k in range(0, 120, 3):
        kv.put(k, bytes([k % 256]) * 64)
    before = pool.stats.snapshot()
    kv.checkpoint()
    assert pool.stats.delta(before).active_lanes() == 4
    pool.pmem.crash(evict=lambda li: False)
    kv2 = PersistentKV.open(pool, cfg, name="kv")
    for k in range(0, 120, 3):
        assert kv2.get(k) == bytes([k % 256]) * 64


# ============================================================ deprecations

def test_legacy_trainwal_constructor_warns():
    pm = PMem(TrainWAL.capacity_for(10))
    pm.memset_zero()
    with pytest.warns(DeprecationWarning, match="TrainWAL"):
        TrainWAL(pm, 0, pm.size)


def test_legacy_kv_constructor_warns():
    cfg = KVConfig(npages=4, page_size=1024, value_size=64,
                   log_capacity=1 << 15)
    pm = PMem(PersistentKV.region_bytes(cfg))
    pm.memset_zero()
    with pytest.warns(DeprecationWarning, match="PersistentKV"):
        PersistentKV(pm, cfg)


def test_pool_constructors_do_not_warn():
    pool = fresh_pool()
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        cfg = KVConfig(npages=4, page_size=1024, value_size=64,
                       log_capacity=1 << 15)
        pool.kv("kv", cfg)
        pool.wal("w", capacity_steps=10)
