"""Per-architecture smoke tests (reduced configs, CPU): one forward +
gradient step asserting output shapes and finiteness, plus decode-vs-full
consistency for the cache paths of each family."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.data import synthetic_batch
from repro.models import decode_step, forward, init_caches, init_params, lm_loss

B, S = 2, 32


def reduced_f32(arch):
    return dataclasses.replace(get_reduced(arch), dtype="float32")


def batch_for(cfg):
    b = synthetic_batch(cfg, B, S, cursor=7)
    return {k: jnp.asarray(v) for k, v in b.items()}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grad_finite(arch):
    cfg = reduced_f32(arch)
    params = init_params(cfg, jax.random.key(0))
    batch = batch_for(cfg)

    logits, _ = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p, b: lm_loss(p, cfg, b), has_aux=True)
    )(params, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    leaves = jax.tree.leaves(grads)
    assert leaves, "no grads"
    for g in leaves:
        assert bool(jnp.isfinite(g).all()), f"{arch}: non-finite grad"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_remat_matches_no_remat(arch):
    cfg = reduced_f32(arch)
    params = init_params(cfg, jax.random.key(1))
    batch = batch_for(cfg)
    l1, _ = lm_loss(params, cfg, batch, remat=False)
    l2, _ = lm_loss(params, cfg, batch, remat=True)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


@pytest.mark.parametrize("arch", ["tinyllama_1_1b", "mamba2_130m",
                                  "recurrentgemma_9b", "deepseek_v2_236b",
                                  "phi35_moe_42b"])
def test_decode_matches_full_forward(arch):
    """Step-by-step decode through the caches must reproduce the full
    causal forward — validates KV caches, ring buffers, recurrent states,
    the MLA absorbed path, and per-token MoE routing.

    MoE capacity_factor is raised so no tokens are dropped: capacity
    dropping is train-batch-size dependent (correct but not decode-
    comparable); drop behavior is asserted separately below."""
    cfg = dataclasses.replace(reduced_f32(arch), capacity_factor=8.0)
    params = init_params(cfg, jax.random.key(2))
    n = 12
    toks = jax.random.randint(jax.random.key(3), (B, n), 0, cfg.vocab_size)
    full_logits, _ = forward(params, cfg, {"tokens": toks})

    caches = init_caches(cfg, B, max_len=n)
    outs = []
    for t in range(n):
        logits, caches = decode_step(
            params, cfg, toks[:, t : t + 1], caches, jnp.int32(t))
        outs.append(logits[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits), rtol=2e-4, atol=2e-4)


def test_windowed_chunked_attention_matches_dense():
    """The O(S·2w) chunked band attention must equal the dense masked
    implementation (recurrentgemma's sub-quadratic path)."""
    cfg = dataclasses.replace(reduced_f32("recurrentgemma_9b"), window=16)
    params = init_params(cfg, jax.random.key(4))
    toks = jax.random.randint(jax.random.key(5), (B, 64), 0, cfg.vocab_size)
    chunked, _ = forward(params, cfg, {"tokens": toks})  # 64 % 16 == 0 → chunked
    cfg_dense = dataclasses.replace(cfg, window=0)
    # emulate dense sliding window by comparing against explicit windowed mask
    # path: S == window → dense branch
    cfg_dense2 = dataclasses.replace(cfg, window=64)
    # instead: directly test attention module
    from repro.models.attention import gqa_apply, gqa_init
    p = gqa_init(jax.random.key(6), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(7), (B, 64, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(64)[None], (B, 64))
    out_chunked, _ = gqa_apply(p, x, cfg=cfg, positions=pos, causal=True,
                               window=16)
    # dense path: pad sequence length so S % window != 0 → dense masked
    out_dense, _ = gqa_apply(p, x, cfg=dataclasses.replace(cfg, window=16),
                             positions=pos, causal=True, window=17)
    # window 17 isn't the same math — use the internal dense route instead:
    from repro.models import attention as att
    import math
    # call dense branch by using S % window != 0 via window=16 but S=64? S%16==0.
    # Temporarily force dense: window > S disables chunking
    out_dense2, _ = gqa_apply(p, x[:, :63], cfg=cfg,
                              positions=pos[:, :63], causal=True, window=16)
    # compare chunked vs dense on the overlapping prefix
    np.testing.assert_allclose(np.asarray(out_chunked[:, :63]),
                               np.asarray(out_dense2), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_instantiates_abstractly(arch):
    """FULL configs are exercised via eval_shape only (no allocation)."""
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))
    nbytes = sum(np.prod(s.shape) * s.dtype.itemsize
                 for s in jax.tree.leaves(shapes))
    assert nbytes > 1e8, f"{arch}: implausibly small parameter footprint"


def test_param_counts_match_published():
    """Analytic param counts are within tolerance of the published sizes."""
    expect = {
        "recurrentgemma_9b": (9e9, 0.35),
        "phi35_moe_42b": (42e9, 0.15),
        "deepseek_v2_236b": (236e9, 0.15),
        "tinyllama_1_1b": (1.1e9, 0.15),
        "stablelm_12b": (12.1e9, 0.15),
        "codeqwen15_7b": (7.3e9, 0.15),
        "deepseek_coder_33b": (33e9, 0.15),
        "mamba2_130m": (130e6, 0.35),
        "qwen2_vl_7b": (7.6e9, 0.15),
        "whisper_large_v3": (1.55e9, 0.25),
    }
    for arch, (target, tol) in expect.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol, (
            f"{arch}: {n/1e9:.2f}B vs published {target/1e9:.2f}B")


def test_moe_activated_params():
    cfg = get_config("deepseek_v2_236b")
    active = cfg.active_param_count()
    assert active < 0.2 * cfg.param_count()  # 21B active of 236B


def test_moe_capacity_dropping_is_deterministic():
    """With a tight capacity factor, overloaded experts drop tokens — the
    output changes but stays finite and deterministic."""
    import jax.numpy as jnp
    from repro.models.moe import moe_apply, moe_init
    cfg = dataclasses.replace(reduced_f32("phi35_moe_42b"), capacity_factor=0.5)
    cfg_full = dataclasses.replace(cfg, capacity_factor=8.0)
    p = moe_init(jax.random.key(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y_tight = moe_apply(p, x, cfg)
    y_tight2 = moe_apply(p, x, cfg)
    y_full = moe_apply(p, x, cfg_full)
    assert bool(jnp.isfinite(y_tight).all())
    np.testing.assert_array_equal(np.asarray(y_tight), np.asarray(y_tight2))
    assert not np.allclose(np.asarray(y_tight), np.asarray(y_full))


def test_flash_attention_matches_dense():
    """Online-softmax chunked attention == dense masked attention, for
    causal GQA, non-causal (encoder/cross), and the MLA flash path."""
    import jax.numpy as jnp
    from repro.models import attention as att
    cfg = reduced_f32("tinyllama_1_1b")
    p = jax.random.normal(jax.random.key(0), (2, 256, 4, 2, 32))
    q = p
    k = jax.random.normal(jax.random.key(1), (2, 256, 4, 32))
    v = jax.random.normal(jax.random.key(2), (2, 256, 4, 32))
    for causal in (True, False):
        out_f = att._attend_flash(q, k, v, causal=causal, scale=0.2, k_chunk=64)
        qpos = jnp.arange(256)[:, None]
        kpos = jnp.arange(256)[None, :]
        m = (kpos <= qpos) if causal else jnp.ones((256, 256), bool)
        mask = jnp.broadcast_to(m[None, None, None], (2, 4, 2, 256, 256))
        out_d = att._attend(q, k, v, mask, 0.2)
        np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_d),
                                   rtol=2e-5, atol=2e-5)


def test_mla_flash_matches_dense():
    import dataclasses as dc
    import jax.numpy as jnp
    from repro.models import attention as att
    cfg = reduced_f32("deepseek_v2_236b")
    p = att.mla_init(jax.random.key(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 128, cfg.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(128)[None], (2, 128))
    out_dense, _ = att.mla_apply(p, x, cfg=cfg, positions=pos)
    old = att.FLASH_THRESHOLD
    try:
        att.FLASH_THRESHOLD = 32   # force the flash path
        out_flash, _ = att.mla_apply(p, x, cfg=cfg, positions=pos)
    finally:
        att.FLASH_THRESHOLD = old
    np.testing.assert_allclose(np.asarray(out_flash), np.asarray(out_dense),
                               rtol=2e-4, atol=2e-4)
