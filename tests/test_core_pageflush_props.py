"""Crash-atomicity properties of the page flush protocols (hypothesis).

Invariant (failure atomicity, §3.2): after a crash at ANY point in a flush
protocol with ANY eviction subset, recovery yields for each page EITHER the
previous version or the new version — never a torn mix.

Requires the ``test`` extra; deterministic page-flush tests live in
``test_core_pageflush.py``.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import PMem, PageStore, PageStoreLayout

PAGE = 1024  # 16 lines — small pages keep property tests fast
NPAGES = 4


def make_store(n_mulogs=1, threads=1):
    layout = PageStoreLayout(base=0, page_size=PAGE, npages=NPAGES, nslots=NPAGES + 2)
    pm = PMem(layout.total_bytes + 8 * 4096)
    pm.memset_zero()
    return pm, PageStore(pm, layout, n_mulogs=n_mulogs, threads=threads)


def page_of(b):
    return np.full(PAGE, b, dtype=np.uint8)


@settings(max_examples=100, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(
    use_mulog=st.booleans(),
    dirty=st.lists(st.integers(0, PAGE // 64 - 1), min_size=1, max_size=8, unique=True),
    seed=st.integers(0, 2**31 - 1),
    prob=st.sampled_from([0.0, 0.3, 0.7, 1.0]),
)
def test_crash_during_flush_is_atomic(use_mulog, dirty, seed, prob):
    pm, store = make_store()
    rng0 = np.random.default_rng(7)
    v1 = rng0.integers(0, 255, PAGE, dtype=np.uint8) | 1  # nonzero
    store.flush_cow(0, v1)
    v2 = v1.copy()
    for li in dirty:
        v2[li * 64 : (li + 1) * 64] = rng0.integers(0, 255, 64, dtype=np.uint8)
    if use_mulog:
        store.flush_mulog(0, v2, dirty_lines=sorted(dirty))
    else:
        store.flush_cow(0, v2)
    pm.crash(rng=np.random.default_rng(seed), evict_prob=prob)
    s2 = PageStore.open(pm, store.layout)
    got = np.asarray(s2.read_page(0))
    ok_v1 = (got == v1).all()
    ok_v2 = (got == v2).all()
    assert ok_v1 or ok_v2, "torn page after crash"


@settings(max_examples=50, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 2**31 - 1), prob=st.sampled_from([0.0, 0.5, 1.0]))
def test_completed_flush_survives_crash(seed, prob):
    """A flush whose final barrier returned must be the recovered version."""
    pm, store = make_store()
    store.flush_cow(1, page_of(3))
    store.flush_mulog(1, page_of(4), dirty_lines=list(range(PAGE // 64)))
    pm.crash(rng=np.random.default_rng(seed), evict_prob=prob)
    s2 = PageStore.open(pm, store.layout)
    assert (np.asarray(s2.read_page(1)) == 4).all()
