"""Page-flush tests: barrier counts, pvn recovery, µLog replay, hybrid policy.

The crash-atomicity properties (a page is always *some* complete version)
live in ``test_core_pageflush_props.py`` (skipped without the ``test``
extra)."""

import numpy as np

from repro.core import (
    HybridPolicy,
    PMem,
    PageStore,
    PageStoreLayout,
    recover_page_table,
)

PAGE = 1024  # 16 lines — small pages keep property tests fast
NPAGES = 4


def make_store(n_mulogs=1, threads=1):
    layout = PageStoreLayout(base=0, page_size=PAGE, npages=NPAGES, nslots=NPAGES + 2)
    pm = PMem(layout.total_bytes + 8 * 4096)
    pm.memset_zero()
    return pm, PageStore(pm, layout, n_mulogs=n_mulogs, threads=threads)


def page_of(b):
    return np.full(PAGE, b, dtype=np.uint8)


# ------------------------------------------------------------ barrier counts

def test_cow_pvn_two_barriers():
    pm, store = make_store()
    store.flush_cow(0, page_of(1))
    before = pm.stats.barriers
    store.flush_cow(0, page_of(2))
    assert pm.stats.barriers - before == 2   # §3.2.1: pvn removes barrier #3


def test_cow_invalidate_three_barriers():
    pm, store = make_store()
    store.flush_cow(0, page_of(1))
    before = pm.stats.barriers
    store.flush_cow(0, page_of(2), invalidate_first=True)
    assert pm.stats.barriers - before == 3


def test_mulog_four_barriers():
    pm, store = make_store()
    store.flush_cow(0, page_of(1))
    before = pm.stats.barriers
    store.flush_mulog(0, page_of(2), dirty_lines=[0, 3])
    assert pm.stats.barriers - before == 4   # Listing 1 right column


# ------------------------------------------------------------------ recovery

def test_pvn_picks_latest_version():
    pm, store = make_store()
    for v in range(1, 4):
        store.flush_cow(0, page_of(v))
    table = recover_page_table(pm, store.layout)
    assert table[0][1] == 3
    s2 = PageStore.open(pm, store.layout)
    assert (s2.read_page(0) == 3).all()


def test_mulog_applies_only_dirty_lines():
    pm, store = make_store()
    base = np.arange(PAGE, dtype=np.uint8)
    store.flush_cow(0, base)
    newp = base.copy()
    newp[64:128] = 255          # line 1
    store.flush_mulog(0, newp, dirty_lines=[1])
    s2 = PageStore.open(pm, store.layout)
    np.testing.assert_array_equal(s2.read_page(0), newp)


def test_stale_mulog_not_replayed_after_cow():
    """A valid µlog from version v must NOT clobber a later CoW at v+1."""
    pm, store = make_store()
    store.flush_cow(0, page_of(1))
    store.flush_mulog(0, page_of(2), dirty_lines=list(range(4)))
    # now a full CoW supersedes; the µlog header is still valid on PMem
    store.flush_cow(0, page_of(7))
    s2 = PageStore.open(pm, store.layout)
    assert (s2.read_page(0) == 7).all()


def test_cow_dirty_variant_reads_old_slot():
    pm, store = make_store()
    base = np.arange(PAGE, dtype=np.uint8)
    store.flush_cow(0, base)
    before = pm.stats.device_read_bytes
    newp = base.copy()
    newp[:64] = 9
    store.flush_cow(0, newp, dirty_lines=[0])
    assert pm.stats.device_read_bytes - before == PAGE  # merged old page
    s2 = PageStore.open(pm, store.layout)
    np.testing.assert_array_equal(s2.read_page(0), newp)


# ------------------------------------------------------------------- hybrid

def test_hybrid_policy_crossovers_match_paper():
    """Fig. 5: µLog wins below ≈112 dirty lines at 1 thread, ≈32 at 7
    threads, for 16 KB pages (256 lines)."""
    layout = PageStoreLayout(base=0, page_size=16384, npages=4, nslots=6)
    pol = HybridPolicy(layout)
    x1 = pol.crossover(threads=1)
    x7 = pol.crossover(threads=7)
    assert 96 <= x1 <= 136, f"1-thread crossover {x1} outside paper range"
    assert 24 <= x7 <= 40, f"7-thread crossover {x7} outside paper range"
    assert pol.prefer_mulog(8, 1) and not pol.prefer_mulog(200, 1)


def test_hybrid_flush_dispatches():
    # paper-sized 16 KB pages: µLog wins for few dirty lines, CoW for many.
    # (For tiny pages the 4-vs-2 barrier overhead makes CoW always win —
    # the policy captures that too, see crossover test above.)
    layout = PageStoreLayout(base=0, page_size=16384, npages=2, nslots=4)
    pm = PMem(layout.total_bytes + 16 * 4096)
    pm.memset_zero()
    store = PageStore(pm, layout)
    big = np.full(16384, 1, dtype=np.uint8)
    store.flush(0, big)                                       # first: CoW
    assert store.flush(0, big, dirty_lines=[0]) == "mulog"
    assert store.flush(0, big, dirty_lines=list(range(256))) == "cow"
