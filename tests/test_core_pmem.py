"""Unit tests for the PMem functional model (cache/WC semantics, crash)."""

import numpy as np
import pytest

from repro.core import FlushKind, PMem


def test_store_visible_but_not_durable():
    pm = PMem(4096)
    pm.store(0, b"hello")
    assert bytes(pm.load(0, 5)) == b"hello"
    assert bytes(pm.durable_view()[:5]) == b"\x00" * 5


def test_persist_makes_durable():
    pm = PMem(4096)
    pm.store(128, b"abc")
    pm.persist(128, 3)
    assert bytes(pm.durable_view()[128:131]) == b"abc"


def test_streaming_store_durable_only_after_sfence():
    pm = PMem(4096)
    pm.store(0, b"xyz", streaming=True)
    assert bytes(pm.durable_view()[:3]) == b"\x00" * 3
    pm.sfence()
    assert bytes(pm.durable_view()[:3]) == b"xyz"


def test_flush_stages_data_at_flush_time():
    """A store after flush but before sfence is NOT covered (§3.1)."""
    pm = PMem(4096)
    pm.store(0, b"A")
    pm.flush(0, 1)
    pm.store(0, b"B")        # dirty again, not staged
    pm.sfence()
    assert bytes(pm.durable_view()[:1]) == b"A"
    assert bytes(pm.load(0, 1)) == b"B"  # program order still sees B


def test_crash_drops_unflushed_lines():
    pm = PMem(4096)
    pm.store(0, b"keep")
    pm.persist(0, 4)
    pm.store(64, b"lost")
    img = pm.crash(evict=lambda li: False)
    assert bytes(img.durable[:4]) == b"keep"
    assert bytes(img.durable[64:68]) == b"\x00" * 4
    assert 1 in img.dropped_lines


def test_crash_may_evict_unflushed_lines():
    """Spontaneous eviction is legal: an unflushed store MAY survive."""
    pm = PMem(4096)
    pm.store(64, b"evicted")
    img = pm.crash(evict=lambda li: True)
    assert bytes(img.durable[64:71]) == b"evicted"


def test_barrier_counting():
    pm = PMem(4096)
    pm.sfence()                       # nothing pending: not a barrier
    assert pm.stats.barriers == 0
    pm.store(0, b"x")
    pm.persist(0, 1)
    assert pm.stats.barriers == 1
    pm.store(0, b"y", streaming=True)
    pm.sfence()
    assert pm.stats.barriers == 2


def test_write_combining_block_accounting():
    pm = PMem(4096)
    # 4 lines of one 256B block committed together -> 1 block write
    pm.store(0, bytes(256), streaming=True)
    pm.sfence()
    assert pm.stats.blocks_written == 1
    assert pm.stats.partial_block_writes == 0
    # a single line commits as a partial block write
    pm.store(1024, bytes(64), streaming=True)
    pm.sfence()
    assert pm.stats.blocks_written == 2
    assert pm.stats.partial_block_writes == 1


def test_same_line_flush_detection():
    pm = PMem(4096)
    for _ in range(4):
        pm.store(0, b"z")
        pm.persist(0, 1)
    assert pm.stats.same_line_flushes == 3


def test_file_backed_region(tmp_path):
    p = str(tmp_path / "region.pmem")
    pm = PMem(4096, path=p)
    pm.store(10, b"disk", streaming=True)
    pm.sfence()
    pm.fsync()
    pm2 = PMem(4096, path=p)
    assert bytes(pm2.load(10, 4)) == b"disk"


def test_bounds_checking():
    pm = PMem(128)
    with pytest.raises(ValueError):
        pm.store(120, b"123456789")
    with pytest.raises(ValueError):
        pm.load(-1, 4)
