"""End-to-end engine tests: the PersistentKV (buffer pool + WAL + hybrid
page flush) must never lose a committed put, for every logging technique.

The arbitrary-crash-point/eviction-subset property lives in
``test_core_recovery_props.py`` (skipped without the ``test`` extra)."""

import numpy as np
import pytest

from repro.core import KVConfig, PMem, PersistentKV


def make_kv(technique="zero", **kw):
    kw.setdefault("log_capacity", 1 << 15)
    cfg = KVConfig(npages=4, page_size=1024, value_size=64,
                   technique=technique, **kw)
    pm = PMem(PersistentKV.region_bytes(cfg))
    pm.memset_zero()
    return pm, PersistentKV(pm, cfg), cfg


def val(i: int) -> bytes:
    return bytes([(i * 37 + 11) % 255 + 1]) * 64


@pytest.mark.parametrize("technique", ["classic", "header", "zero"])
def test_put_get_roundtrip(technique):
    pm, kv, cfg = make_kv(technique)
    for k in range(cfg.nkeys):
        kv.put(k, val(k))
    for k in range(cfg.nkeys):
        assert kv.get(k) == val(k)


@pytest.mark.parametrize("technique", ["classic", "header", "zero"])
def test_recovery_without_checkpoint(technique):
    pm, kv, cfg = make_kv(technique)
    for k in range(10):
        kv.put(k, val(k))
    pm.crash(evict=lambda li: False)       # drop ALL in-flight lines
    kv2 = PersistentKV.open(pm, cfg)
    for k in range(10):
        assert kv2.get(k) == val(k), f"lost committed put {k}"


@pytest.mark.parametrize("technique", ["classic", "header", "zero"])
def test_recovery_with_checkpoint(technique):
    pm, kv, cfg = make_kv(technique)
    for k in range(10):
        kv.put(k, val(k))
    kv.checkpoint()
    for k in range(5):
        kv.put(k, val(k + 100))           # overwrite after checkpoint
    pm.crash(evict=lambda li: False)
    kv2 = PersistentKV.open(pm, cfg)
    for k in range(5):
        assert kv2.get(k) == val(k + 100)
    for k in range(5, 10):
        assert kv2.get(k) == val(k)
    assert kv2.checkpoint_lsn == 10


def test_wal_generation_lsns_continue():
    pm, kv, cfg = make_kv("zero")
    lsns = [kv.put(k, val(k)) for k in range(5)]
    kv.checkpoint()
    more = [kv.put(k, val(k)) for k in range(3)]
    assert lsns == [1, 2, 3, 4, 5]
    assert more == [6, 7, 8]


def test_auto_checkpoint_on_log_full():
    pm, kv, cfg = make_kv("zero", log_capacity=2048)
    for k in range(60):                    # overflows the 2 KB WAL
        kv.put(k % cfg.nkeys, val(k))
    assert kv.get(59 % cfg.nkeys) == val(59)
