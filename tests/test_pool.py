"""Pool/handle API tests: durable directory round-trip, crash-safe region
allocation, LogHandle recovery parity with the legacy classes, and the
PersistentKV-on-pool YCSB smoke.

The hypothesis eviction-subset property for mid-allocation crashes lives in
``test_pool_props.py`` (skipped without the ``test`` extra).
"""

import numpy as np
import pytest

from repro.core import KVConfig, LOG_TECHNIQUES, LogConfig, PMem, PersistentKV
from repro.core.directory import KIND_LOG, KIND_RAW, RegionDirectory
from repro.pool import Pool

SIZE = 1 << 20


# ------------------------------------------------------------ directory

def test_directory_roundtrip_in_memory():
    pool = Pool.create(None, SIZE)
    log = pool.log("wal", capacity=1 << 16, technique="zero")
    pages = pool.pages("heap", npages=4, page_size=1024)
    raw = pool.raw("root", nbytes=128)
    log.append(b"alpha")
    log.append(b"beta")
    pages.flush(1, np.full(1024, 7, dtype=np.uint8))
    raw.store(0, b"rootrec", streaming=True)
    raw.persist(0, 7)

    before = {n: (r.kind, r.base, r.length, r.meta)
              for n, r in pool.regions().items()}
    pool.pmem.crash(evict=lambda li: False)   # drop every in-flight line

    pool2 = Pool.open(pmem=pool.pmem)
    after = {n: (r.kind, r.base, r.length, r.meta)
             for n, r in pool2.regions().items()}
    assert after == before
    log2 = pool2.log("wal")
    assert log2.recovered.entries == [b"alpha", b"beta"]
    assert (pool2.pages("heap").read_page(1) == 7).all()
    assert bytes(pool2.raw("root").load(0, 7)) == b"rootrec"


def test_directory_roundtrip_file_backed(tmp_path):
    path = str(tmp_path / "pool.pmem")
    pool = Pool.create(path, SIZE)
    log = pool.log("wal", capacity=1 << 14, technique="classic",
                   cfg=LogConfig(pad_to_line=True))
    log.append(b"persisted")
    pool.fsync()
    regions = {n: (r.base, r.length) for n, r in pool.regions().items()}

    pool2 = Pool.open(path)                    # geometry from the superblock
    assert pool2.geometry == pool.geometry
    assert {n: (r.base, r.length) for n, r in pool2.regions().items()} == regions
    log2 = pool2.log("wal")                    # technique from the directory
    assert log2.technique == "classic"
    assert log2.recovered.entries == [b"persisted"]
    log2.append(b"more")
    assert log2.recover().entries == [b"persisted", b"more"]


def test_open_unformatted_region_fails(tmp_path):
    pm = PMem(SIZE)
    with pytest.raises(ValueError):
        Pool.open(pmem=pm)
    with pytest.raises(FileNotFoundError):
        Pool.open("/nonexistent/pool.pmem")
    # an existing file with a bad superblock is corruption, NOT absence —
    # a try/except FileNotFoundError → create() fallback must not fire
    bad = str(tmp_path / "bad.pmem")
    open(bad, "wb").write(b"\x12" * 4096)
    with pytest.raises(ValueError, match="torn superblock"):
        Pool.open(bad)


def test_attach_refuses_legacy_durable_data():
    """Formatting over a pre-pool durable image would zero its head —
    attach must refuse instead (the shim path is for zeroed regions)."""
    pm = PMem(SIZE)
    pm.store(0, b"legacy log entry data", streaming=True)
    pm.sfence()
    with pytest.raises(ValueError, match="refusing to format"):
        Pool.attach(pm)


def test_legacy_wal_fresh_constructor_resets_existing_region():
    """Legacy recover=False on an existing region means 'fresh WAL', not
    'silently resume the previous generation'."""
    from repro.persistence.wal import StepRecord, TrainWAL

    pm = PMem(TrainWAL.capacity_for(100))
    pm.memset_zero()
    wal = TrainWAL(pm, 0, pm.size)
    wal.commit_step(StepRecord(1, 0, (0, 0), 0.5, 0.1, 1.0))
    fresh = TrainWAL(pm, 0, pm.size)             # recover=False
    assert fresh.records == [] and fresh.last is None
    recovered = TrainWAL(pm, 0, pm.size, recover=True)
    assert recovered.records == []               # old generation gone


def test_open_never_destroys_data(tmp_path):
    """Read paths must refuse, never truncate or reformat."""
    path = str(tmp_path / "pool.pmem")
    pool = Pool.create(path, SIZE)
    pool.log("wal", capacity=4096).append(b"precious")
    pool.fsync()

    # truncated file: refuse to open (PMem would otherwise recreate it)
    with open(path, "r+b") as f:
        f.truncate(SIZE // 2)
    with pytest.raises(ValueError, match="refusing"):
        Pool.open(path)
    assert open(path, "rb").read(8) != b"\x00" * 8   # bytes untouched

    # a non-pool file is someone's data: open_or_create must not format it
    other = str(tmp_path / "notapool.bin")
    open(other, "wb").write(b"user data, not a pool")
    with pytest.raises(ValueError, match="refusing"):
        Pool.open_or_create(other, SIZE)
    assert open(other, "rb").read() == b"user data, not a pool"


def test_open_rejects_capacity_larger_than_region():
    pool = Pool.create(None, SIZE)
    pool.log("wal", capacity=4096)
    with pytest.raises(ValueError, match="cannot grow"):
        pool.log("wal", capacity=1 << 16)
    # asking for less (or nothing) is fine
    assert pool.log("wal", capacity=1024).capacity == 4096


def test_wal_open_uses_stored_technique():
    """Reopening a classic/header WAL without naming the technique must
    work — the directory record decides (regression: the open path used
    to force the zero default and raise)."""
    from repro.persistence.wal import StepRecord

    pool = Pool.create(None, SIZE)
    wal = pool.wal("steps", capacity_steps=50, technique="classic")
    wal.commit_step(StepRecord(1, 0, (0, 0), 0.5, 0.1, 1.0))
    pool.pmem.crash(evict=lambda li: False)
    wal2 = Pool.open(pmem=pool.pmem).wal("steps")     # no technique arg
    assert wal2.technique == "classic"
    assert wal2.last.step == 1
    # a bigger capacity request on reopen is a config error, not a silent
    # undersized region
    with pytest.raises(ValueError, match="cannot grow"):
        Pool.open(pmem=pool.pmem).wal("steps", capacity_steps=10_000)


def test_allocation_errors():
    pool = Pool.create(None, 1 << 16, max_regions=2)
    pool.raw("a", nbytes=256)
    with pytest.raises(ValueError):
        pool.raw("a", nbytes=512)            # wrong: grows an existing region
    with pytest.raises(ValueError):
        pool.directory.allocate("a", KIND_RAW, 256)   # duplicate name
    with pytest.raises(RuntimeError):
        pool.raw("too-big", nbytes=1 << 20)  # exceeds the pool
    pool.raw("b", nbytes=256)
    with pytest.raises(RuntimeError):
        pool.raw("c", nbytes=256)            # directory full (max_regions=2)


def test_handle_conflicts_with_directory_record():
    pool = Pool.create(None, SIZE)
    pool.log("l", capacity=4096, technique="zero")
    pool.pages("p", npages=2, page_size=1024)
    with pytest.raises(ValueError):
        pool.log("l", technique="classic")
    with pytest.raises(TypeError):
        pool.pages("l")                      # kind mismatch
    with pytest.raises(ValueError):
        pool.pages("p", npages=3)


# ------------------------------------------------- crash-safe allocation

def _committed_log_image(pool):
    rec = pool.regions()["a"]
    return pool.pmem.durable_view()[rec.base : rec.base + rec.length].copy()


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("prob", [0.0, 0.5, 1.0])
def test_crash_mid_allocation_preserves_existing(seed, prob):
    """A crash between *place* and *commit* of a new region leaves every
    previously committed region bit-exact and the new name absent."""
    pool = Pool.create(None, SIZE)
    log = pool.log("a", capacity=1 << 14, technique="zero")
    for i in range(8):
        log.append(bytes([i + 1]) * 33)
    img_a = _committed_log_image(pool)

    d = pool.directory
    rec, slot = d._place("b", KIND_LOG, 1 << 14, (2, 1, 1, 0))
    d._initialize(rec)                        # zeroing done, entry NOT committed
    pool.pmem.crash(rng=np.random.default_rng(seed), evict_prob=prob)

    pool2 = Pool.open(pmem=pool.pmem)
    assert "b" not in pool2.regions()
    assert np.array_equal(_committed_log_image(pool2), img_a)
    rec2 = pool2.log("a").recover()
    assert rec2.entries == [bytes([i + 1]) * 33 for i in range(8)]
    # the claimed space is reusable after the crash
    log_b = pool2.log("b", capacity=1 << 14)
    log_b.append(b"fresh")
    assert log_b.recover().entries == [b"fresh"]


@pytest.mark.parametrize("seed", [0, 5, 9])
def test_crash_during_entry_commit_is_atomic(seed):
    """Crash with the entry line stored but not fenced: spontaneous
    eviction may or may not make it durable — either way region "a" is
    intact and "b" is either absent or a valid empty region."""
    pool = Pool.create(None, SIZE)
    log = pool.log("a", capacity=1 << 14, technique="zero")
    for i in range(5):
        log.append(bytes([i + 1]) * 20)
    img_a = _committed_log_image(pool)

    d = pool.directory
    rec, slot = d._place("b", KIND_LOG, 1 << 14, (2, 1, 1, 0))
    d._initialize(rec)
    # store the entry line but crash before the fence of _commit()
    import repro.core.directory as directory_mod
    entry = directory_mod._ENTRY.pack(b"b", rec.kind, rec.generation,
                                      rec.base, rec.length, *rec.meta)
    pool.pmem.store(d._entry_off(slot), entry, streaming=True)
    pool.pmem.crash(rng=np.random.default_rng(seed), evict_prob=0.5)

    pool2 = Pool.open(pmem=pool.pmem)
    assert np.array_equal(_committed_log_image(pool2), img_a)
    if "b" in pool2.regions():
        got = pool2.regions()["b"]
        assert (got.base, got.length) == (rec.base, rec.length)
        assert pool2.log("b").recovered.entries == []   # valid, empty


# --------------------------------------------- LogHandle recovery parity

@pytest.mark.parametrize("technique", ["classic", "header", "zero"])
@pytest.mark.parametrize("padded", [True, False])
def test_log_handle_parity_with_legacy_classes(technique, padded):
    """The unified LogHandle must behave exactly like the legacy class it
    wraps: same barrier count per append and identical recovery."""
    payloads = [bytes([i + 1]) * (5 + 11 * i) for i in range(9)]
    cfg = LogConfig(pad_to_line=padded)

    pool = Pool.create(None, SIZE)
    h = pool.log("log", capacity=1 << 15, technique=technique, cfg=cfg)
    before = pool.stats.barriers
    for p in payloads:
        h.append(p)
    cls = LOG_TECHNIQUES[technique]
    assert pool.stats.barriers - before == len(payloads) * cls.BARRIERS_PER_APPEND
    assert h.barriers_per_append == cls.BARRIERS_PER_APPEND

    pool.pmem.crash(evict=lambda li: False)
    h2 = Pool.open(pmem=pool.pmem).log("log")
    assert h2.recovered.entries == payloads
    assert h2.recovered.lsns == list(range(1, len(payloads) + 1))

    # cross-check: the legacy classmethod recovery at the region base sees
    # exactly what the handle reports
    rec = cls.recover(pool.pmem, h2.base, h2.length, h2.cfg)
    assert rec.entries == h2.recovered.entries
    assert rec.tail == h2.tail      # writer resumed exactly at the durable tail

    # and appends continue with correct LSNs after recovery
    h2.append(b"after-crash")
    assert h2.recover().entries == payloads + [b"after-crash"]


def test_log_handle_reset_starts_new_generation():
    pool = Pool.create(None, SIZE)
    h = pool.log("log", capacity=1 << 14, technique="zero")
    h.append(b"old")
    h.reset()
    assert h.next_lsn == 1
    h.append(b"new")
    assert h.recover().entries == [b"new"]


def test_handle_stats_delta_view():
    pool = Pool.create(None, SIZE)
    h = pool.log("log", capacity=1 << 14, technique="zero")
    h.reset_stats()
    h.append(b"x" * 40)
    s = h.stats()
    assert s.barriers == 1
    assert s.nt_store_bytes > 0


# ------------------------------------------------------ KV-on-pool smoke

def test_kv_on_pool_ycsb_smoke():
    """YCSB-style 100%-write workload through pool.kv: puts survive auto
    checkpoints and an arbitrary-eviction crash."""
    cfg = KVConfig(npages=4, page_size=1024, value_size=64,
                   log_capacity=1 << 13, technique="zero")
    pool = Pool.create(None, PersistentKV.region_bytes(cfg))
    kv = pool.kv("store", cfg)
    rng = np.random.default_rng(42)
    expected = {}
    for i in range(300):                      # overflows the 8 KiB WAL
        k = int(rng.integers(0, cfg.nkeys))
        v = bytes([(i + j) % 256 for j in range(64)])
        kv.put(k, v)
        expected[k] = v
    pool.pmem.crash(rng=np.random.default_rng(0), evict_prob=0.5)

    kv2 = Pool.open(pmem=pool.pmem).kv("store", cfg)
    for k, v in expected.items():
        assert kv2.get(k) == v

    # no caller-visible raw offsets: all three engine regions are named
    names = set(Pool.open(pmem=pool.pmem).regions())
    assert {"store.root", "store.pages", "store.wal"} <= names


def test_kv_legacy_shim_still_works():
    """The old (pmem, cfg) constructor is a shim over Pool.attach."""
    cfg = KVConfig(npages=4, page_size=1024, value_size=64,
                   log_capacity=1 << 13)
    pm = PMem(PersistentKV.region_bytes(cfg))
    pm.memset_zero()
    kv = PersistentKV(pm, cfg)
    kv.put(3, bytes(range(64)))
    pm.crash(evict=lambda li: False)
    kv2 = PersistentKV.open(pm, cfg)
    assert kv2.get(3) == bytes(range(64))


# --------------------------------------------------------- TrainWAL/pool

def test_train_wal_on_pool_roundtrip():
    from repro.persistence.wal import StepRecord

    pool = Pool.create(None, SIZE)
    wal = pool.wal("steps", capacity_steps=100)
    for s in range(6):
        wal.commit_step(StepRecord(s + 1, s * 64, (s, s + 1), float(s), 0.1, 1.0))
    pool.pmem.crash(evict=lambda li: False)
    wal2 = Pool.open(pmem=pool.pmem).wal("steps")
    assert wal2.last.step == 6
    assert wal2.last.rng_key == (5, 6)
    assert wal2.barriers_per_step() == 1
