"""Crash-during-spill properties (hypothesis): recovery never reads a
partially spilled object.

The spill protocol orders every transition down-tier first (SSD bytes →
device flush → PMem map record → source invalidation / watermark), so
whatever instant a crash lands on:

* a WAL generation recovers wholly from PMem (not yet retired) or
  wholly from SSD (retired) — **never both**, never a torn mix;
* every page recovers its newest flushed content from exactly one tier
  (cross-tier max-pvn rule).

Requires the ``test`` extra; deterministic tier tests live in
``test_tier.py``.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.ssd import SSD
from repro.io.flushq import FlushQueue
from repro.io.multilog import MultiLog
from repro.pool import Pool
from repro.tier import SpillScheduler


class SimCrash(BaseException):
    """Raised by the failpoint to cut the spill protocol mid-flight.
    Derived from BaseException so no protocol-level handler can eat it."""


class CrashAt:
    """Failpoint callable: crash at the Nth protocol point reached."""

    def __init__(self, n: int) -> None:
        self.n = n
        self.seen = 0

    def __call__(self, point: str) -> None:
        self.seen += 1
        if self.seen == self.n:
            raise SimCrash(point)


@settings(max_examples=50, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    lanes=st.integers(1, 4),
    gen_sets=st.integers(2, 3),
    group_commit=st.integers(1, 5),
    per_gen=st.lists(st.integers(1, 12), min_size=1, max_size=5),
    crash_step=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
    pmem_prob=st.sampled_from([0.0, 0.5, 1.0]),
    ssd_keep=st.sampled_from([0.0, 0.5, 1.0]),
)
def test_generation_never_read_partially_spilled(
        lanes, gen_sets, group_commit, per_gen, crash_step, seed,
        pmem_prob, ssd_keep):
    """Roll several WAL generations, crash at an arbitrary point inside
    the spill drain (plus arbitrary device-level durability subsets), and
    assert every generation recovers complete from exactly the tier the
    durable watermark names."""
    pool = Pool.create(None, 1 << 21)
    ssd = SSD(1 << 22)
    pool.attach_ssd(ssd)
    sp = SpillScheduler(pool, name="sp", map_capacity=1 << 13)
    ml = MultiLog(pool, "wal", lanes=lanes, capacity=1 << 13,
                  gen_sets=gen_sets, group_commit=group_commit)
    ml.attach_spill(sp)

    contents = {}          # gen -> full payload list
    gen = 1
    committed_live = 0
    crashed = False
    sp.failpoints = CrashAt(crash_step)
    try:
        for count in per_gen:
            contents[gen] = [b"g%d-e%d" % (gen, i) for i in range(count)]
            for p in contents[gen]:
                ml.append(p)
            ml.roll()           # seals gen; may force a drain (failpoints!)
            gen += 1
        contents[gen] = [b"g%d-live" % gen]
        ml.append(contents[gen][0])
        ml.commit()
        committed_live = 1
        sp.drain()              # retire whatever is still queued
    except SimCrash:
        crashed = True

    # power failure: arbitrary surviving subsets on both devices
    rng = np.random.default_rng(seed)
    pool.pmem.crash(rng=rng, evict_prob=pmem_prob)
    ssd.crash(rng=rng, keep_prob=ssd_keep)

    pool2 = Pool.open(pmem=pool.pmem)
    pool2.attach_ssd(ssd)
    sp2 = SpillScheduler(pool2, name="sp")
    ml2 = MultiLog(pool2, "wal")
    ml2.attach_spill(sp2)

    assert ml2.retired_upto < ml2.current_gen
    resident_window = range(ml2.retired_upto + 1, ml2.current_gen + 1)
    for g in range(1, ml2.current_gen + 1):
        if g <= ml2.retired_upto:
            # the watermark says SSD: the copy there must be COMPLETE —
            # the watermark only advances after the device flush and the
            # checksummed map record
            src, entries = ml2.read_generation(g)
            assert src == "ssd"
            assert [bytes(e) for e in entries] == contents[g], g
        elif g < ml2.current_gen:
            # sealed but unretired: wholly from PMem, bit-exact (the SSD
            # may hold a torn partial copy — it must never be consulted)
            assert g in resident_window
            src, entries = ml2.read_generation(g)
            assert src == "pmem"
            assert [bytes(e) for e in entries] == contents[g], g
        else:
            # the live generation: a durable prefix covering every commit
            src, entries = ml2.read_generation(g)
            assert src == "pmem"
            got = [bytes(e) for e in entries]
            assert got == contents.get(g, [])[: len(got)]
            if not crashed:
                assert len(got) >= committed_live

    # …and CONTINUE: roll through the whole ring after recovery. No
    # generation sealed before the crash may be lost to ring reuse (the
    # orphaned-generation regression: sealed-but-unretired generations
    # must be re-enqueued on attach_spill, not silently discarded).
    resume = ml2.current_gen
    for _ in range(ml2.gen_sets):
        ml2.append(b"post")
        ml2.roll()
    sp2.drain()
    for g in range(1, resume):
        src, entries = ml2.read_generation(g)
        assert [bytes(e) for e in entries] == contents[g], (g, src)


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    nslots=st.integers(3, 6),
    writes=st.lists(st.tuples(st.integers(0, 15), st.integers(0, 255)),
                    min_size=1, max_size=40),
    crash_step=st.integers(1, 60),
    seed=st.integers(0, 2**31 - 1),
    pmem_prob=st.sampled_from([0.0, 0.5, 1.0]),
    ssd_keep=st.sampled_from([0.0, 0.5, 1.0]),
)
def test_page_spill_crash_never_loses_flushed_content(
        nslots, writes, crash_step, seed, pmem_prob, ssd_keep):
    """Flush epochs over an overcommitted store with a crash at an
    arbitrary point inside the eviction protocol: every flushed page
    recovers, from exactly one tier, either its last completed epoch's
    image or the in-flight epoch's (a page flush is failure-atomic) —
    never a torn mix, never anything older."""
    pool = Pool.create(None, 1 << 21)
    ssd = SSD(1 << 22)
    pool.attach_ssd(ssd)
    sp = SpillScheduler(pool, name="sp", map_capacity=1 << 13)
    pages = pool.pages("heap", npages=16, page_size=512, nslots=nslots)
    sp.attach_pages(pages)
    fq = FlushQueue(pages, lanes=2, spill=sp)

    flushed = {}        # pid -> content of the last DRAINED epoch
    pending = {}        # pid -> content enqueued for the in-flight epoch
    sp.failpoints = CrashAt(crash_step)
    try:
        for i, (pid, fill) in enumerate(writes):
            img = np.full(512, fill, dtype=np.uint8)
            fq.enqueue(pid, img)
            pending[pid] = img
            if (i + 1) % 8 == 0:
                fq.flush_epoch()
                flushed.update(pending)
                pending.clear()
        fq.flush_epoch()
        flushed.update(pending)
        pending.clear()
    except SimCrash:
        pass

    rng = np.random.default_rng(seed)
    pool.pmem.crash(rng=rng, evict_prob=pmem_prob)
    ssd.crash(rng=rng, keep_prob=ssd_keep)

    pool2 = Pool.open(pmem=pool.pmem)
    pool2.attach_ssd(ssd)
    sp2 = SpillScheduler(pool2, name="sp")
    pages2 = pool2.pages("heap")
    sp2.attach_pages(pages2)
    for pid, img in flushed.items():
        got = bytes(sp2.read_page(pages2.store, pid, promote=False))
        acceptable = {bytes(img)}
        if pid in pending:   # the crashed epoch may have flushed it already
            acceptable.add(bytes(pending[pid]))
        assert got in acceptable, pid
