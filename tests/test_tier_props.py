"""Crash-during-spill properties (hypothesis): recovery never reads a
partially spilled object.

The spill protocol orders every transition down-tier first (SSD bytes →
device flush → PMem map record → source invalidation / watermark), so
whatever instant a crash lands on:

* a WAL generation recovers wholly from PMem (not yet retired) or
  wholly from SSD (retired) — **never both**, never a torn mix;
* every page recovers its newest flushed content from exactly one tier
  (cross-tier max-pvn rule).

The property bodies (and the ``SimCrash``/``CrashAt`` failpoint
helpers) live in ``tests/corpus_runner.py``, shared with the
deterministic regression corpus in ``test_crash_corpus.py``. Requires
the ``test`` extra; deterministic tier tests live in ``test_tier.py``.
"""

import pytest

pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from corpus_runner import run_generation_spill_crash, run_page_spill_crash


@settings(max_examples=50, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    lanes=st.integers(1, 4),
    gen_sets=st.integers(2, 3),
    group_commit=st.integers(1, 5),
    per_gen=st.lists(st.integers(1, 12), min_size=1, max_size=5),
    crash_step=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
    pmem_prob=st.sampled_from([0.0, 0.5, 1.0]),
    ssd_keep=st.sampled_from([0.0, 0.5, 1.0]),
)
def test_generation_never_read_partially_spilled(
        lanes, gen_sets, group_commit, per_gen, crash_step, seed,
        pmem_prob, ssd_keep):
    """Roll several WAL generations, crash at an arbitrary point inside
    the spill drain (plus arbitrary device-level durability subsets), and
    assert every generation recovers complete from exactly the tier the
    durable watermark names."""
    run_generation_spill_crash(lanes, gen_sets, group_commit, per_gen,
                               crash_step, seed, pmem_prob, ssd_keep)


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    nslots=st.integers(3, 6),
    writes=st.lists(st.tuples(st.integers(0, 15), st.integers(0, 255)),
                    min_size=1, max_size=40),
    crash_step=st.integers(1, 60),
    seed=st.integers(0, 2**31 - 1),
    pmem_prob=st.sampled_from([0.0, 0.5, 1.0]),
    ssd_keep=st.sampled_from([0.0, 0.5, 1.0]),
)
def test_page_spill_crash_never_loses_flushed_content(
        nslots, writes, crash_step, seed, pmem_prob, ssd_keep):
    """Flush epochs over an overcommitted store with a crash at an
    arbitrary point inside the eviction protocol: every flushed page
    recovers, from exactly one tier, either its last completed epoch's
    image or the in-flight epoch's (a page flush is failure-atomic) —
    never a torn mix, never anything older."""
    run_page_spill_crash(nslots, writes, crash_step, seed, pmem_prob,
                         ssd_keep)
