"""Hypothesis shape/dtype sweeps for the Pallas kernels (interpret mode).

Requires the ``test`` extra (``pip install -e .[test]``); skipped cleanly
when hypothesis is not installed — the deterministic kernel tests live in
``test_kernels.py``.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

import jax.numpy as jnp
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.kernels.common import LANES, TILE_BLOCKS, as_blocks, block_rows, from_blocks
from repro.kernels.delta_pack.kernel import delta_apply_blocked, delta_pack_blocked
from repro.kernels.delta_pack.ref import delta_apply_blocked_ref, delta_pack_blocked_ref
from repro.kernels.dirty_diff.kernel import dirty_diff_blocked
from repro.kernels.dirty_diff.ref import dirty_diff_blocked_ref
from repro.kernels.popcnt_checksum.kernel import popcnt_blocked
from repro.kernels.popcnt_checksum.ref import popcnt_blocked_ref


def rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    if dtype == jnp.uint32:
        return jnp.asarray((x * 1e6).view(np.uint32).reshape(shape))
    if dtype == jnp.int8:
        return jnp.asarray((x * 10).astype(np.int8))
    return jnp.asarray(x).astype(dtype)


# ------------------------------------------------------------- common layout

@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 5000), dt=st.sampled_from(["float32", "bfloat16", "int8"]))
def test_as_blocks_roundtrip(n, dt):
    dtype = jnp.dtype(dt)
    x = jnp.arange(n).astype(dtype)
    blocked, orig = as_blocks(x)
    assert blocked.shape[2] == LANES
    assert blocked.shape[1] == block_rows(dtype)
    back = from_blocks(blocked, orig)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


# --------------------------------------------------------------- dirty_diff

@settings(max_examples=20, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(
    ntiles=st.integers(1, 4),
    rows=st.sampled_from([8, 16]),
    seed=st.integers(0, 999),
    ndirty=st.integers(0, 8),
)
def test_dirty_diff_kernel_matches_ref(ntiles, rows, seed, ndirty):
    rng = np.random.default_rng(seed)
    nblocks = ntiles * TILE_BLOCKS
    snap = rand(rng, (nblocks, rows, LANES), jnp.float32)
    cur = np.asarray(snap).copy()
    dirty_idx = rng.choice(nblocks, size=min(ndirty, nblocks), replace=False)
    for b in dirty_idx:
        cur[b, rng.integers(rows), rng.integers(LANES)] += 1.0
    cur = jnp.asarray(cur)
    out_k = dirty_diff_blocked(cur, snap, interpret=True)
    out_r = dirty_diff_blocked_ref(cur, snap)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
    assert set(np.flatnonzero(np.asarray(out_k))) == set(dirty_idx.tolist())


# ----------------------------------------------------------- popcnt_checksum

@settings(max_examples=20, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(ntiles=st.integers(1, 4), seed=st.integers(0, 999))
def test_popcnt_kernel_matches_ref_and_numpy(ntiles, seed):
    rng = np.random.default_rng(seed)
    nblocks = ntiles * TILE_BLOCKS
    x_np = rng.integers(0, 2**32, size=(nblocks, 8, LANES), dtype=np.uint32)
    x = jnp.asarray(x_np)
    out_k = popcnt_blocked(x, interpret=True)
    out_r = popcnt_blocked_ref(x)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
    # ground truth against numpy bit counting
    expect = np.array(
        [np.unpackbits(x_np[b].view(np.uint8)).sum() for b in range(nblocks)],
        dtype=np.uint32,
    )
    np.testing.assert_array_equal(np.asarray(out_k), expect)


# ---------------------------------------------------------------- delta pack

@settings(max_examples=20, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(
    nblocks=st.integers(2, 24),
    rows=st.sampled_from([8, 16]),
    seed=st.integers(0, 999),
)
def test_pack_apply_kernels_match_refs(nblocks, rows, seed):
    rng = np.random.default_rng(seed)
    k = rng.integers(1, nblocks + 1)
    idx = jnp.asarray(rng.choice(nblocks, size=k, replace=False).astype(np.int32))
    src = rand(rng, (nblocks, rows, LANES), jnp.float32)
    packed_k = delta_pack_blocked(src, idx, interpret=True)
    packed_r = delta_pack_blocked_ref(src, idx)
    np.testing.assert_array_equal(np.asarray(packed_k), np.asarray(packed_r))

    base = rand(rng, (nblocks, rows, LANES), jnp.float32)
    out_k = delta_apply_blocked(base, packed_k, idx, interpret=True)
    out_r = delta_apply_blocked_ref(base, packed_r, idx)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


# ---------------------------------------------------------- apply_unpack

@settings(max_examples=20, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(
    nblocks=st.integers(2, 24),
    rows=st.sampled_from([8, 16]),
    seed=st.integers(0, 999),
    nbad=st.integers(0, 3),
)
def test_apply_unpack_kernel_matches_ref(nblocks, rows, seed, nbad):
    """Restore-direction sweep: the fused verify+scatter kernel matches
    the jnp oracle on the assembled image, the per-block popcounts and
    the ok flags — including when ``nbad`` expected counts are wrong."""
    from repro.kernels.apply_unpack.kernel import apply_unpack_blocked
    from repro.kernels.apply_unpack.ref import (apply_unpack_blocked_ref,
                                                block_popcounts)
    rng = np.random.default_rng(seed)
    k = int(rng.integers(1, nblocks + 1))
    idx = jnp.asarray(rng.choice(nblocks, size=k, replace=False).astype(np.int32))
    packed = rand(rng, (k, rows, LANES), jnp.float32)
    base = rand(rng, (nblocks, rows, LANES), jnp.float32)
    expected = np.asarray(block_popcounts(packed)).copy()
    corrupt = rng.choice(k, size=min(nbad, k), replace=False)
    expected[corrupt] += 1
    expected = jnp.asarray(expected)
    out_k, ok_k, cnt_k = apply_unpack_blocked(base, packed, idx, expected,
                                              interpret=True)
    out_r, ok_r, cnt_r = apply_unpack_blocked_ref(base, packed, idx, expected)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
    np.testing.assert_array_equal(np.asarray(ok_k), np.asarray(ok_r))
    np.testing.assert_array_equal(np.asarray(cnt_k), np.asarray(cnt_r))
    assert int((1 - np.asarray(ok_k)).sum()) == len(set(corrupt.tolist()))
    # inverse of delta_pack's scatter on the clean blocks
    untouched = [b for b in range(nblocks) if b not in set(np.asarray(idx).tolist())]
    np.testing.assert_array_equal(np.asarray(out_k)[untouched],
                                  np.asarray(base)[untouched])


# ----------------------------------------------------------- flush_scan

@settings(max_examples=20, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(ntiles=st.integers(1, 3), rows=st.sampled_from([8, 16]),
       seed=st.integers(0, 999), ndirty=st.integers(0, 6))
def test_flush_scan_kernel_matches_ref(ntiles, rows, seed, ndirty):
    from repro.kernels.flush_scan.kernel import flush_scan_blocked
    from repro.kernels.flush_scan.ref import flush_scan_blocked_ref
    rng = np.random.default_rng(seed)
    nblocks = ntiles * TILE_BLOCKS
    snap = rand(rng, (nblocks, rows, LANES), jnp.float32)
    cur = np.asarray(snap).copy()
    for b in rng.choice(nblocks, size=min(ndirty, nblocks), replace=False):
        cur[b, rng.integers(rows), rng.integers(LANES)] += 1.0
    cur = jnp.asarray(cur)
    d_k, c_k = flush_scan_blocked(cur, snap, interpret=True)
    d_r, c_r = flush_scan_blocked_ref(cur, snap)
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))
