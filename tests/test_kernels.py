"""Kernel validation: Pallas (interpret mode) vs pure-jnp oracles.

Deterministic checks only; the hypothesis-driven shape/dtype sweeps live in
``test_kernels_props.py`` (skipped without the ``test`` extra)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.common import LANES, TILE_BLOCKS, as_blocks, block_rows, from_blocks
from repro.kernels.delta_pack.kernel import delta_apply_blocked, delta_pack_blocked
from repro.kernels.delta_pack.ref import delta_apply_blocked_ref, delta_pack_blocked_ref
from repro.kernels.delta_pack.ops import apply_delta, pack_delta
from repro.kernels.dirty_diff.kernel import dirty_diff_blocked
from repro.kernels.dirty_diff.ref import dirty_diff_blocked_ref
from repro.kernels.dirty_diff.ops import dirty_blocks
from repro.kernels.popcnt_checksum.kernel import popcnt_blocked
from repro.kernels.popcnt_checksum.ref import popcnt_blocked_ref
from repro.kernels.popcnt_checksum.ops import popcount_blocks, popcount_checksum
from repro.kernels.delta_pack.ops import pack_dirty
from repro.kernels.flush_pack import compact_index, flush_pack

DTYPES = [jnp.float32, jnp.bfloat16, jnp.int8, jnp.uint32]


def rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    if dtype == jnp.uint32:
        return jnp.asarray((x * 1e6).view(np.uint32).reshape(shape))
    if dtype == jnp.int8:
        return jnp.asarray((x * 10).astype(np.int8))
    return jnp.asarray(x).astype(dtype)


# ------------------------------------------------------------- common layout

# --------------------------------------------------------------- dirty_diff

@pytest.mark.parametrize("dtype", DTYPES)
def test_dirty_blocks_op_dtypes(dtype):
    rng = np.random.default_rng(0)
    x = rand(rng, (3000,), dtype)
    y = np.asarray(x).copy()
    y[1234] = np.asarray(rand(rng, (1,), dtype))[0]
    flags_ref = dirty_blocks(x, jnp.asarray(y), impl="ref")
    flags_pal = dirty_blocks(x, jnp.asarray(y), impl="pallas")
    np.testing.assert_array_equal(np.asarray(flags_ref), np.asarray(flags_pal))


def test_dirty_blocks_identical_is_clean():
    x = jnp.arange(10_000, dtype=jnp.float32)
    assert int(dirty_blocks(x, x, impl="pallas").sum()) == 0


# ----------------------------------------------------------- popcnt_checksum

@pytest.mark.parametrize("dtype", DTYPES)
def test_popcount_checksum_properties(dtype):
    rng = np.random.default_rng(1)
    x = rand(rng, (2048,), dtype)
    c_ref = int(popcount_checksum(x, impl="ref"))
    c_pal = int(popcount_checksum(x, impl="pallas"))
    assert c_ref == c_pal
    assert c_ref != 0, "checksum of written data must be nonzero (cnt==0 = never written)"
    # zero buffer => checksum exactly 1 (popcount 0 + 1)
    assert int(popcount_checksum(jnp.zeros(512, dtype), impl="pallas")) == 1
    # dropping a block changes the checksum (Zero-log validity argument)
    y = np.asarray(as_blocks(x)[0]).copy()
    if np.unpackbits(y[0].view(np.uint8) if y.dtype == np.uint8 else y[0].view(np.uint8)).sum() > 0:
        y[0] = 0
        c_dropped = int(popcount_checksum(jnp.asarray(y).reshape(-1), impl="ref"))
        assert c_dropped != c_ref


# ---------------------------------------------------------------- delta pack

@pytest.mark.parametrize("dtype", DTYPES)
def test_delta_roundtrip_restores_buffer(dtype):
    """pack(cur) applied onto snap reproduces cur exactly — the µLog replay
    invariant the checkpoint layer relies on."""
    rng = np.random.default_rng(2)
    snap = rand(rng, (9000,), dtype)
    cur = np.asarray(snap).copy()
    dirty_positions = [0, 4097, 8000]
    for p in dirty_positions:
        cur[p] = np.asarray(rand(rng, (1,), dtype))[0]
    cur = jnp.asarray(cur)
    for impl in ("ref", "pallas"):
        flags = dirty_blocks(cur, snap, impl=impl)
        idx = jnp.asarray(np.flatnonzero(np.asarray(flags)).astype(np.int32))
        delta = pack_delta(cur, idx, impl=impl)
        restored = apply_delta(snap, delta, idx, impl=impl)
        np.testing.assert_array_equal(np.asarray(restored), np.asarray(cur))


def test_apply_delta_preserves_clean_blocks():
    rng = np.random.default_rng(3)
    base = rand(rng, (64, 8, LANES), jnp.float32)
    upd = rand(rng, (2, 8, LANES), jnp.float32)
    idx = jnp.asarray([5, 60], dtype=jnp.int32)
    out = delta_apply_blocked(base, upd, idx, interpret=True)
    out_np, base_np = np.asarray(out), np.asarray(base)
    clean = [b for b in range(64) if b not in (5, 60)]
    np.testing.assert_array_equal(out_np[clean], base_np[clean])
    np.testing.assert_array_equal(out_np[[5, 60]], np.asarray(upd))


# ----------------------------------------------------------- flush_scan

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
def test_flush_scan_consistent_with_separate_kernels(dtype):
    """Fused scan == dirty_diff + popcount_blocks composed (any dtype)."""
    from repro.kernels.flush_scan import flush_scan
    rng = np.random.default_rng(5)
    snap = rand(rng, (5000,), dtype)
    cur = np.asarray(snap).copy()
    cur[123] = np.asarray(rand(rng, (1,), dtype))[0]
    cur = jnp.asarray(cur)
    d, c = flush_scan(cur, snap, impl="pallas")
    d2 = dirty_blocks(cur, snap, impl="ref")
    c2 = popcount_blocks(cur, impl="ref")
    np.testing.assert_array_equal(np.asarray(d), np.asarray(d2))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c2))


# ----------------------------------------------------------- flush_pack

def _dirtied(rng, snap, positions):
    """Copy of ``snap`` with new random values at ``positions``."""
    cur = np.asarray(snap).copy()
    for p in positions:
        cur[p] = np.asarray(rand(rng, (1,), snap.dtype))[0]
    return jnp.asarray(cur)


def _assert_flush_pack_equal(a, b):
    assert a.total == b.total
    for f in ("flags", "counts", "offsets", "packed", "index"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)), err_msg=f)


@pytest.mark.parametrize("dtype", DTYPES)
def test_flush_pack_ref_vs_pallas_dtypes(dtype):
    """The fused Pallas kernel (interpret mode) matches the jnp oracle on
    every FlushPack field, for every checkpointable dtype."""
    rng = np.random.default_rng(7)
    snap = rand(rng, (9000,), dtype)
    cur = _dirtied(rng, snap, [0, 4097, 8000])
    _assert_flush_pack_equal(flush_pack(cur, snap, impl="pallas"),
                             flush_pack(cur, snap, impl="ref"))


@pytest.mark.parametrize("block_bytes", [4096, 8192, 16384])
@pytest.mark.parametrize("n", [4096, 5000, 13000])
def test_flush_pack_block_sizes_and_ragged_tails(block_bytes, n):
    """Parity across block sizes and buffer lengths that are not block
    (or grid-tile) multiples — the zero-padded tail must never read as
    dirty or perturb the prefix-sum offsets."""
    rng = np.random.default_rng(block_bytes + n)
    snap = rand(rng, (n,), jnp.float32)
    cur = _dirtied(rng, snap, [1, n // 2, n - 1])
    fp_pal = flush_pack(cur, snap, block_bytes=block_bytes, impl="pallas")
    fp_ref = flush_pack(cur, snap, block_bytes=block_bytes, impl="ref")
    _assert_flush_pack_equal(fp_pal, fp_ref)
    nblocks = -(-n * 4 // block_bytes)
    assert fp_pal.flags.shape[0] == nblocks
    assert 1 <= fp_pal.total <= 3
    # offsets are the exclusive prefix sum of the flags
    f = np.asarray(fp_pal.flags)
    np.testing.assert_array_equal(np.asarray(fp_pal.offsets),
                                  np.cumsum(f) - f)


@pytest.mark.parametrize("impl", ["ref", "pallas", "fused"])
def test_flush_pack_all_clean_and_all_dirty(impl):
    """The two extremes: identical buffers pack nothing (flags, packed
    and index all zero); fully-rewritten buffers pack every block in
    ascending order, so ``packed`` is just the blocked live buffer."""
    rng = np.random.default_rng(11)
    snap = rand(rng, (6000,), jnp.float32)
    clean = flush_pack(snap, snap, impl=impl)
    assert clean.total == 0
    assert int(np.asarray(clean.flags).sum()) == 0
    assert not np.asarray(clean.packed).any()
    assert not np.asarray(clean.index).any()

    cur = rand(rng, (6000,), jnp.float32)   # independent draw: all blocks differ
    full = flush_pack(cur, snap, impl=impl)
    nblocks = full.flags.shape[0]
    assert full.total == nblocks
    np.testing.assert_array_equal(np.asarray(full.flags), np.ones(nblocks))
    np.testing.assert_array_equal(np.asarray(full.index),
                                  np.arange(nblocks, dtype=np.int32))
    np.testing.assert_array_equal(np.asarray(full.packed),
                                  np.asarray(as_blocks(cur)[0]))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
def test_flush_pack_matches_staged_oracles(dtype):
    """One fused pass == the staged chain composed: dirty_diff flags,
    popcnt checksums, flatnonzero compaction, delta_pack gather."""
    rng = np.random.default_rng(13)
    snap = rand(rng, (7000,), dtype)
    cur = _dirtied(rng, snap, [5, 2048, 6999])
    fp = flush_pack(cur, snap, impl="pallas")
    flags = dirty_blocks(cur, snap, impl="ref")
    counts = popcount_blocks(cur, impl="ref")
    idx = np.flatnonzero(np.asarray(flags)).astype(np.int32)
    delta = pack_delta(cur, jnp.asarray(idx), impl="ref")
    np.testing.assert_array_equal(np.asarray(fp.flags), np.asarray(flags))
    np.testing.assert_array_equal(np.asarray(fp.counts), np.asarray(counts))
    assert fp.total == idx.size
    np.testing.assert_array_equal(np.asarray(fp.index[: fp.total]), idx)
    np.testing.assert_array_equal(np.asarray(fp.packed[: fp.total]),
                                  np.asarray(delta))
    # ...and the packed delta replays: apply onto snap reproduces cur
    restored = apply_delta(snap, fp.packed[: fp.total],
                           fp.index[: fp.total], impl="ref")
    np.testing.assert_array_equal(np.asarray(restored), np.asarray(cur))


def test_compact_index_matches_flatnonzero():
    """On-device prefix-sum compaction == np.flatnonzero, including the
    empty, full, and single-flag patterns."""
    for pattern in ([0] * 16, [1] * 16, [0] * 15 + [1], [1] + [0] * 15,
                    [0, 1, 1, 0, 1, 0, 0, 1], [1, 0] * 8):
        flags = jnp.asarray(pattern, dtype=jnp.int32)
        index, total = compact_index(flags)
        k = int(total)
        want = np.flatnonzero(np.asarray(pattern))
        assert k == want.size
        np.testing.assert_array_equal(np.asarray(index[:k]), want)


# ----------------------------------------------------------- apply_unpack

def _unpack_case(rng, n, dtype, k):
    """A restore-shaped case: ``k`` packed blocks scattered over an
    ``n``-element base, plus their true per-block popcounts."""
    from repro.kernels.apply_unpack import block_popcounts
    base = rand(rng, (n,), dtype)
    nblocks = as_blocks(base)[0].shape[0]
    idx = rng.choice(nblocks, size=min(k, nblocks), replace=False)
    idx = np.sort(idx).astype(np.int32)
    rows = block_rows(dtype)
    packed = rand(rng, (idx.size, rows, LANES), dtype)
    expected = np.asarray(block_popcounts(packed))
    return base, packed, jnp.asarray(idx), jnp.asarray(expected)


@pytest.mark.parametrize("dtype", DTYPES)
def test_apply_unpack_ref_vs_pallas_dtypes(dtype):
    from repro.kernels.apply_unpack import apply_unpack
    rng = np.random.default_rng(19)
    base, packed, idx, exp = _unpack_case(rng, 9000, dtype, 3)
    res_ref = apply_unpack(base, packed, idx, exp, impl="ref")
    res_pal = apply_unpack(base, packed, idx, exp, impl="pallas")
    assert res_ref.nbad == 0 and res_pal.nbad == 0
    np.testing.assert_array_equal(np.asarray(res_pal.out),
                                  np.asarray(res_ref.out))
    np.testing.assert_array_equal(np.asarray(res_pal.counts),
                                  np.asarray(res_ref.counts))
    np.testing.assert_array_equal(np.asarray(res_pal.ok),
                                  np.asarray(res_ref.ok))


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_apply_unpack_inverts_flush_pack(impl):
    """The restore kernel is flush_pack's inverse: scatter the packed
    dirty blocks onto the snapshot and the live buffer reappears,
    checksum-verified against flush_pack's own per-block counts."""
    from repro.kernels.apply_unpack import apply_unpack
    rng = np.random.default_rng(23)
    snap = rand(rng, (9000,), jnp.float32)
    cur = _dirtied(rng, snap, [0, 4097, 8000])
    fp = flush_pack(cur, snap, impl="ref")
    k = fp.total
    exp = np.asarray(fp.counts)[np.asarray(fp.index[:k])]
    res = apply_unpack(snap, fp.packed[:k], fp.index[:k],
                       jnp.asarray(exp), impl=impl)
    assert res.nbad == 0
    np.testing.assert_array_equal(np.asarray(res.out), np.asarray(cur))


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_apply_unpack_detects_corruption(impl):
    """A wrong expected count flags exactly the corrupted block; the
    scatter still lands (the caller discards the whole result)."""
    from repro.kernels.apply_unpack import apply_unpack
    rng = np.random.default_rng(29)
    base, packed, idx, exp = _unpack_case(rng, 8192, jnp.float32, 4)
    bad = jnp.asarray(np.asarray(exp) + np.array([0, 1, 0, 0], np.uint32))
    res = apply_unpack(base, packed, idx, bad, impl=impl)
    assert res.nbad == 1
    np.testing.assert_array_equal(np.asarray(res.ok),
                                  np.array([1, 0, 1, 1], np.int32))


def test_apply_unpack_clean_blocks_preserved():
    """Blocks outside the scatter index keep the base bytes exactly."""
    from repro.kernels.apply_unpack import apply_unpack
    rng = np.random.default_rng(31)
    base, packed, idx, exp = _unpack_case(rng, 9000, jnp.float32, 2)
    res = apply_unpack(base, packed, idx, exp, impl="pallas")
    out_b = np.asarray(as_blocks(jnp.asarray(res.out))[0])
    base_b = np.asarray(as_blocks(jnp.asarray(base))[0])
    touched = set(int(i) for i in np.asarray(idx))
    clean = [b for b in range(base_b.shape[0]) if b not in touched]
    np.testing.assert_array_equal(out_b[clean], base_b[clean])


def test_apply_unpack_empty_and_ragged():
    """k == 0 is a no-op; a base whose length is not a block multiple
    round-trips through the padded blocked form unchanged."""
    from repro.kernels.apply_unpack import apply_unpack
    rng = np.random.default_rng(37)
    base = rand(rng, (5000,), jnp.float32)      # ragged: 5000 * 4 % 4096 != 0
    empty = apply_unpack(base, jnp.zeros((0,), jnp.float32),
                         jnp.zeros((0,), jnp.int32),
                         jnp.zeros((0,), jnp.uint32))
    assert empty.nbad == 0
    np.testing.assert_array_equal(np.asarray(empty.out), np.asarray(base))
    b2, packed, idx, exp = _unpack_case(rng, 5000, jnp.float32, 2)
    res = apply_unpack(b2, packed, idx, exp, impl="pallas")
    assert res.out.shape == b2.shape and res.nbad == 0


def test_pack_dirty_shares_compaction():
    """delta_pack's flag-driven entry point (the staged fallback) uses
    the same on-device compaction — no host flatnonzero — and agrees
    with the explicit-index pack_delta."""
    rng = np.random.default_rng(17)
    snap = rand(rng, (8192,), jnp.float32)
    cur = _dirtied(rng, snap, [100, 3000, 8000])
    flags = dirty_blocks(cur, snap, impl="ref")
    delta, idx, k = pack_dirty(cur, flags, impl="ref")
    want_idx = np.flatnonzero(np.asarray(flags)).astype(np.int32)
    assert k == want_idx.size
    np.testing.assert_array_equal(np.asarray(idx), want_idx)
    np.testing.assert_array_equal(
        np.asarray(delta),
        np.asarray(pack_delta(cur, jnp.asarray(want_idx), impl="ref")))
