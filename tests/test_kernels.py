"""Kernel validation: Pallas (interpret mode) vs pure-jnp oracles.

Deterministic checks only; the hypothesis-driven shape/dtype sweeps live in
``test_kernels_props.py`` (skipped without the ``test`` extra)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.common import LANES, TILE_BLOCKS, as_blocks, block_rows, from_blocks
from repro.kernels.delta_pack.kernel import delta_apply_blocked, delta_pack_blocked
from repro.kernels.delta_pack.ref import delta_apply_blocked_ref, delta_pack_blocked_ref
from repro.kernels.delta_pack.ops import apply_delta, pack_delta
from repro.kernels.dirty_diff.kernel import dirty_diff_blocked
from repro.kernels.dirty_diff.ref import dirty_diff_blocked_ref
from repro.kernels.dirty_diff.ops import dirty_blocks
from repro.kernels.popcnt_checksum.kernel import popcnt_blocked
from repro.kernels.popcnt_checksum.ref import popcnt_blocked_ref
from repro.kernels.popcnt_checksum.ops import popcount_blocks, popcount_checksum

DTYPES = [jnp.float32, jnp.bfloat16, jnp.int8, jnp.uint32]


def rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    if dtype == jnp.uint32:
        return jnp.asarray((x * 1e6).view(np.uint32).reshape(shape))
    if dtype == jnp.int8:
        return jnp.asarray((x * 10).astype(np.int8))
    return jnp.asarray(x).astype(dtype)


# ------------------------------------------------------------- common layout

# --------------------------------------------------------------- dirty_diff

@pytest.mark.parametrize("dtype", DTYPES)
def test_dirty_blocks_op_dtypes(dtype):
    rng = np.random.default_rng(0)
    x = rand(rng, (3000,), dtype)
    y = np.asarray(x).copy()
    y[1234] = np.asarray(rand(rng, (1,), dtype))[0]
    flags_ref = dirty_blocks(x, jnp.asarray(y), impl="ref")
    flags_pal = dirty_blocks(x, jnp.asarray(y), impl="pallas")
    np.testing.assert_array_equal(np.asarray(flags_ref), np.asarray(flags_pal))


def test_dirty_blocks_identical_is_clean():
    x = jnp.arange(10_000, dtype=jnp.float32)
    assert int(dirty_blocks(x, x, impl="pallas").sum()) == 0


# ----------------------------------------------------------- popcnt_checksum

@pytest.mark.parametrize("dtype", DTYPES)
def test_popcount_checksum_properties(dtype):
    rng = np.random.default_rng(1)
    x = rand(rng, (2048,), dtype)
    c_ref = int(popcount_checksum(x, impl="ref"))
    c_pal = int(popcount_checksum(x, impl="pallas"))
    assert c_ref == c_pal
    assert c_ref != 0, "checksum of written data must be nonzero (cnt==0 = never written)"
    # zero buffer => checksum exactly 1 (popcount 0 + 1)
    assert int(popcount_checksum(jnp.zeros(512, dtype), impl="pallas")) == 1
    # dropping a block changes the checksum (Zero-log validity argument)
    y = np.asarray(as_blocks(x)[0]).copy()
    if np.unpackbits(y[0].view(np.uint8) if y.dtype == np.uint8 else y[0].view(np.uint8)).sum() > 0:
        y[0] = 0
        c_dropped = int(popcount_checksum(jnp.asarray(y).reshape(-1), impl="ref"))
        assert c_dropped != c_ref


# ---------------------------------------------------------------- delta pack

@pytest.mark.parametrize("dtype", DTYPES)
def test_delta_roundtrip_restores_buffer(dtype):
    """pack(cur) applied onto snap reproduces cur exactly — the µLog replay
    invariant the checkpoint layer relies on."""
    rng = np.random.default_rng(2)
    snap = rand(rng, (9000,), dtype)
    cur = np.asarray(snap).copy()
    dirty_positions = [0, 4097, 8000]
    for p in dirty_positions:
        cur[p] = np.asarray(rand(rng, (1,), dtype))[0]
    cur = jnp.asarray(cur)
    for impl in ("ref", "pallas"):
        flags = dirty_blocks(cur, snap, impl=impl)
        idx = jnp.asarray(np.flatnonzero(np.asarray(flags)).astype(np.int32))
        delta = pack_delta(cur, idx, impl=impl)
        restored = apply_delta(snap, delta, idx, impl=impl)
        np.testing.assert_array_equal(np.asarray(restored), np.asarray(cur))


def test_apply_delta_preserves_clean_blocks():
    rng = np.random.default_rng(3)
    base = rand(rng, (64, 8, LANES), jnp.float32)
    upd = rand(rng, (2, 8, LANES), jnp.float32)
    idx = jnp.asarray([5, 60], dtype=jnp.int32)
    out = delta_apply_blocked(base, upd, idx, interpret=True)
    out_np, base_np = np.asarray(out), np.asarray(base)
    clean = [b for b in range(64) if b not in (5, 60)]
    np.testing.assert_array_equal(out_np[clean], base_np[clean])
    np.testing.assert_array_equal(out_np[[5, 60]], np.asarray(upd))


# ----------------------------------------------------------- flush_scan

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
def test_flush_scan_consistent_with_separate_kernels(dtype):
    """Fused scan == dirty_diff + popcount_blocks composed (any dtype)."""
    from repro.kernels.flush_scan import flush_scan
    rng = np.random.default_rng(5)
    snap = rand(rng, (5000,), dtype)
    cur = np.asarray(snap).copy()
    cur[123] = np.asarray(rand(rng, (1,), dtype))[0]
    cur = jnp.asarray(cur)
    d, c = flush_scan(cur, snap, impl="pallas")
    d2 = dirty_blocks(cur, snap, impl="ref")
    c2 = popcount_blocks(cur, impl="ref")
    np.testing.assert_array_equal(np.asarray(d), np.asarray(d2))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c2))
