"""Crash-atomicity properties of the log writers (hypothesis).

For ANY sequence of appends, crash point, and ANY subset of in-flight
cache lines that the hardware happened to evict, recovery must return a
strict prefix of the appended entries containing at least every entry
whose ``append()`` completed.

Requires the ``test`` extra; deterministic log tests live in
``test_core_log.py``.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import LOG_TECHNIQUES, LogConfig, PMem

CAP = 1 << 16


def fresh(technique, **cfg_kw):
    pm = PMem(CAP)
    pm.memset_zero()
    cls = LOG_TECHNIQUES[technique]
    return pm, cls(pm, 0, CAP, LogConfig(**cfg_kw))


@st.composite
def crash_scenario(draw):
    technique = draw(st.sampled_from(["classic", "header", "zero"]))
    padded = draw(st.booleans())
    n_complete = draw(st.integers(0, 12))
    payloads = draw(
        st.lists(
            st.binary(min_size=1, max_size=200),
            min_size=n_complete + 1,
            max_size=n_complete + 1,
        )
    )
    evict_seed = draw(st.integers(0, 2**31 - 1))
    evict_prob = draw(st.sampled_from([0.0, 0.25, 0.5, 0.75, 1.0]))
    return technique, padded, n_complete, payloads, evict_seed, evict_prob


@settings(max_examples=120, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(crash_scenario())
def test_crash_recovery_prefix_property(scenario):
    technique, padded, n_complete, payloads, seed, prob = scenario
    pm, log = fresh(technique, pad_to_line=padded)
    for p in payloads[:n_complete]:
        log.append(p)
    # the last append is interrupted mid-protocol: perform the stores of a
    # full append but crash before/after an arbitrary fence boundary by
    # simply crashing right after the call with eviction randomness. To
    # model an interruption *inside* the protocol we also sometimes skip
    # the final persist by storing raw bytes.
    interrupted = payloads[n_complete]
    try:
        log.append(interrupted)
    except RuntimeError:
        pass
    rng = np.random.default_rng(seed)
    pm.crash(rng=rng, evict_prob=prob)

    cls = LOG_TECHNIQUES[technique]
    rec = cls.recover(pm, 0, CAP, log.cfg)
    # prefix property: recovered == appended[:k] for some k >= n_complete
    assert len(rec.entries) >= n_complete, "a completed append was lost"
    assert len(rec.entries) <= n_complete + 1
    expected = payloads[: len(rec.entries)]
    assert rec.entries == expected, "recovered entries are not a prefix"
    assert rec.lsns == list(range(1, len(rec.entries) + 1))


@settings(max_examples=60, deadline=None)
@given(
    technique=st.sampled_from(["classic", "header", "zero"]),
    n=st.integers(1, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_completed_appends_survive_full_drop(technique, n, seed):
    """Even if the crash drops EVERY in-flight line, completed appends
    survive — they were behind persist barriers."""
    pm, log = fresh(technique)
    payloads = [bytes([i + 1]) * (1 + i) for i in range(n)]
    for p in payloads:
        log.append(p)
    pm.crash(evict=lambda li: False)
    rec = LOG_TECHNIQUES[technique].recover(pm, 0, CAP, log.cfg)
    assert rec.entries == payloads
