"""NUMA lane placement: remote accounting, cost-model golden values,
placer policy, dynamic group commit — and the load-bearing invariant
that placement is a performance hint, never a durability input
(cross-socket recovery parity).
"""

import numpy as np
import pytest

from corpus_runner import run_multilog_crash
from repro.core import COST_MODEL, FlushKind, PMem
from repro.core.pmem import PMemStats
from repro.core.ssd import SSD
from repro.io import LanePlacer, MultiLog
from repro.pool import Pool
from repro.tier import SpillScheduler


# ===================================================== remote accounting

def test_remote_accounting_basic():
    """Work done under a lane whose CPU socket differs from the touched
    bytes' home socket is counted remote; near work is not."""
    pm = PMem(1 << 16, sockets=2)
    pm.memset_zero()
    pm.set_home(0, 1 << 12, 0)
    pm.set_home(1 << 12, 1 << 12, 1)
    with pm.lane(0, socket=0):
        pm.store(0, b"x" * 256, streaming=True)        # near
        pm.sfence()
        pm.store(1 << 12, b"y" * 256, streaming=True)  # remote
        pm.sfence()
    s = pm.stats
    assert s.barriers == 2 and s.remote_barriers == 1
    assert s.blocks_written == 2 and s.remote_blocks_written == 1
    assert s.lane_remote_barriers == {0: 1}
    assert s.lane_remote_blocks_written == {0: 1}


def test_unsocketed_lane_never_remote():
    """A lane with no CPU socket (the pre-NUMA call signature) counts
    nothing remote, whatever the homes say."""
    pm = PMem(1 << 16, sockets=2)
    pm.memset_zero()
    pm.set_home(0, 1 << 16, 1)
    with pm.lane(3):
        pm.store(0, b"x" * 256, streaming=True)
        pm.sfence()
    assert pm.stats.barriers == 1
    assert pm.stats.remote_barriers == 0
    assert pm.stats.remote_blocks_written == 0


def test_home_socket_map():
    pm = PMem(1 << 16, sockets=4)
    pm.set_home(4096, 4096, 2)
    pm.set_home(8192, 4096, 3)
    assert pm.home_socket(0) == 0          # unregistered defaults to 0
    assert pm.home_socket(4096) == 2
    assert pm.home_socket(8191) == 2
    assert pm.home_socket(8192) == 3
    assert pm.home_socket(12288) == 0
    pm.set_home(4096, 4096, 1)             # re-registration replaces
    assert pm.home_socket(4200) == 1
    pm.set_home(0, 64, 99)                 # clamps to the topology
    assert pm.home_socket(0) == 3


# ============================================= engine_time_ns golden values

def _lane_stats(lanes, barriers, blocks, partial, remote=False):
    s = PMemStats()
    for li in range(lanes):
        s.lane_barriers[li] = barriers
        s.lane_blocks_written[li] = blocks
        s.lane_partial_blocks[li] = partial
        s.barriers += barriers
        s.blocks_written += blocks
        if remote:
            s.lane_remote_barriers[li] = barriers
            s.lane_remote_blocks_written[li] = blocks
            s.lane_remote_partial_blocks[li] = partial
    return s


#: pinned (local_ns, remote_ns) for 16 barriers + 32 blocks (4 partial)
#: per lane — regenerate only for a deliberate cost-model change, and
#: update docs/costmodel.md provenance alongside
GOLDEN = {
    (1, FlushKind.NT): (7184.0, 15539.199999999999),
    (1, FlushKind.CLWB): (7584.0, 16339.199999999999),
    (2, FlushKind.NT): (7346.666666666667, 15913.333333333334),
    (2, FlushKind.CLWB): (7746.666666666667, 16713.333333333336),
    (4, FlushKind.NT): (9024.133009637313, 19771.505922165816),
    (4, FlushKind.CLWB): (8116.363636363636, 17563.63636363636),
    (8, FlushKind.NT): (16793.450842146493, 37640.93693693693),
    (8, FlushKind.CLWB): (10382.222222222223, 22775.11111111111),
}


@pytest.mark.parametrize("lanes,kind", sorted(GOLDEN, key=str))
def test_engine_time_golden(lanes, kind):
    """Golden values pin the Fig. 2 curve (local column) and the NUMA
    terms (remote column) so neither can silently regress."""
    local, remote = GOLDEN[(lanes, kind)]
    got_local = COST_MODEL.engine_time_ns(
        _lane_stats(lanes, 16, 32, 4), active_lanes=lanes, kind=kind)
    got_remote = COST_MODEL.engine_time_ns(
        _lane_stats(lanes, 16, 32, 4, remote=True), active_lanes=lanes,
        kind=kind)
    assert got_local == pytest.approx(local, rel=1e-12)
    assert got_remote == pytest.approx(remote, rel=1e-12)


@pytest.mark.parametrize("kind", [FlushKind.NT, FlushKind.CLWB,
                                  FlushKind.FLUSHOPT])
@pytest.mark.parametrize("lanes", [1, 2, 3, 4, 5, 6, 8, 12, 16])
def test_engine_time_remote_monotone(lanes, kind):
    """Remote >= local for every technique and lane count, and a partial
    remote mix sits strictly between the all-local and all-remote ends."""
    local = COST_MODEL.engine_time_ns(
        _lane_stats(lanes, 16, 32, 4), active_lanes=lanes, kind=kind)
    remote = COST_MODEL.engine_time_ns(
        _lane_stats(lanes, 16, 32, 4, remote=True), active_lanes=lanes,
        kind=kind)
    assert remote > local
    mixed = _lane_stats(lanes, 16, 32, 4)
    mixed.lane_remote_barriers[0] = 8
    mixed.lane_remote_blocks_written[0] = 16
    mixed.lane_remote_partial_blocks[0] = 2
    got = COST_MODEL.engine_time_ns(mixed, active_lanes=lanes, kind=kind)
    assert local < got < remote


def test_engine_time_near_socket_unchanged():
    """With zero remote counts the NUMA terms vanish: max-over-lanes must
    equal the hand-computed pre-NUMA formula exactly."""
    lanes, barriers, blocks = 4, 16, 32
    stats = _lane_stats(lanes, barriers, blocks, 0)
    got = COST_MODEL.engine_time_ns(stats, active_lanes=lanes,
                                    kind=FlushKind.NT)
    cm = COST_MODEL
    per_block = cm.block_write_ns_single / (
        cm.thread_scale(lanes, FlushKind.NT) / lanes)
    from repro.core.persist import AccessPattern
    expected = barriers * (cm.persist_latency_ns(
        FlushKind.NT, AccessPattern.SEQUENTIAL) + cm.barrier_ns) \
        + blocks * per_block
    assert got == pytest.approx(expected, rel=1e-12)


# ===================================================== placer policy

def test_placer_prefers_near_and_overflows_under_load():
    pm = PMem(1 << 12, sockets=2)
    placer = LanePlacer(pm, cpu_lanes_per_socket=2)
    assert placer.spread(4) == [0, 1, 0, 1]
    # balanced homes within capacity: everything near
    assert placer.place([0, 1, 0, 1]) == [0, 1, 0, 1]
    # skewed homes: near up to capacity, then remote to the idle socket
    assert placer.place([0, 0, 0, 0]) == [0, 0, 1, 1]
    # total saturation (each socket filled by its own near lanes):
    # oversubscribe near rather than go remote — the interconnect adds
    # cost without adding CPU capacity
    assert placer.place([0, 0, 0, 0, 0, 1, 1, 1, 1, 1]) == \
        [0, 0, 0, 0, 0, 1, 1, 1, 1, 1]


def test_placer_single_socket_is_noop():
    pm = PMem(1 << 12, sockets=1)
    placer = LanePlacer(pm)
    assert placer.spread(3) == [0, 0, 0]
    assert placer.place([0, 0, 0]) == [0, 0, 0]


def test_multilog_spreads_and_places_near():
    pool = Pool.create(None, 1 << 21, sockets=2)
    ml = MultiLog(pool, "ml", lanes=4, capacity=1 << 19)
    assert ml.lane_sockets == [0, 1, 0, 1]
    assert ml.lane_cpu == ml.lane_sockets
    # the durable tags round-trip through reopen
    ml.append(b"x", sync=True)
    pool2 = Pool.open(pmem=pool.pmem)
    ml2 = MultiLog(pool2, "ml")
    assert ml2.lane_sockets == [0, 1, 0, 1]
    assert pool2.pmem.home_socket(ml2.handles[1].base) == 1


def test_socket_tags_survive_file_reopen(tmp_path):
    path = str(tmp_path / "numa.pmem")
    pool = Pool.create(path, 1 << 20, sockets=2)
    pool.log("l1", capacity=1 << 12, socket=1)
    pool.fsync()
    pool2 = Pool.open(path)
    assert pool2.sockets == 2
    assert pool2.regions()["l1"].socket == 1
    assert pool2.pmem.home_socket(pool2.regions()["l1"].base) == 1


def test_allocate_rejects_out_of_topology_socket():
    pool = Pool.create(None, 1 << 20, sockets=2)
    with pytest.raises(ValueError, match="socket"):
        pool.log("bad", capacity=1 << 12, socket=2)


# ================================================= dynamic group commit

def test_dynamic_group_commit_adapts_to_submit_rate():
    """Sustained full batches (throughput-bound) grow a lane's k;
    explicit half-empty commits (latency-bound) shrink it back."""
    pool = Pool.create(None, 1 << 21, sockets=2)
    ml = MultiLog(pool, "ml", lanes=2, capacity=1 << 19, group_commit=2)
    assert ml.lane_k() == [2, 2]
    assert ml.lane_group_commit == ml.lane_k()   # alias stays in sync
    for _ in range(64):                      # back-to-back: batches fill
        ml.append(b"x" * 32)
    assert all(k > 2 for k in ml.lane_k())
    grown = ml.lane_k()
    for _ in range(16):                      # caller fences tiny batches
        ml.append(b"x" * 32)
        ml.commit()
    assert all(k < g for k, g in zip(ml.lane_k(), grown))


def test_dynamic_group_commit_remote_floor():
    """A remote lane's k never drops below the remote floor — its
    barriers cost ~2x, so at least twice the appends share each one."""
    pool = Pool.create(None, 1 << 21, sockets=2)
    ml = MultiLog(pool, "ml", lanes=2, capacity=1 << 19, group_commit=2,
                  lane_sockets=[0, 0], lane_cpu_sockets=[0, 1])
    for _ in range(32):                      # lane 1 is remote
        ml.append(b"x" * 32)
        ml.commit()
    remote_floor = LanePlacer(pool.pmem).adapt_k(1, 1, "explicit",
                                                 remote=True, base=2)
    # the near lane tracks the latency-bound workload down to ~base;
    # the remote lane holds its floor above it
    assert ml.lane_k(1) == remote_floor
    assert ml.lane_k(0) <= 2 < remote_floor


def test_group_commit_one_is_a_durability_contract():
    """base=1 means every append durable at return (the KV default);
    the placer must never batch beyond it, remote or not."""
    pool = Pool.create(None, 1 << 21, sockets=2)
    ml = MultiLog(pool, "ml", lanes=2, capacity=1 << 19, group_commit=1,
                  lane_sockets=[0, 0], lane_cpu_sockets=[0, 1])
    for _ in range(64):
        ml.append(b"x" * 32)
        assert ml.pending == 0          # durable at return, every time
    assert ml.lane_k() == [1, 1]


def test_static_without_placer():
    """No placer (single-socket pool, placer=False): k stays put."""
    pool = Pool.create(None, 1 << 21)
    ml = MultiLog(pool, "ml", lanes=2, capacity=1 << 19, group_commit=4)
    for _ in range(64):
        ml.append(b"x" * 32)
    assert ml.lane_k() == [4, 4]


# ========================================= cross-socket recovery parity

def _placements():
    near = ([0, 1, 0], [0, 1, 0])
    far = ([0, 1, 0], [1, 0, 1])
    skew = ([1, 1, 1], [0, 0, 1])
    return [near, far, skew]


def test_multilog_recovery_parity_across_placements():
    """Merge-on-recovery returns byte-identical state whatever socket
    each lane/CPU was placed on — placement is a performance hint, never
    a durability input. Same workload, same crash seed, three
    placements: identical recovered prefixes AND identical durable lane
    bytes."""
    results = []
    for lane_sockets, lane_cpu in _placements():
        rec = run_multilog_crash(
            "zero", 3, 4, 31, {7, 20}, 12345, 0.5,
            lane_sockets=lane_sockets, lane_cpu_sockets=lane_cpu,
            sockets=2)
        results.append((rec.glsns, rec.entries, rec.per_lane))
    assert results[0] == results[1] == results[2]


def test_multilog_durable_lane_bytes_parity():
    """The durable image of every lane region is bit-exact across
    placements (the stronger form of parity: not just what recovery
    returns, but what it reads)."""
    images = []
    for lane_sockets, lane_cpu in _placements():
        pool = Pool.create(None, 1 << 21, sockets=2)
        ml = MultiLog(pool, "ml", lanes=3, capacity=1 << 19,
                      technique="zero", group_commit=4,
                      lane_sockets=lane_sockets,
                      lane_cpu_sockets=lane_cpu, placer=False)
        for i in range(50):
            ml.append(b"entry-%03d" % i)
        ml.commit()
        pool.pmem.crash(rng=np.random.default_rng(777), evict_prob=0.5)
        images.append([bytes(pool.pmem.durable_slice(h.base, h.length))
                       for h in ml.handles])
    assert images[0] == images[1] == images[2]


def test_spill_recovery_parity_across_placements():
    """SpillScheduler.attach_spill + generation retirement produce
    identical recovered generations regardless of lane placement."""
    outcomes = []
    for lane_sockets, lane_cpu in _placements():
        pool = Pool.create(None, 1 << 21, sockets=2)
        ssd = SSD(1 << 22)
        pool.attach_ssd(ssd)
        sp = SpillScheduler(pool, name="sp", map_capacity=1 << 13)
        ml = MultiLog(pool, "wal", lanes=3, capacity=1 << 13, gen_sets=2,
                      group_commit=2, lane_sockets=lane_sockets,
                      lane_cpu_sockets=lane_cpu, placer=False)
        ml.attach_spill(sp)
        for g in range(3):
            for i in range(5):
                ml.append(b"g%d-e%d" % (g, i))
            ml.roll()
        sp.drain()
        pool.pmem.crash(rng=np.random.default_rng(4242), evict_prob=0.5)
        ssd.crash(rng=np.random.default_rng(4242), keep_prob=0.5)

        pool2 = Pool.open(pmem=pool.pmem)
        pool2.attach_ssd(ssd)
        sp2 = SpillScheduler(pool2, name="sp")
        ml2 = MultiLog(pool2, "wal")
        ml2.attach_spill(sp2)
        recovered = {}
        for g in range(1, ml2.current_gen + 1):
            src, entries = ml2.read_generation(g)
            recovered[g] = (src, [bytes(e) for e in entries])
        outcomes.append((ml2.current_gen, ml2.retired_upto, recovered))
    assert outcomes[0] == outcomes[1] == outcomes[2]


# ================================================== pool.wal(gen_sets=)

def test_pool_wal_gen_sets_passthrough():
    """The satellite fix: ``pool.wal(lanes=N, gen_sets=M)`` constructs a
    *generational* MultiLog (it used to silently drop gen_sets), roll()
    works, and reopen comes back generational."""
    from repro.persistence.wal import StepRecord, TrainWAL

    pool = Pool.create(None, TrainWAL.capacity_for(64, lanes=2, gen_sets=2))
    wal = pool.wal("steps", capacity_steps=16, lanes=2, group_commit=2,
                   gen_sets=2)
    assert wal.generational
    assert wal.log.gen_sets == 2
    for s in range(6):
        wal.commit_step(StepRecord(s, s * 10, (1, 2), 0.5, 1.0, 1.0))
    wal.flush()
    sealed = wal.roll()
    assert sealed == 1 and wal.log.current_gen == 2
    wal.commit_step(StepRecord(6, 60, (1, 2), 0.4, 1.0, 1.0))
    wal.flush()

    pool2 = Pool.open(pmem=pool.pmem)
    wal2 = pool2.wal("steps")
    assert wal2.generational
    # live generation holds only the post-roll step
    assert [r.step for r in wal2.records] == [6]
    # the sealed generation is still recoverable from its ring slot
    src, entries = wal2.log.read_generation(1)
    assert src == "pmem"
    assert [StepRecord.unpack(e).step for e in entries] == list(range(6))


def test_pool_wal_single_lane_rejects_gen_sets():
    pool = Pool.create(None, 1 << 21)
    pool.wal("w", capacity_steps=8)
    with pytest.raises(ValueError, match="single-lane"):
        pool.wal("w", gen_sets=2)


def test_multilog_rejects_generational_upgrade_in_place():
    """Opening an existing non-generational MultiLog with gen_sets >= 2
    must raise, not silently create an empty ring that orphans the
    committed entries in the old lane regions."""
    pool = Pool.create(None, 1 << 21)
    ml = MultiLog(pool, "ml", lanes=2, capacity=1 << 18)
    for i in range(5):
        ml.append(b"keep-%d" % i, sync=True)
    with pytest.raises(ValueError, match="non-generational"):
        MultiLog(pool, "ml", capacity=1 << 18, gen_sets=2)
    # the original log is untouched and still opens
    ml2 = MultiLog(pool, "ml")
    assert len(ml2.recovered.entries) == 5


def test_raw_rejects_conflicting_socket_on_reopen():
    pool = Pool.create(None, 1 << 20, sockets=2)
    pool.raw("r", nbytes=128, socket=1)
    assert pool.raw("r", socket=1).record.socket == 1   # matching is fine
    with pytest.raises(ValueError, match="fixed at creation"):
        pool.raw("r", socket=0)


def test_async_flusher_interleaves_shard_sockets():
    """AsyncFlusher(sockets=2) must actually land shard 1's regions on
    socket 1 — propagating the topology into a default (single-socket)
    shard config, not just setting a home that then clamps to 0."""
    from repro.persistence.checkpoint import CheckpointConfig, CheckpointManager
    from repro.persistence.flusher import AsyncFlusher

    cfg = CheckpointConfig(page_size=4096 * 4, manifest_capacity=1 << 17)
    mgrs = [CheckpointManager(None, cfg, shard_id=i) for i in range(2)]
    fl = AsyncFlusher(mgrs, sockets=2)
    state = {"w": np.arange(4096, dtype=np.float32)}
    fl.submit_all(1, [state, state])
    fl.close()
    assert mgrs[0].pool.sockets == 2 and mgrs[1].pool.sockets == 2
    assert mgrs[0].pool.regions()["pages"].socket == 0
    assert mgrs[1].pool.regions()["pages"].socket == 1
