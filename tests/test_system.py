"""End-to-end behaviour tests: the paper's primitives carrying a simulated
training job through crashes — WAL + delta checkpoints + recovery combine to
exactly-once step semantics."""

import numpy as np
import pytest

from repro.core import PMem
from repro.persistence import (
    CheckpointConfig,
    CheckpointManager,
    StepRecord,
    TrainWAL,
)

CFG = CheckpointConfig(page_size=128 * 1024, manifest_capacity=1 << 16)


def fake_train_state(step: int, nparam: int = 1 << 15):
    """Deterministic 'parameters' after `step` optimizer updates."""
    rng = np.random.default_rng(42)
    base = rng.standard_normal(nparam).astype(np.float32)
    return {"params": base * (1.0 + 0.01 * step)}


def fake_update(state, step):
    base = fake_train_state(0)["params"] / 1.0
    return {"params": fake_train_state(step)["params"]}


class MiniTrainer:
    """A training loop skeleton wired to the persistence stack the way
    launch/train.py does it (checkpoint every k steps, WAL every step)."""

    def __init__(self, ckpt_path, wal_pmem, ckpt_every=5):
        self.manager = CheckpointManager(ckpt_path, CFG)
        self.wal = TrainWAL(wal_pmem, 0, wal_pmem.size)
        self.ckpt_every = ckpt_every

    def run(self, state, start_step, n_steps, crash_at=None):
        for step in range(start_step, start_step + n_steps):
            if crash_at is not None and step == crash_at:
                return state, step  # simulate process death mid-run
            state = fake_update(state, step + 1)
            self.wal.commit_step(StepRecord(
                step + 1, (step + 1) * 4096, (0, step + 1),
                float(1.0 / (step + 1)), 0.1, 1.0))
            if (step + 1) % self.ckpt_every == 0:
                self.manager.save(step + 1, state)
        return state, start_step + n_steps


def test_train_crash_resume_exact_state(tmp_path):
    wal_pm = PMem(TrainWAL.capacity_for(1000))
    wal_pm.memset_zero()
    t = MiniTrainer(str(tmp_path / "ckpt.pmem"), wal_pm, ckpt_every=5)
    state = fake_train_state(0)
    t.manager.save(0, state)

    # run 12 steps then 'crash' (checkpoints at 5, 10; WAL through 12)
    state, _ = t.run(state, 0, 12)
    wal_pm.crash(evict=lambda li: False)

    # --- restart ---
    m2 = CheckpointManager(str(tmp_path / "ckpt.pmem"), CFG)
    ckpt_step, restored = m2.restore()
    assert ckpt_step == 10
    np.testing.assert_array_equal(restored["params"],
                                  fake_train_state(10)["params"])
    wal2 = TrainWAL(wal_pm, 0, wal_pm.size, recover=True)
    assert wal2.last.step == 12          # WAL is ahead of the checkpoint
    assert wal2.last.data_cursor == 12 * 4096
    # deterministic replay: fast-forward from ckpt_step to wal.last.step
    replay_state = dict(restored)
    for s in range(ckpt_step, wal2.last.step):
        replay_state = fake_update(replay_state, s + 1)
    np.testing.assert_array_equal(replay_state["params"],
                                  fake_train_state(12)["params"])


def test_wal_and_checkpoint_disagree_gracefully(tmp_path):
    """Crash right after a checkpoint but before its WAL record would be
    an ordering bug; our ordering (WAL first, checkpoint after) means the
    WAL step is always >= checkpoint step."""
    wal_pm = PMem(TrainWAL.capacity_for(1000))
    wal_pm.memset_zero()
    t = MiniTrainer(str(tmp_path / "ckpt.pmem"), wal_pm, ckpt_every=3)
    state = fake_train_state(0)
    t.manager.save(0, state)
    state, _ = t.run(state, 0, 7)
    wal_pm.crash(evict=lambda li: False)
    m2 = CheckpointManager(str(tmp_path / "ckpt.pmem"), CFG)
    ckpt_step, _ = m2.restore()
    wal2 = TrainWAL(wal_pm, 0, wal_pm.size, recover=True)
    assert wal2.last.step >= ckpt_step


def test_repeated_crash_recovery_cycles(tmp_path):
    """Several crash/restart cycles in a row never lose committed work."""
    wal_pm = PMem(TrainWAL.capacity_for(1000))
    wal_pm.memset_zero()
    path = str(tmp_path / "ckpt.pmem")
    t = MiniTrainer(path, wal_pm, ckpt_every=2)
    state = fake_train_state(0)
    t.manager.save(0, state)
    step = 0
    for cycle in range(3):
        state, step = t.run(state, step, 4)
        wal_pm.crash(rng=np.random.default_rng(cycle), evict_prob=0.5)
        m = CheckpointManager(path, CFG)
        ckpt_step, restored = m.restore()
        wal = TrainWAL(wal_pm, 0, wal_pm.size, recover=True)
        assert ckpt_step % 2 == 0 and ckpt_step <= step
        assert wal.last.step == step
        np.testing.assert_array_equal(
            restored["params"], fake_train_state(ckpt_step)["params"])
        # resume from the recovered checkpoint + WAL replay
        state = dict(restored)
        for s in range(ckpt_step, step):
            state = fake_update(state, s + 1)
        t = MiniTrainer(path, wal_pm, ckpt_every=2)
        t.manager.restore()
        t.wal = TrainWAL(wal_pm, 0, wal_pm.size, recover=True)
