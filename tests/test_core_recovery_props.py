"""Crash properties (hypothesis): every committed put survives an
arbitrary crash point and eviction subset, for every logging technique;
and a lane-partitioned MultiLog recovers a consistent global-LSN prefix
from ANY durable-line subset (cross-lane recovery, repro.io engine).

Requires the ``test`` extra; deterministic engine tests live in
``test_core_recovery.py`` and ``test_io_engine.py``.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import KVConfig, PMem, PersistentKV
from repro.io import MultiLog
from repro.pool import Pool


def make_kv(technique="zero", **kw):
    kw.setdefault("log_capacity", 1 << 15)
    cfg = KVConfig(npages=4, page_size=1024, value_size=64,
                   technique=technique, **kw)
    pm = PMem(PersistentKV.region_bytes(cfg))
    pm.memset_zero()
    return pm, PersistentKV(pm, cfg), cfg


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    technique=st.sampled_from(["classic", "header", "zero"]),
    ops=st.lists(st.tuples(st.integers(0, 63), st.integers(0, 10**6)),
                 min_size=1, max_size=40),
    ckpt_every=st.sampled_from([0, 7, 13]),
    seed=st.integers(0, 2**31 - 1),
    prob=st.sampled_from([0.0, 0.4, 1.0]),
)
def test_kv_crash_property(technique, ops, ckpt_every, seed, prob):
    """Every committed put survives an arbitrary crash; recovered values are
    exactly the last committed value per key."""
    pm, kv, cfg = make_kv(technique)
    expected = {}
    for i, (k, v) in enumerate(ops):
        value = bytes([(v + j) % 256 for j in range(64)])
        kv.put(k, value)
        expected[k] = value
        if ckpt_every and (i + 1) % ckpt_every == 0:
            kv.checkpoint()
    pm.crash(rng=np.random.default_rng(seed), evict_prob=prob)
    kv2 = PersistentKV.open(pm, cfg)
    for k, value in expected.items():
        assert kv2.get(k) == value


# ===================================================== cross-lane recovery

@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    technique=st.sampled_from(["classic", "header", "zero"]),
    lanes=st.integers(1, 5),
    group_commit=st.integers(1, 9),
    n_entries=st.integers(0, 40),
    commit_after=st.sets(st.integers(0, 39)),
    seed=st.integers(0, 2**31 - 1),
    prob=st.sampled_from([0.0, 0.3, 0.7, 1.0]),
)
def test_multilog_crash_recovers_global_lsn_prefix(
        technique, lanes, group_commit, n_entries, commit_after, seed, prob):
    """Cross-lane crash property: whatever durable-line subset a crash
    leaves behind, a MultiLog recovers entries forming EXACTLY the global
    LSNs 1..m, with correct payloads, covering at least every entry
    appended before the last full commit(); and the repaired log accepts
    new appends that extend the prefix with no duplicate LSNs."""
    pool = Pool.create(None, 1 << 21)
    ml = MultiLog(pool, "ml", lanes=lanes, capacity=1 << 19,
                  technique=technique, group_commit=group_commit)
    payloads = {}
    committed_through = 0
    for i in range(n_entries):
        glsn = ml.append(b"payload-%04d-%d" % (i, seed % 97))
        payloads[glsn] = b"payload-%04d-%d" % (i, seed % 97)
        if i in commit_after:
            ml.commit()
            committed_through = glsn
    pool.pmem.crash(rng=np.random.default_rng(seed), evict_prob=prob)

    pool2 = Pool.open(pmem=pool.pmem)
    ml2 = MultiLog(pool2, "ml")
    rec = ml2.recovered
    m = len(rec.glsns)
    assert rec.glsns == list(range(1, m + 1))          # contiguous prefix
    assert m >= committed_through                       # commits survive
    for glsn, payload in zip(rec.glsns, rec.entries):
        assert payload == payloads[glsn]
    # appending continues cleanly after the truncation repair
    new_glsn = ml2.append(b"post-crash", sync=True)
    assert new_glsn == m + 1
    rec2 = ml2.recover()
    assert rec2.glsns == list(range(1, m + 2))
    assert rec2.entries[-1] == b"post-crash"
