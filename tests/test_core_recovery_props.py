"""Crash properties (hypothesis): every committed put survives an
arbitrary crash point and eviction subset, for every logging technique;
and a lane-partitioned MultiLog recovers a consistent global-LSN prefix
from ANY durable-line subset (cross-lane recovery, repro.io engine).

The property *bodies* live in ``tests/corpus_runner.py`` and are shared
with the deterministic regression corpus (``test_crash_corpus.py``),
which replays checked-in seeds through them without hypothesis. This
file is the randomized search on top (requires the ``test`` extra);
deterministic engine tests live in ``test_core_recovery.py`` and
``test_io_engine.py``.
"""

import pytest

pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from corpus_runner import run_kv_crash, run_multilog_crash


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    technique=st.sampled_from(["classic", "header", "zero"]),
    ops=st.lists(st.tuples(st.integers(0, 63), st.integers(0, 10**6)),
                 min_size=1, max_size=40),
    ckpt_every=st.sampled_from([0, 7, 13]),
    seed=st.integers(0, 2**31 - 1),
    prob=st.sampled_from([0.0, 0.4, 1.0]),
)
def test_kv_crash_property(technique, ops, ckpt_every, seed, prob):
    """Every committed put survives an arbitrary crash; recovered values are
    exactly the last committed value per key."""
    run_kv_crash(technique, ops, ckpt_every, seed, prob)


# ===================================================== cross-lane recovery

@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    technique=st.sampled_from(["classic", "header", "zero"]),
    lanes=st.integers(1, 5),
    group_commit=st.integers(1, 9),
    n_entries=st.integers(0, 40),
    commit_after=st.sets(st.integers(0, 39)),
    seed=st.integers(0, 2**31 - 1),
    prob=st.sampled_from([0.0, 0.3, 0.7, 1.0]),
)
def test_multilog_crash_recovers_global_lsn_prefix(
        technique, lanes, group_commit, n_entries, commit_after, seed, prob):
    """Cross-lane crash property: whatever durable-line subset a crash
    leaves behind, a MultiLog recovers entries forming EXACTLY the global
    LSNs 1..m, with correct payloads, covering at least every entry
    appended before the last full commit(); and the repaired log accepts
    new appends that extend the prefix with no duplicate LSNs."""
    run_multilog_crash(technique, lanes, group_commit, n_entries,
                       commit_after, seed, prob)
