"""KV-engine crash property (hypothesis): every committed put survives an
arbitrary crash point and eviction subset, for every logging technique.

Requires the ``test`` extra; deterministic engine tests live in
``test_core_recovery.py``.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import KVConfig, PMem, PersistentKV


def make_kv(technique="zero", **kw):
    kw.setdefault("log_capacity", 1 << 15)
    cfg = KVConfig(npages=4, page_size=1024, value_size=64,
                   technique=technique, **kw)
    pm = PMem(PersistentKV.region_bytes(cfg))
    pm.memset_zero()
    return pm, PersistentKV(pm, cfg), cfg


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    technique=st.sampled_from(["classic", "header", "zero"]),
    ops=st.lists(st.tuples(st.integers(0, 63), st.integers(0, 10**6)),
                 min_size=1, max_size=40),
    ckpt_every=st.sampled_from([0, 7, 13]),
    seed=st.integers(0, 2**31 - 1),
    prob=st.sampled_from([0.0, 0.4, 1.0]),
)
def test_kv_crash_property(technique, ops, ckpt_every, seed, prob):
    """Every committed put survives an arbitrary crash; recovered values are
    exactly the last committed value per key."""
    pm, kv, cfg = make_kv(technique)
    expected = {}
    for i, (k, v) in enumerate(ops):
        value = bytes([(v + j) % 256 for j in range(64)])
        kv.put(k, value)
        expected[k] = value
        if ckpt_every and (i + 1) % ckpt_every == 0:
            kv.checkpoint()
    pm.crash(rng=np.random.default_rng(seed), evict_prob=prob)
    kv2 = PersistentKV.open(pm, cfg)
    for k, value in expected.items():
        assert kv2.get(k) == value
