"""Log-writer tests: barrier counts, recovery, log-full handling.

The hypothesis crash-atomicity properties live in
``test_core_log_props.py`` (skipped without the ``test`` extra)."""

import pytest

from repro.core import (
    ClassicLog,
    HeaderLog,
    LOG_TECHNIQUES,
    LogConfig,
    PMem,
    ZeroLog,
)

CAP = 1 << 16


def fresh(technique, **cfg_kw):
    pm = PMem(CAP)
    pm.memset_zero()
    cls = LOG_TECHNIQUES[technique]
    return pm, cls(pm, 0, CAP, LogConfig(**cfg_kw))


# ----------------------------------------------------------------- barriers

@pytest.mark.parametrize(
    "technique,expected", [("classic", 2), ("header", 2), ("zero", 1)]
)
def test_barriers_per_append(technique, expected):
    """The paper's central count: Zero needs ONE persistency barrier."""
    pm, log = fresh(technique)
    log.append(b"payload-0")
    before = pm.stats.barriers
    log.append(b"payload-1")
    assert pm.stats.barriers - before == expected
    assert log.BARRIERS_PER_APPEND == expected


def test_header_same_line_rewrites_vs_dancing():
    """Header's size field rewrites the same cache line every append; with
    64 dancing fields the rewrites disappear (§3.3.2)."""
    pm, log = fresh("header", dancing=1)
    for i in range(8):
        log.append(b"x" * 32)
    naive_same = pm.stats.same_line_nt
    pm2, log2 = fresh("header", dancing=64)
    for i in range(8):
        log2.append(b"x" * 32)
    assert pm2.stats.same_line_nt == 0
    assert naive_same >= 7


def test_unpadded_entries_rewrite_boundary_lines():
    pm, log = fresh("zero", pad_to_line=False)
    for _ in range(8):
        log.append(b"y" * 10)   # entries share cache lines
    assert pm.stats.same_line_nt > 0
    pm2, log2 = fresh("zero", pad_to_line=True)
    for _ in range(8):
        log2.append(b"y" * 10)
    assert pm2.stats.same_line_nt == 0


# ----------------------------------------------------------------- recovery

@pytest.mark.parametrize("technique", ["classic", "header", "zero"])
@pytest.mark.parametrize("padded", [True, False])
def test_recover_all_after_clean_run(technique, padded):
    pm, log = fresh(technique, pad_to_line=padded)
    payloads = [bytes([i]) * (5 + 7 * i) for i in range(10)]
    for p in payloads:
        log.append(p)
    cls = LOG_TECHNIQUES[technique]
    rec = cls.recover(pm, 0, CAP, log.cfg)
    assert rec.entries == payloads
    assert rec.lsns == list(range(1, 11))
    assert rec.next_lsn == 11


@pytest.mark.parametrize("technique", ["classic", "header", "zero"])
def test_open_for_append_continues(technique):
    pm, log = fresh(technique)
    log.append(b"one")
    log.append(b"two")
    cls = LOG_TECHNIQUES[technique]
    w, rec = cls.open_for_append(pm, 0, CAP, log.cfg)
    assert rec.entries == [b"one", b"two"]
    w.append(b"three")
    rec2 = cls.recover(pm, 0, CAP, log.cfg)
    assert rec2.entries == [b"one", b"two", b"three"]


def test_log_full():
    pm = PMem(1024)
    pm.memset_zero()
    log = ZeroLog(pm, 0, 1024, LogConfig())
    with pytest.raises(RuntimeError):
        for _ in range(100):
            log.append(b"z" * 64)


def test_zero_log_single_barrier_total():
    """End to end: N appends on Zero = exactly N barriers."""
    pm, log = fresh("zero")
    for i in range(50):
        log.append(bytes([i]) * 40)
    assert pm.stats.barriers == 50
