"""Log-writer tests: barrier counts, recovery, crash atomicity (hypothesis)."""

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import (
    ClassicLog,
    HeaderLog,
    LOG_TECHNIQUES,
    LogConfig,
    PMem,
    ZeroLog,
)

CAP = 1 << 16


def fresh(technique, **cfg_kw):
    pm = PMem(CAP)
    pm.memset_zero()
    cls = LOG_TECHNIQUES[technique]
    return pm, cls(pm, 0, CAP, LogConfig(**cfg_kw))


# ----------------------------------------------------------------- barriers

@pytest.mark.parametrize(
    "technique,expected", [("classic", 2), ("header", 2), ("zero", 1)]
)
def test_barriers_per_append(technique, expected):
    """The paper's central count: Zero needs ONE persistency barrier."""
    pm, log = fresh(technique)
    log.append(b"payload-0")
    before = pm.stats.barriers
    log.append(b"payload-1")
    assert pm.stats.barriers - before == expected
    assert log.BARRIERS_PER_APPEND == expected


def test_header_same_line_rewrites_vs_dancing():
    """Header's size field rewrites the same cache line every append; with
    64 dancing fields the rewrites disappear (§3.3.2)."""
    pm, log = fresh("header", dancing=1)
    for i in range(8):
        log.append(b"x" * 32)
    naive_same = pm.stats.same_line_nt
    pm2, log2 = fresh("header", dancing=64)
    for i in range(8):
        log2.append(b"x" * 32)
    assert pm2.stats.same_line_nt == 0
    assert naive_same >= 7


def test_unpadded_entries_rewrite_boundary_lines():
    pm, log = fresh("zero", pad_to_line=False)
    for _ in range(8):
        log.append(b"y" * 10)   # entries share cache lines
    assert pm.stats.same_line_nt > 0
    pm2, log2 = fresh("zero", pad_to_line=True)
    for _ in range(8):
        log2.append(b"y" * 10)
    assert pm2.stats.same_line_nt == 0


# ----------------------------------------------------------------- recovery

@pytest.mark.parametrize("technique", ["classic", "header", "zero"])
@pytest.mark.parametrize("padded", [True, False])
def test_recover_all_after_clean_run(technique, padded):
    pm, log = fresh(technique, pad_to_line=padded)
    payloads = [bytes([i]) * (5 + 7 * i) for i in range(10)]
    for p in payloads:
        log.append(p)
    cls = LOG_TECHNIQUES[technique]
    rec = cls.recover(pm, 0, CAP, log.cfg)
    assert rec.entries == payloads
    assert rec.lsns == list(range(1, 11))
    assert rec.next_lsn == 11


@pytest.mark.parametrize("technique", ["classic", "header", "zero"])
def test_open_for_append_continues(technique):
    pm, log = fresh(technique)
    log.append(b"one")
    log.append(b"two")
    cls = LOG_TECHNIQUES[technique]
    w, rec = cls.open_for_append(pm, 0, CAP, log.cfg)
    assert rec.entries == [b"one", b"two"]
    w.append(b"three")
    rec2 = cls.recover(pm, 0, CAP, log.cfg)
    assert rec2.entries == [b"one", b"two", b"three"]


def test_log_full():
    pm = PMem(1024)
    pm.memset_zero()
    log = ZeroLog(pm, 0, 1024, LogConfig())
    with pytest.raises(RuntimeError):
        for _ in range(100):
            log.append(b"z" * 64)


# ------------------------------------------------- crash atomicity property
#
# For ANY sequence of appends, crash point, and ANY subset of in-flight
# cache lines that the hardware happened to evict, recovery must return a
# strict prefix of the appended entries containing at least every entry
# whose append() completed before the crash.

@st.composite
def crash_scenario(draw):
    technique = draw(st.sampled_from(["classic", "header", "zero"]))
    padded = draw(st.booleans())
    n_complete = draw(st.integers(0, 12))
    payloads = draw(
        st.lists(
            st.binary(min_size=1, max_size=200),
            min_size=n_complete + 1,
            max_size=n_complete + 1,
        )
    )
    evict_seed = draw(st.integers(0, 2**31 - 1))
    evict_prob = draw(st.sampled_from([0.0, 0.25, 0.5, 0.75, 1.0]))
    return technique, padded, n_complete, payloads, evict_seed, evict_prob


@settings(max_examples=120, deadline=None, suppress_health_check=[HealthCheck.too_slow])
@given(crash_scenario())
def test_crash_recovery_prefix_property(scenario):
    technique, padded, n_complete, payloads, seed, prob = scenario
    pm, log = fresh(technique, pad_to_line=padded)
    for p in payloads[:n_complete]:
        log.append(p)
    # the last append is interrupted mid-protocol: perform the stores of a
    # full append but crash before/after an arbitrary fence boundary by
    # simply crashing right after the call with eviction randomness. To
    # model an interruption *inside* the protocol we also sometimes skip
    # the final persist by storing raw bytes.
    interrupted = payloads[n_complete]
    try:
        log.append(interrupted)
    except RuntimeError:
        pass
    rng = np.random.default_rng(seed)
    pm.crash(rng=rng, evict_prob=prob)

    cls = LOG_TECHNIQUES[technique]
    rec = cls.recover(pm, 0, CAP, log.cfg)
    # prefix property: recovered == appended[:k] for some k >= n_complete
    assert len(rec.entries) >= n_complete, "a completed append was lost"
    assert len(rec.entries) <= n_complete + 1
    expected = payloads[: len(rec.entries)]
    assert rec.entries == expected, "recovered entries are not a prefix"
    assert rec.lsns == list(range(1, len(rec.entries) + 1))


@settings(max_examples=60, deadline=None)
@given(
    technique=st.sampled_from(["classic", "header", "zero"]),
    n=st.integers(1, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_completed_appends_survive_full_drop(technique, n, seed):
    """Even if the crash drops EVERY in-flight line, completed appends
    survive — they were behind persist barriers."""
    pm, log = fresh(technique)
    payloads = [bytes([i + 1]) * (1 + i) for i in range(n)]
    for p in payloads:
        log.append(p)
    pm.crash(evict=lambda li: False)
    rec = LOG_TECHNIQUES[technique].recover(pm, 0, CAP, log.cfg)
    assert rec.entries == payloads


def test_zero_log_single_barrier_total():
    """End to end: N appends on Zero = exactly N barriers."""
    pm, log = fresh("zero")
    for i in range(50):
        log.append(bytes([i]) * 40)
    assert pm.stats.barriers == 50
