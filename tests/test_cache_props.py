"""Buffer-manager crash property (hypothesis): DRAM caching is invisible
to recovery.

The cache's whole crash argument is that it adds no durability points:
dirty frames reach PMem only through the flush queue's epoch drains and
promotions fire on the k-th touch of the access stream regardless of
frame residency — so the SAME op stream run with a warm cache and with
``frames=0`` performs the SAME durable-op sequence, and a crash at the
SAME protocol point with the SAME device rngs recovers IDENTICAL state.

The property body lives in ``tests/corpus_runner.py``
(``run_cache_crash``), shared with the deterministic regression corpus
in ``test_crash_corpus.py``. Requires the ``test`` extra; deterministic
cache tests live in ``test_cache.py``.
"""

import pytest

pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from corpus_runner import run_cache_crash

# writes confined to pids 0-7 (an epoch's dirty set must fit the frame
# budget — a clock-evicted dirty frame parks in the queue and shifts the
# drain order a frameless run never sees); reads range over all 16 pids
_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("w"), st.integers(0, 7), st.integers(0, 255)),
        st.tuples(st.just("r"), st.integers(0, 15), st.just(0)),
    ),
    min_size=4, max_size=60,
)


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    frames=st.integers(8, 16),
    admit_k=st.integers(1, 4),
    ops=_OPS,
    epoch_every=st.integers(4, 8),
    crash_step=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
    pmem_prob=st.sampled_from([0.0, 0.5, 1.0]),
    ssd_keep=st.sampled_from([0.0, 0.5, 1.0]),
)
def test_cache_recovery_identical_to_frameless(
        frames, admit_k, ops, epoch_every, crash_step, seed, pmem_prob,
        ssd_keep):
    """Warm cache vs frames=0: identical recovered state under an
    arbitrary crash point, and each run individually correct."""
    run_cache_crash(frames, admit_k, ops, epoch_every, crash_step, seed,
                    pmem_prob, ssd_keep)
