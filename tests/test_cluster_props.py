"""Crash-mid-reshard properties (hypothesis): a view change interrupted
at ANY protocol point recovers each range at exactly its old owner or
exactly its new owner — never both, never neither — and resuming the
view change converges to the target assignment.

The migration protocol orders every range's handoff destination-first
(copy durable images + committed WAL records to the target → flush and
commit there → durable ownership record in the shard map → invalidate
at the source), so the single ownership record is the atomic authority:
crash before it and the source still serves the range; crash after it
and the target does, with recovery's scrub finishing the interrupted
invalidation.

The property body (``run_cluster_crash``) lives in
``tests/corpus_runner.py``, shared with the deterministic regression
corpus in ``test_crash_corpus.py``. Requires the ``test`` extra;
deterministic cluster scenarios live in ``test_cluster_acceptance.py``.
"""

import pytest

pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from corpus_runner import run_cluster_crash


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    shape=st.sampled_from([(2, 3), (3, 2), (2, 4), (4, 2), (3, 4)]),
    n_ops=st.integers(8, 64),
    ckpt=st.sampled_from([0, 8, 10]),
    crash_step=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
    prob=st.sampled_from([0.0, 0.5, 1.0]),
)
def test_reshard_crash_exactly_one_owner(shape, n_ops, ckpt, crash_step,
                                         seed, prob):
    """Run a seeded workload, reshard between arbitrary shard counts,
    crash at an arbitrary protocol step (plus arbitrary device-level
    durability subsets), and assert every range recovers at exactly one
    owner, all committed data stays readable, and ``resume()`` reaches
    the target view without re-migrating flipped ranges."""
    nsh, new = shape
    run_cluster_crash(nsh, new, n_ops, ckpt, crash_step, seed, prob)


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    crash_step=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
    prob=st.sampled_from([0.0, 0.5]),
    ssd_keep=st.sampled_from([0.0, 0.5, 1.0]),
)
def test_reshard_crash_tiered_source(crash_step, seed, prob, ssd_keep):
    """Same property with tiered source engines: migrating a range whose
    pages spilled to SSD reads them back through the spill map, and the
    crash may also drop an arbitrary subset of unflushed SSD writes."""
    run_cluster_crash(3, 4, 48, 8, crash_step, seed, prob,
                      tiered=True, ssd_keep=ssd_keep)


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    shape=st.sampled_from([(2, 3), (2, 4), (4, 2)]),
    ckpt=st.sampled_from([0, 10]),
    crash_step=st.integers(2, 16),
    seed=st.integers(0, 2**31 - 1),
    prob=st.sampled_from([0.0, 0.5]),
)
def test_reshard_crash_no_stale_wal_replay(shape, ckpt, crash_step, seed,
                                           prob):
    """The stale-WAL-residue arm: after the crash + reopen, overwrite
    the still-moving ranges' keys through their recovered owners and
    checkpoint them (new values live only in page images), resume, then
    crash and reopen AGAIN — no record a crash-interrupted copy left in
    a migration target's WAL may replay over the newer images (the
    reopen scrub must fence it)."""
    nsh, new = shape
    run_cluster_crash(nsh, new, 48, ckpt, crash_step, seed, prob,
                      resume_interleave=True)
