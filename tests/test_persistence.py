"""Persistence-layer tests: checkpoint save/restore/delta, WAL, async
flusher overlap, elastic re-sharding, crash consistency of the manifest."""

import numpy as np
import pytest

from repro.core import PMem
from repro.persistence import (
    AsyncFlusher,
    CheckpointConfig,
    CheckpointManager,
    StepRecord,
    TrainWAL,
    assemble_global,
    reshard_state,
)
from repro.persistence.restore import slice_state

# 128 KiB pages (32 × 4 KiB dirty-tracking lines, 8 × 16 KiB write blocks):
# large enough that the hybrid policy has a real µLog-vs-CoW tradeoff.
CFG = CheckpointConfig(page_size=128 * 1024, manifest_capacity=1 << 16)


def make_state(seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return {
        "w_embed": (rng.standard_normal((512, 64)) * scale).astype(np.float32),
        "w_out": (rng.standard_normal((64, 512)) * scale).astype(np.float32),
        "step_count": np.array([7], dtype=np.int64),
    }


# ------------------------------------------------------------- checkpoint

def test_save_restore_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path / "s0.pmem"), CFG)
    state = make_state(0)
    m.save(100, state)
    m2 = CheckpointManager(str(tmp_path / "s0.pmem"), CFG)
    step, got = m2.restore()
    assert step == 100
    for k in state:
        np.testing.assert_array_equal(got[k], state[k])


def test_multiple_saves_restore_latest(tmp_path):
    m = CheckpointManager(str(tmp_path / "s0.pmem"), CFG)
    for i, seed in enumerate([1, 2, 3]):
        m.save(i, make_state(seed))
    step, got = CheckpointManager(str(tmp_path / "s0.pmem"), CFG).restore()
    assert step == 2
    np.testing.assert_array_equal(got["w_embed"], make_state(3)["w_embed"])


def test_delta_save_uses_mulog_for_sparse_change(tmp_path):
    """Shadow-slot deltas: a µLog delta must cover the change since v-1
    (union of the last two saves' dirty sets), so the FIRST sparse save
    after a full rewrite still takes CoW; the SECOND sparse save in a row
    takes the µLog path."""
    m = CheckpointManager(str(tmp_path / "s0.pmem"), CFG)
    r0 = m.save(0, make_state(0))
    assert r0.pages_cow == r0.pages_total  # first save: all CoW
    m.save(1, make_state(1))               # full rewrite: CoW, shadows set
    state2 = {k: v.copy() for k, v in make_state(1).items()}
    state2["w_embed"][0, 0] += 1.0
    r2 = m.save(2, state2)                 # sparse, but union w/ full dirt
    assert r2.pages_clean >= r2.pages_total - 2
    assert r2.pages_cow >= 1
    state3 = {k: v.copy() for k, v in state2.items()}
    state3["w_embed"][0, 1] += 1.0
    r3 = m.save(3, state3)                 # sparse twice in a row → µLog
    assert r3.pages_mulog >= 1, "sparse change should take the µLog path"
    assert r3.blocks_written < r2.blocks_written or r3.pages_mulog >= 1
    # restore gives exactly state3
    step, got = CheckpointManager(str(tmp_path / "s0.pmem"), CFG).restore()
    assert step == 3
    for k in state3:
        np.testing.assert_array_equal(got[k], state3[k])


def test_clean_pages_are_skipped(tmp_path):
    m = CheckpointManager(str(tmp_path / "s0.pmem"), CFG)
    state = make_state(0)
    m.save(0, state)
    r = m.save(1, state)          # identical state
    assert r.pages_clean == r.pages_total
    assert r.pages_cow == 0 and r.pages_mulog == 0
    step, got = CheckpointManager(str(tmp_path / "s0.pmem"), CFG).restore()
    assert step == 1
    np.testing.assert_array_equal(got["w_embed"], state["w_embed"])


def test_restore_then_continue_saving(tmp_path):
    m = CheckpointManager(str(tmp_path / "s0.pmem"), CFG)
    m.save(0, make_state(0))
    m.save(1, make_state(1))
    m2 = CheckpointManager(str(tmp_path / "s0.pmem"), CFG)
    step, got = m2.restore()
    assert step == 1
    m2.save(2, make_state(2))
    step3, got3 = CheckpointManager(str(tmp_path / "s0.pmem"), CFG).restore()
    assert step3 == 2
    np.testing.assert_array_equal(got3["w_out"], make_state(2)["w_out"])


def test_manifest_commit_is_single_barrier(tmp_path):
    """The checkpoint commit point (manifest append) = ONE barrier (Zero)."""
    m = CheckpointManager(str(tmp_path / "s0.pmem"), CFG)
    state = make_state(0)
    m.save(0, state)
    before = m.pmem.stats.barriers
    m.manifest.append(b'{"probe": true}')
    assert m.pmem.stats.barriers - before == 1


def test_crash_before_manifest_commit_restores_previous(tmp_path):
    """Pages of save N+1 flushed, but manifest not committed → restore N.
    This is the shadow-slot guarantee: save N's pages are never touched
    while manifest N is the last committed one."""
    m = CheckpointManager(str(tmp_path / "s0.pmem"), CFG)
    s0, s1 = make_state(10), make_state(11)
    m.save(0, s0)
    # replicate save(1) page flushing WITHOUT the manifest append
    for name in sorted(s1):
        per_page, buf, _counts = m._dirty_lines_per_page(name, s1[name])
        pages = m._leaf_pages[name]
        from repro.persistence.checkpoint import SaveReport
        rep = SaveReport(step=1)
        for i, pid in enumerate(pages):
            lo = i * CFG.page_size
            page = np.zeros(CFG.page_size, dtype=np.uint8)
            chunk = buf[lo : lo + CFG.page_size]
            page[: chunk.size] = chunk
            dirty = set(range(CFG.blocks_per_page)) if per_page is None else per_page.get(i, set())
            if dirty or per_page is None:
                m._flush_page(pid, page, sorted(dirty), per_page is None, rep)
    m.pmem.fsync()
    # crash: drop every in-flight line (nothing was mid-flush anyway)
    m.pmem.crash(evict=lambda li: False)
    step, got = CheckpointManager(str(tmp_path / "s0.pmem"), CFG).restore()
    assert step == 0
    for k in s0:
        np.testing.assert_array_equal(got[k], s0[k])


def test_fused_and_staged_pipelines_agree_end_to_end(tmp_path):
    """The fused flush_pack scan and the staged chain route every page
    identically (same CoW/µLog/clean split), restore byte-identical
    state — and the fused save reads the live bytes once where staged
    reads them up to three times, which engine_time_ns must credit."""
    import dataclasses
    reports = {}
    for impl in ("fused", "staged"):
        cfg = dataclasses.replace(CFG, kernel_impl=impl)
        m = CheckpointManager(str(tmp_path / f"{impl}.pmem"), cfg)
        m.save(0, make_state(0))
        m.save(1, make_state(1))               # full rewrite
        s2 = {k: v.copy() for k, v in make_state(1).items()}
        s2["w_embed"][0, 0] += 1.0             # sparse delta save
        reports[impl] = m.save(2, s2)
        step, got = CheckpointManager(str(tmp_path / f"{impl}.pmem"), cfg).restore()
        assert step == 2
        for k in s2:
            np.testing.assert_array_equal(got[k], s2[k])
    rf, rs = reports["fused"], reports["staged"]
    assert (rf.pages_cow, rf.pages_mulog, rf.pages_clean) == \
        (rs.pages_cow, rs.pages_mulog, rs.pages_clean)
    assert rf.blocks_written == rs.blocks_written
    # the tentpole claim: ≥2x fewer device bytes read per delta save
    assert rs.scan_read_bytes >= 2 * rf.scan_read_bytes > 0
    assert rf.scan_ns < rs.scan_ns
    assert rf.modeled_ns < rs.modeled_ns


def test_fused_full_rewrite_when_delta_disabled(tmp_path):
    """delta=False: every save takes the full-rewrite path, and the scan
    accounting is exactly one popcount pass over the live bytes."""
    import dataclasses
    cfg = dataclasses.replace(CFG, delta=False, kernel_impl="fused")
    m = CheckpointManager(str(tmp_path / "s0.pmem"), cfg)
    state = make_state(4)
    m.save(0, state)
    r = m.save(1, state)                       # identical state: still CoW
    assert r.pages_cow == r.pages_total and r.pages_mulog == 0
    assert r.scan_read_bytes == r.bytes_logical
    step, got = CheckpointManager(str(tmp_path / "s0.pmem"), cfg).restore()
    assert step == 1
    for k in state:
        np.testing.assert_array_equal(got[k], state[k])


# -------------------------------------------------------------------- WAL

def test_wal_zero_single_barrier_per_step():
    pm = PMem(TrainWAL.capacity_for(100))
    pm.memset_zero()
    wal = TrainWAL(pm, 0, pm.size, technique="zero")
    before = pm.stats.barriers           # pool setup cost is off the path
    for s in range(20):
        wal.commit_step(StepRecord(s, s * 256, (1, 2), 1.5, 0.1, 1.0))
    assert pm.stats.barriers - before == 20
    assert wal.barriers_per_step() == 1


@pytest.mark.parametrize("technique,barriers", [("classic", 2), ("header", 2)])
def test_wal_baselines_cost_more(technique, barriers):
    pm = PMem(TrainWAL.capacity_for(100))
    pm.memset_zero()
    wal = TrainWAL(pm, 0, pm.size, technique=technique)
    before = pm.stats.barriers
    for s in range(10):
        wal.commit_step(StepRecord(s, s, (0, 0), 0.0, 0.0, 1.0))
    assert pm.stats.barriers - before == 10 * barriers


def test_wal_recovery_resume_point():
    pm = PMem(TrainWAL.capacity_for(100))
    pm.memset_zero()
    wal = TrainWAL(pm, 0, pm.size)
    for s in range(7):
        wal.commit_step(StepRecord(s, s * 1024, (s, s + 1), float(s), 0.5, 2.0))
    pm.crash(evict=lambda li: False)
    wal2 = TrainWAL(pm, 0, pm.size, recover=True)
    assert wal2.last.step == 6
    assert wal2.last.data_cursor == 6 * 1024
    assert wal2.last.rng_key == (6, 7)
    # appends continue after recovery
    wal2.commit_step(StepRecord(7, 7 * 1024, (7, 8), 7.0, 0.5, 2.0))
    wal3 = TrainWAL(pm, 0, pm.size, recover=True)
    assert [r.step for r in wal3.records] == list(range(8))


# ---------------------------------------------------------------- flusher

def test_async_flusher_overlap_and_order(tmp_path):
    m = CheckpointManager(str(tmp_path / "s0.pmem"), CFG)
    fl = AsyncFlusher(m, max_pending=2)
    states = [make_state(s) for s in range(4)]
    for i, st in enumerate(states):
        fl.submit(i, st)
    reports = fl.close()
    assert [r.step for r in reports] == [0, 1, 2, 3]
    step, got = CheckpointManager(str(tmp_path / "s0.pmem"), CFG).restore()
    assert step == 3
    np.testing.assert_array_equal(got["w_embed"], states[3]["w_embed"])


def test_async_flusher_staging_isolates_mutation(tmp_path):
    """Training may mutate the live state right after submit(); the staged
    copy must be what lands on disk."""
    m = CheckpointManager(str(tmp_path / "s0.pmem"), CFG)
    fl = AsyncFlusher(m)
    state = make_state(1)
    snapshot = {k: v.copy() for k, v in state.items()}
    fl.submit(0, state)
    state["w_embed"][:] = -1.0    # mutate immediately
    fl.close()
    _, got = CheckpointManager(str(tmp_path / "s0.pmem"), CFG).restore()
    np.testing.assert_array_equal(got["w_embed"], snapshot["w_embed"])


# ----------------------------------------------------------------- elastic

def test_slice_assemble_roundtrip():
    g = make_state(5)
    shards = slice_state(g, 4)
    states = [s for s, _ in shards]
    specs = [sp for _, sp in shards]
    back = assemble_global(states, specs)
    for k in g:
        np.testing.assert_array_equal(back[k], g[k])


def test_elastic_reshard_4_to_2(tmp_path):
    """4 shard regions on disk → restore → re-shard to 2 (elastic shrink)."""
    g = make_state(9)
    shards = slice_state(g, 4)
    for i, (st, spec) in enumerate(shards):
        mgr = CheckpointManager(str(tmp_path / f"s{i}.pmem"), CFG, shard_id=i)
        mgr.save(50, st)
    # recover all shards, assemble, re-shard
    states, specs = [], []
    for i, (_, spec) in enumerate(shards):
        mgr = CheckpointManager(str(tmp_path / f"s{i}.pmem"), CFG, shard_id=i)
        step, st = mgr.restore()
        assert step == 50
        states.append(st)
        specs.append(spec)
    global_state = assemble_global(states, specs)
    new_shards = reshard_state(global_state, 2)
    assert len(new_shards) == 2
    merged = assemble_global([s for s, _ in new_shards], [sp for _, sp in new_shards])
    for k in g:
        np.testing.assert_array_equal(merged[k], g[k])
