"""Distribution-layer tests: sharding rules, gradient compression with
error feedback, straggler policies, elastic fault tolerance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.compression import (
    CompressionConfig,
    compress_grads,
    compressed_bytes_ratio,
    init_error_state,
)
from repro.cluster.membership import BackupStepPolicy, HeartbeatRegistry
from repro.distributed.fault_tolerance import ElasticCoordinator
from repro.distributed.sharding import batch_specs, param_spec, state_specs
from repro.distributed.straggler import QuorumPolicy


# ------------------------------------------------------------- sharding

class FakeMesh:
    """Stand-in with the production mesh's geometry (the sharding rules
    consume only .shape and .axis_names; real meshes need 512 devices)."""

    def __init__(self, multi_pod=True):
        if multi_pod:
            self.shape = {"pod": 2, "data": 16, "model": 16}
        else:
            self.shape = {"data": 16, "model": 16}
        self.axis_names = tuple(self.shape)


def test_param_specs_divisibility():
    """Every assigned arch's parameter tree gets specs whose axes divide
    the production mesh extents (the dry-run would fail otherwise; this is
    the fast unit-level guard)."""
    from repro.configs import ARCH_IDS, get_config
    from repro.models import init_params
    mesh = FakeMesh(multi_pod=True)
    for arch in ARCH_IDS[:4]:
        cfg = get_config(arch)
        params = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))
        specs = state_specs(params, mesh)
        for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_leaves_with_path(params),
                jax.tree_util.tree_leaves_with_path(
                    specs, is_leaf=lambda x: isinstance(x, P))):
            for dim, ax in zip(leaf.shape, spec):
                if ax is None:
                    continue
                ext = np.prod([mesh.shape[a] for a in
                               ((ax,) if isinstance(ax, str) else ax)])
                assert dim % ext == 0, (path, leaf.shape, spec)


def test_batch_specs_replicate_unshardable():
    mesh = FakeMesh(multi_pod=True)
    specs = batch_specs(
        {"tokens": jax.ShapeDtypeStruct((1, 128), jnp.int32)}, mesh)
    assert specs["tokens"] == P(None, None)  # batch=1 can't shard over 32


# ----------------------------------------------------------- compression

@pytest.mark.parametrize("scheme", ["topk", "int8"])
def test_compression_error_feedback_converges(scheme):
    """Compressed-gradient descent on a quadratic still converges thanks to
    error feedback (the residual re-enters the next step)."""
    rng = np.random.default_rng(0)
    target = jnp.asarray(rng.standard_normal(256).astype(np.float32))
    x = jnp.zeros(256)
    cfg = CompressionConfig(scheme=scheme, topk_frac=0.1)
    err = init_error_state({"x": x})
    # EF stability: a coordinate unselected for ~1/frac steps accumulates
    # ~(1/frac)× its gradient, so lr must be ≲ 2·frac for the quadratic.
    lr = 0.1 if scheme == "int8" else 0.05
    for step in range(400):
        g = {"x": x - target}
        cg, err = compress_grads(g, err, cfg, step)
        x = x - lr * cg["x"]
    assert float(jnp.linalg.norm(x - target)) < 0.25 * float(
        jnp.linalg.norm(target))


def test_compression_wire_ratio():
    assert compressed_bytes_ratio(CompressionConfig("int8")) == 0.25
    assert compressed_bytes_ratio(CompressionConfig("topk", topk_frac=0.01)) == 0.02
    assert compressed_bytes_ratio(CompressionConfig("none")) == 1.0


# ------------------------------------------------------------ straggler

def test_backup_step_policy_cordons_persistent_straggler():
    pol = BackupStepPolicy(threshold=1.5, patience=3)
    cordoned = []
    for step in range(6):
        for h in range(8):
            t = 1.0 if h != 3 else 3.0   # host 3 is 3x slower
            pol.observe(h, t)
        cordoned += pol.evaluate()
    assert cordoned == [3]
    # transient slowness does NOT cordon
    pol2 = BackupStepPolicy(threshold=1.5, patience=3)
    for step in range(6):
        for h in range(8):
            t = 3.0 if (h == 2 and step == 1) else 1.0
            pol2.observe(h, t)
        pol2.evaluate()
    assert not pol2.cordoned


def test_quorum_policy():
    pol = QuorumPolicy(quorum_frac=0.75)
    grads = [np.ones(4) * i for i in range(4)]
    grads[3] = None                       # one straggler
    out = pol.combine(grads)
    np.testing.assert_allclose(out, np.ones(4))  # mean of 0,1,2
    with pytest.raises(TimeoutError):
        pol.combine([np.ones(4), None, None, None])


# ------------------------------------------------------- fault tolerance

def test_heartbeat_detection():
    reg = HeartbeatRegistry(deadline_s=5.0)
    for h in range(4):
        reg.beat(h, now=0.0)
    reg.beat(0, now=4.0)
    dead = reg.sweep(now=6.0)
    assert set(dead) == {1, 2, 3}
    assert reg.alive == [0]


def test_elastic_save_restore_shrink(tmp_path):
    """4-shard checkpoint → restore all → re-shard to 2 (elastic shrink)."""
    rng = np.random.default_rng(1)
    g = {"w": rng.standard_normal((64, 16)).astype(np.float32),
         "b": rng.standard_normal((64,)).astype(np.float32)}
    paths = [str(tmp_path / f"s{i}.pmem") for i in range(4)]
    coord = ElasticCoordinator(paths)
    specs = coord.save_sharded(7, g)
    step, new_shards = coord.restore_elastic([0, 1, 2, 3], specs, 2)
    assert step == 7 and len(new_shards) == 2
    from repro.persistence.restore import assemble_global, slice_state
    merged = assemble_global(new_shards,
                             [sp for _, sp in slice_state(g, 2)])
    for k in g:
        np.testing.assert_array_equal(merged[k], g[k])
