"""Deterministic tests for the SSD tier: the device model, the
directory's SSD-backed region kind, the spill scheduler (page eviction /
promotion, generation retirement), MultiLog generations, the tiered
PersistentKV, and the tiered CheckpointManager. Crash *properties* live
in ``test_tier_props.py`` (hypothesis)."""

import numpy as np
import pytest

from repro.core import KIND_SSD, KVConfig, PersistentKV, SSD, SSD_COST_MODEL
from repro.core.ssd import SSDStats
from repro.io.flushq import FlushQueue
from repro.io.multilog import MultiLog
from repro.pool import Pool
from repro.tier import SpillScheduler


# ============================================================ SSD device

def test_ssd_write_read_flush_roundtrip():
    ssd = SSD(1 << 16, block=4096)
    data = np.arange(5000, dtype=np.uint32).view(np.uint8)[: 5000]
    ssd.pwrite(100, data)
    assert bytes(ssd.pread(100, 5000)) == bytes(data)     # sees cached
    assert not bytes(ssd.durable_read(100, 5000)) == bytes(data)
    ssd.flush()
    assert bytes(ssd.durable_read(100, 5000)) == bytes(data)


def test_ssd_counts_blocks_and_rmw():
    ssd = SSD(1 << 16, block=4096)
    ssd.pwrite(0, np.zeros(4096, dtype=np.uint8))         # exactly 1 block
    assert ssd.stats.rmw_blocks == 0
    ssd.pwrite(8192, np.zeros(100, dtype=np.uint8))       # partial block
    assert ssd.stats.rmw_blocks == 1
    ssd.flush()
    assert ssd.stats.blocks_written == 2
    assert ssd.stats.flushes == 1
    ssd.pread(0, 4096 + 1)                                # spans 2 blocks
    assert ssd.stats.blocks_read == 2


def test_ssd_crash_drops_unflushed_subset():
    ssd = SSD(1 << 16, block=4096)
    ssd.pwrite(0, bytes([1]) * 4096)
    ssd.flush()
    ssd.pwrite(0, bytes([2]) * 4096)      # unflushed overwrite
    ssd.pwrite(4096, bytes([3]) * 4096)   # unflushed new block
    survivors = ssd.crash(keep=lambda b: b == 0)
    assert survivors == {0}
    assert bytes(ssd.durable_read(0, 1)) == b"\x02"   # survived
    assert bytes(ssd.durable_read(4096, 1)) == b"\x00"  # dropped


def test_ssd_cost_model_asymmetry():
    """Flash programs cost more per byte than reads (the Fig. 1 write
    asymmetry), and both sit far above PMem's per-op costs."""
    w = SSDStats(writes=1, blocks_written=256)   # 1 MiB programmed
    r = SSDStats(reads=1, blocks_read=256)       # 1 MiB read
    assert SSD_COST_MODEL.time_ns(w) > SSD_COST_MODEL.time_ns(r)
    assert SSD_COST_MODEL.read_ns(4096) > 50_000   # way above PMem's ~100ns


# ============================================= directory KIND_SSD regions

def test_ssd_region_allocate_and_reopen():
    pool = Pool.create(None, 1 << 20)
    ssd = SSD(1 << 22)
    pool.attach_ssd(ssd)
    h = pool.ssd_region("cold", nbytes=8192)
    assert h.record.kind == KIND_SSD
    h.pwrite(0, b"tiered!")
    h.flush()
    pmem_end_before = pool.directory.data_end
    h2 = pool.ssd_region("cold2", nbytes=4096)
    # SSD regions bump the SSD space, never PMem
    assert pool.directory.data_end == pmem_end_before
    assert h2.base == h.base + 8192
    # reopen from the durable directory
    pool2 = Pool.open(pmem=pool.pmem)
    pool2.attach_ssd(ssd)
    h3 = pool2.ssd_region("cold")
    assert bytes(h3.durable_read(0, 7)) == b"tiered!"


def test_ssd_region_requires_attached_device():
    pool = Pool.create(None, 1 << 20)
    with pytest.raises(RuntimeError, match="attach_ssd"):
        pool.ssd_region("cold", nbytes=4096)


def test_ssd_region_bounds_checked():
    pool = Pool.create(None, 1 << 20)
    pool.attach_ssd(SSD(1 << 16))
    h = pool.ssd_region("cold", nbytes=4096)
    with pytest.raises(ValueError):
        h.pwrite(4090, b"x" * 10)


# ======================================================= page spill tier

def _tiered_pages(npages=24, nslots=6, page_size=512):
    pool = Pool.create(None, 1 << 21)
    pool.attach_ssd(SSD(1 << 23))
    sp = SpillScheduler(pool, name="sp", map_capacity=1 << 13)
    pages = pool.pages("heap", npages=npages, page_size=page_size,
                       nslots=nslots)
    sp.attach_pages(pages)
    return pool, sp, pages


def test_overcommitted_epoch_spills_instead_of_raising():
    pool, sp, pages = _tiered_pages()
    fq = FlushQueue(pages, lanes=4, spill=sp)
    rng = np.random.default_rng(0)
    imgs = {pid: rng.integers(0, 256, 512, dtype=np.uint8)
            for pid in range(24)}
    for pid, img in imgs.items():
        fq.enqueue(pid, img)
    rep = fq.flush_epoch()
    assert rep.pages == 24
    assert rep.pages_spilled > 0
    assert rep.spill_ns > 0
    # every page readable from its tier, bit-exact
    for pid, img in imgs.items():
        assert bytes(sp.read_page(pages.store, pid, promote=False)) \
            == bytes(img)


def test_overcommitted_epoch_without_spill_raises():
    pool = Pool.create(None, 1 << 21)
    pages = pool.pages("heap", npages=24, page_size=512, nslots=6)
    fq = FlushQueue(pages, lanes=4)   # no scheduler attached
    for pid in range(24):
        fq.enqueue(pid, np.full(512, pid, dtype=np.uint8))
    with pytest.raises(RuntimeError, match="no free slots"):
        fq.flush_epoch()


def test_promotion_reinstalls_above_ssd_pvn():
    pool, sp, pages = _tiered_pages()
    store = pages.store
    fq = FlushQueue(pages, lanes=2, spill=sp)
    for pid in range(24):
        fq.enqueue(pid, np.full(512, pid % 256, dtype=np.uint8))
    fq.flush_epoch()
    victim = next(iter(sp.spilled_pages(store)))
    spilled_pvn = sp.spilled_pages(store)[victim]
    got = sp.read_page(store, victim, promote=True)
    assert bytes(got) == bytes([victim % 256]) * 512
    assert victim in store.table
    assert store.table[victim][1] > spilled_pvn   # strictly above SSD history
    # the map entry is tombstoned: PMem now owns the page
    assert victim not in sp.spilled_pages(store)


def test_stale_durable_header_loses_to_newer_ssd_copy():
    """A page CoW-flushed twice leaves a stale lower-pvn header in a
    retired slot; after the current slot spills, recovery must pick the
    SSD copy (cross-tier max-pvn), not resurrect the stale header."""
    pool, sp, pages = _tiered_pages(npages=4, nslots=3)
    store = pages.store
    store.flush_cow(0, np.full(512, 1, dtype=np.uint8))   # pvn 1, slot A
    store.flush_cow(0, np.full(512, 2, dtype=np.uint8))   # pvn 2, slot B
    # slot A's header (pid 0, pvn 1) is still durable; now spill pvn 2
    sp.ensure_slots(store, need=store.layout.nslots)
    assert 0 not in store.table
    # a fresh open rebuilds the table from headers: finds the stale pvn 1
    pool2 = Pool.open(pmem=pool.pmem)
    pool2.attach_ssd(pool.ssd_dev)
    sp2 = SpillScheduler(pool2, name="sp")
    pages2 = pool2.pages("heap")
    sp2.attach_pages(pages2)
    got = sp2.read_page(pages2.store, 0, promote=False)
    assert bytes(got) == bytes([2]) * 512   # SSD (pvn 2) wins


def test_spill_map_compaction_keeps_pages_reachable():
    pool = Pool.create(None, 1 << 21)
    pool.attach_ssd(SSD(1 << 23))
    # tiny map: forces double-buffer compaction quickly (live set = up to
    # 20 spilled-page records x 64 B lines = 1280 B; churn overflows 2 KiB)
    sp = SpillScheduler(pool, name="sp", map_capacity=1 << 11)
    pages = pool.pages("heap", npages=24, page_size=512, nslots=4)
    sp.attach_pages(pages)
    fq = FlushQueue(pages, lanes=2, spill=sp)
    rng = np.random.default_rng(0)
    imgs = {}
    for round_ in range(4):
        for pid in range(24):
            imgs[pid] = rng.integers(0, 256, 512, dtype=np.uint8)
            fq.enqueue(pid, imgs[pid])
        fq.flush_epoch()
    assert sp.stats.map_compactions > 0
    for pid, img in imgs.items():
        assert bytes(sp.read_page(pages.store, pid, promote=False)) \
            == bytes(img)
    # and the compacted map replays after a crash
    pool.pmem.crash(evict=lambda li: True)
    pool2 = Pool.open(pmem=pool.pmem)
    pool2.attach_ssd(pool.ssd_dev)
    sp2 = SpillScheduler(pool2, name="sp")
    pages2 = pool2.pages("heap")
    sp2.attach_pages(pages2)
    for pid, img in imgs.items():
        assert bytes(sp2.read_page(pages2.store, pid, promote=False)) \
            == bytes(img)


# ===================================================== MultiLog generations

def test_multilog_generation_roll_and_sources():
    pool = Pool.create(None, 1 << 21)
    pool.attach_ssd(SSD(1 << 22))
    sp = SpillScheduler(pool, name="sp")
    ml = MultiLog(pool, "wal", lanes=2, capacity=1 << 13, gen_sets=2,
                  group_commit=1)
    ml.attach_spill(sp)
    for i in range(4):
        ml.append(b"g1-%d" % i)
    assert ml.generation == 1
    sealed = ml.roll()
    assert sealed == 1 and ml.generation == 2
    assert ml.next_glsn == 1                      # LSNs restart per gen
    ml.append(b"g2-0")
    # sealed generation still PMem-resident until the drain
    src, ents = ml.read_generation(1)
    assert src == "pmem" and ents == [b"g1-%d" % i for i in range(4)]
    assert sp.drain() == 1
    assert ml.retired_upto == 1
    src, ents = ml.read_generation(1)
    assert src == "ssd" and ents == [b"g1-%d" % i for i in range(4)]
    src, ents = ml.read_generation(2)
    assert src == "pmem" and ents == [b"g2-0"]


def test_multilog_roll_without_scheduler_discards_old_ring_slot():
    pool = Pool.create(None, 1 << 21)
    ml = MultiLog(pool, "wal", lanes=2, capacity=1 << 13, gen_sets=2,
                  group_commit=1)
    for g in range(1, 5):
        ml.append(b"gen-%d" % g)
        ml.roll()
    # ring of 2: generations 1..2 were reclaimed (plain truncation)
    assert ml.generation == 5
    assert ml.retired_upto == 3
    with pytest.raises(RuntimeError, match="spill"):
        ml.read_generation(1)


def test_multilog_generational_reopen_after_crash():
    pool = Pool.create(None, 1 << 21)
    ml = MultiLog(pool, "wal", lanes=3, capacity=1 << 13, gen_sets=3,
                  group_commit=1)
    for i in range(3):
        ml.append(b"a%d" % i)
    ml.roll()
    for i in range(2):
        ml.append(b"b%d" % i)
    pool.pmem.crash(evict=lambda li: True)
    pool2 = Pool.open(pmem=pool.pmem)
    ml2 = MultiLog(pool2, "wal")
    assert ml2.generation == 2 and ml2.gen_sets == 3 and ml2.lanes == 3
    assert [bytes(e) for e in ml2.recovered.entries] == [b"b0", b"b1"]
    assert ml2.sealed_generations() == {1: [b"a0", b"a1", b"a2"]}
    # and the ring keeps rolling after recovery
    ml2.append(b"b2")
    ml2.roll()
    assert ml2.generation == 3


def test_sealed_generation_survives_crash_between_roll_and_drain():
    """Regression: a crash landing between roll() and the spill drain
    used to orphan the sealed generation — the reopened log never
    re-enqueued it, so the next ring reuse discarded it while advancing
    the watermark past it. attach_spill now re-enqueues recovered
    sealed-but-unretired generations."""
    pool = Pool.create(None, 1 << 21)
    ssd = SSD(1 << 22)
    pool.attach_ssd(ssd)
    sp = SpillScheduler(pool, name="sp")
    ml = MultiLog(pool, "wal", lanes=2, capacity=1 << 13, gen_sets=2,
                  group_commit=1)
    ml.attach_spill(sp)
    for i in range(3):
        ml.append(b"keep-%d" % i)
    ml.roll()                       # sealed; drain NOT called — crash here
    pool.pmem.crash(evict=lambda li: True)
    ssd.crash(keep=lambda b: True)

    pool2 = Pool.open(pmem=pool.pmem)
    pool2.attach_ssd(ssd)
    sp2 = SpillScheduler(pool2, name="sp")
    ml2 = MultiLog(pool2, "wal")
    ml2.attach_spill(sp2)           # re-enqueues the recovered sealed gen
    ml2.append(b"g2-0")
    ml2.roll()                      # ring reuse would have discarded gen 1
    ml2.append(b"g3-0")
    src, ents = ml2.read_generation(1)
    assert src == "ssd"
    assert [bytes(e) for e in ents] == [b"keep-0", b"keep-1", b"keep-2"]


def test_multilog_reset_truncates_in_place():
    pool = Pool.create(None, 1 << 21)
    ml = MultiLog(pool, "wal", lanes=2, capacity=1 << 13, group_commit=1)
    for i in range(6):
        ml.append(b"x%d" % i)
    ml.reset()
    assert ml.next_glsn == 1
    ml.append(b"fresh", sync=True)
    pool2 = Pool.open(pmem=pool.pmem)
    ml2 = MultiLog(pool2, "wal")
    assert [bytes(e) for e in ml2.recovered.entries] == [b"fresh"]


def test_resident_reflush_epoch_spills_nothing():
    """An epoch that only re-flushes already-resident pages (µLog deltas
    / in-place CoW churn) needs no new slots and must not feed the SSD."""
    pool = Pool.create(None, 1 << 21)
    pool.attach_ssd(SSD(1 << 23))
    sp = SpillScheduler(pool, name="sp")
    pages = pool.pages("heap", npages=24, page_size=512, nslots=32)
    sp.attach_pages(pages)
    fq = FlushQueue(pages, lanes=2, spill=sp)
    for pid in range(24):
        fq.enqueue(pid, np.full(512, pid, dtype=np.uint8))
    fq.flush_epoch()
    assert sp.stats.pages_spilled == 0   # everything fits
    for pid in range(24):                # second epoch: pure re-flush
        fq.enqueue(pid, np.full(512, pid + 1, dtype=np.uint8),
                   dirty_lines=[0])
    rep = fq.flush_epoch()
    assert rep.pages_spilled == 0 and sp.stats.pages_spilled == 0


def test_promote_evict_churn_reuses_extents():
    """Sustained evict->promote cycles must recycle SSD extents instead
    of growing the arena set until the directory fills."""
    pool, sp, pages = _tiered_pages(npages=8, nslots=4)
    store = pages.store
    fq = FlushQueue(pages, lanes=2, spill=sp)
    for pid in range(8):
        fq.enqueue(pid, np.full(512, pid, dtype=np.uint8))
    fq.flush_epoch()
    arenas_before = len(sp._arenas)
    for cycle in range(300):
        spilled = sp.spilled_pages(store)
        pid = next(iter(spilled))
        sp.read_page(store, pid, promote=True)      # promote...
        sp.ensure_slots(store, need=store.layout.nslots)  # ...and re-evict
    assert len(sp._arenas) == arenas_before
    for pid in range(8):
        assert bytes(sp.read_page(store, pid, promote=False)) \
            == bytes([pid]) * 512


def test_lru_attribution_with_two_stores():
    """Each store's LRU signal is keyed by its own owner name: touching
    pages of store B must not protect (or doom) pages of store A."""
    pool = Pool.create(None, 1 << 21)
    pool.attach_ssd(SSD(1 << 23))
    sp = SpillScheduler(pool, name="sp")
    pa = pool.pages("a", npages=8, page_size=512, nslots=4)
    pb = pool.pages("b", npages=8, page_size=512, nslots=4)
    sp.attach_pages(pa)
    sp.attach_pages(pb)
    fa = FlushQueue(pa, lanes=1, spill=sp)
    fb = FlushQueue(pb, lanes=1, spill=sp)
    for pid in range(3):
        fa.enqueue(pid, np.full(512, pid, dtype=np.uint8))
        fb.enqueue(pid, np.full(512, 100 + pid, dtype=np.uint8))
    fa.flush_epoch()
    fb.flush_epoch()
    # heat up A's page 0 through B's-agnostic touches, then evict from A:
    # the victim must be a cold A page, not page 0
    sp.touch(1, pb.store)
    sp.touch(2, pb.store)
    sp.touch(0, pa.store)
    assert sp.ensure_slots(pa.store, need=1) >= 1
    assert 0 in pa.store.table          # the hot page survived
    assert set(sp.spilled_pages(pb.store)) == set()  # B untouched


def test_kv_group_commit_wal_survives_log_full():
    """Regression: with wal_group_commit > 1 a mid-batch lane-full used
    to poison roll()'s commit; capacity is now reserved at submit, so
    the auto-checkpoint path just rolls."""
    cfg = KVConfig(npages=4, page_size=1024, value_size=64,
                   log_capacity=1 << 12, wal_lanes=2, wal_group_commit=4,
                   wal_gen_sets=2)
    pool = Pool.create(None, PersistentKV.region_bytes(cfg))
    kv = pool.kv("kv", cfg)
    for i in range(300):                 # >> what 4 KiB of WAL holds
        kv.put(i % cfg.nkeys, bytes([i % 256]) * 64)
    assert kv.wal.generation > 1


# ========================================================== tiered KV

def _tiered_kv_cfg():
    # 64 logical pages on 5 PMem slots: the tiered pool is sized by the
    # BUDGET, so the classic sizing (64 + slack slots) cannot fit in it
    return KVConfig(npages=64, page_size=1024, value_size=64,
                    log_capacity=1 << 13, slot_budget=5,
                    wal_lanes=4, wal_gen_sets=2, flush_lanes=4)


def test_kv_acceptance_capacity_and_wal_cycles():
    """The PR's acceptance shape: a working set over the PMem slot budget
    completes via SSD spill (the seed engine cannot even build it), and
    the lane-striped WAL runs >= 3 checkpoint/truncate cycles with a
    bounded PMem log footprint."""
    cfg = _tiered_kv_cfg()
    size = PersistentKV.region_bytes(cfg)

    # seed shape on the same budget: allocation fails
    seed_cfg = KVConfig(npages=64, page_size=1024, value_size=64,
                        log_capacity=1 << 13)
    seed_pool = Pool.create(None, size)
    with pytest.raises((RuntimeError, ValueError)):
        seed_pool.kv("kv", seed_cfg)

    pool = Pool.create(None, size)
    pool.attach_ssd(SSD(1 << 24))
    kv = pool.kv("kv", cfg)
    assert kv.wal.generational and kv.wal.lanes == 4
    rng = np.random.default_rng(0)
    oracle = {}
    wal_regions = {n for n in pool.regions() if n.startswith("kv.wal")}
    for cycle in range(4):
        for _ in range(60):
            k = int(rng.integers(0, cfg.nkeys))
            v = bytes(rng.integers(0, 256, 64, dtype=np.uint8))
            kv.put(k, v)
            oracle[k] = v
        kv.checkpoint()
        # bounded footprint: no new WAL regions ever appear
        assert {n for n in pool.regions()
                if n.startswith("kv.wal")} == wal_regions
    assert kv.wal.generation == 5              # one roll per checkpoint
    assert kv.wal.retired_upto >= 3            # retired to SSD, not leaked
    assert kv._spill.stats.pages_spilled > 0
    for k, v in oracle.items():
        assert kv.get(k) == v


def test_kv_tiered_crash_recovery():
    cfg = _tiered_kv_cfg()
    pool = Pool.create(None, PersistentKV.region_bytes(cfg))
    ssd = SSD(1 << 24)
    pool.attach_ssd(ssd)
    kv = pool.kv("kv", cfg)
    rng = np.random.default_rng(3)
    oracle = {}
    for i in range(150):
        k = int(rng.integers(0, cfg.nkeys))
        v = bytes(rng.integers(0, 256, 64, dtype=np.uint8))
        kv.put(k, v)
        oracle[k] = v
        if i % 50 == 49:
            kv.checkpoint()
    pool.pmem.crash(rng=rng, evict_prob=0.5)
    ssd.crash(rng=rng, keep_prob=0.5)
    pool2 = Pool.open(pmem=pool.pmem)
    pool2.attach_ssd(ssd)
    kv2 = pool2.kv("kv", cfg)
    for k, v in oracle.items():
        assert kv2.get(k) == v


def test_kv_tiered_requires_ssd():
    cfg = _tiered_kv_cfg()
    pool = Pool.create(None, PersistentKV.region_bytes(cfg))
    with pytest.raises(ValueError, match="attach_ssd"):
        pool.kv("kv", cfg)


def test_kv_wal_full_triggers_roll_not_failure():
    """The unbounded-redo-log bug the ISSUE names: a tiny WAL now rolls
    through auto-checkpoint instead of dying once full."""
    cfg = KVConfig(npages=4, page_size=1024, value_size=64,
                   log_capacity=1 << 11, wal_lanes=2, wal_gen_sets=2)
    pool = Pool.create(None, PersistentKV.region_bytes(cfg))
    kv = pool.kv("kv", cfg)
    for i in range(200):                       # >> what 2 KiB of WAL holds
        kv.put(i % cfg.nkeys, bytes([i % 256]) * 64)
    assert kv.wal.generation > 1               # rolled at least once


# ================================================= tiered CheckpointManager

def test_checkpoint_manager_slot_budget_save_restore():
    from repro.persistence.checkpoint import (CheckpointConfig,
                                              CheckpointManager)
    rng = np.random.default_rng(0)
    state = {f"w{i}": rng.standard_normal((32, 32)).astype(np.float32)
             for i in range(6)}
    cfg = CheckpointConfig(page_size=16 * 1024, threads=2,
                           pmem_slot_budget=3)
    mgr = CheckpointManager(None, cfg, ssd=SSD(1 << 26))
    for step in range(3):
        state["w0"] = state["w0"] + 1.0
        rep = mgr.save(step, state)
        assert rep.pages_spilled > 0 or step > 0
    step, restored = mgr.restore()
    assert step == 2
    for k, arr in state.items():
        got = np.asarray(restored[k]).view(np.float32).reshape(arr.shape)
        assert np.array_equal(got, arr), k


# ============================================================ compare tool

def test_bench_compare_flags_regressions(tmp_path):
    import json
    import sys
    sys.path.insert(0, "benchmarks")
    try:
        from compare import compare, load_rows
    finally:
        sys.path.pop(0)
    doc = {"suites": {"s": {"rows": [
        {"name": "a", "us_per_call": 10.0},
        {"name": "b", "us_per_call": 10.0},
        {"name": "label", "us_per_call": 0.0},
    ], "checks": []}}}
    prev = tmp_path / "prev.json"
    prev.write_text(json.dumps(doc))
    doc["suites"]["s"]["rows"][0]["us_per_call"] = 12.0    # +20%
    doc["suites"]["s"]["rows"][1]["us_per_call"] = 10.5    # +5%
    curr = tmp_path / "curr.json"
    curr.write_text(json.dumps(doc))
    reg, imp, lop = compare(load_rows(str(prev)), load_rows(str(curr)), 0.10)
    assert [r[1] for r in reg] == ["a"]
    assert not imp and not lop
