"""Batched serving example: prefill + autoregressive decode with KV caches,
on the decoder-only and the encoder-decoder (whisper) families.

  PYTHONPATH=src python examples/serve_batch.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data import synthetic_batch
from repro.launch.serve import serve_batch
from repro.models import init_params

for arch in ("tinyllama-1.1b", "mamba2-130m"):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.key(0))
    b = synthetic_batch(cfg, 4, 24, cursor=0)
    toks, tps = serve_batch(cfg, params, jnp.asarray(b["tokens"]), gen=12)
    print(f"{cfg.name}: generated {toks.shape} at {tps:.0f} tok/s "
          f"sample={np.asarray(toks[0, :6]).tolist()}")
print("OK")
