"""Multi-tenant serving example: two tenants share one pool — an
interactive point-read tenant and a batch tenant mixing puts + scans —
while the checkpoint shards of a ``repro.configs`` model page through
the cache/spill tiers on the side (the model-state serving scenario).
Everything runs on the modeled clock: the printed percentiles and the
latency histogram come from ``engine_time_ns``, bit-stable from the
seed.

  PYTHONPATH=src python examples/serve_batch.py
"""

from repro.core import KVConfig
from repro.core.recovery import PersistentKV
from repro.core.ssd import SSD
from repro.pool import Pool
from repro.serve import (ModelStateStore, ServeFrontend, SLOConfig,
                         TenantSpec, generate)

cfg = KVConfig(npages=64, page_size=1024, value_size=64,
               log_capacity=1 << 18, slot_budget=16, wal_lanes=2,
               wal_group_commit=2, wal_gen_sets=2, cache_frames=24)
pool = Pool.create(None, 4 * PersistentKV.region_bytes(cfg) + (1 << 23),
                   sockets=2)
pool.attach_ssd(SSD(1 << 24))

tenants = [
    TenantSpec(name="chat", clients=400, rate=20_000.0,
               get_frac=0.9, put_frac=0.1, zipf_s=1.3,
               burst_every_s=0.02, burst_len_s=0.004, burst_x=4.0),
    TenantSpec(name="batch", clients=100, rate=6_000.0, get_frac=0.2,
               put_frac=0.5, scan_frac=0.3, scan_len=8, zipf_s=1.1),
]
fe = ServeFrontend(pool, tenants, cfg,
                   slo=SLOConfig(p99_target_us=2000.0))
for spec in tenants:                       # preload every key
    kv = fe.kv(spec.name)
    for k in range(cfg.nkeys):
        kv.put(k, bytes([k % 256]) * cfg.value_size)
    kv.checkpoint()
fe.set_cache_quota("batch", 8)             # scans can't starve chat

reqs = generate(tenants, nkeys=cfg.nkeys, duration_s=0.05, seed=42)
report = fe.run(reqs)

print(f"served {report.served} of {len(reqs)} requests "
      f"({report.shed} shed) in {report.batches} batches, "
      f"{report.throughput_rps:.0f} req/s modeled")
for spec in tenants:
    s = report.by_tenant[spec.name]
    print(f"  {spec.name:5s}: p50={s.p50_us:8.2f}us p99={s.p99_us:8.2f}us "
          f"p999={s.p999_us:8.2f}us hit={report.hit_ratio[spec.name]:.3f}")

print("\nlatency histogram (all tenants, log buckets):")
rows = report.recorder.histogram(base_us=0.5)
peak = max(c for _, c in rows)
for upper_us, count in rows:
    bar = "#" * max(1, round(40 * count / peak))
    print(f"  <= {upper_us:10.1f}us  {count:6d}  {bar}")

# ---- model-state serving: page one model's shards through the tiers ----
ms = ModelStateStore(pool, "tinyllama-1.1b", name="ms", slot_frac=0.25,
                     seed=7)
tiers = [ms.residency(p) for p in range(ms.npages)]
print(f"\nmodel state: {ms.config.name} -> {ms.npages} pages in "
      f"{ms.num_shards} shards ({tiers.count('pmem')} pmem / "
      f"{tiers.count('ssd')} ssd after populate)")
for shard in (0, 1):                       # embedding + first layer
    assert ms.verify_shard(shard)
    print(f"  shard {shard}: {len(ms.shard_pages(shard))} pages verified "
          f"through the cache")
print("OK")
