"""Quickstart: the public API in one file.

Build a small model, take a training step, commit it to the WAL, flush a
delta checkpoint, crash, recover, and decode a few tokens.

  PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data import SyntheticPipeline
from repro.launch.steps import build_train_step
from repro.models import decode_step, init_caches, init_params
from repro.optim import adamw_init
from repro.persistence import CheckpointConfig, CheckpointManager, StepRecord
from repro.pool import Pool

out = tempfile.mkdtemp(prefix="repro_quickstart_")

# 1. model + optimizer -----------------------------------------------------
cfg = get_reduced("tinyllama-1.1b")
params = init_params(cfg, jax.random.key(0))
opt_state = adamw_init(params)
step_fn = jax.jit(build_train_step(cfg))

# 2. data + one training step ----------------------------------------------
pipe = SyntheticPipeline(cfg, batch=4, seq=64)
batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
params, opt_state, metrics = step_fn(params, opt_state, batch)
print(f"step 1: loss={float(metrics['loss']):.4f} "
      f"grad_norm={float(metrics['grad_norm']):.4f}")

# 3. durable commit: Zero-log WAL = ONE persistency barrier per step --------
# All PMem layout goes through a named pool region — no raw byte offsets.
pool = Pool.create(os.path.join(out, "wal.pmem"), 1 << 20)
wal = pool.wal("train", capacity_steps=1000)
before = pool.stats.barriers
wal.commit_step(StepRecord(1, 1, (0, 0), float(metrics["loss"]), 0.0, 1.0))
print(f"WAL committed step 1 with {pool.stats.barriers - before} barrier(s)")

# 4. checkpoint: CoW+pvn pages, Zero-log manifest ---------------------------
mgr = CheckpointManager(os.path.join(out, "ckpt.pmem"),
                        CheckpointConfig(page_size=128 * 1024))
state = {f"p{i}": np.asarray(l) for i, l in enumerate(jax.tree.leaves(params))}
report = mgr.save(1, state)
print(f"checkpoint: {report.pages_cow} CoW pages, "
      f"{report.barriers} barriers, {report.bytes_device} device bytes")

# 5. crash + recover --------------------------------------------------------
pool.pmem.crash(evict=lambda li: False)   # drop every in-flight line
wal2 = Pool.open(pmem=pool.pmem).wal("train")   # directory + log recovery
step, restored = CheckpointManager(os.path.join(out, "ckpt.pmem"),
                                   CheckpointConfig(page_size=128 * 1024)).restore()
print(f"recovered: checkpoint step {step}, WAL last step {wal2.last.step}")
np.testing.assert_array_equal(restored["p0"], state["p0"])

# 6. decode a few tokens ----------------------------------------------------
caches = init_caches(cfg, batch=2, max_len=8)
toks = jnp.zeros((2, 1), jnp.int32)
for t in range(4):
    logits, caches = decode_step(params, cfg, toks, caches, jnp.int32(t))
    toks = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
print("decoded tokens:", np.asarray(toks).ravel().tolist())
print("OK")
