"""Crash recovery demo: kill training mid-run, restart, verify
exactly-once step semantics (checkpoint + WAL fast-forward).

The Trainer keeps all persistent state in ``repro.pool`` pools (a WAL pool
and a checkpoint pool per run directory); restart re-opens the same named
regions and recovers them.

  PYTHONPATH=src python examples/crash_recovery.py
"""

import tempfile

import numpy as np

from repro.launch.train import Trainer, TrainerConfig

out = tempfile.mkdtemp(prefix="repro_crash_")
tc = TrainerConfig(arch="tinyllama-1.1b", reduced=True, steps=30, batch=4,
                   seq=64, ckpt_every=10, out=out, async_flush=False)

# run 1: crash at step 17 (after the step-10 checkpoint, WAL ahead of it)
t1 = Trainer(tc)
r1 = t1.run(crash_at=17)
print(f"crashed at step {r1['crashed_at']}; "
      f"WAL last committed step = {t1.wal.last.step}")
assert t1.wal.last.step == 17

# run 2: fresh process restores checkpoint@10 and replays deterministically
t2 = Trainer(tc)
assert t2.start_step == 10, t2.start_step
r2 = t2.run()
print(f"resumed from {t2.start_step}, finished {r2['steps']} steps, "
      f"last loss {r2['last_loss']:.4f}")

# reference: an uninterrupted run reaches the same final loss
ref_out = tempfile.mkdtemp(prefix="repro_ref_")
t3 = Trainer(TrainerConfig(arch="tinyllama-1.1b", reduced=True, steps=30,
                           batch=4, seq=64, ckpt_every=10, out=ref_out,
                           async_flush=False))
r3 = t3.run()
np.testing.assert_allclose(r2["last_loss"], r3["last_loss"], rtol=1e-4)
print(f"crash/resume loss == uninterrupted loss ({r3['last_loss']:.4f})  OK")
