"""End-to-end training driver example: a TinyLlama-family model trained on
the synthetic pipeline for a few hundred steps with the full persistence
stack (Zero-log WAL each step, async hybrid CoW/µLog checkpoints).

Default runs a ~25M-param model sized for this CPU container; pass
--full100m for the ~100M variant (same code path, longer wall time).

  PYTHONPATH=src python examples/train_tinyllama.py [--steps 200] [--full100m]
"""

import argparse
import dataclasses
import json
import tempfile

import numpy as np

from repro.configs import get_config
from repro.launch.train import Trainer, TrainerConfig
import repro.configs.tinyllama_1_1b as tl


def model_cfg(full100m: bool):
    base = tl.CONFIG
    if full100m:
        return dataclasses.replace(
            base, name="tinyllama-100m", num_layers=10, d_model=640,
            num_heads=10, num_kv_heads=2, head_dim=64, d_ff=1792,
            vocab_size=32000, tp_heads_multiple=1)
    return dataclasses.replace(
        base, name="tinyllama-25m", num_layers=6, d_model=384,
        num_heads=6, num_kv_heads=2, head_dim=64, d_ff=1024,
        vocab_size=8192, tp_heads_multiple=1, vocab_pad=64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full100m", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = model_cfg(args.full100m)
    n = cfg.param_count()
    print(f"model: {cfg.name}  params={n/1e6:.1f}M")
    out = args.out or tempfile.mkdtemp(prefix="repro_train_")
    tc = TrainerConfig(arch="tinyllama-1.1b", reduced=True, steps=args.steps,
                       batch=args.batch, seq=args.seq, ckpt_every=50,
                       out=out, lr=1e-3)
    t = Trainer(tc)
    t.cfg = cfg                     # swap in the example config
    from repro.launch.steps import build_train_step
    from repro.optim import AdamWConfig, adamw_init
    from repro.models import init_params
    from repro.data import SyntheticPipeline
    import jax
    t.pipeline = SyntheticPipeline(cfg, tc.batch, tc.seq)
    t.step_fn = jax.jit(build_train_step(cfg, AdamWConfig(lr=tc.lr),
                                         total_steps=args.steps))
    t.params = init_params(cfg, jax.random.key(0))
    t.opt_state = adamw_init(t.params)
    report = t.run()
    losses = report["losses"]
    k = max(1, len(losses) // 10)
    print(json.dumps({
        "steps": report["steps"], "wall_s": round(report["wall_s"], 1),
        "loss_first": round(float(np.mean(losses[:k])), 4),
        "loss_last": round(float(np.mean(losses[-k:])), 4),
        "wal_barriers_per_step": report["wal_barriers_per_step"],
    }, indent=1))
    assert np.mean(losses[-k:]) < np.mean(losses[:k]) - 0.3, \
        "loss did not improve"
    print("loss improved  OK")


if __name__ == "__main__":
    main()
