#!/usr/bin/env python
"""Docs gate for CI: fail on (a) public symbols in ``repro.pool``,
``repro.io``, ``repro.tier``, ``repro.cache``, ``repro.serve``,
``repro.kernels`` and ``repro.cluster`` missing docstrings, and
(b) broken intra-repo links in README.md and docs/.

Pure stdlib (ast + re): runs before any dependency is installed.

Usage::

    python tools/check_docs.py            # check everything
    python tools/check_docs.py --docstrings-only
    python tools/check_docs.py --links-only
"""

from __future__ import annotations

import argparse
import ast
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

#: modules whose public API must be fully docstringed
DOC_SCOPES = ["src/repro/pool.py", "src/repro/io", "src/repro/tier",
              "src/repro/cache", "src/repro/serve", "src/repro/kernels",
              "src/repro/cluster"]

#: markdown files whose intra-repo links must resolve
LINK_ROOTS = ["README.md", "docs"]

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def is_public(name: str) -> bool:
    return not name.startswith("_")


def check_docstrings() -> list:
    """Every module, public class, and public function/method in scope
    must carry a docstring. ``__init__`` may lean on its class docstring
    only if it takes no parameters beyond ``self``."""
    problems = []
    files = []
    for scope in DOC_SCOPES:
        p = REPO / scope
        files.extend(sorted(p.rglob("*.py")) if p.is_dir() else [p])
    for path in files:
        rel = path.relative_to(REPO)
        tree = ast.parse(path.read_text(), filename=str(rel))
        if not ast.get_docstring(tree):
            problems.append(f"{rel}: module missing docstring")
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef) and is_public(node.name):
                if not ast.get_docstring(node):
                    problems.append(
                        f"{rel}:{node.lineno}: class {node.name} missing "
                        f"docstring")
                for item in node.body:
                    if not isinstance(item, (ast.FunctionDef,
                                             ast.AsyncFunctionDef)):
                        continue
                    name = item.name
                    if name == "__init__":
                        takes_args = len(item.args.args) > 1 or \
                            item.args.vararg or item.args.kwonlyargs
                        if takes_args and not ast.get_docstring(item):
                            problems.append(
                                f"{rel}:{item.lineno}: "
                                f"{node.name}.__init__ missing docstring")
                        continue
                    if not is_public(name):
                        continue
                    if not ast.get_docstring(item):
                        problems.append(
                            f"{rel}:{item.lineno}: {node.name}.{name} "
                            f"missing docstring")
        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and is_public(node.name) and not ast.get_docstring(node):
                problems.append(
                    f"{rel}:{node.lineno}: function {node.name} missing "
                    f"docstring")
    return problems


def check_links() -> list:
    """Every relative markdown link in README/docs must point at an
    existing file (anchors are checked for file existence only)."""
    problems = []
    files = []
    for root in LINK_ROOTS:
        p = REPO / root
        files.extend(sorted(p.rglob("*.md")) if p.is_dir() else
                     ([p] if p.exists() else []))
    for path in files:
        rel = path.relative_to(REPO)
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            for m in _LINK.finditer(line):
                target = m.group(1)
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                target = target.split("#", 1)[0]
                if not target:
                    continue   # pure in-page anchor
                resolved = (path.parent / target).resolve()
                if not resolved.exists():
                    problems.append(
                        f"{rel}:{lineno}: broken link -> {m.group(1)}")
    return problems


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--docstrings-only", action="store_true")
    ap.add_argument("--links-only", action="store_true")
    args = ap.parse_args()

    problems = []
    if not args.links_only:
        problems += check_docstrings()
    if not args.docstrings_only:
        problems += check_links()
    for p in problems:
        print(p)
    scope = ", ".join(DOC_SCOPES)
    print(f"# checked docstrings in [{scope}] and links in "
          f"[{', '.join(LINK_ROOTS)}]: {len(problems)} problem(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
