"""Distribution: mesh/sharding rules, activation-sharding hooks,
fault tolerance, straggler mitigation, gradient compression."""
