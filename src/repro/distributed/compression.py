"""Gradient compression for the DP all-reduce, with error feedback.

Two composable schemes (off by default; enabled per-config):

  - ``topk``: per-leaf magnitude top-k sparsification. The residual is fed
    back into the next step's gradient (error feedback), which keeps SGD
    convergent (Stich et al.). Compressed payload = k indices + k values →
    the DP collective moves k/(n) of the bytes.
  - ``int8``: symmetric per-leaf int8 quantization with stochastic
    rounding; residual feedback likewise.

On the wire (jax lowering) the compressed representation reduces the
reduce-scatter/all-gather payload of the ``pod`` axis — the slow DCN hop
in the multi-pod mesh. Both schemes are pure pytree→pytree transforms so
they compose with any optimizer.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    scheme: str = "none"          # none | topk | int8
    topk_frac: float = 0.01       # fraction of entries kept
    seed: int = 0


def init_error_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _topk_leaf(g: jax.Array, frac: float) -> Tuple[jax.Array, jax.Array]:
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.shape[0] * frac))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    dense = jnp.zeros_like(flat).at[idx].set(vals)
    return dense.reshape(g.shape), dense.reshape(g.shape)


def _int8_leaf(g: jax.Array, key: jax.Array) -> jax.Array:
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    scaled = gf / scale
    # stochastic rounding
    noise = jax.random.uniform(key, gf.shape) - 0.5
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def compress_grads(grads, err_state, cfg: CompressionConfig,
                   step: jax.Array | int = 0):
    """Returns (compressed_grads, new_error_state).

    Error feedback: e' = (g + e) - C(g + e); the optimizer consumes C(g+e).
    """
    if cfg.scheme == "none":
        return grads, err_state

    leaves, treedef = jax.tree.flatten(grads)
    errs = treedef.flatten_up_to(err_state)

    out_g, out_e = [], []
    key = jax.random.fold_in(jax.random.key(cfg.seed), jnp.asarray(step, jnp.int32))
    for i, (g, e) in enumerate(zip(leaves, errs)):
        acc = g.astype(jnp.float32) + e
        if cfg.scheme == "topk":
            comp, _ = _topk_leaf(acc, cfg.topk_frac)
        elif cfg.scheme == "int8":
            comp = _int8_leaf(acc, jax.random.fold_in(key, i))
        else:
            raise ValueError(cfg.scheme)
        out_g.append(comp.astype(g.dtype))
        out_e.append(acc - comp)
    return treedef.unflatten(out_g), treedef.unflatten(out_e)


def compressed_bytes_ratio(cfg: CompressionConfig) -> float:
    """Wire-bytes ratio vs uncompressed f32 gradients (for the roofline's
    collective term on the pod axis)."""
    if cfg.scheme == "topk":
        return cfg.topk_frac * 2.0   # values + indices
    if cfg.scheme == "int8":
        return 0.25
    return 1.0
