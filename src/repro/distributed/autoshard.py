"""Activation-sharding constraints that degrade gracefully off-mesh.

``constrain(x, ("model", "fsdp", None))`` applies a
``with_sharding_constraint`` when tracing under a mesh, mapping the logical
axis name "fsdp" to whichever data axes the active mesh has
(("pod","data") multi-pod, ("data",) single-pod), and is a no-op when no
mesh is active (smoke tests on a single CPU device).
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


def _active_mesh():
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and not mesh.empty:
            return mesh
    except Exception:
        pass
    try:  # legacy `with mesh:` context (thread resources)
        from jax._src import mesh as _mesh_lib
        phys = _mesh_lib.thread_resources.env.physical_mesh
        if phys is not None and not phys.empty:
            return phys
    except Exception:
        pass
    return None


def resolve_axes(mesh_axes: Sequence[str], logical: Axis) -> Axis:
    """Map logical axis names to the mesh's physical axes."""
    if logical is None:
        return None
    names = (logical,) if isinstance(logical, str) else tuple(logical)
    out = []
    for n in names:
        if n == "fsdp":
            out.extend(a for a in ("pod", "data") if a in mesh_axes)
        elif n in mesh_axes:
            out.append(n)
    if not out:
        return None
    return out[0] if len(out) == 1 else tuple(out)


def spec_for(mesh_axes: Sequence[str], logical_spec: Sequence[Axis]) -> P:
    return P(*(resolve_axes(mesh_axes, ax) for ax in logical_spec))


def constrain(x: jax.Array, logical_spec: Sequence[Axis]) -> jax.Array:
    mesh = _active_mesh()
    if mesh is None:
        return x
    if len(logical_spec) != x.ndim:
        return x
    spec = spec_for(mesh.axis_names, logical_spec)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x
