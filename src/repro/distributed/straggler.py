"""Straggler mitigation for synchronous data-parallel training.

At 1000+ nodes the slowest worker sets the step time. Two composable
policies, both simulated deterministically in tests (no real cluster in
this container — the *decision logic* is what's tested):

  - ``BackupStepPolicy``: track an EWMA of per-host step times; hosts
    slower than ``threshold × median`` are flagged; after ``patience``
    consecutive flags the host is cordoned (training continues on the
    survivors via elastic re-shard — see fault_tolerance).
  - ``QuorumPolicy``: proceed when K of N microbatch gradients arrived;
    late gradients are dropped and the contribution renormalized by K/N
    (unbiased in expectation for i.i.d. microbatches).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np


@dataclasses.dataclass
class BackupStepPolicy:
    threshold: float = 1.8       # × median EWMA step time
    patience: int = 3
    ewma: float = 0.3

    def __post_init__(self) -> None:
        self._t: Dict[int, float] = {}
        self._flags: Dict[int, int] = {}
        self.cordoned: Set[int] = set()

    def observe(self, host: int, step_time: float) -> None:
        prev = self._t.get(host, step_time)
        self._t[host] = (1 - self.ewma) * prev + self.ewma * step_time

    def evaluate(self) -> List[int]:
        """Returns hosts newly cordoned this round."""
        active = {h: t for h, t in self._t.items() if h not in self.cordoned}
        if len(active) < 2:
            return []
        med = float(np.median(list(active.values())))
        newly = []
        for h, t in active.items():
            if t > self.threshold * med:
                self._flags[h] = self._flags.get(h, 0) + 1
                if self._flags[h] >= self.patience:
                    self.cordoned.add(h)
                    newly.append(h)
            else:
                self._flags[h] = 0
        return newly


@dataclasses.dataclass(frozen=True)
class QuorumPolicy:
    quorum_frac: float = 0.9

    def required(self, n_workers: int) -> int:
        return max(1, int(np.ceil(self.quorum_frac * n_workers)))

    def combine(self, grads: Sequence[Optional[np.ndarray]]) -> np.ndarray:
        """Average the gradients that arrived; renormalize by the count.
        ``None`` = missing (straggler past deadline)."""
        present = [g for g in grads if g is not None]
        n = len(present)
        if n < self.required(len(grads)):
            raise TimeoutError(
                f"quorum not met: {n}/{len(grads)} < {self.required(len(grads))}")
        return np.mean(np.stack(present), axis=0)
