"""Straggler mitigation for synchronous data-parallel training.

At 1000+ nodes the slowest worker sets the step time. Two composable
policies, both simulated deterministically in tests (no real cluster in
this container — the *decision logic* is what's tested):

  - ``BackupStepPolicy``: track an EWMA of per-host step times; hosts
    slower than ``threshold × median`` are flagged; after ``patience``
    consecutive flags the host is cordoned (training continues on the
    survivors via elastic re-shard — see fault_tolerance).
  - ``QuorumPolicy``: proceed when K of N microbatch gradients arrived;
    late gradients are dropped and the contribution renormalized by K/N
    (unbiased in expectation for i.i.d. microbatches).

``BackupStepPolicy`` lives in ``repro.cluster.membership`` — the
sharded-KV cluster uses it to plan view changes — and is re-exported
here unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

# EWMA straggler cordoning moved to the storage-cluster membership
# layer, where it feeds view planning; re-exported for training callers.
from repro.cluster.membership import BackupStepPolicy

__all__ = ["BackupStepPolicy", "QuorumPolicy"]


@dataclasses.dataclass(frozen=True)
class QuorumPolicy:
    quorum_frac: float = 0.9

    def required(self, n_workers: int) -> int:
        return max(1, int(np.ceil(self.quorum_frac * n_workers)))

    def combine(self, grads: Sequence[Optional[np.ndarray]]) -> np.ndarray:
        """Average the gradients that arrived; renormalize by the count.
        ``None`` = missing (straggler past deadline)."""
        present = [g for g in grads if g is not None]
        n = len(present)
        if n < self.required(len(grads)):
            raise TimeoutError(
                f"quorum not met: {n}/{len(grads)} < {self.required(len(grads))}")
        return np.mean(np.stack(present), axis=0)
