"""Fault tolerance: heartbeats, failure detection, elastic restart.

The orchestration loop for a 1000-node job:
  1. every host heartbeats; misses past a deadline ⇒ host declared dead,
  2. training halts at the step boundary; survivors hold the last
     committed checkpoint (CoW+pvn pages, Zero-log manifest) + WAL,
  3. restore: shard regions of the survivors (+ replacements, if any) are
     assembled into the global state and re-sharded for the new world size
     (persistence/restore.py), data pipeline fast-forwards to the WAL
     cursor, training resumes — exactly-once step semantics.

This container is single-process, so hosts are simulated actors; the logic
(detection, quorum, restore orchestration) is real and tested — it is the
part that must be correct, the transport is jax.distributed in deployment.
Failure detection itself (``HeartbeatRegistry``) lives in
``repro.cluster.membership`` — the sharded-KV cluster uses it to plan
view changes — and is re-exported here unchanged.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# Failure detection moved to the storage-cluster membership layer, where
# it feeds view planning; re-exported here for the training-loop callers.
from repro.cluster.membership import HeartbeatRegistry
from repro.persistence.checkpoint import CheckpointConfig, CheckpointManager
from repro.persistence.restore import assemble_global, reshard_state

__all__ = ["HeartbeatRegistry", "ElasticCoordinator"]


class ElasticCoordinator:
    """Drives checkpoint-based elastic recovery across shard regions."""

    def __init__(self, paths: Sequence[str],
                 cfg: CheckpointConfig = CheckpointConfig()) -> None:
        self.paths = list(paths)
        self.cfg = cfg

    def save_sharded(self, step: int, global_state: Dict[str, np.ndarray],
                     axis_rules: Optional[Dict[str, int]] = None) -> List[Dict]:
        from repro.persistence.restore import slice_state
        shards = slice_state(global_state, len(self.paths), axis_rules)
        specs = []
        for i, (state, spec) in enumerate(shards):
            mgr = CheckpointManager(self.paths[i], self.cfg, shard_id=i)
            mgr.save(step, state)
            specs.append(spec)
        return specs

    def restore_elastic(
        self,
        surviving: Sequence[int],
        shard_specs: Sequence[Dict],
        new_nshards: int,
        axis_rules: Optional[Dict[str, int]] = None,
    ) -> Tuple[int, List[Dict[str, np.ndarray]]]:
        """Recover from the surviving shard regions and re-shard to the new
        world size. Raises if the surviving set cannot cover the state
        (with default slicing every shard is required unless replicated —
        deployments add cross-shard replication for loss tolerance; here
        survivors must include every shard, or a replica path)."""
        states, specs, steps = [], [], []
        for i in surviving:
            mgr = CheckpointManager(self.paths[i], self.cfg, shard_id=i)
            step, state = mgr.restore()
            states.append(state)
            specs.append(shard_specs[i])
            steps.append(step)
        if len(set(steps)) != 1:
            # shards committed different steps ⇒ roll back to the minimum
            # manifest step present everywhere (each region keeps history)
            raise RuntimeError(f"inconsistent shard steps {steps}; "
                               "cross-shard commit protocol violated")
        global_state = assemble_global(states, specs)
        new_shards = reshard_state(global_state, new_nshards, axis_rules)
        return steps[0], [s for s, _ in new_shards]
