"""Parameter / batch / cache sharding rules (DP + FSDP + TP + EP).

Logical scheme on the production mesh (pod, data, model):
  - "fsdp"  = (pod, data): ZeRO-3 storage sharding of parameters and
    optimizer moments along a contraction dimension; XLA re-materializes
    per-layer full weights with all-gathers inside the scan (the standard
    FSDP lowering) and reduce-scatters gradients.
  - "model" = tensor parallelism: attention heads / FFN width / MoE experts
    (EP) / LRU width.
  - Activations: batch over (pod, data); MoE dispatch buffers over
    (model=experts, fsdp=capacity).

Every rule degrades gracefully: an axis is only used when the dimension is
divisible by its mesh extent (e.g. KV heads with kv < 16 replicate across
``model``; batch=1 decode replicates the batch axis).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

Axis = Optional[str | Tuple[str, ...]]


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _extent(mesh: Mesh, axes: Axis) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fit(mesh: Mesh, dim: int, axes: Axis) -> Axis:
    """Use `axes` only if `dim` divides evenly; otherwise replicate."""
    if axes is None or dim <= 0:
        return None
    ext = _extent(mesh, axes)
    return axes if dim % ext == 0 and ext > 1 else None


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "name"):
            names.append(str(k.name))
        else:
            names.append(str(k))
    return tuple(names)


def param_spec(path, leaf, mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf, by pytree path."""
    names = _path_names(path)
    name = names[-1]
    fs: Axis = fsdp_axes(mesh) or None
    stacked = any(n.startswith("seg") for n in names)  # scanned stacks: (n, ...)
    lead: Tuple[Axis, ...] = (None,) if stacked else ()
    shape = leaf.shape[1:] if stacked else leaf.shape

    def spec(*axes: Axis) -> P:
        fitted = tuple(_fit(mesh, d, a) for d, a in zip(shape, axes))
        return P(*lead, *fitted)

    # ---- embeddings / head: (V, D) — never stacked
    if name in ("embed", "head"):
        return P(_fit(mesh, leaf.shape[0], "model"), _fit(mesh, leaf.shape[1], fs))

    # ---- MoE ----
    if "moe" in names or name == "router":
        if name in ("gate", "up"):       # (E, D, F)
            return spec("model", fs, None)
        if name == "down":               # (E, F, D)
            return spec("model", fs, None)
        if name == "router":             # (D, E)
            return spec(fs, None)
        if len(shape) == 2:              # shared-expert ffn leaves (D,F)/(F,D)
            if name in ("gate", "up"):
                return spec(fs, "model")
            return spec("model", fs)

    # ---- attention ----
    if name in ("wq", "wk", "wv"):       # (D, H*hd)
        return spec(fs, "model")
    if name == "wo":                     # (H*hd, D)
        return spec("model", fs)
    if name in ("wq_a", "wkv_a"):        # (D, lora)
        return spec(fs, None)
    if name in ("wq_b", "wkv_b"):        # (lora, H*x)
        return spec(fs, "model")

    # ---- shared-expert / dense FFN ----
    if name in ("gate", "up"):           # (D, F)
        return spec(fs, "model")
    if name == "down":                   # (F, D)
        return spec("model", fs)

    # ---- RG-LRU ----
    if name in ("wx", "wg"):             # (D, W)
        return spec(fs, "model")
    if name in ("wa", "wi"):             # (W, W)
        return spec(None, "model")
    if name == "lam":                    # (W,)
        return spec("model")
    if name == "conv_w" and "rec" in names:   # (k, W)
        return spec(None, "model")
    if name == "conv_b" and "rec" in names:
        return spec("model")

    # ---- SSD (mamba2) ----
    if name == "w_in":                   # (D, 2di+2N+H) — fused; shard D only
        return spec(fs, None)
    if name == "w_out":                  # (di, D)
        return spec("model", fs)

    # ---- everything else (norms, conv stacks, scalars): replicate ----
    return P(*lead, *(None,) * len(shape))


def state_specs(params, mesh: Mesh):
    """Specs for a parameter pytree (and reusable for adam moments)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(path, leaf, mesh), params)


def opt_specs(opt_state, param_specs_tree, mesh: Mesh):
    return {
        "m": param_specs_tree,
        "v": param_specs_tree,
        "count": P(),
    }


def batch_specs(batch: Dict[str, Any], mesh: Mesh) -> Dict[str, P]:
    fs = fsdp_axes(mesh) or None
    out = {}
    for k, v in batch.items():
        shape = v.shape
        if k == "positions":             # (3, B, S)
            out[k] = P(None, _fit(mesh, shape[1], fs), None)
        elif k in ("frames", "vis_embeds"):  # (B, S, D)
            out[k] = P(_fit(mesh, shape[0], fs), None, None)
        else:                            # tokens/labels (B, S)
            out[k] = P(_fit(mesh, shape[0], fs), *(None,) * (len(shape) - 1))
    return out


def cache_spec(path, leaf, mesh: Mesh) -> P:
    """Decode-cache sharding: batch over fsdp; heads/width over model when
    divisible. Leading scan-stack axis is replicated."""
    names = _path_names(path)
    name = names[-1]
    fs: Axis = fsdp_axes(mesh) or None
    shape = leaf.shape[1:]               # strip scan-stack axis
    b_ax = _fit(mesh, shape[0], fs) if shape else None

    if name in ("k", "v") and len(shape) == 4:      # (B, T, KV, hd)
        head_ax = _fit(mesh, shape[2], "model")
        if head_ax is not None:
            return P(None, b_ax, None, head_ax, None)
        # KV heads don't divide the model axis (GQA kv<16): shard the TIME
        # axis over `model` instead — sequence-sharded KV cache. Attention
        # over T is a reduction, so scores psum across the axis; this cuts
        # the per-device cache footprint 16× vs replication.
        return P(None, b_ax, _fit(mesh, shape[1], "model"), None, None)
    if name in ("ckv", "k_rope"):                   # (B, T, d) — MLA latent
        return P(None, b_ax, _fit(mesh, shape[1], "model"), None)
    if name == "h" and len(shape) == 2:             # rec state (B, W)
        return P(None, b_ax, _fit(mesh, shape[1], "model"))
    if name == "h" and len(shape) == 4:             # ssd state (B, H, P, N)
        return P(None, b_ax, _fit(mesh, shape[1], "model"), None, None)
    if name == "conv":                              # (B, k-1, C)
        return P(None, b_ax, None, None)
    if name == "pos":                               # (1, T)
        return P(None, None, None)
    return P(*(None,) * (len(shape) + 1))


def cache_specs(caches, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: cache_spec(path, leaf, mesh), caches)


def to_named(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
