"""Asynchronous checkpoint flushing, overlapped with training.

Guideline G5 ("performance-critical code should prefer DRAM … buffer writes
in a DRAM cache") becomes: the training loop *stages* device state to host
memory (a cheap device→host copy) and returns to compute immediately; a
bounded background pool (guideline G4: over-saturating the durable tier
degrades throughput, so writer concurrency is capped) runs the actual
CoW/µLog flushing off the critical path.

Lane model (repro.io engine): the flusher runs **one worker lane per
checkpoint shard**. A single manager keeps the original contract — saves
serialized in submission order. A list of managers (one per shard of the
host's state) flushes the shards concurrently, which is exactly the
paper's multi-threaded page-flush setting (Fig. 5(b)): each shard's
:class:`CheckpointManager` batches its own pages through a
:class:`~repro.io.FlushQueue` epoch, and the per-shard worker count is
the engine's active-lane count.

Ordering contract: saves for a given shard are serialized in submission
order (a single worker per shard region); ``wait()`` drains everything —
the train loop calls it before intentionally stopping, and the WAL makes
any un-flushed tail recoverable anyway.

The flusher owns no layout: each :class:`CheckpointManager` manages its
shard through its own :class:`repro.pool.Pool` (manifest + pages regions),
so the worker threads only ever call ``manager.save``.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.persistence.checkpoint import (
    CheckpointConfig,
    CheckpointManager,
    SaveReport,
)

__all__ = ["AsyncFlusher"]


class AsyncFlusher:
    """Background flusher: one worker lane per checkpoint shard."""

    def __init__(self,
                 managers: Union[CheckpointManager, Sequence[CheckpointManager]],
                 *, max_pending: int = 2,
                 sockets: Optional[int] = None,
                 cache_frames: Optional[int] = None,
                 cache_admit_k: Optional[int] = None,
                 kernel_impl: Optional[str] = None) -> None:
        """``sockets`` (when > 1) interleaves the shards' home sockets
        round-robin across the host's NUMA sockets, so each shard's
        worker lane flushes near-socket instead of funneling every
        shard's pages through socket 0. Only shards that have not yet
        built their pool (first save pending) and did not pin a socket
        themselves (``CheckpointConfig.socket``) are moved; a shard
        config still at the single-socket default also has the topology
        propagated into it (its pool is created ``sockets``-wide —
        without that the home assignment would clamp back to 0).

        ``cache_frames`` / ``cache_admit_k`` likewise propagate a
        host-level DRAM budget into every shard config still at its
        default: the flusher's aggregate staging DRAM is
        ``lanes × cache_frames × page_size``, bounded regardless of the
        state size — per-shard snapshot frames are the shard pool's
        :class:`~repro.cache.BufferManager` (``pool.cache``), not an
        unbounded host-RAM mirror. Shards whose pools are already built
        or whose configs pin their own values keep them.

        ``kernel_impl`` propagates a scan dispatch (e.g. ``"fused"`` or
        ``"staged"``) into every shard config still at ``"auto"``, and
        it governs BOTH directions: each worker lane's saves run the
        one-pass ``flush_pack`` kernel (or the staged A/B chain), and
        each shard's ``restore``/``adopt`` runs the one-pass
        ``apply_unpack`` verify+assemble (or the staged
        verify-then-copy loop) — see ``CheckpointConfig.kernel_impl``."""
        if isinstance(managers, CheckpointManager):
            managers = [managers]
        self.managers: List[CheckpointManager] = list(managers)
        if not self.managers:
            raise ValueError("AsyncFlusher needs at least one manager")
        import dataclasses
        if sockets is not None and sockets > 1:
            for i, mgr in enumerate(self.managers):
                if mgr.pool is not None or mgr.cfg.socket is not None:
                    continue
                if mgr.cfg.sockets == 1:
                    mgr.cfg = dataclasses.replace(mgr.cfg,
                                                  sockets=int(sockets))
                mgr.home_socket = i % mgr.cfg.sockets
        if cache_frames is not None or cache_admit_k is not None:
            for mgr in self.managers:
                if mgr.pool is not None:
                    continue
                kw = {}
                if cache_frames is not None and mgr.cfg.cache_frames is None:
                    kw["cache_frames"] = int(cache_frames)
                if cache_admit_k is not None \
                        and mgr.cfg.cache_admit_k == CheckpointConfig.cache_admit_k:
                    kw["cache_admit_k"] = int(cache_admit_k)
                if kw:
                    mgr.cfg = dataclasses.replace(mgr.cfg, **kw)
        if kernel_impl is not None:
            for mgr in self.managers:
                if mgr.pool is None and mgr.cfg.kernel_impl == "auto":
                    mgr.cfg = dataclasses.replace(mgr.cfg,
                                                  kernel_impl=str(kernel_impl))
        #: first shard's manager — kept for the single-shard call sites
        self.manager = self.managers[0]
        self._queues: List["queue.Queue"] = [
            queue.Queue(maxsize=max_pending) for _ in self.managers
        ]
        self._reports: List[List[SaveReport]] = [[] for _ in self.managers]
        self.errors: List[BaseException] = []
        self._workers = [
            threading.Thread(target=self._run, args=(i,), daemon=True)
            for i in range(len(self.managers))
        ]
        for w in self._workers:
            w.start()

    @property
    def lanes(self) -> int:
        return len(self.managers)

    @property
    def reports(self) -> List[SaveReport]:
        """All completed saves: submission order within a shard; across
        shards, ordered by (step, shard)."""
        if len(self._reports) == 1:
            return list(self._reports[0])
        merged = [
            (r.step, shard, r)
            for shard, reps in enumerate(self._reports) for r in reps
        ]
        return [r for _, _, r in sorted(merged, key=lambda t: (t[0], t[1]))]

    def _run(self, lane: int) -> None:
        q = self._queues[lane]
        while True:
            item = q.get()
            if item is None:
                q.task_done()
                return
            step, state = item
            try:
                self._reports[lane].append(self.managers[lane].save(step, state))
            except BaseException as e:  # surfaced on wait()
                self.errors.append(e)
            finally:
                q.task_done()

    @staticmethod
    def stage(state: Dict[str, Any]) -> Dict[str, np.ndarray]:
        """Device→host staging copy (the only synchronous cost). Must be a
        real copy: the training loop mutates the live buffers immediately
        after submit()."""
        return {k: np.array(v, copy=True) for k, v in state.items()}

    def submit(self, step: int, state: Dict[str, Any], *, shard: int = 0) -> None:
        """Stage and enqueue one shard's save; blocks only if that shard
        already has ``max_pending`` saves in flight (back-pressure instead
        of unbounded host RAM)."""
        self._queues[shard].put((step, self.stage(state)))

    def submit_all(self, step: int, states: Sequence[Dict[str, Any]]) -> None:
        """Stage and enqueue one save per shard (lane-parallel flush)."""
        if len(states) != len(self.managers):
            raise ValueError(
                f"{len(states)} shard states for {len(self.managers)} managers")
        for shard, state in enumerate(states):
            self.submit(step, state, shard=shard)

    def wait(self) -> List[SaveReport]:
        for q in self._queues:
            q.join()
        if self.errors:
            raise self.errors[0]
        return self.reports

    def restore_all(self, *, verify: bool = True):
        """Restore every shard (drains in-flight saves first) and return
        ``(step, states)`` — the common committed step and one state
        dict per shard. Each shard restores through its own manager, so
        the per-shard ``kernel_impl`` (fused ``apply_unpack`` vs staged)
        and restore accounting (``manager.last_restore``) apply
        shard-by-shard. Raises if the shards disagree on the newest
        committed step — a torn multi-shard save (submit_all + wait
        makes this impossible in normal operation)."""
        self.wait()
        steps, states = [], []
        for mgr in self.managers:
            step, state = mgr.restore(verify=verify)
            steps.append(step)
            states.append(state)
        if len(set(steps)) != 1:
            raise RuntimeError(
                f"shards restored different steps {steps}: torn "
                f"multi-shard checkpoint")
        return steps[0], states

    def close(self) -> List[SaveReport]:
        for q in self._queues:
            q.put(None)
        for q in self._queues:
            q.join()
        for w in self._workers:
            w.join(timeout=60)
        if self.errors:
            raise self.errors[0]
        return self.reports
