"""Asynchronous checkpoint flushing, overlapped with training.

Guideline G5 ("performance-critical code should prefer DRAM … buffer writes
in a DRAM cache") becomes: the training loop *stages* device state to host
memory (a cheap device→host copy) and returns to compute immediately; a
bounded background pool (guideline G4: over-saturating the durable tier
degrades throughput, so writer concurrency is capped) runs the actual
CoW/µLog flushing off the critical path.

Ordering contract: saves for a given manager are serialized in submission
order (a single worker per shard region); ``wait()`` drains everything —
the train loop calls it before intentionally stopping, and the WAL makes
any un-flushed tail recoverable anyway.

The flusher owns no layout: each :class:`CheckpointManager` manages its
shard through its own :class:`repro.pool.Pool` (manifest + pages regions),
so the worker thread only ever calls ``manager.save``.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.persistence.checkpoint import CheckpointManager, SaveReport

__all__ = ["AsyncFlusher"]


class AsyncFlusher:
    """Background flusher for one :class:`CheckpointManager`."""

    def __init__(self, manager: CheckpointManager, *, max_pending: int = 2) -> None:
        self.manager = manager
        self._q: "queue.Queue" = queue.Queue(maxsize=max_pending)
        self.reports: List[SaveReport] = []
        self.errors: List[BaseException] = []
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, state = item
            try:
                self.reports.append(self.manager.save(step, state))
            except BaseException as e:  # surfaced on wait()
                self.errors.append(e)
            finally:
                self._q.task_done()

    @staticmethod
    def stage(state: Dict[str, Any]) -> Dict[str, np.ndarray]:
        """Device→host staging copy (the only synchronous cost). Must be a
        real copy: the training loop mutates the live buffers immediately
        after submit()."""
        return {k: np.array(v, copy=True) for k, v in state.items()}

    def submit(self, step: int, state: Dict[str, Any]) -> None:
        """Stage and enqueue; blocks only if ``max_pending`` saves are
        already in flight (back-pressure instead of unbounded host RAM)."""
        self._q.put((step, self.stage(state)))

    def wait(self) -> List[SaveReport]:
        self._q.join()
        if self.errors:
            raise self.errors[0]
        return self.reports

    def close(self) -> List[SaveReport]:
        self._q.put(None)
        self._q.join()
        self._worker.join(timeout=60)
        if self.errors:
            raise self.errors[0]
        return self.reports
