"""Training-state persistence built on the paper's I/O primitives.

Every module here gets its PMem layout from :class:`repro.pool.Pool` —
named, typed directory regions instead of hand-carved byte offsets. The
checkpoint manager owns a pool per shard file ("manifest" + "pages"
regions); the training WAL is a pool log region (``pool.wal(name)``).

- :mod:`repro.persistence.checkpoint` — sharded checkpoint manager: each
  parameter/optimizer shard is a sequence of *pages* flushed failure-
  atomically (CoW + pvn for full snapshots, µLog deltas for sparse change),
  manifest committed through a Zero log.
- :mod:`repro.persistence.wal`        — step-granular training WAL (Zero
  logging: one durability barrier per training step).
- :mod:`repro.persistence.flusher`    — asynchronous background flushing,
  overlapped with training (guideline G5: stage in DRAM, bound writer
  concurrency per G4; one repro.io worker lane per checkpoint shard).
- :mod:`repro.persistence.restore`    — crash recovery + elastic re-shard.
"""

from repro.persistence.checkpoint import CheckpointConfig, CheckpointManager  # noqa: F401
from repro.persistence.flusher import AsyncFlusher  # noqa: F401
from repro.persistence.restore import assemble_global, reshard_state  # noqa: F401
from repro.persistence.wal import StepRecord, TrainWAL  # noqa: F401
