"""Crash recovery and *elastic* re-sharding.

Each shard's manifest records, per leaf, the global array shape and the
slice this shard owns. Restoring onto a different mesh (more/fewer hosts —
elastic scaling after node loss) assembles the global arrays from whatever
shard regions survive and re-slices them for the new topology. Assembly is
pure numpy on hosts; the new device placement happens in the distributed
layer (``jax.device_put`` with the new sharding).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["shard_slices", "slice_state", "assemble_global", "reshard_state"]

Slice = Tuple[int, int]


def shard_slices(global_shape: Sequence[int], nshards: int, axis: int = 0
                 ) -> List[Tuple[Slice, ...]]:
    """Even partition of ``global_shape`` along ``axis`` into nshards."""
    dim = global_shape[axis]
    if dim % nshards != 0:
        raise ValueError(f"axis {axis} of {global_shape} not divisible by {nshards}")
    step = dim // nshards
    out = []
    for s in range(nshards):
        sl = []
        for d, size in enumerate(global_shape):
            sl.append((s * step, (s + 1) * step) if d == axis else (0, size))
        out.append(tuple(sl))
    return out


def slice_state(global_state: Dict[str, np.ndarray], nshards: int,
                axis_rules: Optional[Dict[str, int]] = None
                ) -> List[Tuple[Dict[str, np.ndarray], Dict[str, Any]]]:
    """Split a global state dict into per-shard (state, specs) pairs.

    ``specs[name] = {"global_shape": [...], "slices": [[lo, hi], ...]}`` —
    exactly what gets stored in each shard's manifest (as leaf metadata
    piggybacked by the caller) and what :func:`assemble_global` inverts.
    """
    shards: List[Tuple[Dict[str, np.ndarray], Dict[str, Any]]] = [
        ({}, {}) for _ in range(nshards)
    ]
    for name, arr in global_state.items():
        axis = (axis_rules or {}).get(name, 0)
        if arr.ndim == 0 or arr.shape[axis] % nshards != 0:
            # unshardable leaf: replicate (shard 0 is authoritative)
            for state, specs in shards:
                state[name] = arr
                specs[name] = {"global_shape": list(arr.shape), "slices": None}
            continue
        for s, sl in enumerate(shard_slices(arr.shape, nshards, axis)):
            view = arr[tuple(slice(lo, hi) for lo, hi in sl)]
            shards[s][0][name] = np.ascontiguousarray(view)
            shards[s][1][name] = {
                "global_shape": list(arr.shape),
                "slices": [list(x) for x in sl],
            }
    return shards


def assemble_global(shard_states: Sequence[Dict[str, np.ndarray]],
                    shard_specs: Sequence[Dict[str, Any]]
                    ) -> Dict[str, np.ndarray]:
    """Inverse of :func:`slice_state`: merge shard states into global arrays."""
    out: Dict[str, np.ndarray] = {}
    for state, specs in zip(shard_states, shard_specs):
        for name, arr in state.items():
            spec = specs[name]
            if spec["slices"] is None:
                out.setdefault(name, arr)
                continue
            if name not in out:
                out[name] = np.zeros(spec["global_shape"], dtype=arr.dtype)
            idx = tuple(slice(lo, hi) for lo, hi in spec["slices"])
            out[name][idx] = arr
    return out


def reshard_state(global_state: Dict[str, np.ndarray], new_nshards: int,
                  axis_rules: Optional[Dict[str, int]] = None):
    """Elastic transition: global state → shard list for a new world size."""
    return slice_state(global_state, new_nshards, axis_rules)
