"""Step-granular training write-ahead log — Zero logging in production.

The latency-critical log of the paper maps to the per-step training record:
(step, data cursor, RNG key, loss, grad-norm, loss scale). It is on the
critical path of every training step (the step is not "committed" until the
record is durable — exactly a transaction commit), so the technique with the
fewest persistency barriers wins: Zero logging, ONE barrier per step.

Records are fixed-size and cache-line padded (Fig. 6's ≈8× lesson), so the
WAL also never rewrites a line. On restart the WAL gives the exact resume
point: the last durable step, its RNG key, and the data-pipeline cursor —
replaying the pipeline deterministically with no re-read of earlier batches.

Construction goes through :class:`repro.pool.Pool` — ``pool.wal(name)`` or
:meth:`TrainWAL.on_pool` — which open-or-create a named log region and
recover automatically; the WAL never computes a byte offset itself (all
layout lives behind the pool directory). ``pool.wal(name, lanes=N,
group_commit=k)`` runs the WAL on the repro.io engine's
:class:`~repro.io.MultiLog` instead: N zero-log lanes, k steps amortized
per persistency barrier (data-parallel trainers whose replicas commit
steps concurrently). The legacy ``TrainWAL(pmem, base, capacity)``
signature survives only as a deprecation shim: it formats (or attaches)
a pool directory over the caller's region and opens the WAL as the
named region ``train_wal`` inside it — ``base`` must be 0 and is not a
raw offset into anything; nonzero values are rejected.
"""

from __future__ import annotations

import dataclasses
import struct
import warnings
from typing import List, Optional, Tuple

__all__ = ["StepRecord", "TrainWAL"]

_REC = struct.Struct("<QQQQfffQ")  # step, cursor, rng_hi, rng_lo, loss, gnorm, lscale, t_ns

#: cache-line-padded bytes per record in a Zero log (header + record < 128)
_BYTES_PER_STEP = 128


@dataclasses.dataclass(frozen=True)
class StepRecord:
    step: int
    data_cursor: int
    rng_key: Tuple[int, int]      # (hi, lo) of a jax PRNG key's raw words
    loss: float
    grad_norm: float
    loss_scale: float
    time_ns: int = 0

    def pack(self) -> bytes:
        return _REC.pack(
            self.step, self.data_cursor, self.rng_key[0], self.rng_key[1],
            self.loss, self.grad_norm, self.loss_scale, self.time_ns,
        )

    @classmethod
    def unpack(cls, buf: bytes) -> "StepRecord":
        s, c, hi, lo, loss, gn, ls, t = _REC.unpack(buf[: _REC.size])
        return cls(s, c, (hi, lo), loss, gn, ls, t)


class TrainWAL:
    """Training WAL over a pool log region. Technique defaults to "zero"
    (the paper's result); "classic"/"header" remain available as baselines
    so the end-to-end benefit is measurable."""

    #: directory region name used by the legacy shim
    _LEGACY_REGION = "train_wal"

    def __init__(
        self,
        pmem=None,
        base: int = 0,
        capacity: Optional[int] = None,
        *,
        technique: str = "zero",
        recover: bool = False,
        _handle=None,
    ) -> None:
        """Open the WAL. Preferred: :meth:`on_pool` / ``pool.wal(name)``
        (``_handle`` carries the pool log handle). The positional
        ``(pmem, base, capacity)`` form is the deprecated shim described
        in the module docstring: it adopts the region as a pool and opens
        the ``train_wal`` directory region — no raw offsets are used, and
        ``base`` must be 0. ``recover=False`` on the shim starts a fresh
        generation over an existing region instead of resuming it."""
        if _handle is None:
            # Legacy shim: adopt the caller's raw region as a pool. The
            # directory lives at the head, so base must be 0; the log gets
            # whatever the directory does not use (clamped to `capacity`).
            from repro.pool import Pool
            warnings.warn(
                "TrainWAL(pmem, base, capacity) raw-region construction is "
                "deprecated; use pool.wal(name) / TrainWAL.on_pool(pool, "
                "name) instead", DeprecationWarning, stacklevel=2)
            if pmem is None:
                raise TypeError("TrainWAL needs a pool handle or a PMem")
            if base != 0:
                raise ValueError(
                    "raw base offsets are no longer supported; allocate a "
                    "region through repro.pool.Pool instead")
            pool = Pool.attach(pmem)
            if pool.directory.lookup(self._LEGACY_REGION) is not None:
                # the durable record decides the technique on reopen
                _handle = pool.log(self._LEGACY_REGION)
                if not recover:
                    # legacy recover=False meant "fresh WAL over this
                    # region": start a new generation instead of silently
                    # resuming the old one
                    _handle.reset()
            else:
                cap = min(capacity if capacity is not None else pool.free_bytes,
                          pool.free_bytes)
                _handle = pool.log(self._LEGACY_REGION, capacity=cap,
                                   technique=technique)
        from repro.io.multilog import MultiLog
        self.log = _handle
        self._multilog = isinstance(_handle, MultiLog)
        self.technique = _handle.technique
        self.records: List[StepRecord] = [
            StepRecord.unpack(e) for e in _handle.recovered.entries
        ]

    @classmethod
    def on_pool(cls, pool, name: str = "train_wal", *,
                capacity_steps: Optional[int] = None,
                technique: Optional[str] = None,
                lanes: int = 1, group_commit: int = 1,
                gen_sets: int = 1) -> "TrainWAL":
        """Open-or-create a named WAL region on ``pool``.

        ``capacity_steps`` is required when creating; on open it is
        *verified* against the durable region (a region cannot grow, so
        asking for more steps than it holds raises rather than failing
        thousands of steps later with a full log). ``technique`` defaults
        to "zero" when creating; on open the directory record decides.

        ``lanes > 1`` creates the WAL on a lane-striped group-commit
        :class:`~repro.io.MultiLog` (regions ``<name>.lane<i>``): commits
        batch ``group_commit`` steps per barrier, and ``commit_step``
        grows a ``sync=`` knob. A WAL created multi-lane is reopened
        multi-lane automatically (the lane regions are discovered). On a
        multi-socket pool the lane regions are spread over the sockets
        and each lane runs near its region (the pool's
        :class:`~repro.io.placer.LanePlacer`).

        ``gen_sets >= 2`` (multi-lane only) puts the WAL on a generation
        ring: ``capacity_steps`` is then *per generation*, and
        :meth:`roll` seals the live generation at a checkpoint so the
        step log stops growing without bound (sealed generations stay
        recoverable until a spill scheduler retires them to SSD). A
        generational WAL is reopened generational automatically."""
        from repro.io.multilog import MultiLog
        multi_exists = (pool.directory.lookup(f"{name}.lane0") is not None
                        or pool.directory.lookup(f"{name}.gen") is not None)
        single_exists = pool.directory.lookup(name) is not None
        if single_exists and lanes > 1:
            raise ValueError(
                f"WAL {name!r} exists as a single-lane region; it cannot "
                f"be reopened with lanes={lanes} (recreate it, or open "
                f"with lanes=1)")
        if gen_sets > 1 and single_exists:
            raise ValueError(
                f"WAL {name!r} exists as a single-lane region; it cannot "
                f"be reopened with gen_sets={gen_sets} (recreate it)")
        # the generation ring runs on the MultiLog even at lanes=1
        if multi_exists or ((lanes > 1 or gen_sets > 1) and not single_exists):
            if multi_exists:
                handle = MultiLog(pool, name, technique=technique,
                                  group_commit=group_commit,
                                  gen_sets=gen_sets)
                if capacity_steps is not None:
                    held = sum(h.record.length for h in handle.handles)
                    if held < capacity_steps * _BYTES_PER_STEP:
                        raise ValueError(
                            f"WAL {name!r} holds {held} B across "
                            f"{handle.lanes} lanes, caller asked for "
                            f"{capacity_steps} steps "
                            f"({capacity_steps * _BYTES_PER_STEP} B) — "
                            f"durable regions cannot grow")
            else:
                if capacity_steps is None:
                    raise ValueError(
                        f"creating WAL {name!r} requires capacity_steps=")
                capacity = (capacity_steps * _BYTES_PER_STEP
                            + 4096 * max(1, lanes))
                handle = MultiLog(pool, name, lanes=lanes, capacity=capacity,
                                  technique=technique or "zero",
                                  group_commit=group_commit,
                                  gen_sets=gen_sets)
            return cls(_handle=handle)
        if single_exists:
            capacity = (capacity_steps * _BYTES_PER_STEP
                        if capacity_steps is not None else None)
            handle = pool.log(name, capacity=capacity, technique=technique)
        else:
            if capacity_steps is None:
                raise ValueError(
                    f"creating WAL {name!r} requires capacity_steps=")
            handle = pool.log(name,
                              capacity=capacity_steps * _BYTES_PER_STEP + 4096,
                              technique=technique or "zero")
        return cls(_handle=handle)

    def commit_step(self, record: StepRecord, *, sync: bool = True) -> int:
        """Commit a training step (one barrier under single-lane Zero).

        On a multi-lane WAL, ``sync=False`` buffers the record for group
        commit — it becomes durable with the next full batch or
        :meth:`flush`; the returned LSN is assigned immediately."""
        if self._multilog:
            lsn = self.log.append(record.pack(), sync=sync)
        else:
            lsn = self.log.append(record.pack())
        self.records.append(record)
        return lsn

    def flush(self) -> None:
        """Force group commit of any buffered steps (multi-lane WAL)."""
        if self._multilog:
            self.log.commit()

    @property
    def generational(self) -> bool:
        """Whether this WAL runs on a generation ring (``gen_sets >= 2``)."""
        return bool(getattr(self.log, "generational", False))

    def roll(self) -> int:
        """Seal the live WAL generation and start the next one (checkpoint
        truncation for a generational WAL — the in-memory ``records``
        history is kept; the sealed generation stays recoverable until a
        spill scheduler retires it). Returns the sealed generation."""
        if not self.generational:
            raise RuntimeError(
                "TrainWAL.roll needs a generational WAL — create it with "
                "pool.wal(lanes=N, gen_sets>=2)")
        return self.log.roll()

    @property
    def last(self) -> Optional[StepRecord]:
        return self.records[-1] if self.records else None

    def barriers_per_step(self) -> float:
        """Persistency barriers per committed step — amortized over the
        group-commit batch on a multi-lane WAL."""
        if self._multilog:
            per_batch = self.log.handles[0].barriers_per_append
            return per_batch / self.log.group_commit
        return self.log.barriers_per_append

    @classmethod
    def capacity_for(cls, steps: int, *, lanes: int = 1,
                     gen_sets: int = 1) -> int:
        """Bytes for a pool region holding a `steps`-step WAL (directory
        overhead included; a multi-lane WAL adds per-lane slack and
        block-padding on top of the striped capacity; a generational WAL
        holds ``gen_sets`` lane sets of ``steps`` each plus the ring
        header)."""
        from repro.pool import Pool
        per_set = steps * _BYTES_PER_STEP + 8192 + 8192 * max(1, lanes)
        return (max(1, gen_sets) * per_set + 8192
                + Pool.overhead_bytes())
