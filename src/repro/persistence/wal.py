"""Step-granular training write-ahead log — Zero logging in production.

The latency-critical log of the paper maps to the per-step training record:
(step, data cursor, RNG key, loss, grad-norm, loss scale). It is on the
critical path of every training step (the step is not "committed" until the
record is durable — exactly a transaction commit), so the technique with the
fewest persistency barriers wins: Zero logging, ONE barrier per step.

Records are fixed-size and cache-line padded (Fig. 6's ≈8× lesson), so the
WAL also never rewrites a line. On restart the WAL gives the exact resume
point: the last durable step, its RNG key, and the data-pipeline cursor —
replaying the pipeline deterministically with no re-read of earlier batches.
"""

from __future__ import annotations

import dataclasses
import struct
import time
from typing import List, Optional, Tuple, Type

import numpy as np

from repro.core.log import LOG_TECHNIQUES, LogConfig, ZeroLog, _LogBase
from repro.core.pmem import PMem

__all__ = ["StepRecord", "TrainWAL"]

_REC = struct.Struct("<QQQQfffQ")  # step, cursor, rng_hi, rng_lo, loss, gnorm, lscale, t_ns


@dataclasses.dataclass(frozen=True)
class StepRecord:
    step: int
    data_cursor: int
    rng_key: Tuple[int, int]      # (hi, lo) of a jax PRNG key's raw words
    loss: float
    grad_norm: float
    loss_scale: float
    time_ns: int = 0

    def pack(self) -> bytes:
        return _REC.pack(
            self.step, self.data_cursor, self.rng_key[0], self.rng_key[1],
            self.loss, self.grad_norm, self.loss_scale, self.time_ns,
        )

    @classmethod
    def unpack(cls, buf: bytes) -> "StepRecord":
        s, c, hi, lo, loss, gn, ls, t = _REC.unpack(buf[: _REC.size])
        return cls(s, c, (hi, lo), loss, gn, ls, t)


class TrainWAL:
    """Training WAL over a PMem region. Technique defaults to "zero" (the
    paper's result); "classic"/"header" remain available as baselines so the
    end-to-end benefit is measurable (benchmarks/tab_ycsb.py analogue)."""

    def __init__(
        self,
        pmem: PMem,
        base: int,
        capacity: int,
        *,
        technique: str = "zero",
        recover: bool = False,
    ) -> None:
        self.pmem = pmem
        self.base = base
        self.capacity = capacity
        self.technique = technique
        cls: Type[_LogBase] = LOG_TECHNIQUES[technique]
        cfg = LogConfig(pad_to_line=True)
        self.records: List[StepRecord] = []
        if recover:
            self.log, rec = cls.open_for_append(pmem, base, capacity, cfg)
            self.records = [StepRecord.unpack(e) for e in rec.entries]
        else:
            self.log = cls(pmem, base, capacity, cfg)

    def commit_step(self, record: StepRecord) -> int:
        """Durably commit a training step (one barrier under Zero)."""
        lsn = self.log.append(record.pack())
        self.records.append(record)
        return lsn

    @property
    def last(self) -> Optional[StepRecord]:
        return self.records[-1] if self.records else None

    def barriers_per_step(self) -> int:
        return self.log.BARRIERS_PER_APPEND

    @classmethod
    def capacity_for(cls, steps: int) -> int:
        # padded record (64 B) + Zero header, cache-line stride
        return steps * 128 + 4096
