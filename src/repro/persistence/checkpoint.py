"""Sharded, failure-atomic, delta-capable checkpoint manager.

Every host writes its own shard region (no cross-device funnel — at 1000+
nodes the durable tier must be written in parallel). Within a shard:

  state leaf  →  fixed-size *pages*  →  PageStore slots (CoW + pvn)
                                     ↘  µLog shadow-slot deltas when sparse
  manifest    →  Zero log            (ONE barrier commits the checkpoint)

Consistency story (the non-trivial part):

* Every page keeps **two** slots once it has been flushed twice: *current*
  (version v) and *shadow* (v-1). A full flush CoWs into a free slot; a
  delta flush µLogs the changed blocks **onto the shadow slot** — never in
  place — so the page set referenced by the last *committed* manifest stays
  physically intact no matter where a crash lands. (The paper's in-place
  µLog is correct for a buffer manager, where only the newest page version
  matters; a checkpoint must restore a *consistent cut*, hence the shadow
  variant. Recorded in DESIGN.md §7.)
* The manifest entry (step, page→(slot, pvn), checksums) is appended to a
  Zero log: the checkpoint becomes durable with a single persistency
  barrier, and recovery picks the last manifest whose pages still verify
  (slot pvn match + popcount checksum — the same validity argument as
  Zero logging, at page scale).
* Dirtiness is *computed*, not intercepted: the fused ``flush_pack``
  Pallas kernel compares live parameters against the last-flushed
  snapshot at 4 KiB TPU-tile granularity and, in the SAME device pass,
  emits the per-block popcount checksums and the prefix-sum-compacted
  dirty block ids — the live bytes cross HBM once per save
  (``kernel_impl="staged"`` keeps the pre-fusion dirty_diff → popcnt →
  compaction chain for A/B benchmarking and crash-parity checks).
  ``HybridPolicy`` (threads-aware, §3.2.3) picks CoW vs µLog per page.
  A delta onto the shadow slot must cover the change since v-1, so the
  dirty set is the union of the last two saves' dirty blocks.
* The last-flushed snapshot lives in the pool's DRAM buffer manager
  (``pool.cache``), one clean frame per page, written through
  :meth:`~repro.cache.BufferManager.writeback` — the save epoch leaves
  each frame holding exactly the bytes it flushed. Bounding the frame
  pool (``CheckpointConfig.cache_frames``) bounds the manager's DRAM
  footprint: a leaf whose snapshot frames were evicted degrades to a
  full-page rewrite on its next save (correct, merely conservative).
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.blocks import BlockGeometry, TPU_TILE, align_up
from repro.core.costmodel import COST_MODEL
from repro.core.log import LogConfig, popcount
from repro.core.pageflush import HybridPolicy, PageStore, PageStoreLayout
from repro.core.persist import AccessPattern, FlushKind
from repro.core.pmem import PMem, PMemStats
from repro.pool import LogHandle, PagesHandle, Pool
from repro.kernels.apply_unpack import apply_unpack
from repro.kernels.dirty_diff import dirty_blocks
from repro.kernels.flush_pack import compact_index, flush_pack
from repro.kernels.popcnt_checksum import popcount_blocks

__all__ = ["CheckpointConfig", "CheckpointManager", "RestoreReport",
           "SaveReport"]

#: checkpoint geometry: dirty unit = 4 KiB TPU tile, write granule = 16 KiB
CKPT_GEOMETRY = BlockGeometry(cache_line=TPU_TILE, block=4 * TPU_TILE)

#: spill-map log capacity per buffer for a tiered shard — 4 KiB lines pad
#: each map record to a line, so the maps need real capacity; referenced by
#: the pool sizing AND every SpillScheduler construction, which must agree
_SPILL_MAP_CAPACITY = 1 << 20


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    page_size: int = 256 * 1024
    manifest_capacity: int = 1 << 20
    delta: bool = True               # enable µLog shadow-slot deltas
    threads: int = 1                 # writer threads (G4: bounded; feeds policy)
    #: scan-kernel dispatch, BOTH directions. Save:
    #: "auto"/"fused"/"pallas"/"ref" run the one-pass flush_pack kernel
    #: (auto = pallas on TPU, jnp oracle off); "staged" keeps the
    #: pre-fusion dirty_diff → popcnt → compaction chain (three
    #: live-buffer reads) for A/B benchmarks and the crash corpus'
    #: byte-parity case. Restore: the same values route the one-pass
    #: apply_unpack kernel (verify+scatter+apply, one read of the
    #: restored image) vs the staged popcount-verify → copy chain (two
    #: reads) — staged and fused recover bit-identical state.
    kernel_impl: str = "auto"
    extra_slots: int = 4             # beyond the 2-per-page steady state
    #: PMem page-slot budget for the shard. None = classic sizing (two
    #: slots per page: current + shadow). A smaller budget makes the
    #: save epoch *spill*: cold slots overflow to the shard's SSD device
    #: instead of the pool allocation failing, and manifests record the
    #: spilled pages' SSD residence so restore still verifies end-to-end.
    pmem_slot_budget: Optional[int] = None
    #: SSD device size auto-created per shard when a budget is set and no
    #: device is passed to the manager
    ssd_bytes: int = 1 << 28
    #: NUMA sockets of the host this shard's pool models (recorded in the
    #: pool superblock; the flush epoch's lanes then run near the shard's
    #: home socket via the pool's LanePlacer)
    sockets: int = 1
    #: home socket of this shard's regions. None = ``shard_id % sockets``
    #: (AsyncFlusher interleaves its shards across the sockets)
    socket: Optional[int] = None
    #: DRAM buffer-manager frames holding the last-flushed snapshots.
    #: None = one frame per page (full snapshot set — every delta save
    #: diffs against DRAM, the classic behavior). A smaller value bounds
    #: the shard's DRAM footprint; evicted snapshots degrade that leaf's
    #: next save to a full rewrite.
    cache_frames: Optional[int] = None
    #: k-touch SSD→PMem promotion threshold for the shard's pages
    cache_admit_k: int = 2

    @property
    def geometry(self) -> BlockGeometry:
        return CKPT_GEOMETRY

    @property
    def blocks_per_page(self) -> int:
        return self.page_size // self.geometry.cache_line


@dataclasses.dataclass
class SaveReport:
    step: int
    pages_total: int = 0
    pages_cow: int = 0
    pages_mulog: int = 0
    pages_clean: int = 0
    bytes_logical: int = 0          # checkpoint state size
    barriers: int = 0
    blocks_written: int = 0
    modeled_ns: float = 0.0
    #: flush lanes actually active in this save's epoch drain
    active_lanes: int = 1
    #: cold PMem slots evicted to SSD during this save's epoch
    pages_spilled: int = 0
    #: modeled SSD time of those evictions (overlappable with PMem work)
    spill_ns: float = 0.0
    #: device (HBM) bytes the save's scan kernels read — one live-buffer
    #: pass with the fused flush_pack kernel, up to three when staged
    scan_read_bytes: int = 0
    #: modeled device time of that scan traffic (included in modeled_ns)
    scan_ns: float = 0.0

    @property
    def bytes_device(self) -> int:
        return self.blocks_written * CKPT_GEOMETRY.block


@dataclasses.dataclass
class RestoreReport:
    """What one :meth:`CheckpointManager.restore` did — the read-side
    mirror of :class:`SaveReport`. ``restore_read_bytes`` is the device
    bytes the restore scan read over every attempted manifest entry: one
    pass over the packed page images with the fused ``apply_unpack``
    kernel, two (verify + copy) when staged. ``scan_ns`` prices that
    traffic alone; ``modeled_ns`` folds it into the pool's full delta
    via ``engine_time_ns(scan_read_bytes=)``."""

    step: int = -1
    #: manifest entries walked (newest-first) before one verified
    entries_tried: int = 0
    pages_total: int = 0
    #: pages read back through the SSD spill map rather than PMem slots
    pages_spilled: int = 0
    restore_read_bytes: int = 0
    scan_ns: float = 0.0
    modeled_ns: float = 0.0
    kernel_impl: str = "auto"


class CheckpointManager:
    """Checkpoint manager for one shard (one host's slice of the state).

    State is a flat ``{name: array}`` dict with a stable key set. Arrays may
    be jax or numpy; they are staged to host memory on save (guideline G5 —
    the device-side dirty computation is the only on-device work).
    """

    def __init__(self, path: Optional[str], cfg: CheckpointConfig = CheckpointConfig(),
                 *, shard_id: int = 0, ssd=None) -> None:
        """``path`` backs the shard's pool file (``None`` = in-memory);
        ``ssd`` is the shard's flash device when ``cfg.pmem_slot_budget``
        turns on the spill tier (auto-created in memory if omitted)."""
        self.cfg = cfg
        self.path = path
        self.shard_id = shard_id
        #: NUMA home socket of this shard's regions (settable until the
        #: first save builds the pool — AsyncFlusher interleaves shards)
        self.home_socket = (cfg.socket if cfg.socket is not None
                            else shard_id % max(1, cfg.sockets))
        self._ssd = ssd
        self._spill = None
        self._spilled_pvn: Dict[int, int] = {}   # evicted pid -> pvn on SSD
        self.pool: Optional[Pool] = None
        self.pmem: Optional[PMem] = None
        self.store: Optional[PageStore] = None
        self.manifest: Optional[LogHandle] = None
        self._pages: Optional[PagesHandle] = None
        self._flushq = None                           # repro.io.FlushQueue
        self._epoch_report: Optional[SaveReport] = None
        self._epoch_prev_dirty: Dict[int, set] = {}
        self._layout: Optional[PageStoreLayout] = None
        self._cache = None                            # pool's BufferManager
        self._leaf_pages: Dict[str, List[int]] = {}
        self._leaf_meta: Dict[str, Dict[str, Any]] = {}
        self._prev_dirty: Dict[int, set] = {}         # page -> dirty lines of last save
        self._shadow: Dict[int, int] = {}             # page -> shadow slot
        self._manifest_base = 0
        self._saves = 0
        #: accounting of the most recent :meth:`restore` (None before one)
        self.last_restore: Optional[RestoreReport] = None
        self._restore_read_bytes = 0
        self._restore_pages_spilled = 0

    # ----------------------------------------------------------- layout

    @staticmethod
    def _leaf_bytes(arr: np.ndarray) -> np.ndarray:
        a = np.ascontiguousarray(np.asarray(arr))
        return a.view(np.uint8).reshape(-1)

    def _build(self, state: Dict[str, np.ndarray]) -> None:
        cfg, g = self.cfg, self.cfg.geometry
        pid = 0
        for name in sorted(state):
            buf = self._leaf_bytes(state[name])
            npages = max(1, -(-buf.size // cfg.page_size))
            self._leaf_pages[name] = list(range(pid, pid + npages))
            arr = np.asarray(state[name])
            self._leaf_meta[name] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "nbytes": int(buf.size),
            }
            pid += npages
        npages = pid
        if cfg.pmem_slot_budget is not None:
            nslots = int(cfg.pmem_slot_budget)
        else:
            nslots = 2 * npages + cfg.extra_slots
        tiered = nslots <= 2 * npages and cfg.pmem_slot_budget is not None
        sizing = PageStoreLayout(base=0, page_size=cfg.page_size,
                                 npages=npages, nslots=nslots, geometry=g,
                                 overcommit=nslots <= npages)
        spill_bytes = 0
        if tiered:
            # spill map double buffer + ping-pong head (4 KiB lines pad
            # each map record to a line, so the maps need real capacity)
            spill_bytes = 2 * (_SPILL_MAP_CAPACITY + g.block) \
                + align_up(2 * g.cache_line, g.block)
        total = (Pool.overhead_bytes(g, max_regions=16)
                 + align_up(cfg.manifest_capacity, g.block)
                 + PageStore.region_bytes(sizing, n_mulogs=cfg.threads)
                 + spill_bytes + 2 * g.block)
        self.pool = Pool.create(self.path, total, geometry=g, max_regions=16,
                                sockets=cfg.sockets)
        self.pmem = self.pool.pmem
        home = min(self.home_socket, max(1, cfg.sockets) - 1)
        self.manifest = self.pool.log(
            "manifest", capacity=cfg.manifest_capacity, technique="zero",
            cfg=LogConfig(geometry=g, pad_to_line=True), socket=home)
        self._pages = self.pool.pages(
            "pages", npages=npages, page_size=cfg.page_size, nslots=nslots,
            n_mulogs=cfg.threads, threads=cfg.threads, socket=home)
        self.store = self._pages.store
        self._layout = self._pages.layout
        if tiered:
            self._spill = self._make_spill()
        self._flushq = self._pages.flush_queue(
            lanes=cfg.threads, flush_fn=self._engine_flush_page)
        self._flushq.spill = self._spill
        self._cache = self._pool_cache(npages)
        self._cache.attach_pages(self._pages, flushq=self._flushq,
                                 spill=self._spill)

    def _pool_cache(self, npages: int):
        """The shard pool's buffer manager: explicit ``cache_frames`` /
        ``cache_admit_k`` are verified against any pre-existing pool
        cache (conflict raises); default-configured shards reuse one
        quietly, or create the full snapshot set (a frame per page)."""
        from repro.cache import BufferManager
        cfg = self.cfg
        return BufferManager.for_pool(
            self.pool, frames=cfg.cache_frames,
            admit_k=None
            if cfg.cache_admit_k == CheckpointConfig.cache_admit_k
            else cfg.cache_admit_k,
            default_frames=npages, default_admit_k=cfg.cache_admit_k)

    def _make_spill(self):
        """The shard's spill scheduler (creates the SSD device if none
        was passed) — the save epoch feeds it, restore reads through it."""
        from repro.core.ssd import SSD
        from repro.tier import SpillScheduler
        if self._ssd is None:
            self._ssd = SSD(self.cfg.ssd_bytes)
        self.pool.attach_ssd(self._ssd)
        spill = SpillScheduler(self.pool, name="sp", map_capacity=_SPILL_MAP_CAPACITY)
        spill.attach_pages(self._pages, on_evict=self._on_page_evicted)
        return spill

    def _on_page_evicted(self, pid: int) -> None:
        """Spill-tier callback: a pid's *current* slot left PMem. Drop
        the shadow bookkeeping that referenced PMem slots (the shadow
        slot is freed — its stale durable header loses the cross-tier
        max-pvn rule) and pin the SSD-resident version for the next
        manifest."""
        self._spilled_pvn[pid] = self.store.pvn_floor.get(pid, 0)
        shadow = self._shadow.pop(pid, None)
        if shadow is not None:
            self.store.free.append(shadow)
        self._prev_dirty.pop(pid, None)

    # ------------------------------------------------------------- save

    def _note_scan(self, nbytes: int) -> None:
        """Attribute save-scan HBM traffic to the epoch being built (the
        flush queue folds it into the epoch's modeled time)."""
        if self._flushq is not None:
            self._flushq.note_scan(nbytes)

    def _dirty_lines_per_page(
        self, name: str, cur: jax.Array | np.ndarray,
    ) -> Tuple[Optional[Dict[int, set]], np.ndarray, np.ndarray]:
        """One fused device pass (flush_pack kernel): dirty (page → line
        set) vs the snapshot (None = everything dirty) AND per-block
        popcounts for the page checksums. The dirty block ids come out of
        the kernel's on-device prefix-sum compaction — no host-side
        ``flatnonzero`` over the flag vector. ``kernel_impl="staged"``
        runs the pre-fusion chain instead (dirty_diff + popcnt + the
        shared compaction), reading the live buffer thrice."""
        buf = self._leaf_bytes(cur)
        snap = self._leaf_snapshot(name)
        cl = self.cfg.geometry.cache_line
        impl = self.cfg.kernel_impl
        jbuf = jax.numpy.asarray(buf)
        if snap is None or not self.cfg.delta:
            counts = np.asarray(popcount_blocks(
                jbuf, block_bytes=cl,
                impl="auto" if impl in ("fused", "staged") else impl))
            self._note_scan(buf.size)   # full rewrite: one pass, no diff
            return None, buf, counts
        jsnap = jax.numpy.asarray(snap)
        if impl == "staged":
            flags = dirty_blocks(jbuf, jsnap, block_bytes=cl)
            counts = np.asarray(popcount_blocks(jbuf, block_bytes=cl))
            index, total = compact_index(flags)
            k = int(total)
            dirty_idx = np.asarray(index[:k])
            # dirty_diff read the live bytes, popcnt read them again, and
            # the delta gather re-reads each dirty block
            self._note_scan(2 * buf.size + k * cl)
        else:
            fp = flush_pack(jbuf, jsnap, block_bytes=cl, impl=impl)
            dirty_idx = np.asarray(fp.index[: fp.total])
            counts = np.asarray(fp.counts)
            self._note_scan(buf.size)   # the whole point: one pass
        per_page: Dict[int, set] = {}
        lpp = self.cfg.blocks_per_page
        for b in dirty_idx.tolist():
            per_page.setdefault(b // lpp, set()).add(b % lpp)
        return per_page, buf, counts

    def _leaf_snapshot(self, name: str) -> Optional[np.ndarray]:
        """Last-flushed bytes of a leaf, reassembled from the buffer
        manager's frames (one clean frame per page after each save's
        write-back). ``None`` — the full-rewrite path — when any page's
        snapshot frame was evicted, or before the leaf's first save."""
        if self._cache is None:
            return None
        cfg = self.cfg
        pids = self._leaf_pages[name]
        out = np.empty(len(pids) * cfg.page_size, dtype=np.uint8)
        for i, pid in enumerate(pids):
            frame = self._cache.peek(pid, self.store)
            if frame is None:
                return None
            out[i * cfg.page_size : (i + 1) * cfg.page_size] = frame
        return out[: self._leaf_meta[name]["nbytes"]]

    def save(self, step: int, state: Dict[str, Any]) -> SaveReport:
        if self.pmem is None:
            self._build(state)
        assert self.store is not None and self.manifest is not None
        if set(state) != set(self._leaf_pages):
            raise ValueError("state keys changed between saves")
        cfg = self.cfg
        before: PMemStats = self.pmem.stats.snapshot()
        report = SaveReport(step=step)
        entry: Dict[str, Any] = {"step": step, "shard": self.shard_id, "leaves": {}}

        # Pass 1 — dirty scan + page build: clean pages keep their slot,
        # dirty pages are enqueued on the engine's flush queue.
        self._epoch_report = report
        self._epoch_prev_dirty = {}
        leaf_checks: Dict[str, List[int]] = {}
        for name in sorted(state):
            per_page, buf, counts = self._dirty_lines_per_page(name, state[name])
            report.bytes_logical += buf.size
            pages = self._leaf_pages[name]
            lpp = cfg.blocks_per_page
            checks = []
            for i, pid in enumerate(pages):
                lo = i * cfg.page_size
                page = np.zeros(cfg.page_size, dtype=np.uint8)
                chunk = buf[lo : lo + cfg.page_size]
                page[: chunk.size] = chunk
                report.pages_total += 1
                # page checksum from the fused scan's per-block popcounts
                # (zero padding beyond the leaf contributes 0 bits)
                blk = counts[i * lpp : (i + 1) * lpp]
                checks.append(int((int(blk.sum(dtype=np.uint64)) + 1) & 0xFFFFFFFF))
                if per_page is None:
                    # first save / no delta: full rewrite, forced CoW
                    self._cache.put(pid, page, None, store=self.store)
                    continue
                dirty = per_page.get(i, set())
                if not dirty:
                    report.pages_clean += 1   # previous version still valid
                    continue
                self._cache.put(pid, page, sorted(dirty), store=self.store)
            leaf_checks[name] = checks

        # Pass 2 — the buffer manager's write-back: one lane-partitioned
        # epoch drains every dirty frame (pinned for the duration); the
        # Hybrid µLog-vs-CoW decision sees the epoch's ACTUAL active-lane
        # count, not the constructor's thread constant. The frames stay
        # resident holding exactly the flushed bytes — the next save's
        # dirty-diff snapshots.
        epoch = self._cache.writeback(self.store)
        report.active_lanes = max(1, epoch.active_lanes)
        report.pages_spilled = epoch.pages_spilled
        report.spill_ns = epoch.spill_ns
        report.scan_read_bytes = epoch.scan_read_bytes
        report.scan_ns = epoch.scan_ns
        self._prev_dirty.update(self._epoch_prev_dirty)

        # Pass 3 — manifest records from the post-epoch page table. A
        # page whose slot spilled during the epoch is recorded with
        # slot -1 and its SSD-resident pvn: restore reads it back through
        # the spill map (same checksum verification, different tier).
        for name in sorted(state):
            page_records = [self._page_record(pid)
                            for pid in self._leaf_pages[name]]
            entry["leaves"][name] = dict(
                self._leaf_meta[name], pages=page_records,
                checksums=leaf_checks[name])

        # commit: one Zero-log barrier makes the whole checkpoint durable
        self.manifest.append(json.dumps(entry).encode())
        self.pmem.fsync()
        self._saves += 1
        delta = self.pmem.stats.delta(before)
        report.barriers = delta.barriers
        report.blocks_written = delta.blocks_written
        report.modeled_ns = COST_MODEL.engine_time_ns(
            delta, active_lanes=report.active_lanes, kind=FlushKind.NT,
            pattern=AccessPattern.SEQUENTIAL, burst=True,
            scan_read_bytes=report.scan_read_bytes)
        return report

    def _page_record(self, pid: int) -> List[int]:
        """Manifest record for one page: ``[pid, slot, pvn]`` when PMem-
        resident, ``[pid, -1, pvn]`` when its current version lives on
        the shard's SSD tier."""
        rec = self.store.table.get(pid)
        if rec is not None:
            return [pid, rec[0], rec[1]]
        return [pid, -1, self._spilled_pvn[pid]]

    def _engine_flush_page(self, pid: int, page: np.ndarray,
                           dirty: Optional[List[int]], active: int) -> str:
        """``flush_fn`` for the save epoch's flush queue: the shadow-slot
        protocol of :meth:`_flush_page` with the Hybrid decision taken at
        the epoch's actual active-lane count."""
        force_cow = dirty is None
        lines = list(range(self.cfg.blocks_per_page)) if force_cow else list(dirty)
        tech = self._flush_page(pid, page, lines, force_cow,
                                self._epoch_report, threads=active)
        self._epoch_prev_dirty[pid] = set(lines)
        return tech

    def _flush_page(self, pid: int, page: np.ndarray, dirty: List[int],
                    force_cow: bool, report: SaveReport, *,
                    threads: Optional[int] = None) -> str:
        store = self.store
        t = self.cfg.threads if threads is None else threads
        shadow = self._shadow.get(pid)
        use_mulog = (
            not force_cow
            and self.cfg.delta
            and shadow is not None
            and pid in store.table
            and store.policy.prefer_mulog(
                len(set(dirty) | self._prev_dirty.get(pid, set())), t)
        )
        if use_mulog:
            # shadow-slot delta must cover change since v-1 = union of the
            # last two saves' dirty sets
            lines = sorted(set(dirty) | self._prev_dirty.get(pid, set()))
            old_current = store.table[pid][0]
            store.flush_mulog(pid, page, lines, target_slot=shadow)
            self._shadow[pid] = old_current
            report.pages_mulog += 1
            return "mulog"
        old = store.table.get(pid)
        store.flush_cow(pid, page, retire_old=False)
        if old is not None:
            prev_shadow = self._shadow.get(pid)
            if prev_shadow is not None:
                store.free.append(prev_shadow)   # v-2 slot is released
            self._shadow[pid] = old[0]
        report.pages_cow += 1
        return "cow"

    # ---------------------------------------------------------- restore

    def restore(self, *, path: Optional[str] = None,
                verify: bool = True) -> Tuple[int, Dict[str, np.ndarray]]:
        """Recover the newest committed checkpoint that verifies.

        Walks manifest entries newest-first; for each, checks every page's
        slot header still carries the recorded (pid, pvn) and the page data
        matches the recorded popcount checksum. Falls back to older
        manifests if a newer one was partially overwritten (can only happen
        beyond the double-buffer guarantee, but verification is cheap
        insurance at restore time).

        Checksum verification and image assembly run as ONE device pass
        per leaf through the fused ``apply_unpack`` kernel (the inverse
        of the save scan's ``flush_pack``); ``cfg.kernel_impl="staged"``
        keeps the pre-fusion verify-then-copy chain, which reads the
        restored bytes twice. Either way the read traffic and modeled
        time land in :attr:`last_restore` (a :class:`RestoreReport`)."""
        path = path or self.path
        cfg = self.cfg
        if self.pool is None:
            if path is None:
                raise ValueError("nothing to restore from")
            if not os.path.exists(path):
                raise FileNotFoundError(path)
            self.pool = Pool.open(path)
            self.pmem = self.pool.pmem
            self.manifest = self.pool.log("manifest")
        if cfg.pmem_slot_budget is not None and self._spill is None:
            from repro.tier import SpillScheduler
            if self._ssd is None:
                raise ValueError(
                    "this shard was saved with a PMem slot budget — its "
                    "cold pages live on SSD; pass the shard's SSD device "
                    "to CheckpointManager(ssd=...) before restoring")
            self.pool.attach_ssd(self._ssd)
            self._spill = SpillScheduler(self.pool, name="sp",
                                         map_capacity=_SPILL_MAP_CAPACITY)
        rec = self.manifest.recover()
        if not rec.entries:
            raise FileNotFoundError("no committed checkpoint manifest")
        # layout from the durable directory record — deliberately without
        # opening the page store (that would replay µlogs before the
        # manifests are verified against the untouched image)
        self._layout = self.pool.pages_layout("pages")
        img = self.pmem.durable_view()
        before: PMemStats = self.pmem.stats.snapshot()
        report = RestoreReport(kernel_impl=cfg.kernel_impl)
        self._restore_read_bytes = 0
        self._restore_pages_spilled = 0
        for raw in reversed(rec.entries):
            entry = json.loads(raw.decode())
            report.entries_tried += 1
            state = self._try_restore_entry(entry, img, verify)
            if state is not None:
                self._adopt(entry, state)
                report.step = entry["step"]
                report.pages_total = sum(
                    len(meta["pages"]) for meta in entry["leaves"].values())
                report.pages_spilled = self._restore_pages_spilled
                report.restore_read_bytes = self._restore_read_bytes
                report.scan_ns = COST_MODEL.scan_read_ns(
                    report.restore_read_bytes)
                report.modeled_ns = COST_MODEL.engine_time_ns(
                    self.pmem.stats.delta(before),
                    active_lanes=max(1, cfg.threads),
                    scan_read_bytes=report.restore_read_bytes)
                self.last_restore = report
                return entry["step"], state
        raise RuntimeError("no manifest entry verifies — checkpoint corrupt")

    def _try_restore_entry(self, entry: Dict[str, Any], img: np.ndarray,
                           verify: bool) -> Optional[Dict[str, np.ndarray]]:
        """One manifest entry → recovered state, or None if it no longer
        verifies. The slot-header checks are host-side (a 12-byte unpack
        per page); the data work — checksum verification + image
        assembly — is one fused ``apply_unpack`` pass per leaf, or the
        staged verify-then-copy chain under ``kernel_impl="staged"``."""
        import struct as _s
        cfg = self.cfg
        state: Dict[str, np.ndarray] = {}
        layout = self._layout
        staged = cfg.kernel_impl == "staged" or cfg.page_size % 128 != 0
        for name, meta in entry["leaves"].items():
            pages: List[Optional[np.ndarray]] = []
            spilled: List[Tuple[int, int, int]] = []   # (pos, pid, pvn)
            for i, (pid, slot, pvn) in enumerate(meta["pages"]):
                if slot == -1:
                    # SSD-resident page: the manifest pinned its pvn; the
                    # spill map must still hold exactly that version
                    if self._spill is None:
                        return None
                    spilled.append((i, pid, pvn))
                    pages.append(None)
                    continue
                hdr_pid, hdr_pvn = _s.unpack_from("<IQ", img,
                                                  layout.slot_off(slot))
                if hdr_pid != pid or hdr_pvn != pvn:
                    return None   # slot was reused; not restorable
                off = layout.slot_data_off(slot)
                pages.append(img[off : off + cfg.page_size])
            if spilled:
                try:
                    got = self._spill.read_spilled_many(
                        "pages", [(pid, pvn) for _, pid, pvn in spilled])
                except (KeyError, RuntimeError):
                    return None
                for (pos, _, _), page in zip(spilled, got):
                    pages[pos] = page
                self._restore_pages_spilled += len(spilled)
            csums = meta["checksums"]
            if staged:
                buf = self._staged_assemble(pages, csums, verify)
            else:
                buf = self._fused_assemble(pages, csums, verify)
            if buf is None:
                return None
            arr = buf[: meta["nbytes"]].view(np.dtype(meta["dtype"]))
            state[name] = arr.reshape(meta["shape"])
        return state

    def _staged_assemble(self, pages: Sequence[np.ndarray],
                         csums: Sequence[int],
                         verify: bool) -> Optional[np.ndarray]:
        """Pre-fusion restore chain: a popcount pass over every page to
        verify it, then a second pass copying it into the leaf image —
        the restored bytes cross the device twice."""
        cfg = self.cfg
        buf = np.zeros(len(pages) * cfg.page_size, dtype=np.uint8)
        for i, (page, csum) in enumerate(zip(pages, csums)):
            self._restore_read_bytes += (2 if verify else 1) * cfg.page_size
            if verify and csum and int((popcount(page) + 1) & 0xFFFFFFFF) != csum:
                return None
            buf[i * cfg.page_size : (i + 1) * cfg.page_size] = page
        return buf

    def _fused_assemble(self, pages: Sequence[np.ndarray],
                        csums: Sequence[int],
                        verify: bool) -> Optional[np.ndarray]:
        """Fused restore: ONE ``apply_unpack`` device pass verifies every
        page's popcount against its manifest checksum AND scatters it to
        its offset of the leaf image. A manifest checksum of 0 means
        "never recorded" and is skipped, like the staged chain does."""
        cfg = self.cfg
        k = len(pages)
        packed = (np.concatenate([np.asarray(p, dtype=np.uint8)
                                  for p in pages])
                  if k else np.zeros(0, dtype=np.uint8))
        base = np.zeros(k * cfg.page_size, dtype=np.uint8)
        # manifest stores popcount+1 (the Zero-log cnt==0 convention)
        expected = ((np.asarray(csums, dtype=np.int64) - 1)
                    & 0xFFFFFFFF).astype(np.uint32)
        res = apply_unpack(base, packed,
                           np.arange(k, dtype=np.int32), expected,
                           block_bytes=cfg.page_size,
                           impl=cfg.kernel_impl)
        self._restore_read_bytes += k * cfg.page_size   # one pass, fused
        if verify and res.nbad:
            skip = np.asarray(csums, dtype=np.uint32) == 0
            if np.any((np.asarray(res.ok) == 0) & ~skip):
                return None
        return np.asarray(res.out)

    def _adopt(self, entry: Dict[str, Any], state: Dict[str, np.ndarray]) -> None:
        """Rebuild volatile metadata so saving can continue after restore."""
        cfg = self.cfg
        self._leaf_pages = {}
        self._leaf_meta = {}
        # open the pages region now (µlog replay is safe post-verification)
        self._pages = self.pool.pages("pages", threads=cfg.threads)
        self.store = self._pages.store
        self._layout = self._pages.layout
        if self._spill is not None:
            self._spill.attach_pages(self._pages,
                                     on_evict=self._on_page_evicted)
        self._flushq = self._pages.flush_queue(
            lanes=cfg.threads, flush_fn=self._engine_flush_page,
            spill=self._spill)
        self._cache = self._pool_cache(self._layout.npages)
        self._cache.attach_pages(self._pages, flushq=self._flushq,
                                 spill=self._spill)
        self._cache.invalidate(self.store)
        referenced = set()
        self._spilled_pvn = {}
        for name, meta in entry["leaves"].items():
            self._leaf_pages[name] = [p[0] for p in meta["pages"]]
            self._leaf_meta[name] = {k: meta[k] for k in ("shape", "dtype", "nbytes")}
            for pid, slot, pvn in meta["pages"]:
                if slot == -1:
                    # SSD-resident: stays with the spill map; keep any
                    # stale PMem header out of the table (lower pvn loses
                    # the cross-tier rule anyway)
                    self._spilled_pvn[pid] = pvn
                    self.store.table.pop(pid, None)
                    continue
                referenced.add(slot)
                # trust the committed manifest over µlog-advanced versions
                self.store.table[pid] = (slot, pvn)
            # seed the snapshot frames from the restored bytes, so the
            # next save delta-diffs instead of rewriting every page
            buf = self._leaf_bytes(state[name])
            for i, pid in enumerate(self._leaf_pages[name]):
                page = np.zeros(cfg.page_size, dtype=np.uint8)
                chunk = buf[i * cfg.page_size : (i + 1) * cfg.page_size]
                page[: chunk.size] = chunk
                self._cache.install(pid, page, store=self.store)
        self.store.free = [s for s in range(self._layout.nslots)
                           if s not in referenced]
        self._shadow = {}
        self._prev_dirty = {}
