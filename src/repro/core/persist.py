"""Persistency-barrier model (paper §3.1).

On x86/Optane, durability of a store requires (a) forcing the cache line out
of the CPU cache (``clflush`` / ``clflushopt`` / ``clwb`` or a non-temporal
streaming store) and (b) an ``sfence`` that waits until the line reached the
persistent domain (ADR — the DIMM's battery-backed write buffer).

    void persist(void* ptr) { clwb(ptr); sfence(); }

The paper's cost unit is the *persistency barrier* (flush + sfence): Zero
logging needs 1 per log entry, Header/Classic need 2, CoW page flush needs
2 (with pvn) or 3 (with explicit invalidation), µLog needs 4.

TPU adaptation note: the role-equivalent ordering point on a TPU host is
"device→host DMA complete, then durable-media ack (fsync/O_DIRECT)". We keep
the paper's terminology; :class:`FlushKind` distinguishes the four x86
variants because Fig. 4 shows they have different latencies (Cascade Lake
implements clwb as flushopt; streaming stores skip the read-for-ownership).
"""

from __future__ import annotations

import enum


class FlushKind(enum.Enum):
    """The four ways of forcing data out of the CPU cache (paper Fig. 4)."""

    FLUSH = "flush"        # clflush: write back + invalidate
    FLUSHOPT = "flushopt"  # clflushopt: weaker ordering, still invalidates
    CLWB = "clwb"          # cache line write back, line stays valid
    NT = "nt"              # non-temporal (streaming) store, bypasses cache


class AccessPattern(enum.Enum):
    """Write-target pattern; same-line rewrites are the pathological case
    the paper highlights (Fig. 4 left group, §2.3)."""

    SAME_LINE = "same"
    SEQUENTIAL = "seq"
    RANDOM = "rand"


#: Invalid page/log identifier used by the failure-atomicity protocols.
INVALID_PID: int = 0xFFFFFFFF
