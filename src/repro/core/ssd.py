"""Modeled flash (SSD) device — the capacity tier below PMem.

The paper positions PMem *between* DRAM and flash: a three-tier
hierarchy in which PMem is fast but capacity-constrained, and cold data
overflows to block-addressed NAND. This module is the flash analogue of
:class:`repro.core.pmem.PMem`: a *functional* device model (which bytes
are durable when) plus *exact operation counts* that
:class:`repro.core.costmodel.SSDCostModel` converts to modeled time.

Differences from the PMem model, mirroring the real device gap:

* **Block granularity.** The device services whole 4 KiB blocks. A read
  touches every covering block; a write that covers only part of a block
  is a read-modify-write (``rmw_blocks``) — flash cannot update bytes in
  place, so sub-block writes pay a block read plus a block program.
* **Write-buffered durability.** Writes land in the device's volatile
  write cache and become durable only at :meth:`flush` (fsync /
  FLUSH CACHE). A crash may keep an *arbitrary subset* of unflushed
  block writes — exactly the discipline the PMem model applies to
  unfenced cache lines, and what the crash-during-spill property tests
  exercise.
* **Read/write asymmetry.** Reads and writes are counted separately
  (``blocks_read`` / ``blocks_written``) because the cost model charges
  them asymmetrically: NAND page reads are device-latency bound while
  programs are bandwidth/erase bound (the Fig. 1 gap between Optane and
  flash — PMem sits orders of magnitude closer to DRAM than the SSD on
  both axes, but the SSD's *write* side is the farther of its two).

The device is deliberately address-space separate from PMem: pool
directory records of kind ``KIND_SSD`` name ranges of *this* device
(see :meth:`repro.pool.Pool.ssd_region`), so PMem byte offsets and SSD
byte offsets can never be confused.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, Optional, Set

import numpy as np

__all__ = ["SSD", "SSDStats", "SSD_BLOCK"]

#: default flash block (logical-block/page) size in bytes
SSD_BLOCK = 4096


@dataclasses.dataclass
class SSDStats:
    """Exact SSD operation counts. All fields are monotonic counters."""

    reads: int = 0
    read_bytes: int = 0
    writes: int = 0
    write_bytes: int = 0
    blocks_read: int = 0      # device blocks touched by reads
    blocks_written: int = 0   # device blocks programmed (after coalescing)
    rmw_blocks: int = 0       # programs covering < a full block (read-modify-write)
    flushes: int = 0          # FLUSH CACHE / fsync commands

    def snapshot(self) -> "SSDStats":
        """A frozen copy, for windowed deltas."""
        return dataclasses.replace(self)

    def delta(self, since: "SSDStats") -> "SSDStats":
        """Counts accrued since ``since`` (an earlier :meth:`snapshot`)."""
        d = SSDStats()
        for f in dataclasses.fields(SSDStats):
            setattr(d, f.name, getattr(self, f.name) - getattr(since, f.name))
        return d


class SSD:
    """A block-addressed flash device with a volatile write cache.

    ``pwrite``/``pread`` move bytes; durability requires :meth:`flush`.
    Like :class:`~repro.core.pmem.PMem`, the model separates functional
    semantics (durable vs cached bytes, crash simulation) from cost
    accounting (:class:`SSDStats`, converted to time by
    :class:`~repro.core.costmodel.SSDCostModel`).
    """

    def __init__(self, size: int, *, path: Optional[str] = None,
                 block: int = SSD_BLOCK) -> None:
        """Create a device of ``size`` bytes.

        Args:
            size: device capacity in bytes.
            path: optional backing file (``np.memmap``); ``None`` keeps
                the device in memory (simulations and benchmarks).
            block: device block size in bytes (default 4 KiB).
        """
        self.size = int(size)
        self.block = int(block)
        if self.block <= 0:
            raise ValueError("block must be positive")
        if path is not None:
            exists = os.path.exists(path) and os.path.getsize(path) == self.size
            mode = "r+" if exists else "w+"
            self._durable = np.memmap(path, dtype=np.uint8, mode=mode,
                                      shape=(self.size,))
        else:
            self._durable = np.zeros(self.size, dtype=np.uint8)
        self.path = path
        #: unflushed block writes: block index -> block image (write cache)
        self._cache: Dict[int, np.ndarray] = {}
        self.stats = SSDStats()

    # ------------------------------------------------------------------ io

    def _check(self, off: int, size: int) -> None:
        if off < 0 or size < 0 or off + size > self.size:
            raise ValueError(
                f"SSD access [{off}, {off + size}) outside device of "
                f"{self.size} B")

    def _blocks(self, off: int, size: int) -> range:
        if size <= 0:
            return range(0)
        return range(off // self.block, (off + size - 1) // self.block + 1)

    def _block_image(self, b: int) -> np.ndarray:
        """Current (cache-merged) contents of block ``b``."""
        if b in self._cache:
            return self._cache[b]
        lo = b * self.block
        hi = min(lo + self.block, self.size)
        img = np.zeros(self.block, dtype=np.uint8)
        img[: hi - lo] = self._durable[lo:hi]
        return img

    def pwrite(self, off: int, data: bytes | np.ndarray) -> None:
        """Write bytes at ``off`` into the device's write cache.

        The data is NOT durable until :meth:`flush`. Writes covering only
        part of a block count as read-modify-writes (``rmw_blocks``).
        """
        buf = (np.frombuffer(bytes(data), dtype=np.uint8)
               if not isinstance(data, np.ndarray)
               else data.astype(np.uint8, copy=False).ravel())
        n = buf.size
        self._check(off, n)
        if n == 0:
            return
        self.stats.writes += 1
        self.stats.write_bytes += n
        for b in self._blocks(off, n):
            lo = b * self.block
            img = self._block_image(b)
            s = max(off, lo) - lo
            e = min(off + n, lo + self.block) - lo
            img[s:e] = buf[max(off, lo) - off : min(off + n, lo + self.block) - off]
            covered = e - s
            if covered < min(self.block, self.size - lo) and b not in self._cache:
                self.stats.rmw_blocks += 1
            self._cache[b] = img

    def pread(self, off: int, size: int) -> np.ndarray:
        """Read bytes (sees unflushed cached writes). Counts the covering
        device blocks as reads."""
        self._check(off, size)
        self.stats.reads += 1
        self.stats.read_bytes += size
        out = np.zeros(size, dtype=np.uint8)
        for b in self._blocks(off, size):
            self.stats.blocks_read += 1
            lo = b * self.block
            img = self._block_image(b)
            s = max(off, lo)
            e = min(off + size, lo + self.block)
            out[s - off : e - off] = img[s - lo : e - lo]
        return out

    # ----------------------------------------------------------- durability

    def flush(self) -> None:
        """FLUSH CACHE: commit every cached block write to durable media.
        Each committed block counts as one programmed block."""
        self.stats.flushes += 1
        self._commit(set(self._cache))
        self._cache.clear()

    def _commit(self, blocks: Set[int]) -> None:
        for b in sorted(blocks):
            img = self._cache.get(b)
            if img is None:
                continue
            lo = b * self.block
            hi = min(lo + self.block, self.size)
            self._durable[lo:hi] = img[: hi - lo]
            self.stats.blocks_written += 1

    def durable_read(self, off: int, size: int) -> np.ndarray:
        """The durable image of a range (what recovery would see), without
        touching the read counters — a recovery-inspection primitive, the
        analogue of :meth:`PMem.durable_slice`."""
        self._check(off, size)
        return np.array(self._durable[off : off + size], copy=True)

    def crash(self, *, keep: Optional[Callable[[int], bool]] = None,
              rng: Optional[np.random.Generator] = None,
              keep_prob: float = 0.5) -> Set[int]:
        """Simulate power failure: each unflushed cached block write may or
        may not have reached media (``keep`` per block index, or
        Bernoulli(``keep_prob``) under ``rng``). Returns the block indices
        that survived; the cache is dropped."""
        if keep is None:
            gen = rng or np.random.default_rng(0)
            keep = lambda b: bool(gen.random() < keep_prob)  # noqa: E731
        survivors = {b for b in self._cache if keep(b)}
        self._commit(survivors)
        self._cache.clear()
        return survivors

    def fsync(self) -> None:
        """Push the durable image to the backing file (file-backed devices).
        Device-cache durability is :meth:`flush`; this is host-side."""
        if isinstance(self._durable, np.memmap):
            self._durable.flush()

    @property
    def pending_blocks(self) -> int:
        """Unflushed block writes sitting in the device write cache."""
        return len(self._cache)

    def reset_stats(self) -> SSDStats:
        """Swap in fresh counters; returns the old ones."""
        old = self.stats
        self.stats = SSDStats()
        return old
