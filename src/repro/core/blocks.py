"""Block/cache-line geometry of PMem (paper §2.2).

The paper's central physical observation: Optane DC PMM internally operates
on 256-byte blocks (4 cache lines) behind a small write-combining buffer,
while the CPU transfer granule stays 64 bytes. Guideline G1: "Algorithms
should no longer be designed to fit data on single cache lines (64 byte) but
on PMem blocks (256 byte)."

On TPU we additionally expose a ``tpu_tile`` geometry: the natural device
block of one float32 (8, 128) VREG tile = 4096 bytes, used by the
delta-checkpoint layer when tracking dirtiness of HBM-resident parameters.
"""

from __future__ import annotations

import dataclasses

CACHE_LINE: int = 64
PMEM_BLOCK: int = 256  # 4 cache lines — Optane internal write granule
TPU_TILE: int = 4096   # (8, 128) f32 tile — TPU-native "block"

LINES_PER_BLOCK: int = PMEM_BLOCK // CACHE_LINE


def align_down(off: int, granule: int) -> int:
    return off - (off % granule)


def align_up(off: int, granule: int) -> int:
    return -(-off // granule) * granule


def line_index(off: int) -> int:
    """Cache line number covering byte offset ``off``."""
    return off // CACHE_LINE


def block_index(off: int) -> int:
    """PMem block number covering byte offset ``off``."""
    return off // PMEM_BLOCK


def lines_covering(off: int, size: int) -> range:
    """All cache-line indices touched by the byte range [off, off+size)."""
    if size <= 0:
        return range(0)
    return range(off // CACHE_LINE, (off + size - 1) // CACHE_LINE + 1)


def blocks_covering(off: int, size: int, block: int = PMEM_BLOCK) -> range:
    if size <= 0:
        return range(0)
    return range(off // block, (off + size - 1) // block + 1)


@dataclasses.dataclass(frozen=True)
class BlockGeometry:
    """Configurable geometry so the same algorithms run in paper mode
    (256 B Optane blocks) and TPU mode (4 KB tiles)."""

    cache_line: int = CACHE_LINE
    block: int = PMEM_BLOCK

    @property
    def lines_per_block(self) -> int:
        return self.block // self.cache_line

    def pad_to_line(self, size: int) -> int:
        return align_up(size, self.cache_line)

    def pad_to_block(self, size: int) -> int:
        return align_up(size, self.block)


PAPER_GEOMETRY = BlockGeometry()
#: Checkpoint-layer geometry: the dirty-tracking unit ("cache line") is one
#: 4 KiB TPU tile (= the Pallas kernels' block), and the device write
#: granule ("block") is 16 KiB — preserving the paper's 4:1 line:block ratio
#: at TPU-native sizes.
TPU_GEOMETRY = BlockGeometry(cache_line=TPU_TILE, block=4 * TPU_TILE)
