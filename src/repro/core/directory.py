"""Durable region directory — the allocation substrate of :mod:`repro.pool`.

A *pool* region starts with a small table of named, typed, geometry-tagged
region records. The table is built from the repo's own primitives and is
failure-atomic by the same arguments the paper makes for page headers and
the ping-pong root:

* The **superblock** (line 0) records magic, format version, geometry and
  table capacity. It is written once at format time, behind a persistency
  barrier.
* Each **entry** occupies exactly one cache line (64 B in paper geometry;
  a 4 KiB tile in checkpoint geometry), so its commit is atomic: after a
  crash the durable image holds either the whole record or none of it
  (lines are never torn, §3.1).
* Validity is *pvn-style*: ``generation == 0`` means "slot never written";
  among duplicate names the highest generation wins (monotonic counter,
  same max-rule as the page-version number of §3.2.1).

Allocation protocol (failure-atomic):

1. *place* — pick the byte range (bump pointer over committed entries) and
   a free entry slot; nothing durable changes.
2. *initialize* — zero the claimed data range (streaming stores + sfence).
   Zero logging requires a zeroed region; page stores read zeroed slot
   headers as invalid, so zero-init is universally safe.
3. *commit* — store the entry line and persist it. One barrier.

A crash before step 3 leaves the directory untouched: the claimed space is
invisible and will be re-claimed (and re-zeroed) by the next allocation.
A spontaneous eviction of the entry line during step 3 is also safe — the
data range was already durably zeroed, so the region appears committed and
empty, which is a valid state. Existing regions are never written by an
allocation, so they survive any crash bit-exact.
"""

from __future__ import annotations

import dataclasses
import struct
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.blocks import BlockGeometry, align_up
from repro.core.persist import FlushKind
from repro.core.pmem import PMem

__all__ = [
    "DIRECTORY_MAGIC",
    "KIND_RAW",
    "KIND_LOG",
    "KIND_PAGES",
    "KIND_SSD",
    "RegionRecord",
    "RegionDirectory",
    "directory_bytes",
    "probe_file",
]

DIRECTORY_MAGIC = b"RPMPOOL\x01"
_FORMAT_VERSION = 1

#: region kinds (the ``kind`` field of an entry)
KIND_RAW = 1    # untyped byte range
KIND_LOG = 2    # Classic/Header/Zero log; meta = (technique, flags, dancing, 0)
KIND_PAGES = 3  # PageStore slot array + µlogs; meta = (page_size, npages,
                #                                       nslots, n_mulogs)
KIND_SSD = 4    # SSD-backed range: ``base`` is an offset in the pool's
                # attached SSD device's address space, NOT in PMem. The
                # entry itself (the name → range binding) lives durably in
                # this PMem table; the range's *content* validity is the
                # consumer's problem (the spill tier gates reads with
                # checksummed map records).

# magic, version, cache_line, block, max_regions, pool_size, sockets
# (sockets == 0 in a pre-NUMA superblock and is read as 1)
_SUPER = struct.Struct("<8sIIIIQI")
# name, kind, generation, base, length, meta[4]  — exactly 64 bytes.
# meta[3]'s high 16 bits carry the region's NUMA home socket for every
# kind (consumers own only the low 16 bits); see RegionRecord.socket.
_ENTRY = struct.Struct("<20sIQQQ4I")
_NAME_BYTES = 20
_SOCKET_SHIFT = 16

assert _ENTRY.size == 64


@dataclasses.dataclass(frozen=True)
class RegionRecord:
    """One committed directory entry."""

    name: str
    kind: int
    generation: int
    base: int
    length: int
    meta: Tuple[int, int, int, int] = (0, 0, 0, 0)

    @property
    def end(self) -> int:
        return self.base + self.length

    @property
    def socket(self) -> int:
        """NUMA home socket of the region's bytes (high 16 bits of
        ``meta[3]``; 0 for regions created socket-unaware). A placement
        hint for the cost model and the lane placer — never a durability
        input: recovery is byte-identical under any socket tag."""
        return (self.meta[3] >> _SOCKET_SHIFT) & 0xFFFF


def directory_bytes(geometry: BlockGeometry, max_regions: int) -> int:
    """Bytes the directory occupies at the head of a pool region
    (superblock line + one line per entry, block-aligned)."""
    return align_up((1 + max_regions) * geometry.cache_line, geometry.block)


class RegionDirectory:
    """Durable name → (base, length, type, params) table over a PMem."""

    def __init__(self, pmem: PMem, max_regions: int) -> None:
        self.pmem = pmem
        self.max_regions = int(max_regions)
        self.records: Dict[str, RegionRecord] = {}
        self._slot_of: Dict[str, int] = {}
        self._next_gen = 1

    # ---------------------------------------------------------- lifecycle

    @classmethod
    def format(cls, pmem: PMem, *, max_regions: int = 64) -> "RegionDirectory":
        """Write a fresh superblock (one barrier). Entry lines are expected
        to be zero (``Pool.create`` zeroes the whole region)."""
        if max_regions < 1:
            raise ValueError("max_regions must be >= 1")
        d = cls(pmem, max_regions)
        table_bytes = directory_bytes(pmem.geometry, max_regions)
        if table_bytes > pmem.size:
            raise ValueError("region too small for the directory table")
        g = pmem.geometry
        # Zero the whole table first so stale bytes can never parse as
        # committed entries, then commit the superblock.
        pmem.store(0, np.zeros(table_bytes, dtype=np.uint8), streaming=True)
        sb = _SUPER.pack(DIRECTORY_MAGIC, _FORMAT_VERSION, g.cache_line,
                         g.block, max_regions, pmem.size, pmem.sockets)
        pmem.store(0, sb, streaming=True)
        pmem.persist(0, table_bytes, kind=FlushKind.NT)
        return d

    @classmethod
    def load(cls, pmem: PMem) -> "RegionDirectory":
        """Open an existing directory from the *durable* image, applying the
        max-generation rule to duplicate names."""
        sb = pmem.durable_slice(0, min(_SUPER.size, pmem.size))
        if sb.size < _SUPER.size:
            raise ValueError("region too small to hold a pool superblock")
        magic, version, cl, blk, max_regions, size, sockets = \
            _SUPER.unpack_from(sb, 0)
        if magic != DIRECTORY_MAGIC:
            raise ValueError("not a pool region (bad directory magic)")
        if version != _FORMAT_VERSION:
            raise ValueError(f"unsupported pool format version {version}")
        g = pmem.geometry
        if (cl, blk) != (g.cache_line, g.block):
            raise ValueError(
                f"pool geometry ({cl}, {blk}) != PMem geometry "
                f"({g.cache_line}, {g.block})")
        if size != pmem.size:
            raise ValueError(f"pool was formatted for {size} B, region is "
                             f"{pmem.size} B")
        # the superblock records the socket topology the pool was
        # formatted for; adopt it (sockets affect accounting only, never
        # layout — unlike geometry, a mismatch cannot corrupt anything)
        if sockets:
            pmem.sockets = max(pmem.sockets, int(sockets))
        d = cls(pmem, max_regions)
        # the table is tiny — read just it, not the whole durable image
        img = pmem.durable_slice(0, (1 + max_regions) * g.cache_line)
        for slot in range(max_regions):
            rec = d._read_entry(img, slot)
            if rec is None:
                continue
            prev = d.records.get(rec.name)
            if prev is None or rec.generation > prev.generation:
                d.records[rec.name] = rec
                d._slot_of[rec.name] = slot
            d._next_gen = max(d._next_gen, rec.generation + 1)
        for rec in d.records.values():
            if rec.kind != KIND_SSD:
                pmem.set_home(rec.base, rec.length, rec.socket)
        return d

    @staticmethod
    def is_formatted(pmem: PMem) -> bool:
        n = min(len(DIRECTORY_MAGIC), pmem.size)
        return bytes(pmem.durable_slice(0, n)) == DIRECTORY_MAGIC

    # ------------------------------------------------------------- layout

    def _entry_off(self, slot: int) -> int:
        return (1 + slot) * self.pmem.geometry.cache_line

    @property
    def data_start(self) -> int:
        """First byte after the entry table."""
        return directory_bytes(self.pmem.geometry, self.max_regions)

    @property
    def data_end(self) -> int:
        """Current PMem bump pointer: first byte past every committed
        PMem-resident region (``KIND_SSD`` records address the SSD device's
        space and do not consume PMem bytes)."""
        end = self.data_start
        for rec in self.records.values():
            if rec.kind != KIND_SSD:
                end = max(end, rec.end)
        return align_up(end, self.pmem.geometry.block)

    @property
    def free_bytes(self) -> int:
        return self.pmem.size - self.data_end

    @property
    def ssd_data_end(self) -> int:
        """Bump pointer over the SSD address space: first SSD byte past
        every committed ``KIND_SSD`` region."""
        end = 0
        for rec in self.records.values():
            if rec.kind == KIND_SSD:
                end = max(end, rec.end)
        return end

    def _read_entry(self, img: np.ndarray, slot: int) -> Optional[RegionRecord]:
        raw_name, kind, gen, base, length, *meta = _ENTRY.unpack_from(
            img, self._entry_off(slot))
        if gen == 0:
            return None
        # defensive sanity — a record that fails these is ignored, never
        # fatal. KIND_SSD bases live in the SSD device's address space, so
        # the PMem bounds do not apply to them.
        if length <= 0:
            return None
        if kind != KIND_SSD and (
                base < self.data_start or base + length > self.pmem.size):
            return None
        try:
            name = raw_name.rstrip(b"\x00").decode("utf-8")
        except UnicodeDecodeError:
            return None
        if not name:
            return None
        return RegionRecord(name, kind, gen, base, length,
                            tuple(int(m) for m in meta))

    # --------------------------------------------------------------- read

    def lookup(self, name: str) -> Optional[RegionRecord]:
        return self.records.get(name)

    def require(self, name: str, kind: int) -> RegionRecord:
        rec = self.records.get(name)
        if rec is None:
            raise KeyError(f"no region named {name!r} in pool")
        if rec.kind != kind:
            raise TypeError(f"region {name!r} has kind {rec.kind}, wanted {kind}")
        return rec

    # ---------------------------------------------------------- allocate

    def allocate(self, name: str, kind: int, length: int,
                 meta: Tuple[int, int, int, int] = (0, 0, 0, 0),
                 socket: int = 0) -> RegionRecord:
        """Failure-atomically allocate a named region: place → zero-init →
        single-line entry commit. See the module docstring for the crash
        argument. ``socket`` tags the region's NUMA home socket (stored in
        the high 16 bits of ``meta[4]``'s last word; a pure performance
        hint — see :attr:`RegionRecord.socket`)."""
        socket = int(socket)
        if not 0 <= socket < max(1, self.pmem.sockets):
            raise ValueError(
                f"socket {socket} outside the pool's {self.pmem.sockets}"
                f"-socket topology")
        if meta[3] >> _SOCKET_SHIFT:
            raise ValueError("meta[3] high bits are reserved for the socket tag")
        meta = (meta[0], meta[1], meta[2],
                (meta[3] & 0xFFFF) | (socket << _SOCKET_SHIFT))
        rec, slot = self._place(name, kind, length, meta)
        self._initialize(rec)
        self._commit(rec, slot)
        return rec

    def allocate_ssd(self, name: str, length: int, ssd_size: int,
                     meta: Tuple[int, int, int, int] = (0, 0, 0, 0),
                     socket: int = 0) -> RegionRecord:
        """Allocate a named range of the pool's SSD address space.

        The binding (name → SSD byte range) is committed in this PMem
        table with the same single-line atomic entry commit as a PMem
        region; the SSD bytes themselves are NOT zero-initialized (the
        directory does not own the device — consumers must gate reads on
        their own validity metadata, e.g. the spill tier's checksummed
        map records).

        Args:
            name: region name (≤ 20 bytes UTF-8, unique in the pool).
            length: SSD bytes to claim.
            ssd_size: capacity of the attached SSD device — the bump
                allocation is bounds-checked against it.
            meta: four consumer-defined ints stored in the entry.
            socket: the region's NUMA home (the socket whose I/O complex
                the device hangs off) — same meta[3] packing as
                :meth:`allocate`; a performance hint only. No
                ``set_home`` mapping: SSD bases are device-space offsets,
                not PMem addresses.
        """
        socket = int(socket)
        if not 0 <= socket < max(1, self.pmem.sockets):
            raise ValueError(
                f"socket {socket} outside the pool's {self.pmem.sockets}"
                f"-socket topology")
        if meta[3] >> _SOCKET_SHIFT:
            raise ValueError("meta[3] high bits are reserved for the socket tag")
        meta = (meta[0], meta[1], meta[2],
                (meta[3] & 0xFFFF) | (socket << _SOCKET_SHIFT))
        slot = self._claim_slot(name, length)
        base = self.ssd_data_end
        if base + length > ssd_size:
            raise RuntimeError(
                f"SSD full: need {length} B at {base}, device is "
                f"{ssd_size} B")
        rec = RegionRecord(name, KIND_SSD, self._next_gen, base, int(length),
                           tuple(int(m) for m in meta))
        self._commit(rec, slot)
        return rec

    def _claim_slot(self, name: str, length: int) -> int:
        """Shared entry admission: validate the name/length and pick a
        free entry slot (purely volatile). One source of truth for both
        the PMem and SSD allocation paths."""
        if name in self.records:
            raise ValueError(f"region {name!r} already exists")
        if len(name.encode("utf-8")) > _NAME_BYTES:
            raise ValueError(f"region name {name!r} longer than {_NAME_BYTES} B")
        if length <= 0:
            raise ValueError("region length must be positive")
        used = set(self._slot_of.values())
        slot = next((s for s in range(self.max_regions) if s not in used), None)
        if slot is None:
            raise RuntimeError(f"directory full ({self.max_regions} regions)")
        return slot

    def _place(self, name: str, kind: int, length: int,
               meta: Tuple[int, int, int, int]) -> Tuple[RegionRecord, int]:
        """Pick the byte range and entry slot. Purely volatile."""
        slot = self._claim_slot(name, length)
        base = self.data_end
        if base + length > self.pmem.size:
            raise RuntimeError(
                f"pool full: need {length} B at {base}, region is "
                f"{self.pmem.size} B")
        rec = RegionRecord(name, kind, self._next_gen, base, int(length),
                           tuple(int(m) for m in meta))
        return rec, slot

    def _initialize(self, rec: RegionRecord, chunk: int = 1 << 20) -> None:
        """Durably zero the claimed range (bulk streaming traffic, fenced
        once). Must complete before the entry commit: a spontaneously
        evicted entry line must only ever expose initialized data."""
        off, end = rec.base, rec.end
        while off < end:
            n = min(chunk, end - off)
            self.pmem.store(off, np.zeros(n, dtype=np.uint8), streaming=True)
            off += n
        self.pmem.sfence()

    def _commit(self, rec: RegionRecord, slot: int) -> None:
        """Atomic commit: the entry fits a single cache line."""
        entry = _ENTRY.pack(rec.name.encode("utf-8"), rec.kind, rec.generation,
                            rec.base, rec.length, *rec.meta)
        off = self._entry_off(slot)
        self.pmem.store(off, entry, streaming=True)
        self.pmem.persist(off, _ENTRY.size, kind=FlushKind.NT)
        self.records[rec.name] = rec
        self._slot_of[rec.name] = slot
        self._next_gen += 1
        if rec.kind != KIND_SSD:
            self.pmem.set_home(rec.base, rec.length, rec.socket)


def probe_file(path: str) -> Optional[Tuple[int, int, int, int, int]]:
    """Read a pool file's superblock without mapping the region.
    Returns ``(cache_line, block, max_regions, size, sockets)`` or
    ``None`` if the file is missing or not a formatted pool (``sockets``
    is 1 for a pre-NUMA superblock)."""
    try:
        with open(path, "rb") as f:
            buf = f.read(_SUPER.size)
    except OSError:
        return None
    if len(buf) < _SUPER.size:
        return None
    magic, version, cl, blk, max_regions, size, sockets = _SUPER.unpack(buf)
    if magic != DIRECTORY_MAGIC or version != _FORMAT_VERSION:
        return None
    return cl, blk, max_regions, size, max(1, sockets)
