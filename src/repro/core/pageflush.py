"""Failure-atomic page flushing (paper §3.2): CoW(+pvn), µLog, Hybrid.

A *page store* is an array of slots on PMem, each slot = one cache line of
header (pid, pvn) + page_size bytes of data. ``nslots > npages`` so CoW
always finds a free slot. Logical pages are located by scanning slot
headers: for each pid the slot with the highest page-version-number (pvn)
holds the current contents — which is exactly why CoW needs no
"invalidate old slot" barrier (3 → 2 barriers, the paper's ≈10 % win).

  CoW (pvn)        — write new slot data (barrier 1), then persist the
                     header (pid, pvn+1) (barrier 2). Header fits one cache
                     line ⇒ it becomes durable atomically: recovery sees
                     either the old version (max pvn = old) or the complete
                     new one.
  CoW (invalidate) — the 3-barrier baseline: invalidate old header, write
                     data, validate. Kept for the ≈10 % comparison.
  µLog             — for small deltas: (1) invalidate µlog, (2) write the
                     dirty lines + target pvn into the µlog, (3) validate
                     µlog, (4) apply dirty lines in place to the page slot
                     — 4 barriers but only ~dirty bytes of traffic.
                     Recovery replays any valid µlog whose pvn is >= the
                     slot's pvn (idempotent; a torn in-place apply is
                     always repaired by the replay).
  Hybrid           — closed-form cost model picks µLog below the dirty-line
                     crossover, CoW above. The crossover *moves with thread
                     count* because multi-threaded small writes defeat the
                     device's write-combining buffer (Fig. 2), amplifying
                     every dirty line to a full 256 B block write:
                     ≈119 dirty lines at 1 thread → ≈31 at 7 threads for
                     16 KB pages, matching Fig. 5 (a)/(c).
"""

from __future__ import annotations

import dataclasses
import math
import struct
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.blocks import BlockGeometry, PAPER_GEOMETRY, align_up
from repro.core.costmodel import COST_MODEL, PMemCostModel
from repro.core.persist import INVALID_PID, FlushKind
from repro.core.pmem import PMem

__all__ = [
    "PageStoreLayout",
    "PageStore",
    "MicroLog",
    "HybridPolicy",
    "recover_page_table",
]

_SLOT_HDR = struct.Struct("<IQ")        # pid, pvn  (12 B, single cache line)
_ULOG_HDR = struct.Struct("<IQII")      # pid, pvn, target slot, nlines
#: target slot meaning "the page's current slot" (paper-faithful in-place µLog)
SLOT_CURRENT = 0xFFFFFFFF


@dataclasses.dataclass(frozen=True)
class PageStoreLayout:
    """Byte layout of a slot array within a PMem region."""

    base: int
    page_size: int
    npages: int
    nslots: int
    geometry: BlockGeometry = PAPER_GEOMETRY
    #: ``nslots <= npages`` is normally an error (CoW must always find a
    #: free slot). A store *overcommits* when a spill tier stands behind
    #: it: the PMem slot array is a cache of a larger logical page space
    #: and the :class:`repro.tier.SpillScheduler` evicts cold slots to SSD
    #: before CoW would run dry.
    overcommit: bool = False

    def __post_init__(self) -> None:
        if self.nslots <= self.npages and not self.overcommit:
            raise ValueError("CoW needs nslots > npages (or overcommit=True "
                             "with a spill tier attached)")
        if self.page_size % self.geometry.cache_line != 0:
            raise ValueError("page_size must be cache-line aligned")

    @property
    def lines_per_page(self) -> int:
        return self.page_size // self.geometry.cache_line

    @property
    def slot_stride(self) -> int:
        return align_up(self.geometry.cache_line + self.page_size, self.geometry.block)

    @property
    def total_bytes(self) -> int:
        return self.nslots * self.slot_stride

    def slot_off(self, slot: int) -> int:
        return self.base + slot * self.slot_stride

    def slot_data_off(self, slot: int) -> int:
        return self.slot_off(slot) + self.geometry.cache_line


def recover_page_table(pmem: PMem, layout: PageStoreLayout) -> Dict[int, Tuple[int, int]]:
    """Scan all slot headers in the durable image; return pid -> (slot, pvn)
    picking the highest pvn per pid (paper §3.2.1 recovery)."""
    img = pmem.durable_view()
    table: Dict[int, Tuple[int, int]] = {}
    for s in range(layout.nslots):
        pid, pvn = _SLOT_HDR.unpack_from(img, layout.slot_off(s))
        if pid == INVALID_PID or pvn == 0 or pid >= layout.npages:
            continue
        if pid not in table or pvn > table[pid][1]:
            table[pid] = (s, pvn)
    return table


class MicroLog:
    """One µLog area: header line + line-index array + line-data array."""

    def __init__(self, pmem: PMem, base: int, layout: PageStoreLayout) -> None:
        self.pmem = pmem
        self.base = base
        self.layout = layout
        g = layout.geometry
        self.idx_off = base + g.cache_line
        idx_bytes = align_up(4 * layout.lines_per_page, g.cache_line)
        self.data_off = self.idx_off + idx_bytes
        self.total_bytes = (self.data_off - base) + layout.lines_per_page * g.cache_line

    # Steps follow Listing 1 (right column) with the pvn + target-slot
    # extensions (the checkpoint layer applies deltas onto a *shadow* slot
    # so the previously committed snapshot stays intact).
    def invalidate(self) -> None:
        self.pmem.store(self.base, _ULOG_HDR.pack(INVALID_PID, 0, 0, 0), streaming=True)
        self.pmem.persist(self.base, _ULOG_HDR.size, kind=FlushKind.NT)   # barrier 1

    def write(self, pvn: int, lines: Sequence[int], line_data: np.ndarray,
              target_slot: int = SLOT_CURRENT) -> None:
        g = self.layout.geometry
        idx = np.asarray(lines, dtype=np.uint32)
        self.pmem.store(self.idx_off, idx.tobytes(), streaming=True)
        self.pmem.store(self.data_off, line_data.tobytes(), streaming=True)
        # header body (pvn, slot, nlines) shares the header line; pid stays
        # INVALID until validate()
        self.pmem.store(
            self.base,
            _ULOG_HDR.pack(INVALID_PID, pvn, target_slot, len(lines)),
            streaming=True,
        )
        self.pmem.sfence()                                                # barrier 2

    def validate(self, pid: int) -> None:
        hdr = self.pmem.load(self.base, _ULOG_HDR.size)
        _, pvn, slot, nlines = _ULOG_HDR.unpack(hdr.tobytes())
        self.pmem.store(self.base, _ULOG_HDR.pack(pid, pvn, slot, nlines), streaming=True)
        self.pmem.persist(self.base, _ULOG_HDR.size, kind=FlushKind.NT)   # barrier 3

    def read_durable(self) -> Optional[Tuple[int, int, int, np.ndarray, np.ndarray]]:
        """(pid, pvn, slot, line_idx[n], line_data[n, cl]) if durably valid."""
        img = self.pmem.durable_view()
        pid, pvn, slot, nlines = _ULOG_HDR.unpack_from(img, self.base)
        if pid == INVALID_PID or pid >= self.layout.npages or nlines == 0:
            return None
        if nlines > self.layout.lines_per_page:
            return None
        if slot != SLOT_CURRENT and slot >= self.layout.nslots:
            return None
        g = self.layout.geometry
        idx = np.frombuffer(
            img[self.idx_off : self.idx_off + 4 * nlines].tobytes(), dtype=np.uint32
        )
        data = np.frombuffer(
            img[self.data_off : self.data_off + nlines * g.cache_line].tobytes(),
            dtype=np.uint8,
        ).reshape(nlines, g.cache_line)
        if (idx >= self.layout.lines_per_page).any():
            return None
        return int(pid), int(pvn), int(slot), idx, data


class PageStore:
    """Failure-atomic page store over a PMem region (CoW / µLog / hybrid)."""

    def __init__(
        self,
        pmem: PMem,
        layout: PageStoreLayout,
        *,
        n_mulogs: int = 1,
        cost_model: PMemCostModel = COST_MODEL,
        threads: int = 1,
    ) -> None:
        self.pmem = pmem
        self.layout = layout
        self.cost_model = cost_model
        self.threads = threads
        g = layout.geometry
        mulog_base = align_up(layout.base + layout.total_bytes, g.block)
        self.mulogs = []
        off = mulog_base
        self.total_end = off
        for _ in range(n_mulogs):
            ml = MicroLog(pmem, off, layout)
            off = align_up(off + ml.total_bytes, g.block)
            self.total_end = off
            self.mulogs.append(ml)
        self._next_mulog = 0
        # Volatile state rebuilt on open: pid -> (slot, pvn); free slots.
        self.table: Dict[int, Tuple[int, int]] = {}
        self.free: List[int] = list(range(layout.nslots))
        # pid -> minimum pvn history (maintained by the spill tier): a
        # page whose version history continued on SSD must re-enter PMem
        # strictly above it, or recovery's max-pvn rule could resurrect a
        # stale durable header or a stale SSD copy.
        self.pvn_floor: Dict[int, int] = {}
        self.policy = HybridPolicy(layout, cost_model)

    # ------------------------------------------------------------ sizing

    @staticmethod
    def region_bytes(layout: PageStoreLayout, *, n_mulogs: int = 1) -> int:
        """Bytes from ``layout.base`` to ``total_end`` for a store with
        ``n_mulogs`` micro logs — the exact span ``__init__`` lays out,
        assuming ``layout.base`` is block-aligned."""
        g = layout.geometry
        mulog_hdr_idx = g.cache_line + align_up(4 * layout.lines_per_page,
                                                g.cache_line)
        mulog_total = mulog_hdr_idx + layout.lines_per_page * g.cache_line
        off = align_up(layout.base + layout.total_bytes, g.block)
        for _ in range(n_mulogs):
            off = align_up(off + mulog_total, g.block)
        return off - layout.base

    # ------------------------------------------------------------- open

    @classmethod
    def open(cls, pmem: PMem, layout: PageStoreLayout, **kw) -> "PageStore":
        """Recover: rebuild the page table from slot headers, then replay
        any valid µlog with pvn >= the slot's (torn-apply repair)."""
        store = cls(pmem, layout, **kw)
        store.table = recover_page_table(pmem, layout)
        for ml in store.mulogs:
            rec = ml.read_durable()
            if rec is None:
                continue
            pid, pvn, target, idx, data = rec
            if pid not in store.table:
                continue
            slot, slot_pvn = store.table[pid]
            if target != SLOT_CURRENT:
                # checkpoint-layer shadow-slot delta: apply onto the
                # recorded slot — but ONLY while that slot still belongs
                # to this page at a not-newer version. The slot may have
                # been freed (spill-tier eviction) and reused by another
                # page, or re-CoW'd by this page at a higher pvn; an
                # unconditional apply would corrupt the new occupant. A
                # torn apply (header at pvn, some data lines lost) still
                # replays: hdr_pid matches and hdr_pvn <= pvn.
                slot = target
                hdr_pid, hdr_pvn = _SLOT_HDR.unpack_from(
                    pmem.durable_view(), layout.slot_off(target))
                if hdr_pid != pid or hdr_pvn > pvn:
                    continue  # slot reused / superseded: µlog is stale
            elif pvn < slot_pvn:
                continue  # stale in-place µlog, superseded by a newer CoW
            g = layout.geometry
            doff = layout.slot_data_off(slot)
            for li, line in zip(idx.tolist(), data):
                pmem.store(doff + li * g.cache_line, line.tobytes(), streaming=True)
            pmem.store(layout.slot_off(slot), _SLOT_HDR.pack(pid, pvn), streaming=True)
            pmem.sfence()
            if pvn >= store.table.get(pid, (0, 0))[1]:
                store.table[pid] = (slot, pvn)
        used = {s for s, _ in store.table.values()}
        store.free = [s for s in range(layout.nslots) if s not in used]
        return store

    # ------------------------------------------------------------ flush

    def _alloc_slot(self) -> int:
        if not self.free:
            raise RuntimeError("no free slots")
        return self.free.pop()

    def flush_cow(
        self,
        pid: int,
        page: np.ndarray,
        *,
        dirty_lines: Optional[Sequence[int]] = None,
        invalidate_first: bool = False,
        retire_old: bool = True,
        pvn_floor: int = 0,
    ) -> None:
        """Copy-on-write flush. ``dirty_lines`` given ⇒ the ☆ variant of
        Fig. 5: only dirty lines are in DRAM, clean lines are read back
        from the old PMem slot (device reads). ``invalidate_first`` selects
        the legacy 3-barrier protocol (≈10 % slower, §3.2.1).
        ``retire_old=False`` leaves the superseded slot OUT of the free
        list — the caller owns it (checkpoint shadow slots). ``pvn_floor``
        forces the new version number past a given value — the spill
        tier's promotion path re-installs a page whose pvn history
        continued on SSD, and must stay above any stale durable slot."""
        layout, g = self.layout, self.layout.geometry
        page = np.asarray(page, dtype=np.uint8).ravel()
        if page.size != layout.page_size:
            raise ValueError("page size mismatch")
        old = self.table.get(pid)
        new_pvn = max((old[1] if old else 0) + 1, int(pvn_floor),
                      self.pvn_floor.get(pid, 0) + 1)
        slot = self._alloc_slot()

        if invalidate_first and old is not None:
            # legacy: explicitly invalidate the old slot header  (barrier 0)
            self.pmem.store(
                layout.slot_off(old[0]), _SLOT_HDR.pack(INVALID_PID, 0), streaming=True
            )
            self.pmem.persist(layout.slot_off(old[0]), _SLOT_HDR.size, kind=FlushKind.NT)

        data = page
        if dirty_lines is not None and old is not None:
            # merge: clean lines come from the old PMem slot (uncached read)
            merged = self.pmem.load(
                layout.slot_data_off(old[0]), layout.page_size, uncached=True
            )
            dirty = np.zeros(layout.lines_per_page, dtype=bool)
            dirty[np.asarray(list(dirty_lines), dtype=np.int64)] = True
            m2 = merged.reshape(layout.lines_per_page, g.cache_line).copy()
            p2 = page.reshape(layout.lines_per_page, g.cache_line)
            m2[dirty] = p2[dirty]
            data = m2.ravel()

        # 1. write data, persist                                  (barrier 1)
        self.pmem.store(layout.slot_data_off(slot), data.tobytes(), streaming=True)
        self.pmem.persist(layout.slot_data_off(slot), layout.page_size, kind=FlushKind.NT)
        # 2. make the slot valid: header fits one line ⇒ atomic   (barrier 2)
        self.pmem.store(layout.slot_off(slot), _SLOT_HDR.pack(pid, new_pvn), streaming=True)
        self.pmem.persist(layout.slot_off(slot), _SLOT_HDR.size, kind=FlushKind.NT)

        if old is not None and retire_old:
            self.free.append(old[0])  # implicitly invalid: lower pvn
        self.table[pid] = (slot, new_pvn)

    def flush_mulog(self, pid: int, page: np.ndarray, dirty_lines: Sequence[int],
                    *, target_slot: Optional[int] = None) -> None:
        """µLog flush: persist only the dirty lines through the micro log,
        then apply them (Listing 1 right; 4 barriers).

        Default (paper §3.2.2): apply *in place* to the page's current slot.
        ``target_slot`` (checkpoint layer): apply onto that slot instead —
        the shadow-slot delta that keeps the previous snapshot intact. The
        caller guarantees ``page`` restricted to ``dirty_lines`` turns the
        shadow slot's contents into the new version."""
        layout, g = self.layout, self.layout.geometry
        if pid not in self.table:
            # first flush of a page must materialize a slot → CoW
            self.flush_cow(pid, page)
            return
        slot, pvn = self.table[pid]
        new_pvn = pvn + 1
        apply_slot = slot if target_slot is None else target_slot
        page = np.asarray(page, dtype=np.uint8).reshape(
            layout.lines_per_page, g.cache_line
        )
        idx = sorted(int(i) for i in dirty_lines)
        data = page[np.asarray(idx, dtype=np.int64)]
        ml = self.mulogs[self._next_mulog]
        self._next_mulog = (self._next_mulog + 1) % len(self.mulogs)

        ml.invalidate()                       # barrier 1
        ml.write(new_pvn, idx, data,          # barrier 2
                 target_slot=SLOT_CURRENT if target_slot is None else target_slot)
        ml.validate(pid)                      # barrier 3
        # 4. apply + bump the target slot's pvn, one barrier      (barrier 4)
        doff = layout.slot_data_off(apply_slot)
        for li, line in zip(idx, data):
            self.pmem.store(doff + li * g.cache_line, line.tobytes(), streaming=True)
        self.pmem.store(layout.slot_off(apply_slot), _SLOT_HDR.pack(pid, new_pvn),
                        streaming=True)
        self.pmem.sfence()
        self.table[pid] = (apply_slot, new_pvn)

    def flush(self, pid: int, page: np.ndarray,
              dirty_lines: Optional[Sequence[int]] = None, *,
              threads: Optional[int] = None) -> str:
        """Hybrid flush: pick µLog vs CoW by the cost model. Returns the
        technique used ("mulog" / "cow").

        ``threads`` overrides the constructor's writer-thread count for the
        crossover decision — the repro.io flush queue passes the *actual*
        number of concurrently-active lanes in the current epoch, which is
        what moves the Fig. 5 crossover (≈119 dirty lines at 1 lane → ≈31
        at 7) instead of a static constructor constant."""
        t = self.threads if threads is None else int(threads)
        if dirty_lines is None or pid not in self.table:
            self.flush_cow(pid, page, dirty_lines=None)
            return "cow"
        if self.policy.prefer_mulog(len(dirty_lines), t):
            self.flush_mulog(pid, page, dirty_lines)
            return "mulog"
        self.flush_cow(pid, page)
        return "cow"

    # ------------------------------------------------------------- evict

    def release(self, pid: int) -> int:
        """Give ``pid``'s PMem slot back: durably invalidate the slot
        header (one barrier) and return the slot to the free list.

        This is the *last* step of the spill tier's eviction — the caller
        must already have made the page bytes durable on the lower tier
        (SSD extent + map record), so a crash before this call leaves two
        identical copies, which recovery resolves by preferring the PMem
        version at equal-or-higher pvn. Returns the released pvn."""
        layout = self.layout
        if pid not in self.table:
            raise KeyError(pid)
        slot, pvn = self.table.pop(pid)
        self.pmem.store(layout.slot_off(slot),
                        _SLOT_HDR.pack(INVALID_PID, 0), streaming=True)
        self.pmem.persist(layout.slot_off(slot), _SLOT_HDR.size,
                          kind=FlushKind.NT)
        self.free.append(slot)
        return pvn

    # ------------------------------------------------------------- read

    def read_page(self, pid: int) -> np.ndarray:
        slot, _ = self.table[pid]
        return self.pmem.load(self.layout.slot_data_off(slot), self.layout.page_size)

    def fill_page(self, pid: int) -> Tuple[np.ndarray, int]:
        """Frame fill for the DRAM buffer manager (``repro.cache``): an
        *uncached* device read of the page's current slot — the whole
        page crosses the memory bus into a DRAM frame, so the full size
        is charged as ``device_read_bytes`` (the Fig. 3 PMem rung),
        unlike :meth:`read_page`'s CPU-cache-modeled load. Returns
        ``(data, pvn)``."""
        slot, pvn = self.table[pid]
        data = self.pmem.load(self.layout.slot_data_off(slot),
                              self.layout.page_size, uncached=True)
        return data, pvn

    def durable_page(self, pid: int) -> Optional[np.ndarray]:
        table = recover_page_table(self.pmem, self.layout)
        if pid not in table:
            return None
        slot, _ = table[pid]
        img = self.pmem.durable_view()
        off = self.layout.slot_data_off(slot)
        return img[off : off + self.layout.page_size]


class HybridPolicy:
    """Closed-form µLog-vs-CoW cost model (paper §3.2.3: "a hybrid technique
    based on a simple cost model should be used").

    µLog cost = 4 barriers + (µlog content + in-place apply) block writes.
    CoW  cost = 2 barriers + full-page block writes.
    Past ≈4 concurrent writer threads the WC buffer stops combining small
    writes (Fig. 2) ⇒ every dirty line costs a whole 256 B block in both the
    µlog content and the apply, which moves the crossover from ≈119 dirty
    lines (1 thread) to ≈31 (7 threads) for 16 KB pages — Fig. 5 (a)/(c).
    """

    def __init__(self, layout: PageStoreLayout, cm: PMemCostModel = COST_MODEL) -> None:
        self.layout = layout
        self.cm = cm

    def _per_block_ns(self, threads: int) -> float:
        # page flushes are large sequential bursts → burst thread curve
        ts = self.cm.thread_scale_burst(threads)
        return self.cm.block_write_ns_single / (ts / max(threads, 1))

    def _barrier_ns(self) -> float:
        from repro.core.persist import AccessPattern
        return (
            self.cm.persist_latency_ns(FlushKind.NT, AccessPattern.SEQUENTIAL)
            + self.cm.barrier_ns
        )

    def cow_cost_ns(self, threads: int) -> float:
        g = self.layout.geometry
        blocks = math.ceil(self.layout.page_size / g.block)
        return 2 * self._barrier_ns() + blocks * self._per_block_ns(threads)

    def mulog_cost_ns(self, dirty: int, threads: int) -> float:
        g = self.layout.geometry
        lpb = g.lines_per_block
        combining = threads <= 4
        rec_bytes = 4 + g.cache_line  # index + line payload
        if combining:
            content_blocks = math.ceil(dirty * rec_bytes / g.block)
            apply_blocks = math.ceil(dirty / lpb)  # adjacent lines combine
        else:
            content_blocks = dirty  # WC combining defeated (Fig. 2)
            apply_blocks = dirty
        return 4 * self._barrier_ns() + (content_blocks + apply_blocks) * self._per_block_ns(threads)

    def crossover(self, threads: int) -> int:
        """Smallest dirty-line count at which CoW becomes cheaper."""
        for d in range(1, self.layout.lines_per_page + 1):
            if self.mulog_cost_ns(d, threads) >= self.cow_cost_ns(threads):
                return d
        return self.layout.lines_per_page + 1

    def prefer_mulog(self, dirty: int, threads: int) -> bool:
        return self.mulog_cost_ns(dirty, threads) < self.cow_cost_ns(threads)
