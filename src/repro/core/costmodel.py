"""Cost model calibrated to the paper's measured PMem characteristics.

This container has no Optane DIMMs (and the deploy target, TPU v5e hosts,
never will); wall-clock here measures nothing about the algorithms. The
functional layer (`core.pmem`) therefore records *exact operation counts*,
and this module converts counts → modeled nanoseconds with constants
calibrated so that every ratio the paper reports is reproduced:

  - read latency: PMem 3.2× DRAM                     (Fig. 3)
  - read bandwidth: PMem 2.6× below DRAM             (§2.2)
  - write bandwidth: PMem 7.5× below DRAM            (§2.2)
  - peak write BW only at 256 B granularity          (Fig. 1)
  - nt stores peak ≈3 threads, clwb ≈12, regular
    stores stop combining beyond ≈4 threads          (Fig. 2)
  - persist latency: same-line ≫ sequential/random,
    streaming ≫ cheaper on same-line, clwb==flushopt (Fig. 4)
  - log-entry padding → ≈8× throughput               (Fig. 6)
  - Zero ≈2× Classic log throughput                  (Fig. 6, §5)
  - CoW with pvn ≈10 % over CoW-invalidate           (§3.2.1)
  - µLog/CoW crossover ≈112 dirty CLs @1 thread,
    ≈32 @7 threads (16 KB pages)                     (Fig. 5)

Absolute constants are representative of published Optane measurements; the
*ratios* are the calibrated quantity and are what benchmarks assert.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.blocks import CACHE_LINE, PMEM_BLOCK
from repro.core.persist import AccessPattern, FlushKind
from repro.core.pmem import PMemStats
from repro.core.ssd import SSDStats

__all__ = ["PMemCostModel", "DRAMCostModel", "SSDCostModel",
           "COST_MODEL", "SSD_COST_MODEL"]

GiB = float(1 << 30)


@dataclasses.dataclass(frozen=True)
class SSDCostModel:
    """Flash tier constants — the Fig. 1 gap below PMem.

    The paper's Fig. 1 places PMem between DRAM and flash on both the
    latency and bandwidth axes; these constants are representative NVMe
    flash numbers chosen to reproduce that *gap* (PMem random read
    ≈260 ns vs flash ≈85 µs — over two orders of magnitude; PMem nt-store
    bandwidth ≈6.9 GB/s vs flash program ≈1.4 GB/s), with the read/write
    asymmetry that NAND has and PMem does not: reads are latency-bound
    page fetches, writes are bandwidth/erase-bound programs. Every
    constant is documented with its provenance in ``docs/costmodel.md``.
    """

    #: 4 KiB random read latency (QD1 NVMe NAND page fetch)
    read_latency_ns: float = 85_000.0
    #: per-command write latency into the device's buffer (program is
    #: deferred; the sustained cost is bandwidth, below)
    write_latency_ns: float = 25_000.0
    #: FLUSH CACHE: drain the device write buffer to NAND
    flush_latency_ns: float = 120_000.0
    #: sequential read bandwidth
    read_bw_gbps: float = 3.2
    #: sustained program (write) bandwidth — the asymmetric axis
    write_bw_gbps: float = 1.4
    #: extra NAND page read charged per read-modify-write block program
    rmw_read_ns: float = 85_000.0
    block: int = 4096

    def read_time_ns(self, reads: int, nbytes: int) -> float:
        """Aggregate read cost: ``reads`` command latencies plus
        ``nbytes`` of transfer at read bandwidth."""
        return (reads * self.read_latency_ns
                + nbytes / (self.read_bw_gbps * GiB) * 1e9)

    def read_ns(self, nbytes: int) -> float:
        """One read command of ``nbytes``: latency + transfer."""
        return self.read_time_ns(1, nbytes)

    def write_ns(self, nbytes: int) -> float:
        """One write command of ``nbytes``: latency + sustained program."""
        return self.write_latency_ns + nbytes / (self.write_bw_gbps * GiB) * 1e9

    def time_ns(self, stats: SSDStats) -> float:
        """Convert an :class:`~repro.core.ssd.SSDStats` delta to modeled ns.

        Model: reads pay per-command latency plus block transfer at read
        bandwidth; programs pay block transfer at (lower) write bandwidth
        plus per-command submit latency; each read-modify-write adds a
        NAND page read; each flush drains the buffer.
        """
        t = 0.0
        t += stats.reads * self.read_latency_ns
        t += stats.blocks_read * self.block / (self.read_bw_gbps * GiB) * 1e9
        t += stats.writes * self.write_latency_ns
        t += stats.blocks_written * self.block / (self.write_bw_gbps * GiB) * 1e9
        t += stats.rmw_blocks * self.rmw_read_ns
        t += stats.flushes * self.flush_latency_ns
        return t


@dataclasses.dataclass(frozen=True)
class DRAMCostModel:
    """DRAM reference numbers (per socket, 24 threads) — paper Fig. 1-4."""

    load_latency_ns: float = 81.0
    load_bw_gbps: float = 68.3          # random 64 B-granular loads, 24 thr
    store_bw_nt_gbps: float = 52.0      # streaming stores
    store_bw_regular_gbps: float = 38.0  # regular stores (RFO traffic)

    def read_time_ns(self, reads: int, nbytes: int) -> float:
        """Aggregate DRAM read cost: ``reads`` random-read latencies plus
        ``nbytes`` of transfer at DRAM load bandwidth — the single
        source of the DRAM-hit formula (``readpath_time_ns`` and
        ``engine_time_ns(cache=…)`` both charge through here)."""
        return (reads * self.load_latency_ns
                + nbytes / (self.load_bw_gbps * GiB) * 1e9)

    def read_ns(self, nbytes: int) -> float:
        """One DRAM buffer-cache hit of ``nbytes``: the Fig. 3 DRAM
        random-read latency plus transfer at DRAM load bandwidth — the
        top rung of the ladder the ``repro.cache`` buffer manager
        serves from."""
        return self.read_time_ns(1, nbytes)


@dataclasses.dataclass(frozen=True)
class PMemCostModel:
    dram: DRAMCostModel = dataclasses.field(default_factory=DRAMCostModel)

    # Latency (Fig. 3): PMem random read = 3.2 × DRAM.
    load_latency_ns: float = 81.0 * 3.2
    # Memory-mode L4 miss penalty (§2.3): ≈10 % overhead when cached,
    # degrading toward raw PMem latency as the working set outgrows DRAM.
    memory_mode_hit_overhead: float = 0.10

    # Bandwidth peaks (§2.2 summary): read 2.6× / write 7.5× below DRAM.
    load_bw_gbps: float = 68.3 / 2.6
    store_bw_nt_gbps: float = 52.0 / 7.5
    # Regular stores WITH clwb reach streaming performance (Fig. 1a);
    # without clwb they peak ≈40 % of it once threads > 4 (Fig. 2a).
    store_bw_regular_clwb_gbps: float = 52.0 / 7.5
    store_bw_regular_noclwb_frac: float = 0.40

    # Persist-write latency (Fig. 4), ns per persist() on one line.
    # Columns: flush, flushopt, clwb, nt. clwb==flushopt on Cascade Lake
    # ("Intel ... implement it as flush_opt for now").
    persist_ns_same: dict = dataclasses.field(
        default_factory=lambda: {
            FlushKind.FLUSH: 800.0,
            FlushKind.FLUSHOPT: 780.0,
            FlushKind.CLWB: 780.0,
            FlushKind.NT: 180.0,
        }
    )
    persist_ns_seq: dict = dataclasses.field(
        default_factory=lambda: {
            FlushKind.FLUSH: 450.0,
            FlushKind.FLUSHOPT: 130.0,
            FlushKind.CLWB: 130.0,
            FlushKind.NT: 105.0,
        }
    )
    persist_ns_rand: dict = dataclasses.field(
        default_factory=lambda: {
            FlushKind.FLUSH: 470.0,
            FlushKind.FLUSHOPT: 170.0,
            FlushKind.CLWB: 170.0,
            FlushKind.NT: 160.0,
        }
    )

    # Extra stall when a line is persisted again while still in flight in
    # the DIMM's write-combining buffer (the §2.3 pathology). Calibrated so
    # that unpadded log writing (which re-persists the boundary line of
    # every entry) is ≈8× slower than padded (Fig. 6).
    same_line_stall_ns: float = 6500.0

    # Fixed barrier cost: sfence waiting for the ADR domain to ack.
    barrier_ns: float = 100.0

    # Device-side service time per 256 B block write (1/peak-block-rate).
    # peak nt store BW 6.93 GB/s / 256 B ≈ 27.1 M blocks/s → ~36.9 ns, but
    # a single thread cannot saturate the DIMMs; single-thread streaming
    # lands near 2.1 GB/s (Fig. 2a at 1 thread) → ≈122 ns per block.
    block_write_ns_single: float = 122.0

    # Thread scaling (Fig. 2): throughput peaks then degrades slightly.
    nt_peak_threads: int = 3
    clwb_peak_threads: int = 12
    oversaturation_decay: float = 0.015  # per thread past peak
    # Large sequential bursts (16 KB page flushes) saturate later than the
    # 256 B random-store microbench: Fig. 5(b) peaks at 7-11 threads.
    burst_peak_threads: int = 9

    # Concurrent-lane write-combining defeat (Fig. 2a): past this many
    # simultaneously-active writer lanes, the device's WC buffer can no
    # longer merge small (sub-block) writes arriving interleaved from
    # different lanes — every partial block write pays an extra read-
    # modify-write stall on the DIMM.
    wc_defeat_lanes: int = 4
    wc_defeat_stall_ns: float = 320.0

    # HBM read bandwidth of the accelerator the save-path scan kernels run
    # on (TPU v5e HBM ≈819 GB/s — the same constant benchmarks/roofline.py
    # uses). The fused flush_pack kernel reads each live byte exactly once
    # per save; the staged dirty_diff → popcnt → delta_pack chain reads
    # them up to three times (Wu arXiv:2005.07658: redundant flush passes
    # dominate; Izraelevitz arXiv:1903.05714: read bandwidth is the scarce
    # axis). ``engine_time_ns(scan_read_bytes=…)`` charges this term.
    hbm_read_bw_gbps: float = 819.0

    # NUMA remote-access multipliers (Izraelevitz et al., "Basic
    # Performance Measurements of the Intel Optane DC Persistent Memory
    # Module", arXiv:1903.05714): far-socket PMem access crosses the UPI
    # interconnect — sequential write bandwidth drops ~2-3x vs
    # near-socket (remote stores also defeat the DIMM's write combining
    # earlier), and persist latency roughly doubles (the fence waits for
    # the remote ADR domain's ack across the interconnect). Applied by
    # ``engine_time_ns`` to the ``lane_remote_*`` counts a socket-tagged
    # lane accrues; a lane with no remote work pays exactly the local
    # cost, so an all-near placement is bit-identical to the pre-NUMA
    # model.
    numa_remote_block_mult: float = 2.3
    numa_remote_barrier_mult: float = 2.0

    # ----------------------------------------------------------- helpers

    def cluster_transfer_ns(self, nbytes: int) -> float:
        """Modeled wall-clock of moving ``nbytes`` between shards during a
        view change (repro.cluster).

        A migration streams page images and WAL records from the source
        engine's pool into the target's over the interconnect. The bytes
        are charged at the NT-store peak derated by the far-socket block
        multiplier — Izraelevitz (arXiv:1903.05714) measures remote
        streaming stores at ~1/2.3 the near rate, and a cross-*node* hop
        cannot beat the cross-socket one — plus one remote-latency setup
        round trip per transfer. ``engine_time_ns(cluster_transfer_bytes=…)``
        adds this term to the receiving engine's serialized remainder, so
        resharding competes with foreground I/O on the same modeled clock
        (Wu arXiv:2005.07658: migration scheduling against foreground
        traffic decides partitioned-engine tail latency)."""
        if nbytes <= 0:
            return 0.0
        bw = self.store_bw_nt_gbps / self.numa_remote_block_mult
        setup = self.barrier_ns * self.numa_remote_barrier_mult
        return setup + nbytes / bw   # B / (GB/s) = ns

    def persist_latency_ns(
        self, kind: FlushKind, pattern: AccessPattern
    ) -> float:
        table = {
            AccessPattern.SAME_LINE: self.persist_ns_same,
            AccessPattern.SEQUENTIAL: self.persist_ns_seq,
            AccessPattern.RANDOM: self.persist_ns_rand,
        }[pattern]
        return table[kind]

    def thread_scale(self, threads: int, kind: FlushKind) -> float:
        """Aggregate-throughput multiplier vs a single thread (Fig. 2)."""
        peak = self.nt_peak_threads if kind == FlushKind.NT else self.clwb_peak_threads
        # Near-linear up to the peak, then mild oversaturation decay (G4).
        if threads <= peak:
            return float(threads) * (1.0 - 0.04 * (threads - 1))
        at_peak = float(peak) * (1.0 - 0.04 * (peak - 1))
        return at_peak * (1.0 - self.oversaturation_decay * (threads - peak))

    def thread_scale_burst(self, threads: int) -> float:
        """Aggregate-throughput multiplier for large sequential bursts
        (page flushing, Fig. 5(b)): peaks at 7-11 threads."""
        peak = self.burst_peak_threads
        if threads <= peak:
            return float(threads) * (1.0 - 0.03 * (threads - 1))
        at_peak = float(peak) * (1.0 - 0.03 * (peak - 1))
        return at_peak * (1.0 - self.oversaturation_decay * (threads - peak))

    def store_bandwidth_gbps(
        self, adjacent_lines: int, threads: int, kind: FlushKind
    ) -> float:
        """Fig. 1(a)/2(a): store bandwidth vs granularity and threads."""
        lines_per_block = PMEM_BLOCK // CACHE_LINE
        dev_blocks = math.ceil(adjacent_lines / lines_per_block)
        granularity_eff = adjacent_lines / (dev_blocks * lines_per_block)
        peak = self.store_bw_nt_gbps
        if kind in (FlushKind.NT, FlushKind.CLWB):
            # Normalize the thread curve so its best point hits `peak`.
            best = max(self.thread_scale(t, kind) for t in range(1, 49))
            scale = self.thread_scale(threads, kind) / best
        else:
            # Regular stores without write-back: WC combining works while
            # few threads keep eviction order; beyond ~4 threads lines
            # arrive out of order and blocks are written piecemeal (Fig. 2a).
            best = max(self.thread_scale(t, FlushKind.CLWB) for t in range(1, 49))
            scale = self.thread_scale(threads, FlushKind.CLWB) / best
            if threads > 4:
                scale *= self.store_bw_regular_noclwb_frac
        return peak * granularity_eff * scale

    def load_bandwidth_gbps(self, adjacent_lines: int, threads: int) -> float:
        """Fig. 1(c)/2(c): load bandwidth vs granularity and threads."""
        lines_per_block = PMEM_BLOCK // CACHE_LINE
        dev_blocks = math.ceil(adjacent_lines / lines_per_block)
        granularity_eff = adjacent_lines / (dev_blocks * lines_per_block)
        # Hardware prefetcher kicks in at ≥10 adjacent lines and wastes
        # bandwidth on lines we never use (Fig. 1c/d note).
        prefetch_penalty = 0.85 if adjacent_lines >= 10 else 1.0
        # Loads saturate near ~12 threads and stay flat (Fig. 2c/d).
        scale = min(1.0, 0.25 + threads / 12.0) if threads >= 1 else 0.0
        return self.load_bw_gbps * granularity_eff * prefetch_penalty * scale

    # ------------------------------------------------------ count → time

    def time_ns(
        self,
        stats: PMemStats,
        *,
        kind: FlushKind = FlushKind.NT,
        pattern: AccessPattern = AccessPattern.SEQUENTIAL,
        threads: int = 1,
    ) -> float:
        """Convert an operation-count delta into modeled nanoseconds.

        Model: time = barriers × (flush+fence latency for the pattern)
                     + device block writes × per-block service time
                     + same-line stalls
                     + uncached device reads at load bandwidth.
        Block service time scales with the aggregate-throughput curve of
        Fig. 2 (per-thread view: service/thread_scale×threads).
        """
        t = 0.0
        t += stats.barriers * (
            self.persist_latency_ns(kind, pattern) + self.barrier_ns
        )
        per_block = self.block_write_ns_single / (
            self.thread_scale(threads, kind) / threads
        )
        t += stats.blocks_written * per_block
        t += stats.same_line_flushes * self.same_line_stall_ns
        t += stats.same_line_nt * (self.same_line_stall_ns * 0.35)
        if stats.device_read_bytes:
            bw = self.load_bandwidth_gbps(4, threads) * GiB
            t += stats.device_read_bytes / bw * 1e9
        return t

    def throughput_per_s(self, stats: PMemStats, n_ops: int, **kw) -> float:
        total_ns = self.time_ns(stats, **kw)
        if total_ns <= 0:
            return float("inf")
        return n_ops / (total_ns * 1e-9)

    # -------------------------------------------------- read-path (Fig. 3)

    def pmem_read_time_ns(self, reads: int, nbytes: int) -> float:
        """Aggregate PMem frame-fill cost: ``reads`` random-read
        latencies (the Fig. 3 3.2× rung) plus ``nbytes`` at PMem load
        bandwidth."""
        return (reads * self.load_latency_ns
                + nbytes / (self.load_bw_gbps * GiB) * 1e9)

    def pmem_read_ns(self, nbytes: int) -> float:
        """One PMem frame fill of ``nbytes``: the Fig. 3 PMem random-read
        latency (3.2× DRAM) plus transfer at PMem load bandwidth."""
        return self.pmem_read_time_ns(1, nbytes)

    def remote_fill_ns(self, fills: int, nbytes: int) -> float:
        """Far-socket surcharge for cache fills whose source tier is
        homed on a remote NUMA node (``CacheStats.remote_fills`` /
        ``remote_fill_bytes``): the fill's interconnect crossing costs
        ``numa_remote_block_mult``× the PMem read rung (Izraelevitz,
        arXiv:1903.05714), so the surcharge is the (mult − 1) excess on
        top of the base fill already charged by :meth:`readpath_time_ns`.
        Exactly 0.0 at zero remote counts — an all-near run is
        bit-identical to the pre-NUMA model."""
        if not fills and not nbytes:
            return 0.0
        return ((self.numa_remote_block_mult - 1.0)
                * self.pmem_read_time_ns(fills, nbytes))

    def readpath_time_ns(self, cache, *, ssd: Optional["SSDCostModel"] = None
                         ) -> float:
        """Modeled read-path time of a ``repro.cache.CacheStats`` delta
        against the Fig. 3 latency ladder: DRAM hits at DRAM
        latency/bandwidth, PMem frame fills at the 3.2× rung, SSD fills
        per the flash model (``ssd`` defaults to ``SSD_COST_MODEL``),
        plus the :meth:`remote_fill_ns` far-socket surcharge for fills
        sourced from a remote-homed tier.
        Only *read* traffic is charged here — promotion/eviction writes
        are already counted where they execute (``PMemStats`` lane
        work, ``SSDStats`` programs) and costed by :meth:`engine_time_ns`
        / :meth:`SSDCostModel.time_ns`."""
        ssd = ssd if ssd is not None else SSD_COST_MODEL
        return (self.dram.read_time_ns(cache.dram_hits,
                                       cache.dram_hit_bytes)
                + self.pmem_read_time_ns(cache.pmem_fills,
                                         cache.pmem_fill_bytes)
                + ssd.read_time_ns(cache.ssd_fills, cache.ssd_fill_bytes)
                + self.remote_fill_ns(cache.remote_fills,
                                      cache.remote_fill_bytes))

    def scan_read_ns(self, nbytes: int) -> float:
        """Device time of streaming ``nbytes`` from HBM at the
        accelerator's read bandwidth — the save-path scan term. One fused
        ``flush_pack`` pass charges each live byte once; the staged chain
        charges the same bytes per pass, which is how ``engine_time_ns``
        credits the fused kernel's win."""
        return nbytes / self.hbm_read_bw_gbps   # B / (GB/s) = ns

    # ------------------------------------------------- lane-partitioned time

    def engine_time_ns(
        self,
        stats: PMemStats,
        *,
        active_lanes: Optional[int] = None,
        kind: FlushKind = FlushKind.NT,
        pattern: AccessPattern = AccessPattern.SEQUENTIAL,
        burst: bool = False,
        cache=None,
        scan_read_bytes: int = 0,
        cluster_transfer_bytes: int = 0,
    ) -> float:
        """Wall-clock of a lane-partitioned engine (repro.io).

        Per-lane counts (``PMemStats.lane_*``, recorded under
        ``PMem.lane(i)``) are costed per lane and the lanes overlap: the
        engine's wall clock is the *max* over lanes, not the sum. Device
        service per 256 B block follows the aggregate Fig. 2 curve at
        ``active_lanes`` concurrent writers (``burst=True`` selects the
        large-sequential-burst curve of Fig. 5(b), peaking at 7-11 lanes);
        past ``wc_defeat_lanes`` every *partial* block write additionally
        pays the write-combining-defeat stall. Work not attributed to any
        lane (setup, shared-structure commits) is serialized and added on
        top. With no lane-attributed work at all this degrades exactly to
        :meth:`time_ns` at ``threads=active_lanes``.

        NUMA: a lane's *remote* work (``lane_remote_*``, accrued when the
        lane's CPU socket differs from the touched bytes' home socket)
        pays the Izraelevitz far-socket multipliers — barriers x
        ``numa_remote_barrier_mult``, device blocks (and the WC-defeat
        stall, which is a device-side RMW) x ``numa_remote_block_mult``.
        With every lane near its memory the remote counts are zero and
        the result is identical to the pre-NUMA model.

        ``cache`` (a ``repro.cache.CacheStats`` delta) folds the DRAM
        buffer manager's hit traffic into the same clock: hits are
        served at the Fig. 3 DRAM rung and added to the serialized
        remainder (tier *fills* are not added here — they already appear
        in the PMem/SSD op counts this method and
        :meth:`SSDCostModel.time_ns` charge). Fills sourced from a
        far-homed tier add their :meth:`remote_fill_ns` interconnect
        surcharge on top — zero remote counts add exactly 0.0.

        ``scan_read_bytes`` is the save-path scan's HBM traffic (device
        bytes the flush kernels read to find/pack/checksum dirty blocks),
        charged at :meth:`scan_read_ns` and added to the serialized
        remainder — the epoch's lanes cannot start on a page before its
        scan has classified it.

        ``cluster_transfer_bytes`` is cross-shard migration traffic
        received during the window (repro.cluster view changes), charged
        at :meth:`cluster_transfer_ns` and likewise serialized — the
        engine cannot acknowledge a migrated range before its bytes have
        landed.
        """
        dram_ns = 0.0
        if cache is not None:
            dram_ns = self.dram.read_time_ns(cache.dram_hits,
                                             cache.dram_hit_bytes)
            # far-homed fills cross the interconnect: the (mult − 1)
            # excess over the base fill (which the PMem/SSD op counts
            # already carry) serializes with the consumer
            dram_ns += self.remote_fill_ns(cache.remote_fills,
                                           cache.remote_fill_bytes)
        if scan_read_bytes:
            dram_ns += self.scan_read_ns(scan_read_bytes)
        if cluster_transfer_bytes:
            dram_ns += self.cluster_transfer_ns(cluster_transfer_bytes)
        lanes = set()
        for field in (stats.lane_barriers, stats.lane_lines,
                      stats.lane_blocks_written, stats.lane_partial_blocks):
            lanes.update(k for k, v in field.items() if v)
        n = int(active_lanes) if active_lanes is not None else max(1, len(lanes))
        if not lanes:
            return dram_ns + self.time_ns(stats, kind=kind, pattern=pattern,
                                          threads=n)
        scale = self.thread_scale_burst(n) if burst else self.thread_scale(n, kind)
        per_block = self.block_write_ns_single / (scale / n)
        barrier_ns = self.persist_latency_ns(kind, pattern) + self.barrier_ns
        defeated = n > self.wc_defeat_lanes
        critical = 0.0
        for li in lanes:
            bar = stats.lane_barriers.get(li, 0)
            rbar = min(stats.lane_remote_barriers.get(li, 0), bar)
            blk = stats.lane_blocks_written.get(li, 0)
            rblk = min(stats.lane_remote_blocks_written.get(li, 0), blk)
            t = (bar - rbar) * barrier_ns \
                + rbar * barrier_ns * self.numa_remote_barrier_mult
            t += (blk - rblk) * per_block \
                + rblk * per_block * self.numa_remote_block_mult
            if defeated:
                par = stats.lane_partial_blocks.get(li, 0)
                rpar = min(stats.lane_remote_partial_blocks.get(li, 0), par)
                t += (par - rpar) * self.wc_defeat_stall_ns
                t += rpar * self.wc_defeat_stall_ns * self.numa_remote_block_mult
            critical = max(critical, t)
        # Unattributed (shared, serialized) remainder at single-writer cost.
        shared_barriers = stats.barriers - sum(stats.lane_barriers.values())
        shared_blocks = stats.blocks_written - sum(stats.lane_blocks_written.values())
        shared = (shared_barriers * barrier_ns
                  + shared_blocks * self.block_write_ns_single)
        # Same-line stalls serialize against the in-flight WC entry wherever
        # they occur; device reads run at the aggregate load curve.
        shared += stats.same_line_flushes * self.same_line_stall_ns
        shared += stats.same_line_nt * (self.same_line_stall_ns * 0.35)
        if stats.device_read_bytes:
            bw = self.load_bandwidth_gbps(4, n) * GiB
            shared += stats.device_read_bytes / bw * 1e9
        return critical + shared + dram_ns


COST_MODEL = PMemCostModel()
SSD_COST_MODEL = SSDCostModel()
