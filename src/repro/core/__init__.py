"""The paper's primary contribution: PMem I/O primitives.

The public entry point for *consuming* these primitives is
:class:`repro.pool.Pool` — a PMDK-style pool with a durable region
directory and uniform named handles (``pool.log`` / ``pool.pages`` /
``pool.kv`` / ``pool.wal``). The modules below are the substrate:

- :mod:`repro.core.pmem`      — functional PMem model (cache/WC semantics,
  crash simulation, exact op accounting)
- :mod:`repro.core.directory` — durable region directory (single-line
  entry commits, pvn-style max-generation validity) under the pool
- :mod:`repro.core.log`       — Classic / Header(±dancing) / Zero logging
- :mod:`repro.core.pageflush` — CoW(+pvn) / µLog / Hybrid page flushing
- :mod:`repro.core.recovery`  — minimal buffer-managed KV engine (YCSB
  validation target), built on the pool
- :mod:`repro.core.costmodel` — counts → time, calibrated to the paper
  (incl. ``engine_time_ns``: lane-concurrent wall-clock for
  :mod:`repro.io`, the lane-partitioned I/O engine built on all of this)
- :mod:`repro.core.ssd`       — functional flash model (block-granular,
  write-buffered, crash-simulated) — the capacity tier below PMem that
  :mod:`repro.tier` spills to, costed by ``SSDCostModel``
"""

from repro.core.blocks import (  # noqa: F401
    BlockGeometry,
    CACHE_LINE,
    PAPER_GEOMETRY,
    PMEM_BLOCK,
    TPU_GEOMETRY,
    TPU_TILE,
)
from repro.core.costmodel import (  # noqa: F401
    COST_MODEL,
    DRAMCostModel,
    PMemCostModel,
    SSD_COST_MODEL,
    SSDCostModel,
)
from repro.core.directory import (  # noqa: F401
    KIND_LOG,
    KIND_PAGES,
    KIND_RAW,
    KIND_SSD,
    RegionDirectory,
    RegionRecord,
    directory_bytes,
)
from repro.core.log import (  # noqa: F401
    ClassicLog,
    HeaderLog,
    LOG_TECHNIQUES,
    LogConfig,
    RecoveredLog,
    ZeroLog,
)
from repro.core.pageflush import (  # noqa: F401
    HybridPolicy,
    MicroLog,
    PageStore,
    PageStoreLayout,
    recover_page_table,
)
from repro.core.persist import AccessPattern, FlushKind, INVALID_PID  # noqa: F401
from repro.core.pmem import CrashImage, PMem, PMemStats  # noqa: F401
from repro.core.recovery import KVConfig, PersistentKV  # noqa: F401
from repro.core.ssd import SSD, SSDStats  # noqa: F401
