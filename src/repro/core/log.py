"""Log-writing primitives (paper §3.3): Classic, Header(±dancing), Zero.

All three append variable-size entries to a pre-allocated, pre-zeroed PMem
region and guarantee *failure atomicity*: after a crash, recovery returns
exactly a prefix of the appended entries, containing at least every entry
whose ``append()`` call had completed.

  Classic  — entry = [header(len,lsn) | payload | footer(lsn)].
             persist(header+payload); persist(footer)      → 2 barriers.
             Valid iff footer.lsn == header.lsn (footer is only *written*
             after the first barrier made the payload durable).
  Header   — PMDK libpmemlog scheme: entry = [header(len,lsn) | payload],
             file head holds a size field.
             persist(entry); size += n; persist(size)      → 2 barriers,
             plus a same-cache-line rewrite of the size field on EVERY
             append — the pathology of §2.3. ``dancing`` size fields
             (round-robin, one per cache line) remove the same-line
             rewrites; recovery takes the max over the slots.
  Zero     — the paper's contribution: file is pre-zeroed; entry =
             [header(len, lsn, cnt) | payload] where cnt = popcount of the
             entry's other bits + 1 (the +1 keeps cnt nonzero even for
             all-zero payloads; cnt==0 ⇒ slot never written).
             persist(entry)                                → 1 barrier.
             Valid iff stored cnt matches the recomputed popcount: every
             cache line is either fully durable (evicted/flushed) or still
             all-zero, so a dropped line changes the popcount — unless the
             dropped line was all-zero, in which case the recovered bytes
             are identical anyway and the entry is trivially valid.

Entry *padding* (``pad_to_line``) aligns each entry start to a cache-line
boundary so consecutive appends never re-persist the boundary line of the
previous entry — the ≈8× effect of Fig. 6. ``pad_to_block`` aligns to the
256 B device block (guideline G1).
"""

from __future__ import annotations

import dataclasses
import struct
from typing import List, Optional, Tuple

import numpy as np

from repro.core.blocks import BlockGeometry, PAPER_GEOMETRY, align_up
from repro.core.persist import FlushKind
from repro.core.pmem import PMem

__all__ = [
    "LogConfig",
    "RecoveredLog",
    "ClassicLog",
    "HeaderLog",
    "ZeroLog",
    "LOG_TECHNIQUES",
]


def popcount(buf: np.ndarray) -> int:
    """Bit population count of a uint8 buffer (x86 ``popcnt`` analogue)."""
    if hasattr(np, "bitwise_count"):
        return int(np.bitwise_count(buf).sum())
    return int(np.unpackbits(buf).sum())


@dataclasses.dataclass(frozen=True)
class LogConfig:
    geometry: BlockGeometry = PAPER_GEOMETRY
    pad_to_line: bool = True    # Fig. 6 right: align entries to cache lines
    pad_to_block: bool = False  # guideline G1: align to 256 B device blocks
    dancing: int = 1            # HeaderLog only: number of size slots
    flush_kind: FlushKind = FlushKind.NT

    def pad(self, size: int) -> int:
        if self.pad_to_block:
            return align_up(size, self.geometry.block)
        if self.pad_to_line:
            return align_up(size, self.geometry.cache_line)
        return size


@dataclasses.dataclass
class RecoveredLog:
    entries: List[bytes]
    lsns: List[int]
    tail: int       # byte offset where the next entry would go
    next_lsn: int
    #: byte offset where each recovered entry starts (same order as
    #: ``entries``) — lets a caller truncate a log back to a chosen prefix
    #: (MultiLog merge-on-recovery discards beyond-gap tail entries).
    offsets: List[int] = dataclasses.field(default_factory=list)


class _LogBase:
    """Common machinery: region window, tail tracking, store+persist."""

    #: barriers issued per append() — asserted in tests per the paper.
    BARRIERS_PER_APPEND: int = -1

    def __init__(self, pmem: PMem, base: int, capacity: int,
                 cfg: Optional[LogConfig] = None) -> None:
        self.pmem = pmem
        self.base = base
        self.capacity = capacity
        self.cfg = cfg or LogConfig()
        self.tail = self._data_start()
        self.next_lsn = 1

    # -- layout -----------------------------------------------------------
    def _data_start(self) -> int:
        return 0

    def _remaining(self) -> int:
        return self.capacity - self.tail

    # -- io ---------------------------------------------------------------
    def _store(self, off: int, data: bytes) -> None:
        streaming = self.cfg.flush_kind == FlushKind.NT
        self.pmem.store(self.base + off, data, streaming=streaming)

    def _persist(self, off: int, size: int) -> None:
        self.pmem.persist(self.base + off, size, kind=self.cfg.flush_kind)

    def _persist_many(self, spans: List[Tuple[int, int]]) -> None:
        """Flush many ranges, then ONE sfence — a single persistency barrier
        covering all of them (clwb/clflushopt allow batching flushes before
        the fence; NT stores need no flush instruction at all)."""
        if self.cfg.flush_kind != FlushKind.NT:
            for off, size in spans:
                self.pmem.flush(self.base + off, size, self.cfg.flush_kind)
        self.pmem.sfence()

    def append(self, payload: bytes) -> int:
        raise NotImplementedError

    def stride(self, payload_len: int) -> int:
        """Region bytes one appended entry of this payload size occupies
        (technique framing + padding included) — lets a batching caller
        (MultiLog) reserve capacity at submit time, so a buffered batch
        can never fail its later commit with "log full"."""
        raise NotImplementedError

    def append_batch(self, payloads: "List[bytes]") -> List[int]:
        """Group commit: append many entries amortizing the technique's
        barriers over the whole batch (k entries cost what one append
        costs in barriers).

        Every shipped technique overrides this with an implementation
        that is also all-or-nothing capacity-wise (the whole batch fits
        or nothing is written — MultiLog relies on that to retry safely).
        This base fallback is a plain unbatched loop with NEITHER
        property; a new technique must override it before being used for
        group commit."""
        return [self.append(p) for p in payloads]

    # -- recovery ---------------------------------------------------------
    @classmethod
    def recover(cls, pmem: PMem, base: int, capacity: int,
                cfg: Optional[LogConfig] = None) -> RecoveredLog:
        raise NotImplementedError

    @classmethod
    def open_for_append(cls, pmem: PMem, base: int, capacity: int,
                        cfg: Optional[LogConfig] = None):
        """Recover, then return (writer positioned at the tail, recovered)."""
        rec = cls.recover(pmem, base, capacity, cfg)
        w = cls(pmem, base, capacity, cfg)
        w.tail = rec.tail
        w.next_lsn = rec.next_lsn
        if isinstance(w, HeaderLog):
            w._size = rec.tail - w._data_start()
        return w, rec


# =========================================================================
# Classic
# =========================================================================

_CL_HDR = struct.Struct("<IQ")   # len, lsn
_CL_FTR = struct.Struct("<Q")    # lsn copy


class ClassicLog(_LogBase):
    """Header+payload persisted, then footer persisted (2 barriers).

    In padded mode the footer sits on its *own* cache line — otherwise the
    footer persist would rewrite the just-persisted tail line of the
    payload (the §2.3 stall). This is why the paper's footnote says Classic
    pads "up to 2 cache lines" per entry vs 1 for Header/Zero.
    """

    BARRIERS_PER_APPEND = 2

    def _footer_off(self, n: int) -> int:
        head_len = _CL_HDR.size + n
        if self.cfg.pad_to_line or self.cfg.pad_to_block:
            return self.cfg.geometry.pad_to_line(head_len)
        return head_len

    def stride(self, payload_len: int) -> int:
        """See :meth:`_LogBase.stride`: header + payload + own-line footer."""
        return self.cfg.pad(self._footer_off(payload_len) + _CL_FTR.size)

    def append(self, payload: bytes) -> int:
        n = len(payload)
        ftr_off = self._footer_off(n)
        stride = self.cfg.pad(ftr_off + _CL_FTR.size)
        if stride > self._remaining():
            raise RuntimeError("log full")
        head_len = _CL_HDR.size + n
        # 1. header + payload, persist (barrier 1)
        self._store(self.tail, _CL_HDR.pack(n, self.next_lsn) + payload)
        self._persist(self.tail, head_len)
        # 2. footer (own line when padded), persist (barrier 2)
        self._store(self.tail + ftr_off, _CL_FTR.pack(self.next_lsn))
        self._persist(self.tail + ftr_off, _CL_FTR.size)
        lsn = self.next_lsn
        self.tail += stride
        self.next_lsn += 1
        return lsn

    def append_batch(self, payloads: List[bytes]) -> List[int]:
        """Group commit: all headers+payloads behind barrier 1, all footers
        behind barrier 2 — 2 barriers for the whole batch. A footer is only
        stored after barrier 1 made every payload durable, so the per-entry
        validity argument is unchanged."""
        if not payloads:
            return []
        heads: List[Tuple[int, bytes]] = []
        footers: List[Tuple[int, bytes]] = []
        off, lsn = self.tail, self.next_lsn
        for payload in payloads:
            n = len(payload)
            fo = self._footer_off(n)
            heads.append((off, _CL_HDR.pack(n, lsn) + payload))
            footers.append((off + fo, _CL_FTR.pack(lsn)))
            off += self.cfg.pad(fo + _CL_FTR.size)
            lsn += 1
        if off - self.tail > self._remaining():
            raise RuntimeError("log full")
        for o, b in heads:
            self._store(o, b)
        self._persist_many([(o, len(b)) for o, b in heads])      # barrier 1
        for o, b in footers:
            self._store(o, b)
        self._persist_many([(o, len(b)) for o, b in footers])    # barrier 2
        lsns = list(range(self.next_lsn, lsn))
        self.tail, self.next_lsn = off, lsn
        return lsns

    @classmethod
    def recover(cls, pmem: PMem, base: int, capacity: int,
                cfg: Optional[LogConfig] = None) -> RecoveredLog:
        cfg = cfg or LogConfig()
        img = pmem.durable_view()[base : base + capacity]
        entries: List[bytes] = []
        lsns: List[int] = []
        offsets: List[int] = []
        off, lsn = 0, 1

        def footer_off(n: int) -> int:
            head_len = _CL_HDR.size + n
            if cfg.pad_to_line or cfg.pad_to_block:
                return cfg.geometry.pad_to_line(head_len)
            return head_len

        while off + _CL_HDR.size <= capacity:
            n, got_lsn = _CL_HDR.unpack_from(img, off)
            fo = footer_off(n)
            end = off + fo + _CL_FTR.size
            if n == 0 or got_lsn != lsn or end > capacity:
                break
            (ftr_lsn,) = _CL_FTR.unpack_from(img, off + fo)
            if ftr_lsn != got_lsn:
                break
            entries.append(bytes(img[off + _CL_HDR.size : off + _CL_HDR.size + n]))
            lsns.append(got_lsn)
            offsets.append(off)
            off += cfg.pad(fo + _CL_FTR.size)
            lsn += 1
        return RecoveredLog(entries, lsns, off, lsn, offsets)


# =========================================================================
# Header (libpmemlog)
# =========================================================================

_HD_HDR = struct.Struct("<IQ")  # len, lsn
_HD_SIZE = struct.Struct("<Q")  # used-bytes slot


class HeaderLog(_LogBase):
    """PMDK libpmemlog scheme: append entry, then update the size field.

    ``cfg.dancing`` > 1 spreads the size field over that many cache lines,
    written round-robin, eliminating the same-line rewrite on every append
    (§3.3.2 "dancing size field"; 64 slots recovers Classic throughput).
    Recovery size = max over slots (sizes are monotonic).
    """

    BARRIERS_PER_APPEND = 2

    def __init__(self, *a, **kw) -> None:
        super().__init__(*a, **kw)
        self._size = 0          # bytes used in the data area
        self._next_slot = 0

    def _data_start(self) -> int:
        cfg = self.cfg  # always set by _LogBase.__init__ before _data_start()
        return align_up(cfg.dancing * cfg.geometry.cache_line, cfg.geometry.block)

    def stride(self, payload_len: int) -> int:
        """See :meth:`_LogBase.stride`: (len, lsn) header + payload."""
        return self.cfg.pad(_HD_HDR.size + payload_len)

    def append(self, payload: bytes) -> int:
        n = len(payload)
        entry = _HD_HDR.pack(n, self.next_lsn) + payload
        stride = self.cfg.pad(len(entry))
        if stride > self._remaining():
            raise RuntimeError("log full")
        # 1. entry, persist (barrier 1)
        self._store(self.tail, entry)
        self._persist(self.tail, len(entry))
        # 2. size slot, persist (barrier 2). With dancing=1 this re-persists
        #    the same cache line on every append — the §2.3 pathology.
        self._size += stride
        slot_off = self._next_slot * self.cfg.geometry.cache_line
        self._next_slot = (self._next_slot + 1) % self.cfg.dancing
        self._store(slot_off, _HD_SIZE.pack(self._size))
        self._persist(slot_off, _HD_SIZE.size)
        lsn = self.next_lsn
        self.tail += stride
        self.next_lsn += 1
        return lsn

    def append_batch(self, payloads: List[bytes]) -> List[int]:
        """Group commit: all entries behind barrier 1, then ONE size-field
        update covering the whole batch behind barrier 2 — 2 barriers per
        batch, and the size field is rewritten once per batch instead of
        once per append (group commit also amortizes the §2.3 pathology)."""
        if not payloads:
            return []
        entries: List[Tuple[int, bytes]] = []
        off, lsn, added = self.tail, self.next_lsn, 0
        for payload in payloads:
            e = _HD_HDR.pack(len(payload), lsn) + payload
            entries.append((off, e))
            stride = self.cfg.pad(len(e))
            off += stride
            added += stride
            lsn += 1
        if off - self.tail > self._remaining():
            raise RuntimeError("log full")
        for o, e in entries:
            self._store(o, e)
        self._persist_many([(o, len(e)) for o, e in entries])    # barrier 1
        self._size += added
        slot_off = self._next_slot * self.cfg.geometry.cache_line
        self._next_slot = (self._next_slot + 1) % self.cfg.dancing
        self._store(slot_off, _HD_SIZE.pack(self._size))
        self._persist(slot_off, _HD_SIZE.size)                   # barrier 2
        lsns = list(range(self.next_lsn, lsn))
        self.tail, self.next_lsn = off, lsn
        return lsns

    @classmethod
    def recover(cls, pmem: PMem, base: int, capacity: int,
                cfg: Optional[LogConfig] = None) -> RecoveredLog:
        cfg = cfg or LogConfig()
        img = pmem.durable_view()[base : base + capacity]
        data_start = align_up(cfg.dancing * cfg.geometry.cache_line, cfg.geometry.block)
        size = 0
        for slot in range(cfg.dancing):
            (s,) = _HD_SIZE.unpack_from(img, slot * cfg.geometry.cache_line)
            size = max(size, s)
        entries: List[bytes] = []
        lsns: List[int] = []
        offsets: List[int] = []
        off, lsn = data_start, 1
        end_valid = data_start + size
        while off + _HD_HDR.size <= end_valid:
            n, got_lsn = _HD_HDR.unpack_from(img, off)
            if n == 0 or got_lsn != lsn or off + _HD_HDR.size + n > end_valid:
                break
            entries.append(bytes(img[off + _HD_HDR.size : off + _HD_HDR.size + n]))
            lsns.append(got_lsn)
            offsets.append(off)
            off += cfg.pad(_HD_HDR.size + n)
            lsn += 1
        return RecoveredLog(entries, lsns, off, lsn, offsets)


# =========================================================================
# Zero — the paper's single-barrier technique
# =========================================================================

_ZR_HDR = struct.Struct("<IQQ")  # len, lsn, cnt


class ZeroLog(_LogBase):
    """One persistency barrier per entry; validity via popcount over a
    pre-zeroed file (paper §3.3.1 "Zero")."""

    BARRIERS_PER_APPEND = 1

    def stride(self, payload_len: int) -> int:
        """See :meth:`_LogBase.stride`: (len, lsn, cnt) header + payload."""
        return self.cfg.pad(_ZR_HDR.size + payload_len)

    def append(self, payload: bytes) -> int:
        n = len(payload)
        body = _ZR_HDR.pack(n, self.next_lsn, 0)[: _ZR_HDR.size - 8] + payload
        # cnt counts every bit of the entry EXCEPT the cnt field itself;
        # +1 keeps it nonzero (cnt==0 must mean "never written").
        cnt = popcount(np.frombuffer(body, dtype=np.uint8)) + 1
        entry = _ZR_HDR.pack(n, self.next_lsn, cnt) + payload
        stride = self.cfg.pad(len(entry))
        if stride > self._remaining():
            raise RuntimeError("log full")
        # header + cnt + payload persisted together (single barrier)
        self._store(self.tail, entry)
        self._persist(self.tail, len(entry))
        lsn = self.next_lsn
        self.tail += stride
        self.next_lsn += 1
        return lsn

    def append_batch(self, payloads: List[bytes]) -> List[int]:
        """Group commit at its best: the whole batch costs ONE persistency
        barrier (all entries streamed, one fence). Per-entry popcounts keep
        the per-entry validity argument — a crash mid-batch recovers the
        longest valid prefix of the batch."""
        if not payloads:
            return []
        entries: List[Tuple[int, bytes]] = []
        off, lsn = self.tail, self.next_lsn
        for payload in payloads:
            n = len(payload)
            body = _ZR_HDR.pack(n, lsn, 0)[: _ZR_HDR.size - 8] + payload
            cnt = popcount(np.frombuffer(body, dtype=np.uint8)) + 1
            entries.append((off, _ZR_HDR.pack(n, lsn, cnt) + payload))
            off += self.cfg.pad(_ZR_HDR.size + n)
            lsn += 1
        if off - self.tail > self._remaining():
            raise RuntimeError("log full")
        for o, e in entries:
            self._store(o, e)
        self._persist_many([(o, len(e)) for o, e in entries])  # the ONE barrier
        lsns = list(range(self.next_lsn, lsn))
        self.tail, self.next_lsn = off, lsn
        return lsns

    @classmethod
    def recover(cls, pmem: PMem, base: int, capacity: int,
                cfg: Optional[LogConfig] = None) -> RecoveredLog:
        cfg = cfg or LogConfig()
        img = pmem.durable_view()[base : base + capacity]
        entries: List[bytes] = []
        lsns: List[int] = []
        offsets: List[int] = []
        off, lsn = 0, 1
        while off + _ZR_HDR.size <= capacity:
            n, got_lsn, cnt = _ZR_HDR.unpack_from(img, off)
            if cnt == 0 or got_lsn != lsn or off + _ZR_HDR.size + n > capacity:
                break
            body = bytes(img[off : off + _ZR_HDR.size - 8]) + bytes(
                img[off + _ZR_HDR.size : off + _ZR_HDR.size + n]
            )
            if popcount(np.frombuffer(body, dtype=np.uint8)) + 1 != cnt:
                break  # some cache line of the entry never became durable
            entries.append(bytes(img[off + _ZR_HDR.size : off + _ZR_HDR.size + n]))
            lsns.append(got_lsn)
            offsets.append(off)
            off += cfg.pad(_ZR_HDR.size + n)
            lsn += 1
        return RecoveredLog(entries, lsns, off, lsn, offsets)


LOG_TECHNIQUES = {
    "classic": ClassicLog,
    "header": HeaderLog,
    "zero": ZeroLog,
}
