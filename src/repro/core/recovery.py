"""A minimal buffer-managed storage engine tying the primitives together.

This is the validation vehicle of paper §3.3.2 (HyMem + YCSB): a DRAM
"buffer pool" of fixed-size pages over a PMem :class:`PageStore`, with a
write-ahead log using any of the three logging techniques. It exists to

  * demonstrate the I/O primitives composing into a correct engine,
  * run the YCSB-style 100 %-write validation (``benchmarks/tab_ycsb.py``),
  * provide the crash-recovery property-test target (arbitrary eviction
    subsets at crash time must never lose a committed put).

Commit protocol per ``put``: modify the DRAM page (track dirty lines),
append a redo record to the WAL, persist per the technique. Background
``checkpoint()`` flushes dirty pages (hybrid CoW/µLog) and then advances a
failure-atomic *root* (ping-pong slots, max-generation rule — same
line-atomicity argument as the pvn) recording the checkpoint LSN. Recovery
= page table scan + µlog replay + redo of WAL entries past the checkpoint
LSN (puts are idempotent, so the §3.2.1 "log entries might be reapplied"
caveat is benign here — noted where it would not be).
"""

from __future__ import annotations

import dataclasses
import struct
from typing import Dict, List, Optional, Set, Tuple, Type

import numpy as np

from repro.core.blocks import BlockGeometry, PAPER_GEOMETRY, align_up
from repro.core.log import LOG_TECHNIQUES, LogConfig, _LogBase
from repro.core.pageflush import PageStore, PageStoreLayout
from repro.core.pmem import PMem

__all__ = ["PersistentKV", "KVConfig"]

_ROOT = struct.Struct("<QQ")  # generation, checkpoint_lsn
_REC = struct.Struct("<II")   # key, value_len   (redo record header)


@dataclasses.dataclass(frozen=True)
class KVConfig:
    npages: int = 16
    page_size: int = 4096
    value_size: int = 64
    log_capacity: int = 1 << 20
    technique: str = "zero"              # classic | header | zero
    log: LogConfig = dataclasses.field(default_factory=LogConfig)
    geometry: BlockGeometry = PAPER_GEOMETRY
    auto_checkpoint: bool = True

    @property
    def recs_per_page(self) -> int:
        return self.page_size // self.value_size

    @property
    def nkeys(self) -> int:
        return self.npages * self.recs_per_page


class PersistentKV:
    """Fixed-size-record KV store: DRAM buffer pool + PMem pages + WAL."""

    def __init__(self, pmem: PMem, cfg: KVConfig, *, _recover: bool = False) -> None:
        self.pmem = pmem
        self.cfg = cfg
        g = cfg.geometry
        # --- layout: [root | page slots + µlogs | wal] ---------------------
        self.root_off = 0
        root_bytes = align_up(2 * g.cache_line, g.block)
        self.layout = PageStoreLayout(
            base=root_bytes,
            page_size=cfg.page_size,
            npages=cfg.npages,
            nslots=cfg.npages + max(2, cfg.npages // 4),
            geometry=g,
        )
        log_cls: Type[_LogBase] = LOG_TECHNIQUES[cfg.technique]
        if _recover:
            self.store = PageStore.open(pmem, self.layout)
        else:
            self.store = PageStore(pmem, self.layout)
        self.log_base = align_up(self.store.total_end, g.block)
        if self.log_base + cfg.log_capacity > pmem.size:
            raise ValueError("region too small for layout")
        self._log_cls = log_cls
        self.checkpoint_lsn = 0
        self._root_gen = 0
        # --- volatile state -------------------------------------------------
        self.pool = np.zeros((cfg.npages, cfg.page_size), dtype=np.uint8)
        self.dirty: Dict[int, Set[int]] = {}

        if _recover:
            self._recover_state()
        else:
            self.wal = log_cls(pmem, self.log_base, cfg.log_capacity, cfg.log)

    # ------------------------------------------------------------- sizing

    @staticmethod
    def region_bytes(cfg: KVConfig) -> int:
        g = cfg.geometry
        root = align_up(2 * g.cache_line, g.block)
        layout = PageStoreLayout(
            base=root, page_size=cfg.page_size, npages=cfg.npages,
            nslots=cfg.npages + max(2, cfg.npages // 4), geometry=g,
        )
        slots = layout.total_bytes
        mulog = align_up(cfg.page_size * 2, g.block)  # generous µlog bound
        return root + slots + mulog + cfg.log_capacity + g.block

    # --------------------------------------------------------------- api

    def _locate(self, key: int) -> Tuple[int, int]:
        if not (0 <= key < self.cfg.nkeys):
            raise KeyError(key)
        return key // self.cfg.recs_per_page, (key % self.cfg.recs_per_page) * self.cfg.value_size

    def put(self, key: int, value: bytes) -> int:
        """Durable upsert; returns the commit LSN (absolute across WAL
        generations; WAL-internal LSNs restart at 1 after a checkpoint)."""
        if len(value) != self.cfg.value_size:
            raise ValueError("fixed-size values only")
        pid, off = self._locate(key)
        self.pool[pid, off : off + len(value)] = np.frombuffer(value, dtype=np.uint8)
        cl = self.cfg.geometry.cache_line
        lines = self.dirty.setdefault(pid, set())
        lines.update(range(off // cl, (off + len(value) - 1) // cl + 1))
        try:
            lsn = self.wal.append(_REC.pack(key, len(value)) + value)
        except RuntimeError:
            if not self.cfg.auto_checkpoint:
                raise
            self.checkpoint()
            lsn = self.wal.append(_REC.pack(key, len(value)) + value)
        return self.checkpoint_lsn + lsn

    def get(self, key: int) -> bytes:
        pid, off = self._locate(key)
        return self.pool[pid, off : off + self.cfg.value_size].tobytes()

    # -------------------------------------------------------- checkpoint

    def checkpoint(self) -> None:
        """Flush all dirty pages (hybrid), advance the root, reset the WAL.

        Page flushes precede the root update; a crash in between merely
        replays redo records onto already-flushed pages (idempotent puts).
        """
        for pid, lines in sorted(self.dirty.items()):
            self.store.flush(pid, self.pool[pid], dirty_lines=sorted(lines))
        self.dirty.clear()
        ckpt_lsn = self.checkpoint_lsn + (self.wal.next_lsn - 1)
        self._root_gen += 1
        slot = self._root_gen % 2
        g = self.cfg.geometry
        self.pmem.store(
            self.root_off + slot * g.cache_line,
            _ROOT.pack(self._root_gen, ckpt_lsn),
            streaming=True,
        )
        self.pmem.persist(self.root_off + slot * g.cache_line, _ROOT.size)
        self.checkpoint_lsn = ckpt_lsn
        # New WAL generation: re-zero the log region (Zero logging requires
        # it; the others tolerate it) and restart the writer. The zeroing
        # itself is bulk streaming traffic, not barrier-bound.
        zero = np.zeros(self.cfg.log_capacity, dtype=np.uint8)
        self.pmem.store(self.log_base, zero, streaming=True)
        self.pmem.sfence()
        self.wal = self._log_cls(self.pmem, self.log_base, self.cfg.log_capacity, self.cfg.log)

    # ----------------------------------------------------------- recovery

    def _read_root(self) -> Tuple[int, int]:
        img = self.pmem.durable_view()
        best = (0, 0)
        g = self.cfg.geometry
        for slot in range(2):
            gen, lsn = _ROOT.unpack_from(img, self.root_off + slot * g.cache_line)
            if gen > best[0]:
                best = (gen, lsn)
        return best

    def _recover_state(self) -> None:
        self._root_gen, self.checkpoint_lsn = self._read_root()
        # load persistent pages into the pool
        for pid in range(self.cfg.npages):
            if pid in self.store.table:
                self.pool[pid] = self.store.read_page(pid)
        # redo WAL entries past the checkpoint
        rec = self._log_cls.recover(self.pmem, self.log_base, self.cfg.log_capacity, self.cfg.log)
        cl = self.cfg.geometry.cache_line
        for entry in rec.entries:
            key, vlen = _REC.unpack_from(entry, 0)
            value = entry[_REC.size : _REC.size + vlen]
            pid, off = self._locate(key)
            self.pool[pid, off : off + vlen] = np.frombuffer(value, dtype=np.uint8)
            lines = self.dirty.setdefault(pid, set())
            lines.update(range(off // cl, (off + vlen - 1) // cl + 1))
        self.wal, _ = self._log_cls.open_for_append(
            self.pmem, self.log_base, self.cfg.log_capacity, self.cfg.log
        )

    @classmethod
    def open(cls, pmem: PMem, cfg: KVConfig) -> "PersistentKV":
        return cls(pmem, cfg, _recover=True)
