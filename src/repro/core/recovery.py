"""A minimal buffer-managed storage engine tying the primitives together.

This is the validation vehicle of paper §3.3.2 (HyMem + YCSB): a DRAM
"buffer pool" of fixed-size pages over a PMem page region, with a
write-ahead log using any of the three logging techniques. It exists to

  * demonstrate the I/O primitives composing into a correct engine,
  * run the YCSB-style 100 %-write validation (``benchmarks/tab_ycsb.py``),
  * provide the crash-recovery property-test target (arbitrary eviction
    subsets at crash time must never lose a committed put).

All persistent layout goes through :class:`repro.pool.Pool`: the engine
owns three named directory regions — ``<name>.root`` (failure-atomic
ping-pong root: two slots, max-generation rule, same line-atomicity
argument as the pvn), ``<name>.pages`` (PageStore slots + µlogs) and
``<name>.wal`` (redo log). The preferred constructor is
``pool.kv(name, cfg)``; passing a bare :class:`PMem` still works as a
deprecation shim (the engine formats/attaches a pool in place).

Commit protocol per ``put``: modify the DRAM page (track dirty lines),
append a redo record to the WAL, persist per the technique. Background
``checkpoint()`` flushes dirty pages (hybrid CoW/µLog) and then advances
the root recording the checkpoint LSN. Recovery = page table scan + µlog
replay + redo of WAL entries past the checkpoint LSN (puts are idempotent,
so the §3.2.1 "log entries might be reapplied" caveat is benign here).
"""

from __future__ import annotations

import dataclasses
import struct
import warnings
from typing import Dict, Set, Tuple, Union

import numpy as np

from repro.core.blocks import BlockGeometry, PAPER_GEOMETRY, align_up
from repro.core.log import LogConfig
from repro.core.pageflush import PageStore, PageStoreLayout
from repro.core.pmem import PMem

__all__ = ["PersistentKV", "KVConfig"]

_ROOT = struct.Struct("<QQ")  # generation, checkpoint_lsn
_REC = struct.Struct("<II")   # key, value_len   (redo record header)


@dataclasses.dataclass(frozen=True)
class KVConfig:
    npages: int = 16
    page_size: int = 4096
    value_size: int = 64
    log_capacity: int = 1 << 20
    technique: str = "zero"              # classic | header | zero
    log: LogConfig = dataclasses.field(default_factory=LogConfig)
    geometry: BlockGeometry = PAPER_GEOMETRY
    auto_checkpoint: bool = True
    #: checkpoint page flushing runs through a lane-partitioned
    #: repro.io FlushQueue when > 1 (the Hybrid crossover then follows
    #: the actual active-lane count of each checkpoint epoch)
    flush_lanes: int = 1

    @property
    def recs_per_page(self) -> int:
        return self.page_size // self.value_size

    @property
    def nkeys(self) -> int:
        return self.npages * self.recs_per_page

    @property
    def nslots(self) -> int:
        return self.npages + max(2, self.npages // 4)


class PersistentKV:
    """Fixed-size-record KV store: DRAM buffer pool + pool-managed PMem."""

    def __init__(self, pool_or_pmem, cfg: KVConfig, *, name: str = "kv",
                 _recover: bool = False) -> None:
        from repro.pool import Pool
        if isinstance(pool_or_pmem, PMem):
            # deprecation shim for the legacy (pmem, cfg) constructor:
            # format-or-open a pool directly over the caller's region
            warnings.warn(
                "PersistentKV(pmem, cfg) raw-region construction is "
                "deprecated; use pool.kv(name, cfg) on a repro.pool.Pool "
                "instead", DeprecationWarning, stacklevel=2)
            pmpool = Pool.attach(pool_or_pmem)
        else:
            pmpool = pool_or_pmem
        if cfg.geometry != pmpool.geometry:
            raise ValueError("KVConfig.geometry must match the pool geometry")
        self._pmpool = pmpool
        self.pmem = pmpool.pmem
        self.cfg = cfg
        self.name = name
        g = cfg.geometry

        recover = _recover or pmpool.directory.lookup(f"{name}.root") is not None
        self.root = pmpool.raw(f"{name}.root", nbytes=2 * g.cache_line)
        pages = pmpool.pages(f"{name}.pages", npages=cfg.npages,
                             page_size=cfg.page_size, nslots=cfg.nslots)
        self.store: PageStore = pages.store
        self.wal = pmpool.log(f"{name}.wal", capacity=cfg.log_capacity,
                              technique=cfg.technique, cfg=cfg.log)
        self.checkpoint_lsn = 0
        self._root_gen = 0
        # --- volatile state ------------------------------------------------
        self.pool = np.zeros((cfg.npages, cfg.page_size), dtype=np.uint8)
        self.dirty: Dict[int, Set[int]] = {}
        if recover:
            self._recover_state()

    # ------------------------------------------------------------- sizing

    @staticmethod
    def region_bytes(cfg: KVConfig) -> int:
        """Pool region size that fits this engine (directory included)."""
        from repro.pool import DEFAULT_MAX_REGIONS, Pool
        g = cfg.geometry
        layout = PageStoreLayout(base=0, page_size=cfg.page_size,
                                 npages=cfg.npages, nslots=cfg.nslots,
                                 geometry=g)
        return (Pool.overhead_bytes(g, DEFAULT_MAX_REGIONS)
                + align_up(2 * g.cache_line, g.block)
                + PageStore.region_bytes(layout, n_mulogs=1)
                + cfg.log_capacity + 4 * g.block)

    # --------------------------------------------------------------- api

    def _locate(self, key: int) -> Tuple[int, int]:
        if not (0 <= key < self.cfg.nkeys):
            raise KeyError(key)
        return key // self.cfg.recs_per_page, (key % self.cfg.recs_per_page) * self.cfg.value_size

    def put(self, key: int, value: bytes) -> int:
        """Durable upsert; returns the commit LSN (absolute across WAL
        generations; WAL-internal LSNs restart at 1 after a checkpoint)."""
        if len(value) != self.cfg.value_size:
            raise ValueError("fixed-size values only")
        pid, off = self._locate(key)
        self.pool[pid, off : off + len(value)] = np.frombuffer(value, dtype=np.uint8)
        cl = self.cfg.geometry.cache_line
        lines = self.dirty.setdefault(pid, set())
        lines.update(range(off // cl, (off + len(value) - 1) // cl + 1))
        try:
            lsn = self.wal.append(_REC.pack(key, len(value)) + value)
        except RuntimeError:
            if not self.cfg.auto_checkpoint:
                raise
            self.checkpoint()
            lsn = self.wal.append(_REC.pack(key, len(value)) + value)
        return self.checkpoint_lsn + lsn

    def get(self, key: int) -> bytes:
        pid, off = self._locate(key)
        return self.pool[pid, off : off + self.cfg.value_size].tobytes()

    # -------------------------------------------------------- checkpoint

    def checkpoint(self) -> None:
        """Flush all dirty pages (hybrid), advance the root, reset the WAL.

        Page flushes precede the root update; a crash in between merely
        replays redo records onto already-flushed pages (idempotent puts).
        With ``cfg.flush_lanes > 1`` the flushes run through a lane-
        partitioned engine epoch (batched, actual-lane-count Hybrid).
        """
        if self.cfg.flush_lanes > 1:
            from repro.io.flushq import FlushQueue
            fq = FlushQueue(self.store, lanes=self.cfg.flush_lanes)
            for pid, lines in sorted(self.dirty.items()):
                fq.enqueue(pid, self.pool[pid], sorted(lines))
            fq.flush_epoch()
        else:
            for pid, lines in sorted(self.dirty.items()):
                self.store.flush(pid, self.pool[pid], dirty_lines=sorted(lines))
        self.dirty.clear()
        ckpt_lsn = self.checkpoint_lsn + (self.wal.next_lsn - 1)
        self._root_gen += 1
        slot = self._root_gen % 2
        g = self.cfg.geometry
        self.root.store(slot * g.cache_line,
                        _ROOT.pack(self._root_gen, ckpt_lsn), streaming=True)
        self.root.persist(slot * g.cache_line, _ROOT.size)
        self.checkpoint_lsn = ckpt_lsn
        # New WAL generation (re-zeroes the region — Zero logging requires
        # it — and restarts the writer at LSN 1).
        self.wal.reset()

    # ----------------------------------------------------------- recovery

    def _read_root(self) -> Tuple[int, int]:
        img = self.root.durable_view()
        best = (0, 0)
        g = self.cfg.geometry
        for slot in range(2):
            gen, lsn = _ROOT.unpack_from(img, slot * g.cache_line)
            if gen > best[0]:
                best = (gen, lsn)
        return best

    def _recover_state(self) -> None:
        self._root_gen, self.checkpoint_lsn = self._read_root()
        # load persistent pages into the buffer pool
        for pid in range(self.cfg.npages):
            if pid in self.store.table:
                self.pool[pid] = self.store.read_page(pid)
        # redo WAL entries past the checkpoint (the handle recovered them
        # when it was opened, and is already positioned at the tail)
        cl = self.cfg.geometry.cache_line
        for entry in self.wal.recovered.entries:
            key, vlen = _REC.unpack_from(entry, 0)
            value = entry[_REC.size : _REC.size + vlen]
            pid, off = self._locate(key)
            self.pool[pid, off : off + vlen] = np.frombuffer(value, dtype=np.uint8)
            lines = self.dirty.setdefault(pid, set())
            lines.update(range(off // cl, (off + vlen - 1) // cl + 1))

    @classmethod
    def open(cls, pool_or_pmem, cfg: KVConfig, *, name: str = "kv") -> "PersistentKV":
        return cls(pool_or_pmem, cfg, name=name, _recover=True)
