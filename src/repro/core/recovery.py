"""A minimal buffer-managed storage engine tying the primitives together.

This is the validation vehicle of paper §3.3.2 (HyMem + YCSB): a DRAM
buffer pool of fixed-size pages over a PMem page region, with a
write-ahead log using any of the three logging techniques. The buffer
pool is a real one since PR 5: a bounded
:class:`~repro.cache.BufferManager` (``pool.cache``) rather than a
resident array — reads fault frames in from whichever tier holds the
page (DRAM frame → PMem slot → SSD spill extent), writes dirty frames
that the checkpoint epoch writes back, and ``KVConfig(cache_frames=…)``
bounds the DRAM footprint independently of the PMem slot budget. It
exists to

  * demonstrate the I/O primitives composing into a correct engine,
  * run the YCSB-style 100 %-write validation (``benchmarks/tab_ycsb.py``),
  * provide the crash-recovery property-test target (arbitrary eviction
    subsets at crash time must never lose a committed put).

All persistent layout goes through :class:`repro.pool.Pool` — the engine
never sees a raw byte offset. Its named directory regions are
``<name>.root`` (failure-atomic ping-pong root: two slots, max-generation
rule, same line-atomicity argument as the pvn), ``<name>.pages``
(PageStore slots + µlogs) and the redo log: a single region
``<name>.wal`` by default, or — with ``KVConfig(wal_lanes > 1)`` — a
generational lane-striped :class:`~repro.io.multilog.MultiLog` over
``<name>.wal.g<j>.lane<i>`` plus the ``<name>.wal.gen`` ring header. A
tiered engine (``KVConfig(slot_budget=…)``) adds the spill scheduler's
regions (``<name>.sp.*``) and requires a flash device on the pool
(``pool.attach_ssd``). The preferred constructor is
``pool.kv(name, cfg)``; passing a bare :class:`PMem` still works as a
deprecation shim (the engine formats/attaches a pool in place — raw
base offsets are gone, the shim exists only for old call sites).

Commit protocol per ``put``: modify the DRAM page (track dirty lines),
append a redo record to the WAL, persist per the technique. Background
``checkpoint()`` flushes dirty pages (hybrid CoW/µLog; through a
spill-aware flush-queue epoch when tiered, so a working set larger than
the PMem slot budget overflows to SSD instead of failing) and then
advances the root recording the checkpoint LSN, and truncates the WAL —
``reset`` in place for a single-lane log, a generation ``roll`` for the
striped one (the sealed generation is retired to SSD by the same
epoch's spill drain, which is what bounds the PMem log footprint over
an unbounded run). Recovery = page table scan (cross-tier max-pvn rule
when spilled) + µlog replay + redo of WAL entries past the checkpoint
LSN (puts are idempotent, so the §3.2.1 "log entries might be
reapplied" caveat is benign here).
"""

from __future__ import annotations

import dataclasses
import struct
import warnings
from typing import Dict, Optional, Tuple

from repro.core.blocks import BlockGeometry, PAPER_GEOMETRY, align_up
from repro.core.costmodel import COST_MODEL
from repro.core.log import LogConfig
from repro.core.pageflush import PageStore, PageStoreLayout
from repro.core.pmem import PMem

__all__ = ["PersistentKV", "KVConfig", "RecoveryReport"]

_ROOT = struct.Struct("<QQ")  # generation, checkpoint_lsn
_REC = struct.Struct("<II")   # key, value_len   (redo record header)

#: spill-map log capacity per buffer for a tiered KV — referenced by both
#: the scheduler construction and the region_bytes sizing, which must agree
_SPILL_MAP_CAPACITY = 1 << 14


@dataclasses.dataclass(frozen=True)
class KVConfig:
    """Engine configuration. The tiered-storage knobs (``slot_budget``,
    ``wal_lanes``/``wal_gen_sets``) turn the fixed-size engine into one
    whose working set may exceed its PMem budget: cold page slots spill
    to the pool's attached SSD, and the redo log runs lane-striped over a
    generation ring that a checkpoint rolls (and the spill tier retires)
    instead of growing without bound."""

    npages: int = 16
    page_size: int = 4096
    value_size: int = 64
    log_capacity: int = 1 << 20
    technique: str = "zero"              # classic | header | zero
    log: LogConfig = dataclasses.field(default_factory=LogConfig)
    geometry: BlockGeometry = PAPER_GEOMETRY
    auto_checkpoint: bool = True
    #: checkpoint page flushing runs through a lane-partitioned
    #: repro.io FlushQueue when > 1 (the Hybrid crossover then follows
    #: the actual active-lane count of each checkpoint epoch)
    flush_lanes: int = 1
    #: PMem page-slot budget. None = the classic sizing (every page fits:
    #: npages + 25 % slack). A value <= npages *overcommits* the slot
    #: array — the pool must have an SSD attached, and a SpillScheduler
    #: evicts cold slots at checkpoint epochs instead of failing.
    slot_budget: Optional[int] = None
    #: redo-log stripe width; > 1 runs the WAL on a generational
    #: repro.io MultiLog (regions <name>.wal.g<j>.lane<i>) whose
    #: generations a checkpoint seals and rolls
    wal_lanes: int = 1
    #: appends batched per lane barrier on the multi-lane WAL. 1 (the
    #: default) keeps every put() durable at return, like the single-lane
    #: WAL; > 1 trades that for amortized barriers (a put is durable at
    #: the next full batch or checkpoint)
    wal_group_commit: int = 1
    #: generation ring size for the multi-lane WAL (>= 2): bounded PMem
    #: log footprint = wal_gen_sets x log_capacity
    wal_gen_sets: int = 2
    #: fraction of page slots the spill keeps free beyond each epoch's
    #: immediate need (eviction slack)
    spill_low_watermark: float = 0.25
    #: NUMA home socket for the engine's root/pages (and single-lane WAL)
    #: regions on a multi-socket pool; multi-lane WAL regions are spread
    #: over the sockets by the pool's LanePlacer regardless
    socket: int = 0
    #: DRAM buffer-pool frames. None = every page fits (``npages`` frames
    #: — the classic resident buffer pool). A smaller value bounds the
    #: engine's DRAM footprint: cold frames are clock-evicted (dirty ones
    #: park in the flush queue until the next checkpoint epoch) and reads
    #: fault back in from the page's resident tier. 0 disables caching.
    cache_frames: Optional[int] = None
    #: touches before an SSD-resident page is promoted back into a PMem
    #: slot on read (k-touch admission; 1 = promote on first access)
    cache_admit_k: int = 2
    #: 2Q probationary fraction of a quota'd owner's frame budget
    #: (scan resistance; 1.0 disables the split — see
    #: ``pool.cache(scan_frac=)``)
    cache_scan_frac: float = 1.0

    @property
    def recs_per_page(self) -> int:
        return self.page_size // self.value_size

    @property
    def nkeys(self) -> int:
        return self.npages * self.recs_per_page

    @property
    def nslots(self) -> int:
        if self.slot_budget is not None:
            return self.slot_budget
        return self.npages + max(2, self.npages // 4)

    @property
    def tiered(self) -> bool:
        """Whether this config needs the SSD tier (overcommitted slots)."""
        return self.slot_budget is not None and self.slot_budget <= self.npages


@dataclasses.dataclass
class RecoveryReport:
    """What one engine reopen's WAL replay did, on the modeled clock.

    ``active_lanes`` is the number of WAL lanes that contributed
    replayed records; the replay *applies* records in global-LSN order
    (cross-lane writes to one key must land in commit order) but
    *attributes* each record's device work to the lane that carried it,
    so ``engine_time_ns``'s max-over-lanes model prices the lanes
    draining concurrently — Izraelevitz et al. (arXiv:1903.05714): PMem
    read bandwidth scales with thread count far better than writes, so
    a lane-striped WAL should replay at lane parallelism, not as one
    serial stream."""

    wal_entries: int = 0
    #: bytes of replayed redo records (the recovery read-scan traffic)
    wal_bytes: int = 0
    active_lanes: int = 1
    modeled_ns: float = 0.0


class PersistentKV:
    """Fixed-size-record KV store: DRAM buffer pool + pool-managed PMem."""

    def __init__(self, pool_or_pmem, cfg: KVConfig, *, name: str = "kv",
                 _recover: bool = False) -> None:
        from repro.pool import Pool
        if isinstance(pool_or_pmem, PMem):
            # deprecation shim for the legacy (pmem, cfg) constructor:
            # format-or-open a pool directly over the caller's region
            warnings.warn(
                "PersistentKV(pmem, cfg) raw-region construction is "
                "deprecated; use pool.kv(name, cfg) on a repro.pool.Pool "
                "instead", DeprecationWarning, stacklevel=2)
            pmpool = Pool.attach(pool_or_pmem)
        else:
            pmpool = pool_or_pmem
        if cfg.geometry != pmpool.geometry:
            raise ValueError("KVConfig.geometry must match the pool geometry")
        self._pmpool = pmpool
        self.pmem = pmpool.pmem
        self.cfg = cfg
        self.name = name
        g = cfg.geometry

        recover = _recover or pmpool.directory.lookup(f"{name}.root") is not None
        #: lane placer for the WAL stripes and checkpoint flush epochs
        #: (None on a single-socket pool — placement is then a no-op)
        self._placer = pmpool.placer() if pmpool.sockets > 1 else None
        self.root = pmpool.raw(f"{name}.root", nbytes=2 * g.cache_line,
                               socket=cfg.socket)
        pages = pmpool.pages(f"{name}.pages", npages=cfg.npages,
                             page_size=cfg.page_size, nslots=cfg.nslots,
                             socket=cfg.socket)
        self.store: PageStore = pages.store
        self._spill = None
        if cfg.tiered:
            from repro.tier import SpillScheduler
            if pmpool.ssd_dev is None:
                raise ValueError(
                    f"KVConfig(slot_budget={cfg.slot_budget}) overcommits "
                    f"{cfg.npages} pages onto {cfg.nslots} PMem slots; "
                    f"attach a flash device first (pool.attach_ssd)")
            self._spill = SpillScheduler(
                pmpool, name=f"{name}.sp",
                low_watermark=cfg.spill_low_watermark,
                map_capacity=_SPILL_MAP_CAPACITY)
            self._spill.attach_pages(pages)
        if cfg.wal_lanes > 1:
            from repro.io.multilog import MultiLog
            self.wal = MultiLog(pmpool, f"{name}.wal", lanes=cfg.wal_lanes,
                                capacity=cfg.log_capacity,
                                technique=cfg.technique,
                                group_commit=cfg.wal_group_commit,
                                cfg=cfg.log, gen_sets=cfg.wal_gen_sets,
                                placer=self._placer)
            if self._spill is not None:
                self.wal.attach_spill(self._spill)
        else:
            self.wal = pmpool.log(f"{name}.wal", capacity=cfg.log_capacity,
                                  technique=cfg.technique, cfg=cfg.log,
                                  socket=cfg.socket)
        self.checkpoint_lsn = 0
        self._root_gen = 0
        # --- volatile state: the DRAM buffer pool is the pool's shared
        # BufferManager; the engine's dirty tracking, snapshot reads and
        # tier faulting all live behind cache.get/write/writeback --------
        from repro.io.flushq import FlushQueue
        self._fq = FlushQueue(self.store, lanes=cfg.flush_lanes,
                              spill=self._spill, placer=self._placer)
        # Explicit cache config is verified against a pre-existing pool
        # cache (conflict raises); values still at the KVConfig defaults
        # reuse it quietly. A cache-less pool defaults to the classic
        # resident buffer pool: one frame per page.
        from repro.cache import BufferManager
        self.cache = BufferManager.for_pool(
            pmpool, frames=cfg.cache_frames,
            admit_k=None if cfg.cache_admit_k == KVConfig.cache_admit_k
            else cfg.cache_admit_k,
            scan_frac=None if cfg.cache_scan_frac == KVConfig.cache_scan_frac
            else cfg.cache_scan_frac,
            default_frames=cfg.npages, default_admit_k=cfg.cache_admit_k,
            default_scan_frac=cfg.cache_scan_frac)
        self.cache.attach_pages(pages, flushq=self._fq, spill=self._spill)
        #: accounting of the most recent reopen's WAL replay (None on a
        #: fresh engine)
        self.last_recovery: Optional[RecoveryReport] = None
        if recover:
            self._recover_state()

    # ------------------------------------------------------------- sizing

    @staticmethod
    def region_bytes(cfg: KVConfig) -> int:
        """Pool region size that fits this engine (directory included).

        Accounts for whichever WAL shape the config selects — single-lane
        (one log region) or generational multi-lane (``wal_gen_sets``
        lane sets plus the generation header) — and for the spill
        scheduler's PMem-side regions (map double buffer + head) when the
        slot budget overcommits."""
        from repro.pool import DEFAULT_MAX_REGIONS, Pool
        g = cfg.geometry
        layout = PageStoreLayout(base=0, page_size=cfg.page_size,
                                 npages=cfg.npages, nslots=cfg.nslots,
                                 geometry=g,
                                 overcommit=cfg.nslots <= cfg.npages)
        if cfg.wal_lanes > 1:
            per_lane = g.pad_to_block(
                max(1, cfg.log_capacity // cfg.wal_lanes))
            wal_bytes = (cfg.wal_gen_sets * cfg.wal_lanes
                         * (per_lane + g.block)
                         + align_up(2 * g.cache_line, g.block))
        else:
            wal_bytes = cfg.log_capacity + 4 * g.block
        spill_bytes = 0
        if cfg.tiered:
            # map double buffer + ping-pong head (see PersistentKV.__init__)
            spill_bytes = 2 * (_SPILL_MAP_CAPACITY + g.block) \
                + align_up(2 * g.cache_line, g.block)
        return (Pool.overhead_bytes(g, DEFAULT_MAX_REGIONS)
                + align_up(2 * g.cache_line, g.block)
                + PageStore.region_bytes(layout, n_mulogs=1)
                + wal_bytes + spill_bytes)

    # --------------------------------------------------------------- api

    def _locate(self, key: int) -> Tuple[int, int]:
        if not (0 <= key < self.cfg.nkeys):
            raise KeyError(key)
        return key // self.cfg.recs_per_page, (key % self.cfg.recs_per_page) * self.cfg.value_size

    def put(self, key: int, value: bytes) -> int:
        """Durable upsert; returns the commit LSN (absolute across WAL
        generations; WAL-internal LSNs restart at 1 after a checkpoint)."""
        if len(value) != self.cfg.value_size:
            raise ValueError("fixed-size values only")
        pid, off = self._locate(key)
        # buffer-pool write: dirties the page's DRAM frame (faulting the
        # rest of the page in from its resident tier if needed — write
        # faults never promote); nothing touches PMem until a checkpoint
        self.cache.write(pid, off, value, store=self.store)
        try:
            lsn = self.wal.append(_REC.pack(key, len(value)) + value)
        except RuntimeError:
            if not self.cfg.auto_checkpoint:
                raise
            self.checkpoint()
            lsn = self.wal.append(_REC.pack(key, len(value)) + value)
        return self.checkpoint_lsn + lsn

    def get(self, key: int) -> bytes:
        pid, off = self._locate(key)
        page = self.cache.get(pid, store=self.store)
        return page[off : off + self.cfg.value_size].tobytes()

    # -------------------------------------------------------- checkpoint

    def checkpoint(self) -> None:
        """Flush all dirty pages (hybrid), advance the root, truncate the
        WAL.

        Page flushes precede the root update; a crash in between merely
        replays redo records onto already-flushed pages (idempotent puts).
        The dirty frames drain through the buffer manager's write-back
        epoch (one lane-partitioned ``FlushQueue`` drain at
        ``cfg.flush_lanes``, frames pinned for the duration); a tiered
        engine additionally spills cold slots to SSD during that epoch
        instead of failing allocation.

        WAL truncation depends on the log: a single-lane WAL starts a new
        generation in place (``reset`` re-zeroes the region); a multi-lane
        WAL *rolls* — the sealed generation moves to the next ring slot,
        stays recoverable, and the spill scheduler retires it to SSD in
        the same checkpoint epoch.
        """
        self.cache.writeback(self.store)
        ckpt_lsn = self.checkpoint_lsn + (self.wal.next_lsn - 1)
        self._root_gen += 1
        slot = self._root_gen % 2
        g = self.cfg.geometry
        self.root.store(slot * g.cache_line,
                        _ROOT.pack(self._root_gen, ckpt_lsn), streaming=True)
        self.root.persist(slot * g.cache_line, _ROOT.size)
        self.checkpoint_lsn = ckpt_lsn
        # New WAL generation. Multi-lane: seal + ring roll (and retire the
        # sealed generation to SSD within this checkpoint epoch). Single-
        # lane: re-zero in place (Zero logging requires it) and restart
        # the writer at LSN 1.
        if getattr(self.wal, "generational", False):
            self.wal.roll()
            if self._spill is not None:
                self._spill.drain()
        else:
            self.wal.reset()

    # ----------------------------------------------------------- recovery

    def _read_root(self) -> Tuple[int, int]:
        img = self.root.durable_view()
        best = (0, 0)
        g = self.cfg.geometry
        for slot in range(2):
            gen, lsn = _ROOT.unpack_from(img, slot * g.cache_line)
            if gen > best[0]:
                best = (gen, lsn)
        return best

    def _recover_state(self) -> None:
        self._root_gen, self.checkpoint_lsn = self._read_root()
        # No eager page loads: the buffer manager faults each page in
        # from whichever tier holds its newest version (cross-tier
        # max-pvn rule) on first access, and write faults never promote
        # — recovery does not churn the slot budget before the workload
        # tells us which pages are actually hot.
        # Redo WAL entries past the checkpoint (the handle recovered them
        # when it was opened, and is already positioned at the tail):
        # each write dirties the page's frame, re-flushed at the next
        # checkpoint exactly like a fresh put. Records APPLY in
        # global-LSN order (cross-lane writes to one key must land in
        # commit order) but each record's device work is attributed to
        # the WAL lane that carried it, so the cost model prices a
        # lane-striped WAL's replay at max-over-lanes — see
        # RecoveryReport.
        rec = self.wal.recovered
        lanes = getattr(rec, "lanes", None) or []
        lane_base = getattr(self.wal, "lane_id_base", 0)
        lane_cpu = getattr(self.wal, "lane_cpu", None)
        before = self.pmem.stats.snapshot()
        report = RecoveryReport()
        stripe_bytes: Dict[int, int] = {}
        for n, entry in enumerate(rec.entries):
            key, vlen = _REC.unpack_from(entry, 0)
            value = entry[_REC.size : _REC.size + vlen]
            pid, off = self._locate(key)
            report.wal_entries += 1
            report.wal_bytes += len(entry)
            if n < len(lanes) and lane_cpu is not None:
                lane = lanes[n]
                stripe_bytes[lane] = stripe_bytes.get(lane, 0) + len(entry)
                with self.pmem.lane(lane_base + lane,
                                    socket=lane_cpu[lane]):
                    self.cache.write(pid, off, bytes(value),
                                     store=self.store)
            else:
                stripe_bytes[-1] = stripe_bytes.get(-1, 0) + len(entry)
                self.cache.write(pid, off, bytes(value), store=self.store)
        report.active_lanes = max(1, len(set(lanes))) if lanes else 1
        # The replay scan reads each lane's stripe concurrently (PMem
        # reads scale with threads — Izraelevitz), so the scan term is
        # the LARGEST stripe, not the summed WAL bytes; a single-lane
        # log degenerates to the full serial scan.
        report.modeled_ns = COST_MODEL.engine_time_ns(
            self.pmem.stats.delta(before),
            active_lanes=report.active_lanes,
            scan_read_bytes=max(stripe_bytes.values(), default=0))
        self.last_recovery = report

    @classmethod
    def open(cls, pool_or_pmem, cfg: KVConfig, *, name: str = "kv") -> "PersistentKV":
        return cls(pool_or_pmem, cfg, name=name, _recover=True)

    # ------------------------------------------------- cross-shard handoff
    # (repro.cluster view changes: a migration's "copy" step reads the
    # source engine's *durable* cut — page images + committed WAL records
    # — so the bytes it ships are exactly what the source's own recovery
    # would reconstruct, and re-running an interrupted copy is idempotent.)

    def durable_page_image(self, pid: int):
        """The page's newest *flushed* content, read from whichever tier
        holds it (cross-tier max-pvn rule), or ``None`` if the page was
        never flushed. Never promotes, never touches DRAM frames — this
        is the migration copy source, not a read path."""
        if self._spill is not None:
            if self._spill.residency(self.store, pid) is None:
                return None
            return self._spill.read_page(self.store, pid, promote=False)
        if pid not in self.store.table:
            return None
        data, _pvn = self.store.fill_page(pid)
        return data

    def committed_wal_records(self):
        """``(key, value)`` pairs of every redo record a restart right
        now would replay, oldest first: sealed-but-unretired generations
        (rare — checkpoint retires them in the same epoch), then the
        durable prefix of the live generation re-read from PMem. Applied
        through the target's own ``put`` during a migration, so each
        record lands in the target's WAL *after* the page images it
        supersedes."""
        out = []
        if getattr(self.wal, "generational", False):
            sealed = self.wal.sealed_generations()
            for gen in sorted(sealed):
                for entry in sealed[gen]:
                    key, vlen = _REC.unpack_from(entry, 0)
                    out.append((key, bytes(entry[_REC.size:_REC.size + vlen])))
        for entry in self.wal.recover().entries:
            key, vlen = _REC.unpack_from(entry, 0)
            out.append((key, bytes(entry[_REC.size:_REC.size + vlen])))
        return out

    def discard_page(self, pid: int) -> None:
        """Drop every copy of a page this engine holds — DRAM frame,
        parked flush-queue image, PMem slot, SSD extent. The view-change
        invalidation step: only call when the page's content is durably
        owned elsewhere (the ownership record has flipped), because the
        bytes are gone from this engine afterwards."""
        self.cache.drop(pid, store=self.store)
        if self._spill is not None:
            self._spill.discard_page(self.store, pid)
        elif pid in self.store.table:
            self.store.release(pid)
