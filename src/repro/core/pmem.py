"""Functional PMem model: region + CPU-cache/WC-buffer semantics + crash sim.

This is the substrate the paper's primitives (log writers, page flushers) run
on. Two concerns are deliberately separated:

1. **Functional semantics** (this module) — which bytes are durable when.
   Stores land in a modeled CPU cache; they reach the persistent domain only
   via (a) an explicit flush (``clflush``/``clflushopt``/``clwb``) followed by
   an ``sfence``, (b) a non-temporal store drained by an ``sfence``, or (c)
   *spontaneous eviction*, which the hardware may perform AT ANY TIME
   (paper §3.1: "programs cannot prevent the eviction"). Crash simulation
   therefore makes an *arbitrary subset* of unflushed dirty lines durable —
   failure-atomic algorithms must be correct for every such subset, which is
   exactly what the hypothesis property tests assert.

2. **Cost accounting** — exact counts of barriers, flushed lines, device
   block writes (after write combining), same-line rewrites, and bytes moved.
   ``core.costmodel`` converts these counts into modeled time using constants
   calibrated to the paper's measured ratios. The counts themselves are
   ground truth of the algorithms (e.g. "Zero logging issues exactly one
   barrier per entry") and are asserted in unit tests.

The region is optionally file-backed (``np.memmap``) so the training
checkpoint/WAL layer gets real on-disk persistence; crash simulation then
operates on the in-memory cache layers only.
"""

from __future__ import annotations

import bisect
import collections
import contextlib
import dataclasses
import os
from typing import Callable, Dict, Iterable, Iterator, Optional, Set

import numpy as np

from repro.core.blocks import BlockGeometry, PAPER_GEOMETRY
from repro.core.persist import FlushKind

__all__ = ["PMem", "PMemStats", "CrashImage"]

#: How many most-recently-flushed lines count as "temporally close" for the
#: same-line-rewrite penalty (paper §2.3 / Fig. 4 "same cache line" group).
_RECENCY_WINDOW = 8


@dataclasses.dataclass
class PMemStats:
    """Exact operation counts. All fields are monotonic counters."""

    stores: int = 0
    store_bytes: int = 0
    nt_stores: int = 0
    nt_store_bytes: int = 0
    loads: int = 0
    load_bytes: int = 0
    device_read_bytes: int = 0  # loads that bypass the cache (cold page reads)

    flushes: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {k.value: 0 for k in FlushKind}
    )
    lines_flushed: int = 0
    sfences: int = 0
    barriers: int = 0  # sfences that actually had pending persistent work

    blocks_written: int = 0       # 256 B device writes after WC combining
    partial_block_writes: int = 0  # device writes covering < lines_per_block
    same_line_flushes: int = 0    # flush of a line flushed very recently
    same_line_nt: int = 0         # nt store to a line nt-stored very recently

    # Per-lane accounting (repro.io engine): work performed inside a
    # ``PMem.lane(i)`` context is additionally attributed to lane ``i``.
    # Lanes model concurrently-executing writers; ``costmodel.engine_time_ns``
    # takes the max over lanes instead of summing (lane work overlaps).
    lane_barriers: Dict[int, int] = dataclasses.field(default_factory=dict)
    lane_lines: Dict[int, int] = dataclasses.field(default_factory=dict)
    lane_blocks_written: Dict[int, int] = dataclasses.field(default_factory=dict)
    lane_partial_blocks: Dict[int, int] = dataclasses.field(default_factory=dict)

    # NUMA accounting: persistent work performed by a lane whose CPU socket
    # (``PMem.lane(i, socket=s)``) differs from the *home* socket of the
    # touched bytes (``PMem.set_home``). Far-socket PMem access costs
    # ~2-3x near-socket (Izraelevitz et al.); ``engine_time_ns`` charges
    # these counts the remote multipliers. Remote counts are always a
    # subset of the corresponding totals above.
    remote_barriers: int = 0
    remote_blocks_written: int = 0
    lane_remote_barriers: Dict[int, int] = dataclasses.field(default_factory=dict)
    lane_remote_blocks_written: Dict[int, int] = dataclasses.field(default_factory=dict)
    lane_remote_partial_blocks: Dict[int, int] = dataclasses.field(default_factory=dict)

    def snapshot(self) -> "PMemStats":
        d = dataclasses.replace(self)
        for f in dataclasses.fields(PMemStats):
            v = getattr(d, f.name)
            if isinstance(v, dict):
                setattr(d, f.name, dict(v))
        return d

    def delta(self, since: "PMemStats") -> "PMemStats":
        d = PMemStats()
        for f in dataclasses.fields(PMemStats):
            v = getattr(self, f.name)
            if isinstance(v, dict):
                sv = getattr(since, f.name)
                setattr(d, f.name, {k: v[k] - sv.get(k, 0) for k in v})
            else:
                setattr(d, f.name, v - getattr(since, f.name))
        return d

    def active_lanes(self) -> int:
        """Number of lanes that performed any persistent work."""
        lanes = set()
        for field in (self.lane_barriers, self.lane_lines,
                      self.lane_blocks_written, self.lane_partial_blocks):
            lanes.update(k for k, v in field.items() if v)
        return len(lanes)


@dataclasses.dataclass
class CrashImage:
    """The durable bytes after a simulated crash, plus what got evicted."""

    durable: np.ndarray
    evicted_lines: Set[int]
    dropped_lines: Set[int]


class PMem:
    """A byte-addressable persistent region with modeled cache semantics."""

    def __init__(
        self,
        size: int,
        *,
        path: Optional[str] = None,
        geometry: BlockGeometry = PAPER_GEOMETRY,
        sockets: int = 1,
    ) -> None:
        self.size = int(size)
        self.geometry = geometry
        #: socket topology: byte ranges have a *home* socket (set_home) and
        #: lanes an executing CPU socket (lane(i, socket=s)); a mismatch is
        #: a remote access and is counted in the ``remote_*`` stats.
        self.sockets = max(1, int(sockets))
        if path is not None:
            exists = os.path.exists(path) and os.path.getsize(path) == self.size
            mode = "r+" if exists else "w+"
            self._durable = np.memmap(path, dtype=np.uint8, mode=mode, shape=(self.size,))
        else:
            self._durable = np.zeros(self.size, dtype=np.uint8)
        self.path = path
        # Program-visible contents (cache + durable merged).
        self._logical = np.array(self._durable, dtype=np.uint8, copy=True)
        # Dirty cache lines: line index -> None (data lives in _logical).
        self._dirty: Set[int] = set()
        # Lines flushed (clwb/clflush/clflushopt) but not yet fenced. The
        # *data at flush time* is what the fence makes durable — a store
        # after the flush but before the fence is NOT covered (§3.1).
        self._staged: Dict[int, np.ndarray] = {}
        # Non-temporal stores buffered in the WC buffer, awaiting sfence.
        self._wc: Dict[int, np.ndarray] = {}
        # Lines resident in the CPU cache in *clean* state: written back by
        # clwb (which keeps the line valid) or brought in by a load. A
        # clflush/clflushopt removes the line; a later load of it is a
        # device read (``device_read_bytes``).
        self._clean: Set[int] = set()
        # Recently flushed / nt-stored lines for the same-line penalty.
        self._recent_flushed: collections.deque = collections.deque(maxlen=_RECENCY_WINDOW)
        self._recent_nt: collections.deque = collections.deque(maxlen=_RECENCY_WINDOW)
        #: lane currently executing (repro.io engine); None = unattributed.
        self._lane: Optional[int] = None
        #: CPU socket of the executing lane; None = topology-agnostic work
        #: (never counted remote).
        self._lane_socket: Optional[int] = None
        # home-socket interval map: parallel sorted arrays (base, end, socket)
        self._home_bases: list = []
        self._home_ends: list = []
        self._home_sockets: list = []
        self.stats = PMemStats()

    # ----------------------------------------------------------------- lanes

    @contextlib.contextmanager
    def lane(self, lane_id: int, *, socket: Optional[int] = None) -> Iterator[None]:
        """Attribute all persistent work inside the block to ``lane_id``.

        Lanes model *concurrently executing* writers (the sim itself runs
        them sequentially): each lane's barrier / line / block counts are
        recorded separately so ``costmodel.engine_time_ns`` can take the
        wall-clock max over lanes and apply the Fig. 2 concurrency curve
        for the number of simultaneously-active lanes.

        ``socket`` names the CPU socket the lane executes on: persistent
        work it performs against bytes whose home socket (:meth:`set_home`)
        differs is *remote* and additionally counted in the
        ``remote_*`` / ``lane_remote_*`` stats, which the cost model
        charges the Izraelevitz far-socket multipliers."""
        prev, prev_socket = self._lane, self._lane_socket
        self._lane = int(lane_id)
        self._lane_socket = None if socket is None else int(socket)
        try:
            yield
        finally:
            self._lane, self._lane_socket = prev, prev_socket

    def _lane_add(self, field: Dict[int, int], n: int = 1) -> None:
        if self._lane is not None and n:
            field[self._lane] = field.get(self._lane, 0) + n

    # --------------------------------------------------------------- sockets

    def set_home(self, off: int, size: int, socket: int) -> None:
        """Declare the home socket of byte range ``[off, off+size)`` —
        which socket's DIMMs back it. Unregistered bytes default to
        socket 0. Re-registering a base replaces its span (pool regions
        re-register on every open). Sockets beyond the topology clamp to
        the last socket (defensive: a durable tag from a wider machine)."""
        if size <= 0:
            return
        socket = min(max(0, int(socket)), self.sockets - 1)
        i = bisect.bisect_left(self._home_bases, off)
        if i < len(self._home_bases) and self._home_bases[i] == off:
            self._home_ends[i] = off + size
            self._home_sockets[i] = socket
        else:
            self._home_bases.insert(i, off)
            self._home_ends.insert(i, off + size)
            self._home_sockets.insert(i, socket)

    def home_socket(self, off: int) -> int:
        """Home socket of byte ``off`` (0 when unregistered)."""
        i = bisect.bisect_right(self._home_bases, off) - 1
        if i >= 0 and off < self._home_ends[i]:
            return self._home_sockets[i]
        return 0

    def _is_remote(self, line: int) -> bool:
        """Whether touching cache line ``line`` from the executing lane's
        CPU socket crosses a socket boundary."""
        if self._lane_socket is None:
            return False
        return self.home_socket(line * self.geometry.cache_line) != self._lane_socket

    # ------------------------------------------------------------------ io

    def _check(self, off: int, size: int) -> None:
        if off < 0 or size < 0 or off + size > self.size:
            raise ValueError(f"access [{off}, {off + size}) outside region of {self.size} B")

    def _lines(self, off: int, size: int) -> range:
        """Cache-line indices covering [off, off+size) under this region's
        geometry (64 B in paper mode, 4 KiB in checkpoint/TPU mode)."""
        cl = self.geometry.cache_line
        if size <= 0:
            return range(0)
        return range(off // cl, (off + size - 1) // cl + 1)

    def store(self, off: int, data: bytes | np.ndarray, *, streaming: bool = False) -> None:
        """Store bytes at ``off``. Regular stores dirty cache lines;
        streaming (non-temporal) stores go to the WC buffer and become
        durable at the next ``sfence`` without a flush instruction."""
        buf = np.frombuffer(bytes(data), dtype=np.uint8) if not isinstance(data, np.ndarray) else data.astype(np.uint8, copy=False).ravel()
        n = buf.size
        self._check(off, n)
        if n == 0:
            return
        self._logical[off : off + n] = buf
        lines = self._lines(off, n)
        if streaming:
            self.stats.nt_stores += 1
            self.stats.nt_store_bytes += n
            self._lane_add(self.stats.lane_lines, len(lines))
            for li in lines:
                if li in self._recent_nt:
                    self.stats.same_line_nt += 1
                self._recent_nt.append(li)
                lo = li * self.geometry.cache_line
                hi = min(lo + self.geometry.cache_line, self.size)
                self._wc[li] = self._logical[lo:hi].copy()
                self._dirty.discard(li)
                self._clean.discard(li)  # nt stores bypass (and evict) the cache
        else:
            self.stats.stores += 1
            self.stats.store_bytes += n
            self._dirty.update(lines)
            self._clean.difference_update(lines)  # cached, but dirty now

    def load(self, off: int, size: int, *, uncached: bool = False) -> np.ndarray:
        """Read bytes (program order — sees un-persisted stores).

        Lines that are neither dirty- nor clean-cached (nor sitting in the
        WC buffer) come from the device and count as ``device_read_bytes``;
        the read then installs them in the cache, clean. ``uncached=True``
        marks a read that deliberately bypasses the cache (e.g. CoW reading
        the old page version non-temporally): the full size is a device
        read and nothing is cached."""
        self._check(off, size)
        self.stats.loads += 1
        self.stats.load_bytes += size
        if uncached:
            self.stats.device_read_bytes += size
        elif size > 0:
            cl = self.geometry.cache_line
            for li in self._lines(off, size):
                if li in self._dirty or li in self._clean or li in self._wc:
                    continue
                lo, hi = li * cl, min((li + 1) * cl, self.size)
                self.stats.device_read_bytes += min(hi, off + size) - max(lo, off)
                self._clean.add(li)
        return self._logical[off : off + size].copy()

    # --------------------------------------------------------------- flush

    def flush(self, off: int, size: int, kind: FlushKind = FlushKind.CLWB) -> None:
        """Issue a flush instruction for every cache line covering the range.
        Data is *staged*; durability requires a subsequent ``sfence``."""
        if kind == FlushKind.NT:
            raise ValueError("NT is a store attribute, not a flush instruction")
        self._check(off, size)
        self.stats.flushes[kind.value] += 1
        self._lane_add(self.stats.lane_lines, len(self._lines(off, size)))
        for li in self._lines(off, size):
            self.stats.lines_flushed += 1
            if li in self._recent_flushed:
                self.stats.same_line_flushes += 1
            self._recent_flushed.append(li)
            lo = li * self.geometry.cache_line
            hi = min(lo + self.geometry.cache_line, self.size)
            self._staged[li] = self._logical[lo:hi].copy()
            self._dirty.discard(li)
            if kind in (FlushKind.FLUSH, FlushKind.FLUSHOPT):
                # clflush/clflushopt invalidate: a later load is a device read
                self._clean.discard(li)
            else:
                # clwb keeps the line cached (clean)
                self._clean.add(li)

    def sfence(self) -> None:
        """Commit all staged flushes and WC-buffered streaming stores to the
        durable domain. Counts as a *barrier* iff there was pending work."""
        self.stats.sfences += 1
        pending = {}
        pending.update(self._staged)
        pending.update(self._wc)  # nt data wins for lines in both (later store)
        if pending:
            self.stats.barriers += 1
            self._lane_add(self.stats.lane_barriers)
            if self._lane_socket is not None and any(
                    self._is_remote(li) for li in pending):
                # the fence waits for the far socket's ADR domain to ack
                self.stats.remote_barriers += 1
                self._lane_add(self.stats.lane_remote_barriers)
            self._commit(pending)
        self._staged.clear()
        self._wc.clear()

    def persist(self, off: int, size: int, kind: FlushKind = FlushKind.CLWB) -> None:
        """The paper's ``persist()``: flush covering lines, then sfence.
        For data written with streaming stores pass ``kind=FlushKind.NT``:
        no flush instruction is needed, only the fence."""
        if kind != FlushKind.NT:
            self.flush(off, size, kind)
        self.sfence()

    # -------------------------------------------------------------- commit

    def _commit(self, lines: Dict[int, np.ndarray]) -> None:
        """Write staged lines into the durable image, accounting device
        block writes after write combining: lines committed *together* that
        fall in the same 256 B block combine into one block write."""
        blocks: Dict[int, int] = {}
        lpb = self.geometry.lines_per_block
        for li, data in lines.items():
            lo = li * self.geometry.cache_line
            self._durable[lo : lo + data.size] = data
            blocks[li // lpb] = blocks.get(li // lpb, 0) + 1
        for blk, nlines in blocks.items():
            self.stats.blocks_written += 1
            self._lane_add(self.stats.lane_blocks_written)
            remote = self._is_remote(blk * lpb)
            if remote:
                self.stats.remote_blocks_written += 1
                self._lane_add(self.stats.lane_remote_blocks_written)
            if nlines < lpb:
                self.stats.partial_block_writes += 1
                self._lane_add(self.stats.lane_partial_blocks)
                if remote:
                    self._lane_add(self.stats.lane_remote_partial_blocks)

    # --------------------------------------------------------------- crash

    def crash(
        self,
        *,
        evict: Optional[Callable[[int], bool]] = None,
        rng: Optional[np.random.Generator] = None,
        evict_prob: float = 0.5,
    ) -> CrashImage:
        """Simulate a power failure.

        Every line that was dirty, staged-but-not-fenced, or WC-buffered may
        or may not have reached the durable domain (spontaneous eviction is
        legal at any time; a fence was never issued so nothing is promised).
        ``evict`` (or Bernoulli(evict_prob) under ``rng``) decides per line.
        Returns the durable image; the region object itself is reset to it.
        """
        if evict is None:
            gen = rng or np.random.default_rng(0)
            evict = lambda li: bool(gen.random() < evict_prob)  # noqa: E731
        candidates: Dict[int, np.ndarray] = {}
        for li in self._dirty:
            lo = li * self.geometry.cache_line
            hi = min(lo + self.geometry.cache_line, self.size)
            candidates[li] = self._logical[lo:hi].copy()
        candidates.update(self._staged)
        candidates.update(self._wc)
        evicted: Set[int] = set()
        dropped: Set[int] = set()
        survivors: Dict[int, np.ndarray] = {}
        for li, data in sorted(candidates.items()):
            if evict(li):
                evicted.add(li)
                survivors[li] = data
            else:
                dropped.add(li)
        if survivors:
            self._commit(survivors)
        self._dirty.clear()
        self._staged.clear()
        self._wc.clear()
        self._clean.clear()
        self._logical = np.array(self._durable, dtype=np.uint8, copy=True)
        return CrashImage(
            durable=np.array(self._durable, copy=True),
            evicted_lines=evicted,
            dropped_lines=dropped,
        )

    # ---------------------------------------------------------------- misc

    def durable_view(self) -> np.ndarray:
        """The current durable image (what recovery would see)."""
        return np.array(self._durable, copy=True)

    def durable_slice(self, off: int, size: int) -> np.ndarray:
        """Copy of one byte range of the durable image — recovery reads of
        small structures (roots, directory tables) without paying an
        O(region) copy."""
        self._check(off, size)
        return np.array(self._durable[off : off + size], copy=True)

    def fsync(self) -> None:
        """For file-backed regions: push the durable image to stable media."""
        if isinstance(self._durable, np.memmap):
            self._durable.flush()

    def memset_zero(self) -> None:
        """Pre-zero the region (Zero logging requires a zeroed file; the
        paper notes DBs do this anyway to force file-system allocation)."""
        self._logical[:] = 0
        self._durable[:] = 0
        self._dirty.clear()
        self._staged.clear()
        self._wc.clear()
        self._clean.clear()

    def reset_stats(self) -> PMemStats:
        old = self.stats
        self.stats = PMemStats()
        return old
