from repro.data.synthetic import SyntheticPipeline, synthetic_batch  # noqa: F401
