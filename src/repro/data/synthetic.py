"""Synthetic data pipeline — deterministic and cursor-resumable.

Every batch is a pure function of (config, cursor); the cursor is committed
per step in the training WAL, so after a crash the pipeline resumes exactly
where the last durable step left it (no duplicated or skipped batches —
the data-side half of exactly-once step semantics).

Batches carry the modality extras the assigned families need: mel-frame
embeddings for whisper (conv frontend stubbed per the assignment), patch
embeddings + M-RoPE position ids for qwen2-vl.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


def _tokens(rng: np.random.Generator, batch: int, seq: int, vocab: int) -> np.ndarray:
    # Mixture of zipf-ish and uniform tokens — enough structure for loss to
    # move under training without any external corpus.
    z = rng.zipf(1.3, size=(batch, seq)) % vocab
    u = rng.integers(0, vocab, size=(batch, seq))
    pick = rng.random((batch, seq)) < 0.5
    return np.where(pick, z, u).astype(np.int32)


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, cursor: int,
                    *, np_dtype=np.float32) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(cursor * 2654435761 % (2**31))
    toks = _tokens(rng, batch, seq, cfg.vocab_size)
    labels = np.concatenate(
        [toks[:, 1:], np.full((batch, 1), -1, np.int32)], axis=1)
    out: Dict[str, np.ndarray] = {"tokens": toks, "labels": labels.astype(np.int32)}
    if cfg.frontend == "audio_frames":
        out["frames"] = rng.standard_normal(
            (batch, seq, cfg.d_model)).astype(np_dtype)
    if cfg.frontend == "vision_patches":
        s_vis = max(1, seq // 4)
        out["vis_embeds"] = rng.standard_normal(
            (batch, s_vis, cfg.d_model)).astype(np_dtype)
        # M-RoPE ids: text positions identical across (t, h, w); patch
        # positions get a simple grid (the real model derives them from the
        # image layout — frontend is a stub here).
        base = np.broadcast_to(np.arange(seq, dtype=np.int32), (batch, seq))
        pos = np.stack([base, base, base])
        out["positions"] = pos.astype(np.int32)
    return out


@dataclasses.dataclass
class SyntheticPipeline:
    """Resumable iterator: ``pipeline.batch(cursor)``; the training loop owns
    the cursor and persists it in the WAL."""

    cfg: ModelConfig
    batch: int
    seq: int

    def batch_at(self, cursor: int) -> Dict[str, np.ndarray]:
        return synthetic_batch(self.cfg, self.batch, self.seq, cursor)
