"""``repro.pool`` — a PMDK-style pool/handle API over the PMem primitives.

This is the single entry point every PMem consumer goes through. A *pool*
is one PMem region (optionally file-backed) whose head holds a durable
:class:`~repro.core.directory.RegionDirectory`: a table of named, typed,
geometry-tagged regions, each allocated failure-atomically (single-cache-
line entry commit, pvn-style max-generation validity). On top of the
directory sit uniform *handles*, all sharing one lifecycle protocol —
open-or-create by name, recover automatically, ``close()`` when done, and
a ``stats()`` delta view windowing the pool's exact op counts from the
moment the handle was opened (pool-wide counters: concurrent handles on
one pool see each other's traffic):

    pool = Pool.create("/dev/shm/app.pmem", 1 << 24)
    wal  = pool.log("wal", capacity=1 << 20, technique="zero")
    wal.append(b"record")                       # ONE barrier (paper §3.3.1)

    pages = pool.pages("heap", npages=64, page_size=16384)
    pages.flush(0, page, dirty_lines=[3, 4])    # hybrid CoW/µLog (§3.2.3)

    kv = pool.kv("store", KVConfig())           # buffer pool + WAL + root
    train_wal = pool.wal("steps", capacity_steps=10_000)
    cache = pool.cache(frames=64, admit_k=2)    # DRAM rung (repro.cache)

    pool2 = Pool.open("/dev/shm/app.pmem")      # after crash: same names,
    wal2  = pool2.log("wal")                    # recovered to the tail

Geometry is a pool-level property (paper 64 B/256 B or TPU 4 KiB/16 KiB
tiles) recorded in the superblock, so ``Pool.open`` needs no out-of-band
configuration. Handles never hand out raw byte offsets; all layout math
lives behind the directory.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.blocks import BlockGeometry, PAPER_GEOMETRY, align_up
from repro.core.directory import (
    KIND_LOG,
    KIND_PAGES,
    KIND_RAW,
    KIND_SSD,
    RegionDirectory,
    RegionRecord,
    directory_bytes,
    probe_file,
)
from repro.core.log import LOG_TECHNIQUES, LogConfig, RecoveredLog
from repro.core.pageflush import PageStore, PageStoreLayout
from repro.core.persist import FlushKind
from repro.core.pmem import PMem, PMemStats
from repro.core.ssd import SSD

__all__ = [
    "Pool",
    "Handle",
    "LogHandle",
    "PagesHandle",
    "RawHandle",
    "SSDRegionHandle",
    "DEFAULT_MAX_REGIONS",
]

DEFAULT_MAX_REGIONS = 64

_TECH_ID = {"classic": 0, "header": 1, "zero": 2}
_TECH_NAME = {v: k for k, v in _TECH_ID.items()}
_FLAG_PAD_LINE = 1
_FLAG_PAD_BLOCK = 2


def _log_meta(technique: str, cfg: LogConfig) -> Tuple[int, int, int, int]:
    flags = (_FLAG_PAD_LINE if cfg.pad_to_line else 0) | (
        _FLAG_PAD_BLOCK if cfg.pad_to_block else 0)
    return (_TECH_ID[technique], flags, cfg.dancing, 0)


def _log_cfg_from_meta(meta: Sequence[int], geometry: BlockGeometry,
                       flush_kind: FlushKind) -> Tuple[str, LogConfig]:
    technique = _TECH_NAME[meta[0]]
    cfg = LogConfig(
        geometry=geometry,
        pad_to_line=bool(meta[1] & _FLAG_PAD_LINE),
        pad_to_block=bool(meta[1] & _FLAG_PAD_BLOCK),
        dancing=int(meta[2]) or 1,
        flush_kind=flush_kind,
    )
    return technique, cfg


class Handle:
    """Base of every pool handle: name/record access and a stats window."""

    def __init__(self, pool: "Pool", record: RegionRecord) -> None:
        """Bind to ``record`` in ``pool`` and open a stats window."""
        self.pool = pool
        self.record = record
        self._stats0 = pool.pmem.stats.snapshot()
        self._closed = False

    # -- identity ---------------------------------------------------------
    @property
    def name(self) -> str:
        """The region's directory name."""
        return self.record.name

    @property
    def base(self) -> int:
        """First byte of the region (pool-absolute; SSD-space for
        ``KIND_SSD`` records)."""
        return self.record.base

    @property
    def length(self) -> int:
        """Region size in bytes."""
        return self.record.length

    # -- lifecycle --------------------------------------------------------
    def stats(self) -> PMemStats:
        """Exact op counts accrued on the pool since this handle was opened
        (or since :meth:`reset_stats`)."""
        return self.pool.pmem.stats.delta(self._stats0)

    def reset_stats(self) -> None:
        """Restart the stats window at the current pool counters."""
        self._stats0 = self.pool.pmem.stats.snapshot()

    def close(self) -> None:
        """Drop volatile state. The durable region stays; reopen by name."""
        self._closed = True

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError(f"handle {self.name!r} is closed")


class LogHandle(Handle):
    """One interface over the three log techniques, recovery included.

    Created by :meth:`Pool.log`. ``append()`` costs exactly
    ``barriers_per_append`` persistency barriers (1 for Zero, 2 for
    Header/Classic); ``recovered`` holds what recovery found at open time
    (empty for a fresh region)."""

    def __init__(self, pool: "Pool", record: RegionRecord, technique: str,
                 cfg: LogConfig, writer, recovered: RecoveredLog) -> None:
        """Wrap an opened per-technique writer (built by :meth:`Pool.log`)
        together with what recovery found at open time."""
        super().__init__(pool, record)
        self.technique = technique
        self.cfg = cfg
        self._writer = writer
        self.recovered = recovered

    # -- append path ------------------------------------------------------
    def append(self, payload: bytes) -> int:
        """Durably append one entry; returns its LSN."""
        self._check_open()
        return self._writer.append(payload)

    def append_batch(self, payloads: Sequence[bytes]) -> list:
        """Group commit: durably append many entries, amortizing the
        technique's barriers over the batch (repro.io engine path)."""
        self._check_open()
        return self._writer.append_batch(list(payloads))

    @property
    def tail(self) -> int:
        """Byte offset (region-relative) where the next entry goes."""
        return self._writer.tail

    @property
    def next_lsn(self) -> int:
        """LSN the next append will receive."""
        return self._writer.next_lsn

    @property
    def barriers_per_append(self) -> int:
        """Persistency barriers per append (1 Zero, 2 Header/Classic)."""
        return self._writer.BARRIERS_PER_APPEND

    @property
    def capacity(self) -> int:
        """Region bytes available to the log."""
        return self.record.length

    # -- recovery ---------------------------------------------------------
    def recover(self) -> RecoveredLog:
        """Re-run recovery against the current *durable* image (what a
        restart would see right now)."""
        cls = LOG_TECHNIQUES[self.technique]
        return cls.recover(self.pool.pmem, self.base, self.length, self.cfg)

    def reset(self) -> None:
        """Start a new log generation: durably re-zero the region (Zero
        logging requires it; the others tolerate it) and restart the writer
        at LSN 1. Bulk streaming traffic, not barrier-bound."""
        self._check_open()
        pm = self.pool.pmem
        off, end = self.base, self.base + self.length
        while off < end:
            n = min(1 << 20, end - off)
            pm.store(off, np.zeros(n, dtype=np.uint8), streaming=True)
            off += n
        pm.sfence()
        cls = LOG_TECHNIQUES[self.technique]
        self._writer = cls(pm, self.base, self.length, self.cfg)
        self.recovered = RecoveredLog([], [], self._writer.tail, 1)


class PagesHandle(Handle):
    """Failure-atomic page region: CoW(+pvn) / µLog / hybrid flushing.

    Wraps a :class:`PageStore` (and its :class:`HybridPolicy`) whose layout
    — slot array plus µlogs — lives entirely inside this region."""

    def __init__(self, pool: "Pool", record: RegionRecord,
                 store: PageStore) -> None:
        """Wrap an opened :class:`PageStore` (built by :meth:`Pool.pages`)."""
        super().__init__(pool, record)
        self.store = store

    # layout / policy passthroughs ---------------------------------------
    @property
    def layout(self) -> PageStoreLayout:
        """The store's byte layout (slots, µlogs, geometry)."""
        return self.store.layout

    @property
    def policy(self):
        """The µLog-vs-CoW :class:`~repro.core.pageflush.HybridPolicy`."""
        return self.store.policy

    @property
    def table(self) -> Dict[int, Tuple[int, int]]:
        """Volatile page table: pid -> (slot, pvn)."""
        return self.store.table

    @property
    def npages(self) -> int:
        """Logical pages the region addresses."""
        return self.store.layout.npages

    @property
    def page_size(self) -> int:
        """Bytes per page."""
        return self.store.layout.page_size

    # flush / read --------------------------------------------------------
    def flush(self, pid: int, page: np.ndarray,
              dirty_lines: Optional[Sequence[int]] = None, *,
              threads: Optional[int] = None) -> str:
        """Hybrid flush (µLog vs CoW by the cost model); returns the
        technique used. See :meth:`PageStore.flush`."""
        self._check_open()
        return self.store.flush(pid, page, dirty_lines=dirty_lines,
                                threads=threads)

    def flush_queue(self, *, lanes: int = 4, lane_id_base: int = 0,
                    flush_fn=None, spill=None, placer=None):
        """A :class:`repro.io.FlushQueue` over this region: enqueue dirty
        pages, drain once per epoch with lane-partitioned, batched flushing
        (the Hybrid crossover then follows the actual active-lane count).
        ``spill`` attaches a :class:`repro.tier.SpillScheduler` so epochs
        that outgrow the slot budget evict to SSD instead of raising;
        ``placer`` defaults to the pool's lane placer on a multi-socket
        pool (flush lanes then run near this region's home socket)."""
        from repro.io.flushq import FlushQueue
        if placer is None and self.pool.sockets > 1:
            placer = self.pool.placer()
        return FlushQueue(self, lanes=lanes, lane_id_base=lane_id_base,
                          flush_fn=flush_fn, spill=spill, placer=placer)

    def flush_cow(self, pid: int, page: np.ndarray, **kw) -> None:
        """Force a CoW(+pvn) flush. See :meth:`PageStore.flush_cow`."""
        self._check_open()
        self.store.flush_cow(pid, page, **kw)

    def flush_mulog(self, pid: int, page: np.ndarray,
                    dirty_lines: Sequence[int], **kw) -> None:
        """Force a µLog delta flush. See :meth:`PageStore.flush_mulog`."""
        self._check_open()
        self.store.flush_mulog(pid, page, dirty_lines, **kw)

    def read_page(self, pid: int) -> np.ndarray:
        """Program-order read of the page's current slot."""
        return self.store.read_page(pid)

    def durable_page(self, pid: int) -> Optional[np.ndarray]:
        """The page's durable image (what recovery would see), or
        ``None`` if no valid slot holds it."""
        return self.store.durable_page(pid)


class RawHandle(Handle):
    """An untyped byte range with handle-relative addressing — for small
    fixed structures (roots, superblock-like records) that a consumer
    commits with its own protocol."""

    def _span(self, off: int, size: int) -> None:
        if off < 0 or size < 0 or off + size > self.length:
            raise ValueError(
                f"access [{off}, {off + size}) outside region "
                f"{self.name!r} of {self.length} B")

    def store(self, off: int, data: bytes | np.ndarray, *,
              streaming: bool = False) -> None:
        """Store bytes at a handle-relative offset (bounds-checked)."""
        self._check_open()
        data = np.frombuffer(bytes(data), dtype=np.uint8) \
            if not isinstance(data, np.ndarray) else data
        self._span(off, data.size)
        self.pool.pmem.store(self.base + off, data, streaming=streaming)

    def load(self, off: int, size: int, **kw) -> np.ndarray:
        """Program-order read at a handle-relative offset."""
        self._span(off, size)
        return self.pool.pmem.load(self.base + off, size, **kw)

    def persist(self, off: int, size: int,
                kind: FlushKind = FlushKind.CLWB) -> None:
        """persist() a handle-relative range (flush covering lines +
        fence; ``kind=NT`` fences streaming stores)."""
        self._span(off, size)
        self.pool.pmem.persist(self.base + off, size, kind=kind)

    def durable_view(self) -> np.ndarray:
        """The region's durable image (what recovery would see)."""
        return self.pool.pmem.durable_slice(self.base, self.length)


class SSDRegionHandle(Handle):
    """A named range of the pool's attached SSD device (``KIND_SSD``).

    The *binding* (name → SSD byte range) is a durable single-line entry
    in the pool's PMem directory; the *bytes* live on the SSD attached
    via :meth:`Pool.attach_ssd`. Reads/writes are bounds-checked against
    the record and routed to the device; durability requires
    :meth:`flush` (the device's FLUSH CACHE), mirroring how PMem stores
    require a fence. Content validity across crashes is the consumer's
    protocol — the spill tier gates every read on a checksummed map
    record committed in PMem *after* the SSD flush."""

    def __init__(self, pool: "Pool", record: RegionRecord, ssd: SSD) -> None:
        """Bind a ``KIND_SSD`` record to the attached flash device."""
        super().__init__(pool, record)
        self.ssd = ssd

    def _span(self, off: int, size: int) -> None:
        if off < 0 or size < 0 or off + size > self.length:
            raise ValueError(
                f"access [{off}, {off + size}) outside SSD region "
                f"{self.name!r} of {self.length} B")

    def pwrite(self, off: int, data) -> None:
        """Write into the region (device write cache; durable at
        :meth:`flush`)."""
        self._check_open()
        data = np.frombuffer(bytes(data), dtype=np.uint8) \
            if not isinstance(data, np.ndarray) else data
        self._span(off, data.size)
        self.ssd.pwrite(self.base + off, data)

    def pread(self, off: int, size: int) -> np.ndarray:
        """Read from the region (sees unflushed writes)."""
        self._span(off, size)
        return self.ssd.pread(self.base + off, size)

    def flush(self) -> None:
        """Make every buffered write of the *device* durable (FLUSH
        CACHE is device-wide, like sfence is core-wide)."""
        self.ssd.flush()

    def durable_read(self, off: int, size: int) -> np.ndarray:
        """The durable image of a range (what recovery would see)."""
        self._span(off, size)
        return self.ssd.durable_read(self.base + off, size)


class Pool:
    """One PMem region + durable directory + uniform handles."""

    def __init__(self, pmem: PMem, directory: RegionDirectory) -> None:
        """Bind a PMem to its loaded directory (prefer :meth:`create` /
        :meth:`open` / :meth:`attach`)."""
        self.pmem = pmem
        self.directory = directory
        #: SSD device backing ``KIND_SSD`` regions (see :meth:`attach_ssd`)
        self.ssd_dev: Optional[SSD] = None
        self._placer = None
        self._cache = None

    # ------------------------------------------------------------ basics

    @property
    def geometry(self) -> BlockGeometry:
        """The pool's block geometry (from the superblock)."""
        return self.pmem.geometry

    @property
    def path(self) -> Optional[str]:
        """Backing file path, or ``None`` for an in-memory pool."""
        return self.pmem.path

    @property
    def size(self) -> int:
        """Pool size in bytes."""
        return self.pmem.size

    @property
    def free_bytes(self) -> int:
        """PMem bytes not yet claimed by any directory region."""
        return self.directory.free_bytes

    @property
    def sockets(self) -> int:
        """NUMA socket count the pool was formatted for (superblock)."""
        return self.pmem.sockets

    def placer(self):
        """The pool's default :class:`~repro.io.placer.LanePlacer` (cached):
        assigns lane CPU sockets near the lanes' home-socket regions,
        falling back to remote sockets only when near capacity is
        exhausted, and adapts per-lane group-commit sizes. MultiLog /
        FlushQueue consult it automatically on a multi-socket pool."""
        if self._placer is None:
            from repro.io.placer import LanePlacer
            self._placer = LanePlacer(self.pmem)
        return self._placer

    def cache(self, frames: Optional[int] = None,
              admit_k: Optional[int] = None,
              scan_frac: Optional[float] = None):
        """The pool's DRAM :class:`~repro.cache.BufferManager` (cached,
        like :meth:`placer`): one bounded frame pool fronting every page
        region that registers with it
        (:meth:`~repro.cache.BufferManager.attach_pages`) — the single
        read/write path across DRAM frames, PMem slots and the SSD
        spill tier. ``frames`` bounds the pool (0 disables caching;
        reads/writes pass straight through to the tiers); ``admit_k``
        is the k-touch SSD→PMem promotion threshold; ``scan_frac`` is
        the 2Q probationary fraction of a quota'd owner's budget (1.0
        disables scan resistance; per-owner overrides via
        :meth:`~repro.cache.BufferManager.set_scan_frac`). Defaults on
        first construction: 64 frames, ``admit_k=2``, ``scan_frac=1.0``.
        The first call fixes the configuration; a later call with a
        *different* explicit value raises (consumers sharing the pool
        share the cache)."""
        if self._cache is None:
            from repro.cache import BufferManager
            self._cache = BufferManager(
                self,
                frames=64 if frames is None else int(frames),
                admit_k=2 if admit_k is None else int(admit_k),
                scan_frac=1.0 if scan_frac is None else float(scan_frac))
            return self._cache
        if frames is not None and int(frames) != self._cache.capacity:
            raise ValueError(
                f"pool cache holds {self._cache.capacity} frames, caller "
                f"asked for {frames} — the frame pool is fixed at first "
                f"construction")
        if admit_k is not None and int(admit_k) != self._cache.admit_k:
            raise ValueError(
                f"pool cache admits at k={self._cache.admit_k}, caller "
                f"asked for {admit_k} — the admission policy is fixed at "
                f"first construction")
        if scan_frac is not None and float(scan_frac) != self._cache.scan_frac:
            raise ValueError(
                f"pool cache runs scan_frac={self._cache.scan_frac}, caller "
                f"asked for {scan_frac} — the 2Q split is fixed at first "
                f"construction (override per owner via set_scan_frac)")
        return self._cache

    def regions(self) -> Dict[str, RegionRecord]:
        """Snapshot of every committed directory record, by name."""
        return dict(self.directory.records)

    def fsync(self) -> None:
        """Push a file-backed pool's durable image to stable media."""
        self.pmem.fsync()

    @property
    def stats(self) -> PMemStats:
        """The pool's exact PMem op counters (pool-wide)."""
        return self.pmem.stats

    @staticmethod
    def overhead_bytes(geometry: BlockGeometry = PAPER_GEOMETRY,
                       max_regions: int = DEFAULT_MAX_REGIONS) -> int:
        """Directory bytes at the head of a pool — add this when sizing a
        region for a known payload."""
        return directory_bytes(geometry, max_regions)

    # --------------------------------------------------------- lifecycle

    @classmethod
    def create(cls, path: Optional[str], size: int, *,
               geometry: BlockGeometry = PAPER_GEOMETRY,
               max_regions: int = DEFAULT_MAX_REGIONS,
               sockets: int = 1) -> "Pool":
        """Format a fresh pool (``path=None`` → volatile in-memory region,
        used by simulations and benchmarks). ``sockets`` records the NUMA
        topology in the superblock; region creation then accepts
        ``socket=`` home tags and the lane placer prefers near-socket
        lanes (see ``docs/architecture.md``)."""
        pmem = PMem(size, path=path, geometry=geometry, sockets=sockets)
        pmem.memset_zero()
        directory = RegionDirectory.format(pmem, max_regions=max_regions)
        return cls(pmem, directory)

    @classmethod
    def open(cls, path: Optional[str] = None, *,
             pmem: Optional[PMem] = None) -> "Pool":
        """Open an existing pool from a file (geometry and size come from
        the superblock) or attach to a live :class:`PMem` (crash tests)."""
        if pmem is None:
            if path is None:
                raise ValueError("Pool.open needs a path or a pmem")
            sb = probe_file(path)
            if sb is None:
                if not os.path.exists(path):
                    raise FileNotFoundError(path)
                # existing-but-unreadable is corruption, not absence — a
                # try/except FileNotFoundError → create() fallback must
                # never format over a damaged pool
                raise ValueError(f"{path} exists but is not a formatted "
                                 f"pool (bad or torn superblock)")
            cache_line, block, _max_regions, size, sockets = sb
            actual = os.path.getsize(path)
            if actual != size:
                # never let PMem's size-mismatch branch recreate (truncate)
                # the file on what must be a read path
                raise ValueError(
                    f"{path}: superblock says {size} B but file is "
                    f"{actual} B — refusing to open a truncated/grown pool")
            pmem = PMem(size, path=path,
                        geometry=BlockGeometry(cache_line=cache_line,
                                               block=block),
                        sockets=sockets)
        return cls(pmem, RegionDirectory.load(pmem))

    @classmethod
    def open_or_create(cls, path: str, size: int, *,
                       geometry: BlockGeometry = PAPER_GEOMETRY,
                       max_regions: int = DEFAULT_MAX_REGIONS,
                       sockets: int = 1) -> "Pool":
        """Open ``path`` if it is a formatted pool, else create one there
        (refusing to overwrite a non-pool file). On open, the superblock's
        recorded socket topology wins over ``sockets``."""
        if probe_file(path) is not None:
            return cls.open(path)
        if os.path.exists(path) and os.path.getsize(path) > 0:
            # an existing non-pool file is someone's data, not ours to format
            raise ValueError(
                f"{path} exists but is not a formatted pool; refusing to "
                f"overwrite it (delete it or pick another path)")
        return cls.create(path, size, geometry=geometry,
                          max_regions=max_regions, sockets=sockets)

    @classmethod
    def attach(cls, pmem: PMem,
               max_regions: int = DEFAULT_MAX_REGIONS) -> "Pool":
        """Adopt a caller-owned PMem: open the directory if one is present,
        else format in place (the legacy-constructor shim path).

        Formatting is refused if the would-be directory span holds any
        nonzero durable byte — that is somebody's pre-pool data (e.g. a
        pre-directory legacy image), and formatting would zero it."""
        if RegionDirectory.is_formatted(pmem):
            return cls(pmem, RegionDirectory.load(pmem))
        span = directory_bytes(pmem.geometry, max_regions)
        if pmem.durable_slice(0, min(span, pmem.size)).any():
            raise ValueError(
                "region head holds durable data but no pool directory — "
                "refusing to format over it (zero the region explicitly to "
                "adopt it as a pool)")
        return cls(pmem, RegionDirectory.format(pmem, max_regions=max_regions))

    # ------------------------------------------------------------ handles

    def log(self, name: str, capacity: Optional[int] = None,
            technique: Optional[str] = None,
            cfg: Optional[LogConfig] = None, *,
            socket: Optional[int] = None) -> LogHandle:
        """Open-or-create a named log region.

        Create path (region absent): ``capacity`` is required; ``technique``
        defaults to ``"zero"``; ``socket`` tags the region's NUMA home
        socket (default 0). Open path: layout-relevant parameters come
        from the durable directory record; passing a conflicting
        ``technique``/``cfg``/``socket`` raises. ``cfg.flush_kind`` is
        volatile and honored either way."""
        rec = self.directory.lookup(name)
        flush_kind = cfg.flush_kind if cfg is not None else FlushKind.NT
        if rec is None:
            if capacity is None:
                raise ValueError(f"creating log {name!r} requires capacity=")
            technique = technique or "zero"
            if technique not in LOG_TECHNIQUES:
                raise ValueError(f"unknown log technique {technique!r}")
            cfg = dataclasses.replace(cfg or LogConfig(),
                                      geometry=self.geometry)
            rec = self.directory.allocate(name, KIND_LOG, int(capacity),
                                          _log_meta(technique, cfg),
                                          socket=socket or 0)
            cls = LOG_TECHNIQUES[technique]
            writer = cls(self.pmem, rec.base, rec.length, cfg)
            recovered = RecoveredLog([], [], writer.tail, 1)
            return LogHandle(self, rec, technique, cfg, writer, recovered)

        rec = self.directory.require(name, KIND_LOG)
        if capacity is not None and rec.length < capacity:
            raise ValueError(
                f"log {name!r} holds {rec.length} B, caller asked for "
                f"{capacity} B — the durable region cannot grow")
        if socket is not None and socket != rec.socket:
            raise ValueError(f"log {name!r} lives on socket {rec.socket}, "
                             f"caller asked for {socket} — home sockets "
                             f"are fixed at creation")
        stored_tech, stored_cfg = _log_cfg_from_meta(rec.meta, self.geometry,
                                                     flush_kind)
        if technique is not None and technique != stored_tech:
            raise ValueError(
                f"log {name!r} was created with technique "
                f"{stored_tech!r}, not {technique!r}")
        if cfg is not None and (
            (cfg.pad_to_line, cfg.pad_to_block, cfg.dancing)
            != (stored_cfg.pad_to_line, stored_cfg.pad_to_block,
                stored_cfg.dancing)
        ):
            raise ValueError(f"log {name!r}: cfg conflicts with the durable "
                             f"directory record")
        cls = LOG_TECHNIQUES[stored_tech]
        writer, recovered = cls.open_for_append(self.pmem, rec.base,
                                                rec.length, stored_cfg)
        return LogHandle(self, rec, stored_tech, stored_cfg, writer, recovered)

    def pages(self, name: str, npages: Optional[int] = None,
              page_size: Optional[int] = None, *,
              nslots: Optional[int] = None, n_mulogs: int = 1,
              threads: int = 1, socket: Optional[int] = None) -> PagesHandle:
        """Open-or-create a named failure-atomic page region (slot array +
        µlogs). Geometry-tagged via the pool; on open, the slot table is
        rebuilt from slot headers and valid µlogs are replayed.

        Passing ``nslots <= npages`` creates an *overcommitted* region:
        the PMem slot array holds fewer slots than logical pages and a
        :class:`repro.tier.SpillScheduler` must stand behind it to evict
        cold slots to SSD before CoW runs out (on reopen, overcommit is
        inferred from the durable geometry)."""
        rec = self.directory.lookup(name)
        if rec is None:
            if npages is None or page_size is None:
                raise ValueError(
                    f"creating pages {name!r} requires npages= and page_size=")
            nslots = nslots if nslots is not None else npages + max(2, npages // 4)
            layout = PageStoreLayout(base=0, page_size=page_size,
                                     npages=npages, nslots=nslots,
                                     geometry=self.geometry,
                                     overcommit=nslots <= npages)
            length = PageStore.region_bytes(layout, n_mulogs=n_mulogs)
            rec = self.directory.allocate(
                name, KIND_PAGES, length,
                (page_size, npages, nslots, n_mulogs),
                socket=socket or 0)
            layout = dataclasses.replace(layout, base=rec.base)
            store = PageStore(self.pmem, layout, n_mulogs=n_mulogs,
                              threads=threads)
            return PagesHandle(self, rec, store)

        rec = self.directory.require(name, KIND_PAGES)
        m_page, m_npages, m_nslots, m_mulogs = rec.meta
        m_mulogs &= 0xFFFF            # high bits carry the socket tag
        for arg, stored, what in ((npages, m_npages, "npages"),
                                  (page_size, m_page, "page_size"),
                                  (nslots, m_nslots, "nslots"),
                                  (socket, rec.socket, "socket")):
            if arg is not None and arg != stored:
                raise ValueError(f"pages {name!r}: {what}={arg} conflicts "
                                 f"with durable record ({stored})")
        layout = PageStoreLayout(base=rec.base, page_size=m_page,
                                 npages=m_npages, nslots=m_nslots,
                                 geometry=self.geometry,
                                 overcommit=m_nslots <= m_npages)
        store = PageStore.open(self.pmem, layout, n_mulogs=m_mulogs,
                               threads=threads)
        return PagesHandle(self, rec, store)

    def pages_layout(self, name: str) -> PageStoreLayout:
        """The durable layout of an existing pages region, without opening
        it (opening replays µlogs; verification passes may need the image
        untouched first)."""
        rec = self.directory.require(name, KIND_PAGES)
        m_page, m_npages, m_nslots, _ = rec.meta
        return PageStoreLayout(base=rec.base, page_size=m_page,
                               npages=m_npages, nslots=m_nslots,
                               geometry=self.geometry,
                               overcommit=m_nslots <= m_npages)

    def raw(self, name: str, nbytes: Optional[int] = None, *,
            socket: Optional[int] = None) -> RawHandle:
        """Open-or-create a named untyped region (``socket`` tags its NUMA
        home when creating; on open, a conflicting value raises — like
        :meth:`log` and :meth:`pages`, home sockets are fixed at
        creation)."""
        rec = self.directory.lookup(name)
        if rec is None:
            if nbytes is None:
                raise ValueError(f"creating raw {name!r} requires nbytes=")
            rec = self.directory.allocate(
                name, KIND_RAW, align_up(nbytes, self.geometry.block),
                socket=socket or 0)
        else:
            rec = self.directory.require(name, KIND_RAW)
            if nbytes is not None and nbytes > rec.length:
                raise ValueError(f"raw {name!r} holds {rec.length} B, "
                                 f"wanted {nbytes}")
            if socket is not None and socket != rec.socket:
                raise ValueError(
                    f"raw {name!r} lives on socket {rec.socket}, caller "
                    f"asked for {socket} — home sockets are fixed at "
                    f"creation")
        return RawHandle(self, rec)

    # ------------------------------------------------------- SSD tier

    def attach_ssd(self, ssd: SSD) -> SSD:
        """Attach the flash device backing this pool's ``KIND_SSD`` regions.

        The attachment is volatile (like the PMem object itself): on
        reopen after a crash, attach the device again before opening any
        SSD region handle. Returns the device for chaining."""
        if self.ssd_dev is not None and self.ssd_dev is not ssd:
            raise ValueError("pool already has an attached SSD device")
        end = self.directory.ssd_data_end
        if end > ssd.size:
            raise ValueError(
                f"directory has {end} B of committed SSD regions but the "
                f"attached device holds only {ssd.size} B")
        self.ssd_dev = ssd
        return ssd

    def ssd_region(self, name: str, nbytes: Optional[int] = None,
                   socket: Optional[int] = None) -> SSDRegionHandle:
        """Open-or-create a named SSD-backed region (``KIND_SSD``).

        Requires an attached device (:meth:`attach_ssd`). Creation
        bump-allocates ``nbytes`` of the SSD address space and commits the
        binding as a single-line directory entry; the SSD bytes are not
        zeroed (consumers gate reads on their own validity metadata).
        ``socket`` tags the region's NUMA home (the socket whose I/O
        complex the device hangs off — the cache's fill-socket
        accounting reads it back); like :meth:`log` and :meth:`pages`,
        home sockets are fixed at creation and a conflicting open
        raises."""
        if self.ssd_dev is None:
            raise RuntimeError(
                f"SSD region {name!r} needs a device: call "
                f"pool.attach_ssd(SSD(...)) first")
        rec = self.directory.lookup(name)
        if rec is None:
            if nbytes is None:
                raise ValueError(f"creating SSD region {name!r} requires "
                                 f"nbytes=")
            rec = self.directory.allocate_ssd(name, int(nbytes),
                                              self.ssd_dev.size,
                                              socket=socket or 0)
        else:
            rec = self.directory.require(name, KIND_SSD)
            if nbytes is not None and nbytes > rec.length:
                raise ValueError(f"SSD region {name!r} holds {rec.length} B, "
                                 f"wanted {nbytes}")
            if socket is not None and socket != rec.socket:
                raise ValueError(
                    f"SSD region {name!r} lives on socket {rec.socket}, "
                    f"caller asked for {socket} — home sockets are fixed "
                    f"at creation")
        return SSDRegionHandle(self, rec, self.ssd_dev)

    # --------------------------------------------------- typed consumers

    def kv(self, name: str, cfg=None):
        """Open-or-create a :class:`~repro.core.recovery.PersistentKV`
        whose root / page slots / WAL are directory regions ``<name>.root``
        / ``<name>.pages`` / ``<name>.wal``."""
        from repro.core.recovery import KVConfig, PersistentKV
        return PersistentKV(self, cfg or KVConfig(), name=name)

    def wal(self, name: str = "train_wal", *,
            capacity_steps: Optional[int] = None,
            technique: Optional[str] = None,
            lanes: int = 1, group_commit: int = 1,
            gen_sets: int = 1):
        """Open-or-create a training step WAL
        (:class:`~repro.persistence.wal.TrainWAL`) on this pool.
        ``technique`` defaults to "zero" when creating; on open the durable
        record decides (passing one verifies it). ``lanes > 1`` runs the
        WAL on a lane-striped group-commit :class:`~repro.io.MultiLog`;
        ``gen_sets >= 2`` additionally makes that MultiLog generational
        (a ring of lane sets that :meth:`TrainWAL.roll` seals, so the
        step WAL can be truncated at checkpoints instead of only at
        restart)."""
        from repro.persistence.wal import TrainWAL
        return TrainWAL.on_pool(self, name, capacity_steps=capacity_steps,
                                technique=technique, lanes=lanes,
                                group_commit=group_commit,
                                gen_sets=gen_sets)

    def multilog(self, name: str, capacity: Optional[int] = None, *,
                 lanes: Optional[int] = None,
                 technique: Optional[str] = None,
                 group_commit: int = 8,
                 cfg: Optional[LogConfig] = None,
                 gen_sets: int = 1,
                 lane_sockets: Optional[Sequence[int]] = None,
                 placer=None):
        """Open-or-create a lane-striped group-commit log
        (:class:`~repro.io.MultiLog`) over regions ``<name>.lane<i>``.
        Creating requires ``capacity`` (total, split over ``lanes``);
        opening discovers the lanes from the directory and runs merged
        recovery automatically. ``lane_sockets`` pins each lane region's
        NUMA home socket at creation (default: the placer spreads them);
        ``placer`` overrides the pool's default lane placer."""
        from repro.io.multilog import MultiLog
        return MultiLog(self, name, lanes=lanes, capacity=capacity,
                        technique=technique, group_commit=group_commit,
                        cfg=cfg, gen_sets=gen_sets,
                        lane_sockets=lane_sockets, placer=placer)
