"""Popcount checksum kernels: the Zero-log validity argument at page
scale (per-block bit counts; buffer checksum = sum + 1 so zero = never
written)."""

from repro.kernels.popcnt_checksum.ops import (  # noqa: F401
    popcount_blocks,
    popcount_checksum,
)
