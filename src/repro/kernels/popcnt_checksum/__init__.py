from repro.kernels.popcnt_checksum.ops import (  # noqa: F401
    popcount_blocks,
    popcount_checksum,
)
