"""Public op: popcount checksum of an arbitrary-dtype flat buffer.

Used by the persistence layer as the Zero-log validity word for checkpoint
manifests and WAL records computed on device (the host never has to stream
the data just to checksum it)."""

from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.blocks import TPU_TILE
from repro.kernels.common import TILE_BLOCKS, as_blocks, pad_blocks_to_tile
from repro.kernels.popcnt_checksum.kernel import popcnt_blocked
from repro.kernels.popcnt_checksum.ref import popcnt_blocked_ref

Impl = Literal["auto", "pallas", "ref"]


def _as_u32(x: jax.Array) -> jax.Array:
    """Bitcast any dtype to uint32 (pad to 4-byte multiple via uint8)."""
    if x.dtype == jnp.uint32:
        return x.reshape(-1)
    b = jax.lax.bitcast_convert_type(x.reshape(-1), jnp.uint8).reshape(-1)
    pad = (-b.shape[0]) % 4
    if pad:
        b = jnp.pad(b, (0, pad))
    return jax.lax.bitcast_convert_type(b.reshape(-1, 4), jnp.uint32).reshape(-1)


def popcount_blocks(x: jax.Array, *, block_bytes: int = TPU_TILE,
                    impl: Impl = "auto") -> jax.Array:
    """(nblocks,) uint32 per-block popcounts of a flat buffer."""
    u32 = _as_u32(x)
    xb, _ = as_blocks(u32, block_bytes)
    nblocks = xb.shape[0]
    if impl == "ref" or (impl == "auto" and jax.default_backend() != "tpu"):
        return popcnt_blocked_ref(xb)
    interpret = jax.default_backend() != "tpu"
    padded = pad_blocks_to_tile(nblocks, TILE_BLOCKS)
    if padded != nblocks:
        xb = jnp.pad(xb, ((0, padded - nblocks), (0, 0), (0, 0)))
    return popcnt_blocked(xb, interpret=interpret)[:nblocks]


def popcount_checksum(x: jax.Array, *, impl: Impl = "auto") -> jax.Array:
    """uint32 scalar: modular popcount checksum (Zero-log validity word).
    Returned value is popcount(x) + 1 (mod 2³²) so 0 always means
    "never written" — the paper's cnt==0 convention."""
    per_block = popcount_blocks(x, impl=impl)
    return (jnp.sum(per_block, dtype=jnp.uint32) + jnp.uint32(1))
