"""Pure-jnp oracle for the popcount-checksum kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def popcnt_blocked_ref(x: jax.Array) -> jax.Array:
    """(nblocks, rows, 128) uint32 → (nblocks,) uint32 per-block popcounts."""
    return jnp.sum(jax.lax.population_count(x), axis=(1, 2), dtype=jnp.uint32)
