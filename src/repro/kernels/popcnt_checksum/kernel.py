"""Pallas TPU kernel: popcount checksum (Zero-logging validity word, §3.3.1).

The paper validates a Zero-log entry by storing the entry's bit population
count next to it: a cache line (here: a 4 KiB TPU block) is either fully
durable or still all-zero, so a dropped block changes the popcount — unless
the block was all-zero, in which case the recovered bytes are identical
anyway. The same argument holds mod 2³²: dropping a block with popcount
0 < c < 2³² always changes the modular sum.

Grid: one program per TILE_BLOCKS blocks; each program popcounts a
(TILE_BLOCKS, rows, 128) uint32 tile on the VPU (``lax.population_count``)
and emits per-block partial sums; ops.py does the final modular reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import LANES, TILE_BLOCKS


def _popcnt_kernel(x_ref, out_ref):
    counts = jax.lax.population_count(x_ref[...])
    out_ref[...] = jnp.sum(counts, axis=(1, 2), dtype=jnp.uint32)[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def popcnt_blocked(x: jax.Array, *, interpret: bool = False) -> jax.Array:
    """(nblocks, rows, 128) uint32 → (nblocks,) uint32 per-block popcounts."""
    nblocks, rows, lanes = x.shape
    assert lanes == LANES and x.dtype == jnp.uint32
    assert nblocks % TILE_BLOCKS == 0
    grid = (nblocks // TILE_BLOCKS,)
    out = pl.pallas_call(
        _popcnt_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_BLOCKS, rows, LANES), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((TILE_BLOCKS, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, 1), jnp.uint32),
        interpret=interpret,
    )(x)
    return out[:, 0]
