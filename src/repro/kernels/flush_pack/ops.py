"""Public op: fused one-pass diff + pack + checksum of a flat buffer.

``flush_pack`` is the save path's single device pass: everything the
checkpoint epoch needs about a buffer — dirty flags, popcount checksums,
prefix-sum offsets, packed delta blocks, dirty block ids — from one read
of the live bytes. Replaces the staged flush_scan → host flatnonzero →
delta_pack chain.
"""

from __future__ import annotations

from typing import Literal, NamedTuple

import jax

from repro.core.blocks import TPU_TILE
from repro.kernels.common import as_blocks, blocked_for_tiles
from repro.kernels.flush_pack.kernel import flush_pack_blocked
from repro.kernels.flush_pack.ref import flush_pack_blocked_ref

Impl = Literal["auto", "pallas", "fused", "ref"]

#: the oracle is jitted so the off-TPU fallback is still ONE dispatch per
#: buffer (diff+popcount+compaction+pack fused by XLA) — the save path's
#: staged chain pays three dispatches and a host round-trip per buffer
_ref_jit = jax.jit(flush_pack_blocked_ref)


class FlushPack(NamedTuple):
    """Everything one fused device pass yields about a buffer.

    ``flags``: (nblocks,) int32 dirty bitmap vs the snapshot.
    ``counts``: (nblocks,) uint32 per-block popcounts of the live bytes.
    ``offsets``: (nblocks,) int32 exclusive prefix sum of ``flags`` —
    block b's slot in ``packed`` when dirty.
    ``packed``: (nblocks, rows, 128) live-dtype; the first ``total``
    blocks are the dirty blocks in ascending block order (tail zeroed).
    ``index``: (nblocks,) int32; first ``total`` entries are the dirty
    block ids (tail zeroed).
    ``total``: python int dirty-block count (the only host sync).
    """

    flags: jax.Array
    counts: jax.Array
    offsets: jax.Array
    packed: jax.Array
    index: jax.Array
    total: int


def flush_pack(cur: jax.Array, snap: jax.Array, *,
               block_bytes: int = TPU_TILE,
               impl: Impl = "auto") -> FlushPack:
    """Fused diff+pack+checksum of flat ``cur`` vs ``snap`` → FlushPack.

    ``impl="fused"`` is an alias for ``"pallas"`` (the fused kernel IS
    the pallas path); ``"auto"`` picks pallas on TPU and the jnp oracle
    elsewhere, like every other kernel in this package.
    """
    if cur.shape != snap.shape or cur.dtype != snap.dtype:
        raise ValueError("cur and snap must match in shape and dtype")
    if impl == "ref" or (impl == "auto" and jax.default_backend() != "tpu"):
        cur_b, _ = as_blocks(cur, block_bytes)
        snap_b, _ = as_blocks(snap, block_bytes)
        nblocks = cur_b.shape[0]
        flags, counts, off, packed, index = _ref_jit(cur_b, snap_b)
    else:
        interpret = jax.default_backend() != "tpu"
        cur_b, nblocks, _ = blocked_for_tiles(cur, block_bytes)
        snap_b, _, _ = blocked_for_tiles(snap, block_bytes)
        flags, counts, off, packed, index = flush_pack_blocked(
            cur_b, snap_b, interpret=interpret)
        flags = flags[:nblocks]
        counts = counts[:nblocks]
        off = off[:nblocks]
        packed = packed[:nblocks]
        index = index[:nblocks]
    total = int(off[-1] + flags[-1]) if nblocks else 0
    return FlushPack(flags, counts, off, packed, index, total)
