"""Pallas TPU kernel: fused one-pass flush pipeline (diff+pack+checksum).

The staged save path reads the live parameter buffer from HBM up to three
times — flush_scan (dirty flags + popcounts), delta_pack (gather of dirty
blocks), plus a host round-trip to turn flags into a gather index. This
kernel does all of it in ONE sequential pass: each grid step diffs a tile
of blocks against the snapshot, popcounts the live bytes, extends a
running exclusive prefix sum of dirty flags carried in SMEM, and copies
each dirty block straight to its prefix-sum slot of the packed output
while the bytes are still in VMEM. The live buffer is read from HBM
exactly once per save (Wu arXiv:2005.07658: redundant flush passes
dominate PMem cost; Izraelevitz arXiv:1903.05714: PMem read bandwidth is
the scarce resource).

Grid: sequential, one program per TILE_BLOCKS blocks. Tiled outputs
(flags / popcounts / offsets) stream per step; the packed-delta and
block-index outputs are whole-array residents scattered into with
``pl.ds`` dynamic stores at prefix-sum offsets.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import LANES, TILE_BLOCKS

_UINT_FOR = {4: jnp.uint32, 2: jnp.uint16, 1: jnp.uint8}


def _flush_pack_kernel(cur_ref, snap_ref, dirty_ref, cnt_ref, off_ref,
                       packed_ref, idx_ref, carry_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        # Zero the resident scatter targets so the tail past the dirty
        # count is deterministic (the ref oracle zero-fills too).
        carry_ref[0] = 0
        packed_ref[...] = jnp.zeros(packed_ref.shape, packed_ref.dtype)
        idx_ref[...] = jnp.zeros(idx_ref.shape, idx_ref.dtype)

    cur = cur_ref[...]
    snap = snap_ref[...]
    dirty = jnp.any(cur != snap, axis=(1, 2)).astype(jnp.int32)
    dirty_ref[...] = dirty[:, None]
    udt = _UINT_FOR[cur.dtype.itemsize]
    bits = jax.lax.population_count(jax.lax.bitcast_convert_type(cur, udt))
    cnt_ref[...] = jnp.sum(bits.astype(jnp.uint32), axis=(1, 2),
                           dtype=jnp.uint32)[:, None]

    base = carry_ref[0]
    within = jnp.cumsum(dirty) - dirty        # exclusive, within this tile
    off_ref[...] = (base + within)[:, None]

    for b in range(TILE_BLOCKS):

        @pl.when(dirty[b] != 0)
        def _copy(b=b):
            o = base + within[b]
            packed_ref[pl.ds(o, 1)] = cur[b][None]
            idx_ref[pl.ds(o, 1)] = jnp.full(
                (1, 1), i * TILE_BLOCKS + b, jnp.int32)

    carry_ref[0] = base + jnp.sum(dirty)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flush_pack_blocked(cur: jax.Array, snap: jax.Array, *,
                       interpret: bool = False):
    """(nblocks, rows, 128) ×2 → (flags, counts, offsets, packed, index).

    One device pass; see the module docstring. ``nblocks`` must be a
    multiple of TILE_BLOCKS (pad with ``pad_blocks_to_tile`` first —
    zero-padded tails are never dirty, so padding only appends clean
    blocks).
    """
    nblocks, rows, lanes = cur.shape
    assert lanes == LANES and cur.shape == snap.shape
    assert nblocks % TILE_BLOCKS == 0
    assert cur.dtype.itemsize in _UINT_FOR
    grid = (nblocks // TILE_BLOCKS,)
    spec = pl.BlockSpec((TILE_BLOCKS, rows, LANES), lambda i: (i, 0, 0))
    col_spec = pl.BlockSpec((TILE_BLOCKS, 1), lambda i: (i, 0))
    # packed/index stay resident across the whole sequential grid (their
    # index_map is constant) so dynamic stores can cross tile boundaries.
    packed_spec = pl.BlockSpec((nblocks, rows, LANES), lambda i: (0, 0, 0))
    idx_spec = pl.BlockSpec((nblocks, 1), lambda i: (0, 0))
    flags, cnt, off, packed, idx = pl.pallas_call(
        _flush_pack_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[col_spec, col_spec, col_spec, packed_spec, idx_spec],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, 1), jnp.int32),
            jax.ShapeDtypeStruct((nblocks, 1), jnp.uint32),
            jax.ShapeDtypeStruct((nblocks, 1), jnp.int32),
            jax.ShapeDtypeStruct((nblocks, rows, LANES), cur.dtype),
            jax.ShapeDtypeStruct((nblocks, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(cur, snap)
    return flags[:, 0], cnt[:, 0], off[:, 0], packed, idx[:, 0]
