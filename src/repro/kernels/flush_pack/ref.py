"""Pure-jnp oracle for the fused flush pipeline.

The compaction story is a single exclusive prefix sum over the dirty
flags: dirty block *b* lands at packed position ``prefix[b]``. The ref
oracle realizes it as an index scatter (``.at[dst].set``, clean blocks
routed to a discard row) followed by a masked gather — bit-identical to
the Pallas kernel's sequential prefix-sum writes, and reused by
``delta_pack.pack_dirty`` so the staged fallback shares one compaction
implementation (no host-side ``np.flatnonzero`` anywhere on the save
path).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

_UINT_FOR = {4: jnp.uint32, 2: jnp.uint16, 1: jnp.uint8}


def exclusive_prefix_sum(flags: jax.Array) -> jax.Array:
    """(nblocks,) int dirty flags → (nblocks,) int32 exclusive prefix sum
    (the packed-delta offset of each dirty block)."""
    f = flags.astype(jnp.int32)
    return jnp.cumsum(f) - f


def compact_index(flags: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """On-device prefix-sum compaction of a dirty bitmap.

    Returns ``(index, total)``: ``index`` is (nblocks,) int32 whose first
    ``total`` entries are the dirty block ids in ascending order (the
    rest are don't-care zeros), ``total`` is the scalar dirty count.
    Equivalent to ``np.flatnonzero`` but computed on device — only the
    scalar ``total`` ever needs a host sync.
    """
    n = flags.shape[0]
    off = exclusive_prefix_sum(flags)
    dst = jnp.where(flags > 0, off, n)        # clean blocks → discard row
    index = jnp.zeros((n + 1,), jnp.int32).at[dst].set(
        jnp.arange(n, dtype=jnp.int32))[:n]
    return index, jnp.sum(flags.astype(jnp.int32))


def flush_pack_blocked_ref(cur: jax.Array, snap: jax.Array):
    """(nblocks, rows, 128) ×2 → (flags, counts, offsets, packed, index).

    One logical pass: ``flags`` (int32 dirty bitmap), ``counts`` (uint32
    per-block popcounts of ``cur``), ``offsets`` (exclusive prefix sum of
    ``flags``), ``packed`` (same shape as ``cur``; the first
    ``sum(flags)`` blocks are the dirty blocks in ascending block order),
    ``index`` (int32; first ``sum(flags)`` entries are the dirty block
    ids). Entries of ``packed``/``index`` beyond the dirty count are
    zero-filled don't-cares.

    Only the small int32 ``index`` is built by scatter; ``packed`` is a
    gather through it plus a live mask — one read of ``cur``, one write
    of the output, no full-size scatter (the scatter variant copies its
    zero operand before updating, a third pass over the data).
    """
    nblocks = cur.shape[0]
    flags = jnp.any(cur != snap, axis=(1, 2)).astype(jnp.int32)
    udt = _UINT_FOR[cur.dtype.itemsize]
    bits = jax.lax.population_count(jax.lax.bitcast_convert_type(cur, udt))
    counts = jnp.sum(bits.astype(jnp.uint32), axis=(1, 2), dtype=jnp.uint32)
    offsets = exclusive_prefix_sum(flags)
    dst = jnp.where(flags > 0, offsets, nblocks)
    index = jnp.zeros((nblocks + 1,), jnp.int32).at[dst].set(
        jnp.arange(nblocks, dtype=jnp.int32))[:nblocks]
    total = offsets[-1] + flags[-1]
    live = jnp.arange(nblocks, dtype=jnp.int32) < total
    packed = jnp.where(live[:, None, None], jnp.take(cur, index, axis=0),
                       jnp.zeros((), cur.dtype))
    return flags, counts, offsets, packed, index
