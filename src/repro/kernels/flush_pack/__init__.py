"""Fused flush pipeline: one-pass diff + pack + checksum (µLog in one read).

``flush_pack`` is the checkpoint hot path's single device pass: it reads a
parameter buffer's current bytes from HBM exactly once (and the snapshot
once) and emits everything the save epoch needs — dirty flags, per-block
popcount checksums, an exclusive prefix sum of the dirty counts, the dirty
block ids, and the packed delta blocks already compacted at their
prefix-sum offsets. It replaces the staged dirty_diff → delta_pack →
popcnt_checksum chain (three reads of the live buffer) and the host-side
``np.flatnonzero`` compaction.
"""

from repro.kernels.flush_pack.ops import FlushPack, flush_pack  # noqa: F401
from repro.kernels.flush_pack.ref import (  # noqa: F401
    compact_index,
    exclusive_prefix_sum,
)
