"""Pure-jnp oracle for the fused restore pipeline.

The restore story is one scatter: packed block *i* lands at base block
``idx[i]``, and its popcount is compared against the checksum the
manifest recorded at save time. The oracle realizes it as
``base.at[idx].set(packed)`` plus a vectorized popcount — bit-identical
to the Pallas kernel's per-step aliased scatter (blocks outside ``idx``
keep the base bytes in both), and shared by the staged fallback so
staged and fused restores agree bit-for-bit on the assembled image.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_UINT_FOR = {4: jnp.uint32, 2: jnp.uint16, 1: jnp.uint8}


def block_popcounts(packed: jax.Array) -> jax.Array:
    """(k, rows, 128) → (k,) uint32 per-block popcounts."""
    udt = _UINT_FOR[packed.dtype.itemsize]
    bits = jax.lax.population_count(
        jax.lax.bitcast_convert_type(packed, udt))
    return jnp.sum(bits.astype(jnp.uint32), axis=(1, 2), dtype=jnp.uint32)


def apply_unpack_blocked_ref(base: jax.Array, packed: jax.Array,
                             idx: jax.Array, expected: jax.Array):
    """(nblocks, rows, 128) base + (k, rows, 128) packed → (out, ok, counts).

    One logical pass: ``out`` is ``base`` with ``out[idx[i]] =
    packed[i]`` (``idx`` duplicate-free), ``ok[i]`` is 1 iff packed
    block i's popcount equals ``expected[i]``, ``counts`` are the actual
    popcounts. Verification is *reported*, not enforced — the caller
    discards the image when any verdict fails, exactly like the staged
    restore rejects a manifest entry on its first bad page.
    """
    counts = block_popcounts(packed)
    ok = (counts == expected.astype(jnp.uint32)).astype(jnp.int32)
    out = base.at[idx.astype(jnp.int32)].set(packed)
    return out, ok, counts
