"""Fused restore pipeline: one-pass verify + scatter + apply (flush_pack⁻¹).

``apply_unpack`` is the restore hot path's single device pass: it reads a
run of packed page/delta blocks from HBM exactly once and, in that one
pass, popcount-verifies each block against the checksum the manifest
recorded at save time AND scatters it to its destination block of the
base image. It replaces the staged popcount-verify → copy chain (two
reads of the restored bytes), making the restore direction symmetric
with ``flush_pack``'s save direction.
"""

from repro.kernels.apply_unpack.ops import ApplyUnpack, apply_unpack  # noqa: F401
from repro.kernels.apply_unpack.ref import block_popcounts  # noqa: F401
