"""Public op: fused one-pass verify + scatter + apply of packed blocks.

``apply_unpack`` is the restore path's single device pass and the exact
inverse of ``flush_pack``: given a flat base image, a flat run of packed
blocks, their destination block ids and the popcount checksums the
manifest recorded at save time, it verifies every block AND applies it
onto the base in one read of the packed bytes. Replaces the staged
popcount-verify → copy chain (two reads of the restored image).
"""

from __future__ import annotations

from typing import Literal, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.blocks import TPU_TILE
from repro.kernels.common import LANES, as_blocks, from_blocks
from repro.kernels.apply_unpack.kernel import apply_unpack_blocked
from repro.kernels.apply_unpack.ref import apply_unpack_blocked_ref

Impl = Literal["auto", "pallas", "fused", "ref"]

#: the oracle is jitted so the off-TPU fallback is still ONE dispatch per
#: buffer (popcount+scatter fused by XLA) — the staged restore chain pays
#: a verify dispatch plus a copy pass per buffer
_ref_jit = jax.jit(apply_unpack_blocked_ref)


class ApplyUnpack(NamedTuple):
    """Everything one fused restore pass yields about a buffer.

    ``out``: flat array, same shape/dtype as ``base``, with packed block
    i applied at block ``index[i]`` (all other blocks keep base bytes).
    ``ok``: (k,) int32; 1 iff packed block i's popcount matched
    ``expected[i]`` — the caller discards ``out`` if any verdict fails.
    ``counts``: (k,) uint32 actual popcounts of the packed blocks.
    ``nbad``: python int count of failed verdicts (the only host sync).
    """

    out: jax.Array
    ok: jax.Array
    counts: jax.Array
    nbad: int


def apply_unpack(base: jax.Array, packed: jax.Array, index, expected, *,
                 block_bytes: int = TPU_TILE,
                 impl: Impl = "auto") -> ApplyUnpack:
    """Fused verify+scatter of flat ``packed`` onto flat ``base``.

    ``packed`` holds k consecutive blocks (``k * block_bytes`` bytes);
    ``index`` (k,) names each block's destination block of ``base``
    (duplicate-free); ``expected`` (k,) uint32 holds the popcounts to
    verify against. ``impl="fused"`` is an alias for ``"pallas"`` (the
    fused kernel IS the pallas path); ``"auto"`` picks pallas on TPU and
    the jnp oracle elsewhere, like every other kernel in this package.
    """
    if packed.dtype != base.dtype:
        raise ValueError("base and packed must share a dtype")
    elems = block_bytes // base.dtype.itemsize
    if packed.size % elems:
        raise ValueError(
            f"packed ({packed.size} elems) is not whole {block_bytes}-byte "
            f"blocks")
    k = packed.size // elems
    if k == 0:
        return ApplyUnpack(base, jnp.zeros((0,), jnp.int32),
                           jnp.zeros((0,), jnp.uint32), 0)
    rows = elems // LANES
    packed_b = jnp.asarray(packed).reshape(k, rows, LANES)
    idx = jnp.asarray(index, dtype=jnp.int32)
    exp = jnp.asarray(expected, dtype=jnp.uint32)
    base_b, orig_len = as_blocks(base, block_bytes)
    if impl == "ref" or (impl == "auto" and jax.default_backend() != "tpu"):
        out_b, ok, counts = _ref_jit(base_b, packed_b, idx, exp)
    else:
        interpret = jax.default_backend() != "tpu"
        out_b, ok, counts = apply_unpack_blocked(
            base_b, packed_b, idx, exp, interpret=interpret)
    out = from_blocks(out_b, orig_len).reshape(base.shape)
    nbad = int(k - jnp.sum(ok))
    return ApplyUnpack(out, ok, counts, nbad)
