"""Pallas TPU kernel: fused one-pass restore pipeline (verify+scatter+apply).

The staged restore path reads the packed page images up to twice — one
popcount pass to verify each block against its manifest checksum, then a
second pass that copies the verified bytes onto the base image. This
kernel is the inverse of ``flush_pack``: each grid step popcounts ONE
packed block while its bytes are in VMEM and, in the same step, scatters
it to its destination block of the base image — the packed bytes cross
HBM exactly once per restore (Wu arXiv:2005.07658: restart time is
dominated by read-side scan traffic; Izraelevitz arXiv:1903.05714: PMem
read bandwidth is the scarce, thread-scalable resource).

Grid: one program per packed block, destination driven by a
scalar-prefetched index vector (the canonical Pallas TPU scatter, same
shape as ``delta_pack``'s apply kernel). The base image is aliased into
the output, so unreferenced blocks are never copied; the per-block
popcounts and checksum verdicts stream out as small column outputs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import LANES

_UINT_FOR = {4: jnp.uint32, 2: jnp.uint16, 1: jnp.uint8}


def _apply_unpack_kernel(idx_ref, upd_ref, exp_ref, base_ref,
                         out_ref, ok_ref, cnt_ref):
    # base_ref is aliased into out_ref and never read: the kernel's only
    # job at this grid step is to land the packed block and its verdict.
    upd = upd_ref[...]
    udt = _UINT_FOR[upd.dtype.itemsize]
    bits = jax.lax.population_count(jax.lax.bitcast_convert_type(upd, udt))
    cnt = jnp.sum(bits.astype(jnp.uint32), dtype=jnp.uint32)
    cnt_ref[...] = cnt.reshape(1, 1)
    ok_ref[...] = (cnt == exp_ref[0, 0]).astype(jnp.int32).reshape(1, 1)
    out_ref[...] = upd


@functools.partial(jax.jit, static_argnames=("interpret",))
def apply_unpack_blocked(base: jax.Array, packed: jax.Array,
                         idx: jax.Array, expected: jax.Array, *,
                         interpret: bool = False):
    """(nblocks, rows, 128) base + (k, rows, 128) packed → (out, ok, counts).

    ``out`` is ``base`` with ``out[idx[i]] = packed[i]`` (in-place via
    aliasing — blocks outside ``idx`` never move); ``ok[i]`` is 1 iff
    block i's popcount equals ``expected[i]``; ``counts[i]`` is the
    actual popcount. ``idx`` must not contain duplicates (each
    destination block written once).
    """
    nblocks, rows, lanes = base.shape
    k = packed.shape[0]
    assert lanes == LANES and packed.shape[1:] == (rows, lanes)
    assert packed.dtype == base.dtype and base.dtype.itemsize in _UINT_FOR
    assert idx.shape == (k,) and expected.shape == (k,)
    blk = pl.BlockSpec((1, rows, LANES), lambda i, idx: (i, 0, 0))
    col = pl.BlockSpec((1, 1), lambda i, idx: (i, 0))
    dst = pl.BlockSpec((1, rows, LANES), lambda i, idx: (idx[i], 0, 0))
    out, ok, cnt = pl.pallas_call(
        _apply_unpack_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(k,),
            in_specs=[blk, col, dst],
            out_specs=[dst, col, col],
        ),
        out_shape=[
            jax.ShapeDtypeStruct(base.shape, base.dtype),
            jax.ShapeDtypeStruct((k, 1), jnp.int32),
            jax.ShapeDtypeStruct((k, 1), jnp.uint32),
        ],
        input_output_aliases={3: 0},  # base (after the scalar operand) → out
        interpret=interpret,
    )(idx.astype(jnp.int32), packed,
      expected.astype(jnp.uint32).reshape(k, 1), base)
    return out, ok[:, 0], cnt[:, 0]
