"""Pure-jnp oracles for delta pack/apply."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def delta_pack_blocked_ref(src: jax.Array, idx: jax.Array) -> jax.Array:
    """Gather the blocks named by ``idx`` into a dense (k, rows, 128) delta."""
    return jnp.take(src, idx, axis=0)


def delta_apply_blocked_ref(base: jax.Array, upd: jax.Array, idx: jax.Array) -> jax.Array:
    """Scatter delta blocks ``upd`` onto ``base`` at block ids ``idx``."""
    return base.at[idx].set(upd)
