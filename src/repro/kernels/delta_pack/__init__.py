from repro.kernels.delta_pack.ops import apply_delta, pack_delta  # noqa: F401
