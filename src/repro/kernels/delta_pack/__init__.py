"""Delta pack/apply kernels: gather dirty blocks into a dense delta and
scatter a delta back onto a base buffer (the µLog replay primitive)."""

from repro.kernels.delta_pack.ops import (  # noqa: F401
    apply_delta,
    pack_delta,
    pack_dirty,
)
