"""Public ops: pack dirty blocks to a compact delta / apply a delta.

The flusher decides CoW-vs-µLog per page on the host (HybridPolicy), after
which the dirty-block index vector is host-known; these ops therefore take a
concrete index array. Index vectors are bucketed to power-of-two lengths by
the persistence layer to bound the number of compiled shapes.
"""

from __future__ import annotations

from typing import Literal, Tuple

import jax
import jax.numpy as jnp

from repro.core.blocks import TPU_TILE
from repro.kernels.common import as_blocks, from_blocks
from repro.kernels.delta_pack.kernel import delta_apply_blocked, delta_pack_blocked
from repro.kernels.delta_pack.ref import (
    delta_apply_blocked_ref,
    delta_pack_blocked_ref,
)

Impl = Literal["auto", "pallas", "ref"]


def _use_ref(impl: Impl) -> bool:
    return impl == "ref" or (impl == "auto" and jax.default_backend() != "tpu")


def pack_delta(
    buf: jax.Array,
    idx: jax.Array,
    *,
    block_bytes: int = TPU_TILE,
    impl: Impl = "auto",
) -> jax.Array:
    """Gather blocks ``idx`` of a flat buffer → (k, rows, 128) compact delta."""
    blocked, _ = as_blocks(buf, block_bytes)
    if _use_ref(impl):
        return delta_pack_blocked_ref(blocked, idx)
    return delta_pack_blocked(blocked, idx, interpret=jax.default_backend() != "tpu")


def apply_delta(
    buf: jax.Array,
    delta: jax.Array,
    idx: jax.Array,
    *,
    block_bytes: int = TPU_TILE,
    impl: Impl = "auto",
) -> jax.Array:
    """Scatter a packed delta back into a flat buffer; returns the new buffer
    (same shape/dtype as ``buf``)."""
    blocked, n = as_blocks(buf, block_bytes)
    if _use_ref(impl):
        out = delta_apply_blocked_ref(blocked, delta, idx)
    else:
        out = delta_apply_blocked(blocked, delta, idx,
                                  interpret=jax.default_backend() != "tpu")
    return from_blocks(out, n).reshape(buf.shape)
