"""Public ops: pack dirty blocks to a compact delta / apply a delta.

The flusher decides CoW-vs-µLog per page on the host (HybridPolicy), after
which the dirty-block index vector is host-known; these ops therefore take a
concrete index array. Index vectors are bucketed to power-of-two lengths by
the persistence layer to bound the number of compiled shapes.
"""

from __future__ import annotations

from typing import Literal, Tuple

import jax
import jax.numpy as jnp

from repro.core.blocks import TPU_TILE
from repro.kernels.common import as_blocks, from_blocks
from repro.kernels.delta_pack.kernel import delta_apply_blocked, delta_pack_blocked
from repro.kernels.delta_pack.ref import (
    delta_apply_blocked_ref,
    delta_pack_blocked_ref,
)

Impl = Literal["auto", "pallas", "ref"]


def _use_ref(impl: Impl) -> bool:
    return impl == "ref" or (impl == "auto" and jax.default_backend() != "tpu")


def pack_delta(
    buf: jax.Array,
    idx: jax.Array,
    *,
    block_bytes: int = TPU_TILE,
    impl: Impl = "auto",
) -> jax.Array:
    """Gather blocks ``idx`` of a flat buffer → (k, rows, 128) compact delta."""
    blocked, _ = as_blocks(buf, block_bytes)
    if _use_ref(impl):
        return delta_pack_blocked_ref(blocked, idx)
    return delta_pack_blocked(blocked, idx, interpret=jax.default_backend() != "tpu")


def pack_dirty(
    buf: jax.Array,
    flags: jax.Array,
    *,
    block_bytes: int = TPU_TILE,
    impl: Impl = "auto",
) -> Tuple[jax.Array, jax.Array, int]:
    """Pack the dirty blocks of a flat buffer given its dirty bitmap.

    The index build is the shared on-device prefix-sum compaction from
    ``flush_pack`` (no host ``np.flatnonzero``): only the scalar dirty
    count crosses to the host, to size the gather. Returns
    ``(delta (k, rows, 128), idx (k,) int32, k)`` — the same compaction
    story the fused kernel uses, so staged and fused paths agree
    bit-for-bit on packing order (ascending block id).
    """
    from repro.kernels.flush_pack.ref import compact_index

    index, total = compact_index(flags)
    k = int(total)
    idx = index[:k]
    return pack_delta(buf, idx, block_bytes=block_bytes, impl=impl), idx, k


def apply_delta(
    buf: jax.Array,
    delta: jax.Array,
    idx: jax.Array,
    *,
    block_bytes: int = TPU_TILE,
    impl: Impl = "auto",
) -> jax.Array:
    """Scatter a packed delta back into a flat buffer; returns the new buffer
    (same shape/dtype as ``buf``)."""
    blocked, n = as_blocks(buf, block_bytes)
    if _use_ref(impl):
        out = delta_apply_blocked_ref(blocked, delta, idx)
    else:
        out = delta_apply_blocked(blocked, delta, idx,
                                  interpret=jax.default_backend() != "tpu")
    return from_blocks(out, n).reshape(buf.shape)
