"""Pallas TPU kernels: pack/apply dirty blocks (the µLog delta path, §3.2.2).

``delta_pack`` gathers the dirty 4 KiB blocks of a parameter buffer into a
compact staging buffer — only this compacted delta crosses the HBM→host
link during a delta checkpoint. ``delta_apply`` is the recovery inverse:
scatter packed blocks back into a base buffer (µLog replay on restore).

Both use a scalar-prefetched index vector to drive the BlockSpec index_map
— the canonical Pallas TPU gather/scatter: the index arrives in SMEM before
the grid runs, so each grid step's DMA source/destination block is known in
time to pipeline HBM↔VMEM copies. The kernel body is a pure VMEM copy; the
interesting work IS the data movement, which is exactly the paper's point
(page flushing is bandwidth-critical, not compute-critical).

``delta_apply`` aliases the base buffer into the output (in-place scatter,
no second copy of a multi-GiB parameter buffer).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import LANES


def _copy_kernel(idx_ref, src_ref, out_ref):
    out_ref[...] = src_ref[...]


def _apply_kernel(idx_ref, upd_ref, base_ref, out_ref):
    out_ref[...] = upd_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def delta_pack_blocked(src: jax.Array, idx: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Gather: out[i] = src[idx[i]].  src (nblocks, rows, 128), idx (k,)."""
    nblocks, rows, lanes = src.shape
    assert lanes == LANES
    k = idx.shape[0]
    out = pl.pallas_call(
        _copy_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(k,),
            in_specs=[pl.BlockSpec((1, rows, LANES), lambda i, idx: (idx[i], 0, 0))],
            out_specs=pl.BlockSpec((1, rows, LANES), lambda i, idx: (i, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((k, rows, LANES), src.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), src)
    return out


@functools.partial(jax.jit, static_argnames=("interpret",))
def delta_apply_blocked(
    base: jax.Array, upd: jax.Array, idx: jax.Array, *, interpret: bool = False
) -> jax.Array:
    """Scatter: out = base with out[idx[i]] = upd[i]. In-place via aliasing.

    ``idx`` must not contain duplicates (each block written once)."""
    nblocks, rows, lanes = base.shape
    k = upd.shape[0]
    assert lanes == LANES and upd.shape[1:] == (rows, lanes)
    out = pl.pallas_call(
        _apply_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(k,),
            in_specs=[
                pl.BlockSpec((1, rows, LANES), lambda i, idx: (i, 0, 0)),
                pl.BlockSpec((1, rows, LANES), lambda i, idx: (idx[i], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, rows, LANES), lambda i, idx: (idx[i], 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct(base.shape, base.dtype),
        input_output_aliases={2: 0},  # base (after the scalar operand) → out
        interpret=interpret,
    )(idx.astype(jnp.int32), upd, base)
    return out
