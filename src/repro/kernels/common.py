"""Shared geometry for the persistence kernels.

The paper's guideline G1 ("optimize for PMem blocks, not cache lines")
becomes, on TPU: track checkpoint-delta dirtiness at the granularity of a
device-native tile. One float32 (8, 128) VREG tile = 4096 bytes = the
``TPU_TILE`` block. All kernels view a flat parameter buffer as
``(nblocks, rows, 128)`` where ``rows × 128 × itemsize = block_bytes``,
so every block is a whole number of hardware tiles and the MXU/VPU lane
dimension stays 128-aligned.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocks import TPU_TILE

LANES = 128

#: blocks per kernel tile along the block axis (VMEM working set:
#: 8 blocks × 4 KiB = 32 KiB per operand — comfortably inside the
#: ~16 MiB VMEM even with double buffering and 3 operands).
TILE_BLOCKS = 8


def block_rows(dtype, block_bytes: int = TPU_TILE) -> int:
    """Rows of 128 lanes per block for ``dtype``."""
    itemsize = jnp.dtype(dtype).itemsize
    if block_bytes % (LANES * itemsize) != 0:
        raise ValueError(f"block_bytes={block_bytes} not a multiple of "
                         f"{LANES}*{itemsize} for dtype {dtype}")
    return block_bytes // (LANES * itemsize)


def as_blocks(flat: jax.Array, block_bytes: int = TPU_TILE) -> Tuple[jax.Array, int]:
    """Reshape a flat buffer to (nblocks, rows, 128), zero-padding the tail.

    Returns (blocked, original_length). Zero padding is semantically safe
    for every kernel here: padded regions are identical in cur/snap (never
    dirty) and contribute 0 to popcounts.
    """
    flat = flat.reshape(-1)
    rows = block_rows(flat.dtype, block_bytes)
    elems = rows * LANES
    n = flat.shape[0]
    nblocks = -(-n // elems) if n else 1
    padded = nblocks * elems
    if padded != n:
        flat = jnp.pad(flat, (0, padded - n))
    return flat.reshape(nblocks, rows, LANES), n


def from_blocks(blocked: jax.Array, orig_len: int) -> jax.Array:
    """Inverse of :func:`as_blocks`: flatten and drop the zero padding."""
    return blocked.reshape(-1)[:orig_len]


def pad_blocks_to_tile(nblocks: int, tile: int = TILE_BLOCKS) -> int:
    """Round a block count up to the kernel grid's tile multiple."""
    return -(-nblocks // tile) * tile


def blocked_for_tiles(flat: jax.Array, block_bytes: int = TPU_TILE,
                      tile: int = TILE_BLOCKS) -> Tuple[jax.Array, int, int]:
    """``as_blocks`` plus tile-multiple padding along the block axis.

    Returns ``(blocked, nblocks, orig_len)`` where ``blocked`` has a
    first dimension padded up to a multiple of ``tile`` (extra blocks are
    zero, hence clean) and ``nblocks`` is the count BEFORE tile padding —
    slice kernel outputs back to ``[:nblocks]``.
    """
    blocked, orig_len = as_blocks(flat, block_bytes)
    nblocks = blocked.shape[0]
    padded = pad_blocks_to_tile(nblocks, tile)
    if padded != nblocks:
        blocked = jnp.pad(blocked, ((0, padded - nblocks), (0, 0), (0, 0)))
    return blocked, nblocks, orig_len
