"""Pallas TPU kernel: fused checkpoint flush scan (beyond-paper).

The checkpoint save path needs TWO facts per 4 KiB block of live
parameters: is it dirty vs the snapshot (µLog dirty set), and its popcount
(Zero-log page checksums). Both are O(1) flops/byte, i.e. HBM-bandwidth
bound — running them as separate kernels reads the parameter buffer twice.
This kernel computes both in ONE pass (the snapshot is read once too), so
the device-side cost of a delta-checkpoint scan drops from 3 buffer-reads
to 2 — a 1.5× cut of the dominant term of the save path (EXPERIMENTS.md
§Perf, persistence numbers).

Grid: one program per TILE_BLOCKS blocks; outputs per-block
(dirty int32, popcount uint32) vectors.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import LANES, TILE_BLOCKS

_UINT_FOR = {4: jnp.uint32, 2: jnp.uint16, 1: jnp.uint8}


def _flush_scan_kernel(cur_ref, snap_ref, dirty_ref, cnt_ref):
    cur = cur_ref[...]
    snap = snap_ref[...]
    dirty_ref[...] = jnp.any(cur != snap, axis=(1, 2)).astype(jnp.int32)[:, None]
    udt = _UINT_FOR[cur.dtype.itemsize]
    bits = jax.lax.population_count(jax.lax.bitcast_convert_type(cur, udt))
    cnt_ref[...] = jnp.sum(bits.astype(jnp.uint32), axis=(1, 2),
                           dtype=jnp.uint32)[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def flush_scan_blocked(cur: jax.Array, snap: jax.Array, *,
                       interpret: bool = False):
    """(nblocks, rows, 128) ×2 → ((nblocks,) int32 dirty, (nblocks,) uint32
    popcounts), one pass."""
    nblocks, rows, lanes = cur.shape
    assert lanes == LANES and cur.shape == snap.shape
    assert nblocks % TILE_BLOCKS == 0
    assert cur.dtype.itemsize in _UINT_FOR
    grid = (nblocks // TILE_BLOCKS,)
    spec = pl.BlockSpec((TILE_BLOCKS, rows, LANES), lambda i: (i, 0, 0))
    out_spec = pl.BlockSpec((TILE_BLOCKS, 1), lambda i: (i, 0))
    dirty, cnt = pl.pallas_call(
        _flush_scan_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks, 1), jnp.int32),
            jax.ShapeDtypeStruct((nblocks, 1), jnp.uint32),
        ],
        interpret=interpret,
    )(cur, snap)
    return dirty[:, 0], cnt[:, 0]
