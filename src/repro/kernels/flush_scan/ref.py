"""Pure-jnp oracle for the fused flush scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp

_UINT_FOR = {4: jnp.uint32, 2: jnp.uint16, 1: jnp.uint8}


def flush_scan_blocked_ref(cur: jax.Array, snap: jax.Array):
    """(nblocks, rows, 128) ×2 → per-block (dirty flags, popcounts)."""
    dirty = jnp.any(cur != snap, axis=(1, 2)).astype(jnp.int32)
    udt = _UINT_FOR[cur.dtype.itemsize]
    bits = jax.lax.population_count(jax.lax.bitcast_convert_type(cur, udt))
    cnt = jnp.sum(bits.astype(jnp.uint32), axis=(1, 2), dtype=jnp.uint32)
    return dirty, cnt
