"""Public op: one-pass (dirty bitmap, per-block popcount) of a flat buffer.

Used by CheckpointManager.save: replaces the separate dirty_diff pass and
the host-side per-page popcount with a single device scan.
"""

from __future__ import annotations

from typing import Literal, Tuple

import jax
import jax.numpy as jnp

from repro.core.blocks import TPU_TILE
from repro.kernels.common import TILE_BLOCKS, as_blocks, pad_blocks_to_tile
from repro.kernels.flush_scan.kernel import flush_scan_blocked
from repro.kernels.flush_scan.ref import flush_scan_blocked_ref

Impl = Literal["auto", "pallas", "ref"]


def flush_scan(cur: jax.Array, snap: jax.Array, *,
               block_bytes: int = TPU_TILE,
               impl: Impl = "auto") -> Tuple[jax.Array, jax.Array]:
    """((nblocks,) int32 dirty flags, (nblocks,) uint32 popcounts)."""
    if cur.shape != snap.shape or cur.dtype != snap.dtype:
        raise ValueError("cur and snap must match in shape and dtype")
    cur_b, _ = as_blocks(cur, block_bytes)
    snap_b, _ = as_blocks(snap, block_bytes)
    nblocks = cur_b.shape[0]
    if impl == "ref" or (impl == "auto" and jax.default_backend() != "tpu"):
        return flush_scan_blocked_ref(cur_b, snap_b)
    interpret = jax.default_backend() != "tpu"
    padded = pad_blocks_to_tile(nblocks, TILE_BLOCKS)
    if padded != nblocks:
        pad = ((0, padded - nblocks), (0, 0), (0, 0))
        cur_b = jnp.pad(cur_b, pad)
        snap_b = jnp.pad(snap_b, pad)
    dirty, cnt = flush_scan_blocked(cur_b, snap_b, interpret=interpret)
    return dirty[:nblocks], cnt[:nblocks]
