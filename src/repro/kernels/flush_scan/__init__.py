from repro.kernels.flush_scan.ops import flush_scan  # noqa: F401
