"""Fused flush scan: dirty flags + popcount checksums in one pass
(subsumed by :mod:`repro.kernels.flush_pack` on the save path; kept as
the two-output primitive and for A/B comparison)."""

from repro.kernels.flush_scan.ops import flush_scan  # noqa: F401
