from repro.kernels.dirty_diff.ops import dirty_blocks  # noqa: F401
