"""Dirty-diff kernel: per-block changed/clean flags of a live buffer vs
its last-flushed snapshot."""

from repro.kernels.dirty_diff.ops import dirty_blocks  # noqa: F401
