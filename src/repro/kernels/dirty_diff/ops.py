"""Public op: dirty-block bitmap of a flat parameter buffer.

Dispatch: Pallas kernel on TPU (or ``impl='pallas'`` which uses interpret
mode off-TPU — used by the test suite), pure-jnp reference otherwise. Both
paths share padding/reshape via :mod:`repro.kernels.common`.
"""

from __future__ import annotations

from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.core.blocks import TPU_TILE
from repro.kernels.common import TILE_BLOCKS, as_blocks, pad_blocks_to_tile
from repro.kernels.dirty_diff.kernel import dirty_diff_blocked
from repro.kernels.dirty_diff.ref import dirty_diff_blocked_ref

Impl = Literal["auto", "pallas", "ref"]


def dirty_blocks(
    cur: jax.Array,
    snap: jax.Array,
    *,
    block_bytes: int = TPU_TILE,
    impl: Impl = "auto",
) -> jax.Array:
    """int32 (nblocks,) dirty flags for a flat buffer vs its snapshot.

    nblocks = ceil(cur.size * itemsize / block_bytes); the tail block is
    zero-padded identically on both sides (never spuriously dirty).
    """
    if cur.shape != snap.shape or cur.dtype != snap.dtype:
        raise ValueError("cur and snap must match in shape and dtype")
    cur_b, _ = as_blocks(cur, block_bytes)
    snap_b, _ = as_blocks(snap, block_bytes)
    nblocks = cur_b.shape[0]
    if impl == "ref" or (impl == "auto" and jax.default_backend() != "tpu"):
        return dirty_diff_blocked_ref(cur_b, snap_b)
    interpret = jax.default_backend() != "tpu"
    padded = pad_blocks_to_tile(nblocks, TILE_BLOCKS)
    if padded != nblocks:
        pad = ((0, padded - nblocks), (0, 0), (0, 0))
        cur_b = jnp.pad(cur_b, pad)
        snap_b = jnp.pad(snap_b, pad)
    flags = dirty_diff_blocked(cur_b, snap_b, interpret=interpret)
    return flags[:nblocks]
