"""Pure-jnp oracle for the dirty_diff kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dirty_diff_blocked_ref(cur: jax.Array, snap: jax.Array) -> jax.Array:
    """(nblocks, rows, 128) ×2 → (nblocks,) int32 dirty flags."""
    return jnp.any(cur != snap, axis=(1, 2)).astype(jnp.int32)
