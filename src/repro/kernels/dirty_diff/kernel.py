"""Pallas TPU kernel: block-granular dirty bitmap (cur vs snapshot).

This is the on-device realization of the paper's "the page is required to
track modified areas since its last flush" (§3.2.2) — a training loop has no
write interception, so dirtiness is *computed* by diffing live parameters
against the last-flushed snapshot, at TPU-block (4 KiB tile) granularity.

Grid: one program per TILE_BLOCKS blocks. Each program streams two
(TILE_BLOCKS, rows, 128) tiles from HBM into VMEM, reduces ``any(cur !=
snap)`` per block on the VPU, and writes a (TILE_BLOCKS, 1) int32 flag
vector. Arithmetic intensity is ~1 op/byte ⇒ the kernel is HBM-bandwidth
bound by design; the win over the naive jnp composition is fusing compare +
reduce in one pass (no materialized boolean array in HBM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import LANES, TILE_BLOCKS


def _dirty_diff_kernel(cur_ref, snap_ref, out_ref):
    neq = cur_ref[...] != snap_ref[...]
    out_ref[...] = jnp.any(neq, axis=(1, 2)).astype(jnp.int32)[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def dirty_diff_blocked(cur: jax.Array, snap: jax.Array, *, interpret: bool = False) -> jax.Array:
    """(nblocks, rows, 128) ×2 → (nblocks,) int32 dirty flags.

    ``nblocks`` must be a multiple of TILE_BLOCKS (ops.py pads).
    """
    nblocks, rows, lanes = cur.shape
    assert lanes == LANES and cur.shape == snap.shape
    assert nblocks % TILE_BLOCKS == 0
    grid = (nblocks // TILE_BLOCKS,)
    spec = pl.BlockSpec((TILE_BLOCKS, rows, LANES), lambda i: (i, 0, 0))
    out = pl.pallas_call(
        _dirty_diff_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((TILE_BLOCKS, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, 1), jnp.int32),
        interpret=interpret,
    )(cur, snap)
    return out[:, 0]
