"""Pallas TPU kernels for the persistence layer's compute hot-spots.

The paper optimizes I/O; the on-device work of its adapted primitives is:
  - dirty_diff       — block-granular dirty bitmap (µLog dirty tracking)
  - popcnt_checksum  — Zero-log validity word (popcount, §3.3.1)
  - delta_pack       — gather/scatter dirty blocks (µLog content/replay)
  - flush_scan       — fused dirty bitmap + popcounts (two facts, one read)
  - flush_pack       — the whole save pass fused: diff+pack+checksum plus
                       on-device prefix-sum compaction, one HBM read
  - apply_unpack     — the whole restore pass fused: checksum-verify +
                       scatter + apply onto the base image, one HBM read
                       (flush_pack's inverse)

Each subpackage has kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
dispatch wrapper: Pallas on TPU, ref elsewhere), ref.py (pure-jnp oracle).
Kernels are validated in interpret mode against the oracles with
hypothesis-driven shape/dtype sweeps (tests/test_kernels.py).
"""

from repro.kernels.apply_unpack import ApplyUnpack, apply_unpack  # noqa: F401
from repro.kernels.delta_pack import apply_delta, pack_delta, pack_dirty  # noqa: F401
from repro.kernels.dirty_diff import dirty_blocks  # noqa: F401
from repro.kernels.flush_pack import FlushPack, flush_pack  # noqa: F401
from repro.kernels.flush_scan import flush_scan  # noqa: F401
from repro.kernels.popcnt_checksum import popcount_blocks, popcount_checksum  # noqa: F401
