import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, WITHOUT allocating any real state:
  - compiled.memory_analysis()  → bytes/device (proves it fits)
  - compiled.cost_analysis()    → HLO FLOPs / bytes accessed
  - collective byte totals parsed from the optimized HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute), with
    while-loop (scan) bodies multiplied by their trip counts
  → the three roofline terms (benchmarks/roofline.py).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k --mesh multi           # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --out artifacts/dryrun
"""

import argparse
import dataclasses
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.configs import ALIASES, ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    SHAPES,
    cell_supported,
    caches_abstract,
    input_specs,
    opt_state_abstract,
    params_abstract,
)
from repro.launch.steps import (
    shard_prefill_step,
    shard_serve_step,
    shard_train_step,
)

# ---------------------------------------------------------------- HLO scan

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2, "u16": 2, "f8e4m3": 1,
    "f8e5m2": 1,
}


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of all shapes in an HLO result/operand string."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo: str) -> Dict[str, Any]:
    """Parse optimized HLO text: per-collective byte totals, with while-loop
    bodies scaled by trip count.

    Strategy: split into computations; find trip counts from while loops
    (XLA names bodies `while_body` / region annotations; robust fallback =
    constant comparison in the loop condition); attribute each collective's
    *result* bytes (shape of its output) to its computation; multiply by
    the computation's execution count.
    """
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo.splitlines():
        m = re.match(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->", line)
        if m and ("{" in line or line.rstrip().endswith("{")):
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            comps[cur].append(line)

    # trip counts: find `while` ops and their condition computations
    trip: Dict[str, int] = {}
    body_of: Dict[str, str] = {}
    for cname, lines in comps.items():
        for ln in lines:
            if " while(" in ln or "= while " in ln or re.search(r"=\s*\w*\[?.*\bwhile\b", ln):
                mb = re.search(r"body=%?([\w\.\-]+)", ln)
                mc = re.search(r"condition=%?([\w\.\-]+)", ln)
                if mb:
                    body_of[mb.group(1)] = mc.group(1) if mc else ""

    def cond_trip_count(cond_name: str) -> Optional[int]:
        lines = comps.get(cond_name, [])
        consts = []
        for ln in lines:
            for m in re.finditer(r"constant\((-?\d+)\)", ln):
                consts.append(int(m.group(1)))
        pos = [c for c in consts if c > 0]
        return max(pos) if pos else None

    exec_count: Dict[str, int] = {}
    for body, cond in body_of.items():
        tc = cond_trip_count(cond) if cond else None
        exec_count[body] = tc if tc else 1

    per_op: Dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    details = []
    for cname, lines in comps.items():
        mult = exec_count.get(cname, 1)
        for ln in lines:
            for cop in _COLLECTIVES:
                m = re.search(rf"=\s*(.*?)\b{cop}(?:-start)?\(", ln)
                if m:
                    # result shape(s) sit between '=' and the opcode
                    nbytes = _shape_bytes(m.group(1))
                    per_op[cop] += nbytes * mult
                    details.append({"op": cop, "comp": cname, "bytes": nbytes,
                                    "mult": mult})
                    break
    total = sum(per_op.values())
    return {"per_op": per_op, "total_bytes": total, "ops": len(details),
            "details": details[:50]}


# ---------------------------------------------------------------- one cell


def run_cell(arch: str, shape: str, *, multi_pod: bool,
             save_hlo_to: Optional[str] = None,
             opt_overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    cfg = get_config(arch)
    if opt_overrides:
        cfg = dataclasses.replace(cfg, **opt_overrides)
    ok, why = cell_supported(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    base = {
        "arch": cfg.name, "shape": shape, "mesh": mesh_name,
        "family": cfg.family,
    }
    if not ok:
        return dict(base, status="skipped", reason=why)

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    spec = input_specs(cfg, shape)
    params_abs = params_abstract(cfg)

    try:
        with jax.set_mesh(mesh):
            if spec["kind"] == "train":
                opt_abs = opt_state_abstract(params_abs)
                step, _ = shard_train_step(cfg, mesh, params_abs, opt_abs,
                                           spec["batch"])
                lowered = step.lower(params_abs, opt_abs, spec["batch"])
            elif spec["kind"] == "prefill":
                step, _ = shard_prefill_step(cfg, mesh, params_abs, spec["batch"])
                lowered = step.lower(params_abs, spec["batch"])
            else:
                batch = spec["tokens"].shape[0]
                step, _ = shard_serve_step(cfg, mesh, params_abs,
                                           spec["caches"], batch)
                lowered = step.lower(params_abs, spec["tokens"],
                                     spec["caches"], spec["cache_pos"])
            compiled = lowered.compile()
    except Exception as e:
        return dict(base, status="error", error=f"{type(e).__name__}: {e}",
                    traceback=traceback.format_exc()[-2000:])

    t1 = time.time()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)
    from repro.launch.hlo_analysis import analyze
    rep = analyze(hlo)
    if save_hlo_to:
        with open(save_hlo_to, "w") as f:
            f.write(hlo)

    def _mem_field(name):
        v = getattr(mem, name, None)
        return int(v) if v is not None else None

    result = dict(
        base,
        status="ok",
        compile_s=round(t1 - t0, 1),
        ndev=int(np.prod(list(mesh.shape.values()))),
        memory={
            "argument_bytes": _mem_field("argument_size_in_bytes"),
            "output_bytes": _mem_field("output_size_in_bytes"),
            "temp_bytes": _mem_field("temp_size_in_bytes"),
            "alias_bytes": _mem_field("alias_size_in_bytes"),
        },
        cost={
            "flops": float(cost.get("flops", -1.0)),
            "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        },
        hlo_analysis={
            "flops": rep.flops,
            "traffic_bytes": rep.traffic_bytes,
            "collective_bytes": rep.collective_bytes,
            "collective_per_op": rep.collective_per_op,
            "scan_trip_counts": rep.exec_counts,
            "dot_count": rep.dot_count,
        },
        collectives=dict(coll, details=None),
        hlo_lines=hlo.count("\n"),
    )
    result["kind"] = spec["kind"]
    return result


# ------------------------------------------------------------------- main


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="arch id (assignment-sheet name ok)")
    ap.add_argument("--shape", choices=list(SHAPES), default="train_4k")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true", help="all 40 cells")
    ap.add_argument("--out", default=None, help="dir for per-cell JSON")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        if not args.arch:
            ap.error("--arch or --all required")
        cells.append((args.arch, args.shape))
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    if args.out:
        os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            res = run_cell(arch, shape, multi_pod=mp, save_hlo_to=args.save_hlo)
            tag = f"{res['arch']}|{shape}|{res['mesh']}"
            print(f"[{res['status']:7s}] {tag}  "
                  + (f"flops={res['cost']['flops']:.3e} "
                     f"coll={res['collectives']['total_bytes']:.3e}B "
                     f"temp={res['memory']['temp_bytes']}B "
                     f"({res['compile_s']}s)" if res["status"] == "ok"
                     else res.get("reason", res.get("error", ""))[:200]))
            sys.stdout.flush()
            if res["status"] == "error":
                failures += 1
            if args.out:
                fn = f"{ALIASES.get(arch, arch).replace('.', '_')}_{shape}_{res['mesh']}.json"
                with open(os.path.join(args.out, fn), "w") as f:
                    json.dump(res, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
