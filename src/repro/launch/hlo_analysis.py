"""Static analysis of optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` visits each instruction once, so everything
inside a ``jax.lax.scan`` (→ ``while``) body is under-counted by its trip
count. This module rebuilds the numbers from the HLO text itself:

  1. split the module into computations,
  2. build the call graph (fusion/call/to_apply edges inline; while
     body/condition edges carry a trip count recovered from the loop
     condition's comparison constant),
  3. propagate execution counts from ENTRY,
  4. per executed computation, accumulate
       - matmul FLOPs: 2 × |result| × |contracting dims| per ``dot``,
       - memory traffic: operand + result bytes of top-level materializing
         instructions (fusion internals excluded — they don't touch HBM),
       - collective bytes: result bytes of all-gather / all-reduce /
         reduce-scatter / all-to-all / collective-permute.

All numbers are per-device (post-partitioning shapes) and multiplied by
execution counts. They are estimates of the *steady-state* device work —
exact for FLOPs, a good proxy for HBM traffic (fusions write their result
once and read their operands once).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
#: top-level ops that materialize their result in memory
_MATERIALIZING = (
    "fusion", "dot", "copy", "convert", "dynamic-update-slice", "gather",
    "scatter", "dynamic-slice", "broadcast", "transpose", "reshape",
    "reduce", "sort", "iota", "concatenate", "pad", "slice", "select",
) + _COLLECTIVES


def _shape_list(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        out.append((dt, dims))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    result_shapes: list
    operands: List[str]
    raw: str


@dataclasses.dataclass
class HloReport:
    flops: float
    traffic_bytes: float
    collective_bytes: float
    collective_per_op: Dict[str, float]
    exec_counts: Dict[str, int]
    dot_count: int
    notes: List[str]


_COMP_HDR = re.compile(r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*?)\s([\w\-]+)\((.*)$")


def parse_computations(hlo: str):
    comps: Dict[str, List[str]] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        m = _COMP_HDR.match(line)
        if m and ("{" in line):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
        elif cur is not None and line.strip() and line.strip() != "}":
            comps[cur].append(line)
    return comps, entry


def _parse_instr(line: str) -> Optional[Instruction]:
    m = _INSTR.match(line)
    if not m:
        return None
    name, result_txt, opcode, rest = m.groups()
    operands = re.findall(r"%([\w\.\-]+)", rest.split("),")[0] + ")")
    return Instruction(name, opcode, _shape_list(result_txt), operands, line)


def analyze(hlo: str, *, include_traffic: bool = True) -> HloReport:
    comps, entry = parse_computations(hlo)
    notes: List[str] = []

    # --- parse instructions, build shape table -------------------------
    instrs: Dict[str, List[Instruction]] = {}
    shape_of: Dict[str, list] = {}
    for cname, lines in comps.items():
        out = []
        for ln in lines:
            ins = _parse_instr(ln)
            if ins is None:
                # parameters: "%p = f32[..] parameter(0)"
                pm = re.match(r"^\s*%([\w\.\-]+)\s*=\s*(.*?)\sparameter\(", ln)
                if pm:
                    shape_of[pm.group(1)] = _shape_list(pm.group(2))
                continue
            out.append(ins)
            shape_of[ins.name] = ins.result_shapes
        instrs[cname] = out

    # --- call graph + trip counts ---------------------------------------
    body_cond: List[Tuple[str, str, str]] = []   # (caller, body, cond)
    call_edges: List[Tuple[str, str]] = []       # inline calls (count x1)
    for cname, ins_list in instrs.items():
        for ins in ins_list:
            if ins.opcode == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", ins.raw)
                mc = re.search(r"condition=%?([\w\.\-]+)", ins.raw)
                if mb:
                    body_cond.append((cname, mb.group(1),
                                      mc.group(1) if mc else ""))
            else:
                for kw in ("calls=", "to_apply=", "body="):
                    for m in re.finditer(kw + r"%?([\w\.\-]+)", ins.raw):
                        call_edges.append((cname, m.group(1)))

    def trip_count(cond: str) -> int:
        # loop condition compares the induction variable against a constant
        best = 0
        for ln in comps.get(cond, []):
            for m in re.finditer(r"constant\((\d+)\)", ln):
                best = max(best, int(m.group(1)))
        return best if best > 0 else 1

    exec_count: Dict[str, float] = {c: 0.0 for c in comps}
    if entry is None:
        entry = next(iter(comps), None)
        notes.append("no ENTRY found; using first computation")
    if entry is None:
        return HloReport(0, 0, 0, {}, {}, 0, ["empty HLO"])
    exec_count[entry] = 1.0

    # propagate in call order (HLO text lists callees before callers, so
    # iterate a few times to reach a fixed point; graphs are shallow)
    for _ in range(8):
        changed = False
        for caller, body, cond in body_cond:
            t = trip_count(cond)
            want_b = exec_count[caller] * t
            if body in exec_count and exec_count[body] < want_b:
                exec_count[body] = want_b
                changed = True
            if cond in exec_count and exec_count[cond] < want_b + exec_count[caller]:
                exec_count[cond] = want_b + exec_count[caller]
                changed = True
        for caller, callee in call_edges:
            if callee in exec_count and exec_count[callee] < exec_count[caller]:
                exec_count[callee] = exec_count[caller]
                changed = True
        if not changed:
            break

    # computations reached only via fusion/call are *inlined*: their
    # instruction traffic must not be double counted. Executed-standalone =
    # entry + while bodies/conditions.
    standalone = {entry}
    standalone.update(b for _, b, _ in body_cond)
    standalone.update(c for _, _, c in body_cond if c)

    # --- accumulate ------------------------------------------------------
    flops = 0.0
    traffic = 0.0
    coll_bytes = 0.0
    per_op = {c: 0.0 for c in _COLLECTIVES}
    dot_count = 0

    def dot_flops(ins: Instruction) -> float:
        out_elems = 1
        for dt, dims in ins.result_shapes:
            for d in dims:
                out_elems *= d
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.raw)
        lhs = ins.operands[0] if ins.operands else None
        if not m or lhs is None or lhs not in shape_of or not shape_of[lhs]:
            return 2.0 * out_elems  # fallback: unknown contraction
        lhs_dims = shape_of[lhs][0][1]
        k = 1
        for idx in (int(i) for i in m.group(1).split(",") if i):
            if idx < len(lhs_dims):
                k *= lhs_dims[idx]
        return 2.0 * out_elems * k

    for cname, ins_list in instrs.items():
        mult = exec_count.get(cname, 0.0)
        if mult <= 0:
            continue
        for ins in ins_list:
            if ins.opcode == "dot":
                flops += dot_flops(ins) * mult
                dot_count += 1
            if ins.opcode in _COLLECTIVES or (
                    ins.opcode.endswith("-start")
                    and ins.opcode[:-6] in _COLLECTIVES):
                op = ins.opcode[:-6] if ins.opcode.endswith("-start") else ins.opcode
                b = _nbytes(ins.result_shapes)
                per_op[op] += b * mult
                coll_bytes += b * mult
        if include_traffic and cname in standalone:
            for ins in ins_list:
                base = ins.opcode[:-6] if ins.opcode.endswith("-start") else ins.opcode
                if base in _MATERIALIZING:
                    w = _nbytes(ins.result_shapes)
                    r = sum(_nbytes(shape_of.get(o, [])) for o in ins.operands)
                    traffic += (w + r) * mult

    # dots inside fusion computations: count their flops with the *caller's*
    # multiplicity — handled above because fusion comps inherit exec_count
    # via call_edges; their traffic is excluded (not standalone). ✓

    return HloReport(
        flops=flops,
        traffic_bytes=traffic,
        collective_bytes=coll_bytes,
        collective_per_op=per_op,
        exec_counts={k: int(v) for k, v in exec_count.items() if v > 1},
        dot_count=dot_count,
        notes=notes,
    )
