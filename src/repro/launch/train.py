"""End-to-end training driver.

Wires together: model (any --arch), synthetic resumable data pipeline,
AdamW, and the paper's persistence stack — Zero-log WAL committed every
step (ONE durability barrier on the critical path), hybrid CoW/µLog delta
checkpoints flushed asynchronously every --ckpt-every steps, crash
recovery on restart (checkpoint + WAL fast-forward = exactly-once steps).

CPU-runnable: reduced configs train a real model for hundreds of steps
(examples/train_tinyllama.py); full configs are exercised by the dry-run.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --steps 200 --batch 8 --seq 128 --out /tmp/run1
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.data import SyntheticPipeline
from repro.pool import Pool
from repro.launch.steps import build_train_step
from repro.models import init_params
from repro.optim import AdamWConfig, adamw_init
from repro.persistence import (
    AsyncFlusher,
    CheckpointConfig,
    CheckpointManager,
    StepRecord,
    TrainWAL,
)


def flatten_state(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = np.asarray(leaf)
    return out


def unflatten_like(template, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = flat[key]
        leaves.append(jnp.asarray(arr).astype(leaf.dtype).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, leaves)


@dataclasses.dataclass
class TrainerConfig:
    arch: str = "tinyllama-1.1b"
    reduced: bool = True
    steps: int = 100
    batch: int = 8
    seq: int = 128
    ckpt_every: int = 20
    out: str = "/tmp/repro_run"
    wal_capacity_steps: int = 100_000
    lr: float = 3e-4
    remat: bool = True
    resume: bool = True
    async_flush: bool = True
    # repro.io engine: >1 stripes the WAL over that many zero-log lanes
    # and amortizes `wal_group_commit` steps per persistency barrier
    wal_lanes: int = 1
    wal_group_commit: int = 1
    # >= 2 runs the step WAL on a generation ring: every checkpoint rolls
    # (seals) the live generation and the spill tier retires it to SSD in
    # the same cadence, so the WAL's PMem footprint stays at
    # gen_sets x capacity_steps instead of growing for the whole run
    # (capacity_steps is then per generation — size it to the checkpoint
    # cadence, not the run length)
    wal_gen_sets: int = 1


class Trainer:
    def __init__(self, tc: TrainerConfig) -> None:
        self.tc = tc
        os.makedirs(tc.out, exist_ok=True)
        self.cfg = get_reduced(tc.arch) if tc.reduced else get_config(tc.arch)
        self.pipeline = SyntheticPipeline(self.cfg, tc.batch, tc.seq)
        self.step_fn = jax.jit(build_train_step(
            self.cfg, AdamWConfig(lr=tc.lr), remat=tc.remat,
            total_steps=max(tc.steps, 100)))
        # --- persistence ------------------------------------------------
        wal_path = os.path.join(tc.out, "wal.pmem")
        wal_bytes = TrainWAL.capacity_for(tc.wal_capacity_steps,
                                          lanes=tc.wal_lanes,
                                          gen_sets=tc.wal_gen_sets)
        if tc.wal_gen_sets > 1:
            wal_bytes += 1 << 16   # spill-map double buffer + head regions
        self.wal_pool = Pool.open_or_create(wal_path, wal_bytes)
        self.wal_pmem = self.wal_pool.pmem
        self.wal = self.wal_pool.wal(
            "train_wal", capacity_steps=tc.wal_capacity_steps,
            lanes=tc.wal_lanes, group_commit=tc.wal_group_commit,
            gen_sets=tc.wal_gen_sets)
        self.wal_spill = None
        if self.wal.generational:
            # the ring needs a retirement path: sealed step generations
            # move to SSD at the checkpoint cadence (the durable retired
            # watermark keeps every generation recoverable from exactly
            # one tier), bounding the WAL's PMem footprint for good
            from repro.core.ssd import SSD
            from repro.tier import SpillScheduler
            self.wal_pool.attach_ssd(SSD(1 << 26))
            self.wal_spill = SpillScheduler(self.wal_pool, name="twsp",
                                            map_capacity=1 << 14)
            self.wal.log.attach_spill(self.wal_spill)
        self.manager = CheckpointManager(
            os.path.join(tc.out, "ckpt.pmem"),
            CheckpointConfig(page_size=128 * 1024))
        self.flusher = AsyncFlusher(self.manager) if tc.async_flush else None

        self.start_step = 0
        params = opt_state = None
        if tc.resume and os.path.exists(os.path.join(tc.out, "ckpt.pmem")) \
                and os.path.getsize(os.path.join(tc.out, "ckpt.pmem")) > 0:
            try:
                step, flat = self.manager.restore()
                tmpl_p = jax.eval_shape(lambda k: init_params(self.cfg, k),
                                        jax.random.key(0))
                tmpl_o = jax.eval_shape(adamw_init, tmpl_p)
                np_params = {k[2:]: v for k, v in flat.items() if k.startswith("p/")}
                np_opt = {k[2:]: v for k, v in flat.items() if k.startswith("o/")}
                params = unflatten_like(tmpl_p, np_params)
                opt_state = unflatten_like(tmpl_o, np_opt)
                self.start_step = step
                print(f"[train] restored checkpoint @ step {step}")
                if self.wal.last is not None and self.wal.last.step > step:
                    print(f"[train] WAL ahead at step {self.wal.last.step}; "
                          f"fast-forwarding data cursor")
                    self.start_step = step  # deterministic replay from ckpt
            except FileNotFoundError:
                pass
        if params is None:
            params = init_params(self.cfg, jax.random.key(0))
            opt_state = adamw_init(params)
        self.params, self.opt_state = params, opt_state

    def _ckpt_state(self) -> Dict[str, np.ndarray]:
        flat = {f"p/{k}": v for k, v in flatten_state(self.params).items()}
        flat.update({f"o/{k}": v for k, v in flatten_state(self.opt_state).items()})
        return flat

    def run(self, crash_at: Optional[int] = None) -> Dict[str, Any]:
        tc = self.tc
        losses = []
        t_start = time.time()
        for step in range(self.start_step, tc.steps):
            if crash_at is not None and step == crash_at:
                # simulated process death: no cleanup, no final flush
                return {"crashed_at": step, "losses": losses}
            batch = {k: jnp.asarray(v)
                     for k, v in self.pipeline.batch_at(step).items()}
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            # WAL commit: ONE barrier on the critical path (Zero logging);
            # with group commit enabled, steps buffer and the barrier is
            # amortized per batch (crash loses at most a replayable tail)
            self.wal.commit_step(StepRecord(
                step + 1, step + 1, (0, 0), loss,
                float(metrics["grad_norm"]), 1.0, time.time_ns()),
                sync=tc.wal_group_commit <= 1)
            if (step + 1) % tc.ckpt_every == 0:
                state = self._ckpt_state()
                if self.flusher is not None:
                    self.flusher.submit(step + 1, state)
                else:
                    self.manager.save(step + 1, state)
                if self.wal.generational:
                    # checkpoint-cadence truncation: seal the live step
                    # generation and retire it through the spill tier —
                    # it stays recoverable (PMem until the drain's map
                    # record + watermark commit, SSD after), but its
                    # ring slot frees for reuse instead of the step WAL
                    # only ever truncating at restart
                    self.wal.roll()
                    self.wal_spill.drain()
        self.wal.flush()   # drain any group-commit-buffered steps
        if self.flusher is not None:
            reports = self.flusher.wait()
        else:
            reports = []
        wall = time.time() - t_start
        return {
            "steps": tc.steps - self.start_step,
            "wall_s": wall,
            "losses": losses,
            "first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
            "wal_barriers_per_step": self.wal.barriers_per_step(),
            "ckpt_reports": [dataclasses.asdict(r) for r in reports][-3:],
        }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--out", default="/tmp/repro_run")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()
    tc = TrainerConfig(arch=args.arch, reduced=args.reduced, steps=args.steps,
                       batch=args.batch, seq=args.seq,
                       ckpt_every=args.ckpt_every, out=args.out, lr=args.lr,
                       resume=not args.no_resume)
    report = Trainer(tc).run()
    print(json.dumps({k: v for k, v in report.items() if k != "losses"},
                     indent=1, default=str))
    losses = report["losses"]
    if losses:
        k = max(1, len(losses) // 10)
        print(f"loss: first10={np.mean(losses[:k]):.4f} "
              f"last10={np.mean(losses[-k:]):.4f}")


if __name__ == "__main__":
    main()
