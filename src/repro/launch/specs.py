"""Input/state ShapeDtypeStruct stand-ins for every (arch × shape) cell.

``input_specs`` mirrors the synthetic pipeline's batch structure; the
dry-run lowers against these without allocating anything. Assigned shapes:

  train_4k     seq 4096,    global_batch 256   → train_step
  prefill_32k  seq 32768,   global_batch 32    → prefill (full forward)
  decode_32k   seq 32768,   global_batch 128   → serve_step (1 token, KV@32k)
  long_500k    seq 524288,  global_batch 1     → serve_step; ONLY for
               sub-quadratic mixers (ssm/hybrid) — skipped for pure
               full-attention archs (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import init_caches, init_params
from repro.models.config import ModelConfig

SHAPES: Dict[str, Dict[str, int]] = {
    "train_4k": {"seq": 4096, "batch": 256, "mode": 0},
    "prefill_32k": {"seq": 32768, "batch": 32, "mode": 1},
    "decode_32k": {"seq": 32768, "batch": 128, "mode": 2},
    "long_500k": {"seq": 524288, "batch": 1, "mode": 2},
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def cell_supported(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, ("full-attention arch: a 512k dense KV cache is the "
                       "quadratic cost this shape excludes (DESIGN.md §5)")
    return True, ""


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def batch_specs_abstract(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    """ShapeDtypeStructs matching data.synthetic_batch."""
    out = {
        "tokens": _sds((batch, seq), jnp.int32),
        "labels": _sds((batch, seq), jnp.int32),
    }
    if cfg.frontend == "audio_frames":
        out["frames"] = _sds((batch, seq, cfg.d_model), cfg.dtype)
    if cfg.frontend == "vision_patches":
        out["vis_embeds"] = _sds((batch, max(1, seq // 4), cfg.d_model), cfg.dtype)
        out["positions"] = _sds((3, batch, seq), jnp.int32)
    return out


def params_abstract(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.key(0))


def opt_state_abstract(params_abs):
    from repro.optim import adamw_init
    return jax.eval_shape(adamw_init, params_abs)


def caches_abstract(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0):
    return jax.eval_shape(
        lambda: init_caches(cfg, batch, max_len, enc_len))


def decode_inputs_abstract(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    out = {"tokens": _sds((batch, 1), jnp.int32)}
    return out


def input_specs(cfg: ModelConfig, shape: str) -> Dict[str, Any]:
    """The complete abstract input bundle for one cell."""
    info = SHAPES[shape]
    seq, batch = info["seq"], info["batch"]
    mode = info["mode"]
    if mode in (0, 1):
        return {
            "kind": "train" if mode == 0 else "prefill",
            "batch": batch_specs_abstract(cfg, batch, seq),
        }
    enc_len = seq if cfg.encoder_layers else 0
    return {
        "kind": "decode",
        "tokens": _sds((batch, 1), jnp.int32),
        "caches": caches_abstract(cfg, batch, seq, enc_len),
        "cache_pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
