"""Jitted step builders: train_step / prefill_step / serve_step.

Built per (config, mesh): in/out shardings come from the rule tables in
``distributed.sharding``; params and optimizer moments are donated so the
updated state reuses the same buffers.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models import decode_step as model_decode
from repro.models import forward, lm_loss
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_update, warmup_cosine


def build_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig = AdamWConfig(),
                     *, remat: bool = True, total_steps: int = 10_000):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, batch, remat=remat), has_aux=True)(params)
        lr_scale = warmup_cosine(opt_state["count"], total=total_steps)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, opt_state, params, opt_cfg, lr_scale)
        metrics = dict(metrics, **opt_metrics, lr_scale=lr_scale)
        return new_params, new_opt, metrics

    return train_step


def build_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, _ = forward(params, cfg, batch)
        # serving returns only the last position's logits
        return logits[:, -1]

    return prefill_step


def build_serve_step(cfg: ModelConfig):
    def serve_step(params, tokens, caches, cache_pos):
        logits, new_caches = model_decode(params, cfg, tokens, caches, cache_pos)
        return logits[:, 0], new_caches

    return serve_step


# -----------------------------------------------------------------------
# sharded jit wrappers
# -----------------------------------------------------------------------


def shard_train_step(cfg: ModelConfig, mesh: Mesh, params_abs, opt_abs,
                     batch_abs, **kw):
    """jit train_step with explicit in/out shardings for `mesh`."""
    pspecs = shd.state_specs(params_abs, mesh)
    ospecs = shd.opt_specs(opt_abs, pspecs, mesh)
    bspecs = shd.batch_specs(batch_abs, mesh)
    named = functools.partial(shd.to_named, mesh=mesh)
    metric_sharding = NamedSharding(mesh, P())
    step = build_train_step(cfg, **kw)
    return jax.jit(
        step,
        in_shardings=(named(pspecs), named(ospecs), named(bspecs)),
        out_shardings=(named(pspecs), named(ospecs), None),
        donate_argnums=(0, 1),
    ), (pspecs, ospecs, bspecs)


def shard_prefill_step(cfg: ModelConfig, mesh: Mesh, params_abs, batch_abs):
    pspecs = shd.state_specs(params_abs, mesh)
    bspecs = shd.batch_specs(batch_abs, mesh)
    named = functools.partial(shd.to_named, mesh=mesh)
    step = build_prefill_step(cfg)
    return jax.jit(
        step,
        in_shardings=(named(pspecs), named(bspecs)),
    ), (pspecs, bspecs)


def shard_serve_step(cfg: ModelConfig, mesh: Mesh, params_abs, caches_abs,
                     batch: int):
    pspecs = shd.state_specs(params_abs, mesh)
    cspecs = shd.cache_specs(caches_abs, mesh)
    fs = shd.fsdp_axes(mesh) or None
    tok_spec = P(shd._fit(mesh, batch, fs), None)
    named = functools.partial(shd.to_named, mesh=mesh)
    step = build_serve_step(cfg)
    return jax.jit(
        step,
        in_shardings=(named(pspecs), NamedSharding(mesh, tok_spec),
                      named(cspecs), NamedSharding(mesh, P())),
        out_shardings=(None, named(cspecs)),
        donate_argnums=(2,),
    ), (pspecs, cspecs)
