"""Batched serving driver: prefill + decode loop with KV caches.

CPU-runnable on reduced configs; the production-shape serve_step is what
the dry-run lowers for decode_32k / long_500k cells.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.data import synthetic_batch
from repro.models import decode_step, forward, init_caches


def serve_batch(cfg, params, prompts: jax.Array, gen: int,
                extras: Optional[Dict[str, jax.Array]] = None,
                greedy: bool = True):
    """Prefill via teacher-forced forward, then autoregressive decode.

    Returns (generated tokens (B, gen), tokens/s)."""
    B, P = prompts.shape
    max_len = P + gen
    caches = init_caches(cfg, B, max_len,
                         enc_len=extras["frames"].shape[1] if extras and "frames" in extras else 0)
    step = jax.jit(lambda p, t, c, pos: decode_step(p, cfg, t, c, pos,
                                                    extras=None))
    # prefill by stepping the prompt through the decode path (cache-exact;
    # a fused prefill kernel is a serving optimization, not a correctness
    # requirement — the dry-run lowers the full-seq prefill separately)
    tok = prompts[:, :1]
    t0 = time.time()
    if extras and "frames" in extras:
        # enc-dec: encoder output becomes the cross cache at position 0
        from repro.models.model import encode
        enc_out = encode(params, cfg, extras["frames"].astype(jnp.bfloat16)
                         if cfg.dtype == "bfloat16" else extras["frames"])
        # write cross k/v through one forward call with caches
        logits, caches = forward(params, cfg,
                                 {"tokens": tok, "frames": extras["frames"]},
                                 caches=caches, cache_pos=jnp.int32(0))
        start = 1
    else:
        start = 0
    for t in range(start, P):
        _, caches = step(params, prompts[:, t : t + 1], caches, jnp.int32(t))
    out = []
    last = prompts[:, -1:]
    for t in range(P, P + gen):
        logits, caches = step(params, last, caches, jnp.int32(t))
        last_logits = logits[:, -1]          # (B, V)
        if greedy:
            nxt = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]
        else:
            nxt = jax.random.categorical(
                jax.random.key(t), last_logits)[:, None].astype(jnp.int32)
        nxt = jnp.minimum(nxt, cfg.vocab_size - 1)
        out.append(nxt)
        last = nxt
    toks = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    return toks, (B * (P + gen)) / dt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    from repro.models import init_params
    params = init_params(cfg, jax.random.key(0))
    b = synthetic_batch(cfg, args.batch, args.prompt_len, cursor=0)
    prompts = jnp.asarray(b["tokens"])
    extras = {"frames": jnp.asarray(b["frames"])} if "frames" in b else None
    toks, tps = serve_batch(cfg, params, prompts, args.gen, extras)
    print(json.dumps({
        "arch": cfg.name, "batch": args.batch,
        "generated_shape": list(toks.shape), "tokens_per_s": round(tps, 1),
        "sample": np.asarray(toks[0, :8]).tolist(),
    }))


if __name__ == "__main__":
    main()
