"""Launchers: mesh construction, jitted steps, dry-run, train/serve."""
