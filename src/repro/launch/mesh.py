"""Production mesh definition.

A function (not a module-level constant) so importing never touches jax
device state. Single pod: 16×16 = 256 chips (v5e pod), axes (data, model).
Multi-pod: 2×16×16 = 512 chips, axes (pod, data, model) — the leading
``pod`` axis carries pure data parallelism across the inter-pod DCN links,
so its collectives are gradient reduce-scatter/all-gathers only.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate 1×1 mesh for CPU smoke runs of the launch path."""
    return jax.make_mesh((1, 1), ("data", "model"))
