"""``repro.cache.BufferManager`` — the DRAM rung of the Fig. 3 ladder.

The paper's latency ladder (Fig. 3: DRAM ≪ PMem ≪ flash) is what makes
tier placement worth engineering, yet until this module the stack read
every page from its *resident* tier on every access and promoted
SSD-resident pages on first touch — scans thrashed the spill tier and
nothing was ever served at DRAM latency. The buffer manager closes the
ladder's top rung: a bounded pool of DRAM *frames* in front of the
PMem page slots and the SSD spill tier, so the read path becomes

    frame hit (DRAM)  →  slot fill (PMem, uncached device read)
                      →  spill fill (SSD, checksum-verified via the map)

with per-tier hit/miss accounting (:class:`CacheStats`) that
``costmodel`` converts to modeled time against the Fig. 3 constants.
Every counter is attributed twice — globally (``stats``) and under the
region owner that caused it (``stats_by_owner``) — so a multi-tenant
consumer (``repro.serve``) gets per-tenant hit ratios for free, and
:meth:`BufferManager.set_quota` can cap one owner's resident frames
without touching the shared clock.

Design points, each load-bearing for crash safety:

* **Volatile by construction.** Frames are DRAM: nothing the cache does
  changes what a crash recovers. Dirty frames reach PMem only through
  the owning :class:`~repro.io.flushq.FlushQueue` — the exact epoch
  path writes took before the cache existed — so recovery is
  bit-identical with the cache enabled, disabled, or sized to zero
  (``tests/test_crash_corpus.py`` replays the same op stream under
  ``frames=0`` and a warm cache and asserts identical recovered state).
* **Clock eviction, far-first then clean-first.** Frames are recycled
  by a clock (second-chance) sweep. Every frame records the NUMA
  socket it was *filled from* (the PMem slot's home-socket tag, or the
  SSD arena's region home); under pressure the sweep prefers far-filled
  frames, then clean ones — far-clean → near-clean → far-dirty →
  near-dirty, pin/ref rules unchanged. A dirty victim is not flushed
  synchronously but *parked* in the flush queue's pending set (still
  DRAM, still coalescing), where the next epoch drain picks it up —
  eviction never adds a durability point. On a single-socket pool every
  fill is near and the sweep is bit-identical to the socket-blind
  clock (``numa_evict=False`` restores that order for A/B).
* **Remote fills are charged the Izraelevitz read rung.** A fill whose
  source tier lives on a far socket crosses the interconnect; the
  counts land in ``CacheStats.remote_fills`` / ``remote_fill_bytes``
  and both ``readpath_time_ns`` and ``engine_time_ns(cache=…)`` add the
  ``numa_remote_block_mult`` surcharge (arXiv:1903.05714). Zero remote
  fills add exactly 0.0 — an all-near run is bit-identical to the
  pre-NUMA model.
* **2Q scan resistance inside an owner's quota.** Frames enter a
  *probationary* segment and graduate to *protected* on re-reference
  (Götze arXiv:2001.02172). For a quota'd owner whose probationary
  frames have reached ``scan_frac`` of the quota, the quota sweep
  recycles probationary frames only — one sequential scan cycles the
  probationary fraction of that owner's budget and leaves its
  re-referenced hot set resident. ``scan_frac=1.0`` (the default)
  disables the split; the knob is fixed at ``pool.cache(scan_frac=)``
  construction like ``admit_k`` and can be overridden per owner
  (:meth:`BufferManager.set_scan_frac` — the serve layer's per-tenant
  handle).
* **Pin/unpin.** A pinned frame is never clock-evicted, and the spill
  scheduler treats pinned pages as protected during ``ensure_slots``,
  so a spill epoch cannot evict the PMem slot of a page whose frame is
  mid-flush (:meth:`writeback` pins the batch for the epoch).
* **k-touch admission.** SSD→PMem promotion is no longer
  first-access: a spilled page is served *from DRAM* (the frame) until
  it has been touched ``admit_k`` times, and only then CoW-promoted
  into a PMem slot. Scans stop churning the slot budget; genuinely hot
  pages still end up in PMem. The policy is also registered as the
  spill scheduler's ``admission`` hook so direct
  :meth:`~repro.tier.spill.SpillScheduler.read_page` callers inherit
  it. Write faults never promote (the fill is about to be superseded by
  a flush-queue CoW anyway).

One manager fronts one pool (``pool.cache(frames=, admit_k=)``, cached
like ``pool.placer()``); page regions register with
:meth:`attach_pages` and share the frame pool, keyed by region name —
the same multi-store shape as :class:`~repro.tier.spill.SpillScheduler`.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.costmodel import (
    COST_MODEL,
    SSD_COST_MODEL,
    PMemCostModel,
    SSDCostModel,
)

__all__ = ["BufferManager", "CacheStats"]


@dataclasses.dataclass
class CacheStats:
    """Per-tier read-path counts. All fields are monotonic counters.

    ``costmodel.PMemCostModel.readpath_time_ns`` converts a delta of
    these into modeled nanoseconds on the Fig. 3 ladder; DRAM-hit terms
    also fold into ``engine_time_ns(..., cache=delta)``.
    """

    #: reads served from a DRAM frame (or the flush queue's pending set)
    dram_hits: int = 0
    dram_hit_bytes: int = 0
    #: frame fills from a PMem page slot (uncached device reads)
    pmem_fills: int = 0
    pmem_fill_bytes: int = 0
    #: frame fills from the SSD spill tier (checksum-verified map reads)
    ssd_fills: int = 0
    ssd_fill_bytes: int = 0
    #: fills (PMem or SSD) whose source tier is homed on a far NUMA
    #: socket — a subset of pmem_fills/ssd_fills; the cost models add
    #: the Izraelevitz remote read surcharge for exactly these
    remote_fills: int = 0
    remote_fill_bytes: int = 0
    #: fresh pages materialized as zero frames (resident in no tier yet)
    fresh_pages: int = 0
    #: SSD→PMem promotions the k-touch policy admitted
    promotions: int = 0
    #: SSD reads served without promotion (below the admission threshold)
    admissions_deferred: int = 0
    #: clean frames recycled by the clock sweep
    evictions_clean: int = 0
    #: dirty frames parked in the flush queue by the clock sweep
    evictions_dirty: int = 0
    #: installs that overshot an owner's quota because every one of that
    #: owner's frames was pinned (the best-effort escape hatch of
    #: :meth:`BufferManager.set_quota`) — the serve layer's isolation
    #: claims are auditable against this
    quota_overflows: int = 0
    #: dirty frames pushed through a write-back epoch
    writebacks: int = 0

    def snapshot(self) -> "CacheStats":
        """A frozen copy of the current counters (windowing, like
        :meth:`PMemStats.snapshot <repro.core.pmem.PMemStats.snapshot>`)."""
        return dataclasses.replace(self)

    def delta(self, since: "CacheStats") -> "CacheStats":
        """Counts accrued since a :meth:`snapshot`."""
        d = CacheStats()
        for f in dataclasses.fields(CacheStats):
            setattr(d, f.name,
                    getattr(self, f.name) - getattr(since, f.name))
        return d

    @property
    def accesses(self) -> int:
        """Total read-path accesses that touched any tier."""
        return (self.dram_hits + self.pmem_fills + self.ssd_fills
                + self.fresh_pages)

    @property
    def hit_ratio(self) -> float:
        """Fraction of read-path accesses served at DRAM latency."""
        total = self.accesses
        return self.dram_hits / total if total else 0.0


class _Frame:
    """One DRAM frame: a page image plus its cache state."""

    __slots__ = ("owner", "pid", "data", "dirty", "pins", "ref",
                 "socket", "protected")

    def __init__(self, owner: str, pid: int, data: np.ndarray,
                 socket: int = 0) -> None:
        self.owner = owner
        self.pid = pid
        self.data = data
        #: dirty line set (empty = clean; ``None`` = every line dirty),
        #: same convention as :meth:`FlushQueue.enqueue`
        self.dirty: Optional[Set[int]] = set()
        self.pins = 0
        self.ref = False
        #: NUMA socket the frame was filled from (the source tier's
        #: home-socket tag; DRAM-born content — writes, restores,
        #: fresh pages — carries the cache's local socket)
        self.socket = int(socket)
        #: 2Q segment: frames install probationary and graduate on
        #: re-reference; a quota'd owner's scan recycles only its
        #: probationary fraction (see ``scan_frac``)
        self.protected = False

    @property
    def is_dirty(self) -> bool:
        return self.dirty is None or bool(self.dirty)


class BufferManager:
    """Bounded DRAM frame pool fronting the three-tier page read path."""

    def __init__(self, pool=None, *, frames: int = 64, admit_k: int = 2,
                 scan_frac: float = 1.0, local_socket: int = 0,
                 cost_model: PMemCostModel = COST_MODEL,
                 ssd_cost: SSDCostModel = SSD_COST_MODEL) -> None:
        """Create a manager with ``frames`` DRAM frames.

        Args:
            pool: the :class:`repro.pool.Pool` this cache fronts (held
                for introspection only; all I/O goes through registered
                stores and their flush queues).
            frames: frame-pool capacity in pages. ``0`` disables
                caching entirely — every read fills from its resident
                tier and every write routes straight into the flush
                queue's pending set; admission counting still runs, so
                promotion behavior is identical to a warm cache.
            admit_k: touches before an SSD-resident page is promoted
                into a PMem slot (1 = the legacy promote-on-first-access).
            scan_frac: probationary fraction of a quota'd owner's frame
                budget (2Q scan resistance). Once an owner's
                probationary frames reach ``scan_frac`` of its quota,
                the quota sweep recycles probationary frames only, so
                one sequential scan cycles that fraction of the budget
                and leaves the re-referenced (protected) hot set
                resident. ``1.0`` disables the split (the legacy
                clean-first quota sweep). Overridable per owner via
                :meth:`set_scan_frac`.
            local_socket: the NUMA socket the cache's consumers fault
                from; fills sourced from a region homed elsewhere count
                as ``remote_fills`` and pay the Izraelevitz read
                surcharge. Single-socket pools leave this at 0.
            cost_model: converts :class:`CacheStats` deltas and PMem op
                counts to modeled time.
            ssd_cost: flash constants for the SSD rungs of the ladder.
        """
        self.pool = pool
        self.capacity = max(0, int(frames))
        self.admit_k = max(1, int(admit_k))
        if not 0.0 < float(scan_frac) <= 1.0:
            raise ValueError("scan_frac must be in (0, 1]")
        self.scan_frac = float(scan_frac)
        self.local_socket = int(local_socket)
        #: socket-aware eviction order (far-clean → near-clean →
        #: far-dirty → near-dirty). ``False`` restores the socket-blind
        #: clean-first clock — the A/B knob ``benchmarks/readpath.py``
        #: uses to price what far-first eviction recovers.
        self.numa_evict = True
        self.cost_model = cost_model
        self.ssd_cost = ssd_cost
        self.stats = CacheStats()
        #: per-owner (region-name) CacheStats — every counter bump on
        #: ``stats`` is mirrored here under the owner that caused it
        #: (eviction counters attribute to the *victim* frame's owner),
        #: so ``sum(stats_by_owner.values())`` == ``stats`` field-wise.
        #: The serve layer reads these for per-tenant hit ratios.
        self.stats_by_owner: Dict[str, CacheStats] = {}
        self._frames: Dict[Tuple[str, int], _Frame] = {}
        self._ring: List[Tuple[str, int]] = []     # clock order
        self._hand = 0
        #: dirty keys in first-dirtied order — the write-back enqueue
        #: order, which matches the order a frameless (frames=0) run
        #: inserts the same pages into the flush queue
        self._dirty_order: Dict[Tuple[str, int], None] = {}
        self._stores: Dict[str, object] = {}
        self._owner_by_id: Dict[int, str] = {}
        self._fq: Dict[str, object] = {}
        self._spill: Dict[str, object] = {}
        #: touches per (owner, pid) — the k-touch admission counter
        self._touches: Dict[Tuple[str, int], int] = {}
        #: resident-frame count per owner (quota bookkeeping)
        self._owner_frames: Dict[str, int] = {}
        #: opt-in per-owner frame ceilings (absent = share freely)
        self._quota: Dict[str, int] = {}
        #: per-owner scan_frac overrides (absent = the cache-wide value)
        self._scan_frac: Dict[str, float] = {}

    # ------------------------------------------------------------- wiring

    @staticmethod
    def for_pool(pool, *, frames: Optional[int] = None,
                 admit_k: Optional[int] = None,
                 scan_frac: Optional[float] = None,
                 default_frames: Optional[int] = None,
                 default_admit_k: Optional[int] = None,
                 default_scan_frac: Optional[float] = None
                 ) -> "BufferManager":
        """Consumer-side get-or-create for ``pool.cache`` distinguishing
        *explicit* configuration from *defaults*: an explicit ``frames``
        / ``admit_k`` / ``scan_frac`` is verified against a pre-existing
        pool cache (a conflict raises, per :meth:`repro.pool.Pool.cache`);
        ``None`` reuses an existing cache quietly, and only on a
        cache-less pool falls back to ``default_frames`` /
        ``default_admit_k`` / ``default_scan_frac`` (e.g. PersistentKV's
        one-frame-per-page buffer pool)."""
        if pool._cache is None:
            return pool.cache(
                frames=frames if frames is not None else default_frames,
                admit_k=admit_k if admit_k is not None else default_admit_k,
                scan_frac=(scan_frac if scan_frac is not None
                           else default_scan_frac))
        return pool.cache(frames=frames, admit_k=admit_k,
                          scan_frac=scan_frac)

    def attach_pages(self, pages, *, flushq=None, spill=None,
                     name: Optional[str] = None) -> None:
        """Register a page region (:class:`~repro.pool.PagesHandle` or a
        bare :class:`~repro.core.pageflush.PageStore` with ``name=``) as
        a consumer of the frame pool.

        ``flushq`` is the region's :class:`~repro.io.flushq.FlushQueue`
        — the only path dirty frames take to PMem (one is created with
        defaults if omitted). ``spill`` is the region's
        :class:`~repro.tier.spill.SpillScheduler`, if tiered; the cache
        registers its k-touch policy as the scheduler's ``admission``
        hook and its pinned set as the ``pin_guard``, and resets a
        page's touch count when its slot is evicted."""
        store = getattr(pages, "store", pages)
        owner = name if name is not None else getattr(pages, "name", None)
        if owner is None:
            raise ValueError("attach_pages needs a PagesHandle or an "
                             "explicit name= for a bare PageStore")
        if flushq is None:
            from repro.io.flushq import FlushQueue
            flushq = FlushQueue(store, spill=spill)
        if spill is None:
            spill = flushq.spill
        self._stores[owner] = store
        self._owner_by_id[id(store)] = owner
        self._fq[owner] = flushq
        self._spill[owner] = spill
        if spill is not None:
            spill.admission = self._admit
            spill.pin_guard = self._is_pinned
            spill.on_page_evict = self._on_slot_evicted

    def _resolve(self, store) -> Tuple[str, object]:
        if store is None:
            if len(self._stores) != 1:
                raise ValueError(
                    "this cache fronts multiple page regions; pass store=")
            owner = next(iter(self._stores))
            return owner, self._stores[owner]
        st = getattr(store, "store", store)
        try:
            owner = self._owner_by_id[id(st)]
        except KeyError:
            raise ValueError(
                "page store is not registered with this cache; call "
                "attach_pages(handle) first") from None
        return owner, st

    # ------------------------------------------------------- accounting

    def _acct(self, owner: str, field: str, n: int = 1) -> None:
        """Bump one :class:`CacheStats` counter globally *and* under the
        owner it is attributed to (the accessed region for hits/fills,
        the victim frame's region for evictions)."""
        setattr(self.stats, field, getattr(self.stats, field) + n)
        per = self.stats_by_owner.get(owner)
        if per is None:
            per = self.stats_by_owner[owner] = CacheStats()
        setattr(per, field, getattr(per, field) + n)

    def owner_stats(self, owner: str) -> CacheStats:
        """The live :class:`CacheStats` attributed to one region owner
        (created on first request, so callers may ``snapshot()`` it
        before the first access). Owners are region names — the serve
        layer keys tenants by their KV's pages region."""
        per = self.stats_by_owner.get(owner)
        if per is None:
            per = self.stats_by_owner[owner] = CacheStats()
        return per

    def frames_of(self, owner: str) -> int:
        """Resident-frame count currently held by one region owner."""
        return self._owner_frames.get(owner, 0)

    def set_quota(self, owner: str, frames: Optional[int]) -> None:
        """Cap one owner's resident frames (``None`` lifts the cap).

        The cap is enforced at install time: a new frame for an
        at-quota owner first clock-evicts one of *that owner's* frames
        (clean-first, pin/ref rules as usual) instead of stealing from
        the shared pool — the cache-isolation half of per-tenant
        quotas. Best-effort: if every one of the owner's frames is
        pinned, the install overflows the cap rather than failing
        (pins are transient — epoch drains — so the overshoot is too).
        Quotas are volatile policy, like frames themselves: they never
        change what a crash recovers."""
        if frames is None:
            self._quota.pop(owner, None)
            return
        if frames < 0:
            raise ValueError("quota must be >= 0 frames")
        self._quota[owner] = int(frames)

    def quota(self, owner: str) -> Optional[int]:
        """The owner's frame cap, or ``None`` if uncapped."""
        return self._quota.get(owner)

    def set_scan_frac(self, owner: str, frac: Optional[float]) -> None:
        """Override one owner's probationary fraction (``None`` reverts
        to the cache-wide ``scan_frac``). Only meaningful together with
        a quota (the 2Q split sizes against the owner's budget); the
        serve layer exposes it per tenant
        (:meth:`ServeFrontend.set_cache_scan_frac
        <repro.serve.frontend.ServeFrontend.set_cache_scan_frac>`)."""
        if frac is None:
            self._scan_frac.pop(owner, None)
            return
        if not 0.0 < float(frac) <= 1.0:
            raise ValueError("scan_frac must be in (0, 1]")
        self._scan_frac[owner] = float(frac)

    def scan_frac_of(self, owner: str) -> float:
        """The probationary fraction in force for one owner."""
        return self._scan_frac.get(owner, self.scan_frac)

    # -------------------------------------------------------- admission

    def _admit(self, owner: str, pid: int) -> bool:
        """The spill scheduler's ``admission`` hook: promote only once a
        page has been touched ``admit_k`` times."""
        return self._touches.get((owner, int(pid)), 0) >= self.admit_k

    def _is_pinned(self, owner: str, pid: int) -> bool:
        f = self._frames.get((owner, int(pid)))
        return f is not None and f.pins > 0

    def _on_slot_evicted(self, owner: str, pid: int) -> None:
        """A page's PMem slot left for SSD: restart its admission count
        (re-promotion must be re-earned) — the DRAM frame, if any, stays
        valid (frames cache *content*, tiers own durability)."""
        self._touches.pop((owner, int(pid)), None)

    def touches(self, pid: int, store=None) -> int:
        """Current admission-touch count for a page."""
        owner, _ = self._resolve(store)
        return self._touches.get((owner, int(pid)), 0)

    def _note_touch(self, key: Tuple[str, int], spill, store) -> None:
        self._touches[key] = self._touches.get(key, 0) + 1
        if spill is not None:
            spill.touch(key[1], store)

    # ------------------------------------------------------- frame pool

    def _install(self, key: Tuple[str, int], data: np.ndarray,
                 socket: Optional[int] = None) -> _Frame:
        """Install a page image as a frame (probationary — it graduates
        to protected on re-reference). An at-quota owner first evicts
        one of its *own* frames (see :meth:`set_quota`); the shared
        pool clock-evicts only when globally full. ``socket`` is the
        fill-source socket tag (``None`` = DRAM-born content, tagged
        local)."""
        assert self.capacity > 0
        owner = key[0]
        q = self._quota.get(owner)
        if q is not None and self._owner_frames.get(owner, 0) >= q:
            # best-effort: every frame of this owner may be pinned — the
            # install then overflows the cap (pins are transient), but
            # audibly: quota_overflows is the serve layer's isolation
            # escape-hatch counter
            if not self._evict_frame(owner_only=owner):
                self._acct(owner, "quota_overflows")
        if len(self._frames) >= self.capacity:
            self._evict_frame()
        f = _Frame(owner, key[1], data,
                   socket=self.local_socket if socket is None else socket)
        self._frames[key] = f
        self._ring.append(key)
        self._owner_frames[owner] = self._owner_frames.get(owner, 0) + 1
        return f

    def _probation_due(self, owner: str) -> bool:
        """Whether the owner's probationary segment has reached its
        ``scan_frac`` share of the quota — the quota sweep then recycles
        probationary frames only (2Q: a scan cycles inside its own
        fraction instead of churning the protected hot set)."""
        q = self._quota.get(owner)
        if q is None or q <= 0:
            return False
        cap = max(1, int(self.scan_frac_of(owner) * q))
        if cap >= q:
            return False          # scan_frac=1.0: the split is off
        nprob = sum(1 for k, f in self._frames.items()
                    if k[0] == owner and not f.protected)
        return nprob >= cap

    def _evict_frame(self, owner_only: Optional[str] = None) -> bool:
        """Evict one frame. ``owner_only`` restricts the sweep to one
        owner's frames (quota enforcement; other owners' ref bits are
        left untouched) and returns ``False`` instead of raising when
        every candidate is pinned. A quota'd owner whose probationary
        segment is full recycles probationary frames first (2Q)."""
        if owner_only is not None and self._probation_due(owner_only):
            if self._sweep(owner_only, probation_only=True):
                return True
        if self._sweep(owner_only):
            return True
        if owner_only is not None:
            return False
        raise RuntimeError(
            f"buffer manager: all {self.capacity} frames are pinned")

    def _sweep(self, owner_only: Optional[str] = None, *,
               probation_only: bool = False) -> bool:
        """Clock sweep in far-first, clean-first preference order:
        far-clean → near-clean → far-dirty → near-dirty (far = the
        frame's fill socket differs from ``local_socket``). Pinned and
        referenced frames are skipped (ref bits cleared on the pass that
        considers them); a dirty victim parks its image in the flush
        queue. With no far-filled frames — every single-socket pool —
        the far passes are no-ops and the sweep is bit-identical to the
        socket-blind clean-first clock (as it is with
        ``numa_evict=False``)."""
        local = self.local_socket
        has_far = self.numa_evict and any(
            f.socket != local for f in self._frames.values())
        for require_clean, require_far in ((True, True), (True, False),
                                           (False, True), (False, False)):
            if require_far and not has_far:
                continue
            swept = 0
            limit = 2 * len(self._ring)   # ref bits all clear after one lap
            while self._ring and swept < limit:
                if self._hand >= len(self._ring):
                    self._hand = 0
                key = self._ring[self._hand]
                f = self._frames[key]
                # candidacy filters leave ref bits alone — a pass that
                # cannot take a frame must not spend its second chance
                if ((owner_only is not None and key[0] != owner_only)
                        or (probation_only and f.protected)
                        or (require_far and f.socket == local)):
                    self._hand += 1
                    swept += 1
                    continue
                if f.pins > 0:
                    self._hand += 1
                    swept += 1
                    continue
                if f.ref:
                    f.ref = False
                    self._hand += 1
                    swept += 1
                    continue
                if require_clean and f.is_dirty:
                    self._hand += 1
                    swept += 1
                    continue
                self._drop_frame(key, park_dirty=True)
                return True
        return False

    def _drop_frame(self, key: Tuple[str, int], *, park_dirty: bool) -> None:
        f = self._frames.pop(key)
        idx = self._ring.index(key)
        del self._ring[idx]
        if idx < self._hand:
            self._hand -= 1
        self._owner_frames[key[0]] -= 1
        if f.is_dirty:
            self._dirty_order.pop(key, None)
            if park_dirty:
                # park in the flush queue's (DRAM) pending set: the next
                # epoch drain flushes it — no new durability point here
                lines = None if f.dirty is None else sorted(f.dirty)
                self._fq[key[0]].enqueue(key[1], f.data, lines,
                                         copy=False, touch=False)
                self._acct(key[0], "evictions_dirty")
        else:
            self._acct(key[0], "evictions_clean")

    def _mark_dirty(self, key: Tuple[str, int], f: _Frame,
                    dirty_lines: Optional[Sequence[int]]) -> None:
        was_clean = not f.is_dirty
        if dirty_lines is None or f.dirty is None:
            f.dirty = None
        else:
            f.dirty.update(int(i) for i in dirty_lines)
        if was_clean and f.is_dirty:
            self._dirty_order[key] = None

    # ---------------------------------------------------------- tiers

    def _residency(self, owner: str, store, pid: int) -> Optional[str]:
        """Which tier holds the page's current version: ``"pmem"``,
        ``"ssd"``, or ``None`` (never flushed)."""
        sp = self._spill[owner]
        if sp is not None:
            return sp.residency(store, pid)
        return "pmem" if pid in store.table else None

    def _fill(self, owner: str, store, pid: int, *,
              for_write: bool) -> Tuple[np.ndarray, int]:
        """Read the page from its resident tier (the frame-fill path).
        Returns ``(data, socket)`` — the source tier's home socket tags
        the frame and, when it differs from ``local_socket``, the fill
        counts as remote (the Izraelevitz read surcharge).

        Never promotes: read faults had their admission decision taken by
        :meth:`_promote_if_due` before the fill (so an SSD fill here is by
        definition below the threshold), and write faults never promote —
        the fill is about to be superseded by a flush-queue CoW."""
        sp = self._spill[owner]
        tier = self._residency(owner, store, pid)
        if tier == "pmem":
            data, _pvn = store.fill_page(pid)
            slot, _ = store.table[pid]
            sock = store.pmem.home_socket(store.layout.slot_off(slot))
            self._acct(owner, "pmem_fills")
            self._acct(owner, "pmem_fill_bytes", data.size)
            if sock != self.local_socket:
                self._acct(owner, "remote_fills")
                self._acct(owner, "remote_fill_bytes", data.size)
            return data, sock
        if tier == "ssd":
            data = np.asarray(sp.read_page(store, pid, promote=False))
            sock = sp.fill_socket(store, pid)
            self._acct(owner, "ssd_fills")
            self._acct(owner, "ssd_fill_bytes", data.size)
            if not for_write:
                self._acct(owner, "admissions_deferred")
            if sock != self.local_socket:
                self._acct(owner, "remote_fills")
                self._acct(owner, "remote_fill_bytes", data.size)
            return data, sock
        if pid < 0 or pid >= store.layout.npages:
            raise KeyError(pid)
        self._acct(owner, "fresh_pages")
        return (np.zeros(store.layout.page_size, dtype=np.uint8),
                self.local_socket)

    def _promote_if_due(self, owner: str, store, pid: int) -> None:
        """k-touch admission is a property of the *access stream*, not of
        frame residency: a DRAM hit on an SSD-resident page that crosses
        the threshold still promotes (identical PMem/SSD op sequence to
        a frameless run — the crash-parity invariant)."""
        sp = self._spill[owner]
        if sp is None or not self._admit(owner, pid):
            return
        if self._residency(owner, store, pid) == "ssd":
            sp.read_page(store, pid, promote=True)
            self._acct(owner, "promotions")

    # ------------------------------------------------------------ reads

    def get(self, pid: int, store=None, *, pin: bool = False) -> np.ndarray:
        """Read a page wherever it lives; returns a copy of its newest
        content (frame → flush-queue pending → resident tier, in that
        order). Counts the touch for LRU + admission; ``pin=True``
        additionally pins the frame (no-op at ``frames=0``)."""
        owner, store = self._resolve(store)
        pid = int(pid)
        key = (owner, pid)
        self._note_touch(key, self._spill[owner], store)
        self._promote_if_due(owner, store, pid)
        f = self._frames.get(key)
        if f is not None:
            f.ref = True
            f.protected = True   # 2Q: re-reference graduates the frame
            self._acct(owner, "dram_hits")
            self._acct(owner, "dram_hit_bytes", f.data.size)
            if pin:
                f.pins += 1
            return np.array(f.data, copy=True)
        pend = self._fq[owner].pending_image(pid)
        if pend is not None:
            if pin and self.capacity > 0:
                # the pin contract needs a frame (clock immunity + the
                # spill guard): re-adopt the parked image, dirty set intact
                f = self._adopt_or_install(owner, (owner, pid))
                f.ref = True
                f.pins += 1
                self._acct(owner, "dram_hits")
                self._acct(owner, "dram_hit_bytes", f.data.size)
                return np.array(f.data, copy=True)
            # parked by a dirty eviction (or frames=0 write): the queue's
            # pending set is DRAM — serve it as a hit, leave it queued
            self._acct(owner, "dram_hits")
            self._acct(owner, "dram_hit_bytes", pend[0].size)
            return np.array(pend[0], copy=True)
        data, sock = self._fill(owner, store, pid, for_write=False)
        if self.capacity == 0:
            return np.array(data, copy=True)
        f = self._install(key, np.array(data, copy=True), socket=sock)
        if pin:
            f.pins += 1
        return np.array(f.data, copy=True)

    def peek(self, pid: int, store=None) -> Optional[np.ndarray]:
        """The page's frame content, or ``None`` if not framed. No touch,
        no fill, no stats — the checkpoint manager's snapshot read."""
        owner, _ = self._resolve(store)
        f = self._frames.get((owner, int(pid)))
        return None if f is None else f.data

    # ----------------------------------------------------------- writes

    def put(self, pid: int, page: np.ndarray,
            dirty_lines: Optional[Sequence[int]] = None,
            store=None) -> None:
        """Write a full page image (``dirty_lines`` annotates which lines
        changed; ``None`` = all). Dirty data stays in DRAM — a frame, or
        the flush queue's pending set at ``frames=0`` — until the next
        write-back epoch, exactly like direct ``FlushQueue.enqueue``."""
        owner, store = self._resolve(store)
        pid = int(pid)
        key = (owner, pid)
        page = np.asarray(page, dtype=np.uint8).ravel()
        if page.size != store.layout.page_size:
            raise ValueError("page size mismatch")
        self._note_touch(key, self._spill[owner], store)
        if self.capacity == 0:
            self._fq[owner].enqueue(pid, page, dirty_lines, touch=False)
            return
        f = self._frames.get(key)
        if f is None:
            # a full image supersedes any parked pending copy ("latest
            # image wins", like the queue's own coalescing) — only the
            # parked dirty set carries over; no tier fill needed
            parked = self._fq[owner].pop_pending(pid)
            f = self._install(key, np.array(page, copy=True))
            if parked is not None:
                self._mark_dirty(key, f, None if parked[1] is None
                                 else sorted(parked[1]))
        else:
            f.data[:] = page
            f.protected = True   # 2Q: re-reference graduates the frame
        f.ref = True
        self._mark_dirty(key, f, dirty_lines)

    def write(self, pid: int, off: int, data: bytes, store=None) -> None:
        """Read-modify-write ``len(data)`` bytes at a page offset (the
        KV engine's put path). Faults the rest of the page in from its
        resident tier if needed (write faults never promote); the
        covered cache lines are marked dirty."""
        owner, store = self._resolve(store)
        pid = int(pid)
        key = (owner, pid)
        buf = np.frombuffer(bytes(data), dtype=np.uint8)
        cl = store.layout.geometry.cache_line
        if off < 0 or off + buf.size > store.layout.page_size:
            raise ValueError("write outside page")
        lines = range(off // cl, (off + buf.size - 1) // cl + 1) \
            if buf.size else range(0)
        self._note_touch(key, self._spill[owner], store)
        if self.capacity == 0:
            fq = self._fq[owner]
            pend = fq.pending_image(pid)
            if pend is not None:
                img = pend[0]
                img[off : off + buf.size] = buf
                fq.enqueue(pid, img, list(lines), copy=False, touch=False)
                return
            img = np.array(
                self._fill(owner, store, pid, for_write=True)[0], copy=True)
            img[off : off + buf.size] = buf
            fq.enqueue(pid, img, list(lines), copy=False, touch=False)
            return
        f = self._frames.get(key)
        if f is None:
            f = self._adopt_or_install(owner, key)
        else:
            f.protected = True   # 2Q: re-reference graduates the frame
        f.data[off : off + buf.size] = buf
        f.ref = True
        self._mark_dirty(key, f, list(lines))

    def _adopt_or_install(self, owner: str, key: Tuple[str, int]) -> _Frame:
        """Frame a page whose current content must be preserved (partial
        writes, pins): re-adopt a parked pending image from the flush
        queue (its dirty set carries over), else fill from the resident
        tier (a write-style fault: never promotes)."""
        fq = self._fq[owner]
        store = self._stores[owner]
        parked = fq.pop_pending(key[1])
        if parked is not None:
            img, dirty = parked
            f = self._install(key, np.array(img, copy=True))
            self._mark_dirty(key, f,
                             None if dirty is None else sorted(dirty))
            return f
        data, sock = self._fill(owner, store, key[1], for_write=True)
        return self._install(key, np.array(data, copy=True), socket=sock)

    # ------------------------------------------------------ pin / unpin

    def pin(self, pid: int, store=None) -> None:
        """Pin a page's frame: immune to clock eviction, and its PMem
        slot is protected from spill eviction for the duration (the
        mid-flush guard). Faults the page in if unframed. No-op at
        ``frames=0``."""
        if self.capacity == 0:
            return
        owner, store = self._resolve(store)
        key = (owner, int(pid))
        f = self._frames.get(key)
        if f is None:
            self.get(pid, store, pin=True)
            return
        f.pins += 1

    def unpin(self, pid: int, store=None) -> None:
        """Release one pin."""
        if self.capacity == 0:
            return
        owner, _ = self._resolve(store)
        f = self._frames.get((owner, int(pid)))
        if f is None or f.pins <= 0:
            raise ValueError(f"page {pid} is not pinned")
        f.pins -= 1

    # -------------------------------------------------------- write-back

    def dirty_pages(self, store=None) -> List[int]:
        """Pids with un-flushed frame content, in first-dirtied order."""
        owner, _ = self._resolve(store)
        pids = [k[1] for k in self._dirty_order if k[0] == owner]
        fq = self._fq[owner]
        pids += [p for p in fq.pending_pids() if p not in set(pids)]
        return pids

    def writeback(self, store=None):
        """Drain every dirty frame through the region's flush queue in
        one lane-partitioned epoch (frames are pinned for the duration,
        so the epoch's own spill evictions cannot touch them). Frames
        stay resident and become clean — the next save's snapshots.
        Returns the :class:`~repro.io.flushq.EpochReport`."""
        owner, _ = self._resolve(store)
        fq = self._fq[owner]
        keys = [k for k in self._dirty_order if k[0] == owner]
        pinned = []
        for key in keys:
            f = self._frames[key]
            f.pins += 1
            pinned.append(f)
            lines = None if f.dirty is None else sorted(f.dirty)
            # copy=False: the frame is pinned and nothing mutates it
            # between enqueue and the drain below — aliasing avoids a
            # second full copy of the epoch's page set (the spike the
            # queue's copy= knob exists to prevent)
            fq.enqueue(key[1], f.data, lines, copy=False, touch=False)
            self._acct(key[0], "writebacks")
        try:
            report = fq.flush_epoch()
        finally:
            for f in pinned:
                f.pins -= 1
        for key in keys:
            f = self._frames.get(key)
            if f is not None:
                f.dirty = set()
            self._dirty_order.pop(key, None)
        return report

    def invalidate(self, store=None) -> None:
        """Drop every DRAM image of a region — frames (and their dirty
        marking) *and* parked pending images in the flush queue — for
        restore paths that rewrite the page table out from under the
        cache. A surviving parked image would be flushed by the next
        epoch drain, resurrecting pre-restore bytes over the restored
        pages. Refuses to run while any of the region's frames is
        pinned (like :meth:`drop`): discarding a pinned frame would
        break the pin contract mid-epoch. Admission touch counts
        survive: they describe the access stream, not frame residency."""
        owner, _ = self._resolve(store)
        keys = [k for k in self._frames if k[0] == owner]
        pinned = [k[1] for k in keys if self._frames[k].pins > 0]
        if pinned:
            raise ValueError(
                f"cannot invalidate {owner!r}: pages {pinned} are pinned")
        for key in keys:
            self._frames.pop(key)
            idx = self._ring.index(key)
            del self._ring[idx]
            if idx < self._hand:
                self._hand -= 1
            self._dirty_order.pop(key, None)
            self._owner_frames[owner] -= 1
        fq = self._fq[owner]
        for pid in list(fq.pending_pids()):
            fq.pop_pending(pid)

    def drop(self, pid: int, store=None) -> None:
        """Discard one page's DRAM state without flushing it: its frame
        (clean *or* dirty) and any image parked in the flush queue's
        pending set. This is the cross-shard invalidation primitive
        (repro.cluster): by the time a range's source engine drops a
        page, the new owner already holds its content durably, so the
        dirty bytes die here on purpose. Unlike :meth:`invalidate` the
        admission touch count resets too — the page's access history
        moved with it. No-op if the page is unframed and unparked;
        refuses pinned frames."""
        owner, _ = self._resolve(store)
        key = (owner, int(pid))
        f = self._frames.get(key)
        if f is not None:
            if f.pins > 0:
                raise ValueError(f"page {pid} is pinned")
            self._frames.pop(key)
            idx = self._ring.index(key)
            del self._ring[idx]
            if idx < self._hand:
                self._hand -= 1
            self._dirty_order.pop(key, None)
            self._owner_frames[owner] -= 1
        self._fq[owner].pop_pending(int(pid))
        self._touches.pop(key, None)

    def install(self, pid: int, page: np.ndarray, store=None) -> None:
        """Install a *clean* frame holding ``page`` (restore/adopt paths
        seeding snapshots). No touch, no dirty marking. Supersedes any
        image parked in the flush queue's pending set, like :meth:`put`
        — a restore's content must win over a pre-restore parked copy,
        at ``frames=0`` too."""
        owner, store = self._resolve(store)
        page = np.asarray(page, dtype=np.uint8).ravel()
        if page.size != store.layout.page_size:
            raise ValueError("page size mismatch")
        key = (owner, int(pid))
        self._fq[owner].pop_pending(int(pid))
        if self.capacity == 0:
            return
        f = self._frames.get(key)
        if f is None:
            f = self._install(key, np.array(page, copy=True))
        else:
            f.data[:] = page
            f.dirty = set()
            self._dirty_order.pop(key, None)

    # ---------------------------------------------------------- metrics

    @property
    def frames_in_use(self) -> int:
        """Resident frames across all registered regions."""
        return len(self._frames)

    def modeled_read_ns(self, delta: Optional[CacheStats] = None) -> float:
        """Modeled read-path time of a :class:`CacheStats` delta (the
        whole window since construction when omitted) on the Fig. 3
        ladder — DRAM hits at DRAM latency/bandwidth, PMem fills at the
        3.2× rung, SSD fills per the flash model. Promotion *write*
        traffic is charged where it executes (PMem lane stats / SSD
        stats), not here."""
        return self.cost_model.readpath_time_ns(
            delta if delta is not None else self.stats, ssd=self.ssd_cost)
