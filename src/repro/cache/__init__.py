"""``repro.cache`` — the DRAM buffer manager over the three-tier read path.

The paper's Fig. 3 ladder (DRAM ≪ PMem ≪ flash) says *where data is
cached* dominates read cost; Wu et al. (arXiv:2005.07658) confirm it
end-to-end for Optane DBMSs. This package adds the ladder's top rung to
the stack:

- :class:`BufferManager` — a bounded pool of DRAM frames fronting a
  pool's page regions: clock (second-chance) eviction preferring clean
  frames, dirty-frame write-back routed through the owning
  :class:`~repro.io.flushq.FlushQueue` (durability semantics
  unchanged), pin/unpin so a spill epoch can never evict a frame
  mid-flush, and a k-touch admission policy replacing the spill tier's
  promote-on-first-access.
- :class:`CacheStats` — exact per-tier hit/miss counts, converted to
  modeled time by ``costmodel.PMemCostModel.readpath_time_ns`` (and
  folded into ``engine_time_ns(..., cache=...)``) with the DRAM
  constants of the Fig. 3 ladder.

Construct one per pool with ``pool.cache(frames=, admit_k=)`` (cached,
like ``pool.placer()``) and register page regions with
:meth:`BufferManager.attach_pages`. The cache is volatile by
construction: crash recovery is bit-identical with the cache enabled,
disabled, or sized to zero (``tests/test_crash_corpus.py``).
"""

from repro.cache.bufmgr import BufferManager, CacheStats  # noqa: F401
