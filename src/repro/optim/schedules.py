"""Learning-rate schedules."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup: int = 100, total: int = 10_000,
                  floor: float = 0.1):
    """Multiplier in [floor, 1]: linear warmup then cosine decay."""
    step = jnp.asarray(step, dtype=jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return warm * cos
