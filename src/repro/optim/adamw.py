"""AdamW with global-norm clipping — mixed precision (bf16 params, f32
moments), sharding-transparent (moments inherit parameter specs)."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, dtype=jnp.float32)  # noqa: E731
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), dtype=jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads, opt_state, params, cfg: AdamWConfig, lr_scale: jax.Array | float = 1.0
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m2 / (1 - cfg.b1 ** count)
        vhat = v2 / (1 - cfg.b2 ** count)
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - cfg.lr * lr_scale * step
        return p2.astype(p.dtype), m2, v2

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "clip_scale": scale}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
