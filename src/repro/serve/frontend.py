"""Request scheduler + admission controller — the serving frontend.

One :class:`ServeFrontend` owns one engine per tenant: a
:class:`~repro.core.recovery.PersistentKV` (its own WAL lanes, flush
queue, page region) on a shared pool, all fronted by the pool's one
:class:`~repro.cache.BufferManager`. Lanes are per-tenant hardware —
each tenant's batches execute on its own KV's lanes and overlap in
modeled time with other tenants' batches (the engine clock is
max-over-lanes, not a global serializer). What tenants *share* is the
DRAM frame pool — which is why cache quotas, not scheduling, are the
isolation lever.

The serving loop is a discrete-event simulation on the modeled clock:

1. **Arrivals** (from :mod:`repro.serve.workload`) are admitted or
   shed the moment they arrive, per tenant: estimated wait = time
   until the tenant's lanes free up + its queued ops × an EWMA of its
   per-op service time; if that exceeds the SLO's queue budget the
   request is rejected *before* touching the engine (its WAL never
   sees it — recovery-wise a shed request never happened).
2. **Batching** reuses the WAL's adaptive group-commit state: a
   tenant's admit-batch budget is the sum of its WAL's
   :meth:`MultiLog.lane_k` targets — when the placer has grown a
   lane's group commit under sustained load, the frontend admits
   bigger batches to match (one ``commit()`` per batch); when
   latency-bound traffic has shrunk them, batches follow.
3. **Service time** is fully modeled: the exact PMem/SSD/cache op
   deltas the batch executed, priced by ``engine_time_ns`` (+ the SSD
   model when tiered). Every request in a batch completes when its
   batch does; latency = completion − arrival
   (:mod:`repro.serve.latency`). Batches across tenants execute in
   start-time order (ties broken by tenant position), so cache state
   — and therefore every counter — is bit-stable across runs.

Crash semantics: the frontend adds no durability points of its own.
Everything flows through ``PersistentKV.put`` / the WAL's group
commit, so a crash mid-batch recovers exactly the committed prefix —
admitted-but-uncommitted requests recover as if they had been shed
(asserted by the serve cases in ``tests/test_crash_corpus.py``). The
optional ``failpoints`` hook (the corpus' ``CrashAt`` protocol) fires
at ``req_applied`` / ``batch_commit`` points to make that testable.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

from repro.core.costmodel import COST_MODEL, SSD_COST_MODEL
from repro.serve.latency import LatencyRecorder, LatencySummary
from repro.serve.workload import Request, TenantSpec

__all__ = ["SLOConfig", "ServeFrontend", "ServeReport"]


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """The serving contract the admission controller enforces.

    ``queue_budget_us`` is the wait the controller will knowingly book
    a request into before shedding it instead; it defaults to the p99
    target (a request admitted into a longer queue would already have
    blown the tail on arrival)."""

    #: tail-latency objective, µs of modeled time (reported against
    #: the p99 of served requests)
    p99_target_us: float = 500.0
    #: max estimated wait a request may be queued behind (None → the
    #: p99 target)
    queue_budget_us: Optional[float] = None

    @property
    def queue_budget_ns(self) -> float:
        """The shed threshold in ns (see class docstring)."""
        budget = (self.queue_budget_us if self.queue_budget_us is not None
                  else self.p99_target_us)
        return budget * 1000.0


@dataclasses.dataclass(frozen=True)
class ServeReport:
    """Everything one :meth:`ServeFrontend.run` produced."""

    #: per-request digests: overall and per tenant
    overall: LatencySummary
    by_tenant: Dict[str, LatencySummary]
    #: the recorder itself (histograms, raw latency lists)
    recorder: LatencyRecorder
    #: requests served / shed
    served: int
    shed: int
    #: summed per-tenant lane busy time, and end-to-end makespan
    #: (modeled ns; tenants overlap, so busy can exceed makespan)
    busy_ns: float
    makespan_ns: float
    #: batches executed and ops applied (scan = scan_len ops)
    batches: int
    ops: int
    #: per-tenant DRAM hit ratio over the run (buffer-manager per-owner
    #: accounting)
    hit_ratio: Dict[str, float]

    @property
    def throughput_rps(self) -> float:
        """Served requests per second of modeled time."""
        return self.served / (self.makespan_ns / 1e9) if self.makespan_ns \
            else 0.0


class _Tenant:
    """Frontend-side runtime state for one tenant: its engine, queue,
    lane busy-horizon, and per-op service estimate."""

    __slots__ = ("spec", "kv", "queue", "free_ns", "ewma_ns",
                 "applied", "committed")

    def __init__(self, spec: TenantSpec, kv) -> None:
        self.spec = spec
        self.kv = kv
        self.queue: Deque[Request] = deque()
        #: this tenant's lanes are busy until here (modeled ns)
        self.free_ns = 0.0
        #: EWMA per-op service estimate (None until the first batch)
        self.ewma_ns: Optional[float] = None
        #: puts applied / puts known committed (crash bookkeeping)
        self.applied = 0
        self.committed = 0


def _put_value(value_size: int, tenant: str, key: int, vseed: int) -> bytes:
    """The deterministic value a put request writes: unique per
    ``(tenant, key, vseed)``, so tests can recognize exactly which
    request's write is (or is not) present after a crash."""
    raw = f"{tenant}:{key}:{vseed}:".encode()
    reps = -(-value_size // len(raw))
    return (raw * reps)[:value_size]


class ServeFrontend:
    """Admission-controlled, batch-scheduled serving over per-tenant
    :class:`~repro.core.recovery.PersistentKV` engines (module doc)."""

    #: EWMA weight of the newest per-op service observation
    _EWMA_ALPHA = 0.2

    def __init__(self, pool, tenants: Sequence[TenantSpec], kv_cfg, *,
                 slo: Optional[SLOConfig] = None,
                 admission: bool = True,
                 min_batch: int = 1,
                 failpoints: Optional[Callable[[str], None]] = None,
                 record_applied: bool = False) -> None:
        """Build one KV engine per tenant on ``pool`` (all sharing the
        pool's cache and, if tiered, its SSD).

        Args:
            pool: the :class:`repro.pool.Pool` hosting every tenant.
            tenants: traffic specs; ``spec.name`` becomes the KV name.
            kv_cfg: one :class:`~repro.core.recovery.KVConfig` shared by
                every tenant (``nkeys`` bounds the workload key space).
            slo: serving contract (default :class:`SLOConfig`).
            admission: ``False`` disables shedding — every arrival
                queues, however deep the backlog (the open-loop
                collapse mode the benchmarks contrast against).
            min_batch: admit-batch floor before ``lane_k`` feedback.
            failpoints: crash-corpus hook, called with protocol-point
                names (``req_applied`` / ``batch_commit``).
            record_applied: keep ``(tenant, key, value)`` for every put
                applied, in order (crash-corpus bookkeeping).
        """
        self.pool = pool
        self.slo = slo if slo is not None else SLOConfig()
        self.admission = bool(admission)
        self.min_batch = max(1, int(min_batch))
        self.failpoints = failpoints
        self.record_applied = bool(record_applied)
        self.applied_puts: List[Tuple[str, int, bytes]] = []
        self.kv_cfg = kv_cfg
        self._tenants: Dict[str, _Tenant] = {}
        self._order: List[str] = []
        for spec in tenants:
            kv = pool.kv(spec.name, kv_cfg)
            self._tenants[spec.name] = _Tenant(spec, kv)
            self._order.append(spec.name)
        self.cache = pool.cache()

    # ---------------------------------------------------------- plumbing

    def kv(self, tenant: str):
        """The tenant's :class:`~repro.core.recovery.PersistentKV`."""
        return self._tenants[tenant].kv

    def cache_owner(self, tenant: str) -> str:
        """The buffer-manager owner key of a tenant's pages region."""
        return f"{tenant}.pages"

    def set_cache_quota(self, tenant: str, frames: Optional[int]) -> None:
        """Cap a tenant's resident DRAM frames
        (:meth:`repro.cache.BufferManager.set_quota` on its pages
        region; ``None`` lifts the cap)."""
        self.cache.set_quota(self.cache_owner(tenant), frames)

    def set_cache_scan_frac(self, tenant: str, frac: Optional[float]) -> None:
        """Override one tenant's 2Q probationary fraction
        (:meth:`repro.cache.BufferManager.set_scan_frac` on its pages
        region; ``None`` reverts to the pool cache's ``scan_frac``).
        Only meaningful with a :meth:`set_cache_quota` cap — the split
        sizes against the tenant's budget. A scan-heavy tenant set to
        e.g. ``0.25`` cycles a quarter of its quota instead of churning
        its own hot set."""
        self.cache.set_scan_frac(self.cache_owner(tenant), frac)

    def committed_puts(self, tenant: str) -> int:
        """Puts of this tenant known durably committed (advanced after
        each of its batches' WAL commit — a crash-corpus lower bound on
        what must recover)."""
        return self._tenants[tenant].committed

    def lane_k_budget(self, tenant: str) -> int:
        """The tenant's adaptive admit-batch budget: its WAL's summed
        per-lane group-commit targets (:meth:`MultiLog.lane_k`) — the
        public surface of the ``LanePlacer`` signals. A single-lane WAL
        (no ``lane_k``) counts its static ``group_commit``; floored at
        ``min_batch``."""
        wal = self._tenants[tenant].kv.wal
        lane_k = getattr(wal, "lane_k", None)
        if lane_k is not None:
            total = sum(lane_k())
        else:
            total = int(getattr(wal, "group_commit", 1) or 1)
        return max(self.min_batch, total)

    def _fp(self, point: str) -> None:
        if self.failpoints is not None:
            self.failpoints(point)

    # --------------------------------------------------------- admission

    @staticmethod
    def _req_ops(r: Request) -> int:
        return r.scan_len if r.op == "scan" else 1

    def _should_shed(self, t: _Tenant, r: Request) -> bool:
        """Per-tenant backlog rule (module doc): estimated wait behind
        the tenant's own queue vs the SLO's queue budget."""
        if not self.admission or t.ewma_ns is None:
            return False          # no service estimate yet: admit
        wait = max(0.0, t.free_ns - r.arrival_ns)
        wait += sum(self._req_ops(q) for q in t.queue) * t.ewma_ns
        return wait > self.slo.queue_budget_ns

    def _admit(self, r: Request, rec: LatencyRecorder) -> None:
        t = self._tenants[r.tenant]
        if self._should_shed(t, r):
            rec.shed(r.tenant)
        else:
            t.queue.append(r)

    # ----------------------------------------------------------- serving

    def _apply(self, t: _Tenant, r: Request) -> int:
        """Execute one request against its tenant's engine; returns the
        op count it contributed (scan = ``scan_len``)."""
        kv = t.kv
        if r.op == "get":
            kv.get(r.key)
            ops = 1
        elif r.op == "put":
            value = _put_value(self.kv_cfg.value_size, r.tenant, r.key,
                               r.vseed)
            kv.put(r.key, value)
            t.applied += 1
            if self.record_applied:
                self.applied_puts.append((r.tenant, r.key, value))
            ops = 1
        elif r.op == "scan":
            stop = min(r.key + r.scan_len, self.kv_cfg.nkeys)
            for k in range(r.key, stop):
                kv.get(k)
            ops = max(1, stop - r.key)
        else:
            raise ValueError(f"unknown op {r.op!r}")
        self._fp("req_applied")
        return ops

    def _execute(self, t: _Tenant, start_ns: float
                 ) -> Tuple[float, List[Request], int]:
        """Drain one admit batch from the tenant's queue at ``start_ns``
        on its own lanes: apply, commit its WAL once, price the exact op
        deltas. Returns ``(done_ns, batch, ops)``."""
        pool = self.pool
        pm0 = pool.stats.snapshot()
        c0 = self.cache.stats.snapshot()
        ssd = pool.ssd_dev
        ssd0 = ssd.stats.snapshot() if ssd is not None else None
        budget = self.lane_k_budget(t.spec.name)
        batch: List[Request] = []
        ops = 0
        had_put = False
        while t.queue and len(batch) < budget:
            r = t.queue.popleft()
            batch.append(r)
            ops += self._apply(t, r)
            had_put = had_put or r.op == "put"
        if had_put:
            commit = getattr(t.kv.wal, "commit", None)
            if commit is not None:
                commit()     # single-lane Logs are durable at append
        self._fp("batch_commit")
        t.committed = t.applied
        service = COST_MODEL.engine_time_ns(
            pool.stats.delta(pm0), cache=self.cache.stats.delta(c0))
        if ssd is not None:
            service += SSD_COST_MODEL.time_ns(ssd.stats.delta(ssd0))
        per_op = service / max(1, ops)
        if t.ewma_ns is None:
            t.ewma_ns = per_op
        else:
            t.ewma_ns += self._EWMA_ALPHA * (per_op - t.ewma_ns)
        done = start_ns + service
        t.free_ns = done
        return done, batch, ops

    def run(self, requests: Sequence[Request]) -> ServeReport:
        """Serve an arrival-ordered request list to completion;
        discrete-event on the modeled clock (module doc). Deterministic:
        same requests + same engine state → bit-identical report."""
        rec = LatencyRecorder()
        hit0 = {name: self.cache.owner_stats(
                    self.cache_owner(name)).snapshot()
                for name in self._order}
        busy = 0.0
        batches = 0
        total_ops = 0
        served = 0
        i, n = 0, len(requests)
        while True:
            # earliest batch start over tenants with queued work
            # (tie → tenant position: deterministic cache interleaving)
            cand: Optional[Tuple[float, int]] = None
            for ti, name in enumerate(self._order):
                t = self._tenants[name]
                if not t.queue:
                    continue
                s = max(t.free_ns, float(t.queue[0].arrival_ns))
                if cand is None or s < cand[0]:
                    cand = (s, ti)
            next_arr = requests[i].arrival_ns if i < n else None
            if cand is None:
                if next_arr is None:
                    break
                self._admit(requests[i], rec)
                i += 1
                continue
            if next_arr is not None and next_arr <= cand[0]:
                # the arrival happens before any lane frees: admission
                # decisions observe the queue as of their arrival time
                self._admit(requests[i], rec)
                i += 1
                continue
            t = self._tenants[self._order[cand[1]]]
            done, batch, ops = self._execute(t, cand[0])
            busy += done - cand[0]
            batches += 1
            total_ops += ops
            served += len(batch)
            for r in batch:
                rec.record(r.tenant, r.arrival_ns, int(done))
        hits = {}
        for name in self._order:
            d = self.cache.owner_stats(self.cache_owner(name)).delta(
                hit0[name])
            hits[name] = d.hit_ratio
        makespan = max((self._tenants[n].free_ns for n in self._order),
                      default=0.0)
        return ServeReport(
            overall=rec.summary(),
            by_tenant={name: rec.summary(name) for name in self._order},
            recorder=rec,
            served=served,
            shed=rec.shed_count(),
            busy_ns=busy,
            makespan_ns=makespan,
            batches=batches,
            ops=total_ops,
            hit_ratio=hits,
        )
