"""``repro.serve`` — multi-tenant request serving on the paper's engine.

The primitives below this layer (group-commit WAL lanes, batched block
flushes, the three-tier page cache) exist to serve *requests*; this
package is the request path that makes their interactions measurable:

* :mod:`repro.serve.workload` — an open-loop traffic generator:
  thousands of modeled concurrent clients per tenant, Zipf key
  popularity, Poisson arrivals with burst phases, fully deterministic
  from one seed.
* :mod:`repro.serve.frontend` — the scheduler/admission controller:
  batches arrivals into engine ops sized by the WAL's adaptive
  group-commit state (:meth:`MultiLog.lane_k`), sheds load per tenant
  when the modeled backlog would blow the SLO, and isolates tenants
  with per-owner cache quotas (:meth:`BufferManager.set_quota`).
* :mod:`repro.serve.latency` — per-request queueing-delay accounting:
  p50/p99/p999 derived from ``engine_time_ns`` (completion vs arrival
  on the modeled clock — open-loop, so overload shows up as tail
  collapse, not just lower throughput).
* :mod:`repro.serve.modelstate` — the "model-state serving" scenario:
  checkpoint shards of a ``repro.configs`` model paged through the
  DRAM/PMem/SSD tiers.

Like everything in the repo the clock is modeled: exact op counts ×
calibrated constants. Wall time measures nothing here.
"""

from repro.serve.frontend import ServeFrontend, ServeReport, SLOConfig
from repro.serve.latency import LatencyRecorder, LatencySummary, percentile_ns
from repro.serve.modelstate import ModelStateStore
from repro.serve.workload import Request, TenantSpec, generate

__all__ = [
    "ServeFrontend",
    "ServeReport",
    "SLOConfig",
    "LatencyRecorder",
    "LatencySummary",
    "percentile_ns",
    "ModelStateStore",
    "Request",
    "TenantSpec",
    "generate",
]
