"""Open-loop traffic generation — the offered load half of serving.

Open loop means arrival times are fixed by the generator, not by the
server: a client does not wait for its previous request to complete
before issuing the next one (that would be closed-loop, which
self-throttles and hides tail collapse). Under open-loop load an
overloaded server's queue grows without bound and p99 explodes — the
effect the admission controller (:mod:`repro.serve.frontend`) exists to
contain, and the one closed-loop benchmarks structurally cannot see.

Every stream is deterministic from ``(seed, tenant index)`` via
``np.random.SeedSequence`` — two runs with the same specs and seed
produce bit-identical request lists, which the serve benchmarks assert.

Shapes modeled per tenant (:class:`TenantSpec`):

* **Clients** — a population of modeled concurrent clients; each
  request is issued by one of them (round-trips are not serialized per
  client: open loop).
* **Zipf key popularity** — ranks drawn Zipf(``zipf_s``) and mapped
  through a per-tenant key permutation, so tenants disagree about
  which keys are hot.
* **Poisson arrivals with bursts** — exponential inter-arrivals at
  ``rate``; during a burst window (every ``burst_every_s`` seconds for
  ``burst_len_s``) the instantaneous rate is multiplied by
  ``burst_x``.
* **Op mix** — get/put/scan fractions; a scan touches ``scan_len``
  consecutive keys.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

__all__ = ["TenantSpec", "Request", "generate"]

#: modeled-clock resolution: arrivals are integer nanoseconds
_NS = 1_000_000_000


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One tenant's traffic shape (see module docstring)."""

    #: tenant name; also the KV engine name the frontend creates for it
    #: (keep it short — region names cap at 20 bytes and the engine
    #: derives ``<name>.pages`` / ``<name>.wal`` / ``<name>.root``)
    name: str
    #: modeled concurrent client population
    clients: int = 100
    #: mean request rate, requests/second of modeled time
    rate: float = 10_000.0
    #: op mix — fractions must sum to 1
    get_frac: float = 0.8
    put_frac: float = 0.2
    scan_frac: float = 0.0
    #: Zipf skew for key popularity (values <= 1.0 mean uniform)
    zipf_s: float = 1.2
    #: keys touched by one scan request
    scan_len: int = 8
    #: burst phase: every ``burst_every_s`` seconds the arrival rate is
    #: multiplied by ``burst_x`` for ``burst_len_s`` seconds (0 = none)
    burst_every_s: float = 0.0
    burst_len_s: float = 0.0
    burst_x: float = 1.0

    def __post_init__(self) -> None:
        if abs(self.get_frac + self.put_frac + self.scan_frac - 1.0) > 1e-9:
            raise ValueError(
                f"tenant {self.name!r}: op fractions sum to "
                f"{self.get_frac + self.put_frac + self.scan_frac}, not 1")
        if self.clients < 1 or self.rate <= 0:
            raise ValueError(f"tenant {self.name!r}: need clients >= 1 "
                             f"and rate > 0")


@dataclasses.dataclass(frozen=True)
class Request:
    """One request of the offered load, fixed before serving starts."""

    #: global arrival-order id (assigned after the cross-tenant merge)
    rid: int
    tenant: str
    #: issuing client within the tenant's population
    client: int
    #: arrival on the modeled clock, ns
    arrival_ns: int
    #: ``"get"`` | ``"put"`` | ``"scan"``
    op: str
    key: int
    #: keys covered when ``op == "scan"`` (1 otherwise)
    scan_len: int
    #: deterministic seed for the put value (unique per request, so a
    #: shed request's value is recognizably absent from the store)
    vseed: int


def _tenant_stream(spec: TenantSpec, ti: int, nkeys: int,
                   duration_s: float, seed: int) -> List[Request]:
    """One tenant's arrival stream (rids are assigned later, globally)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, ti]))
    perm = rng.permutation(nkeys)
    out: List[Request] = []
    t = 0.0
    while True:
        rate = spec.rate
        if spec.burst_every_s > 0 and spec.burst_len_s > 0:
            if (t % spec.burst_every_s) < spec.burst_len_s:
                rate *= spec.burst_x
        t += float(rng.exponential(1.0 / rate))
        if t >= duration_s:
            break
        u = float(rng.random())
        if u < spec.get_frac:
            op, slen = "get", 1
        elif u < spec.get_frac + spec.put_frac:
            op, slen = "put", 1
        else:
            op, slen = "scan", spec.scan_len
        if spec.zipf_s > 1.0:
            rank = min(int(rng.zipf(spec.zipf_s)) - 1, nkeys - 1)
        else:
            rank = int(rng.integers(0, nkeys))
        key = int(perm[rank])
        if op == "scan":
            key = min(key, max(0, nkeys - slen))
        out.append(Request(
            rid=-1,
            tenant=spec.name,
            client=int(rng.integers(0, spec.clients)),
            arrival_ns=int(round(t * _NS)),
            op=op,
            key=key,
            scan_len=slen,
            vseed=int(rng.integers(0, 1 << 31)),
        ))
    return out


def generate(tenants: Sequence[TenantSpec], *, nkeys: int,
             duration_s: float, seed: int = 0,
             limit: Optional[int] = None) -> List[Request]:
    """The full offered load: every tenant's stream merged in arrival
    order (ties broken by tenant position, so the merge — and therefore
    every downstream percentile — is bit-stable across runs).

    ``limit`` truncates the merged list (benchmark smoke sizing).
    Returns requests with final ``rid`` values 0..n-1 in arrival order.
    """
    if nkeys < 1:
        raise ValueError("nkeys must be >= 1")
    names = [t.name for t in tenants]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate tenant names: {names}")
    merged: List[Request] = []
    for ti, spec in enumerate(tenants):
        merged.extend(_tenant_stream(spec, ti, nkeys, duration_s, seed))
    order = {t.name: i for i, t in enumerate(tenants)}
    merged.sort(key=lambda r: (r.arrival_ns, order[r.tenant], r.vseed))
    if limit is not None:
        merged = merged[:limit]
    return [dataclasses.replace(r, rid=i) for i, r in enumerate(merged)]
