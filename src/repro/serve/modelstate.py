"""Model-state serving: checkpoint shards paged through the tiers.

The ROADMAP's paged-KV exemplar scenario: the parameters of one
``repro.configs`` model, laid out shard-by-shard (embedding table +
one shard per transformer layer) over a *tiered* page region whose
PMem slot budget holds only a fraction of the pages — the rest live on
the SSD spill tier and fault in through the shared DRAM buffer
manager on access. A serving process that pages model state (adapter
swaps, expert offload, cold checkpoint restore) sees exactly this
stack: DRAM hit ≪ PMem fill ≪ SSD fill, with k-touch admission
deciding which shards earn PMem residency.

Shard sizes are *analytic* — ``ModelConfig.param_count()`` at
``bytes_per_param`` (bf16 = 2) split into an embedding shard
(``vocab_size × d_model`` params) plus equal per-layer shards — so no
tensor framework is imported; page contents are deterministic from
``(seed, pid)`` and verifiable after any crash/spill/promotion
history.
"""

from __future__ import annotations

from typing import List, Tuple, Union

import numpy as np

__all__ = ["ModelStateStore"]

_MAP_CAPACITY = 1 << 17


class ModelStateStore:
    """Shard-addressed paged storage for one model's parameters.

    Shard 0 is the embedding table; shards ``1..num_layers`` are the
    transformer layers. Each shard occupies a contiguous page run of
    the region; :meth:`read_shard` faults its pages through the pool's
    shared cache (so repeated reads of a hot shard hit DRAM, cold
    shards pay the SSD rung — the serving latency ladder)."""

    def __init__(self, pool, config: Union[str, object], *,
                 name: str = "ms", page_size: int = 4096,
                 slot_frac: float = 0.25, bytes_per_param: int = 2,
                 seed: int = 0, flush_lanes: int = 4) -> None:
        """Lay out + populate the shard pages on ``pool``.

        Args:
            pool: host pool; must have an SSD attached when
                ``slot_frac < 1`` (the spill tier backs the overcommit).
            config: a :class:`~repro.models.config.ModelConfig` or a
                name resolved via ``repro.configs.get_reduced``.
            name: region-name prefix (keep short; 20-byte cap).
            page_size: bytes per page.
            slot_frac: fraction of pages that get PMem slots (the rest
                spill; 1.0 = untiered).
            bytes_per_param: checkpoint precision (2 = bf16).
            seed: page-content seed (deterministic, verifiable).
            flush_lanes: lanes of the populate write-back epochs.
        """
        if isinstance(config, str):
            from repro.configs import get_reduced
            config = get_reduced(config)
        self.config = config
        self.page_size = int(page_size)
        embed_params = config.vocab_size * config.d_model
        total_params = config.param_count()
        layer_params = max(0, total_params - embed_params)
        per_layer = layer_params // config.num_layers
        sizes = [embed_params * bytes_per_param]
        for li in range(config.num_layers):
            p = per_layer + (layer_params % config.num_layers
                             if li == config.num_layers - 1 else 0)
            sizes.append(p * bytes_per_param)
        #: (first_pid, npages) per shard, shard 0 = embedding
        self.shards: List[Tuple[int, int]] = []
        pid = 0
        for nbytes in sizes:
            npages = max(1, -(-nbytes // self.page_size))
            self.shards.append((pid, npages))
            pid += npages
        self.npages = pid
        self.nslots = max(1, int(round(self.npages * slot_frac)))
        self.tiered = self.nslots < self.npages
        self.seed = int(seed)
        self.name = name

        from repro.io.flushq import FlushQueue
        pages = pool.pages(f"{name}.pages", npages=self.npages,
                           page_size=self.page_size, nslots=self.nslots)
        self.store = pages.store
        self._spill = None
        if self.tiered:
            from repro.tier import SpillScheduler
            if pool.ssd_dev is None:
                raise ValueError(
                    f"model-state store {name!r}: slot_frac={slot_frac} "
                    f"overcommits {self.npages} pages onto {self.nslots} "
                    f"slots; attach a flash device first (pool.attach_ssd)")
            self._spill = SpillScheduler(pool, name=f"{name}.sp",
                                         map_capacity=_MAP_CAPACITY)
            self._spill.attach_pages(pages)
        self._fq = FlushQueue(self.store, lanes=flush_lanes,
                              spill=self._spill)
        self.cache = pool.cache()
        self.cache.attach_pages(pages, flushq=self._fq, spill=self._spill)
        self._populate()

    # ------------------------------------------------------------ layout

    @property
    def num_shards(self) -> int:
        """Embedding + one per layer."""
        return len(self.shards)

    def shard_pages(self, shard: int) -> range:
        """The contiguous pid run holding one shard."""
        first, npages = self.shards[shard]
        return range(first, first + npages)

    def page_content(self, pid: int) -> np.ndarray:
        """The expected (deterministic) content of one page — what
        :meth:`read_shard` must return no matter which tier served it."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, int(pid)]))
        return rng.integers(0, 256, self.page_size, dtype=np.uint8)

    # ---------------------------------------------------------- populate

    def _populate(self) -> None:
        """Write every page through the cache, draining a write-back
        epoch each slot-budget's worth so the populate never needs more
        than ``nslots`` dirty pages in flight; finish by spilling down
        to the slot budget and dropping the (now stale-ordered) frames
        — cold-start: shards fault back in on first access."""
        for pid in range(self.npages):
            self.cache.put(pid, self.page_content(pid), store=self.store)
            if (pid + 1) % self.nslots == 0:
                self.cache.writeback(self.store)
        self.cache.writeback(self.store)
        if self._spill is not None:
            self._spill.ensure_slots(self.store, need=self.nslots)
        self.cache.invalidate(self.store)

    # ------------------------------------------------------------- reads

    def read_shard(self, shard: int) -> np.ndarray:
        """Fault one shard's pages in through the cache and return the
        concatenated bytes (embedding or one layer's parameters)."""
        parts = [self.cache.get(pid, store=self.store)
                 for pid in self.shard_pages(shard)]
        return np.concatenate(parts)

    def verify_shard(self, shard: int) -> bool:
        """Bit-check one shard against its deterministic content."""
        for pid in self.shard_pages(shard):
            got = self.cache.get(pid, store=self.store)
            if not np.array_equal(got, self.page_content(pid)):
                return False
        return True

    def residency(self, pid: int):
        """Which tier holds a page now (``"pmem"``/``"ssd"``/None)."""
        if self._spill is not None:
            return self._spill.residency(self.store, pid)
        return "pmem" if pid in self.store.table else None
