"""Per-request latency accounting on the modeled clock.

A request's latency is ``completion_ns - arrival_ns`` where completion
comes from the frontend's modeled service loop: the engine's busy time
is ``engine_time_ns`` over the exact PMem/SSD/cache op counts the
request batch executed, and queueing delay is the gap between a
request's arrival and when the engine got around to its batch. That
makes the tail percentiles *queueing-theoretic* quantities — p999
reflects the convolution of burst arrivals with slow batches (spills,
checkpoints), not a throughput average.

Percentiles use the nearest-rank method on the sorted latency list
(``ceil(q * n)``-th value): integer selection, no interpolation — so a
given request trace maps to bit-identical p50/p99/p999 on every
platform, which the determinism checks in ``benchmarks/serve_load.py``
and ``tests/test_serve.py`` rely on.

Shed requests are recorded separately and excluded from the latency
distribution (they were never served; counting them as zero-latency
successes or as infinite-latency failures would each distort the tail
in a different direction — the shed *count* is its own SLO dimension).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["percentile_ns", "LatencySummary", "LatencyRecorder"]


def percentile_ns(sorted_ns: Sequence[float], q: float) -> float:
    """Nearest-rank percentile of an ascending-sorted sequence:
    the ``ceil(q*n)``-th smallest value (q in (0, 1]). Deterministic
    integer selection — no interpolation."""
    n = len(sorted_ns)
    if n == 0:
        return 0.0
    if not 0.0 < q <= 1.0:
        raise ValueError(f"q must be in (0, 1], got {q}")
    return float(sorted_ns[min(n - 1, max(0, math.ceil(q * n) - 1))])


@dataclasses.dataclass(frozen=True)
class LatencySummary:
    """The percentile digest of one (tenant's or the whole run's)
    latency distribution, in microseconds of modeled time."""

    count: int
    shed: int
    p50_us: float
    p99_us: float
    p999_us: float
    mean_us: float
    max_us: float

    @property
    def served_frac(self) -> float:
        """Fraction of offered requests actually served (1 - shed rate)."""
        total = self.count + self.shed
        return self.count / total if total else 1.0


class LatencyRecorder:
    """Accumulates per-request completions and sheds, keyed by tenant.

    The frontend calls :meth:`record` as batches complete on the
    modeled clock and :meth:`shed` for requests the admission
    controller rejected; consumers read :meth:`summary` /
    :meth:`histogram` afterwards."""

    def __init__(self) -> None:
        self._lat: Dict[str, List[int]] = {}
        self._shed: Dict[str, int] = {}

    # ------------------------------------------------------------ intake

    def record(self, tenant: str, arrival_ns: int,
               completion_ns: int) -> int:
        """Record one served request; returns its latency (ns)."""
        lat = int(completion_ns) - int(arrival_ns)
        if lat < 0:
            raise ValueError(
                f"completion {completion_ns} precedes arrival {arrival_ns}")
        self._lat.setdefault(tenant, []).append(lat)
        return lat

    def shed(self, tenant: str) -> None:
        """Count one admission-rejected request (never served)."""
        self._shed[tenant] = self._shed.get(tenant, 0) + 1

    # ----------------------------------------------------------- readout

    def tenants(self) -> List[str]:
        """Every tenant that recorded at least one completion or shed."""
        return sorted(set(self._lat) | set(self._shed))

    def latencies_ns(self, tenant: Optional[str] = None) -> List[int]:
        """Ascending-sorted latency list (one tenant, or the whole run)."""
        if tenant is not None:
            return sorted(self._lat.get(tenant, []))
        out: List[int] = []
        for lats in self._lat.values():
            out.extend(lats)
        return sorted(out)

    def shed_count(self, tenant: Optional[str] = None) -> int:
        """Requests the admission controller rejected."""
        if tenant is not None:
            return self._shed.get(tenant, 0)
        return sum(self._shed.values())

    def summary(self, tenant: Optional[str] = None) -> LatencySummary:
        """Percentile digest (one tenant, or the whole run)."""
        lats = self.latencies_ns(tenant)
        shed = self.shed_count(tenant)
        if not lats:
            return LatencySummary(0, shed, 0.0, 0.0, 0.0, 0.0, 0.0)
        return LatencySummary(
            count=len(lats),
            shed=shed,
            p50_us=percentile_ns(lats, 0.50) / 1000.0,
            p99_us=percentile_ns(lats, 0.99) / 1000.0,
            p999_us=percentile_ns(lats, 0.999) / 1000.0,
            mean_us=sum(lats) / len(lats) / 1000.0,
            max_us=lats[-1] / 1000.0,
        )

    def histogram(self, tenant: Optional[str] = None, *,
                  base_us: float = 1.0,
                  factor: float = 2.0) -> List[Tuple[float, int]]:
        """Log-spaced latency histogram: ``(upper_bound_us, count)``
        rows, buckets doubling (by ``factor``) from ``base_us``; the
        last bucket absorbs the tail. Intended for example scripts —
        percentiles come from :meth:`summary`, not from buckets."""
        lats = self.latencies_ns(tenant)
        if not lats:
            return []
        bounds = [base_us]
        while bounds[-1] * 1000.0 < lats[-1]:
            bounds.append(bounds[-1] * factor)
        counts = [0] * len(bounds)
        for lat in lats:
            us = lat / 1000.0
            for i, b in enumerate(bounds):
                if us <= b:
                    counts[i] += 1
                    break
        return list(zip(bounds, counts))
