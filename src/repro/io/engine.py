"""IOEngine — the facade tying lanes, group commit and accounting together.

One engine per pool. It hands out the two concurrent front ends —
:class:`~repro.io.multilog.MultiLog` (lane-striped group-commit logging)
and :class:`~repro.io.flushq.FlushQueue` (batched, lane-partitioned page
flushing) — with non-overlapping lane-id ranges, so per-lane counts from
different components never collide in :class:`~repro.core.pmem.PMemStats`,
and converts op-count deltas to modeled wall-clock with the lane-aware
``engine_time_ns`` (max-over-lanes + Fig. 2 concurrency curve + write-
combining-defeat penalty past ``wc_defeat_lanes``).

    pool = Pool.create(None, 1 << 24)
    eng  = IOEngine(pool, lanes=4, group_commit=8)
    wal  = eng.multilog("wal", capacity=1 << 20)      # 4 zero-log lanes
    for rec in records:
        wal.append(rec)                                # buffered
    wal.commit()                                       # ~lanes barriers total

    fq = eng.flush_queue(pool.pages("heap", npages=64, page_size=16384))
    for pid, page, dirty in updates:
        fq.enqueue(pid, page, dirty)                   # coalesces
    report = fq.flush_epoch()                          # lanes-aware hybrid
"""

from __future__ import annotations

from typing import Callable, Optional

from repro.core.costmodel import COST_MODEL, PMemCostModel
from repro.core.log import LogConfig
from repro.core.persist import AccessPattern, FlushKind
from repro.core.pmem import PMemStats
from repro.io.flushq import FlushQueue
from repro.io.multilog import DEFAULT_GROUP_COMMIT, MultiLog

__all__ = ["IOEngine"]


class IOEngine:
    """Lane-partitioned concurrent I/O engine over one pool."""

    def __init__(self, pool, *, lanes: int = 4,
                 group_commit: int = DEFAULT_GROUP_COMMIT,
                 cost_model: PMemCostModel = COST_MODEL,
                 placer=None) -> None:
        """One engine per pool: ``lanes`` and ``group_commit`` are the
        defaults handed to front ends; ``cost_model`` converts op-count
        deltas to modeled time. ``placer`` (a
        :class:`~repro.io.placer.LanePlacer`) is handed to every front
        end so lanes run near their regions' NUMA home sockets; it
        defaults to the pool's placer on a multi-socket pool."""
        if lanes < 1:
            raise ValueError("lanes must be >= 1")
        self.pool = pool
        self.lanes = int(lanes)
        self.group_commit = int(group_commit)
        self.cost_model = cost_model
        if placer is None and getattr(pool, "sockets", 1) > 1:
            placer = pool.placer()
        self.placer = placer
        self._next_lane_id = 0

    def _alloc_lane_ids(self, n: int) -> int:
        base = self._next_lane_id
        self._next_lane_id += n
        return base

    # ---------------------------------------------------------- front ends

    def multilog(self, name: str, capacity: Optional[int] = None, *,
                 technique: Optional[str] = None,
                 lanes: Optional[int] = None,
                 group_commit: Optional[int] = None,
                 cfg: Optional[LogConfig] = None,
                 gen_sets: int = 1) -> MultiLog:
        """Open-or-create a lane-striped group-commit log (defaults to the
        engine's lane/group-commit configuration). ``gen_sets >= 2``
        creates it generational — sealable/rollable, with sealed
        generations retirable to the SSD tier."""
        n = lanes if lanes is not None else self.lanes
        ml = MultiLog(self.pool, name, lanes=n if capacity is not None else lanes,
                      capacity=capacity, technique=technique,
                      group_commit=group_commit if group_commit is not None
                      else self.group_commit,
                      cfg=cfg, lane_id_base=0, gen_sets=gen_sets,
                      placer=self.placer)
        ml.lane_id_base = self._alloc_lane_ids(ml.lanes)
        return ml

    def flush_queue(self, pages, *, lanes: Optional[int] = None,
                    flush_fn: Optional[Callable[..., Optional[str]]] = None,
                    spill=None) -> FlushQueue:
        """A batched flush queue over a pages handle / page store; pass
        ``spill=`` (a :class:`repro.tier.SpillScheduler`) to let epochs
        overflow cold slots to the SSD tier instead of raising."""
        n = lanes if lanes is not None else self.lanes
        return FlushQueue(pages, lanes=n,
                          lane_id_base=self._alloc_lane_ids(n),
                          flush_fn=flush_fn, cost_model=self.cost_model,
                          spill=spill, placer=self.placer)

    def spill_scheduler(self, ssd=None, *, name: str = "spill", **kw):
        """The pool's :class:`repro.tier.SpillScheduler` — the engine's
        third front end, feeding the SSD capacity tier at epoch
        boundaries. ``ssd`` attaches a device if the pool has none yet;
        remaining keywords pass through (watermarks, arena sizing)."""
        from repro.tier import SpillScheduler
        return SpillScheduler(self.pool, ssd, name=name, **kw)

    def cache(self, frames: Optional[int] = None,
              admit_k: Optional[int] = None):
        """The pool's DRAM :class:`~repro.cache.BufferManager`
        (``pool.cache``) — the engine's top rung: page reads served from
        bounded DRAM frames, dirty frames written back through this
        engine's flush-queue epochs, SSD→PMem promotion gated by
        k-touch admission."""
        return self.pool.cache(frames=frames, admit_k=admit_k)

    # ---------------------------------------------------------- accounting

    def modeled_ns(self, delta: PMemStats, *,
                   active_lanes: Optional[int] = None,
                   kind: FlushKind = FlushKind.NT,
                   pattern: AccessPattern = AccessPattern.SEQUENTIAL,
                   burst: bool = False, cache=None) -> float:
        """Lane-aware modeled wall-clock for an op-count delta; ``cache``
        (a :class:`~repro.cache.CacheStats` delta) folds DRAM buffer
        hits into the same clock."""
        return self.cost_model.engine_time_ns(
            delta, active_lanes=active_lanes, kind=kind, pattern=pattern,
            burst=burst, cache=cache)
