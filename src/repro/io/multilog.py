"""Lane-partitioned log with group commit — the engine's write-side core.

A :class:`MultiLog` stripes appends across N per-lane logs (each one a
Zero/Classic/Header log in its own pool region ``<name>.lane<i>``) and
assigns every entry a *global* LSN at submit time. Appends are buffered
per lane and committed in batches of ``group_commit`` entries, so the
technique's persistency barriers are amortized over the whole batch:
Zero logging pays ONE barrier per k entries instead of one per entry.
Lane work runs under :meth:`repro.core.pmem.PMem.lane`, so per-lane
barrier/line/block counts land in :class:`~repro.core.pmem.PMemStats`
and ``costmodel.engine_time_ns`` can model the lanes as concurrent.

Durability contract: ``append()`` returns the entry's global LSN but the
entry is durable only after the next :meth:`commit` (or ``sync=True``,
or an automatic full-batch lane commit plus every *earlier* lane batch).
What recovery guarantees is a *consistent global prefix*: the recovered
entries are exactly global LSNs ``1..m`` for some ``m`` that covers at
least every entry committed before the crash.

Merge-on-recovery: each lane's own recovery yields a prefix of that
lane's entries (the per-technique validity argument). Global LSNs are
handed out round-robin, so within a lane they increase monotonically —
the global durable prefix is the longest run ``1..m`` present across
lanes, and everything beyond ``m`` (entries that became durable in one
lane while an *earlier* entry died with another lane's lost batch) is
discarded by durably re-zeroing each lane's tail back to its last kept
entry. Without that repair, re-appending after recovery would produce
two different entries carrying the same global LSN.
"""

from __future__ import annotations

import dataclasses
import struct
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.log import HeaderLog, LogConfig, RecoveredLog

__all__ = ["MultiLog", "MultiLogRecovered"]

_GLSN = struct.Struct("<Q")

#: default number of appends batched per lane commit
DEFAULT_GROUP_COMMIT = 8


@dataclasses.dataclass
class MultiLogRecovered:
    """What merge-on-recovery found: the consistent global-LSN prefix."""

    entries: List[bytes]
    glsns: List[int]
    next_glsn: int
    #: entries recovered per lane *before* the merge cut
    per_lane: List[int]
    #: durable entries discarded because an earlier global LSN was lost
    discarded: int


class MultiLog:
    """N-lane group-commit log over pool regions ``<name>.lane<i>``.

    Create by passing ``capacity`` (total bytes, split evenly over
    ``lanes``); reopen by name alone — the lane regions are discovered
    from the pool directory and merged recovery runs automatically.
    Region names are capped at 20 bytes, so ``name`` must leave room for
    the ``.lane<i>`` suffix.
    """

    def __init__(self, pool, name: str, *, lanes: Optional[int] = None,
                 capacity: Optional[int] = None,
                 technique: Optional[str] = None,
                 group_commit: int = DEFAULT_GROUP_COMMIT,
                 cfg: Optional[LogConfig] = None,
                 lane_id_base: int = 0) -> None:
        self.pool = pool
        self.name = name
        self.group_commit = max(1, int(group_commit))
        self.lane_id_base = int(lane_id_base)

        existing = 0
        while pool.directory.lookup(f"{name}.lane{existing}") is not None:
            existing += 1
        if existing:
            if lanes is not None and lanes != existing:
                raise ValueError(
                    f"multilog {name!r} has {existing} durable lanes, "
                    f"caller asked for {lanes}")
            self.lanes = existing
            self.handles = [pool.log(f"{name}.lane{i}", technique=technique,
                                     cfg=cfg)
                            for i in range(existing)]
        else:
            if capacity is None:
                raise ValueError(
                    f"creating multilog {name!r} requires capacity=")
            self.lanes = int(lanes) if lanes is not None else 2
            if self.lanes < 1:
                raise ValueError("lanes must be >= 1")
            per_lane = pool.geometry.pad_to_block(
                max(1, int(capacity) // self.lanes))
            # Fail BEFORE creating lane 0: a failure mid-loop would leak
            # durable lane regions (directory allocations are permanent),
            # leaving a partially-striped log behind.
            last_name = f"{name}.lane{self.lanes - 1}"
            if len(last_name.encode("utf-8")) > 20:
                raise ValueError(
                    f"multilog name {name!r} too long for {self.lanes} "
                    f"lanes ({last_name!r} exceeds the 20 B region-name cap)")
            if self.lanes * per_lane > pool.free_bytes:
                raise ValueError(
                    f"multilog {name!r}: {self.lanes} lanes x {per_lane} B "
                    f"exceed the pool's {pool.free_bytes} free bytes")
            self.handles = [
                pool.log(f"{name}.lane{i}", capacity=per_lane,
                         technique=technique or "zero", cfg=cfg)
                for i in range(self.lanes)
            ]
        self.technique = self.handles[0].technique
        self._pending: List[List[bytes]] = [[] for _ in range(self.lanes)]
        self._rr = 0
        self.recovered = self._merge_recovery()
        self._next_glsn = self.recovered.next_glsn

    # ------------------------------------------------------------ recovery

    @staticmethod
    def _global_prefix(per_lane_entries: List[List[bytes]]
                       ) -> Tuple[Dict[int, Tuple[int, bytes]], int]:
        """Decode each lane's framed entries and find the longest
        contiguous global-LSN prefix 1..m present across lanes. Returns
        (glsn -> (lane, payload), m). The single source of truth for the
        merge invariant — used by both open-time recovery and the
        read-only :meth:`recover` preview."""
        items: Dict[int, Tuple[int, bytes]] = {}
        for lane_i, entries in enumerate(per_lane_entries):
            for raw in entries:
                (glsn,) = _GLSN.unpack_from(raw)
                items[glsn] = (lane_i, bytes(raw[_GLSN.size:]))
        m = 0
        while (m + 1) in items:
            m += 1
        return items, m

    def _merge_recovery(self) -> MultiLogRecovered:
        per_lane = [h.recovered for h in self.handles]
        items, m = self._global_prefix([rec.entries for rec in per_lane])
        keep = [0] * self.lanes
        for g in range(1, m + 1):
            keep[items[g][0]] += 1
        discarded = 0
        for lane_i, (h, rec) in enumerate(zip(self.handles, per_lane)):
            extra = len(rec.entries) - keep[lane_i]
            if extra > 0:
                discarded += extra
                self._truncate_lane(h, rec, keep[lane_i])
        return MultiLogRecovered(
            entries=[items[g][1] for g in range(1, m + 1)],
            glsns=list(range(1, m + 1)),
            next_glsn=m + 1,
            per_lane=[len(r.entries) for r in per_lane],
            discarded=discarded,
        )

    def _truncate_lane(self, handle, rec: RecoveredLog, kept: int) -> None:
        """Durably re-zero a lane's tail beyond its ``kept``-entry prefix,
        and rewind the writer, so discarded global LSNs can be re-issued."""
        keep_end = rec.offsets[kept] if kept < len(rec.offsets) else rec.tail
        span = rec.tail - keep_end
        pm = self.pool.pmem
        if span > 0:
            pm.store(handle.base + keep_end, np.zeros(span, dtype=np.uint8),
                     streaming=True)
            pm.sfence()
        w = handle._writer
        w.tail = keep_end
        w.next_lsn = kept + 1
        if isinstance(w, HeaderLog):
            # a stale (larger) durable size slot is harmless: recovery
            # stops at the zeroed bytes regardless (n == 0 breaks the scan)
            w._size = keep_end - w._data_start()
        handle.recovered = RecoveredLog(
            rec.entries[:kept], rec.lsns[:kept], keep_end, kept + 1,
            rec.offsets[:kept])

    # -------------------------------------------------------------- append

    def append(self, payload: bytes, *, sync: bool = False) -> int:
        """Submit one entry; returns its global LSN immediately.

        The entry becomes durable at the next :meth:`commit` (``sync=True``
        issues one right away). A lane whose buffer reaches ``group_commit``
        entries commits that batch automatically."""
        glsn = self._next_glsn
        self._next_glsn += 1
        lane = self._rr
        self._rr = (self._rr + 1) % self.lanes
        self._pending[lane].append(_GLSN.pack(glsn) + payload)
        if sync:
            self.commit()
        elif len(self._pending[lane]) >= self.group_commit:
            self._commit_lane(lane)
        return glsn

    def _commit_lane(self, lane: int) -> None:
        batch = self._pending[lane]
        if not batch:
            return
        with self.pool.pmem.lane(self.lane_id_base + lane):
            self.handles[lane].append_batch(batch)
        self._pending[lane] = []

    def commit(self) -> None:
        """Group-commit every buffered entry on every lane. After this
        returns, all previously appended entries are durable."""
        for lane in range(self.lanes):
            self._commit_lane(lane)

    def close(self, *, commit: bool = True) -> None:
        if commit:
            self.commit()
        for h in self.handles:
            h.close()

    # --------------------------------------------------------------- misc

    @property
    def pending(self) -> int:
        return sum(len(b) for b in self._pending)

    @property
    def next_glsn(self) -> int:
        return self._next_glsn

    def recover(self) -> MultiLogRecovered:
        """Re-run merged recovery against the current durable image (what
        a restart would see right now). Read-only: no truncation repair —
        the returned prefix is what a fresh open would keep."""
        items, m = self._global_prefix(
            [h.recover().entries for h in self.handles])
        return MultiLogRecovered(
            entries=[items[g][1] for g in range(1, m + 1)],
            glsns=list(range(1, m + 1)),
            next_glsn=m + 1,
            per_lane=[],
            discarded=len(items) - m,
        )

    def stats(self):
        """Pool-wide op-count delta since the first lane handle opened."""
        return self.handles[0].stats()

    def reset_stats(self) -> None:
        for h in self.handles:
            h.reset_stats()
