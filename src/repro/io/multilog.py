"""Lane-partitioned log with group commit — the engine's write-side core.

A :class:`MultiLog` stripes appends across N per-lane logs (each one a
Zero/Classic/Header log in its own pool region ``<name>.lane<i>``) and
assigns every entry a *global* LSN at submit time. Appends are buffered
per lane and committed in batches of ``group_commit`` entries, so the
technique's persistency barriers are amortized over the whole batch:
Zero logging pays ONE barrier per k entries instead of one per entry.
Lane work runs under :meth:`repro.core.pmem.PMem.lane`, so per-lane
barrier/line/block counts land in :class:`~repro.core.pmem.PMemStats`
and ``costmodel.engine_time_ns`` can model the lanes as concurrent.

Durability contract: ``append()`` returns the entry's global LSN but the
entry is durable only after the next :meth:`commit` (or ``sync=True``,
or an automatic full-batch lane commit plus every *earlier* lane batch).
What recovery guarantees is a *consistent global prefix*: the recovered
entries are exactly global LSNs ``1..m`` for some ``m`` that covers at
least every entry committed before the crash.

Merge-on-recovery: each lane's own recovery yields a prefix of that
lane's entries (the per-technique validity argument). Global LSNs are
handed out round-robin, so within a lane they increase monotonically —
the global durable prefix is the longest run ``1..m`` present across
lanes, and everything beyond ``m`` (entries that became durable in one
lane while an *earlier* entry died with another lane's lost batch) is
discarded by durably re-zeroing each lane's tail back to its last kept
entry. Without that repair, re-appending after recovery would produce
two different entries carrying the same global LSN.

Generations (``gen_sets >= 2``): the log runs a *ring* of lane sets
(regions ``<name>.g<j>.lane<i>``) plus a ping-pong generation header
(``<name>.gen``). :meth:`MultiLog.roll` seals the current generation —
commit everything, then atomically advance the header to generation
``g+1``, whose lane set takes over with LSNs restarting at 1. Sealed
generations stay PMem-resident (readable, crash-recoverable) until a
:class:`repro.tier.SpillScheduler` retires them to SSD (or, with no
scheduler, until their ring slot is reused — plain truncation). The
header's ``retired_upto`` watermark is the single atomic source of
truth for *where* a generation lives: ``gen > retired_upto`` recovers
from PMem, ``gen <= retired_upto`` from SSD, never both — the
crash-during-spill property of ``tests/test_tier_props.py``.
"""

from __future__ import annotations

import dataclasses
import struct
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.log import HeaderLog, LogConfig, RecoveredLog

__all__ = ["MultiLog", "MultiLogRecovered"]

_GLSN = struct.Struct("<Q")
# generation header slot: counter, current_gen, retired_upto, gen_sets, lanes
_GENHDR = struct.Struct("<QQQII")

#: default number of appends batched per lane commit
DEFAULT_GROUP_COMMIT = 8


@dataclasses.dataclass
class MultiLogRecovered:
    """What merge-on-recovery found: the consistent global-LSN prefix."""

    entries: List[bytes]
    glsns: List[int]
    next_glsn: int
    #: entries recovered per lane *before* the merge cut
    per_lane: List[int]
    #: durable entries discarded because an earlier global LSN was lost
    discarded: int
    #: originating lane of each kept entry (parallel to ``entries``) —
    #: lets replay attribute each record's work to the lane that wrote
    #: it, so the cost model prices recovery at max-over-lanes instead
    #: of charging one serial stream
    lanes: List[int] = dataclasses.field(default_factory=list)


class MultiLog:
    """N-lane group-commit log over pool regions ``<name>.lane<i>``.

    Create by passing ``capacity`` (total bytes, split evenly over
    ``lanes``); reopen by name alone — the lane regions are discovered
    from the pool directory and merged recovery runs automatically.
    Region names are capped at 20 bytes, so ``name`` must leave room for
    the ``.lane<i>`` suffix.

    ``gen_sets >= 2`` makes the log *generational*: a ring of
    ``gen_sets`` lane sets (regions ``<name>.g<j>.lane<i>``, generation
    ``g`` living in slot ``(g-1) % gen_sets``) plus a ping-pong header
    region ``<name>.gen``. ``capacity`` is then *per generation*;
    :meth:`roll` seals the live generation and moves appends to the next
    set with LSNs restarting at 1, so a checkpoint-driven consumer (the
    KV redo log) can run indefinitely in ``gen_sets × capacity`` bytes
    of PMem. A generational log is reopened generational automatically.
    """

    def __init__(self, pool, name: str, *, lanes: Optional[int] = None,
                 capacity: Optional[int] = None,
                 technique: Optional[str] = None,
                 group_commit: int = DEFAULT_GROUP_COMMIT,
                 cfg: Optional[LogConfig] = None,
                 lane_id_base: int = 0,
                 gen_sets: int = 1,
                 lane_sockets: Optional[List[int]] = None,
                 lane_cpu_sockets: Optional[List[int]] = None,
                 placer=None) -> None:
        """Open-or-create the log.

        Args:
            pool: the :class:`repro.pool.Pool` holding the lane regions.
            name: base region name; lane regions are ``<name>.lane<i>``
                (or ``<name>.g<j>.lane<i>`` when generational).
            lanes: stripe width when creating (default 2); on reopen the
                durable directory decides and a conflicting value raises.
            capacity: total log bytes when creating (per generation for
                a generational log), split evenly over the lanes.
            technique: per-lane log technique when creating (default
                "zero"); on reopen the durable record decides.
            group_commit: appends buffered per lane before an automatic
                batch commit (1 = commit every append immediately). With
                a placer this is the *base* of per-lane adaptive sizes
                (see :attr:`lane_group_commit`).
            cfg: :class:`~repro.core.log.LogConfig` for the lanes.
            lane_id_base: first lane id used for per-lane stats
                attribution (the :class:`~repro.io.IOEngine` hands out
                non-overlapping ranges).
            gen_sets: size of the generation ring; 1 (default) is the
                plain non-generational log.
            lane_sockets: NUMA home socket per lane region when creating
                (default: the placer spreads them round-robin, or all 0);
                on reopen the durable tags decide and a conflicting list
                raises.
            lane_cpu_sockets: explicit CPU socket per lane — pins where
                each lane *executes*, overriding the placer (benchmarks
                force far-socket-only placement with this).
            placer: :class:`~repro.io.placer.LanePlacer` consulted for
                region spreading, CPU placement and dynamic group-commit
                sizing. Defaults to the pool's placer on a multi-socket
                pool; pass ``placer=False`` to disable.
        """
        self.pool = pool
        self.name = name
        self.group_commit = max(1, int(group_commit))
        self.lane_id_base = int(lane_id_base)
        #: spill scheduler registered via ``attach_spill`` (generational)
        self._spill = None
        if placer is None and getattr(pool, "sockets", 1) > 1:
            placer = pool.placer()
        self._placer = placer or None
        self._lane_cpu_override = lane_cpu_sockets

        gen_rec = pool.directory.lookup(f"{name}.gen")
        if (gen_rec is None and int(gen_sets) > 1
                and pool.directory.lookup(f"{name}.lane0") is not None):
            # upgrading in place would create a fresh empty ring and
            # orphan every committed entry in the existing lane regions
            raise ValueError(
                f"multilog {name!r} exists as a non-generational log; it "
                f"cannot be reopened with gen_sets={gen_sets} (recreate "
                f"it under a new name, or open without gen_sets)")
        self.generational = gen_rec is not None or int(gen_sets) > 1
        if self.generational:
            self._init_generational(lanes, capacity, technique, cfg,
                                    int(gen_sets), lane_sockets,
                                    existing=gen_rec is not None)
            return

        self.gen_sets = 1
        existing = 0
        while pool.directory.lookup(f"{name}.lane{existing}") is not None:
            existing += 1
        if existing:
            if lanes is not None and lanes != existing:
                raise ValueError(
                    f"multilog {name!r} has {existing} durable lanes, "
                    f"caller asked for {lanes}")
            self.lanes = existing
            self.handles = [pool.log(f"{name}.lane{i}", technique=technique,
                                     cfg=cfg)
                            for i in range(existing)]
        else:
            if capacity is None:
                raise ValueError(
                    f"creating multilog {name!r} requires capacity=")
            self.lanes = int(lanes) if lanes is not None else 2
            if self.lanes < 1:
                raise ValueError("lanes must be >= 1")
            per_lane = pool.geometry.pad_to_block(
                max(1, int(capacity) // self.lanes))
            # Fail BEFORE creating lane 0: a failure mid-loop would leak
            # durable lane regions (directory allocations are permanent),
            # leaving a partially-striped log behind.
            last_name = f"{name}.lane{self.lanes - 1}"
            if len(last_name.encode("utf-8")) > 20:
                raise ValueError(
                    f"multilog name {name!r} too long for {self.lanes} "
                    f"lanes ({last_name!r} exceeds the 20 B region-name cap)")
            if self.lanes * per_lane > pool.free_bytes:
                raise ValueError(
                    f"multilog {name!r}: {self.lanes} lanes x {per_lane} B "
                    f"exceed the pool's {pool.free_bytes} free bytes")
            homes = self._home_sockets(lane_sockets)
            self.handles = [
                pool.log(f"{name}.lane{i}", capacity=per_lane,
                         technique=technique or "zero", cfg=cfg,
                         socket=homes[i])
                for i in range(self.lanes)
            ]
        self.technique = self.handles[0].technique
        self._setup_placement(lane_sockets)
        self._pending: List[List[bytes]] = [[] for _ in range(self.lanes)]
        self._pending_bytes: List[int] = [0] * self.lanes
        self._rr = 0
        self.recovered = self._merge_recovery()
        self._next_glsn = self.recovered.next_glsn
        self._live: List[Tuple[int, bytes]] = list(
            zip(self.recovered.glsns, self.recovered.entries))

    # ------------------------------------------------------- NUMA placement

    def _home_sockets(self, requested: Optional[List[int]]) -> List[int]:
        """Home socket per lane region for the create path: the caller's
        list, else the placer's round-robin spread, else all socket 0."""
        if requested is not None:
            if len(requested) != self.lanes:
                raise ValueError(
                    f"multilog {self.name!r}: {len(requested)} lane_sockets "
                    f"for {self.lanes} lanes")
            return [int(s) for s in requested]
        if self._placer is not None:
            return self._placer.spread(self.lanes)
        return [0] * self.lanes

    def _setup_placement(self, requested: Optional[List[int]]) -> None:
        """Resolve, from the (now open) lane handles' durable socket tags,
        where each lane's bytes live and which CPU socket runs it; seed
        the per-lane adaptive group-commit sizes."""
        #: NUMA home socket of each lane's region (durable directory tag)
        self.lane_sockets: List[int] = [h.record.socket for h in self.handles]
        if requested is not None and [int(s) for s in requested] != self.lane_sockets:
            raise ValueError(
                f"multilog {self.name!r} lanes live on sockets "
                f"{self.lane_sockets}, caller asked for {list(requested)} — "
                f"home sockets are fixed at creation")
        if self._lane_cpu_override is not None:
            if len(self._lane_cpu_override) != self.lanes:
                raise ValueError(
                    f"multilog {self.name!r}: {len(self._lane_cpu_override)} "
                    f"lane_cpu_sockets for {self.lanes} lanes")
            #: CPU socket each lane executes on
            self.lane_cpu: List[int] = [int(s) for s in self._lane_cpu_override]
        elif self._placer is not None:
            self.lane_cpu = self._placer.place(self.lane_sockets)
        else:
            self.lane_cpu = list(self.lane_sockets)   # run every lane near
        #: per-lane group-commit target (adapted by the placer; see
        #: LanePlacer.adapt_k) — starts at the configured base
        self._lane_k: List[int] = [self.group_commit] * self.lanes

    def lane_k(self, lane: Optional[int] = None):
        """Stable read-only view of the adaptive group-commit state.

        With no argument, returns a fresh list of every lane's current
        group-commit target (== ``group_commit`` everywhere until a
        placer adapts them to each lane's observed submit rate and
        socket distance).  With ``lane``, returns that single lane's
        target as an int.  This is the public surface consumers such as
        the serve-layer admission controller should read — the backing
        ``_lane_k`` array is private and may change representation.
        """
        if lane is None:
            return list(self._lane_k)
        return int(self._lane_k[lane])

    @property
    def lane_group_commit(self) -> List[int]:
        """Current per-lane group-commit sizes; alias for
        :meth:`lane_k` kept for existing callers."""
        return self.lane_k()

    # ------------------------------------------------------- generations

    def _init_generational(self, lanes: Optional[int],
                           capacity: Optional[int],
                           technique: Optional[str],
                           cfg: Optional[LogConfig],
                           gen_sets: int,
                           lane_sockets: Optional[List[int]] = None,
                           *, existing: bool) -> None:
        """Create or reopen the generation ring + header (see class doc).
        Lane ``i`` lives on the same home socket in every generation set,
        so placement survives rolls."""
        pool = self.pool
        name = self.name
        cl = pool.geometry.cache_line
        if existing:
            self._gen_root = pool.raw(f"{name}.gen")
            hdr = self._read_gen_header()
            if hdr is None:
                raise ValueError(f"multilog {name!r}: generation header "
                                 f"region exists but holds no valid slot")
            self._gen_counter, self.current_gen, self.retired_upto, \
                k, n_lanes = hdr
            if gen_sets > 1 and gen_sets != k:
                raise ValueError(
                    f"multilog {name!r} has {k} durable generation sets, "
                    f"caller asked for {gen_sets}")
            if lanes is not None and lanes != n_lanes:
                raise ValueError(
                    f"multilog {name!r} has {n_lanes} durable lanes, "
                    f"caller asked for {lanes}")
            self.gen_sets, self.lanes = k, n_lanes
            self._sets = [
                [pool.log(f"{name}.g{j}.lane{i}", technique=technique,
                          cfg=cfg) for i in range(self.lanes)]
                for j in range(self.gen_sets)
            ]
        else:
            if capacity is None:
                raise ValueError(
                    f"creating multilog {name!r} requires capacity=")
            if gen_sets < 2:
                raise ValueError("generational logs need gen_sets >= 2")
            self.gen_sets = gen_sets
            self.lanes = int(lanes) if lanes is not None else 2
            if self.lanes < 1:
                raise ValueError("lanes must be >= 1")
            per_lane = pool.geometry.pad_to_block(
                max(1, int(capacity) // self.lanes))
            last_name = f"{name}.g{self.gen_sets - 1}.lane{self.lanes - 1}"
            if len(last_name.encode("utf-8")) > 20:
                raise ValueError(
                    f"multilog name {name!r} too long for {self.gen_sets} "
                    f"generation sets x {self.lanes} lanes ({last_name!r} "
                    f"exceeds the 20 B region-name cap)")
            need = self.gen_sets * self.lanes * per_lane + 2 * cl
            if need > pool.free_bytes:
                raise ValueError(
                    f"multilog {name!r}: {self.gen_sets} generation sets x "
                    f"{self.lanes} lanes x {per_lane} B exceed the pool's "
                    f"{pool.free_bytes} free bytes")
            # Lane regions first, header last: the header's single-line
            # entry commit is the atomic creation point, and re-running
            # this path after a crash mid-creation reopens/creates the
            # lane regions idempotently.
            homes = self._home_sockets(lane_sockets)
            self._sets = [
                [pool.log(f"{name}.g{j}.lane{i}", capacity=per_lane,
                          technique=technique or "zero", cfg=cfg,
                          socket=homes[i])
                 for i in range(self.lanes)]
                for j in range(self.gen_sets)
            ]
            self._gen_root = pool.raw(f"{name}.gen", nbytes=2 * cl)
            self._gen_counter = 0
            self.current_gen = 1
            self.retired_upto = 0
            self._write_gen_header(1, 0)

        self._active = (self.current_gen - 1) % self.gen_sets
        self.handles = self._sets[self._active]
        self.technique = self.handles[0].technique
        self._setup_placement(lane_sockets)
        self._pending = [[] for _ in range(self.lanes)]
        self._pending_bytes = [0] * self.lanes
        self._rr = 0
        # Which ring slot holds which PMem-resident generation. Slots
        # holding only retired (spilled/discarded) generations are
        # conservatively dirty: a crash may have landed between the
        # retired-watermark commit and the slot re-zero.
        occupied: Dict[int, int] = {
            (g - 1) % self.gen_sets: g
            for g in range(self.retired_upto + 1, self.current_gen + 1)
        }
        self._sealed: Dict[int, List[Tuple[int, bytes]]] = {}
        self._slot_clean: Dict[int, bool] = {}
        for j in range(self.gen_sets):
            self._slot_clean[j] = False
            g = occupied.get(j)
            if g is None or j == self._active:
                continue
            rec = self._merge_recovery(self._sets[j])
            self._sealed[g] = list(zip(rec.glsns, rec.entries))
        self.recovered = self._merge_recovery()
        self._next_glsn = self.recovered.next_glsn
        self._live = list(zip(self.recovered.glsns, self.recovered.entries))

    def _read_gen_header(self) -> Optional[Tuple[int, int, int, int, int]]:
        """Durable generation header: max-counter slot of the ping-pong
        pair, or ``None`` if neither slot was ever written."""
        img = self._gen_root.durable_view()
        cl = self.pool.geometry.cache_line
        best = None
        for slot in range(2):
            rec = _GENHDR.unpack_from(img, slot * cl)
            if rec[0] and (best is None or rec[0] > best[0]):
                best = rec
        return best

    def _write_gen_header(self, current_gen: int, retired_upto: int) -> None:
        """Durably advance the generation header (one barrier; the slot
        fits a single cache line, so the commit is atomic)."""
        from repro.core.persist import FlushKind
        self._gen_counter += 1
        slot = self._gen_counter % 2
        cl = self.pool.geometry.cache_line
        self._gen_root.store(
            slot * cl,
            _GENHDR.pack(self._gen_counter, current_gen, retired_upto,
                         self.gen_sets, self.lanes),
            streaming=True)
        self._gen_root.persist(slot * cl, _GENHDR.size, kind=FlushKind.NT)
        self.current_gen = current_gen
        self.retired_upto = retired_upto

    @property
    def generation(self) -> int:
        """The live generation number (1 for a non-generational log)."""
        return self.current_gen if self.generational else 1

    def attach_spill(self, spill) -> None:
        """Register the :class:`repro.tier.SpillScheduler` that retires
        sealed generations to SSD (:meth:`roll` enqueues onto it, and
        reads of retired generations route through it).

        Sealed-but-unretired generations recovered at open time are
        re-enqueued here: a crash that landed between a roll and its
        drain must not leave the generation orphaned — without the
        re-enqueue, the next ring reuse would discard it while the
        watermark advanced past it."""
        self._spill = spill
        for g in sorted(getattr(self, "_sealed", {})):
            spill.enqueue_generation(self, g)

    def roll(self, spill=None) -> int:
        """Seal the live generation and start the next one. Returns the
        sealed generation's number.

        The sequence is: group-commit everything pending (the sealed
        content is now durable in the current lane set), make sure the
        target ring slot is free — if it still holds an unretired sealed
        generation, drain the spill scheduler (or, with no scheduler,
        advance the retired watermark: plain truncation) and re-zero it —
        then atomically advance the header to generation ``g+1``. A crash
        anywhere in between recovers consistently: before the header
        commit the old generation is still live; after it, the new
        (empty) one is.

        The sealed generation stays PMem-resident and readable
        (:meth:`read_generation`) until the scheduler durably retires it.
        """
        if not self.generational:
            raise RuntimeError(
                f"multilog {self.name!r} is not generational; create it "
                f"with gen_sets >= 2 to roll")
        spill = spill if spill is not None else self._spill
        self.commit()
        g = self.current_gen
        sealed = list(self._live)
        nxt = g + 1
        target = (nxt - 1) % self.gen_sets
        evictee = nxt - self.gen_sets   # generation previously in that slot
        if evictee >= 1 and evictee > self.retired_upto:
            if spill is not None:
                spill.drain()
            if evictee > self.retired_upto:
                # No scheduler (or the drain did not cover it): discard —
                # the ring slot is reclaimed and the generation's history
                # is gone, exactly the old reset() truncation semantics.
                self._write_gen_header(g, evictee)
                self._sealed.pop(evictee, None)
        if not self._slot_clean.get(target, False):
            for h in self._sets[target]:
                h.reset()
        self._sealed[g] = sealed
        self._write_gen_header(nxt, self.retired_upto)
        self._active = target
        self.handles = self._sets[target]
        self._slot_clean[target] = False
        self._pending = [[] for _ in range(self.lanes)]
        self._pending_bytes = [0] * self.lanes
        self._rr = 0
        self._next_glsn = 1
        self._live = []
        if spill is not None:
            spill.enqueue_generation(self, g)
        return g

    def mark_retired(self, gen: int) -> None:
        """Durably advance the retired watermark to ``gen`` (called by the
        spill scheduler once the generation is safely on SSD — SSD flush
        and map record first, THEN this; the watermark is what recovery
        consults, so a crash in between still recovers from PMem). Newly
        retired ring slots are re-zeroed for reuse."""
        if not self.generational:
            raise RuntimeError("not a generational multilog")
        if gen >= self.current_gen:
            raise ValueError(f"cannot retire the live generation {gen}")
        if gen <= self.retired_upto:
            return
        old = self.retired_upto
        self._write_gen_header(self.current_gen, gen)
        for g in range(old + 1, gen + 1):
            self._sealed.pop(g, None)
            slot = (g - 1) % self.gen_sets
            if slot == self._active:
                continue
            for h in self._sets[slot]:
                h.reset()
            self._slot_clean[slot] = True

    def sealed_generations(self) -> Dict[int, List[bytes]]:
        """PMem-resident sealed generations: ``{gen: [payload, ...]}`` for
        every generation that is sealed but not yet retired to SSD."""
        if not self.generational:
            return {}
        return {g: [p for _, p in items]
                for g, items in sorted(self._sealed.items())}

    def read_generation(self, gen: int, *, spill=None
                        ) -> Tuple[str, List[bytes]]:
        """Read one generation's payloads and report where they came from.

        Returns ``("pmem", entries)`` for the live or a sealed-but-
        unretired generation (recovered from the lane regions) and
        ``("ssd", entries)`` for a retired one (read through the spill
        scheduler, checksum-verified). The header's retired watermark
        decides — never both tiers, which is the crash-during-spill
        invariant ``tests/test_tier_props.py`` asserts."""
        if not self.generational:
            raise RuntimeError("not a generational multilog")
        if gen < 1 or gen > self.current_gen:
            raise ValueError(f"no generation {gen} (live is "
                             f"{self.current_gen})")
        if gen > self.retired_upto:
            if gen == self.current_gen:
                return "pmem", [p for _, p in self._live]
            return "pmem", [p for _, p in self._sealed.get(gen, [])]
        spill = spill if spill is not None else self._spill
        if spill is None:
            raise RuntimeError(
                f"generation {gen} is retired to SSD; pass the spill "
                f"scheduler that owns the spill map")
        return "ssd", spill.read_generation(self.name, gen)

    # ------------------------------------------------------------ recovery

    @staticmethod
    def _global_prefix(per_lane_entries: List[List[bytes]]
                       ) -> Tuple[Dict[int, Tuple[int, bytes]], int]:
        """Decode each lane's framed entries and find the longest
        contiguous global-LSN prefix 1..m present across lanes. Returns
        (glsn -> (lane, payload), m). The single source of truth for the
        merge invariant — used by both open-time recovery and the
        read-only :meth:`recover` preview."""
        items: Dict[int, Tuple[int, bytes]] = {}
        for lane_i, entries in enumerate(per_lane_entries):
            for raw in entries:
                (glsn,) = _GLSN.unpack_from(raw)
                items[glsn] = (lane_i, bytes(raw[_GLSN.size:]))
        m = 0
        while (m + 1) in items:
            m += 1
        return items, m

    def _merge_recovery(self, handles=None) -> MultiLogRecovered:
        handles = self.handles if handles is None else handles
        per_lane = [h.recovered for h in handles]
        items, m = self._global_prefix([rec.entries for rec in per_lane])
        keep = [0] * len(handles)
        for g in range(1, m + 1):
            keep[items[g][0]] += 1
        discarded = 0
        for lane_i, (h, rec) in enumerate(zip(handles, per_lane)):
            extra = len(rec.entries) - keep[lane_i]
            if extra > 0:
                discarded += extra
                self._truncate_lane(h, rec, keep[lane_i])
        return MultiLogRecovered(
            entries=[items[g][1] for g in range(1, m + 1)],
            glsns=list(range(1, m + 1)),
            next_glsn=m + 1,
            per_lane=[len(r.entries) for r in per_lane],
            discarded=discarded,
            lanes=[items[g][0] for g in range(1, m + 1)],
        )

    def _truncate_lane(self, handle, rec: RecoveredLog, kept: int) -> None:
        """Durably re-zero a lane's tail beyond its ``kept``-entry prefix,
        and rewind the writer, so discarded global LSNs can be re-issued."""
        keep_end = rec.offsets[kept] if kept < len(rec.offsets) else rec.tail
        span = rec.tail - keep_end
        pm = self.pool.pmem
        if span > 0:
            pm.store(handle.base + keep_end, np.zeros(span, dtype=np.uint8),
                     streaming=True)
            pm.sfence()
        w = handle._writer
        w.tail = keep_end
        w.next_lsn = kept + 1
        if isinstance(w, HeaderLog):
            # a stale (larger) durable size slot is harmless: recovery
            # stops at the zeroed bytes regardless (n == 0 breaks the scan)
            w._size = keep_end - w._data_start()
        handle.recovered = RecoveredLog(
            rec.entries[:kept], rec.lsns[:kept], keep_end, kept + 1,
            rec.offsets[:kept])

    # -------------------------------------------------------------- append

    def append(self, payload: bytes, *, sync: bool = False) -> int:
        """Submit one entry; returns its global LSN immediately.

        The entry becomes durable at the next :meth:`commit` (``sync=True``
        issues one right away). A lane whose buffer reaches ``group_commit``
        entries commits that batch automatically."""
        lane = self._rr
        # Reserve capacity at SUBMIT time: the lane's buffered batch must
        # always fit its region, so a later commit()/roll() can never
        # fail with "log full" (the invariant the KV auto-checkpoint
        # path relies on). If this entry would overflow the reservation,
        # flush the partial batch first; if it still does not fit, the
        # lane is genuinely full and nothing was submitted.
        w = self.handles[lane]._writer
        framed = w.stride(_GLSN.size + len(payload))
        if self._pending_bytes[lane] + framed > w.capacity - w.tail:
            self._commit_lane(lane, "capacity")
            if framed > w.capacity - w.tail:
                raise RuntimeError("log full")
        glsn = self._next_glsn
        self._next_glsn += 1
        self._rr = (self._rr + 1) % self.lanes
        self._pending[lane].append(_GLSN.pack(glsn) + payload)
        self._pending_bytes[lane] += framed
        if self.generational:
            self._live.append((glsn, bytes(payload)))
        if sync:
            self.commit()
        elif len(self._pending[lane]) >= self._lane_k[lane]:
            self._commit_lane(lane, "auto")
        return glsn

    def _commit_lane(self, lane: int, cause: str = "explicit") -> None:
        batch = self._pending[lane]
        if not batch:
            return
        with self.pool.pmem.lane(self.lane_id_base + lane,
                                 socket=self.lane_cpu[lane]):
            self.handles[lane].append_batch(batch)
        self._pending[lane] = []
        self._pending_bytes[lane] = 0
        if self._placer is not None:
            # dynamic group-commit sizing: a lane whose batches keep
            # filling grows its k (throughput-bound); one the caller
            # keeps fencing early shrinks it; remote lanes keep a higher
            # floor to amortize their costlier barriers
            self._lane_k[lane] = self._placer.adapt_k(
                self._lane_k[lane], len(batch), cause,
                remote=self.lane_cpu[lane] != self.lane_sockets[lane],
                base=self.group_commit)

    def commit(self) -> None:
        """Group-commit every buffered entry on every lane. After this
        returns, all previously appended entries are durable."""
        for lane in range(self.lanes):
            self._commit_lane(lane, "explicit")

    def reset(self) -> None:
        """Truncate in place: durably re-zero every (active-set) lane and
        restart the global LSN at 1. Pending un-committed entries are
        dropped. Generational logs should prefer :meth:`roll`, which
        preserves the sealed generation; ``reset`` is the bare per-lane
        primitive beneath it."""
        for h in self.handles:
            h.reset()
        self._pending = [[] for _ in range(self.lanes)]
        self._pending_bytes = [0] * self.lanes
        self._rr = 0
        self._next_glsn = 1
        self._live = []
        self.recovered = MultiLogRecovered([], [], 1, [0] * self.lanes, 0)

    def close(self, *, commit: bool = True) -> None:
        """Commit pending entries (unless ``commit=False``) and close
        every lane handle (all generation sets included)."""
        if commit:
            self.commit()
        for h in (h for s in getattr(self, "_sets", [self.handles])
                  for h in s):
            h.close()

    # --------------------------------------------------------------- misc

    @property
    def pending(self) -> int:
        """Entries buffered (submitted, not yet durable) across lanes."""
        return sum(len(b) for b in self._pending)

    @property
    def next_glsn(self) -> int:
        """Global LSN the next append will receive."""
        return self._next_glsn

    @property
    def next_lsn(self) -> int:
        """Alias for :attr:`next_glsn` — lets consumers treat a MultiLog
        and a single-lane :class:`~repro.pool.LogHandle` uniformly."""
        return self._next_glsn

    def recover(self) -> MultiLogRecovered:
        """Re-run merged recovery against the current durable image (what
        a restart would see right now). Read-only: no truncation repair —
        the returned prefix is what a fresh open would keep."""
        items, m = self._global_prefix(
            [h.recover().entries for h in self.handles])
        return MultiLogRecovered(
            entries=[items[g][1] for g in range(1, m + 1)],
            glsns=list(range(1, m + 1)),
            next_glsn=m + 1,
            per_lane=[],
            discarded=len(items) - m,
            lanes=[items[g][0] for g in range(1, m + 1)],
        )

    def stats(self):
        """Pool-wide op-count delta since the first lane handle opened."""
        return self.handles[0].stats()

    def reset_stats(self) -> None:
        """Restart every lane handle's stats window."""
        for h in self.handles:
            h.reset_stats()
