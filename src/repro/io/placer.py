"""NUMA-aware lane placement — which CPU socket runs which lane.

The paper's bandwidth results are per-socket; Izraelevitz et al. ("Basic
Performance Measurements of the Intel Optane DC Persistent Memory
Module", arXiv:1903.05714) measure far-socket PMem access at roughly
2-3x the cost of near-socket: every store crosses the UPI interconnect,
the DIMM's write-combining buffer merges less, and persist barriers wait
for the remote ADR domain. The functional layer models this as *home*
sockets on byte ranges (:meth:`repro.core.pmem.PMem.set_home`, threaded
through the pool directory's per-region socket tags) and CPU sockets on
lanes (``PMem.lane(i, socket=s)``); the cost model charges a lane's
remote work the ``numa_remote_*`` multipliers.

:class:`LanePlacer` is the policy above that mechanism, consulted by
:class:`~repro.io.multilog.MultiLog`, :class:`~repro.io.flushq.FlushQueue`
and :class:`~repro.io.engine.IOEngine` (automatically on any multi-socket
pool — ``pool.placer()``):

* :meth:`spread` — where to *create* lane regions: round-robin over the
  sockets, so every lane can later be served by a near-socket CPU within
  the per-socket lane budget.
* :meth:`place` — which CPU socket *runs* each lane: near its region's
  home socket while that socket has CPU lane capacity left, falling back
  to a remote socket only under load (more lanes than near capacity).
  Placement is a performance hint, never a durability input — recovery
  is byte-identical under any placement (asserted in
  ``tests/test_numa.py``).
* :meth:`adapt_k` — dynamic group-commit sizing: a lane whose batches
  keep filling (throughput-bound — submits arrive faster than commits)
  doubles its batch toward ``k_max``; a lane mostly cut short by
  explicit commits (latency-bound) halves back toward 1. Remote lanes
  keep a higher floor: their barriers cost
  ``numa_remote_barrier_mult`` x as much, so twice the appends should
  share each one.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

from repro.core.costmodel import COST_MODEL, PMemCostModel

__all__ = ["LanePlacer"]

#: CPU lanes a single socket serves at full near-socket speed before the
#: placer starts overflowing to remote sockets. Matches the cost model's
#: ``wc_defeat_lanes``: past ~4 concurrent writers per socket the DIMM's
#: write-combining buffer stops merging anyway (Fig. 2a), so there is no
#: near-socket throughput left to protect.
DEFAULT_CPU_LANES_PER_SOCKET = 4


class LanePlacer:
    """Near-socket-first lane placement + adaptive group-commit sizing."""

    def __init__(self, pmem, *,
                 cpu_lanes_per_socket: int = DEFAULT_CPU_LANES_PER_SOCKET,
                 cost_model: PMemCostModel = COST_MODEL) -> None:
        """Bind to a :class:`~repro.core.pmem.PMem`'s socket topology.

        Args:
            pmem: the PMem whose ``sockets`` count defines the topology.
            cpu_lanes_per_socket: near-socket CPU lane budget per socket;
                lanes beyond it are placed remote (the "under load"
                fallback).
            cost_model: supplies the remote multipliers the adaptive
                group-commit floor is derived from.
        """
        self.pmem = pmem
        self.cpu_lanes_per_socket = max(1, int(cpu_lanes_per_socket))
        self.cost_model = cost_model

    @property
    def sockets(self) -> int:
        """Socket count of the bound topology."""
        return max(1, self.pmem.sockets)

    # ------------------------------------------------------------ placement

    def spread(self, n_lanes: int) -> List[int]:
        """Home sockets for ``n_lanes`` *new* lane regions: round-robin
        over the topology, so each socket serves an equal share and
        :meth:`place` can keep every lane near until the per-socket CPU
        budget is exhausted."""
        return [i % self.sockets for i in range(int(n_lanes))]

    def place(self, region_sockets: Sequence[int]) -> List[int]:
        """CPU socket for each lane, given its region's home socket.

        Near-socket first: a lane runs on its region's socket while that
        socket has CPU capacity (``cpu_lanes_per_socket``) left. Only
        under load — more lanes homed on a socket than it can serve —
        do the overflow lanes fall back to the socket with the most
        remaining capacity (remote, paying the Izraelevitz penalty).
        With *every* socket saturated, lanes oversubscribe their home
        socket instead: going remote then adds interconnect cost without
        adding CPU capacity (the cost model's oversaturation decay is
        the operative penalty there)."""
        free = {s: self.cpu_lanes_per_socket for s in range(self.sockets)}
        cpu: List[Optional[int]] = [None] * len(region_sockets)
        for i, home in enumerate(region_sockets):
            near = min(max(0, int(home)), self.sockets - 1)
            if free[near] > 0:
                cpu[i] = near
                free[near] -= 1
        for i, c in enumerate(cpu):
            if c is not None:
                continue
            best = max(free, key=lambda s: free[s])
            if free[best] > 0:
                free[best] -= 1
                cpu[i] = best       # remote fallback, only under load
            else:
                cpu[i] = min(max(0, int(region_sockets[i])),
                             self.sockets - 1)   # saturated: stay near
        return cpu  # type: ignore[return-value]

    def distance(self, cpu_socket: int, home_socket: int) -> int:
        """0 for a near-socket lane, 1 for a remote one."""
        return 0 if int(cpu_socket) == int(home_socket) else 1

    # ------------------------------------------------- dynamic group commit

    def adapt_k(self, k: int, batch_len: int, cause: str, *,
                remote: bool, base: int) -> int:
        """Next group-commit size for a lane that just committed a batch.

        Args:
            k: the lane's current batch-size target.
            batch_len: entries in the batch just committed.
            cause: why the commit happened — ``"auto"`` (the buffer
                filled: throughput-bound), ``"capacity"`` (submit-time
                reservation forced an early flush: also throughput-bound)
                or ``"explicit"`` (caller ``commit()``/``sync``:
                latency-bound when the batch was still small).
            remote: whether the lane runs far from its region's socket.
            base: the log's configured ``group_commit`` (scales the caps).

        ``base == 1`` is a *durability contract* — the caller wants every
        append durable at return (the PersistentKV default) — so the
        placer never batches beyond it; adaptive sizing engages only for
        callers that already opted into batched durability (base >= 2).
        """
        base = max(1, int(base))
        if base == 1:
            return 1
        floor = min(2 * base, base + 2) if remote else 1
        cap = max(8 * base, floor)
        if cause in ("auto", "capacity") and batch_len >= k:
            # submits outpace commits: amortize more appends per barrier
            k = min(cap, max(k * 2, floor))
        elif cause == "explicit" and batch_len * 2 <= k:
            # the caller keeps fencing half-empty batches: shrink toward
            # per-append durability
            k = max(floor, (k + 1) // 2)
        return max(floor, min(int(k), cap))
