"""``repro.io`` — lane-partitioned concurrent I/O engine.

The paper's headline results are concurrency results: bandwidth scales
with writer threads until the device's write-combining buffer is defeated
(Fig. 2), and both logging and page flushing are evaluated at 1-7 threads
(Figs. 5-6). This package refactors the write path from "caller touches
PMem directly" to "caller submits to an engine that schedules lanes,
batches and barriers":

- :mod:`repro.io.multilog` — :class:`MultiLog`: appends striped over N
  per-lane Zero/Classic/Header logs with a global LSN, group-commit
  batching (k appends per barrier), merge-on-recovery reconstructing the
  exact durable global prefix across lanes; ``gen_sets >= 2`` adds the
  generation ring (``roll()`` seals, the spill tier retires to SSD).
- :mod:`repro.io.flushq`   — :class:`FlushQueue`: coalescing flush queue
  in front of a :class:`~repro.core.pageflush.PageStore`; each epoch is
  lane-partitioned and the Hybrid crossover uses the *actual* number of
  active lanes; with ``spill=`` attached, epochs that outgrow the PMem
  slot budget evict cold slots to the SSD tier instead of raising. It
  is also the sole write-back path of the DRAM buffer manager
  (:class:`~repro.cache.BufferManager`): dirty frames drain as one
  epoch, clock-evicted dirty frames park in the pending set.
- :mod:`repro.io.engine`   — :class:`IOEngine`: facade allocating
  non-overlapping lane ids and converting per-lane op counts to modeled
  wall-clock (``costmodel.engine_time_ns``: max over lanes, Fig. 2
  concurrency curve, write-combining-defeat penalty).
- :mod:`repro.io.placer`   — :class:`LanePlacer`: NUMA-aware lane
  placement — spreads new lane regions over the sockets, runs each lane
  on a CPU socket near its region (falling back to remote sockets only
  under load), and adapts per-lane group-commit sizes to the observed
  submit rate and socket distance. Consulted automatically on any
  multi-socket pool.

Consumers: ``pool.multilog(...)`` / ``pool.wal(..., lanes=N)`` for the
training WAL, ``CheckpointManager`` (page flushes batched per save
epoch), ``PersistentKV`` (checkpoint flushing with ``flush_lanes``), and
``AsyncFlusher`` (one worker lane per checkpoint shard).
"""

from repro.io.engine import IOEngine  # noqa: F401
from repro.io.flushq import EpochReport, FlushQueue  # noqa: F401
from repro.io.multilog import MultiLog, MultiLogRecovered  # noqa: F401
from repro.io.placer import LanePlacer  # noqa: F401
